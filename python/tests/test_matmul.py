"""L1 correctness: Pallas matmul vs pure-jnp oracle, including a
hypothesis sweep over shapes and tile geometries."""

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import matmul, matmul_batched
from compile.kernels.ref import matmul_batched_ref, matmul_ref


def rand(shape, seed=0):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.standard_normal(shape, dtype=np.float32))


@pytest.mark.parametrize("bm,bn,bk", [(16, 32, 8), (32, 32, 16), (64, 64, 16), (128, 64, 32)])
def test_matmul_matches_ref_square(bm, bn, bk):
    x, w = rand((256, 128), 1), rand((128, 256), 2)
    got = matmul(x, w, bm=bm, bn=bn, bk=bk)
    np.testing.assert_allclose(got, matmul_ref(x, w), rtol=1e-5, atol=1e-4)


def test_matmul_rectangular():
    x, w = rand((64, 512), 3), rand((512, 32), 4)
    got = matmul(x, w, bm=32, bn=32, bk=32)
    np.testing.assert_allclose(got, matmul_ref(x, w), rtol=1e-5, atol=1e-4)


def test_matmul_batched():
    x, w = rand((4, 64, 64), 5), rand((4, 64, 64), 6)
    got = matmul_batched(x, w, bm=32, bn=32, bk=16)
    np.testing.assert_allclose(got, matmul_batched_ref(x, w), rtol=1e-5, atol=1e-4)


def test_matmul_rejects_nondividing_tiles():
    x, w = rand((100, 64), 7), rand((64, 64), 8)
    with pytest.raises(AssertionError):
        matmul(x, w, bm=64, bn=64, bk=16)


def test_matmul_identity():
    x = rand((64, 64), 9)
    eye = jnp.eye(64, dtype=jnp.float32)
    np.testing.assert_allclose(matmul(x, eye, bm=32, bn=32, bk=16), x, rtol=1e-6, atol=1e-6)


def test_matmul_zeros():
    x = rand((32, 32), 10)
    z = jnp.zeros((32, 32), jnp.float32)
    assert float(jnp.abs(matmul(x, z, bm=16, bn=16, bk=16)).max()) == 0.0


@settings(max_examples=25, deadline=None)
@given(
    mi=st.integers(1, 4),
    ni=st.integers(1, 4),
    ki=st.integers(1, 4),
    bm=st.sampled_from([16, 32]),
    bn=st.sampled_from([16, 32]),
    bk=st.sampled_from([8, 16]),
    seed=st.integers(0, 2**31 - 1),
)
def test_matmul_hypothesis_sweep(mi, ni, ki, bm, bn, bk, seed):
    """Any (multiple-of-tile) shape x any tile geometry matches the oracle."""
    m, n, k = mi * bm, ni * bn, ki * bk
    x, w = rand((m, k), seed), rand((k, n), seed + 1)
    got = matmul(x, w, bm=bm, bn=bn, bk=bk)
    np.testing.assert_allclose(got, matmul_ref(x, w), rtol=1e-4, atol=1e-4)
