"""L2 model-layer tests: the jax graphs the AOT pipeline lowers."""

import numpy as np
import jax
import jax.numpy as jnp

from compile import model, schedules
from compile.kernels import ref


def rand(shape, seed=0):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.standard_normal(shape, dtype=np.float32))


def test_mm_model_2d_and_batched():
    fn = model.mm_model(32, 32, 16)
    x, w = rand((64, 64), 1), rand((64, 64), 2)
    (out,) = fn(x, w)
    np.testing.assert_allclose(out, ref.matmul_ref(x, w), rtol=1e-4, atol=1e-3)

    xb, wb = rand((2, 64, 64), 3), rand((2, 64, 64), 4)
    (outb,) = fn(xb, wb)
    np.testing.assert_allclose(outb, ref.matmul_batched_ref(xb, wb), rtol=1e-4, atol=1e-3)


def test_mv_model():
    fn = model.mv_model(64, 64)
    w, x = rand((256, 128), 5), rand((128,), 6)
    (out,) = fn(w, x)
    np.testing.assert_allclose(out, ref.matvec_ref(w, x), rtol=1e-4, atol=1e-3)


def test_conv_model():
    fn = model.conv_model(1, 0, 64, 32, 16)
    x, w = rand((2, 8, 8, 32), 7), rand((1, 1, 32, 32), 8)
    (out,) = fn(x, w)
    np.testing.assert_allclose(out, ref.conv2d_ref(x, w), rtol=1e-4, atol=1e-3)


def test_example_args_match_models():
    """Every palette entry's example args must be accepted by its model
    (the invariant `make artifacts` depends on)."""
    for spec in schedules.palette()[::7]:  # sample the palette
        fn = model.model_for(spec)
        args = model.example_args(spec)
        lowered = jax.jit(fn).lower(*args)  # must not raise
        assert lowered is not None


def test_model_for_rejects_unknown_op():
    import dataclasses
    import pytest
    bad = schedules.ArtifactSpec("x", "unknown_op", (1,), 1, 1, 1)
    with pytest.raises(ValueError):
        model.model_for(bad)
