"""L1 correctness: Pallas matvec vs oracle + hypothesis sweep."""

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import matvec, matvec_batched
from compile.kernels.ref import matvec_batched_ref, matvec_ref


def rand(shape, seed=0):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.standard_normal(shape, dtype=np.float32))


@pytest.mark.parametrize("bn,bk", [(64, 64), (128, 128), (256, 64), (64, 128)])
def test_matvec_matches_ref(bn, bk):
    w, x = rand((1024, 512), 1), rand((512,), 2)
    got = matvec(w, x, bn=bn, bk=bk)
    np.testing.assert_allclose(got, matvec_ref(w, x), rtol=1e-4, atol=1e-3)


def test_matvec_batched():
    w, x = rand((8, 256, 128), 3), rand((8, 128), 4)
    got = matvec_batched(w, x, bn=64, bk=64)
    np.testing.assert_allclose(got, matvec_batched_ref(w, x), rtol=1e-4, atol=1e-3)


def test_matvec_rejects_nondividing_tiles():
    w, x = rand((100, 64), 5), rand((64,), 6)
    with pytest.raises(AssertionError):
        matvec(w, x, bn=64, bk=64)


def test_matvec_unit_vector_selects_column():
    w = rand((128, 64), 7)
    e0 = jnp.zeros((64,), jnp.float32).at[0].set(1.0)
    np.testing.assert_allclose(matvec(w, e0, bn=64, bk=64), w[:, 0], rtol=1e-6, atol=1e-6)


@settings(max_examples=25, deadline=None)
@given(
    ni=st.integers(1, 8),
    ki=st.integers(1, 8),
    bn=st.sampled_from([32, 64, 128]),
    bk=st.sampled_from([32, 64]),
    seed=st.integers(0, 2**31 - 1),
)
def test_matvec_hypothesis_sweep(ni, ki, bn, bk, seed):
    n, k = ni * bn, ki * bk
    w, x = rand((n, k), seed), rand((k,), seed + 1)
    got = matvec(w, x, bn=bn, bk=bk)
    np.testing.assert_allclose(got, matvec_ref(w, x), rtol=1e-4, atol=1e-3)
