"""AOT pipeline tests: palette sanity, model lowering, HLO text shape.

These run the same `aot.export_one` path `make artifacts` uses, on a
single cheap variant, and validate the manifest contract the Rust
artifact registry depends on.
"""

import json
import pathlib

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from compile import aot, model, schedules


def test_palette_is_nonempty_and_unique():
    pal = schedules.palette()
    assert len(pal) >= 40
    names = [s.artifact_name for s in pal]
    assert len(set(names)) == len(names), "duplicate artifact names"
    ops = {s.op for s in pal}
    assert ops == {"mm", "mv", "conv"}


def test_palette_tiles_divide_shapes():
    for s in schedules.palette():
        if s.op == "mm":
            _b, m, n, k = s.shape
            assert m % s.bm == 0 and n % s.bn == 0 and k % s.bk == 0, s
        elif s.op == "mv":
            _b, n, k = s.shape
            assert n % s.bn == 0 and k % s.bk == 0, s


def test_variant_id_matches_rust_format():
    s = schedules.palette()[0]
    assert s.variant_id == f"bm{s.bm}_bn{s.bn}_bk{s.bk}"
    assert "__" in s.artifact_name


def test_export_one_writes_parseable_hlo(tmp_path):
    spec = schedules.ArtifactSpec(
        "mm_b1_m64_n64_k64", "mm", (1, 64, 64, 64), 32, 32, 16
    )
    entry = aot.export_one(spec, tmp_path)
    text = (tmp_path / entry["file"]).read_text()
    assert text.startswith("HloModule"), text[:60]
    assert "parameter(0)" in text
    assert entry["arg_shapes"] == [[64, 64], [64, 64]]


def test_lowered_model_matches_kernel_numerics(tmp_path):
    """The lowered-and-reexecuted HLO equals the eager kernel output."""
    spec = schedules.ArtifactSpec(
        "mm_b1_m64_n64_k64", "mm", (1, 64, 64, 64), 32, 32, 16
    )
    fn = model.model_for(spec)
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal((64, 64), dtype=np.float32))
    w = jnp.asarray(rng.standard_normal((64, 64), dtype=np.float32))
    eager = fn(x, w)[0]
    compiled = jax.jit(fn).lower(x, w).compile()(x, w)[0]
    np.testing.assert_allclose(eager, compiled, rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(eager, x @ w, rtol=1e-4, atol=1e-3)


def test_manifest_contract():
    """If `make artifacts` has run, the manifest must index every file."""
    art = pathlib.Path(__file__).resolve().parents[2] / "artifacts"
    manifest = art / "manifest.json"
    if not manifest.exists():
        pytest.skip("artifacts not built yet (run `make artifacts`)")
    entries = json.loads(manifest.read_text())
    assert len(entries) == len(schedules.palette())
    for e in entries:
        assert (art / e["file"]).exists(), e["file"]
        for key in ("workload_id", "variant_id", "bm", "bn", "bk", "arg_shapes"):
            assert key in e
