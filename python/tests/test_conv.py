"""L1/L2 correctness: im2col conv2d vs lax oracle + hypothesis sweep."""

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import conv2d
from compile.kernels.ref import conv2d_ref


def rand(shape, seed=0):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.standard_normal(shape, dtype=np.float32))


def test_conv_1x1_matches_ref():
    x, w = rand((4, 14, 14, 64), 1), rand((1, 1, 64, 32), 2)
    got = conv2d(x, w, stride=1, pad=0, bm=64, bn=32, bk=16)
    np.testing.assert_allclose(got, conv2d_ref(x, w), rtol=1e-4, atol=1e-3)


def test_conv_3x3_same_matches_ref():
    # CONV1-like: 3x3 'same' convolution.
    x, w = rand((2, 7, 7, 32), 3), rand((3, 3, 32, 32), 4)
    got = conv2d(x, w, stride=1, pad=1, bm=32, bn=32, bk=16)
    np.testing.assert_allclose(got, conv2d_ref(x, w, stride=1, pad=1), rtol=1e-4, atol=1e-3)


def test_conv_strided():
    x, w = rand((2, 16, 16, 8), 5), rand((3, 3, 8, 16), 6)
    got = conv2d(x, w, stride=2, pad=1, bm=32, bn=16, bk=16)
    np.testing.assert_allclose(got, conv2d_ref(x, w, stride=2, pad=1), rtol=1e-4, atol=1e-3)


def test_conv_padding_to_tiles_is_exact():
    # Shapes whose GEMM view does NOT divide the tiles: padding path.
    x, w = rand((1, 5, 5, 3), 7), rand((3, 3, 3, 5), 8)
    got = conv2d(x, w, stride=1, pad=1, bm=64, bn=64, bk=32)
    np.testing.assert_allclose(got, conv2d_ref(x, w, stride=1, pad=1), rtol=1e-4, atol=1e-3)


@pytest.mark.parametrize("bm,bn,bk", [(64, 32, 16), (128, 64, 32)])
def test_conv2_palette_variants(bm, bn, bk):
    # The CONV2-lite artifact workload at tiny batch.
    x, w = rand((1, 56, 56, 64), 9), rand((1, 1, 64, 64), 10)
    got = conv2d(x, w, stride=1, pad=0, bm=bm, bn=bn, bk=bk)
    np.testing.assert_allclose(got, conv2d_ref(x, w), rtol=1e-4, atol=1e-3)


@settings(max_examples=15, deadline=None)
@given(
    b=st.integers(1, 3),
    hw=st.sampled_from([6, 8, 12]),
    cin=st.sampled_from([4, 8, 16]),
    cout=st.sampled_from([4, 8]),
    ks=st.sampled_from([1, 3]),
    stride=st.sampled_from([1, 2]),
    seed=st.integers(0, 2**31 - 1),
)
def test_conv_hypothesis_sweep(b, hw, cin, cout, ks, stride, seed):
    pad = ks // 2
    x, w = rand((b, hw, hw, cin), seed), rand((ks, ks, cin, cout), seed + 1)
    got = conv2d(x, w, stride=stride, pad=pad, bm=32, bn=32, bk=16)
    ref = conv2d_ref(x, w, stride=stride, pad=pad)
    np.testing.assert_allclose(got, ref, rtol=1e-4, atol=1e-3)
