"""L1 Pallas kernel: schedule-parameterized tiled matrix multiplication.

The schedule knobs mirror the Rust search space's block geometry
(`block_m`, `block_n`, `tile_k` = the `variant_id` of a searched
schedule): the grid iterates over (M/bm, N/bn) output tiles with a
reduction loop over K/bk stages, staging `bm x bk` / `bk x bn` operand
panels per step — the HBM<->VMEM schedule that CUDA kernels express with
threadblocks + shared memory (see DESIGN.md §Hardware-Adaptation).

Pallas runs with ``interpret=True``: real TPU lowering emits a Mosaic
custom-call the CPU PJRT plugin cannot execute; interpret mode lowers to
plain HLO ops that run anywhere and keep numerics identical.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _mm_kernel(x_ref, w_ref, o_ref, *, n_k_steps: int):
    """One (i, j, k) grid step: accumulate x_tile @ w_tile into the
    revisited output tile (out index_map ignores k, so the same VMEM
    tile stays resident across the reduction)."""
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    # MXU-friendly contraction: accumulate in f32.
    o_ref[...] += jnp.dot(x_ref[...], w_ref[...], preferred_element_type=jnp.float32)


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk"))
def matmul(x, w, *, bm: int = 64, bn: int = 64, bk: int = 16):
    """Tiled matmul ``x @ w`` for 2-D operands.

    Requires M % bm == N % bn == K % bk == 0 (the AOT palette only
    contains dividing variants; the Rust schedule space snaps to them).
    """
    m, k = x.shape
    k2, n = w.shape
    assert k == k2, f"contraction mismatch {k} vs {k2}"
    assert m % bm == 0 and n % bn == 0 and k % bk == 0, (
        f"shape ({m},{n},{k}) not divisible by tile ({bm},{bn},{bk})"
    )
    n_k_steps = k // bk
    grid = (m // bm, n // bn, n_k_steps)
    kernel = functools.partial(_mm_kernel, n_k_steps=n_k_steps)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=True,
    )(x, w)


def matmul_batched(x, w, *, bm: int = 64, bn: int = 64, bk: int = 16):
    """Batched matmul over leading dim: x[b,m,k] @ w[b,k,n]."""
    f = functools.partial(matmul, bm=bm, bn=bn, bk=bk)
    return jax.vmap(f)(x, w)


def vmem_bytes(bm: int, bn: int, bk: int, dtype_bytes: int = 4) -> int:
    """Estimated VMEM residency per grid step: two operand panels + the
    f32 output tile (the quantity DESIGN.md §9 budgets at 16 MiB)."""
    return dtype_bytes * (bm * bk + bk * bn) + 4 * bm * bn
