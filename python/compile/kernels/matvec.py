"""L1 Pallas kernel: schedule-parameterized matrix-vector product.

Computes ``y[n] = sum_k W[n, k] * x[k]`` — the paper's MV operator
(M = 1 GEMM), the memory-bound workload where its RTX 4090 evaluation
found >50% energy savings. The grid tiles N into `bn` rows per step and
the reduction into `bk` stages; the weight panel `bn x bk` streams
through VMEM once (no reuse — MV is compulsory-traffic dominated), while
the `x` slice is broadcast to every row of the panel.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _mv_kernel(w_ref, x_ref, o_ref, *, n_k_steps: int):
    k = pl.program_id(1)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    # Panel-vector product: (bn, bk) @ (bk,) -> (bn,)
    o_ref[...] += jnp.dot(
        w_ref[...], x_ref[...], preferred_element_type=jnp.float32
    )


@functools.partial(jax.jit, static_argnames=("bn", "bk"))
def matvec(w, x, *, bn: int = 128, bk: int = 128):
    """Tiled matvec ``W @ x`` with W of shape (N, K), x of shape (K,)."""
    n, k = w.shape
    (k2,) = x.shape
    assert k == k2, f"contraction mismatch {k} vs {k2}"
    assert n % bn == 0 and k % bk == 0, (
        f"shape ({n},{k}) not divisible by tile ({bn},{bk})"
    )
    n_k_steps = k // bk
    grid = (n // bn, n_k_steps)
    kernel = functools.partial(_mv_kernel, n_k_steps=n_k_steps)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bn, bk), lambda i, kk: (i, kk)),
            pl.BlockSpec((bk,), lambda i, kk: (kk,)),
        ],
        out_specs=pl.BlockSpec((bn,), lambda i, kk: (i,)),
        out_shape=jax.ShapeDtypeStruct((n,), jnp.float32),
        interpret=True,
    )(w, x)


def matvec_batched(w, x, *, bn: int = 128, bk: int = 128):
    """Batched matvec: W[b,n,k] @ x[b,k] -> y[b,n]."""
    f = functools.partial(matvec, bn=bn, bk=bk)
    return jax.vmap(f)(w, x)
