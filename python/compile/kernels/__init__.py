"""L1 Pallas kernels (build-time only; never imported at runtime)."""

from .conv import conv2d  # noqa: F401
from .matmul import matmul, matmul_batched  # noqa: F401
from .matvec import matvec, matvec_batched  # noqa: F401
