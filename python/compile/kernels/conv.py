"""L1/L2 convolution: implicit-im2col lowering onto the Pallas matmul.

NHWC conv2d is lowered exactly the way the Rust schedule space models it
(`Workload::gemm_view`): patches of shape (B*Ho*Wo, Cin*KH*KW) against a
weight matrix (Cin*KH*KW, Cout). 1x1 convolutions skip patch extraction
(a pure reshape). The GEMM itself is the schedule-parameterized Pallas
kernel, so conv artifacts share the same (bm, bn, bk) variant palette.
"""

import functools

import jax
import jax.numpy as jnp

from .matmul import matmul


def _extract_patches(x, ksize: int, stride: int, pad: int):
    """im2col: NHWC -> (B, Ho, Wo, KH*KW*Cin) patches."""
    b, h, w, cin = x.shape
    patches = jax.lax.conv_general_dilated_patches(
        x,
        filter_shape=(ksize, ksize),
        window_strides=(stride, stride),
        padding=[(pad, pad), (pad, pad)],
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    )
    # conv_general_dilated_patches yields channels ordered (Cin, KH, KW).
    return patches


def conv2d(x, w, *, stride: int = 1, pad: int = 0,
           bm: int = 64, bn: int = 64, bk: int = 16):
    """NHWC conv2d with HWIO weights via im2col + Pallas matmul.

    x: (B, H, W, Cin); w: (KH, KW, Cin, Cout). Returns (B, Ho, Wo, Cout).
    """
    b, h, win, cin = x.shape
    kh, kw, cin2, cout = w.shape
    assert cin == cin2 and kh == kw, "square kernels, matching channels"
    ksize = kh
    ho = (h + 2 * pad - ksize) // stride + 1
    wo = (win + 2 * pad - ksize) // stride + 1

    if ksize == 1 and stride == 1 and pad == 0:
        lhs = x.reshape(b * h * win, cin)
        rhs = w.reshape(cin, cout)
    else:
        patches = _extract_patches(x, ksize, stride, pad)
        lhs = patches.reshape(b * ho * wo, -1)
        # Patch channel order is (Cin, KH, KW): permute HWIO to match.
        rhs = jnp.transpose(w, (2, 0, 1, 3)).reshape(cin * ksize * ksize, cout)

    m, k = lhs.shape
    # Pad the GEMM up to tile multiples (zero rows/cols contribute 0).
    pm = (-m) % bm
    pk = (-k) % bk
    pn = (-cout) % bn
    if pm or pk:
        lhs = jnp.pad(lhs, ((0, pm), (0, pk)))
    if pk or pn:
        rhs = jnp.pad(rhs, ((0, pk), (0, pn)))
    out = matmul(lhs, rhs, bm=bm, bn=bn, bk=bk)
    out = out[:m, :cout]
    return out.reshape(b, ho, wo, cout)


conv2d_1x1 = functools.partial(conv2d, stride=1, pad=0)
