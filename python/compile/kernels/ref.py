"""Pure-jnp correctness oracles for the Pallas kernels.

These are the CORE correctness signal of the L1 layer: every kernel
variant must match its oracle to float32 tolerance before it is allowed
into the AOT artifact palette.
"""

import jax
import jax.numpy as jnp


def matmul_ref(x, w):
    """x[m,k] @ w[k,n] in f32."""
    return jnp.dot(x, w, preferred_element_type=jnp.float32)


def matmul_batched_ref(x, w):
    return jax.vmap(matmul_ref)(x, w)


def matvec_ref(w, x):
    """W[n,k] @ x[k] in f32."""
    return jnp.dot(w, x, preferred_element_type=jnp.float32)


def matvec_batched_ref(w, x):
    return jax.vmap(matvec_ref)(w, x)


def conv2d_ref(x, w, *, stride: int = 1, pad: int = 0):
    """NHWC x HWIO conv2d oracle via lax.conv_general_dilated."""
    return jax.lax.conv_general_dilated(
        x,
        w,
        window_strides=(stride, stride),
        padding=[(pad, pad), (pad, pad)],
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    )
