"""build-time compile package."""
