"""The pinned AOT variant palette.

Each entry maps a workload (named exactly as `Workload::id()` on the
Rust side) to the set of (bm, bn, bk) block-geometry variants compiled
to HLO artifacts. The Rust artifact registry resolves a searched
schedule's `variant_id` ("bm{}_bn{}_bk{}") to the nearest palette
member, so every search winner is executable end-to-end.

The palette intentionally spans the block geometries the search space
reaches: small tiles (high grid, high sm_efficiency — K2-like in the
paper's §8 case study) through large tiles (high reuse, low static
energy — K1-like).
"""

from dataclasses import dataclass


@dataclass(frozen=True)
class ArtifactSpec:
    """One AOT compilation unit."""

    workload_id: str     # Rust Workload::id()
    op: str              # "mm" | "mv" | "conv"
    shape: tuple         # op-specific shape tuple
    bm: int
    bn: int
    bk: int

    @property
    def variant_id(self) -> str:
        return f"bm{self.bm}_bn{self.bn}_bk{self.bk}"

    @property
    def artifact_name(self) -> str:
        return f"{self.workload_id}__{self.variant_id}"


def mm_variants():
    """MM1(1, 512, 512, 512): the paper's headline operator (21.69%
    energy reduction; §8 case study)."""
    shape = (1, 512, 512, 512)
    wid = "mm_b1_m512_n512_k512"
    out = []
    for bm in (16, 32, 64, 128):
        for bn in (32, 64, 128):
            for bk in (8, 16, 32):
                out.append(ArtifactSpec(wid, "mm", shape, bm, bn, bk))
    return out


def mv_variants():
    """MV(1, 1, 4096, 1024): the Table-3 / Fig-4 MV operator."""
    shape = (1, 4096, 1024)
    wid = "mv_b1_n4096_k1024"
    out = []
    for bn in (64, 128, 256):
        for bk in (64, 128):
            out.append(ArtifactSpec(wid, "mv", shape, 1, bn, bk))
    return out


def conv_variants():
    """CONV2-lite (4, 56, 56, 64, 64, 1, 1, 0): the Table-2/3 1x1 conv
    at reduced batch so interpret-mode AOT stays fast. GEMM view:
    (12544, 64, 64)."""
    shape = (4, 56, 56, 64, 64, 1, 1, 0)
    wid = "conv_b4_h56_w56_ci64_co64_k1_s1_p0"
    out = []
    for bm in (64, 128):
        for bn in (32, 64):
            for bk in (16, 32):
                out.append(ArtifactSpec(wid, "conv", shape, bm, bn, bk))
    return out


def palette():
    """Every artifact to compile."""
    return mm_variants() + mv_variants() + conv_variants()


def palette_for(workload_id: str):
    return [a for a in palette() if a.workload_id == workload_id]
