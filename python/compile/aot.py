"""AOT export: lower every palette variant to HLO *text* artifacts.

HLO text (NOT ``lowered.compile()`` / proto ``.serialize()``) is the
interchange format: jax >= 0.5 emits HloModuleProto with 64-bit
instruction ids that the runtime's xla_extension 0.5.1 rejects
(``proto.id() <= INT_MAX``); the text parser reassigns ids and
round-trips cleanly (see /opt/xla-example/README.md).

Run once via ``make artifacts``; the Rust binary is self-contained
afterwards. Emits ``artifacts/<workload>__<variant>.hlo.txt`` plus a
``manifest.json`` the Rust artifact registry indexes.
"""

import argparse
import json
import pathlib
import sys

import jax
from jax._src.lib import xla_client as xc

from . import model, schedules


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (return_tuple=True so the
    Rust side unwraps with to_tuple1())."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def export_one(spec, out_dir: pathlib.Path) -> dict:
    fn = model.model_for(spec)
    args = model.example_args(spec)
    lowered = jax.jit(fn).lower(*args)
    text = to_hlo_text(lowered)
    path = out_dir / f"{spec.artifact_name}.hlo.txt"
    path.write_text(text)
    return {
        "workload_id": spec.workload_id,
        "op": spec.op,
        "shape": list(spec.shape),
        "variant_id": spec.variant_id,
        "bm": spec.bm,
        "bn": spec.bn,
        "bk": spec.bk,
        "file": path.name,
        "arg_shapes": [list(a.shape) for a in args],
        "hlo_bytes": len(text),
    }


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts", help="output directory")
    ap.add_argument("--only", default=None,
                    help="substring filter on artifact names (debug)")
    ns = ap.parse_args()
    out_dir = pathlib.Path(ns.out)
    out_dir.mkdir(parents=True, exist_ok=True)

    entries = []
    pal = schedules.palette()
    if ns.only:
        pal = [s for s in pal if ns.only in s.artifact_name]
    for i, spec in enumerate(pal):
        entry = export_one(spec, out_dir)
        entries.append(entry)
        print(f"[{i + 1}/{len(pal)}] {spec.artifact_name} "
              f"({entry['hlo_bytes']} bytes)")
    (out_dir / "manifest.json").write_text(json.dumps(entries, indent=1))
    print(f"wrote {len(entries)} artifacts + manifest to {out_dir}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
