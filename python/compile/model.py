"""L2: JAX compute graphs for the paper's operator workloads.

Each function is the *model-level* computation the search optimizes —
it calls the L1 Pallas kernels with a concrete (bm, bn, bk) schedule
variant, so lowering one of these functions produces a single fused HLO
module per variant. Build-time only; the Rust runtime executes the
lowered artifacts through PJRT.
"""

import functools

import jax
import jax.numpy as jnp

from . import kernels


def mm_model(bm: int, bn: int, bk: int):
    """MM(batch, M, N, K) forward: out = x @ w per batch element."""

    def fn(x, w):
        if x.ndim == 3:
            return (kernels.matmul_batched(x, w, bm=bm, bn=bn, bk=bk),)
        return (kernels.matmul(x, w, bm=bm, bn=bn, bk=bk),)

    return fn


def mv_model(bn: int, bk: int):
    """MV(batch, 1, N, K) forward: y = W @ x per batch element."""

    def fn(w, x):
        if w.ndim == 3:
            return (kernels.matvec_batched(w, x, bn=bn, bk=bk),)
        return (kernels.matvec(w, x, bn=bn, bk=bk),)

    return fn


def conv_model(stride: int, pad: int, bm: int, bn: int, bk: int):
    """Conv2d NHWC forward via implicit im2col onto the Pallas GEMM."""

    def fn(x, w):
        return (kernels.conv2d(x, w, stride=stride, pad=pad, bm=bm, bn=bn, bk=bk),)

    return fn


def example_args(spec):
    """ShapeDtypeStructs for an ArtifactSpec (see schedules.py)."""
    f32 = jnp.float32
    if spec.op == "mm":
        b, m, n, k = spec.shape
        if b == 1:
            return (
                jax.ShapeDtypeStruct((m, k), f32),
                jax.ShapeDtypeStruct((k, n), f32),
            )
        return (
            jax.ShapeDtypeStruct((b, m, k), f32),
            jax.ShapeDtypeStruct((b, k, n), f32),
        )
    if spec.op == "mv":
        b, n, k = spec.shape
        if b == 1:
            return (
                jax.ShapeDtypeStruct((n, k), f32),
                jax.ShapeDtypeStruct((k,), f32),
            )
        return (
            jax.ShapeDtypeStruct((b, n, k), f32),
            jax.ShapeDtypeStruct((b, k), f32),
        )
    if spec.op == "conv":
        b, h, w, cin, cout, ks, _s, _p = spec.shape
        return (
            jax.ShapeDtypeStruct((b, h, w, cin), f32),
            jax.ShapeDtypeStruct((ks, ks, cin, cout), f32),
        )
    raise ValueError(f"unknown op {spec.op}")


def model_for(spec):
    """The L2 function for an ArtifactSpec."""
    if spec.op == "mm":
        return mm_model(spec.bm, spec.bn, spec.bk)
    if spec.op == "mv":
        return mv_model(spec.bn, spec.bk)
    if spec.op == "conv":
        _b, _h, _w, _ci, _co, _ks, s, p = spec.shape
        return conv_model(s, p, spec.bm, spec.bn, spec.bk)
    raise ValueError(f"unknown op {spec.op}")
