//! Explore the latency–energy trade-off space of one operator: the
//! Figure 2 / Figure 3 phenomena, interactively.
//!
//! Samples the schedule space, prints the Pareto frontier
//! (latency vs energy), and the latency–power correlation — the two
//! observations that motivate the paper (§4.1–4.2).
//!
//! ```bash
//! cargo run --release --example energy_pareto [-- WORKLOAD [GPU]]
//! ```

use ecokernel::config::GpuArch;
use ecokernel::schedule::space::ScheduleSpace;
use ecokernel::sim;
use ecokernel::util::{stats, Rng};
use ecokernel::workload::suites;

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let wname = args.first().map(|s| s.as_str()).unwrap_or("MM1");
    let gname = args.get(1).map(|s| s.as_str()).unwrap_or("a100");
    let workload = suites::by_name(wname)
        .ok_or_else(|| anyhow::anyhow!("unknown workload {wname}"))?;
    let gpu = GpuArch::parse(gname).ok_or_else(|| anyhow::anyhow!("unknown gpu {gname}"))?;
    let spec = gpu.spec();

    println!("sampling 500 schedules of {workload} on {gpu} ...\n");
    let space = ScheduleSpace::new(workload, &spec);
    let mut rng = Rng::seed_from_u64(1);
    let g = workload.gemm_view();
    let mut evals: Vec<(ecokernel::schedule::Schedule, sim::Evaluation)> = space
        .sample_n(&mut rng, 500)
        .into_iter()
        .map(|s| (s, sim::evaluate(&g, &s, &spec)))
        .collect();

    // Latency-power correlation (Fig. 3).
    let lats: Vec<f64> = evals.iter().map(|(_, e)| e.latency_s).collect();
    let pows: Vec<f64> = evals.iter().map(|(_, e)| e.avg_power_w).collect();
    let engs: Vec<f64> = evals.iter().map(|(_, e)| e.energy_j).collect();
    println!(
        "latency-power Pearson r = {:.3}  (paper Fig. 3: inverse)",
        stats::pearson(&lats, &pows)
    );
    println!(
        "latency-energy Pearson r = {:.3}  (positive, but NOT 1.0: energy is not just latency)\n",
        stats::pearson(&lats, &engs)
    );

    // Pareto frontier on (latency, energy).
    evals.sort_by(|a, b| a.1.latency_s.partial_cmp(&b.1.latency_s).unwrap());
    println!("Pareto frontier (latency vs energy):");
    println!(
        "{:>12} {:>12} {:>9} {:>8} {:>7}  schedule",
        "latency(ms)", "energy(mJ)", "power(W)", "sm_eff", "grid"
    );
    let mut best_energy = f64::INFINITY;
    let mut n_frontier = 0;
    for (s, e) in &evals {
        if e.energy_j < best_energy {
            best_energy = e.energy_j;
            n_frontier += 1;
            println!(
                "{:>12.4} {:>12.3} {:>9.1} {:>7.1}% {:>7}  {}",
                e.latency_s * 1e3,
                e.energy_j * 1e3,
                e.avg_power_w,
                e.sm_efficiency * 100.0,
                e.profile.grid,
                s
            );
        }
    }
    println!("\n{n_frontier} Pareto-optimal points out of {} samples.", evals.len());
    println!(
        "Fastest kernel is {} the most energy-efficient kernel — the paper's premise.",
        if n_frontier > 1 { "NOT" } else { "also" }
    );
    Ok(())
}
