//! Quickstart: search an energy-efficient MM1 kernel, then execute the
//! winning schedule's AOT artifact through PJRT and verify numerics.
//!
//! ```bash
//! make artifacts && cargo run --release --example quickstart
//! ```

use ecokernel::config::{GpuArch, SearchConfig, SearchMode};
use ecokernel::runtime::ArtifactRegistry;
use ecokernel::search::run_search;
use ecokernel::util::Rng;
use ecokernel::workload::suites;

fn main() -> anyhow::Result<()> {
    // 1. Search: the paper's energy-aware genetic search on MM1.
    let cfg = SearchConfig {
        gpu: GpuArch::A100,
        mode: SearchMode::EnergyAware,
        population: 64,
        m_latency_keep: 16,
        rounds: 6,
        seed: 42,
        ..Default::default()
    };
    println!("searching {} on {} ...", suites::MM1, cfg.gpu);
    let out = run_search(suites::MM1, &cfg);
    println!(
        "best schedule: {}  ->  {:.4} ms, {:.3} mJ, {:.0} W (simulated A100)",
        out.best.schedule,
        out.best.latency_s * 1e3,
        out.best.energy_j * 1e3,
        out.best.avg_power_w
    );

    // 2. Map the winner onto the nearest AOT-compiled Pallas variant.
    let reg = ArtifactRegistry::open(&ArtifactRegistry::default_dir())?;
    let meta = reg
        .nearest("mm_b1_m512_n512_k512", &out.best.schedule)
        .expect("MM1 artifacts exist");
    println!(
        "searched variant {} -> artifact {}",
        out.best.schedule.variant_id(),
        meta.name()
    );

    // 3. Execute through PJRT and verify against a Rust-side oracle.
    let kernel = reg.load(meta)?;
    println!(
        "compiled in {:.2}s; executing 512x512x512 matmul ...",
        kernel.compile_time.as_secs_f64()
    );
    let mut rng = Rng::seed_from_u64(7);
    let x: Vec<f32> = (0..512 * 512).map(|_| rng.normal() as f32 * 0.05).collect();
    let w: Vec<f32> = (0..512 * 512).map(|_| rng.normal() as f32 * 0.05).collect();
    let shape = [512usize, 512usize];
    let got = kernel.run_f32(&[(&x, &shape), (&w, &shape)])?;

    // Spot-check 40 random output entries against an f64 reference.
    let mut max_err = 0.0f64;
    for _ in 0..40 {
        let i = rng.gen_range(0, 512);
        let j = rng.gen_range(0, 512);
        let mut acc = 0.0f64;
        for k in 0..512 {
            acc += x[i * 512 + k] as f64 * w[k * 512 + j] as f64;
        }
        max_err = max_err.max((got[i * 512 + j] as f64 - acc).abs());
    }
    anyhow::ensure!(max_err < 1e-3, "numerics mismatch: max err {max_err}");
    println!("numerics verified (max spot-check error {max_err:.2e})");
    println!("quickstart OK");
    Ok(())
}
