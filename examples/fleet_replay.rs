//! Fleet replay: two serving daemons — one on `unix:`, one on `tcp:` —
//! mounting ONE shared store, replaying a zipf-distributed workload
//! stream split across them. Reports what the fleet machinery buys:
//!
//! * **fleet-wide hit rate** — a search either daemon runs serves both;
//! * **duplicate searches avoided** — misses that coalesced into an
//!   in-flight search (locally or via the in-store fleet claim)
//!   instead of re-searching;
//! * **shed/served ratio** — the daemons run deliberately saturated
//!   (1 worker, 1 queue slot, tiny backlog), so admission control has
//!   to choose: hot keys are kept and searched, cold tail keys shed.
//!
//! ```bash
//! cargo run --release --example fleet_replay [-- N_REQUESTS [ZIPF_S]]
//! ```

#[cfg(unix)]
use ecokernel::config::{GpuArch, SearchConfig, SearchMode};
#[cfg(unix)]
use ecokernel::serve::{
    merged_metrics, BatchRequest, Daemon, DaemonConfig, ServeAddr, ServeClient, StatsReply,
};
#[cfg(unix)]
use ecokernel::util::Rng;
#[cfg(unix)]
use ecokernel::workload::suites;
#[cfg(unix)]
use std::time::Duration;

#[cfg(not(unix))]
fn main() {
    eprintln!("fleet_replay needs a Unix socket runtime (unix-only)");
}

#[cfg(unix)]
fn main() -> anyhow::Result<()> {
    let mut args = std::env::args().skip(1);
    let n_requests: usize = args.next().map(|s| s.parse()).transpose()?.unwrap_or(60);
    let zipf_s: f64 = args.next().map(|s| s.parse()).transpose()?.unwrap_or(1.1);

    let dir = std::env::temp_dir().join(format!("ecokernel_fleet_replay_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir)?;

    // Quick-effort searches and a deliberately saturated daemon: one
    // worker, one queue slot, a two-key backlog — admission has to
    // pick favorites.
    let mut search = SearchConfig {
        gpu: GpuArch::A100,
        mode: SearchMode::EnergyAware,
        population: 24,
        m_latency_keep: 6,
        rounds: 3,
        patience: 0,
        seed: 42,
        ..Default::default()
    };
    search.serve.n_workers = 1;
    search.serve.queue_cap = 1;
    search.serve.n_shards = 8;
    search.fleet.backlog_cap = 2;
    search.fleet.heat_half_life = 32.0;

    let a = Daemon::spawn(
        DaemonConfig {
            addr: ServeAddr::Unix(dir.join("a.sock")),
            store_dir: dir.clone(),
            search: search.clone(),
        },
        None,
    )?;
    let b = Daemon::spawn(
        DaemonConfig {
            addr: ServeAddr::Tcp("127.0.0.1:0".to_string()),
            store_dir: dir.clone(),
            search,
        },
        None,
    )?;
    println!("daemon A on {}, daemon B on {}, one store: {dir:?}\n", a.addr, b.addr);
    let mut ca = ServeClient::connect(&a.addr)?;
    let mut cb = ServeClient::connect(&b.addr)?;

    // Zipf over the Table-2 suite: rank r drawn with p ∝ r^-s.
    let suite = suites::table2_suite();
    let weights: Vec<f64> = (1..=suite.len()).map(|r| 1.0 / (r as f64).powf(zipf_s)).collect();
    let total_w: f64 = weights.iter().sum();
    let mut rng = Rng::seed_from_u64(7);
    let mut pick = || {
        let mut x = rng.gen_f64() * total_w;
        for (i, w) in weights.iter().enumerate() {
            x -= w;
            if x <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    };

    println!(
        "replaying {n_requests} zipf(s={zipf_s}) requests over {} operators, \
         alternating daemons ...\n",
        suite.len()
    );
    let mut request_log: Vec<usize> = Vec::with_capacity(n_requests);
    for req in 0..n_requests {
        let i = pick();
        request_log.push(i);
        let (name, w) = suite[i];
        let (daemon, client) = if req % 2 == 0 { ("A", &mut ca) } else { ("B", &mut cb) };
        let reply = client.get_kernel(w, None, None)?;
        println!(
            "  #{req:<3} {daemon} {name:<6} -> {:4} [{}]{}",
            if reply.hit { "hit" } else { "miss" },
            reply.source.name(),
            if reply.enqueued { " (search admitted)" } else { "" },
        );
    }

    println!("\ndraining admitted searches on both daemons ...");
    ca.wait_for_drain(Duration::from_secs(600))?;
    cb.wait_for_drain(Duration::from_secs(600))?;

    // Second pass of the same stream, BATCHED: the pipelined client
    // packs 8 requests per frame — one write syscall each — and the
    // daemons answer with positionally-matched reply frames. Shed keys
    // get another chance, everything searched in pass 1 is a
    // fleet-wide hit on EITHER daemon regardless of who searched it.
    let mut second_hits = 0usize;
    for (chunk_idx, chunk) in request_log.chunks(8).enumerate() {
        let client = if chunk_idx % 2 == 0 { &mut cb } else { &mut ca }; // swap daemons
        let requests: Vec<BatchRequest> = chunk.iter().map(|&i| (suite[i].1, None, None)).collect();
        for reply in client.get_kernel_batch(&requests)? {
            if reply.map(|k| k.hit).unwrap_or(false) {
                second_hits += 1;
            }
        }
    }
    ca.wait_for_drain(Duration::from_secs(600))?;
    cb.wait_for_drain(Duration::from_secs(600))?;

    let sa = ca.stats()?;
    let sb = cb.stats()?;
    // The fleet-merged telemetry view: ONE `metrics` op per daemon,
    // histograms and counters folded client-side — the amortization
    // and freshness figures below come from it, not hand-summed stats.
    let fleet = merged_metrics(&[a.addr.clone(), b.addr.clone()])?;
    let sum = |f: fn(&StatsReply) -> usize| f(&sa) + f(&sb);
    let requests = sum(|s| s.n_requests);
    let hits = sum(|s| s.n_hits);
    let misses = sum(|s| s.n_misses);
    let searches = sum(|s| s.n_searches_done);
    let shed = sum(|s| s.n_shed);
    let fleet_coalesced = sum(|s| s.n_fleet_coalesced);
    // A miss either searched, was shed, or coalesced into an in-flight
    // search (same-daemon pending set or cross-daemon claim).
    let dup_avoided = misses.saturating_sub(searches + shed);

    println!("\n=== fleet of 2 daemons, one store ===");
    println!(
        "requests        : {requests} total ({} via A, {} via B)",
        sa.n_requests, sb.n_requests
    );
    println!(
        "fleet hit rate  : {:.1}% ({hits}/{requests}); swapped-daemon batched 2nd pass: {}/{}",
        100.0 * hits as f64 / requests.max(1) as f64,
        second_hits,
        request_log.len()
    );
    println!(
        "batching        : {} requests over {} frames = {:.1} per syscall",
        fleet.counter("n_batch_requests"),
        fleet.counter("n_batch_frames"),
        fleet.frames_per_syscall()
    );
    println!(
        "freshness       : {} notify (push) refreshes, {} poll-fallback refreshes",
        fleet.counter("n_notify_refresh"),
        fleet.counter("n_poll_refresh")
    );
    println!(
        "reply (wall)    : p50 {:.3} ms, p99 {:.3} ms over {} replies fleet-wide",
        fleet.reply_wall_s.quantile(50.0) * 1e3,
        fleet.reply_wall_s.quantile(99.0) * 1e3,
        fleet.reply_wall_s.count()
    );
    for (stage, h) in &fleet.stages {
        if h.is_empty() {
            continue;
        }
        println!(
            "  stage {stage:<15}: n={:<5} p50={:.4} ms p99={:.4} ms",
            h.count(),
            h.quantile(50.0) * 1e3,
            h.quantile(99.0) * 1e3
        );
    }
    println!(
        "searches run    : {searches} fleet-wide for {} distinct-key misses",
        misses
    );
    println!(
        "dup avoided     : {dup_avoided} duplicate searches coalesced \
         ({fleet_coalesced} across daemons)"
    );
    println!(
        "shed/served     : {shed}/{requests} = {:.2} (cold tail dropped under saturation)",
        shed as f64 / requests.max(1) as f64
    );
    println!(
        "write-backs     : {} fenced, {} dropped (fleet lease contention; parked retries \
         keep these near zero)",
        sum(|s| s.n_writebacks_fenced),
        sum(|s| s.n_writebacks_dropped)
    );
    println!(
        "store           : {} records in {} shards; shard sizes {:?}",
        sa.n_records, sa.n_shards, sa.shard_records
    );
    println!("key heat        : {:?} (log2 buckets, coldest first)", sa.heat_histogram);
    println!(
        "measurements    : {} paid fleet-wide vs ~{} if every miss had searched",
        sum(|s| s.measurements_paid),
        (sum(|s| s.measurements_paid) / searches.max(1)) * (misses.max(1))
    );

    ca.shutdown()?;
    cb.shutdown()?;
    a.join()?;
    b.join()?;
    let _ = std::fs::remove_dir_all(&dir);
    Ok(())
}
