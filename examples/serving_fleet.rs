//! Fleet-serving replay: run the kernel-serving daemon in-process,
//! replay a zipf-distributed workload stream against it (production
//! traffic is heavy-tailed: a few hot operators dominate), and report
//! how many NVML measurements the store saved versus cold-searching
//! every request.
//!
//! ```bash
//! cargo run --release --example serving_fleet [-- N_REQUESTS [ZIPF_S]]
//! ```

#[cfg(unix)]
use ecokernel::config::{GpuArch, SearchConfig, SearchMode};
#[cfg(unix)]
use ecokernel::serve::{Daemon, DaemonConfig, ServeAddr, ServeClient};
#[cfg(unix)]
use ecokernel::util::Rng;
#[cfg(unix)]
use ecokernel::workload::suites;
#[cfg(unix)]
use std::time::Duration;

#[cfg(not(unix))]
fn main() {
    eprintln!("serving_fleet needs Unix-domain sockets (unix-only)");
}

#[cfg(unix)]
fn main() -> anyhow::Result<()> {
    let mut args = std::env::args().skip(1);
    let n_requests: usize = args.next().map(|s| s.parse()).transpose()?.unwrap_or(60);
    let zipf_s: f64 = args.next().map(|s| s.parse()).transpose()?.unwrap_or(1.2);

    let dir = std::env::temp_dir().join(format!("ecokernel_fleet_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir)?;

    // Quick-effort searches: the point here is serving behavior, not
    // search quality.
    let mut search = SearchConfig {
        gpu: GpuArch::A100,
        mode: SearchMode::EnergyAware,
        population: 24,
        m_latency_keep: 6,
        rounds: 3,
        patience: 0,
        seed: 42,
        ..Default::default()
    };
    search.serve.n_workers = 2;
    search.serve.n_shards = 8;

    let handle = Daemon::spawn(
        DaemonConfig {
            addr: ServeAddr::Unix(dir.join("ecokernel.sock")),
            store_dir: dir.clone(),
            search,
        },
        None,
    )?;
    let mut client = ServeClient::connect(&handle.addr)?;

    // Zipf over the Table-2 suite: rank r drawn with p ∝ r^-s.
    let suite = suites::table2_suite();
    let weights: Vec<f64> =
        (1..=suite.len()).map(|r| 1.0 / (r as f64).powf(zipf_s)).collect();
    let total_w: f64 = weights.iter().sum();
    let mut rng = Rng::seed_from_u64(7);
    let mut pick = || {
        let mut x = rng.gen_f64() * total_w;
        for (i, w) in weights.iter().enumerate() {
            x -= w;
            if x <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    };

    println!(
        "replaying {n_requests} zipf(s={zipf_s}) requests over {} operators ...\n",
        suite.len()
    );
    let mut request_log: Vec<usize> = Vec::with_capacity(n_requests);
    for req in 0..n_requests {
        let i = pick();
        request_log.push(i);
        let (name, w) = suite[i];
        let reply = client.get_kernel(w, None, None)?;
        println!(
            "  #{req:<3} {name:<6} -> {:4} [{}]{}",
            if reply.hit { "hit" } else { "miss" },
            reply.source.name(),
            if reply.enqueued { " (search enqueued)" } else { "" },
        );
    }

    // Let the background searches land, then replay the same stream: a
    // warmed store serves it entirely from cache.
    println!("\ndraining background searches ...");
    client.wait_for_drain(Duration::from_secs(600))?;
    for &i in &request_log {
        let (_, w) = suite[i];
        assert!(client.get_kernel(w, None, None)?.hit, "warmed store must hit");
    }

    let s = client.stats()?;
    // Counterfactual: a fleet with no store cold-searches every request
    // at the average per-search measurement cost.
    let per_search = s.measurements_paid as f64 / s.n_searches_done.max(1) as f64;
    let cold = per_search * s.n_requests as f64;
    println!("\nserving metrics: requests={} hit_rate={:.1}%", s.n_requests, s.hit_rate * 100.0);
    println!(
        "reply time     : p50 {:.3} ms, p99 {:.3} ms (simulated; misses pay the neighbor scan)",
        s.p50_reply_s * 1e3,
        s.p99_reply_s * 1e3
    );
    println!(
        "store          : {} records in {} shards, {} searches run for {} requests",
        s.n_records, s.n_shards, s.n_searches_done, s.n_requests
    );
    println!(
        "measurements   : paid {} vs ~{:.0} if every request cold-searched ({:.1}x saved)",
        s.measurements_paid,
        cold,
        cold / s.measurements_paid.max(1) as f64
    );

    client.shutdown()?;
    handle.join()?;
    let _ = std::fs::remove_dir_all(&dir);
    Ok(())
}
