//! Cluster-level savings projection (§1 motivation): translate the
//! kernel-level energy reductions of Table 2 into datacenter numbers,
//! including the cooling amplification the paper cites ("the power
//! required to run an air-cooling system is cubically proportional to
//! the servers' operating power"; cooling ≈ 50% of cluster energy).
//!
//! ```bash
//! cargo run --release --example cluster_savings [-- N_GPUS]
//! ```

use ecokernel::experiments::{table2, Effort};

fn main() -> anyhow::Result<()> {
    let n_gpus: f64 = std::env::args()
        .nth(1)
        .map(|s| s.parse())
        .transpose()?
        .unwrap_or(24_576.0); // the LLaMA-3 cluster of §1

    println!("running Table-2 style eval (quick effort) to get the kernel-level reduction ...\n");
    let t = table2(Effort::Quick);
    println!("{}", t.render("Kernel-level results"));

    let avg_reduction = t.avg_energy_reduction_pct() / 100.0;
    // Per-GPU average IT power under sustained DNN serving/training.
    let it_power_w = 300.0;
    let it_total_mw = n_gpus * it_power_w / 1e6;

    // Cooling power scales ~cubically with server operating power; with
    // cooling ~= IT power at baseline (50% of total), a fractional IT
    // reduction r shrinks cooling by ~(1 - (1-r)^3).
    let it_after = it_total_mw * (1.0 - avg_reduction);
    let cooling_before = it_total_mw;
    let cooling_after = cooling_before * (1.0 - avg_reduction).powi(3);

    let total_before = it_total_mw + cooling_before;
    let total_after = it_after + cooling_after;
    let yearly_mwh = (total_before - total_after) * 24.0 * 365.0;

    println!("cluster projection ({n_gpus:.0} GPUs @ {it_power_w:.0} W sustained):");
    println!("  kernel-level energy reduction : {:.2}%", avg_reduction * 100.0);
    println!("  IT power     : {it_total_mw:.2} MW -> {it_after:.2} MW");
    println!(
        "  cooling power: {cooling_before:.2} MW -> {cooling_after:.2} MW (cubic scaling)"
    );
    println!(
        "  total        : {total_before:.2} MW -> {total_after:.2} MW  ({:.2}% of cluster)",
        (1.0 - total_after / total_before) * 100.0
    );
    println!(
        "  yearly saving: {yearly_mwh:.0} MWh (~{:.0} U.S. household-years at 10.7 MWh/yr)",
        yearly_mwh / 10.7
    );
    Ok(())
}
