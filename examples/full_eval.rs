//! END-TO-END DRIVER: the full system on a real (small) workload.
//!
//! Proves all layers compose:
//!   L3 search (genetic + cost model + dynamic-k, on the simulated A100)
//!     -> winning schedules for MM / MV / CONV
//!   artifact registry -> nearest AOT-compiled Pallas variant (L1/L2,
//!     lowered once at build time)
//!   PJRT runtime -> load + compile + execute each winner, timing real
//!     CPU executions and validating numerics against f64 oracles.
//!
//! Run `--paper` for full search effort (slower). Results are recorded
//! in EXPERIMENTS.md.
//!
//! ```bash
//! make artifacts && cargo run --release --example full_eval
//! ```

use ecokernel::config::{GpuArch, SearchMode};
use ecokernel::coordinator::{Driver, DriverConfig, EventLog, SearchJob};
use ecokernel::experiments::Effort;
use ecokernel::runtime::{ArtifactRegistry, LoadedKernel};
use ecokernel::util::Rng;
use ecokernel::workload::{suites, Workload};

fn main() -> anyhow::Result<()> {
    let paper = std::env::args().any(|a| a == "--paper");
    let effort = if paper { Effort::Paper } else { Effort::Quick };
    let gpu = GpuArch::A100;

    // The three artifact-backed workloads (one per operator family).
    let evals: Vec<(&str, Workload, &str)> = vec![
        ("MM", suites::MM1, "mm_b1_m512_n512_k512"),
        ("MV", suites::MV_4090, "mv_b1_n4096_k1024"),
        (
            "CONV",
            Workload::Conv2d {
                batch: 4,
                h: 56,
                w: 56,
                cin: 64,
                cout: 64,
                ksize: 1,
                stride: 1,
                pad: 0,
            },
            "conv_b4_h56_w56_ci64_co64_k1_s1_p0",
        ),
    ];

    // ---- Phase 1: dual-mode search on every workload (the L3 system) --
    println!(
        "=== phase 1: search (Ansor baseline vs energy-aware), {} effort ===",
        if paper { "paper" } else { "quick" }
    );
    let log = EventLog::to_file(std::path::Path::new("full_eval_events.jsonl"))?;
    let driver = Driver::new(DriverConfig::default()).with_log(log);
    let mut jobs = Vec::new();
    for (i, (name, w, _)) in evals.iter().enumerate() {
        let seed = 77 + i as u64;
        jobs.push(SearchJob {
            name: format!("{name}/ansor"),
            workload: *w,
            cfg: effort.cfg(gpu, SearchMode::LatencyOnly, seed),
        });
        jobs.push(SearchJob {
            name: format!("{name}/ours"),
            workload: *w,
            cfg: effort.cfg(gpu, SearchMode::EnergyAware, seed),
        });
    }
    let (results, metrics) = driver.run_suite(jobs);
    println!("suite metrics: {}\n", metrics.summary());

    for (pair, (name, w, _)) in results.chunks(2).zip(&evals) {
        let (ansor, ours) = (&pair[0].outcome.best, &pair[1].outcome.best);
        println!(
            "{name} {w}: Ansor {:.3} mJ @ {:.4} ms | ours {:.3} mJ @ {:.4} ms | energy -{:.1}%",
            ansor.energy_j * 1e3,
            ansor.latency_s * 1e3,
            ours.energy_j * 1e3,
            ours.latency_s * 1e3,
            (1.0 - ours.energy_j / ansor.energy_j) * 100.0
        );
        anyhow::ensure!(
            ours.energy_j <= ansor.energy_j * 1.02,
            "{name}: energy-aware search must not lose on energy"
        );
    }

    // ---- Phase 2: execute every winner through PJRT ------------------
    println!("\n=== phase 2: execute winners via PJRT (L1/L2 artifacts) ===");
    let reg = ArtifactRegistry::open(&ArtifactRegistry::default_dir())?;
    let mut rng = Rng::seed_from_u64(99);
    for (pair, (name, _w, wid)) in results.chunks(2).zip(&evals) {
        let ours = &pair[1].outcome.best;
        let meta = reg
            .nearest(wid, &ours.schedule)
            .ok_or_else(|| anyhow::anyhow!("no artifacts for {wid}"))?;
        let kernel = reg.load(meta)?;
        let (inputs, mut check) = make_inputs(&kernel, &mut rng);
        let refs: Vec<(&[f32], &[usize])> =
            inputs.iter().map(|(d, s)| (d.as_slice(), s.as_slice())).collect();

        // Warm once, then time 3 runs.
        let out = kernel.run_f32(&refs)?;
        let mut total = 0.0;
        for _ in 0..3 {
            total += kernel.time_once(&refs)?;
        }
        let max_err = check(&inputs, &out);
        println!(
            "{name}: searched {} -> artifact {} | compile {:.2}s | exec {:.4}s | max err {max_err:.2e}",
            ours.schedule.variant_id(),
            meta.name(),
            kernel.compile_time.as_secs_f64(),
            total / 3.0,
        );
        anyhow::ensure!(max_err < 1e-2, "{name}: numerics mismatch {max_err}");
    }

    println!("\nfull_eval OK — search, artifact mapping, PJRT execution, and numerics all compose.");
    Ok(())
}

/// Build random inputs for an artifact + an oracle spot-checker.
#[allow(clippy::type_complexity)]
fn make_inputs(
    kernel: &LoadedKernel,
    rng: &mut Rng,
) -> (Vec<(Vec<f32>, Vec<usize>)>, Box<dyn FnMut(&[(Vec<f32>, Vec<usize>)], &[f32]) -> f64>) {
    let shapes = kernel.meta.arg_shapes.clone();
    let inputs: Vec<(Vec<f32>, Vec<usize>)> = shapes
        .iter()
        .map(|s| {
            let n: usize = s.iter().product();
            ((0..n).map(|_| rng.normal() as f32 * 0.05).collect(), s.clone())
        })
        .collect();
    let op = kernel.meta.op.clone();
    let mut check_rng = rng.fork(5);
    let checker = move |inputs: &[(Vec<f32>, Vec<usize>)], out: &[f32]| -> f64 {
        let mut max_err = 0.0f64;
        match op.as_str() {
            "mm" => {
                // (m,k) @ (k,n)
                let (ref a, ref sa) = inputs[0];
                let (ref b, ref sb) = inputs[1];
                let (m, k, n) = (sa[0], sa[1], sb[1]);
                for _ in 0..25 {
                    let i = check_rng.gen_range(0, m);
                    let j = check_rng.gen_range(0, n);
                    let mut acc = 0.0f64;
                    for kk in 0..k {
                        acc += a[i * k + kk] as f64 * b[kk * n + j] as f64;
                    }
                    max_err = max_err.max((out[i * n + j] as f64 - acc).abs());
                }
            }
            "mv" => {
                // (n,k) @ (k,)
                let (ref w, ref sw) = inputs[0];
                let (ref x, _) = inputs[1];
                let (n, k) = (sw[0], sw[1]);
                for _ in 0..25 {
                    let i = check_rng.gen_range(0, n);
                    let mut acc = 0.0f64;
                    for kk in 0..k {
                        acc += w[i * k + kk] as f64 * x[kk] as f64;
                    }
                    max_err = max_err.max((out[i] as f64 - acc).abs());
                }
            }
            "conv" => {
                // 1x1 conv == (b*h*w, cin) @ (cin, cout) on NHWC.
                let (ref xim, ref sx) = inputs[0];
                let (ref wt, ref swt) = inputs[1];
                let (b, h, w_, cin) = (sx[0], sx[1], sx[2], sx[3]);
                let cout = swt[3];
                let pixels = b * h * w_;
                for _ in 0..25 {
                    let p = check_rng.gen_range(0, pixels);
                    let co = check_rng.gen_range(0, cout);
                    let mut acc = 0.0f64;
                    for ci in 0..cin {
                        acc += xim[p * cin + ci] as f64 * wt[ci * cout + co] as f64;
                    }
                    max_err = max_err.max((out[p * cout + co] as f64 - acc).abs());
                }
            }
            _ => max_err = f64::INFINITY,
        }
        max_err
    };
    (inputs, Box::new(checker))
}
