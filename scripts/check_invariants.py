#!/usr/bin/env python3
"""Grep-level invariant checks on the serve daemon's hot path (ISSUE 9).

Two contracts that code review keeps re-litigating, enforced in CI
instead (stdlib python only, no build needed):

1. **Lock ordering** — the `traces` and `slo` mutexes must never be
   acquired while a `state`-guard binding is live in
   `rust/src/serve/daemon.rs`. The `state` mutex is the daemon's
   microseconds-only bookkeeping lock; nesting a trace-ring or
   SLO-window lock under it would let trace pressure extend every
   reply's critical section (and is one cycle away from a deadlock if
   any path ever locks the other way around).

2. **No panics on the request path** — the functions a client frame
   flows through must not call `.unwrap()` or `.expect(...)`, except
   the idiomatic poisoned-mutex forms `.lock().expect(...)` /
   `.read().expect(...)` / `.write().expect(...)` (a poisoned lock
   means another thread already panicked; propagating is correct).

3. **No socket write under the state guard** — in `daemon.rs` AND the
   evented accept loop `reactor.rs`, no socket/pipe write
   (`.write_all(`, `.write(buf)`, `writeln!(`, `.flush()`) may happen
   while a `state`-guard binding is live. A blocked peer must never be
   able to extend the daemon's bookkeeping critical section: the
   reactor buffers reply bytes and flushes them strictly outside any
   guard. (`.write()` with no argument is the RwLock acquisition form
   and is exempt.)

The scanner is lexical, not a parser, with exactly the precision the
daemon's style needs:

* a guard is a statement that *ends* at the lock acquisition —
  `let [mut] name = <...>.state.lock().expect("...");` — and stays
  live until `drop(name)`, a bare re-`lock` assignment re-arms it
  (`name = <...>.state.lock().expect("...");`), and the scope that
  opened the binding closes it;
* one-liner statement temporaries
  (`ctx.state.lock().expect("...").metrics.x += 1;`) release at the
  end of the statement and are exempt;
* strings and `//` comments are stripped before any matching, so prose
  about locks never trips the checker.

Exit 0 = clean; exit 1 = violations listed on stderr.
"""

from __future__ import annotations

import re
import sys
from pathlib import Path

REPO = Path(__file__).resolve().parent.parent
DAEMON = REPO / "rust" / "src" / "serve" / "daemon.rs"
REACTOR = REPO / "rust" / "src" / "serve" / "reactor.rs"

# The request path: every function a `get_kernel`/`batch` frame flows
# through between socket read and socket write.
REQUEST_PATH_FNS = [
    "handle_frame",
    "dispatch_fast",
    "run_slow",
    "finish_miss",
    "serve_get_kernel",
    "serve_hit",
    "serve_memory_miss",
    "serve_miss",
    "serve_batch",
    "emit_served",
]

CHAR_LIT = re.compile(r"'(\\.|[^'\\])'")


def strip_code(line: str) -> str:
    """Blank out string/char literals and drop `//` comments so only
    code shapes remain (lifetimes like `&'static` are left alone)."""
    out = []
    i, n = 0, len(line)
    while i < n:
        c = line[i]
        if c == '"':
            j = i + 1
            while j < n:
                if line[j] == "\\":
                    j += 2
                    continue
                if line[j] == '"':
                    break
                j += 1
            out.append('""')
            i = j + 1
        elif c == "'":
            m = CHAR_LIT.match(line, i)
            if m:
                out.append("' '")
                i = m.end()
            else:  # lifetime marker
                out.append(c)
                i += 1
        elif c == "/" and i + 1 < n and line[i + 1] == "/":
            break
        else:
            out.append(c)
            i += 1
    return "".join(out)


GUARD_BIND = re.compile(
    r'(?:^|[({\s])(?:let\s+(?:mut\s+)?)?(\w+)\s*=\s*[\w.\s]*'
    r'\.state\s*\.lock\(\)\s*\.expect\(\s*""\s*\)\s*;\s*$'
)
GUARD_LET = re.compile(r"let\s+(?:mut\s+)?(\w+)\s*=")
DROP = re.compile(r"\bdrop\(\s*(\w+)\s*\)")
FORBIDDEN_UNDER_STATE = re.compile(r"\.(traces|slo)\s*\.lock\(\)")
# Socket/pipe writes: `.write(` only counts with an argument — the
# no-arg form is the RwLock acquisition (`.write().expect(...)`).
SOCKET_WRITE = re.compile(r"\.write_all\(|\.write\(\s*[^)\s]|\bwriteln!\(|\.flush\(\)")


def scan_under_guard(
    label: str, lines: list[str], forbidden: re.Pattern[str], what: str
) -> list[str]:
    """Walk `lines` tracking live state-guard bindings; error on any
    line matching `forbidden` while one is live."""
    errors: list[str] = []
    depth = 0
    # name -> depth the binding's scope opened at (first `let`).
    live: dict[str, int] = {}
    known_depth: dict[str, int] = {}
    for lineno, raw in enumerate(lines, 1):
        code = strip_code(raw)
        m = GUARD_BIND.search(code)
        if m:
            name = m.group(1)
            if GUARD_LET.search(code):
                known_depth[name] = depth
            # A re-assignment re-arms the guard at its original
            # binding depth (the `let` scope still owns the slot).
            live[name] = known_depth.get(name, depth)
        if live and forbidden.search(code) and not m:
            held = ", ".join(sorted(live))
            errors.append(
                f"{label}:{lineno}: {what} while state "
                f"guard(s) [{held}] are live: {raw.strip()}"
            )
        for d in DROP.finditer(code):
            live.pop(d.group(1), None)
        # Brace tracking AFTER the line's checks: a `}` on this line
        # closes scopes for the NEXT line.
        for c in code:
            if c == "{":
                depth += 1
            elif c == "}":
                depth -= 1
                dead = [n for n, d in live.items() if d >= depth]
                for n in dead:
                    del live[n]
    return errors


def check_lock_order(lines: list[str]) -> list[str]:
    """No traces/slo lock while a state-guard binding is live."""
    return scan_under_guard(
        "daemon.rs", lines, FORBIDDEN_UNDER_STATE, "traces/slo mutex acquired"
    )


FN_DEF = re.compile(r"^\s*(?:pub\s*(?:\(\s*\w+\s*\))?\s+)?fn\s+(\w+)\s*[(<]")
ALLOWED_EXPECT = re.compile(r"\.\s*(?:lock|read|write)\(\)\s*\.\s*expect\(")
ANY_EXPECT = re.compile(r"\.\s*expect\(")
ANY_UNWRAP = re.compile(r"\.\s*unwrap\(\)")


def function_bodies(lines: list[str]) -> dict[str, list[tuple[int, str]]]:
    """Map fn name -> [(lineno, stripped-code)] of its body."""
    bodies: dict[str, list[tuple[int, str]]] = {}
    current: str | None = None
    depth = 0
    entered = False
    for lineno, raw in enumerate(lines, 1):
        code = strip_code(raw)
        if current is None:
            m = FN_DEF.match(code)
            if m and m.group(1) in REQUEST_PATH_FNS:
                current = m.group(1)
                depth = 0
                entered = False
                bodies[current] = []
        if current is not None:
            bodies[current].append((lineno, code))
            for c in code:
                if c == "{":
                    depth += 1
                    entered = True
                elif c == "}":
                    depth -= 1
            if entered and depth <= 0:
                current = None
    return bodies


def check_no_panics(lines: list[str]) -> list[str]:
    errors: list[str] = []
    bodies = function_bodies(lines)
    missing = [f for f in REQUEST_PATH_FNS if f not in bodies]
    for f in missing:
        errors.append(
            f"daemon.rs: request-path function `{f}` not found — update "
            "REQUEST_PATH_FNS in scripts/check_invariants.py"
        )
    for name, body in bodies.items():
        # Join so `.lock()\n.expect(` chains split across lines still
        # count as the allowed form.
        text = "\n".join(code for _, code in body)
        allowed_spans = [m.span() for m in ALLOWED_EXPECT.finditer(text)]

        def allowed(pos: int) -> bool:
            return any(a <= pos < b for a, b in allowed_spans)

        for m in ANY_UNWRAP.finditer(text):
            lineno = body[text.count("\n", 0, m.start())][0]
            errors.append(
                f"daemon.rs:{lineno}: `.unwrap()` in request-path fn "
                f"`{name}` — return a positional error frame instead"
            )
        for m in ANY_EXPECT.finditer(text):
            # The allowed regex starts at `.lock`, so the `.expect` it
            # covers begins inside its span.
            if allowed(m.start()):
                continue
            lineno = body[text.count("\n", 0, m.start())][0]
            errors.append(
                f"daemon.rs:{lineno}: non-lock `.expect(` in request-path "
                f"fn `{name}` — request handling must not panic"
            )
    return errors


def main() -> int:
    for path in (DAEMON, REACTOR):
        if not path.is_file():
            print(f"check_invariants: {path} missing", file=sys.stderr)
            return 1
    lines = DAEMON.read_text().splitlines()
    reactor_lines = REACTOR.read_text().splitlines()
    errors = check_lock_order(lines) + check_no_panics(lines)
    # Contract 3: reply bytes are buffered and flushed outside any
    # state guard — in the blocking daemon AND the evented reactor.
    for label, text in (("daemon.rs", lines), ("reactor.rs", reactor_lines)):
        errors += scan_under_guard(label, text, SOCKET_WRITE, "socket write")
    if errors:
        print("serve-daemon invariant violations:", file=sys.stderr)
        for e in errors:
            print(f"  {e}", file=sys.stderr)
        return 1
    n_guards = sum(
        1
        for raw in lines + reactor_lines
        if GUARD_BIND.search(strip_code(raw))
    )
    print(
        f"check_invariants: OK ({n_guards} state-guard sites, "
        f"{len(REQUEST_PATH_FNS)} request-path fns panic-free, "
        "no socket write under a state guard)"
    )
    return 0


if __name__ == "__main__":
    sys.exit(main())
