"""Repo-root pytest shim: make `pytest python/tests/` work from the
repository root by putting `python/` on sys.path (the build-time
`compile` package lives there).

Also provides a minimal, deterministic fallback for `hypothesis` when
the real package is not installed (the build environment is offline):
the property tests then run a fixed-seed random sweep with the same
`@given`/`@settings`/`strategies` surface instead of erroring at
collection. When hypothesis is available it is used untouched.
"""

import pathlib
import sys

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parent / "python"))


def _install_hypothesis_fallback():
    import functools
    import inspect
    import random
    import types
    import zlib

    class _Strategy:
        def __init__(self, sample):
            self.sample = sample

    def integers(min_value, max_value):
        return _Strategy(lambda rng: rng.randint(min_value, max_value))

    def sampled_from(options):
        options = list(options)
        return _Strategy(lambda rng: rng.choice(options))

    def booleans():
        return _Strategy(lambda rng: rng.random() < 0.5)

    def floats(min_value=0.0, max_value=1.0, **_kw):
        return _Strategy(lambda rng: rng.uniform(min_value, max_value))

    class _Rejected(Exception):
        """Raised by assume() to skip one generated example."""

    def assume(condition):
        if not condition:
            raise _Rejected()
        return True

    class settings:  # noqa: N801 - mirrors hypothesis' API
        def __init__(self, max_examples=10, deadline=None, **_kw):
            self.max_examples = max_examples

        def __call__(self, fn):
            fn._fallback_max_examples = self.max_examples
            return fn

    def given(**strategies):
        def decorate(fn):
            @functools.wraps(fn)
            def wrapper(*args, **kwargs):
                max_examples = getattr(wrapper, "_fallback_max_examples", 10)
                rng = random.Random(zlib.crc32(fn.__name__.encode()))
                ran = 0
                attempts = 0
                while ran < max_examples and attempts < max_examples * 50:
                    attempts += 1
                    values = {k: s.sample(rng) for k, s in strategies.items()}
                    try:
                        fn(*args, **values, **kwargs)
                    except _Rejected:
                        continue
                    ran += 1

            # Hide the strategy parameters from pytest's fixture
            # resolution: the wrapper supplies them itself.
            wrapper.__signature__ = inspect.Signature(
                [
                    p
                    for p in inspect.signature(fn).parameters.values()
                    if p.name not in strategies
                ]
            )
            return wrapper

        return decorate

    mod = types.ModuleType("hypothesis")
    st_mod = types.ModuleType("hypothesis.strategies")
    st_mod.integers = integers
    st_mod.sampled_from = sampled_from
    st_mod.booleans = booleans
    st_mod.floats = floats
    mod.strategies = st_mod
    mod.given = given
    mod.settings = settings
    mod.assume = assume
    sys.modules["hypothesis"] = mod
    sys.modules["hypothesis.strategies"] = st_mod


try:
    import hypothesis  # noqa: F401
except ImportError:
    _install_hypothesis_fallback()
