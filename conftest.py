"""Repo-root pytest shim: make `pytest python/tests/` work from the
repository root by putting `python/` on sys.path (the build-time
`compile` package lives there)."""

import pathlib
import sys

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parent / "python"))
