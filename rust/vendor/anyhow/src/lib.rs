//! Offline, API-compatible subset of the `anyhow` crate.
//!
//! The build environment has no network access, so the repository
//! vendors the minimal error-handling surface the codebase actually
//! uses: [`Error`], [`Result`], the [`anyhow!`] / [`bail!`] /
//! [`ensure!`] macros, and the [`Context`] extension trait. Swapping in
//! the real crate is a one-line change in `rust/Cargo.toml`.

use std::error::Error as StdError;
use std::fmt;

/// A boxed dynamic error with context chaining.
///
/// Like the real `anyhow::Error`, this type deliberately does **not**
/// implement `std::error::Error` — that is what makes the blanket
/// `From<E: std::error::Error>` conversion below coherent.
pub struct Error {
    inner: Box<dyn StdError + Send + Sync + 'static>,
}

/// `Result<T, anyhow::Error>` with the error type defaulted.
pub type Result<T, E = Error> = std::result::Result<T, E>;

impl Error {
    /// Construct an error from any displayable message.
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error { inner: Box::new(MessageError(message.to_string())) }
    }

    /// Construct from a concrete `std::error::Error`.
    pub fn new<E: StdError + Send + Sync + 'static>(error: E) -> Error {
        Error { inner: Box::new(error) }
    }

    /// Wrap this error with an outer context message.
    pub fn context<C: fmt::Display>(self, context: C) -> Error {
        Error {
            inner: Box::new(ContextError { context: context.to_string(), source: self.inner }),
        }
    }

    /// Iterate the cause chain, outermost first.
    pub fn chain(&self) -> impl Iterator<Item = &(dyn StdError + 'static)> {
        let mut next: Option<&(dyn StdError + 'static)> = Some(self.inner.as_ref());
        std::iter::from_fn(move || {
            let cur = next?;
            next = cur.source();
            Some(cur)
        })
    }

    /// The root cause (innermost error in the chain).
    pub fn root_cause(&self) -> &(dyn StdError + 'static) {
        self.chain().last().expect("chain is non-empty")
    }
}

impl<E: StdError + Send + Sync + 'static> From<E> for Error {
    fn from(error: E) -> Error {
        Error::new(error)
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.inner)?;
        if f.alternate() {
            let mut source = self.inner.source();
            while let Some(cause) = source {
                write!(f, ": {cause}")?;
                source = cause.source();
            }
        }
        Ok(())
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.inner)?;
        let mut source = self.inner.source();
        if source.is_some() {
            write!(f, "\n\nCaused by:")?;
        }
        while let Some(cause) = source {
            write!(f, "\n    {cause}")?;
            source = cause.source();
        }
        Ok(())
    }
}

/// A plain-message error (what `anyhow!("...")` produces).
#[derive(Debug)]
struct MessageError(String);

impl fmt::Display for MessageError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl StdError for MessageError {}

/// A context layer wrapping a source error.
#[derive(Debug)]
struct ContextError {
    context: String,
    source: Box<dyn StdError + Send + Sync + 'static>,
}

impl fmt::Display for ContextError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.context)
    }
}

impl StdError for ContextError {
    fn source(&self) -> Option<&(dyn StdError + 'static)> {
        Some(self.source.as_ref())
    }
}

/// Extension trait adding `.context(...)` / `.with_context(...)` to
/// `Result`.
pub trait Context<T, E> {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, context: C) -> Result<T, Error>;

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C;
}

impl<T, E: StdError + Send + Sync + 'static> Context<T, E> for std::result::Result<T, E> {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, context: C) -> Result<T, Error> {
        self.map_err(|e| Error::new(e).context(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.map_err(|e| Error::new(e).context(f()))
    }
}

/// Construct an [`Error`] from a format string or displayable value.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
}

/// Return early with an error built like [`anyhow!`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with an error if a condition is false.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            return Err($crate::Error::msg(concat!("condition failed: ", stringify!($cond))));
        }
    };
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            return Err($crate::anyhow!($($arg)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "missing file")
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn inner() -> Result<()> {
            Err(io_err())?;
            Ok(())
        }
        let e = inner().unwrap_err();
        assert_eq!(e.to_string(), "missing file");
    }

    #[test]
    fn context_chains_and_alternate_display_joins() {
        let r: std::result::Result<(), std::io::Error> = Err(io_err());
        let e = r.with_context(|| "read config").unwrap_err();
        assert_eq!(format!("{e}"), "read config");
        assert_eq!(format!("{e:#}"), "read config: missing file");
        assert_eq!(e.chain().count(), 2);
        assert_eq!(e.root_cause().to_string(), "missing file");
    }

    #[test]
    fn macros_build_messages() {
        let name = "MM1";
        let e = anyhow!("unknown workload '{name}'");
        assert_eq!(e.to_string(), "unknown workload 'MM1'");
        let e = anyhow!("{} + {}", 1, 2);
        assert_eq!(e.to_string(), "1 + 2");
        let e = anyhow!(String::from("plain"));
        assert_eq!(e.to_string(), "plain");

        fn check(x: usize) -> Result<usize> {
            ensure!(x > 0, "x must be positive, got {x}");
            if x > 10 {
                bail!("x too big");
            }
            Ok(x)
        }
        assert!(check(5).is_ok());
        assert_eq!(check(0).unwrap_err().to_string(), "x must be positive, got 0");
        assert_eq!(check(11).unwrap_err().to_string(), "x too big");
    }
}
