//! Offline stub of the `xla` (xla-rs) PJRT bindings.
//!
//! The runtime layer compiles against this API-compatible stub so the
//! whole workspace builds with no network and no XLA C++ toolchain. The
//! stub reports a CPU "platform" (so environment probing works) but
//! refuses to parse or compile HLO — [`HloModuleProto::from_text_file`]
//! and [`PjRtClient::compile`] return errors, which the runtime layer
//! already surfaces gracefully ("run `make artifacts`" / skip paths).
//!
//! To execute real artifacts, replace this path dependency in
//! `rust/Cargo.toml` with the actual `xla` crate.

use std::error::Error as StdError;
use std::fmt;
use std::rc::Rc;

/// Error type mirroring `xla::Error`'s display surface.
#[derive(Debug, Clone)]
pub struct XlaError(String);

impl XlaError {
    fn stub(what: &str) -> XlaError {
        XlaError(format!("{what} is unavailable in the offline xla stub (swap in the real xla crate)"))
    }
}

impl fmt::Display for XlaError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl StdError for XlaError {}

/// PJRT client handle. `Rc`-based like the real binding (not `Send`),
/// so the runtime's thread-local sharing pattern keeps its meaning.
#[derive(Clone)]
pub struct PjRtClient {
    _not_send: Rc<()>,
}

impl PjRtClient {
    /// Create the CPU client. Always succeeds in the stub — the client
    /// only fails later, at compile time, where callers already handle
    /// errors.
    pub fn cpu() -> Result<PjRtClient, XlaError> {
        Ok(PjRtClient { _not_send: Rc::new(()) })
    }

    pub fn platform_name(&self) -> String {
        "cpu".to_string()
    }

    pub fn device_count(&self) -> usize {
        1
    }

    pub fn compile(&self, _computation: &XlaComputation) -> Result<PjRtLoadedExecutable, XlaError> {
        Err(XlaError::stub("PjRtClient::compile"))
    }
}

/// Parsed HLO module (never constructible in the stub).
pub struct HloModuleProto {
    _private: (),
}

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto, XlaError> {
        Err(XlaError::stub("HloModuleProto::from_text_file"))
    }
}

/// An XLA computation wrapping a module proto.
pub struct XlaComputation {
    _private: (),
}

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation { _private: () }
    }
}

/// A compiled executable (never constructible in the stub).
pub struct PjRtLoadedExecutable {
    _private: (),
}

impl PjRtLoadedExecutable {
    pub fn execute<T>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>, XlaError> {
        Err(XlaError::stub("PjRtLoadedExecutable::execute"))
    }
}

/// A device buffer returned by execution.
pub struct PjRtBuffer {
    _private: (),
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal, XlaError> {
        Err(XlaError::stub("PjRtBuffer::to_literal_sync"))
    }
}

/// A host literal: flat f32 data plus dimensions.
#[derive(Debug, Clone, PartialEq)]
pub struct Literal {
    data: Vec<f32>,
    dims: Vec<i64>,
}

impl Literal {
    /// Build a rank-1 literal from f32 data.
    pub fn vec1(data: &[f32]) -> Literal {
        Literal { data: data.to_vec(), dims: vec![data.len() as i64] }
    }

    /// Reshape; the element count must be preserved.
    pub fn reshape(&self, dims: &[i64]) -> Result<Literal, XlaError> {
        let want: i64 = dims.iter().product();
        if want as usize != self.data.len() {
            return Err(XlaError(format!(
                "reshape: {} elements into shape {:?}",
                self.data.len(),
                dims
            )));
        }
        Ok(Literal { data: self.data.clone(), dims: dims.to_vec() })
    }

    /// Unwrap a 1-tuple result. Stub literals are never tuples, and no
    /// stub execution can produce one, so this is unreachable in
    /// practice; keep the signature for API compatibility.
    pub fn to_tuple1(self) -> Result<Literal, XlaError> {
        Ok(self)
    }

    /// Copy out the data as the requested element type.
    pub fn to_vec<T: From<f32>>(&self) -> Result<Vec<T>, XlaError> {
        Ok(self.data.iter().map(|&x| T::from(x)).collect())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cpu_client_reports_platform() {
        let c = PjRtClient::cpu().unwrap();
        assert_eq!(c.platform_name(), "cpu");
        assert_eq!(c.device_count(), 1);
    }

    #[test]
    fn hlo_parse_and_compile_are_stubbed_errors() {
        assert!(HloModuleProto::from_text_file("/nonexistent.hlo").is_err());
        let c = PjRtClient::cpu().unwrap();
        let proto = HloModuleProto { _private: () };
        let comp = XlaComputation::from_proto(&proto);
        let e = c.compile(&comp).unwrap_err();
        assert!(e.to_string().contains("offline xla stub"));
    }

    #[test]
    fn literal_roundtrip_and_reshape_check() {
        let l = Literal::vec1(&[1.0, 2.0, 3.0, 4.0]);
        let r = l.reshape(&[2, 2]).unwrap();
        assert_eq!(r.to_vec::<f32>().unwrap(), vec![1.0, 2.0, 3.0, 4.0]);
        assert!(l.reshape(&[3, 3]).is_err());
    }
}
