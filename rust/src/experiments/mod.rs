//! Experiment harnesses: one per table and figure of the paper's
//! evaluation (§7) and case study (§8). Each regenerates the paper's
//! rows/series from the framework and writes text + CSV into
//! `results/`. See DESIGN.md §6 for the experiment index and
//! EXPERIMENTS.md for recorded paper-vs-measured numbers.

pub mod ablations;
pub mod figures;
pub mod report;
pub mod tables;
pub mod warm_cold;

pub use ablations::ablations;
pub use figures::{fig2, fig3, fig4, fig5};
pub use tables::{table1, table2, table3, table4, table5, Effort};
pub use warm_cold::warm_cold;

use anyhow::Result;

/// Run one experiment by id ("table2", "fig4", ...), print its report,
/// and persist text/CSV outputs under `results/`.
pub fn run_by_id(id: &str, effort: Effort) -> Result<String> {
    let out = match id {
        "table1" => {
            let text = table1();
            report::write_result_file("table1.txt", &text)?;
            text
        }
        "table2" => {
            let t = table2(effort);
            let text = t.render("Table 2: energy & latency, Ansor vs ours");
            report::write_result_file("table2.txt", &text)?;
            report::write_result_file("table2.csv", &t.to_csv())?;
            text
        }
        "table3" => {
            let t = table3(effort);
            let text = t.render("Table 3: energy & latency, Ansor vs ours");
            report::write_result_file("table3.txt", &text)?;
            report::write_result_file("table3.csv", &t.to_csv())?;
            text
        }
        "table4" => {
            let t = table4(effort);
            let text = t.render();
            report::write_result_file("table4.txt", &text)?;
            text
        }
        "table5" => {
            let t = table5(effort);
            let text = t.render();
            report::write_result_file("table5.txt", &text)?;
            text
        }
        "fig2" => {
            let f = fig2(effort);
            report::write_result_file("fig2.csv", &f.to_csv())?;
            let text = f.summary();
            report::write_result_file("fig2.txt", &text)?;
            text
        }
        "fig3" => {
            let f = fig3(effort);
            report::write_result_file("fig3.csv", &f.to_csv())?;
            let text = f.summary();
            report::write_result_file("fig3.txt", &text)?;
            text
        }
        "fig4" => {
            let f = fig4(effort);
            report::write_result_file("fig4.csv", &f.to_csv())?;
            let text = f.summary();
            report::write_result_file("fig4.txt", &text)?;
            text
        }
        "fig5" => {
            let f = fig5(effort);
            let text = f.render();
            report::write_result_file("fig5.txt", &text)?;
            text
        }
        "ablations" => {
            let text = ablations(effort);
            report::write_result_file("ablations.txt", &text)?;
            text
        }
        "warmcold" => {
            let r = warm_cold(effort);
            let text = r.render();
            report::write_result_file("warmcold.txt", &text)?;
            report::write_result_file("warmcold.csv", &r.to_csv())?;
            text
        }
        other => anyhow::bail!(
            "unknown experiment '{other}' (try table1..table5, fig2..fig5, ablations, warmcold, all)"
        ),
    };
    Ok(out)
}

/// Every experiment id in paper order (+ the design-choice ablations
/// and the tuning-store warm-vs-cold study).
pub const ALL_IDS: [&str; 11] = [
    "table1", "table2", "table3", "table4", "table5", "fig2", "fig3", "fig4", "fig5",
    "ablations", "warmcold",
];
