//! Ablation studies on the framework's design choices (DESIGN.md §9):
//!
//! * **A1 — Eq. 1 weighted loss**: does weighting samples by `1/E_m`
//!   improve the model's accuracy on the low-energy kernels the search
//!   cares about?
//! * **A2 — dynamic k vs fixed k = 1**: how much measurement budget
//!   does the controller save, and at what quality cost?
//! * **A3 — latency-first selection**: what happens to latency if the
//!   search selects parents purely on energy (dropping §4.3's
//!   latency-first rule)?

use super::report::{f, TextTable};
use super::tables::Effort;
use crate::config::{CostModelConfig, GpuArch, SearchMode};
use crate::costmodel::EnergyCostModel;
use crate::features::featurize;
use crate::nvml::NvmlMeter;
use crate::schedule::{space::ScheduleSpace, Candidate};
use crate::sim;
use crate::util::{stats, Rng};
use crate::workload::suites;

/// A1: Eq. 1 weighting vs flat squared error — relative error on the
/// lowest-energy tercile of a held-out set.
pub struct AblationLoss {
    pub weighted_low_tercile_rel_err: f64,
    pub flat_low_tercile_rel_err: f64,
    pub weighted_rho: f64,
    pub flat_rho: f64,
}

pub fn ablation_loss(effort: Effort) -> AblationLoss {
    let spec = GpuArch::A100.spec();
    let w = suites::MM1;
    let space = ScheduleSpace::new(w, &spec);
    let n = match effort {
        Effort::Quick => 400,
        Effort::Paper => 1500,
    };
    let mut rng = Rng::seed_from_u64(11);
    let mut meter = NvmlMeter::warmed(spec.clone(), Default::default());
    let schedules = space.sample_n(&mut rng, n);
    let split = n * 8 / 10;
    let samples: Vec<_> = schedules[..split]
        .iter()
        .map(|s| {
            let c = Candidate::new(w, *s);
            (featurize(&c, &spec), meter.measure(&c, &mut rng).energy_j)
        })
        .collect();

    let eval = |weighted: bool, rng: &mut Rng| {
        let cfg = CostModelConfig { weighted_loss: weighted, ..Default::default() };
        let mut model = EnergyCostModel::new(cfg);
        model.update(&samples, rng);
        let mut pred = Vec::new();
        let mut truth = Vec::new();
        for s in &schedules[split..] {
            let c = Candidate::new(w, *s);
            pred.push(model.predict_energy_j(&featurize(&c, &spec)));
            truth.push(sim::evaluate_candidate(&c, &spec).energy_j);
        }
        let cutoff = stats::percentile(&truth, 33.0);
        let mut err = 0.0;
        let mut cnt = 0;
        for (p, t) in pred.iter().zip(&truth) {
            if *t <= cutoff {
                err += ((p - t) / t).abs();
                cnt += 1;
            }
        }
        (err / cnt.max(1) as f64, stats::spearman(&pred, &truth))
    };
    let (werr, wrho) = eval(true, &mut rng.fork(1));
    let (ferr, frho) = eval(false, &mut rng.fork(1));
    AblationLoss {
        weighted_low_tercile_rel_err: werr,
        flat_low_tercile_rel_err: ferr,
        weighted_rho: wrho,
        flat_rho: frho,
    }
}

/// A2: dynamic k vs pinned k (no controller).
pub struct AblationDynamicK {
    pub dynamic_measurements: usize,
    pub fixed_measurements: usize,
    pub dynamic_energy_mj: f64,
    pub fixed_energy_mj: f64,
    pub dynamic_time_s: f64,
    pub fixed_time_s: f64,
}

pub fn ablation_dynamic_k(effort: Effort) -> AblationDynamicK {
    let w = suites::MM_4090;
    let mut cfg = effort.cfg(GpuArch::A100, SearchMode::EnergyAware, 21);
    cfg.mu_snr_db = -5.0;
    let dynamic = crate::search::run_search(w, &cfg);
    // Fixed k: disable adaptation by zeroing the step.
    let mut fixed_cfg = cfg.clone();
    fixed_cfg.k_step = 0.0;
    fixed_cfg.k_init = 1.0;
    let fixed = crate::search::run_search(w, &fixed_cfg);
    AblationDynamicK {
        dynamic_measurements: dynamic.n_energy_measurements(),
        fixed_measurements: fixed.n_energy_measurements(),
        dynamic_energy_mj: dynamic.best.energy_j * 1e3,
        fixed_energy_mj: fixed.best.energy_j * 1e3,
        dynamic_time_s: dynamic.clock.total_s,
        fixed_time_s: fixed.clock.total_s,
    }
}

/// A3: latency-first (paper) vs pure-energy parent selection. We proxy
/// "pure energy" by removing the latency-tolerance band from the final
/// selection and by selecting on energy only from the full measured
/// pool.
pub struct AblationLatencyFirst {
    pub paper_latency_ms: f64,
    pub paper_energy_mj: f64,
    pub pure_energy_latency_ms: f64,
    pub pure_energy_energy_mj: f64,
}

pub fn ablation_latency_first(effort: Effort) -> AblationLatencyFirst {
    let w = suites::MM1;
    let cfg = effort.cfg(GpuArch::A100, SearchMode::EnergyAware, 31);
    let out = crate::search::run_search(w, &cfg);
    // Pure-energy pick: global argmin energy over the measured pool,
    // ignoring latency entirely.
    let pure = out
        .measured_pool
        .iter()
        .min_by(|a, b| a.energy_j.partial_cmp(&b.energy_j).expect("finite"))
        .copied()
        .expect("non-empty pool");
    AblationLatencyFirst {
        paper_latency_ms: out.best.latency_s * 1e3,
        paper_energy_mj: out.best.energy_j * 1e3,
        pure_energy_latency_ms: pure.latency_s * 1e3,
        pure_energy_energy_mj: pure.energy_j * 1e3,
    }
}

/// Render all three ablations as one report.
pub fn ablations(effort: Effort) -> String {
    let a1 = ablation_loss(effort);
    let a2 = ablation_dynamic_k(effort);
    let a3 = ablation_latency_first(effort);

    let mut t = TextTable::new(&["ablation", "arm", "metric", "value"]);
    t.row(vec![
        "A1 Eq.1 loss".into(),
        "weighted (paper)".into(),
        "low-tercile rel err".into(),
        f(a1.weighted_low_tercile_rel_err, 4),
    ]);
    t.row(vec![
        "A1 Eq.1 loss".into(),
        "flat".into(),
        "low-tercile rel err".into(),
        f(a1.flat_low_tercile_rel_err, 4),
    ]);
    t.row(vec![
        "A1 Eq.1 loss".into(),
        "weighted (paper)".into(),
        "spearman rho".into(),
        f(a1.weighted_rho, 3),
    ]);
    t.row(vec!["A1 Eq.1 loss".into(), "flat".into(), "spearman rho".into(), f(a1.flat_rho, 3)]);
    t.row(vec![
        "A2 dynamic k".into(),
        "dynamic (paper)".into(),
        "measurements".into(),
        a2.dynamic_measurements.to_string(),
    ]);
    t.row(vec![
        "A2 dynamic k".into(),
        "fixed k=1".into(),
        "measurements".into(),
        a2.fixed_measurements.to_string(),
    ]);
    t.row(vec![
        "A2 dynamic k".into(),
        "dynamic (paper)".into(),
        "best energy (mJ)".into(),
        f(a2.dynamic_energy_mj, 3),
    ]);
    t.row(vec![
        "A2 dynamic k".into(),
        "fixed k=1".into(),
        "best energy (mJ)".into(),
        f(a2.fixed_energy_mj, 3),
    ]);
    t.row(vec![
        "A2 dynamic k".into(),
        "dynamic (paper)".into(),
        "search time (s)".into(),
        f(a2.dynamic_time_s, 1),
    ]);
    t.row(vec![
        "A2 dynamic k".into(),
        "fixed k=1".into(),
        "search time (s)".into(),
        f(a2.fixed_time_s, 1),
    ]);
    t.row(vec![
        "A3 latency-first".into(),
        "band-select (paper)".into(),
        "latency (ms) / energy (mJ)".into(),
        format!("{} / {}", f(a3.paper_latency_ms, 4), f(a3.paper_energy_mj, 3)),
    ]);
    t.row(vec![
        "A3 latency-first".into(),
        "pure-energy argmin".into(),
        "latency (ms) / energy (mJ)".into(),
        format!("{} / {}", f(a3.pure_energy_latency_ms, 4), f(a3.pure_energy_energy_mj, 3)),
    ]);
    format!("Ablations (design choices; DESIGN.md §9)\n{}", t.render())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dynamic_k_saves_measurements_without_collapse() {
        let a = ablation_dynamic_k(Effort::Quick);
        assert!(a.dynamic_measurements < a.fixed_measurements);
        assert!(a.dynamic_time_s < a.fixed_time_s);
        assert!(a.dynamic_energy_mj <= a.fixed_energy_mj * 1.15);
    }

    #[test]
    fn latency_first_guards_latency() {
        let a = ablation_latency_first(Effort::Quick);
        // The pure-energy pick trades latency away (or at best ties);
        // the paper's band-select never exceeds the band.
        assert!(a.paper_latency_ms <= a.pure_energy_latency_ms * 1.001 + 1e-9
            || a.paper_energy_mj <= a.pure_energy_energy_mj * 1.001);
    }

    #[test]
    fn eq1_weighting_does_not_hurt_ranking() {
        let a = ablation_loss(Effort::Quick);
        assert!(a.weighted_rho > 0.85, "rho {}", a.weighted_rho);
        assert!(
            a.weighted_low_tercile_rel_err <= a.flat_low_tercile_rel_err * 1.25,
            "weighted {} vs flat {}",
            a.weighted_low_tercile_rel_err,
            a.flat_low_tercile_rel_err
        );
    }
}
