//! Figure experiments: Figures 2, 3, 4, and 5 of the paper. Each
//! produces the CSV series behind the figure plus the summary statistic
//! that encodes the figure's claim.

use super::report::{f, TextTable};
use super::tables::Effort;
use crate::config::{GpuArch, SearchConfig, SearchMode};
use crate::costmodel::EnergyCostModel;
use crate::features::featurize;
use crate::nvml::NvmlMeter;
use crate::schedule::{space::ScheduleSpace, Candidate};
use crate::sim;
use crate::util::stats;
use crate::util::Rng;
use crate::workload::{suites, Workload};

// ---------------------------------------------------------------------
// Figure 2: latency-energy scatter of Conv kernels (P100) + ours marker
// ---------------------------------------------------------------------

#[derive(Debug, Clone)]
pub struct Fig2 {
    /// (latency_ms, energy_mj) of sampled search-space kernels.
    pub scatter: Vec<(f64, f64)>,
    /// Ansor's pick.
    pub ansor: (f64, f64),
    /// Our pick.
    pub ours: (f64, f64),
}

impl Fig2 {
    pub fn to_csv(&self) -> String {
        let mut t = TextTable::new(&["latency_ms", "energy_mj", "kind"]);
        for (l, e) in &self.scatter {
            t.row(vec![format!("{l}"), format!("{e}"), "sampled".into()]);
        }
        t.row(vec![format!("{}", self.ansor.0), format!("{}", self.ansor.1), "ansor".into()]);
        t.row(vec![format!("{}", self.ours.0), format!("{}", self.ours.1), "ours".into()]);
        t.to_csv()
    }

    pub fn summary(&self) -> String {
        format!(
            "Fig 2 (Conv on p100): {} sampled kernels; Ansor ({:.4} ms, {:.2} mJ) vs ours ({:.4} ms, {:.2} mJ); ours saves {:.1}% energy at {:+.1}% latency",
            self.scatter.len(),
            self.ansor.0,
            self.ansor.1,
            self.ours.0,
            self.ours.1,
            (1.0 - self.ours.1 / self.ansor.1) * 100.0,
            (self.ours.0 / self.ansor.0 - 1.0) * 100.0,
        )
    }
}

pub fn fig2(effort: Effort) -> Fig2 {
    // The paper uses a ResNet-50 conv on a P100 (its Fig. 2 setup).
    let gpu = GpuArch::P100;
    let spec = gpu.spec();
    let w = suites::CONV1;
    let space = ScheduleSpace::new(w, &spec);
    let mut rng = Rng::seed_from_u64(42);
    let n = match effort {
        Effort::Quick => 150,
        Effort::Paper => 600,
    };
    let g = w.gemm_view();
    let scatter: Vec<(f64, f64)> = space
        .sample_n(&mut rng, n)
        .iter()
        .map(|s| {
            let ev = sim::evaluate(&g, s, &spec);
            (ev.latency_s * 1e3, ev.energy_j * 1e3)
        })
        .collect();

    let ansor = crate::search::run_search(w, &effort.cfg(gpu, SearchMode::LatencyOnly, 7));
    let ours = crate::search::run_search(w, &effort.cfg(gpu, SearchMode::EnergyAware, 7));
    Fig2 {
        scatter,
        ansor: (ansor.best.latency_s * 1e3, ansor.best.energy_j * 1e3),
        ours: (ours.best.latency_s * 1e3, ours.best.energy_j * 1e3),
    }
}

// ---------------------------------------------------------------------
// Figure 3: latency-power inverse correlation, MatMul 1024^3 on A100
// ---------------------------------------------------------------------

#[derive(Debug, Clone)]
pub struct Fig3 {
    /// (latency_ms, avg_power_w) pairs.
    pub series: Vec<(f64, f64)>,
    pub pearson_r: f64,
}

impl Fig3 {
    pub fn to_csv(&self) -> String {
        let mut t = TextTable::new(&["latency_ms", "avg_power_w"]);
        for (l, p) in &self.series {
            t.row(vec![format!("{l}"), format!("{p}")]);
        }
        t.to_csv()
    }

    pub fn summary(&self) -> String {
        format!(
            "Fig 3 (MM 1024^3 on a100): {} kernels, latency-power Pearson r = {:.3} (paper: inverse correlation)",
            self.series.len(),
            self.pearson_r
        )
    }
}

pub fn fig3(effort: Effort) -> Fig3 {
    let spec = GpuArch::A100.spec();
    let w = suites::MM2;
    let space = ScheduleSpace::new(w, &spec);
    let mut rng = Rng::seed_from_u64(3);
    let n = match effort {
        Effort::Quick => 200,
        Effort::Paper => 800,
    };
    let g = w.gemm_view();
    let series: Vec<(f64, f64)> = space
        .sample_n(&mut rng, n)
        .iter()
        .map(|s| {
            let ev = sim::evaluate(&g, s, &spec);
            (ev.latency_s * 1e3, ev.avg_power_w)
        })
        .collect();
    let xs: Vec<f64> = series.iter().map(|p| p.0).collect();
    let ys: Vec<f64> = series.iter().map(|p| p.1).collect();
    Fig3 { pearson_r: stats::pearson(&xs, &ys), series }
}

// ---------------------------------------------------------------------
// Figure 4: cost-model predicted vs measured normalized energy
// ---------------------------------------------------------------------

#[derive(Debug, Clone)]
pub struct Fig4Panel {
    pub name: String,
    pub workload: Workload,
    /// (normalized predicted, normalized measured) on the held-out 20%.
    pub points: Vec<(f64, f64)>,
    pub r2: f64,
    pub spearman: f64,
}

#[derive(Debug, Clone)]
pub struct Fig4 {
    pub panels: Vec<Fig4Panel>,
}

impl Fig4 {
    pub fn to_csv(&self) -> String {
        let mut t = TextTable::new(&["panel", "predicted_norm", "measured_norm"]);
        for p in &self.panels {
            for (pr, me) in &p.points {
                t.row(vec![p.name.clone(), format!("{pr}"), format!("{me}")]);
            }
        }
        t.to_csv()
    }

    pub fn summary(&self) -> String {
        let parts: Vec<String> = self
            .panels
            .iter()
            .map(|p| {
                format!("{}: R2={} rho={}", p.name, f(p.r2, 3), f(p.spearman, 3))
            })
            .collect();
        format!("Fig 4 (cost model, 80/20 split): {}", parts.join("; "))
    }
}

pub fn fig4(effort: Effort) -> Fig4 {
    let spec = GpuArch::A100.spec();
    let n = match effort {
        Effort::Quick => 400,
        Effort::Paper => 2000,
    };
    let panels = suites::fig4_suite()
        .into_iter()
        .enumerate()
        .map(|(i, (name, w))| {
            let space = ScheduleSpace::new(w, &spec);
            let mut rng = Rng::seed_from_u64(100 + i as u64);
            let mut meter = NvmlMeter::warmed(spec.clone(), Default::default());
            let schedules = space.sample_n(&mut rng, n);
            let split = n * 8 / 10;

            let mut model = EnergyCostModel::new(Default::default());
            let train: Vec<_> = schedules[..split]
                .iter()
                .map(|s| {
                    let c = Candidate::new(w, *s);
                    let m = meter.measure(&c, &mut rng);
                    (featurize(&c, &spec), m.energy_j)
                })
                .collect();
            model.update(&train, &mut rng);

            let mut pred = Vec::new();
            let mut meas = Vec::new();
            for s in &schedules[split..] {
                let c = Candidate::new(w, *s);
                pred.push(model.predict_energy_j(&featurize(&c, &spec)));
                meas.push(meter.measure(&c, &mut rng).energy_j);
            }
            // Normalize both axes to [0, 1] as in the figure.
            let pmax = pred.iter().cloned().fold(f64::MIN, f64::max);
            let mmax = meas.iter().cloned().fold(f64::MIN, f64::max);
            let points: Vec<(f64, f64)> =
                pred.iter().zip(&meas).map(|(p, m)| (p / pmax, m / mmax)).collect();
            Fig4Panel {
                name: name.to_string(),
                workload: w,
                r2: stats::r2(&pred, &meas),
                spearman: stats::spearman(&pred, &meas),
                points,
            }
        })
        .collect();
    Fig4 { panels }
}

// ---------------------------------------------------------------------
// Figure 5: NVML-only vs cost-model search time
// ---------------------------------------------------------------------

#[derive(Debug, Clone)]
pub struct Fig5Row {
    pub name: String,
    pub nvml_only_s: f64,
    pub cost_model_s: f64,
    pub nvml_measurements_nvml_only: usize,
    pub nvml_measurements_cost_model: usize,
}

impl Fig5Row {
    pub fn speedup(&self) -> f64 {
        self.nvml_only_s / self.cost_model_s
    }
}

#[derive(Debug, Clone)]
pub struct Fig5 {
    pub rows: Vec<Fig5Row>,
}

impl Fig5 {
    pub fn render(&self) -> String {
        let mut t = TextTable::new(&[
            "op",
            "NVML-only (s)",
            "cost-model (s)",
            "speedup",
            "meas (NVML-only)",
            "meas (cost-model)",
        ]);
        for r in &self.rows {
            t.row(vec![
                r.name.clone(),
                f(r.nvml_only_s, 1),
                f(r.cost_model_s, 1),
                format!("{}x", f(r.speedup(), 2)),
                r.nvml_measurements_nvml_only.to_string(),
                r.nvml_measurements_cost_model.to_string(),
            ]);
        }
        format!("Fig 5: search time, NVML-only vs cost-model (a100)\n{}", t.render())
    }
}

pub fn fig5(effort: Effort) -> Fig5 {
    // Paper setup: ~1000 kernels generated per search on the A100; µ is
    // tuned (as §7.4 does) so the measurement count roughly halves.
    let gpu = GpuArch::A100;
    let base = |mode, seed| -> SearchConfig {
        let mut c = effort.cfg(gpu, mode, seed);
        // §7.4: "adjusted the µ value to nearly halve the number of NVML
        // measurements". The SNR is computed on the *selected*
        // (lowest-predicted-energy) kernels — a restricted range whose
        // signal variance sits near the measurement noise floor — so the
        // tuned µ is low in absolute dB terms.
        c.mu_snr_db = -5.0;
        match effort {
            Effort::Paper => {
                c.population = 125;
                c.m_latency_keep = 32;
                c.rounds = 8; // 8 * 125 = 1000 kernels
                c.patience = 0;
            }
            Effort::Quick => {
                c.m_latency_keep = 12;
                c.rounds = 8;
                c.patience = 0;
            }
        }
        c
    };
    let rows = suites::table3_suite()
        .into_iter()
        .enumerate()
        .map(|(i, (name, w))| {
            let seed = 500 + i as u64;
            let ours = crate::search::run_search(w, &base(SearchMode::EnergyAware, seed));
            let nvml = crate::search::run_search(w, &base(SearchMode::EnergyNvmlOnly, seed));
            Fig5Row {
                name: name.to_string(),
                nvml_only_s: nvml.clock.total_s,
                cost_model_s: ours.clock.total_s,
                nvml_measurements_nvml_only: nvml.n_energy_measurements(),
                nvml_measurements_cost_model: ours.n_energy_measurements(),
            }
        })
        .collect();
    Fig5 { rows }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig3_inverse_correlation() {
        let fig = fig3(Effort::Quick);
        assert!(fig.pearson_r < -0.3, "r = {}", fig.pearson_r);
        assert!(fig.to_csv().lines().count() > 100);
    }

    #[test]
    fn fig5_cost_model_is_faster_and_measures_less() {
        let fig = fig5(Effort::Quick);
        for r in &fig.rows {
            assert!(r.speedup() > 1.0, "{}: speedup {}", r.name, r.speedup());
            assert!(
                r.nvml_measurements_cost_model < r.nvml_measurements_nvml_only,
                "{}: {} !< {}",
                r.name,
                r.nvml_measurements_cost_model,
                r.nvml_measurements_nvml_only
            );
        }
    }
}
