//! Reporting helpers shared by the experiment harnesses: aligned text
//! tables, CSV emission, and the paper-vs-measured delta format used in
//! EXPERIMENTS.md.

use std::fmt::Write as _;
use std::path::Path;

/// Percent change from `old` to `new` (negative = reduction).
pub fn pct_change(new: f64, old: f64) -> f64 {
    (new - old) / old * 100.0
}

/// Percent reduction from `old` to `new` (positive = saved energy).
pub fn pct_reduction(new: f64, old: f64) -> f64 {
    (old - new) / old * 100.0
}

/// A simple aligned text table.
pub struct TextTable {
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl TextTable {
    pub fn new(header: &[&str]) -> TextTable {
        TextTable { header: header.iter().map(|s| s.to_string()).collect(), rows: Vec::new() }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.header.len(), "row arity");
        self.rows.push(cells);
    }

    pub fn render(&self) -> String {
        let ncol = self.header.len();
        let mut widths = vec![0usize; ncol];
        for (i, h) in self.header.iter().enumerate() {
            widths[i] = h.len();
        }
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = String::new();
        let line = |cells: &[String], out: &mut String| {
            for (i, c) in cells.iter().enumerate() {
                let _ = write!(out, "{:>w$}  ", c, w = widths[i]);
            }
            out.push('\n');
        };
        line(&self.header, &mut out);
        let total: usize = widths.iter().sum::<usize>() + 2 * ncol;
        out.push_str(&"-".repeat(total));
        out.push('\n');
        for row in &self.rows {
            line(row, &mut out);
        }
        out
    }

    /// CSV rendering (header + rows, comma-separated, quoted as needed).
    pub fn to_csv(&self) -> String {
        let esc = |s: &str| {
            if s.contains(',') || s.contains('"') {
                format!("\"{}\"", s.replace('"', "\"\""))
            } else {
                s.to_string()
            }
        };
        let mut out = String::new();
        out.push_str(&self.header.iter().map(|h| esc(h)).collect::<Vec<_>>().join(","));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&row.iter().map(|c| esc(c)).collect::<Vec<_>>().join(","));
            out.push('\n');
        }
        out
    }
}

/// Write text to `results/<name>`, creating the directory.
pub fn write_result_file(name: &str, text: &str) -> std::io::Result<std::path::PathBuf> {
    let dir = results_dir();
    std::fs::create_dir_all(&dir)?;
    let path = dir.join(name);
    std::fs::write(&path, text)?;
    Ok(path)
}

/// The results directory (override with ECOKERNEL_RESULTS).
pub fn results_dir() -> std::path::PathBuf {
    std::env::var("ECOKERNEL_RESULTS")
        .map(std::path::PathBuf::from)
        .unwrap_or_else(|_| Path::new("results").to_path_buf())
}

/// Format a float with `d` decimals.
pub fn f(x: f64, d: usize) -> String {
    format!("{x:.d$}")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pct_math() {
        assert!((pct_reduction(6.5, 8.3) - 21.686).abs() < 0.01);
        assert!((pct_change(0.0352, 0.0347) - 1.44).abs() < 0.02);
    }

    #[test]
    fn table_renders_aligned_and_csv() {
        let mut t = TextTable::new(&["op", "energy (mJ)", "reduction"]);
        t.row(vec!["MM1".into(), "6.5".into(), "21.69%".into()]);
        t.row(vec!["CONV2".into(), "77.79".into(), "13.05%".into()]);
        let text = t.render();
        assert!(text.contains("MM1"));
        assert!(text.lines().count() == 4);
        let csv = t.to_csv();
        assert!(csv.starts_with("op,energy (mJ),reduction\n"));
        assert_eq!(csv.lines().count(), 3);
    }

    #[test]
    #[should_panic(expected = "row arity")]
    fn wrong_arity_panics() {
        let mut t = TextTable::new(&["a", "b"]);
        t.row(vec!["x".into()]);
    }
}
