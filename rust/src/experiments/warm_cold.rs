//! Warm-vs-cold experiment: quantify what the tuning store saves.
//!
//! For each operator family, an anchor workload is tuned into a fresh
//! store, then a neighboring workload is tuned twice — cold (stateless,
//! the seed behaviour) and warm (store + transfer). The report counts
//! NVML energy measurements and simulated search seconds saved at
//! equal-or-better final energy, plus the exact-hit replay of the
//! anchor (0 measurements, 0 seconds).

use super::report::{f, pct_reduction, TextTable};
use super::tables::Effort;
use crate::config::{GpuArch, SearchMode};
use crate::search::run_search;
use crate::workload::{suites, Workload};
use std::sync::atomic::{AtomicUsize, Ordering};

/// One warm-vs-cold comparison row.
#[derive(Debug, Clone)]
pub struct WarmColdRow {
    pub name: String,
    pub anchor: String,
    pub cold_measurements: usize,
    pub warm_measurements: usize,
    pub cold_sim_s: f64,
    pub warm_sim_s: f64,
    pub cold_energy_j: f64,
    pub warm_energy_j: f64,
}

impl WarmColdRow {
    pub fn measurements_saved_pct(&self) -> f64 {
        pct_reduction(self.warm_measurements as f64, self.cold_measurements as f64)
    }

    pub fn sim_time_saved_pct(&self) -> f64 {
        pct_reduction(self.warm_sim_s, self.cold_sim_s)
    }
}

/// The full warm-vs-cold report.
#[derive(Debug, Clone)]
pub struct WarmColdReport {
    pub rows: Vec<WarmColdRow>,
    /// Energy measurements of replaying the first anchor (exact hit).
    pub exact_hit_measurements: usize,
    /// Simulated seconds of the exact-hit replay.
    pub exact_hit_sim_s: f64,
    /// The anchor's original (cold, store-writing) search cost.
    pub anchor_cold_sim_s: f64,
}

impl WarmColdReport {
    pub fn avg_measurements_saved_pct(&self) -> f64 {
        self.rows.iter().map(|r| r.measurements_saved_pct()).sum::<f64>()
            / self.rows.len().max(1) as f64
    }

    pub fn render(&self) -> String {
        let mut t = TextTable::new(&[
            "op",
            "anchor",
            "cold meas",
            "warm meas",
            "meas saved",
            "cold sim (s)",
            "warm sim (s)",
            "time saved",
            "cold E (mJ)",
            "warm E (mJ)",
        ]);
        for r in &self.rows {
            t.row(vec![
                r.name.clone(),
                r.anchor.clone(),
                r.cold_measurements.to_string(),
                r.warm_measurements.to_string(),
                format!("{:.1}%", r.measurements_saved_pct()),
                f(r.cold_sim_s, 1),
                f(r.warm_sim_s, 1),
                format!("{:.1}%", r.sim_time_saved_pct()),
                f(r.cold_energy_j * 1e3, 3),
                f(r.warm_energy_j * 1e3, 3),
            ]);
        }
        format!(
            "Warm-start transfer vs cold search (store-seeded neighbors)\n{}\navg measurements saved: {:.1}%\nexact-hit replay of anchor: {} measurements, {:.1}s simulated (cold anchor paid {:.1}s)\n",
            t.render(),
            self.avg_measurements_saved_pct(),
            self.exact_hit_measurements,
            self.exact_hit_sim_s,
            self.anchor_cold_sim_s,
        )
    }

    pub fn to_csv(&self) -> String {
        let mut t = TextTable::new(&[
            "op",
            "anchor",
            "cold_measurements",
            "warm_measurements",
            "cold_sim_s",
            "warm_sim_s",
            "cold_energy_mj",
            "warm_energy_mj",
        ]);
        for r in &self.rows {
            t.row(vec![
                r.name.clone(),
                r.anchor.clone(),
                r.cold_measurements.to_string(),
                r.warm_measurements.to_string(),
                r.cold_sim_s.to_string(),
                r.warm_sim_s.to_string(),
                (r.cold_energy_j * 1e3).to_string(),
                (r.warm_energy_j * 1e3).to_string(),
            ]);
        }
        t.to_csv()
    }
}

static RUN_COUNTER: AtomicUsize = AtomicUsize::new(0);

/// Anchor → target pairs, one per operator family (MM / MV / CONV).
fn family_pairs() -> Vec<(&'static str, Workload, &'static str, Workload)> {
    vec![
        ("MM3", suites::MM3, "MM1", suites::MM1),
        ("MV4", suites::MV4, "MV3", suites::MV3),
        ("CONV3", suites::CONV3, "CONV2", suites::CONV2),
    ]
}

/// Run the warm-vs-cold comparison across the operator families.
pub fn warm_cold(effort: Effort) -> WarmColdReport {
    let run_id = RUN_COUNTER.fetch_add(1, Ordering::Relaxed);
    let dir = std::env::temp_dir()
        .join(format!("ecokernel_warmcold_{}_{run_id}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    let store_dir = dir.to_string_lossy().into_owned();

    let mut rows = Vec::new();
    let mut anchor_cold_sim_s = 0.0;
    let mut first_anchor_cfg = None;
    for (i, (anchor_name, anchor, target_name, target)) in family_pairs().into_iter().enumerate() {
        // 1. Tune the anchor into the store (a cold search that records
        //    its outcome).
        let mut anchor_cfg = effort.cfg(GpuArch::A100, SearchMode::EnergyAware, 40 + i as u64);
        anchor_cfg.store.dir = Some(store_dir.clone());
        let anchor_out = run_search(anchor, &anchor_cfg);
        anchor_cold_sim_s += anchor_out.clock.total_s;
        if first_anchor_cfg.is_none() {
            first_anchor_cfg = Some((anchor, anchor_cfg.clone()));
        }

        // 2. Tune the target cold (no store) and warm (store + transfer)
        //    with identical config and seed.
        let cold_cfg = effort.cfg(GpuArch::A100, SearchMode::EnergyAware, 50 + i as u64);
        let cold = run_search(target, &cold_cfg);
        let mut warm_cfg = cold_cfg.clone();
        warm_cfg.store.dir = Some(store_dir.clone());
        let warm = run_search(target, &warm_cfg);

        rows.push(WarmColdRow {
            name: target_name.to_string(),
            anchor: anchor_name.to_string(),
            cold_measurements: cold.n_energy_measurements(),
            warm_measurements: warm.n_energy_measurements(),
            cold_sim_s: cold.clock.total_s,
            warm_sim_s: warm.clock.total_s,
            cold_energy_j: cold.best.energy_j,
            warm_energy_j: warm.best.energy_j,
        });
    }

    // 3. Replay the first anchor: an exact hit costs nothing.
    let (anchor, anchor_cfg) = first_anchor_cfg.expect("at least one family");
    let replay = run_search(anchor, &anchor_cfg);
    let report = WarmColdReport {
        rows,
        exact_hit_measurements: replay.n_energy_measurements(),
        exact_hit_sim_s: replay.clock.total_s,
        anchor_cold_sim_s,
    };
    let _ = std::fs::remove_dir_all(&dir);
    report
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn warm_cold_saves_measurements_at_equal_energy() {
        let r = warm_cold(Effort::Quick);
        assert_eq!(r.rows.len(), 3);
        // The exact-hit replay is free.
        assert_eq!(r.exact_hit_measurements, 0);
        assert_eq!(r.exact_hit_sim_s, 0.0);
        // Transfer saves measurements on average across the families.
        assert!(
            r.avg_measurements_saved_pct() > 0.0,
            "no average saving:\n{}",
            r.render()
        );
        // No family regresses final energy beyond noise.
        for row in &r.rows {
            assert!(
                row.warm_energy_j <= row.cold_energy_j * 1.05,
                "{}: warm {} mJ vs cold {} mJ",
                row.name,
                row.warm_energy_j * 1e3,
                row.cold_energy_j * 1e3
            );
        }
        let text = r.render();
        assert!(text.contains("exact-hit"));
        assert!(r.to_csv().lines().count() == 4);
    }
}
