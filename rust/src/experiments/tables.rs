//! Table experiments: Tables 2, 3, 4, and 5 of the paper.

use super::report::{f, pct_change, pct_reduction, TextTable};
use crate::baselines::CublasSim;
use crate::config::{GpuArch, SearchConfig, SearchMode};
use crate::coordinator::{Driver, DriverConfig, SearchJob};
use crate::schedule::Candidate;
use crate::search::EvaluatedKernel;
use crate::sim;
use crate::workload::{suites, Workload};

/// Search effort preset: `paper` for the real runs, `quick` for tests.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Effort {
    Quick,
    Paper,
}

impl Effort {
    pub fn cfg(self, gpu: GpuArch, mode: SearchMode, seed: u64) -> SearchConfig {
        match self {
            Effort::Quick => SearchConfig {
                gpu,
                mode,
                seed,
                population: 48,
                m_latency_keep: 12,
                rounds: 5,
                patience: 0,
                ..Default::default()
            },
            Effort::Paper => SearchConfig {
                gpu,
                mode,
                seed,
                population: 128,
                m_latency_keep: 32,
                rounds: 12,
                patience: 5,
                ..Default::default()
            },
        }
    }
}

/// One A/B row: baseline (Ansor) vs ours on one operator.
#[derive(Debug, Clone)]
pub struct AbRow {
    pub name: String,
    pub workload: Workload,
    pub ansor: EvaluatedKernel,
    pub ours: EvaluatedKernel,
}

impl AbRow {
    pub fn energy_reduction_pct(&self) -> f64 {
        pct_reduction(self.ours.energy_j, self.ansor.energy_j)
    }

    pub fn latency_increase_pct(&self) -> f64 {
        pct_change(self.ours.latency_s, self.ansor.latency_s)
    }
}

/// A completed A/B comparison table (Table 2 or Table 3).
#[derive(Debug, Clone)]
pub struct AbTable {
    pub gpu: GpuArch,
    pub rows: Vec<AbRow>,
}

impl AbTable {
    pub fn avg_energy_reduction_pct(&self) -> f64 {
        self.rows.iter().map(|r| r.energy_reduction_pct()).sum::<f64>() / self.rows.len() as f64
    }

    pub fn avg_latency_increase_pct(&self) -> f64 {
        self.rows.iter().map(|r| r.latency_increase_pct()).sum::<f64>() / self.rows.len() as f64
    }

    pub fn render(&self, title: &str) -> String {
        let mut t = TextTable::new(&[
            "op",
            "Ansor E (mJ)",
            "Ours E (mJ)",
            "E reduction",
            "Ansor lat (ms)",
            "Ours lat (ms)",
            "lat change",
        ]);
        for r in &self.rows {
            t.row(vec![
                r.name.clone(),
                f(r.ansor.energy_j * 1e3, 3),
                f(r.ours.energy_j * 1e3, 3),
                format!("{}%", f(r.energy_reduction_pct(), 2)),
                f(r.ansor.latency_s * 1e3, 4),
                f(r.ours.latency_s * 1e3, 4),
                format!("{}%", f(r.latency_increase_pct(), 2)),
            ]);
        }
        t.row(vec![
            "Average".into(),
            "".into(),
            "".into(),
            format!("{}%", f(self.avg_energy_reduction_pct(), 2)),
            "".into(),
            "".into(),
            format!("{}%", f(self.avg_latency_increase_pct(), 2)),
        ]);
        format!("{title} ({})\n{}", self.gpu, t.render())
    }

    pub fn to_csv(&self) -> String {
        let mut t = TextTable::new(&[
            "op",
            "ansor_energy_mj",
            "ours_energy_mj",
            "energy_reduction_pct",
            "ansor_latency_ms",
            "ours_latency_ms",
            "latency_increase_pct",
        ]);
        for r in &self.rows {
            t.row(vec![
                r.name.clone(),
                format!("{}", r.ansor.energy_j * 1e3),
                format!("{}", r.ours.energy_j * 1e3),
                format!("{}", r.energy_reduction_pct()),
                format!("{}", r.ansor.latency_s * 1e3),
                format!("{}", r.ours.latency_s * 1e3),
                format!("{}", r.latency_increase_pct()),
            ]);
        }
        t.to_csv()
    }
}

/// Run an Ansor-vs-ours A/B over a named suite on one GPU.
pub fn run_ab(
    gpu: GpuArch,
    suite: Vec<(&'static str, Workload)>,
    effort: Effort,
) -> AbTable {
    let driver = Driver::new(DriverConfig::default());
    let mut jobs = Vec::new();
    for (i, (name, w)) in suite.iter().enumerate() {
        // Same seed for both arms: identical initial population, so the
        // comparison isolates the selection policy.
        let seed = 1000 + i as u64;
        jobs.push(SearchJob {
            name: format!("{name}/ansor"),
            workload: *w,
            cfg: effort.cfg(gpu, SearchMode::LatencyOnly, seed),
        });
        jobs.push(SearchJob {
            name: format!("{name}/ours"),
            workload: *w,
            cfg: effort.cfg(gpu, SearchMode::EnergyAware, seed),
        });
    }
    let (results, _metrics) = driver.run_suite(jobs);
    let rows = results
        .chunks(2)
        .zip(&suite)
        .map(|(pair, (name, w))| AbRow {
            name: name.to_string(),
            workload: *w,
            ansor: pair[0].outcome.best,
            ours: pair[1].outcome.best,
        })
        .collect();
    AbTable { gpu, rows }
}

/// Table 2: the full 11-operator suite on the A100.
pub fn table2(effort: Effort) -> AbTable {
    run_ab(GpuArch::A100, suites::table2_suite(), effort)
}

/// Table 3: MM / MV / CONV on the RTX 4090.
pub fn table3(effort: Effort) -> AbTable {
    run_ab(GpuArch::Rtx4090, suites::table3_suite(), effort)
}

/// Table 4: ours vs the cuBLAS-sim vendor library on MM1/MM2/MV1/MV2.
#[derive(Debug, Clone)]
pub struct Table4 {
    pub rows: Vec<(String, EvaluatedKernel, EvaluatedKernel)>, // (name, cublas, ours)
}

impl Table4 {
    pub fn render(&self) -> String {
        let mut t = TextTable::new(&[
            "op",
            "cuBLAS E (mJ)",
            "Ours E (mJ)",
            "E reduction",
            "cuBLAS lat (ms)",
            "Ours lat (ms)",
        ]);
        for (name, cublas, ours) in &self.rows {
            t.row(vec![
                name.clone(),
                f(cublas.energy_j * 1e3, 3),
                f(ours.energy_j * 1e3, 3),
                format!("{}%", f(pct_reduction(ours.energy_j, cublas.energy_j), 2)),
                f(cublas.latency_s * 1e3, 4),
                f(ours.latency_s * 1e3, 4),
            ]);
        }
        format!("Table 4: ours vs cuBLAS (a100)\n{}", t.render())
    }
}

pub fn table4(effort: Effort) -> Table4 {
    let lib = CublasSim::new(GpuArch::A100);
    let driver = Driver::new(DriverConfig::default());
    let suite = suites::table4_suite();
    let jobs = suite
        .iter()
        .enumerate()
        .map(|(i, (name, w))| SearchJob {
            name: format!("{name}/ours"),
            workload: *w,
            cfg: effort.cfg(GpuArch::A100, SearchMode::EnergyAware, 1000 + i as u64),
        })
        .collect();
    let (results, _) = driver.run_suite(jobs);
    let rows = suite
        .iter()
        .zip(&results)
        .map(|((name, w), r)| (name.to_string(), lib.kernel_for(*w), r.outcome.best))
        .collect();
    Table4 { rows }
}

/// Table 5: the §8 case-study profile — our kernel (K1) vs Ansor's (K2)
/// on MM(1, 512, 512, 512).
#[derive(Debug, Clone)]
pub struct Table5 {
    pub k1: sim::KernelProfile,
    pub k2: sim::KernelProfile,
    pub k1_eval: sim::Evaluation,
    pub k2_eval: sim::Evaluation,
}

impl Table5 {
    pub fn render(&self) -> String {
        let mut t = TextTable::new(&[
            "kernel",
            "grid",
            "block",
            "sm_efficiency",
            "glb_ld",
            "glb_st",
            "shared_ld",
            "shared_st",
            "latency (ms)",
            "energy (mJ)",
        ]);
        for (name, p, e) in
            [("K1 (ours)", &self.k1, &self.k1_eval), ("K2 (Ansor)", &self.k2, &self.k2_eval)]
        {
            t.row(vec![
                name.into(),
                p.grid.to_string(),
                p.block.to_string(),
                format!("{}%", f(p.sm_efficiency_pct, 2)),
                p.glb_ld.to_string(),
                p.glb_st.to_string(),
                p.shared_ld.to_string(),
                p.shared_st.to_string(),
                f(e.latency_s * 1e3, 4),
                f(e.energy_j * 1e3, 2),
            ]);
        }
        format!("Table 5: case-study profile, MM(1,512,512,512) on a100\n{}", t.render())
    }
}

pub fn table5(effort: Effort) -> Table5 {
    let gpu = GpuArch::A100;
    let spec = gpu.spec();
    let ours = crate::search::run_search(
        suites::MM1,
        &effort.cfg(gpu, SearchMode::EnergyAware, 1000),
    );
    let ansor = crate::search::run_search(
        suites::MM1,
        &effort.cfg(gpu, SearchMode::LatencyOnly, 1000),
    );
    let k1_eval = sim::evaluate_candidate(&Candidate::new(suites::MM1, ours.best.schedule), &spec);
    let k2_eval = sim::evaluate_candidate(&Candidate::new(suites::MM1, ansor.best.schedule), &spec);
    Table5 { k1: k1_eval.profile, k2: k2_eval.profile, k1_eval, k2_eval }
}

/// Table 1 is the qualitative related-work matrix; printed verbatim for
/// completeness.
pub fn table1() -> String {
    let mut t = TextTable::new(&["property", "ODPP", "Zeus", "Ansor", "Ours"]);
    t.row(vec!["Energy aware".into(), "yes".into(), "yes".into(), "".into(), "yes".into()]);
    t.row(vec!["System flexible".into(), "".into(), "yes".into(), "yes".into(), "yes".into()]);
    t.row(vec!["Workload friendly".into(), "yes".into(), "".into(), "yes".into(), "yes".into()]);
    t.row(vec![
        "Big exploration space".into(),
        "".into(),
        "yes".into(),
        "yes".into(),
        "yes".into(),
    ]);
    t.row(vec![
        "Fast energy evaluation".into(),
        "yes".into(),
        "".into(),
        "".into(),
        "yes".into(),
    ]);
    format!("Table 1: qualitative comparison (from the paper)\n{}", t.render())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table5_reproduces_case_study_ordering() {
        let t = table5(Effort::Paper);
        // §8: ours has the smaller grid, bigger block, lower
        // sm_efficiency, fewer global+shared loads, lower energy.
        assert!(t.k1.grid <= t.k2.grid, "grid {} !<= {}", t.k1.grid, t.k2.grid);
        assert!(
            t.k1_eval.energy_j < t.k2_eval.energy_j * 1.02,
            "energy {} !< {}",
            t.k1_eval.energy_j,
            t.k2_eval.energy_j
        );
        // Similar latency (the case study's point).
        let dl = (t.k1_eval.latency_s - t.k2_eval.latency_s).abs() / t.k2_eval.latency_s;
        assert!(dl < 0.35, "latency gap {dl}");
        let text = t.render();
        assert!(text.contains("K1 (ours)"));
    }

    #[test]
    fn table1_prints() {
        assert!(table1().contains("Fast energy evaluation"));
    }
}
