//! Operator workload definitions.
//!
//! The paper evaluates three operator families (§7.1): general matrix
//! multiplication (MM), matrix-vector multiplication (MV), and 2-D
//! convolution (Conv). Shapes follow the paper's notation:
//! MM/MV = (batch, M, N, K), Conv = (batch, H, W, Cin, Cout, ksize,
//! stride, pad).

pub mod suites;


/// One operator instance (type + shape).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Workload {
    /// C[b,m,n] = sum_k A[b,m,k] * B[b,k,n]
    MatMul { batch: usize, m: usize, n: usize, k: usize },
    /// y[b,n] = sum_k x[b,k] * W[n,k]  (the paper's MV: M = 1)
    MatVec { batch: usize, n: usize, k: usize },
    /// NHWC conv: out[b, ho, wo, co] over (ksize x ksize x cin)
    Conv2d {
        batch: usize,
        h: usize,
        w: usize,
        cin: usize,
        cout: usize,
        ksize: usize,
        stride: usize,
        pad: usize,
    },
}

impl Workload {
    /// Operator family name ("mm" / "mv" / "conv").
    pub fn family(&self) -> &'static str {
        match self {
            Workload::MatMul { .. } => "mm",
            Workload::MatVec { .. } => "mv",
            Workload::Conv2d { .. } => "conv",
        }
    }

    /// Compact identifier usable in file names and the artifact registry,
    /// e.g. `mm_b1_m512_n512_k512`.
    pub fn id(&self) -> String {
        match *self {
            Workload::MatMul { batch, m, n, k } => format!("mm_b{batch}_m{m}_n{n}_k{k}"),
            Workload::MatVec { batch, n, k } => format!("mv_b{batch}_n{n}_k{k}"),
            Workload::Conv2d { batch, h, w, cin, cout, ksize, stride, pad } => {
                format!("conv_b{batch}_h{h}_w{w}_ci{cin}_co{cout}_k{ksize}_s{stride}_p{pad}")
            }
        }
    }

    /// FP32 multiply-accumulate count (1 MAC = 2 FLOPs).
    pub fn macs(&self) -> u64 {
        match *self {
            Workload::MatMul { batch, m, n, k } => (batch * m * n * k) as u64,
            Workload::MatVec { batch, n, k } => (batch * n * k) as u64,
            Workload::Conv2d { batch, cin, cout, ksize, .. } => {
                let (ho, wo) = self.conv_out_hw().expect("conv");
                (batch * ho * wo * cout * cin * ksize * ksize) as u64
            }
        }
    }

    /// Total FP32 FLOPs (2 * MACs).
    pub fn flops(&self) -> u64 {
        2 * self.macs()
    }

    /// Output spatial dims for conv (ho, wo); `None` for non-conv.
    pub fn conv_out_hw(&self) -> Option<(usize, usize)> {
        match *self {
            Workload::Conv2d { h, w, ksize, stride, pad, .. } => {
                let ho = (h + 2 * pad - ksize) / stride + 1;
                let wo = (w + 2 * pad - ksize) / stride + 1;
                Some((ho, wo))
            }
            _ => None,
        }
    }

    /// The GEMM view of this workload: every family lowers to an implicit
    /// (batch, M, N, K) GEMM — conv via implicit im2col. The schedule
    /// space and the simulator both operate on this view.
    pub fn gemm_view(&self) -> GemmView {
        match *self {
            Workload::MatMul { batch, m, n, k } => GemmView { batch, m, n, k, im2col: false },
            Workload::MatVec { batch, n, k } => GemmView { batch, m: 1, n, k, im2col: false },
            Workload::Conv2d { batch, cin, cout, ksize, .. } => {
                let (ho, wo) = self.conv_out_hw().expect("conv");
                GemmView {
                    batch,
                    m: ho * wo,
                    n: cout,
                    k: cin * ksize * ksize,
                    im2col: ksize > 1,
                }
            }
        }
    }

    /// Bytes of unique input data (FP32), the compulsory DRAM traffic floor.
    pub fn input_bytes(&self) -> u64 {
        match *self {
            Workload::MatMul { batch, m, n, k } => 4 * (batch * (m * k + k * n)) as u64,
            Workload::MatVec { batch, n, k } => 4 * (batch * k + n * k) as u64,
            Workload::Conv2d { batch, h, w, cin, cout, ksize, .. } => {
                4 * (batch * h * w * cin + cout * cin * ksize * ksize) as u64
            }
        }
    }

    /// Bytes of output data (FP32).
    pub fn output_bytes(&self) -> u64 {
        match *self {
            Workload::MatMul { batch, m, n, .. } => 4 * (batch * m * n) as u64,
            Workload::MatVec { batch, n, .. } => 4 * (batch * n) as u64,
            Workload::Conv2d { batch, cout, .. } => {
                let (ho, wo) = self.conv_out_hw().expect("conv");
                4 * (batch * ho * wo * cout) as u64
            }
        }
    }

    /// Arithmetic intensity floor: FLOPs per compulsory DRAM byte.
    pub fn arithmetic_intensity(&self) -> f64 {
        self.flops() as f64 / (self.input_bytes() + self.output_bytes()) as f64
    }

    /// True when the workload is memory-bandwidth-bound on `peak_gflops`
    /// vs `dram_bw_gbs` hardware even at perfect reuse.
    pub fn is_memory_bound_on(&self, spec: &crate::config::GpuSpec) -> bool {
        self.arithmetic_intensity() < spec.roofline_knee()
    }
}

impl std::fmt::Display for Workload {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match *self {
            Workload::MatMul { batch, m, n, k } => write!(f, "MM({batch}, {m}, {n}, {k})"),
            Workload::MatVec { batch, n, k } => write!(f, "MV({batch}, 1, {n}, {k})"),
            Workload::Conv2d { batch, h, w, cin, cout, ksize, stride, pad } => {
                write!(f, "CONV({batch}, {h}, {w}, {cin}, {cout}, {ksize}, {stride}, {pad})")
            }
        }
    }
}

/// The implicit-GEMM view of a workload.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct GemmView {
    pub batch: usize,
    pub m: usize,
    pub n: usize,
    pub k: usize,
    /// True when the GEMM is an implicit im2col (overlapping input
    /// windows: extra index arithmetic + better L2 locality on A).
    pub im2col: bool,
}

impl GemmView {
    /// MACs in the GEMM view.
    pub fn macs(&self) -> u64 {
        (self.batch * self.m * self.n * self.k) as u64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mm_flops() {
        let w = Workload::MatMul { batch: 1, m: 512, n: 512, k: 512 };
        assert_eq!(w.flops(), 2 * 512 * 512 * 512);
        assert_eq!(w.family(), "mm");
    }

    #[test]
    fn mv_is_m1_gemm() {
        let w = Workload::MatVec { batch: 8, n: 4096, k: 1024 };
        let g = w.gemm_view();
        assert_eq!((g.batch, g.m, g.n, g.k), (8, 1, 4096, 1024));
        assert_eq!(w.flops(), 2 * 8 * 4096 * 1024);
    }

    #[test]
    fn conv_out_dims_and_gemm() {
        // CONV1(8, 7, 7, 512, 512, 3, 1, 1): 'same' conv, 7x7 out.
        let w = Workload::Conv2d {
            batch: 8, h: 7, w: 7, cin: 512, cout: 512, ksize: 3, stride: 1, pad: 1,
        };
        assert_eq!(w.conv_out_hw(), Some((7, 7)));
        let g = w.gemm_view();
        assert_eq!((g.m, g.n, g.k), (49, 512, 512 * 9));
        assert!(g.im2col);

        // CONV2(16, 56, 56, 64, 64, 1, 1, 0): 1x1 conv — plain GEMM.
        let w = Workload::Conv2d {
            batch: 16, h: 56, w: 56, cin: 64, cout: 64, ksize: 1, stride: 1, pad: 0,
        };
        assert_eq!(w.conv_out_hw(), Some((56, 56)));
        assert!(!w.gemm_view().im2col);
    }

    #[test]
    fn mv_is_memory_bound_mm_is_not() {
        let spec = crate::config::GpuArch::A100.spec();
        let mv = Workload::MatVec { batch: 1, n: 49512, k: 12288 };
        let mm = Workload::MatMul { batch: 8, m: 1024, n: 1024, k: 1024 };
        assert!(mv.is_memory_bound_on(&spec));
        assert!(!mm.is_memory_bound_on(&spec));
    }

    #[test]
    fn ids_are_unique_across_suites() {
        let mut seen = std::collections::HashSet::new();
        for (name, w) in suites::all_named() {
            assert!(seen.insert(w.id()), "duplicate id for {name}: {}", w.id());
        }
    }

    #[test]
    fn display_matches_paper_notation() {
        let w = Workload::MatMul { batch: 1, m: 512, n: 512, k: 512 };
        assert_eq!(w.to_string(), "MM(1, 512, 512, 512)");
    }
}
