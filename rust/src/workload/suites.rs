//! The paper's named operator suites (§7.1).
//!
//! Table 2 (A100): MM1–MM4, MV1–MV4, CONV1–CONV3.
//! Table 3 (RTX 4090): MM, MV, CONV.
//! Table 4 (vs cuBLAS): MM1, MM2, MV1, MV2.
//! Figure 4 (cost model): MM(1,512³), MV(1,1,4096,1024), CONV2.

use super::Workload;

/// MM1(1, 512, 512, 512)
pub const MM1: Workload = Workload::MatMul { batch: 1, m: 512, n: 512, k: 512 };
/// MM2(1, 1024, 1024, 1024)
pub const MM2: Workload = Workload::MatMul { batch: 1, m: 1024, n: 1024, k: 1024 };
/// MM3(8, 512, 512, 512)
pub const MM3: Workload = Workload::MatMul { batch: 8, m: 512, n: 512, k: 512 };
/// MM4(8, 1024, 1024, 1024)
pub const MM4: Workload = Workload::MatMul { batch: 8, m: 1024, n: 1024, k: 1024 };
/// MV1(1, 1, 49512, 12288) — GPT-3-scale FFN row.
pub const MV1: Workload = Workload::MatVec { batch: 1, n: 49512, k: 12288 };
/// MV2(1, 1, 32768, 16384)
pub const MV2: Workload = Workload::MatVec { batch: 1, n: 32768, k: 16384 };
/// MV3(8, 1, 4096, 1024)
pub const MV3: Workload = Workload::MatVec { batch: 8, n: 4096, k: 1024 };
/// MV4(8, 1, 8192, 2048)
pub const MV4: Workload = Workload::MatVec { batch: 8, n: 8192, k: 2048 };
/// CONV1(8, 7, 7, 512, 512, 3, 1, 1) — ResNet-50 tail block.
pub const CONV1: Workload =
    Workload::Conv2d { batch: 8, h: 7, w: 7, cin: 512, cout: 512, ksize: 3, stride: 1, pad: 1 };
/// CONV2(16, 56, 56, 64, 64, 1, 1, 0) — ResNet-50 1x1 projection.
pub const CONV2: Workload =
    Workload::Conv2d { batch: 16, h: 56, w: 56, cin: 64, cout: 64, ksize: 1, stride: 1, pad: 0 };
/// CONV3(64, 56, 56, 64, 64, 1, 1, 0)
pub const CONV3: Workload =
    Workload::Conv2d { batch: 64, h: 56, w: 56, cin: 64, cout: 64, ksize: 1, stride: 1, pad: 0 };

/// Table-3 (RTX 4090) suite members.
pub const MM_4090: Workload = MM1;
/// MV(1, 1, 4096, 1024)
pub const MV_4090: Workload = Workload::MatVec { batch: 1, n: 4096, k: 1024 };
pub const CONV_4090: Workload = CONV2;

/// The Table 2 suite in paper order.
pub fn table2_suite() -> Vec<(&'static str, Workload)> {
    vec![
        ("MM1", MM1),
        ("MM2", MM2),
        ("MM3", MM3),
        ("MM4", MM4),
        ("MV1", MV1),
        ("MV2", MV2),
        ("MV3", MV3),
        ("MV4", MV4),
        ("CONV1", CONV1),
        ("CONV2", CONV2),
        ("CONV3", CONV3),
    ]
}

/// The Table 3 (RTX 4090) suite.
pub fn table3_suite() -> Vec<(&'static str, Workload)> {
    vec![("MM", MM_4090), ("MV", MV_4090), ("CONV", CONV_4090)]
}

/// The Table 4 (vs cuBLAS) suite.
pub fn table4_suite() -> Vec<(&'static str, Workload)> {
    vec![("MM1", MM1), ("MM2", MM2), ("MV1", MV1), ("MV2", MV2)]
}

/// The Figure 4 (cost-model accuracy) suite.
pub fn fig4_suite() -> Vec<(&'static str, Workload)> {
    vec![("MM", MM1), ("MV", MV_4090), ("CONV", CONV2)]
}

/// Every named workload across all suites (deduplicated by name).
pub fn all_named() -> Vec<(&'static str, Workload)> {
    let mut out: Vec<(&'static str, Workload)> = Vec::new();
    let mut seen = std::collections::HashSet::new();
    for (n, w) in table2_suite()
        .into_iter()
        .chain([("MM_4090", MM_4090), ("MV_4090", MV_4090), ("CONV_4090", CONV_4090)])
    {
        if seen.insert(w.id()) {
            out.push((n, w));
        }
    }
    out
}

/// Resolve a workload by its suite name (case-insensitive), e.g. "mm1",
/// "conv2", "mv_4090".
pub fn by_name(name: &str) -> Option<Workload> {
    let up = name.to_ascii_uppercase();
    match up.as_str() {
        "MM1" => Some(MM1),
        "MM2" => Some(MM2),
        "MM3" => Some(MM3),
        "MM4" => Some(MM4),
        "MV1" => Some(MV1),
        "MV2" => Some(MV2),
        "MV3" => Some(MV3),
        "MV4" => Some(MV4),
        "CONV1" => Some(CONV1),
        "CONV2" => Some(CONV2),
        "CONV3" => Some(CONV3),
        "MM_4090" | "MM4090" => Some(MM_4090),
        "MV_4090" | "MV4090" => Some(MV_4090),
        "CONV_4090" | "CONV4090" => Some(CONV_4090),
        _ => None,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn suites_have_paper_sizes() {
        assert_eq!(table2_suite().len(), 11);
        assert_eq!(table3_suite().len(), 3);
        assert_eq!(table4_suite().len(), 4);
        assert_eq!(fig4_suite().len(), 3);
    }

    #[test]
    fn by_name_resolves_each_table2_member() {
        for (name, w) in table2_suite() {
            assert_eq!(by_name(name), Some(w), "{name}");
            assert_eq!(by_name(&name.to_lowercase()), Some(w));
        }
        assert_eq!(by_name("bogus"), None);
    }

    #[test]
    fn mv1_shape_matches_paper() {
        if let Workload::MatVec { batch, n, k } = MV1 {
            assert_eq!((batch, n, k), (1, 49512, 12288));
        } else {
            panic!("MV1 must be MatVec");
        }
    }
}
