//! Comparison baselines: the cuBLAS-style vendor library (Table 4).

pub mod cublas;

pub use cublas::CublasSim;
