//! cuBLAS-sim: a "vendor library" of latency-tuned kernels (Table 4).
//!
//! Real cuBLAS ships hand-tuned kernels selected per shape for minimum
//! latency. We emulate that with an offline latency-only tuning pass of
//! generous budget (larger population, more rounds, no noise pressure),
//! pinned per (workload, architecture) and cached. The resulting
//! kernels reproduce Table 4's shape: lower latency than the
//! energy-aware search, but higher energy on compute-bound shapes.

use crate::config::{GpuArch, SearchConfig, SearchMode};
use crate::nvml::NvmlMeter;
use crate::schedule::{Candidate, Schedule};
use crate::search::EvaluatedKernel;
use crate::util::Rng;
use crate::workload::Workload;
use std::collections::HashMap;
use std::sync::Mutex;

/// The simulated vendor library.
pub struct CublasSim {
    arch: GpuArch,
    cache: Mutex<HashMap<String, EvaluatedKernel>>,
}

impl CublasSim {
    pub fn new(arch: GpuArch) -> CublasSim {
        CublasSim { arch, cache: Mutex::new(HashMap::new()) }
    }

    /// The vendor kernel for `workload`: latency-tuned with a large
    /// offline budget, then NVML-measured. Deterministic per
    /// (arch, workload); cached.
    pub fn kernel_for(&self, workload: Workload) -> EvaluatedKernel {
        let key = workload.id();
        if let Some(hit) = self.cache.lock().expect("cublas cache").get(&key) {
            return *hit;
        }
        let tuned = self.tune(workload);
        self.cache.lock().expect("cublas cache").insert(key, tuned);
        tuned
    }

    /// The pinned schedule behind the vendor kernel.
    pub fn schedule_for(&self, workload: Workload) -> Schedule {
        self.kernel_for(workload).schedule
    }

    fn tune(&self, workload: Workload) -> EvaluatedKernel {
        // Vendor-scale offline budget: 2x population, extra rounds,
        // fixed seed decoupled from user searches.
        let cfg = SearchConfig {
            gpu: self.arch,
            mode: SearchMode::LatencyOnly,
            population: 192,
            m_latency_keep: 48,
            rounds: 14,
            patience: 5,
            seed: 0xC0B1A5,
            ..Default::default()
        };
        let out = crate::search::latency_only::run(workload, &cfg);
        // Re-measure on a warmed device for a clean number.
        let spec = self.arch.spec();
        let mut meter = NvmlMeter::warmed(spec, cfg.nvml.clone());
        let mut rng = Rng::seed_from_u64(0xB1A5);
        let m = meter.measure(&Candidate::new(workload, out.best.schedule), &mut rng);
        EvaluatedKernel {
            schedule: out.best.schedule,
            latency_s: m.latency_s,
            energy_j: m.energy_j,
            avg_power_w: m.avg_power_w,
            energy_measured: true,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::suites;

    #[test]
    fn cublas_kernel_is_cached_and_deterministic() {
        let lib = CublasSim::new(GpuArch::A100);
        let a = lib.kernel_for(suites::MM1);
        let b = lib.kernel_for(suites::MM1);
        assert_eq!(a.schedule, b.schedule);
        assert_eq!(a.latency_s, b.latency_s);
    }

    #[test]
    fn cublas_is_fast() {
        // Table 4: cuBLAS latency beats the searched kernels.
        let lib = CublasSim::new(GpuArch::A100);
        let k = lib.kernel_for(suites::MM1);
        // Near the best latency the space offers (sanity bound).
        assert!(k.latency_s < 0.2e-3 * 3.0, "latency {}", k.latency_s);
        assert!(k.energy_measured);
    }
}
