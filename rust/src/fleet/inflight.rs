//! Fleet-wide miss coalescing: in-store claims on in-flight searches.
//!
//! Within one daemon, duplicate misses on a key coalesce through the
//! in-memory `pending` set. Across a fleet sharing one store, the same
//! dedup needs a marker **in the store**: before enqueueing a
//! background search, a daemon claims the key here; a claim that is
//! already held by a live fleet member means "someone is searching
//! this" and the miss is answered with the warm guess only. The claim
//! is a [`Lease`] (`<store>/inflight/<fnv64-of-key>.json`, the key
//! itself in the payload), so:
//!
//! * the daemon's heartbeat keeps it alive for the duration of a
//!   multi-second search;
//! * a crashed daemon's claim expires after the TTL and the next miss
//!   re-claims the key instead of coalescing into a dead search
//!   forever;
//! * the claim's **epoch** fences the write-back: a daemon that lost
//!   its claim mid-search (paused past the TTL, reclaimed elsewhere)
//!   has its late record rejected by
//!   [`crate::store::ShardedStore::append_claimed`].

use crate::store::lease::{now_ms, read_lease, Lease, LeaseInfo};
use crate::store::sharded::fnv1a;
use anyhow::Context as _;
use std::path::{Path, PathBuf};

/// Subdirectory of the store dir holding in-flight claims.
pub const INFLIGHT_DIR: &str = "inflight";

/// One daemon's view of the fleet's in-flight searches.
#[derive(Debug)]
pub struct InflightTable {
    dir: PathBuf,
    holder: String,
    ttl_ms: u64,
}

impl InflightTable {
    pub fn open(store_dir: &Path, holder: &str, ttl_ms: u64) -> anyhow::Result<InflightTable> {
        let dir = store_dir.join(INFLIGHT_DIR);
        std::fs::create_dir_all(&dir)
            .with_context(|| format!("create inflight dir {dir:?}"))?;
        Ok(InflightTable { dir, holder: holder.to_string(), ttl_ms })
    }

    fn path_of(&self, key: &str) -> PathBuf {
        self.dir.join(format!("{:016x}.json", fnv1a(key)))
    }

    /// Claim `key` for a background search. `Ok(None)` means another
    /// live fleet member already owns it — coalesce, don't search.
    pub fn claim(&self, key: &str) -> anyhow::Result<Option<Lease>> {
        Lease::acquire(&self.path_of(key), &self.holder, self.ttl_ms, Some(key))
    }

    /// The live claim on `key`, if any (payload-checked, so a hash
    /// collision never reports a foreign key as this one).
    pub fn owner(&self, key: &str) -> anyhow::Result<Option<LeaseInfo>> {
        let info = read_lease(&self.path_of(key))?;
        Ok(info.filter(|i| i.is_live(now_ms()) && i.payload.as_deref() == Some(key)))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp_dir(tag: &str) -> PathBuf {
        let dir = std::env::temp_dir()
            .join(format!("ecokernel_inflight_test_{tag}_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        dir
    }

    #[test]
    fn duplicate_claims_coalesce_until_release() {
        let dir = tmp_dir("dup");
        let a = InflightTable::open(&dir, "daemon-a", 60_000).unwrap();
        let b = InflightTable::open(&dir, "daemon-b", 60_000).unwrap();
        let key = "mm1|a100|energy_aware|fp";

        let claim = a.claim(key).unwrap().expect("first claim wins");
        assert!(b.claim(key).unwrap().is_none(), "duplicate miss coalesces fleet-wide");
        assert_eq!(b.owner(key).unwrap().unwrap().holder, "daemon-a");
        // Unrelated keys claim independently.
        assert!(b.claim("other|key").unwrap().is_some());

        claim.release().unwrap();
        assert!(b.owner(key).unwrap().is_none(), "released claim is gone");
        assert!(b.claim(key).unwrap().is_some(), "key reclaimable after release");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn crashed_holders_claim_expires_and_is_reclaimed() {
        let dir = tmp_dir("crash");
        let a = InflightTable::open(&dir, "daemon-a", 60).unwrap();
        let b = InflightTable::open(&dir, "daemon-b", 60_000).unwrap();
        let key = "mv3|a100|energy_aware|fp";

        let dead = a.claim(key).unwrap().expect("claimed");
        std::thread::sleep(std::time::Duration::from_millis(140));
        assert!(b.owner(key).unwrap().is_none(), "expired claim is not an owner");
        let reclaimed = b.claim(key).unwrap().expect("expired claim reclaimed");
        assert!(reclaimed.epoch() > dead.epoch(), "reclaim bumps the fencing epoch");
        assert!(!dead.is_current().unwrap(), "the dead claim is fenced out");
        let _ = std::fs::remove_dir_all(&dir);
    }
}
