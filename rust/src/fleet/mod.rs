//! Fleet serving: the pieces that turn the single-node daemon into N
//! daemons sharing one store.
//!
//! The paper's tuning cost amortizes best when a search runs **once
//! per fleet**, not once per daemon. Related auto-tuning deployments
//! (DSO-style offline stores, model-steered tuner reuse across
//! installs of one GPU family) show the traffic shape this exploits:
//! many frontends, heavy key repetition, one shared result store. The
//! subsystem has four parts:
//!
//! * [`transport`] — `unix:`/`tcp:` addresses behind one
//!   [`transport::Listener`]/[`transport::Stream`] pair; the versioned
//!   line-JSON frame protocol is wire-agnostic, so the same client
//!   bytes work against either.
//! * leases ([`crate::store::lease`]) — per-shard advisory lock files
//!   with epochs and heartbeat renewal; concurrent daemons append
//!   safely, exactly one at a time compacts/rebalances/evicts, and a
//!   crashed holder's lease expires and is reclaimed.
//! * [`inflight`] — in-store claims that coalesce duplicate misses
//!   **across** daemons: one member runs the search, the rest serve
//!   the warm guess and pick the record up from the store afterwards.
//! * [`admission`] — when the search queue saturates, a decayed
//!   per-key request-rate sketch decides who gets the next slot: hot
//!   keys are backlogged and pumped in heat order, cold keys are shed.
//! * [`notify`] — the write-back push path: a landed search is
//!   announced on an in-store channel, and peer daemons refresh only
//!   the touched shard instead of interval-polling the whole store
//!   (an interval poll remains as the fallback net).
//!
//! The serving daemon ([`crate::serve`], unix-gated for its socket
//! support) wires these together; the store side lives in
//! [`crate::store::sharded`] (fleet mode: incremental refresh, fenced
//! rewrites, epoch-fenced write-backs).

pub mod admission;
pub mod inflight;
pub mod notify;
pub mod transport;

pub use admission::{Backlog, HeatSketch, Offer, HEAT_BUCKETS};
pub use inflight::InflightTable;
pub use notify::{NotifyChannel, NotifyCursor, NotifyEvent};
pub use transport::{AddrList, Listener, ServeAddr, Stream};
