//! Write-back notify channel: push-path freshness for a daemon fleet.
//!
//! Before this channel existed, a daemon learned about other members'
//! landed write-backs only by *polling* the shared store — either a
//! per-request shard refresh (per-request disk I/O on the hot path) or
//! an interval refresh of **all** shards (O(shards) stats per tick,
//! regardless of what changed). The notify channel inverts that into a
//! push path: the daemon whose search lands **announces** the
//! write-back here, and every peer's refresh loop wakes up and
//! refreshes *only the touched shard*.
//!
//! Mechanically the channel is one append-only sequence file under the
//! store (`notify/events.jsonl`) plus a per-daemon in-memory cursor:
//!
//! ```text
//! {"key":"mm1|a100|energy_aware|fp…","shard":3,"holder":"daemon-412-0-…","epoch":7,
//!  "trace":"9f3c2a7b51e80d46"}
//! ```
//!
//! The optional `trace` field carries the originating request's
//! [`TraceId`] (hex) so the peer's refresh loop can close the causal
//! chain: a miss traced on daemon A shows its notify-refresh ingest as
//! a remote span on daemon B, under the same id.
//!
//! * **announce** — the writer loop appends one line per landed
//!   write-back (O_APPEND whole-line writes interleave safely across
//!   daemons, exactly like shard appends);
//! * **cursor** — each daemon remembers the byte offset it has
//!   consumed and tail-reads only complete new lines, skipping its own
//!   announcements (its memory already holds what it wrote);
//! * **epoch fencing** — announcements carry the in-flight claim epoch
//!   the record landed under (same fencing discipline as
//!   [`crate::store::lease`]); a stale epoch's announcement (a holder
//!   that lost its claim to a reclaim) is dropped by the cursor rather
//!   than triggering a refresh on behalf of a superseded writer;
//! * **compaction** — an oversized events file is truncated under the
//!   channel's lease and a generation file is bumped, so cursors reset
//!   instead of mis-applying stale offsets (the same gen/shrink
//!   discipline as shard rewrites).
//!
//! The channel is an *optimization*, never a correctness dependency:
//! a torn line, a lost announcement (crashed announcer, compaction
//! race), or a wedged notifier only delays freshness until the
//! daemon's interval **poll fallback** does a full refresh. The
//! serving daemon's miss path additionally keeps its own targeted
//! refresh, so an exact key requested ahead of its notify still hits.

use crate::store::lease::Lease;
use crate::telemetry::TraceId;
use crate::util::Json;
use anyhow::Context as _;
use std::collections::HashMap;
use std::path::{Path, PathBuf};

/// Subdirectory of the store dir holding the notify channel.
pub const NOTIFY_DIR: &str = "notify";
/// The append-only announcement file.
pub const EVENTS_FILE: &str = "events.jsonl";
/// Compaction generation counter (cursors reset when it bumps).
pub const GEN_FILE: &str = "gen";
/// Lease name guarding events-file compaction.
pub const NOTIFY_LEASE_NAME: &str = "compact";

/// Compact (truncate + gen bump) once the events file passes this
/// size. Generous: events are ~150 bytes, so this is thousands of
/// announcements of slack for a slow cursor before any are dropped —
/// and a dropped announcement only defers to the poll fallback.
const COMPACT_BYTES: u64 = 1 << 20;

/// Cursors fence stale epochs per key; bound the memory of that map on
/// a long-running daemon (clearing it only re-admits a redundant
/// refresh, never a wrong one).
const SEEN_KEYS_CAP: usize = 8192;

/// One announced write-back.
#[derive(Debug, Clone, PartialEq)]
pub struct NotifyEvent {
    /// Serve key of the landed record.
    pub key: String,
    /// Shard the key routes to (what the receiver refreshes).
    pub shard: usize,
    /// Announcing daemon's holder id (receivers skip their own).
    pub holder: String,
    /// In-flight claim epoch the write-back landed under; 0 = the
    /// record landed unclaimed (no fencing applies).
    pub epoch: u64,
    /// Originating request's trace id (hex), when the write-back came
    /// from a traced miss. Absent on pre-trace announcements and on
    /// landings with no trace — encoded only when present so old
    /// cursors parse new lines and vice versa.
    pub trace: Option<String>,
}

impl NotifyEvent {
    fn to_json(&self) -> Json {
        let mut fields = vec![
            ("key", Json::str(self.key.clone())),
            ("shard", Json::num(self.shard as f64)),
            ("holder", Json::str(self.holder.clone())),
            ("epoch", Json::num(self.epoch as f64)),
        ];
        if let Some(t) = &self.trace {
            fields.push(("trace", Json::str(t.clone())));
        }
        Json::obj(fields)
    }

    fn from_json(v: &Json) -> Option<NotifyEvent> {
        Some(NotifyEvent {
            key: v.get("key")?.as_str()?.to_string(),
            shard: v.get("shard")?.as_f64()? as usize,
            holder: v.get("holder")?.as_str()?.to_string(),
            epoch: v.get("epoch")?.as_f64()? as u64,
            trace: v.get("trace").and_then(|x| x.as_str()).map(|s| s.to_string()),
        })
    }

    /// The announcement's trace id, parsed; `None` when absent or
    /// malformed (a garbage trace must not drop the refresh itself).
    pub fn trace_id(&self) -> Option<TraceId> {
        self.trace.as_deref().and_then(TraceId::from_hex)
    }
}

/// One daemon's handle on the store's notify channel.
#[derive(Debug)]
pub struct NotifyChannel {
    dir: PathBuf,
    holder: String,
    lease_ttl_ms: u64,
}

impl NotifyChannel {
    pub fn open(
        store_dir: &Path,
        holder: &str,
        lease_ttl_ms: u64,
    ) -> anyhow::Result<NotifyChannel> {
        let dir = store_dir.join(NOTIFY_DIR);
        std::fs::create_dir_all(&dir).with_context(|| format!("create notify dir {dir:?}"))?;
        Ok(NotifyChannel { dir, holder: holder.to_string(), lease_ttl_ms })
    }

    fn events_path(&self) -> PathBuf {
        self.dir.join(EVENTS_FILE)
    }

    /// Announce one landed write-back (one O_APPEND line), carrying
    /// the originating trace id when the miss was traced. Compacts the
    /// channel opportunistically once it outgrows [`COMPACT_BYTES`].
    pub fn announce(
        &self,
        key: &str,
        shard: usize,
        epoch: u64,
        trace: Option<TraceId>,
    ) -> anyhow::Result<()> {
        let event = NotifyEvent {
            key: key.to_string(),
            shard,
            holder: self.holder.clone(),
            epoch,
            trace: trace.map(|t| t.to_hex()),
        };
        crate::store::append_jsonl(&self.events_path(), &event.to_json())?;
        let len = std::fs::metadata(self.events_path()).map(|m| m.len()).unwrap_or(0);
        if len > COMPACT_BYTES {
            self.compact()?;
        }
        Ok(())
    }

    /// Truncate the events file and bump the generation so cursors
    /// reset. Lease-guarded: skipped (`Ok(false)`) while another member
    /// compacts. Unread events are dropped — a cursor that lagged this
    /// far behind is caught up by its daemon's poll fallback.
    pub fn compact(&self) -> anyhow::Result<bool> {
        let lease_path = self.dir.join(format!("{NOTIFY_LEASE_NAME}.json"));
        let Some(lease) = Lease::acquire(&lease_path, &self.holder, self.lease_ttl_ms, None)?
        else {
            return Ok(false);
        };
        let res = (|| -> anyhow::Result<()> {
            // Truncate first, then bump the gen: a cursor racing the
            // window sees either old gen + shrunken file (caught by its
            // `len < offset` check) or the bump — never a stale offset
            // applied to content it did not read.
            write_atomic(&self.events_path(), "")?;
            let gen = read_gen(&self.dir) + 1;
            write_atomic(&self.dir.join(GEN_FILE), &format!("{gen}\n"))
        })();
        let _ = lease.release();
        res?;
        Ok(true)
    }

    /// A cursor starting at the channel's current end: history from
    /// before the open is already visible through the store open
    /// itself, so only *new* announcements are delivered.
    pub fn cursor(&self) -> anyhow::Result<NotifyCursor> {
        let offset = std::fs::metadata(self.events_path()).map(|m| m.len()).unwrap_or(0);
        Ok(NotifyCursor {
            events_path: self.events_path(),
            dir: self.dir.clone(),
            holder: self.holder.clone(),
            offset,
            gen: read_gen(&self.dir),
            seen: HashMap::new(),
        })
    }
}

/// One daemon's consumption state over the channel: byte offset of the
/// consumed prefix, the compaction generation it was read under, and
/// the per-key epoch fence.
#[derive(Debug)]
pub struct NotifyCursor {
    events_path: PathBuf,
    dir: PathBuf,
    /// Own announcements are skipped — this daemon's memory already
    /// holds everything it wrote.
    holder: String,
    offset: u64,
    gen: u64,
    /// Newest claim epoch delivered per key: a later announcement with
    /// a LOWER epoch comes from a holder that lost the key to a
    /// reclaim and is dropped (stale-epoch fencing).
    seen: HashMap<String, u64>,
}

impl NotifyCursor {
    /// Consume every new, foreign, unfenced announcement since the last
    /// poll. Idle cost is one metadata stat (two with a gen file read);
    /// malformed lines are skipped — garbage in the channel must never
    /// wedge a daemon, the poll fallback is the correctness net.
    pub fn poll(&mut self) -> anyhow::Result<Vec<NotifyEvent>> {
        use std::io::{Read as _, Seek as _};
        let disk_gen = read_gen(&self.dir);
        let len = std::fs::metadata(&self.events_path).map(|m| m.len()).unwrap_or(0);
        if disk_gen != self.gen || len < self.offset {
            // Compacted (or replaced) under us: restart from the top of
            // the new file. The epoch fence map survives the reset.
            self.gen = disk_gen;
            self.offset = 0;
        }
        if len == self.offset {
            return Ok(Vec::new());
        }
        let mut f = std::fs::File::open(&self.events_path)
            .with_context(|| format!("open notify events {:?}", self.events_path))?;
        f.seek(std::io::SeekFrom::Start(self.offset))
            .with_context(|| format!("seek notify events {:?}", self.events_path))?;
        let mut buf = String::new();
        f.read_to_string(&mut buf)
            .with_context(|| format!("read notify tail {:?}", self.events_path))?;
        // Complete lines only: a concurrent announce's unflushed tail
        // stays unconsumed until the next poll.
        let Some(end) = buf.rfind('\n') else { return Ok(Vec::new()) };
        let complete = &buf[..=end];
        let mut out = Vec::new();
        for line in complete.lines() {
            if line.trim().is_empty() {
                continue;
            }
            let Some(event) = Json::parse(line).ok().as_ref().and_then(NotifyEvent::from_json)
            else {
                continue;
            };
            if event.holder == self.holder {
                continue;
            }
            if event.epoch > 0 {
                if self.seen.len() >= SEEN_KEYS_CAP && !self.seen.contains_key(&event.key) {
                    self.seen.clear();
                }
                match self.seen.get(&event.key) {
                    Some(&newest) if event.epoch < newest => continue, // fenced
                    _ => {
                        self.seen.insert(event.key.clone(), event.epoch);
                    }
                }
            }
            out.push(event);
        }
        self.offset += complete.len() as u64;
        Ok(out)
    }
}

/// Last compaction generation of the channel (0 = never compacted).
fn read_gen(dir: &Path) -> u64 {
    std::fs::read_to_string(dir.join(GEN_FILE))
        .ok()
        .and_then(|t| t.trim().parse::<u64>().ok())
        .unwrap_or(0)
}

fn write_atomic(path: &Path, text: &str) -> anyhow::Result<()> {
    let tmp = path.with_extension("tmp");
    std::fs::write(&tmp, text).with_context(|| format!("write {tmp:?}"))?;
    std::fs::rename(&tmp, path).with_context(|| format!("replace {path:?}"))?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp_dir(tag: &str) -> PathBuf {
        let dir = std::env::temp_dir()
            .join(format!("ecokernel_notify_test_{tag}_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        dir
    }

    #[test]
    fn event_lines_roundtrip() {
        let event = NotifyEvent {
            key: "mm1|a100|energy_aware|fp".into(),
            shard: 5,
            holder: "daemon-1-0-abc".into(),
            epoch: 7,
            trace: None,
        };
        let line = event.to_json().to_string();
        assert!(!line.contains("trace"), "absent trace stays off the wire: {line}");
        let back = NotifyEvent::from_json(&Json::parse(&line).unwrap()).unwrap();
        assert_eq!(back, event);
        // Missing fields are unparseable, not a panic.
        assert_eq!(NotifyEvent::from_json(&Json::parse(r#"{"key":"k"}"#).unwrap()), None);

        // A traced announcement roundtrips and parses back to an id;
        // a garbage trace degrades to None instead of dropping the
        // event.
        let id = TraceId::from_hex("9f3c2a7b51e80d46").unwrap();
        let traced = NotifyEvent { trace: Some(id.to_hex()), ..event.clone() };
        let line = traced.to_json().to_string();
        let back = NotifyEvent::from_json(&Json::parse(&line).unwrap()).unwrap();
        assert_eq!(back, traced);
        assert_eq!(back.trace_id(), Some(id));
        let garbage = NotifyEvent { trace: Some("not-hex".into()), ..event };
        let back =
            NotifyEvent::from_json(&Json::parse(&garbage.to_json().to_string()).unwrap()).unwrap();
        assert_eq!(back.trace_id(), None, "malformed trace never drops the refresh");
    }

    #[test]
    fn cursor_delivers_foreign_events_and_skips_own() {
        let dir = tmp_dir("deliver");
        let a = NotifyChannel::open(&dir, "daemon-a", 60_000).unwrap();
        let b = NotifyChannel::open(&dir, "daemon-b", 60_000).unwrap();
        let mut cur_b = b.cursor().unwrap();

        a.announce("k1", 3, 1, None).unwrap();
        b.announce("k2", 0, 1, None).unwrap(); // b's own: skipped by b's cursor
        a.announce("k3", 7, 0, None).unwrap(); // unclaimed landing: epoch 0

        let events = cur_b.poll().unwrap();
        let keys: Vec<&str> = events.iter().map(|e| e.key.as_str()).collect();
        assert_eq!(keys, ["k1", "k3"], "own announcements skipped");
        assert_eq!(events[0].shard, 3);
        assert_eq!(events[0].holder, "daemon-a");
        assert!(cur_b.poll().unwrap().is_empty(), "consumed events are not re-delivered");

        // A cursor opened NOW starts at the end: no history replay.
        let mut late = b.cursor().unwrap();
        assert!(late.poll().unwrap().is_empty());
        a.announce("k4", 1, 2, None).unwrap();
        assert_eq!(late.poll().unwrap().len(), 1, "only post-open events delivered");
        let _ = std::fs::remove_dir_all(&dir);
    }

    /// The fencing pin: a stale epoch's announcement — a holder that
    /// lost the key to a reclaim, announcing after the new owner — is
    /// dropped; newer and equal epochs flow.
    #[test]
    fn stale_epoch_announcements_are_fenced() {
        let dir = tmp_dir("fence");
        let a = NotifyChannel::open(&dir, "daemon-a", 60_000).unwrap();
        let b = NotifyChannel::open(&dir, "daemon-b", 60_000).unwrap();
        let c = NotifyChannel::open(&dir, "daemon-c", 60_000).unwrap();
        let mut cur = c.cursor().unwrap();

        // b reclaimed the key (epoch 6) and landed first; a's write-back
        // under its lost epoch-5 claim would have been fenced by the
        // store — its announcement must be fenced here too.
        b.announce("k", 2, 6, None).unwrap();
        a.announce("k", 2, 5, None).unwrap();
        let events = cur.poll().unwrap();
        assert_eq!(events.len(), 1, "stale epoch dropped: {events:?}");
        assert_eq!((events[0].holder.as_str(), events[0].epoch), ("daemon-b", 6));

        // A newer reclaim's announcement still flows…
        a.announce("k", 2, 7, None).unwrap();
        assert_eq!(cur.poll().unwrap().len(), 1);
        // …and epoch-0 (unclaimed) landings are never fenced.
        a.announce("k", 2, 0, None).unwrap();
        assert_eq!(cur.poll().unwrap().len(), 1);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn compaction_resets_cursors_without_wedging() {
        let dir = tmp_dir("compact");
        let a = NotifyChannel::open(&dir, "daemon-a", 60_000).unwrap();
        let b = NotifyChannel::open(&dir, "daemon-b", 60_000).unwrap();
        let mut cur = b.cursor().unwrap();
        a.announce("k1", 0, 1, None).unwrap();
        assert_eq!(cur.poll().unwrap().len(), 1);

        // Compact: the file truncates and the generation bumps.
        assert!(a.compact().unwrap());
        a.announce("k2", 1, 1, None).unwrap();
        let events = cur.poll().unwrap();
        assert_eq!(events.len(), 1, "cursor reset to the new file: {events:?}");
        assert_eq!(events[0].key, "k2");

        // A second compaction while a foreign lease holds the channel
        // is skipped, not an error.
        let lease_path = dir.join(NOTIFY_DIR).join(format!("{NOTIFY_LEASE_NAME}.json"));
        let foreign = Lease::acquire(&lease_path, "other", 60_000, None).unwrap().unwrap();
        assert!(!a.compact().unwrap(), "foreign lease defers compaction");
        foreign.release().unwrap();
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn torn_tail_is_left_unconsumed_and_garbage_is_skipped() {
        let dir = tmp_dir("torn");
        let a = NotifyChannel::open(&dir, "daemon-a", 60_000).unwrap();
        let b = NotifyChannel::open(&dir, "daemon-b", 60_000).unwrap();
        let mut cur = b.cursor().unwrap();
        a.announce("k1", 0, 1, None).unwrap();

        let events_path = dir.join(NOTIFY_DIR).join(EVENTS_FILE);
        // Garbage whole line: skipped. Torn tail: left for the writer
        // to finish.
        let mut text = std::fs::read_to_string(&events_path).unwrap();
        text.push_str("{not json}\n");
        text.push_str(r#"{"key":"torn"#);
        std::fs::write(&events_path, &text).unwrap();

        let events = cur.poll().unwrap();
        assert_eq!(events.len(), 1);
        assert_eq!(events[0].key, "k1");
        // The writer finishes the torn line: it is delivered whole.
        let mut text = std::fs::read_to_string(&events_path).unwrap();
        text.push_str(r#"","shard":4,"holder":"daemon-a","epoch":2}"#);
        text.push('\n');
        std::fs::write(&events_path, &text).unwrap();
        let events = cur.poll().unwrap();
        assert_eq!(events.len(), 1);
        assert_eq!((events[0].key.as_str(), events[0].shard), ("torn", 4));
        let _ = std::fs::remove_dir_all(&dir);
    }
}
