//! Transport abstraction for the serving protocol: one frame format,
//! two wires.
//!
//! The daemon's protocol is line-delimited JSON; nothing about it is
//! Unix-socket-specific. This module gives `serve`/`query`/the client
//! a [`ServeAddr`] that is either a Unix path or a TCP `host:port`,
//! plus [`Listener`]/[`Stream`] wrappers so the daemon and the client
//! are written once against both. The same client bytes produce the
//! same replies on either wire (the fleet e2e pins this).
//!
//! Address syntax (CLI `--listen` / `--addr`):
//!
//! * `unix:/run/ecokernel.sock` — Unix-domain socket (also the
//!   interpretation of a bare path, for backward compatibility with
//!   `--socket`);
//! * `tcp:127.0.0.1:7461` — TCP. Binding port `0` resolves to a
//!   kernel-assigned port, reported back by [`Listener::bind`].

use anyhow::Context as _;
use std::io::{Read, Write};
use std::net::{TcpListener, TcpStream};
#[cfg(unix)]
use std::os::unix::net::{UnixListener, UnixStream};
#[cfg(unix)]
use std::path::PathBuf;

/// A parsed address list: one or more [`ServeAddr`]s from a
/// comma-separated CLI value. This is THE address parser for every
/// entry point (`serve`, `query`, `bench serve`) — `--socket` (legacy
/// alias), `--addr`, and fleet lists all funnel through it, so a
/// malformed entry produces the same error everywhere, naming the
/// offending entry and its position.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct AddrList {
    pub addrs: Vec<ServeAddr>,
}

impl AddrList {
    /// Parse a comma-separated address list. Every entry must parse as
    /// a [`ServeAddr`]; the error names the malformed entry and its
    /// 1-based position.
    pub fn parse(s: &str) -> Result<AddrList, String> {
        if s.trim().is_empty() {
            return Err("empty address list".to_string());
        }
        let mut addrs = Vec::new();
        for (i, raw) in s.split(',').enumerate() {
            let entry = raw.trim();
            if entry.is_empty() {
                return Err(format!("address list entry {} is empty in '{s}'", i + 1));
            }
            let addr = ServeAddr::parse(entry)
                .map_err(|e| format!("address list entry {} ('{entry}'): {e}", i + 1))?;
            addrs.push(addr);
        }
        Ok(AddrList { addrs })
    }

    /// The single address this list must hold (contexts like `serve`
    /// that listen on exactly one endpoint).
    pub fn single(self) -> Result<ServeAddr, String> {
        match self.addrs.len() {
            1 => Ok(self.addrs.into_iter().next().expect("len checked")),
            n => Err(format!("expected one address, got {n}")),
        }
    }

    pub fn len(&self) -> usize {
        self.addrs.len()
    }

    pub fn is_empty(&self) -> bool {
        self.addrs.is_empty()
    }

    pub fn iter(&self) -> std::slice::Iter<'_, ServeAddr> {
        self.addrs.iter()
    }
}

impl IntoIterator for AddrList {
    type Item = ServeAddr;
    type IntoIter = std::vec::IntoIter<ServeAddr>;

    fn into_iter(self) -> Self::IntoIter {
        self.addrs.into_iter()
    }
}

/// Where a serving daemon listens (or a client connects).
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum ServeAddr {
    /// Unix-domain socket path.
    #[cfg(unix)]
    Unix(PathBuf),
    /// TCP `host:port`.
    Tcp(String),
}

impl ServeAddr {
    /// Parse `unix:PATH`, `tcp:HOST:PORT`, or a bare path (treated as
    /// a Unix socket path).
    pub fn parse(s: &str) -> Result<ServeAddr, String> {
        if let Some(rest) = s.strip_prefix("tcp:") {
            if rest.is_empty() || !rest.contains(':') {
                return Err(format!("tcp address '{rest}' must be HOST:PORT"));
            }
            return Ok(ServeAddr::Tcp(rest.to_string()));
        }
        let path = s.strip_prefix("unix:").unwrap_or(s);
        if path.is_empty() {
            return Err("empty address".to_string());
        }
        #[cfg(unix)]
        {
            Ok(ServeAddr::Unix(PathBuf::from(path)))
        }
        #[cfg(not(unix))]
        {
            Err(format!("unix socket address '{path}' is unsupported on this platform"))
        }
    }
}

impl std::fmt::Display for ServeAddr {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            #[cfg(unix)]
            ServeAddr::Unix(path) => write!(f, "unix:{}", path.display()),
            ServeAddr::Tcp(hostport) => write!(f, "tcp:{hostport}"),
        }
    }
}

/// A bound listening socket on either wire.
pub enum Listener {
    #[cfg(unix)]
    Unix(UnixListener),
    Tcp(TcpListener),
}

impl Listener {
    /// Bind on `addr`. For Unix sockets a *live* daemon's socket is
    /// refused (two daemons on one endpoint would split the clients)
    /// and a stale socket file is removed; for TCP an in-use port
    /// fails naturally. Returns the listener plus the resolved address
    /// (TCP port 0 becomes the kernel-assigned port).
    pub fn bind(addr: &ServeAddr) -> anyhow::Result<(Listener, ServeAddr)> {
        match addr {
            #[cfg(unix)]
            ServeAddr::Unix(path) => {
                if path.exists() {
                    if UnixStream::connect(path).is_ok() {
                        anyhow::bail!(
                            "a daemon is already serving on {path:?} (shut it down first)"
                        );
                    }
                    std::fs::remove_file(path)
                        .with_context(|| format!("remove stale socket {path:?}"))?;
                }
                let listener =
                    UnixListener::bind(path).with_context(|| format!("bind {path:?}"))?;
                Ok((Listener::Unix(listener), addr.clone()))
            }
            ServeAddr::Tcp(hostport) => {
                let listener = TcpListener::bind(hostport.as_str())
                    .with_context(|| format!("bind tcp:{hostport}"))?;
                let local = listener.local_addr().context("resolve tcp local addr")?;
                Ok((Listener::Tcp(listener), ServeAddr::Tcp(local.to_string())))
            }
        }
    }

    /// Accept one connection (blocking, unless the listener was put in
    /// nonblocking mode — then `WouldBlock` means "no one waiting").
    pub fn accept(&self) -> std::io::Result<Stream> {
        match self {
            #[cfg(unix)]
            Listener::Unix(listener) => listener.accept().map(|(s, _)| Stream::Unix(s)),
            Listener::Tcp(listener) => listener.accept().map(|(s, _)| {
                let _ = s.set_nodelay(true); // one frame per write: don't batch
                Stream::Tcp(s)
            }),
        }
    }

    /// Switch blocking mode (the evented accept loop polls instead of
    /// parking in `accept`).
    pub fn set_nonblocking(&self, nonblocking: bool) -> std::io::Result<()> {
        match self {
            #[cfg(unix)]
            Listener::Unix(listener) => listener.set_nonblocking(nonblocking),
            Listener::Tcp(listener) => listener.set_nonblocking(nonblocking),
        }
    }

    /// The raw fd, for registering with `poll(2)`.
    #[cfg(unix)]
    pub fn as_raw_fd(&self) -> std::os::unix::io::RawFd {
        use std::os::unix::io::AsRawFd as _;
        match self {
            Listener::Unix(listener) => listener.as_raw_fd(),
            Listener::Tcp(listener) => listener.as_raw_fd(),
        }
    }
}

/// One connection on either wire.
pub enum Stream {
    #[cfg(unix)]
    Unix(UnixStream),
    Tcp(TcpStream),
}

impl Stream {
    /// Connect to a daemon at `addr`.
    pub fn connect(addr: &ServeAddr) -> anyhow::Result<Stream> {
        match addr {
            #[cfg(unix)]
            ServeAddr::Unix(path) => UnixStream::connect(path)
                .map(Stream::Unix)
                .with_context(|| format!("connect to daemon at unix:{}", path.display())),
            ServeAddr::Tcp(hostport) => TcpStream::connect(hostport.as_str())
                .map(|s| {
                    let _ = s.set_nodelay(true);
                    Stream::Tcp(s)
                })
                .with_context(|| format!("connect to daemon at tcp:{hostport}")),
        }
    }

    /// Clone the handle (separate read/write halves of one connection).
    pub fn try_clone(&self) -> std::io::Result<Stream> {
        match self {
            #[cfg(unix)]
            Stream::Unix(s) => s.try_clone().map(Stream::Unix),
            Stream::Tcp(s) => s.try_clone().map(Stream::Tcp),
        }
    }

    /// Switch blocking mode (reactor connections read/write
    /// nonblocking; `WouldBlock` re-arms the poll interest).
    pub fn set_nonblocking(&self, nonblocking: bool) -> std::io::Result<()> {
        match self {
            #[cfg(unix)]
            Stream::Unix(s) => s.set_nonblocking(nonblocking),
            Stream::Tcp(s) => s.set_nonblocking(nonblocking),
        }
    }

    /// The raw fd, for registering with `poll(2)`.
    #[cfg(unix)]
    pub fn as_raw_fd(&self) -> std::os::unix::io::RawFd {
        use std::os::unix::io::AsRawFd as _;
        match self {
            Stream::Unix(s) => s.as_raw_fd(),
            Stream::Tcp(s) => s.as_raw_fd(),
        }
    }
}

impl Read for Stream {
    fn read(&mut self, buf: &mut [u8]) -> std::io::Result<usize> {
        match self {
            #[cfg(unix)]
            Stream::Unix(s) => s.read(buf),
            Stream::Tcp(s) => s.read(buf),
        }
    }
}

impl Write for Stream {
    fn write(&mut self, buf: &[u8]) -> std::io::Result<usize> {
        match self {
            #[cfg(unix)]
            Stream::Unix(s) => s.write(buf),
            Stream::Tcp(s) => s.write(buf),
        }
    }

    fn flush(&mut self) -> std::io::Result<()> {
        match self {
            #[cfg(unix)]
            Stream::Unix(s) => s.flush(),
            Stream::Tcp(s) => s.flush(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_tcp_addresses() {
        assert_eq!(
            ServeAddr::parse("tcp:127.0.0.1:7461"),
            Ok(ServeAddr::Tcp("127.0.0.1:7461".to_string()))
        );
        assert!(ServeAddr::parse("tcp:").is_err());
        assert!(ServeAddr::parse("tcp:no-port").is_err());
        assert_eq!(ServeAddr::parse("tcp:[::1]:7461").unwrap().to_string(), "tcp:[::1]:7461");
    }

    #[cfg(unix)]
    #[test]
    fn parse_unix_addresses_including_bare_paths() {
        assert_eq!(
            ServeAddr::parse("unix:/run/eco.sock"),
            Ok(ServeAddr::Unix(PathBuf::from("/run/eco.sock")))
        );
        // Backward compatibility: a bare path is a Unix socket.
        assert_eq!(
            ServeAddr::parse("/tmp/eco.sock"),
            Ok(ServeAddr::Unix(PathBuf::from("/tmp/eco.sock")))
        );
        assert!(ServeAddr::parse("").is_err());
        assert_eq!(ServeAddr::parse("unix:/a/b").unwrap().to_string(), "unix:/a/b");
    }

    #[test]
    fn addr_list_parses_commas_and_names_the_bad_entry() {
        let list = AddrList::parse("tcp:127.0.0.1:7461, tcp:127.0.0.1:7462").unwrap();
        assert_eq!(list.len(), 2);
        assert_eq!(list.addrs[1], ServeAddr::Tcp("127.0.0.1:7462".to_string()));
        // The error names the malformed entry and its position.
        let err = AddrList::parse("tcp:127.0.0.1:7461,tcp:no-port").unwrap_err();
        assert!(err.contains("entry 2"), "{err}");
        assert!(err.contains("tcp:no-port") || err.contains("no-port"), "{err}");
        let err = AddrList::parse("tcp:127.0.0.1:7461,,tcp:127.0.0.1:7462").unwrap_err();
        assert!(err.contains("entry 2"), "{err}");
        assert!(AddrList::parse("").is_err());
        assert!(AddrList::parse("  ").is_err());
    }

    #[test]
    fn addr_list_single_rejects_fleets() {
        let one = AddrList::parse("tcp:127.0.0.1:7461").unwrap();
        assert_eq!(one.single().unwrap(), ServeAddr::Tcp("127.0.0.1:7461".to_string()));
        let two = AddrList::parse("tcp:127.0.0.1:1,tcp:127.0.0.1:2").unwrap();
        assert!(two.single().is_err());
    }

    #[test]
    fn tcp_roundtrip_one_line() {
        use std::io::{BufRead as _, BufReader, Write as _};
        let (listener, addr) =
            Listener::bind(&ServeAddr::Tcp("127.0.0.1:0".to_string())).unwrap();
        match &addr {
            ServeAddr::Tcp(hp) => assert!(!hp.ends_with(":0"), "port 0 resolved: {hp}"),
            #[cfg(unix)]
            other => panic!("{other}"),
        }
        let server = std::thread::spawn(move || {
            let stream = listener.accept().unwrap();
            let mut reader = BufReader::new(stream.try_clone().unwrap());
            let mut line = String::new();
            reader.read_line(&mut line).unwrap();
            let mut out = stream;
            write!(out, "echo:{line}").unwrap();
            out.flush().unwrap();
        });
        let mut client = Stream::connect(&addr).unwrap();
        writeln!(client, "hello").unwrap();
        client.flush().unwrap();
        let mut reader = BufReader::new(client.try_clone().unwrap());
        let mut reply = String::new();
        reader.read_line(&mut reply).unwrap();
        assert_eq!(reply, "echo:hello\n");
        server.join().unwrap();
    }
}
