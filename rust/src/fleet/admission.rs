//! Admission control for the background-search queue: when it
//! saturates, shed cold keys and keep hot ones.
//!
//! The PR-2 daemon load-shed with a FIFO `try_submit`: whoever missed
//! while the queue was full was dropped, regardless of how hot their
//! key was. Under zipf traffic that is exactly backwards — the dropped
//! key is as likely to be the head of the distribution as its tail.
//! This module replaces it with:
//!
//! * [`HeatSketch`] — a decayed per-key request-rate estimate on a
//!   request-count clock (every request is one tick; a key's heat
//!   halves every `half_life` requests). Deterministic, O(1) per
//!   touch, bounded memory (coldest half pruned past `cap` keys).
//! * [`Backlog`] — a small buffer in front of the worker queue. A miss
//!   that cannot be submitted is backlogged; when the backlog is full,
//!   the **coldest** key (new arrival included) is shed. Finished
//!   searches pump the **hottest** backlogged key into the freed queue
//!   slot.
//!
//! The effect: a saturated daemon spends its search budget on the keys
//! the traffic actually repeats, and the shed ratio concentrates on
//! one-off cold keys (see `examples/fleet_replay.rs`).

use std::collections::HashMap;

/// Number of buckets in the heat histogram (powers of two from 0.5).
pub const HEAT_BUCKETS: usize = 8;

/// Decayed per-key request-rate sketch on a request-count clock.
#[derive(Debug)]
pub struct HeatSketch {
    half_life: f64,
    cap: usize,
    t: u64,
    /// key -> (heat at `last`, last tick touched).
    heat: HashMap<String, (f64, u64)>,
}

impl HeatSketch {
    /// `half_life`: requests after which an untouched key's heat
    /// halves. `cap`: max tracked keys — outgrowing it prunes down to
    /// the hottest `cap / 2` keys (never the key being credited).
    pub fn new(half_life: f64, cap: usize) -> HeatSketch {
        HeatSketch { half_life: half_life.max(1.0), cap: cap.max(2), t: 0, heat: HashMap::new() }
    }

    fn decayed(&self, rate: f64, last: u64, now: u64) -> f64 {
        if now <= last {
            return rate;
        }
        rate * 0.5_f64.powf((now - last) as f64 / self.half_life)
    }

    /// Advance the clock one request and credit `key`. Returns the
    /// key's updated heat.
    pub fn touch(&mut self, key: &str) -> f64 {
        self.t += 1;
        let (now, half_life) = (self.t, self.half_life);
        let entry = self.heat.entry(key.to_string()).or_insert((0.0, now));
        let decayed = if now > entry.1 {
            entry.0 * 0.5_f64.powf((now - entry.1) as f64 / half_life)
        } else {
            entry.0
        };
        *entry = (decayed + 1.0, now);
        let updated = entry.0;
        if self.heat.len() > self.cap {
            self.prune(key);
        }
        updated
    }

    /// Current heat of a key (0.0 = never seen / fully decayed away).
    pub fn heat(&self, key: &str) -> f64 {
        self.heat.get(key).map(|(rate, last)| self.decayed(*rate, *last, self.t)).unwrap_or(0.0)
    }

    pub fn len(&self) -> usize {
        self.heat.len()
    }

    pub fn is_empty(&self) -> bool {
        self.heat.is_empty()
    }

    /// The `n` hottest keys with their current heat, hottest first
    /// (ties break toward the smaller key, matching
    /// [`Backlog::pop_hottest`]). Cold path — clones and sorts; the
    /// drift watchdog calls it once per interval, never per request.
    pub fn hottest(&self, n: usize) -> Vec<(String, f64)> {
        let mut all: Vec<(String, f64)> = self
            .heat
            .iter()
            .map(|(k, (rate, last))| (k.clone(), self.decayed(*rate, *last, self.t)))
            .collect();
        all.sort_by(|a, b| {
            b.1.partial_cmp(&a.1)
                .unwrap_or(std::cmp::Ordering::Equal)
                .then_with(|| a.0.cmp(&b.0))
        });
        all.truncate(n);
        all
    }

    /// Histogram of current key heats in log2 buckets:
    /// `[0,0.5) [0.5,1) [1,2) [2,4) [4,8) [8,16) [16,32) [32,∞)`.
    pub fn histogram(&self) -> [usize; HEAT_BUCKETS] {
        let mut out = [0usize; HEAT_BUCKETS];
        for (rate, last) in self.heat.values() {
            let h = self.decayed(*rate, *last, self.t);
            let bucket = if h < 0.5 {
                0
            } else {
                // 0.5 -> 1, 1 -> 2, 2 -> 3, ... capped at the top.
                ((h / 0.5).log2().floor() as usize + 1).min(HEAT_BUCKETS - 1)
            };
            out[bucket] += 1;
        }
        out
    }

    /// Prune down to the hottest `cap / 2` keys when the sketch
    /// outgrows its cap. `protect` — the key that was just credited —
    /// always survives: under heavy cold-key churn a fresh touch (heat
    /// 1.0) can rank below the incumbents, and a sketch that evicts the
    /// key it is crediting would never learn a new key's heat at all.
    fn prune(&mut self, protect: &str) {
        let mut all: Vec<(String, f64)> = self
            .heat
            .iter()
            .map(|(k, (rate, last))| (k.clone(), self.decayed(*rate, *last, self.t)))
            .collect();
        all.sort_by(|a, b| {
            b.1.partial_cmp(&a.1)
                .unwrap_or(std::cmp::Ordering::Equal)
                .then_with(|| a.0.cmp(&b.0))
        });
        all.truncate(self.cap / 2);
        let mut keep: std::collections::HashSet<String> =
            all.into_iter().map(|(k, _)| k).collect();
        keep.insert(protect.to_string());
        self.heat.retain(|k, _| keep.contains(k));
    }
}

/// What [`Backlog::offer`] decided.
pub enum Offer<T> {
    /// The key took a backlog slot.
    Queued,
    /// The key took a slot by displacing a colder backlogged key,
    /// which the caller must shed.
    Displaced { key: String, item: T },
    /// The key is colder than everything backlogged: shed it.
    Rejected { key: String, item: T },
}

/// Bounded heat-ordered buffer in front of the worker queue.
#[derive(Debug)]
pub struct Backlog<T> {
    cap: usize,
    entries: Vec<(String, T)>,
}

impl<T> Backlog<T> {
    pub fn new(cap: usize) -> Backlog<T> {
        Backlog { cap: cap.max(1), entries: Vec::new() }
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// Offer a key a backlog slot; when full, the coldest key loses
    /// (ties break deterministically toward keeping the incumbent).
    pub fn offer(&mut self, key: String, item: T, heat: &HeatSketch) -> Offer<T> {
        if self.entries.len() < self.cap {
            self.entries.push((key, item));
            return Offer::Queued;
        }
        let coldest = match self.index_of_coldest(heat) {
            Some(i) => i,
            None => return Offer::Rejected { key, item },
        };
        if heat.heat(&key) > heat.heat(&self.entries[coldest].0) {
            let (old_key, old_item) = self.entries.swap_remove(coldest);
            self.entries.push((key, item));
            Offer::Displaced { key: old_key, item: old_item }
        } else {
            Offer::Rejected { key, item }
        }
    }

    /// Remove and return the hottest backlogged key (deterministic
    /// tie-break on the key string).
    pub fn pop_hottest(&mut self, heat: &HeatSketch) -> Option<(String, T)> {
        let mut best: Option<usize> = None;
        for (i, (key, _)) in self.entries.iter().enumerate() {
            let better = match best {
                None => true,
                Some(b) => {
                    let (hb, hi) = (heat.heat(&self.entries[b].0), heat.heat(key));
                    hi > hb || (hi == hb && *key < self.entries[b].0)
                }
            };
            if better {
                best = Some(i);
            }
        }
        best.map(|i| self.entries.swap_remove(i))
    }

    /// Put back an entry that could not be submitted after all. The
    /// backlog stays bounded: the queue may have refilled between the
    /// pop and this restore, so the entry competes by heat exactly like
    /// a fresh offer — when the backlog is full again, the coldest of
    /// (backlog ∪ restored) is shed and returned for the caller to
    /// release (claim + pending bookkeeping + `job_shed` event).
    pub fn restore(&mut self, key: String, item: T, heat: &HeatSketch) -> Offer<T> {
        self.offer(key, item, heat)
    }

    /// Take every entry (shutdown: release their fleet claims).
    pub fn drain(&mut self) -> Vec<(String, T)> {
        std::mem::take(&mut self.entries)
    }

    fn index_of_coldest(&self, heat: &HeatSketch) -> Option<usize> {
        let mut worst: Option<usize> = None;
        for (i, (key, _)) in self.entries.iter().enumerate() {
            let colder = match worst {
                None => true,
                Some(w) => {
                    let (hw, hi) = (heat.heat(&self.entries[w].0), heat.heat(key));
                    hi < hw || (hi == hw && *key > self.entries[w].0)
                }
            };
            if colder {
                worst = Some(i);
            }
        }
        worst
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn heat_accumulates_and_decays() {
        let mut sketch = HeatSketch::new(4.0, 1024);
        for _ in 0..3 {
            sketch.touch("hot");
        }
        let hot = sketch.heat("hot");
        assert!(hot > 2.0, "three rapid touches stack: {hot}");
        // Eight quiet ticks = two half-lives: heat falls ~4x.
        for _ in 0..8 {
            sketch.touch("other");
        }
        let cooled = sketch.heat("hot");
        assert!(cooled < hot / 3.0, "{cooled} vs {hot}");
        assert!(sketch.heat("never") == 0.0);
    }

    #[test]
    fn hotter_key_wins_regardless_of_arrival_order() {
        let mut sketch = HeatSketch::new(64.0, 1024);
        sketch.touch("cold");
        for _ in 0..5 {
            sketch.touch("hot");
        }
        assert!(sketch.heat("hot") > sketch.heat("cold"));

        let mut backlog: Backlog<u32> = Backlog::new(1);
        assert!(matches!(backlog.offer("cold".into(), 1, &sketch), Offer::Queued));
        // A hotter arrival displaces the cold incumbent...
        match backlog.offer("hot".into(), 2, &sketch) {
            Offer::Displaced { key, item } => {
                assert_eq!(key, "cold");
                assert_eq!(item, 1);
            }
            _ => panic!("hot key must displace the cold one"),
        }
        // ...and a colder arrival is rejected outright.
        match backlog.offer("cold".into(), 3, &sketch) {
            Offer::Rejected { key, item } => {
                assert_eq!(key, "cold");
                assert_eq!(item, 3);
            }
            _ => panic!("cold key must be shed"),
        }
        assert_eq!(backlog.len(), 1);
        let (key, item) = backlog.pop_hottest(&sketch).unwrap();
        assert_eq!((key.as_str(), item), ("hot", 2));
        assert!(backlog.pop_hottest(&sketch).is_none());
    }

    #[test]
    fn pop_hottest_orders_by_heat_then_key() {
        let mut sketch = HeatSketch::new(1e6, 1024); // effectively no decay
        sketch.touch("b");
        sketch.touch("a");
        for _ in 0..3 {
            sketch.touch("c");
        }
        let mut backlog: Backlog<()> = Backlog::new(8);
        for key in ["a", "b", "c"] {
            assert!(matches!(backlog.offer(key.into(), (), &sketch), Offer::Queued));
        }
        let order: Vec<String> = std::iter::from_fn(|| backlog.pop_hottest(&sketch))
            .map(|(key, _)| key)
            .collect();
        assert_eq!(order, ["c", "a", "b"], "hottest first, then lexicographic");
    }

    #[test]
    fn touch_never_prunes_the_key_being_credited() {
        // Four entrenched hot keys, cap 4: a fresh key's own touch
        // overflows the sketch, and its heat (1.0) ranks below every
        // incumbent — it must survive the prune it triggered anyway.
        let mut sketch = HeatSketch::new(1e6, 4);
        for key in ["h1", "h2", "h3", "h4"] {
            for _ in 0..10 {
                sketch.touch(key);
            }
        }
        let fresh = sketch.touch("fresh");
        assert!((fresh - 1.0).abs() < 1e-9, "first touch credits 1.0: {fresh}");
        assert!(sketch.heat("fresh") > 0.0, "just-credited key survives its own prune");
        assert!(sketch.len() <= 4 / 2 + 1, "pruned to the hottest half + the credited key");
    }

    #[test]
    fn restore_keeps_the_backlog_bounded_and_sheds_the_coldest() {
        let mut sketch = HeatSketch::new(1e6, 1024);
        for _ in 0..5 {
            sketch.touch("hot");
        }
        sketch.touch("cold");

        // A cold restore against a refilled backlog is shed, not
        // stacked past the cap.
        let mut backlog: Backlog<u32> = Backlog::new(1);
        assert!(matches!(backlog.offer("hot".into(), 1, &sketch), Offer::Queued));
        match backlog.restore("cold".into(), 2, &sketch) {
            Offer::Rejected { key, item } => assert_eq!((key.as_str(), item), ("cold", 2)),
            _ => panic!("cold restore into a full backlog must be shed"),
        }
        assert_eq!(backlog.len(), 1, "restore never grows the backlog past cap");

        // A hot restore displaces a colder incumbent instead.
        let mut backlog: Backlog<u32> = Backlog::new(1);
        assert!(matches!(backlog.offer("cold".into(), 3, &sketch), Offer::Queued));
        match backlog.restore("hot".into(), 4, &sketch) {
            Offer::Displaced { key, item } => assert_eq!((key.as_str(), item), ("cold", 3)),
            _ => panic!("hot restore must displace the cold incumbent"),
        }
        assert_eq!(backlog.len(), 1);
        // An under-cap restore simply queues.
        let mut backlog: Backlog<u32> = Backlog::new(2);
        assert!(matches!(backlog.restore("hot".into(), 5, &sketch), Offer::Queued));
    }

    #[test]
    fn hottest_ranks_by_heat_then_key() {
        let mut sketch = HeatSketch::new(1e6, 1024);
        sketch.touch("b");
        sketch.touch("a");
        for _ in 0..3 {
            sketch.touch("c");
        }
        let top: Vec<String> = sketch.hottest(2).into_iter().map(|(k, _)| k).collect();
        assert_eq!(top, ["c", "a"], "hottest first, lexicographic tie-break");
        assert_eq!(sketch.hottest(10).len(), 3, "n past the population returns everything");
        assert!(sketch.hottest(0).is_empty());
    }

    #[test]
    fn sketch_memory_stays_bounded() {
        let mut sketch = HeatSketch::new(128.0, 64);
        for i in 0..1000 {
            sketch.touch(&format!("key{i}"));
        }
        assert!(sketch.len() <= 64, "pruned to cap: {}", sketch.len());
        // Recent keys (the hottest under decay) survive the prune.
        assert!(sketch.heat("key999") > 0.0);
    }

    #[test]
    fn histogram_buckets_by_heat() {
        let mut sketch = HeatSketch::new(1e6, 1024);
        sketch.touch("one"); // heat ~1 -> bucket [1,2)
        for _ in 0..40 {
            sketch.touch("forty"); // heat ~40 -> top bucket
        }
        let hist = sketch.histogram();
        assert_eq!(hist.iter().sum::<usize>(), 2);
        assert_eq!(hist[2], 1, "heat ~1 lands in [1,2): {hist:?}");
        assert_eq!(hist[HEAT_BUCKETS - 1], 1, "heat ~40 lands in the top bucket: {hist:?}");
    }
}
