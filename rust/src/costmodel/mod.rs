//! The energy cost model (§5): a GBDT over high-level kernel features
//! predicting a *normalized energy score*, trained online during the
//! search with the Eq. 1 weighted loss, plus the SNR-based prediction
//! error used by the dynamic-k controller (§6).

pub mod dataset;
pub mod gbdt;
pub mod loss;

pub use dataset::{Dataset, Sample};
pub use gbdt::{BoostParams, Gbdt};
pub use loss::{eq1_weight, Loss, PaperWeightedSquaredError, SquaredError};

use crate::config::CostModelConfig;
use crate::features::FeatureVector;
use crate::util::stats;
use crate::util::{Json, Rng};
use gbdt::{Node, Tree};

/// Version of the serialized cost-model snapshot. Versioned separately
/// from the tuning-record schema: a record whose snapshot version is
/// unknown still loads — it just loads without a model.
pub const MODEL_SNAPSHOT_VERSION: u64 = 1;

/// A serializable view of a fitted energy cost model: the GBDT trees
/// plus the feature meta and energy scale needed to predict with them.
/// Persisted inside [`crate::store::TuningRecord`] so a warm-started
/// search can install the neighbor's trees instead of paying the first
/// fit (ROADMAP "Cost-model persistence").
#[derive(Debug, Clone, PartialEq)]
pub struct CostModelSnapshot {
    /// Feature-vector width the trees were trained on; a snapshot from
    /// a build with a different feature map is rejected at install.
    pub n_features: usize,
    /// Energy scale (J) mapping normalized scores back to joules.
    pub scale_j: f64,
    pub base_score: f64,
    pub learning_rate: f64,
    pub trees: Vec<Tree>,
}

impl CostModelSnapshot {
    /// Compact JSON: each tree is an array of nodes, a leaf is `[w]`,
    /// a split is `[feature, threshold, bin_threshold, left, right]`.
    pub fn to_json(&self) -> Json {
        let trees = self.trees.iter().map(|t| {
            Json::arr(t.nodes.iter().map(|n| match n {
                Node::Leaf { weight } => Json::arr([Json::num(*weight)]),
                Node::Split { feature, threshold, bin_threshold, left, right } => Json::arr([
                    Json::num(*feature as f64),
                    Json::num(*threshold),
                    Json::num(*bin_threshold as f64),
                    Json::num(*left as f64),
                    Json::num(*right as f64),
                ]),
            }))
        });
        Json::obj(vec![
            ("model_v", Json::num(MODEL_SNAPSHOT_VERSION as f64)),
            ("n_features", Json::num(self.n_features as f64)),
            ("scale_j", Json::num(self.scale_j)),
            ("base_score", Json::num(self.base_score)),
            ("learning_rate", Json::num(self.learning_rate)),
            ("trees", Json::arr(trees)),
        ])
    }

    pub fn from_json(v: &Json) -> Result<CostModelSnapshot, String> {
        let version = v
            .get("model_v")
            .and_then(|x| x.as_f64())
            .ok_or("snapshot missing 'model_v'")? as u64;
        if version != MODEL_SNAPSHOT_VERSION {
            return Err(format!(
                "unsupported cost-model snapshot version {version} \
                 (this build reads v{MODEL_SNAPSHOT_VERSION})"
            ));
        }
        let num = |key: &str| -> Result<f64, String> {
            v.get(key).and_then(|x| x.as_f64()).ok_or_else(|| format!("snapshot missing '{key}'"))
        };
        let n_features = num("n_features")? as usize;
        let mut trees = Vec::new();
        for tv in v.get("trees").and_then(|t| t.as_arr()).ok_or("snapshot missing 'trees'")? {
            let mut nodes = Vec::new();
            for nv in tv.as_arr().ok_or("snapshot tree is not an array")? {
                let parts: Vec<f64> = nv
                    .as_arr()
                    .ok_or("snapshot node is not an array")?
                    .iter()
                    .map(|x| x.as_f64().ok_or("snapshot node holds a non-number"))
                    .collect::<Result<_, _>>()?;
                nodes.push(match parts.as_slice() {
                    [weight] => Node::Leaf { weight: *weight },
                    [feature, threshold, bin_threshold, left, right] => Node::Split {
                        feature: *feature as usize,
                        threshold: *threshold,
                        bin_threshold: *bin_threshold as u16,
                        left: *left as usize,
                        right: *right as usize,
                    },
                    other => return Err(format!("snapshot node of arity {}", other.len())),
                });
            }
            // A corrupt snapshot must fail parse, not panic (or loop) a
            // background worker at predict time: every split must
            // reference a known feature and link strictly forward (the
            // grower appends children after their parent, so valid
            // trees always satisfy this — and it rules out cycles).
            if nodes.is_empty() {
                return Err("snapshot tree has no nodes".into());
            }
            for (i, node) in nodes.iter().enumerate() {
                if let Node::Split { feature, left, right, .. } = node {
                    let legal = *feature < n_features
                        && *left > i
                        && *right > i
                        && *left < nodes.len()
                        && *right < nodes.len();
                    if !legal {
                        return Err(format!(
                            "snapshot tree node {i} has out-of-bounds feature or non-forward child links"
                        ));
                    }
                }
            }
            trees.push(Tree { nodes });
        }
        Ok(CostModelSnapshot {
            n_features,
            scale_j: num("scale_j")?,
            base_score: num("base_score")?,
            learning_rate: num("learning_rate")?,
            trees,
        })
    }
}

/// The online energy cost model: dataset + fitted GBDT + bookkeeping.
pub struct EnergyCostModel {
    cfg: CostModelConfig,
    data: Dataset,
    model: Option<Gbdt>,
    /// Scale used at last fit (min measured energy, J).
    scale_j: f64,
    /// Number of `fit` calls so far.
    pub n_fits: usize,
}

impl EnergyCostModel {
    pub fn new(cfg: CostModelConfig) -> EnergyCostModel {
        let data = Dataset::new(cfg.max_train_samples);
        EnergyCostModel { cfg, data, model: None, scale_j: 1.0, n_fits: 0 }
    }

    /// True once the model has been trained at least once.
    pub fn is_trained(&self) -> bool {
        self.model.is_some()
    }

    /// Snapshot the fitted ensemble for persistence, or `None` when the
    /// model has never been fit.
    pub fn snapshot(&self) -> Option<CostModelSnapshot> {
        self.model.as_ref().map(|m| CostModelSnapshot {
            n_features: crate::features::FEATURE_DIM,
            scale_j: self.scale_j,
            base_score: m.base_score,
            learning_rate: m.learning_rate,
            trees: m.trees.clone(),
        })
    }

    /// Install a persisted ensemble, replacing any fitted model. The
    /// dataset is untouched: banked samples stay available for the next
    /// refit. Rejects snapshots trained on a different feature map.
    pub fn install(&mut self, snap: &CostModelSnapshot) -> Result<(), String> {
        if snap.n_features != crate::features::FEATURE_DIM {
            return Err(format!(
                "snapshot has {} features, this build extracts {}",
                snap.n_features,
                crate::features::FEATURE_DIM
            ));
        }
        self.model =
            Some(Gbdt::from_parts(snap.base_score, snap.learning_rate, snap.trees.clone()));
        self.scale_j = snap.scale_j;
        Ok(())
    }

    pub fn n_samples(&self) -> usize {
        self.data.len()
    }

    /// Add measured samples WITHOUT refitting.
    pub fn add_samples(&mut self, samples: &[(FeatureVector, f64)]) {
        for (fv, e) in samples {
            self.data.push(fv, *e);
        }
    }

    /// Rescale every stored training target by `factor` (no refit —
    /// call [`Self::fit`] or [`Self::update`] afterwards). Warm-start
    /// calibration uses this to pin transferred samples to the target
    /// workload's measured energy scale.
    pub fn scale_energies(&mut self, factor: f64) {
        self.data.scale_energies(factor);
    }

    /// `ModelUpdate` of Algorithm 1: add fresh measurements and refit on
    /// the full (windowed) dataset.
    pub fn update(&mut self, samples: &[(FeatureVector, f64)], rng: &mut Rng) {
        self.add_samples(samples);
        self.fit(rng);
    }

    /// Refit the GBDT on the current dataset.
    pub fn fit(&mut self, rng: &mut Rng) {
        if self.data.is_empty() {
            return;
        }
        let (x, y, w) = self.data.training_arrays(self.cfg.weighted_loss);
        self.scale_j = self.data.energy_scale();
        let params = BoostParams {
            n_trees: self.cfg.n_trees,
            learning_rate: self.cfg.learning_rate,
            max_depth: self.cfg.max_depth,
            lambda: self.cfg.lambda,
            min_child_weight: self.cfg.min_child_weight,
            n_bins: self.cfg.n_bins,
            colsample: self.cfg.colsample,
        };
        let loss: &dyn Loss =
            if self.cfg.weighted_loss { &PaperWeightedSquaredError } else { &SquaredError };
        self.model = Some(Gbdt::fit(&x, &y, &w, loss, &params, rng));
        self.n_fits += 1;
    }

    /// Predicted normalized energy score (unitless, ~1.0 = best seen).
    pub fn predict_score(&self, fv: &FeatureVector) -> f64 {
        match &self.model {
            Some(m) => m.predict(fv.as_slice()),
            None => 1.0,
        }
    }

    /// Predicted energy in joules (score × scale).
    pub fn predict_energy_j(&self, fv: &FeatureVector) -> f64 {
        self.predict_score(fv) * self.scale_j
    }

    /// Batch prediction of energies (J). Avoids per-row copies — this
    /// is the search's per-round `EnergyModelEvaAndPick` hot path.
    pub fn predict_energy_batch(&self, fvs: &[FeatureVector]) -> Vec<f64> {
        match &self.model {
            Some(m) => crate::util::parallel::par_map(fvs, |f| {
                m.predict(f.as_slice()) * self.scale_j
            }),
            None => vec![self.scale_j; fvs.len()],
        }
    }

    /// Algorithm 1's `SNR(EnergyPredicted, EnergyMeasured)` in dB —
    /// higher means the model explains the measured variation better.
    pub fn snr_error_db(predicted_j: &[f64], measured_j: &[f64]) -> f64 {
        stats::snr_db(predicted_j, measured_j)
    }

    /// [`Self::predict_energy_j`] with a static-analysis prior
    /// (DSO-style static+dynamic fusion, ISSUE 9): a trained model
    /// predicts as usual; a model with **zero samples** returns the
    /// caller's closed-form static estimate instead of the flat
    /// `scale_j` guess, so ranking is informative before the first
    /// measurement lands.
    pub fn predict_energy_with_prior(&self, fv: &FeatureVector, prior_j: f64) -> f64 {
        if self.is_trained() {
            self.predict_energy_j(fv)
        } else {
            prior_j
        }
    }

    /// Batch form of [`Self::predict_energy_with_prior`]: `priors` is
    /// index-aligned with `fvs` (typically
    /// [`crate::analysis::static_energy_priors`]).
    pub fn predict_energy_batch_with_prior(
        &self,
        fvs: &[FeatureVector],
        priors: &[f64],
    ) -> Vec<f64> {
        debug_assert_eq!(fvs.len(), priors.len());
        if self.is_trained() {
            self.predict_energy_batch(fvs)
        } else {
            priors.to_vec()
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::GpuArch;
    use crate::features::featurize;
    use crate::schedule::{space::ScheduleSpace, Candidate};
    use crate::sim;
    use crate::workload::suites;

    /// Train on simulator ground truth and check ranking quality — the
    /// in-miniature version of the paper's Fig. 4 experiment.
    #[test]
    fn learns_to_rank_energy_on_mm() {
        let spec = GpuArch::A100.spec();
        let space = ScheduleSpace::new(suites::MM1, &spec);
        let mut rng = Rng::seed_from_u64(33);

        let train: Vec<_> = space.sample_n(&mut rng, 400);
        let test: Vec<_> = space.sample_n(&mut rng, 100);

        let mut model = EnergyCostModel::new(Default::default());
        let samples: Vec<(crate::features::FeatureVector, f64)> = train
            .iter()
            .map(|s| {
                let c = Candidate::new(suites::MM1, *s);
                let ev = sim::evaluate_candidate(&c, &spec);
                (featurize(&c, &spec), ev.energy_j)
            })
            .collect();
        model.update(&samples, &mut rng);
        assert!(model.is_trained());

        let mut pred = Vec::new();
        let mut truth = Vec::new();
        for s in &test {
            let c = Candidate::new(suites::MM1, *s);
            pred.push(model.predict_energy_j(&featurize(&c, &spec)));
            truth.push(sim::evaluate_candidate(&c, &spec).energy_j);
        }
        let rho = stats::spearman(&pred, &truth);
        assert!(rho > 0.8, "holdout rank correlation {rho}");
    }

    #[test]
    fn untrained_model_predicts_constant() {
        let spec = GpuArch::A100.spec();
        let space = ScheduleSpace::new(suites::MM1, &spec);
        let model = EnergyCostModel::new(Default::default());
        let c = Candidate::new(suites::MM1, space.fallback());
        assert_eq!(model.predict_score(&featurize(&c, &spec)), 1.0);
    }

    #[test]
    fn prior_fallback_only_applies_until_first_fit() {
        let spec = GpuArch::A100.spec();
        let space = ScheduleSpace::new(suites::MM1, &spec);
        let mut rng = Rng::seed_from_u64(51);
        let scheds = space.sample_n(&mut rng, 12);
        let cands: Vec<Candidate> =
            scheds.iter().map(|s| Candidate::new(suites::MM1, *s)).collect();
        let fvs: Vec<crate::features::FeatureVector> =
            cands.iter().map(|c| featurize(c, &spec)).collect();
        let priors = crate::analysis::static_energy_priors(&suites::MM1, &scheds, &spec);

        // Zero samples: the batch IS the static prior, not flat scale_j.
        let mut model = EnergyCostModel::new(Default::default());
        assert_eq!(model.predict_energy_batch_with_prior(&fvs, &priors), priors);
        assert_eq!(model.predict_energy_with_prior(&fvs[0], priors[0]), priors[0]);

        // Trained: the prior is ignored, predictions match the GBDT.
        let samples: Vec<(crate::features::FeatureVector, f64)> = cands
            .iter()
            .map(|c| (featurize(c, &spec), sim::evaluate_candidate(c, &spec).energy_j))
            .collect();
        model.update(&samples, &mut rng);
        assert_eq!(
            model.predict_energy_batch_with_prior(&fvs, &priors),
            model.predict_energy_batch(&fvs)
        );
        assert_eq!(
            model.predict_energy_with_prior(&fvs[0], priors[0]),
            model.predict_energy_j(&fvs[0])
        );
    }

    #[test]
    fn snr_metric_behaves() {
        let measured = vec![1.0, 2.0, 3.0, 4.0];
        let close: Vec<f64> = measured.iter().map(|x| x * 1.01).collect();
        let far: Vec<f64> = measured.iter().map(|x| x * 2.0).collect();
        assert!(
            EnergyCostModel::snr_error_db(&close, &measured)
                > EnergyCostModel::snr_error_db(&far, &measured)
        );
    }

    #[test]
    fn snapshot_roundtrips_and_predicts_identically() {
        let spec = GpuArch::A100.spec();
        let space = ScheduleSpace::new(suites::MM1, &spec);
        let mut rng = Rng::seed_from_u64(44);
        let mut model = EnergyCostModel::new(Default::default());
        let samples: Vec<(crate::features::FeatureVector, f64)> = space
            .sample_n(&mut rng, 60)
            .into_iter()
            .map(|s| {
                let c = Candidate::new(suites::MM1, s);
                (featurize(&c, &spec), sim::evaluate_candidate(&c, &spec).energy_j)
            })
            .collect();
        model.update(&samples, &mut rng);

        let snap = model.snapshot().expect("trained model snapshots");
        let line = snap.to_json().to_string();
        let back = CostModelSnapshot::from_json(&Json::parse(&line).unwrap()).unwrap();
        assert_eq!(back, snap);

        let mut restored = EnergyCostModel::new(Default::default());
        restored.install(&back).unwrap();
        assert!(restored.is_trained());
        for (fv, _) in samples.iter().take(20) {
            assert_eq!(restored.predict_energy_j(fv), model.predict_energy_j(fv));
        }
    }

    #[test]
    fn corrupt_snapshot_links_are_rejected_at_parse() {
        let split = |left: usize, right: usize, feature: usize| Node::Split {
            feature,
            threshold: 1.0,
            bin_threshold: 0,
            left,
            right,
        };
        let good = CostModelSnapshot {
            n_features: crate::features::FEATURE_DIM,
            scale_j: 1.0,
            base_score: 0.5,
            learning_rate: 0.1,
            trees: vec![Tree {
                nodes: vec![split(1, 2, 0), Node::Leaf { weight: 0.1 }, Node::Leaf { weight: 0.2 }],
            }],
        };
        assert!(CostModelSnapshot::from_json(&good.to_json()).is_ok());

        let mut bad_feature = good.clone();
        bad_feature.trees[0].nodes[0] = split(1, 2, 9999);
        assert!(CostModelSnapshot::from_json(&bad_feature.to_json()).is_err());

        // A self/backward link would make predict() loop forever.
        let mut cyclic = good.clone();
        cyclic.trees[0].nodes[0] = split(0, 2, 0);
        assert!(CostModelSnapshot::from_json(&cyclic.to_json()).is_err());

        let mut dangling = good.clone();
        dangling.trees[0].nodes[0] = split(1, 7, 0);
        assert!(CostModelSnapshot::from_json(&dangling.to_json()).is_err());

        let mut empty = good;
        empty.trees[0].nodes.clear();
        assert!(CostModelSnapshot::from_json(&empty.to_json()).is_err());
    }

    #[test]
    fn snapshot_rejects_wrong_version_and_feature_dim() {
        let mut snap = CostModelSnapshot {
            n_features: crate::features::FEATURE_DIM,
            scale_j: 1.0,
            base_score: 0.5,
            learning_rate: 0.1,
            trees: vec![Tree { nodes: vec![Node::Leaf { weight: 0.25 }] }],
        };
        let mut v = snap.to_json();
        if let Json::Obj(m) = &mut v {
            m.insert("model_v".to_string(), Json::num((MODEL_SNAPSHOT_VERSION + 1) as f64));
        }
        let err = CostModelSnapshot::from_json(&v).unwrap_err();
        assert!(err.contains("snapshot version"), "{err}");

        snap.n_features += 1;
        let mut model = EnergyCostModel::new(Default::default());
        assert!(model.install(&snap).is_err());
        assert!(!model.is_trained());
    }

    #[test]
    fn update_accumulates_and_refits() {
        let spec = GpuArch::A100.spec();
        let space = ScheduleSpace::new(suites::MM1, &spec);
        let mut rng = Rng::seed_from_u64(7);
        let mut model = EnergyCostModel::new(Default::default());
        for round in 0..3 {
            let samples: Vec<_> = space
                .sample_n(&mut rng, 20)
                .into_iter()
                .map(|s| {
                    let c = Candidate::new(suites::MM1, s);
                    let ev = sim::evaluate_candidate(&c, &spec);
                    (featurize(&c, &spec), ev.energy_j)
                })
                .collect();
            model.update(&samples, &mut rng);
            assert_eq!(model.n_samples(), (round + 1) * 20);
            assert_eq!(model.n_fits, round + 1);
        }
    }
}
