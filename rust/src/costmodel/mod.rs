//! The energy cost model (§5): a GBDT over high-level kernel features
//! predicting a *normalized energy score*, trained online during the
//! search with the Eq. 1 weighted loss, plus the SNR-based prediction
//! error used by the dynamic-k controller (§6).

pub mod dataset;
pub mod gbdt;
pub mod loss;

pub use dataset::{Dataset, Sample};
pub use gbdt::{BoostParams, Gbdt};
pub use loss::{eq1_weight, Loss, PaperWeightedSquaredError, SquaredError};

use crate::config::CostModelConfig;
use crate::features::FeatureVector;
use crate::util::stats;
use crate::util::Rng;

/// The online energy cost model: dataset + fitted GBDT + bookkeeping.
pub struct EnergyCostModel {
    cfg: CostModelConfig,
    data: Dataset,
    model: Option<Gbdt>,
    /// Scale used at last fit (min measured energy, J).
    scale_j: f64,
    /// Number of `fit` calls so far.
    pub n_fits: usize,
}

impl EnergyCostModel {
    pub fn new(cfg: CostModelConfig) -> EnergyCostModel {
        let data = Dataset::new(cfg.max_train_samples);
        EnergyCostModel { cfg, data, model: None, scale_j: 1.0, n_fits: 0 }
    }

    /// True once the model has been trained at least once.
    pub fn is_trained(&self) -> bool {
        self.model.is_some()
    }

    pub fn n_samples(&self) -> usize {
        self.data.len()
    }

    /// Add measured samples WITHOUT refitting.
    pub fn add_samples(&mut self, samples: &[(FeatureVector, f64)]) {
        for (fv, e) in samples {
            self.data.push(fv, *e);
        }
    }

    /// Rescale every stored training target by `factor` (no refit —
    /// call [`Self::fit`] or [`Self::update`] afterwards). Warm-start
    /// calibration uses this to pin transferred samples to the target
    /// workload's measured energy scale.
    pub fn scale_energies(&mut self, factor: f64) {
        self.data.scale_energies(factor);
    }

    /// `ModelUpdate` of Algorithm 1: add fresh measurements and refit on
    /// the full (windowed) dataset.
    pub fn update(&mut self, samples: &[(FeatureVector, f64)], rng: &mut Rng) {
        self.add_samples(samples);
        self.fit(rng);
    }

    /// Refit the GBDT on the current dataset.
    pub fn fit(&mut self, rng: &mut Rng) {
        if self.data.is_empty() {
            return;
        }
        let (x, y, w) = self.data.training_arrays(self.cfg.weighted_loss);
        self.scale_j = self.data.energy_scale();
        let params = BoostParams {
            n_trees: self.cfg.n_trees,
            learning_rate: self.cfg.learning_rate,
            max_depth: self.cfg.max_depth,
            lambda: self.cfg.lambda,
            min_child_weight: self.cfg.min_child_weight,
            n_bins: self.cfg.n_bins,
            colsample: self.cfg.colsample,
        };
        let loss: &dyn Loss =
            if self.cfg.weighted_loss { &PaperWeightedSquaredError } else { &SquaredError };
        self.model = Some(Gbdt::fit(&x, &y, &w, loss, &params, rng));
        self.n_fits += 1;
    }

    /// Predicted normalized energy score (unitless, ~1.0 = best seen).
    pub fn predict_score(&self, fv: &FeatureVector) -> f64 {
        match &self.model {
            Some(m) => m.predict(fv.as_slice()),
            None => 1.0,
        }
    }

    /// Predicted energy in joules (score × scale).
    pub fn predict_energy_j(&self, fv: &FeatureVector) -> f64 {
        self.predict_score(fv) * self.scale_j
    }

    /// Batch prediction of energies (J). Avoids per-row copies — this
    /// is the search's per-round `EnergyModelEvaAndPick` hot path.
    pub fn predict_energy_batch(&self, fvs: &[FeatureVector]) -> Vec<f64> {
        match &self.model {
            Some(m) => crate::util::parallel::par_map(fvs, |f| {
                m.predict(f.as_slice()) * self.scale_j
            }),
            None => vec![self.scale_j; fvs.len()],
        }
    }

    /// Algorithm 1's `SNR(EnergyPredicted, EnergyMeasured)` in dB —
    /// higher means the model explains the measured variation better.
    pub fn snr_error_db(predicted_j: &[f64], measured_j: &[f64]) -> f64 {
        stats::snr_db(predicted_j, measured_j)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::GpuArch;
    use crate::features::featurize;
    use crate::schedule::{space::ScheduleSpace, Candidate};
    use crate::sim;
    use crate::workload::suites;

    /// Train on simulator ground truth and check ranking quality — the
    /// in-miniature version of the paper's Fig. 4 experiment.
    #[test]
    fn learns_to_rank_energy_on_mm() {
        let spec = GpuArch::A100.spec();
        let space = ScheduleSpace::new(suites::MM1, &spec);
        let mut rng = Rng::seed_from_u64(33);

        let train: Vec<_> = space.sample_n(&mut rng, 400);
        let test: Vec<_> = space.sample_n(&mut rng, 100);

        let mut model = EnergyCostModel::new(Default::default());
        let samples: Vec<(crate::features::FeatureVector, f64)> = train
            .iter()
            .map(|s| {
                let c = Candidate::new(suites::MM1, *s);
                let ev = sim::evaluate_candidate(&c, &spec);
                (featurize(&c, &spec), ev.energy_j)
            })
            .collect();
        model.update(&samples, &mut rng);
        assert!(model.is_trained());

        let mut pred = Vec::new();
        let mut truth = Vec::new();
        for s in &test {
            let c = Candidate::new(suites::MM1, *s);
            pred.push(model.predict_energy_j(&featurize(&c, &spec)));
            truth.push(sim::evaluate_candidate(&c, &spec).energy_j);
        }
        let rho = stats::spearman(&pred, &truth);
        assert!(rho > 0.8, "holdout rank correlation {rho}");
    }

    #[test]
    fn untrained_model_predicts_constant() {
        let spec = GpuArch::A100.spec();
        let space = ScheduleSpace::new(suites::MM1, &spec);
        let model = EnergyCostModel::new(Default::default());
        let c = Candidate::new(suites::MM1, space.fallback());
        assert_eq!(model.predict_score(&featurize(&c, &spec)), 1.0);
    }

    #[test]
    fn snr_metric_behaves() {
        let measured = vec![1.0, 2.0, 3.0, 4.0];
        let close: Vec<f64> = measured.iter().map(|x| x * 1.01).collect();
        let far: Vec<f64> = measured.iter().map(|x| x * 2.0).collect();
        assert!(
            EnergyCostModel::snr_error_db(&close, &measured)
                > EnergyCostModel::snr_error_db(&far, &measured)
        );
    }

    #[test]
    fn update_accumulates_and_refits() {
        let spec = GpuArch::A100.spec();
        let space = ScheduleSpace::new(suites::MM1, &spec);
        let mut rng = Rng::seed_from_u64(7);
        let mut model = EnergyCostModel::new(Default::default());
        for round in 0..3 {
            let samples: Vec<_> = space
                .sample_n(&mut rng, 20)
                .into_iter()
                .map(|s| {
                    let c = Candidate::new(suites::MM1, s);
                    let ev = sim::evaluate_candidate(&c, &spec);
                    (featurize(&c, &spec), ev.energy_j)
                })
                .collect();
            model.update(&samples, &mut rng);
            assert_eq!(model.n_samples(), (round + 1) * 20);
            assert_eq!(model.n_fits, round + 1);
        }
    }
}
