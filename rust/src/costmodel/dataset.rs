//! Training-set management for the energy cost model: feature rows +
//! measured energies, with per-workload-search normalization and an
//! optional sliding window over search rounds.

use crate::features::FeatureVector;

/// One (features, measured energy) training sample.
#[derive(Debug, Clone)]
pub struct Sample {
    pub features: Vec<f64>,
    /// Measured energy, joules.
    pub energy_j: f64,
}

/// The accumulated training data of one search.
#[derive(Debug, Clone, Default)]
pub struct Dataset {
    samples: Vec<Sample>,
    /// 0 = unlimited; otherwise keep only the most recent N samples.
    pub max_samples: usize,
}

impl Dataset {
    pub fn new(max_samples: usize) -> Dataset {
        Dataset { samples: Vec::new(), max_samples }
    }

    pub fn push(&mut self, features: &FeatureVector, energy_j: f64) {
        debug_assert!(energy_j.is_finite() && energy_j > 0.0);
        self.samples.push(Sample { features: features.as_slice().to_vec(), energy_j });
        if self.max_samples > 0 && self.samples.len() > self.max_samples {
            let drop = self.samples.len() - self.max_samples;
            self.samples.drain(..drop);
        }
    }

    pub fn len(&self) -> usize {
        self.samples.len()
    }

    pub fn is_empty(&self) -> bool {
        self.samples.is_empty()
    }

    pub fn samples(&self) -> &[Sample] {
        &self.samples
    }

    /// Multiply every stored target energy by `factor`. Used by
    /// warm-start calibration: transferred cross-shape samples carry an
    /// approximate (MAC-ratio) scale that one real measurement corrects.
    pub fn scale_energies(&mut self, factor: f64) {
        debug_assert!(factor.is_finite() && factor > 0.0);
        for s in &mut self.samples {
            s.energy_j *= factor;
        }
    }

    /// Normalization scale: the minimum measured energy (targets become
    /// `E / E_min`, so the best kernel scores ~1.0 and the model's
    /// "normalized energy score" is search-relative, as in §5.4).
    pub fn energy_scale(&self) -> f64 {
        self.samples
            .iter()
            .map(|s| s.energy_j)
            .fold(f64::INFINITY, f64::min)
            .max(1e-12)
    }

    /// Materialize (X, y_normalized, w) for training. Weights implement
    /// Eq. 1 (`1 / normalized energy`) when `weighted` is true.
    pub fn training_arrays(&self, weighted: bool) -> (Vec<Vec<f64>>, Vec<f64>, Vec<f64>) {
        let scale = self.energy_scale();
        let mut x = Vec::with_capacity(self.samples.len());
        let mut y = Vec::with_capacity(self.samples.len());
        let mut w = Vec::with_capacity(self.samples.len());
        for s in &self.samples {
            let norm = s.energy_j / scale;
            x.push(s.features.clone());
            y.push(norm);
            w.push(if weighted { crate::costmodel::loss::eq1_weight(norm) } else { 1.0 });
        }
        (x, y, w)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::GpuArch;
    use crate::features::featurize;
    use crate::schedule::{space::ScheduleSpace, Candidate};
    use crate::workload::suites;

    fn fv() -> FeatureVector {
        let spec = GpuArch::A100.spec();
        let space = ScheduleSpace::new(suites::MM1, &spec);
        featurize(&Candidate::new(suites::MM1, space.fallback()), &spec)
    }

    #[test]
    fn normalization_uses_min_energy() {
        let mut d = Dataset::new(0);
        d.push(&fv(), 2e-3);
        d.push(&fv(), 8e-3);
        let (_, y, w) = d.training_arrays(true);
        assert!((y[0] - 1.0).abs() < 1e-12);
        assert!((y[1] - 4.0).abs() < 1e-12);
        // Eq. 1: weight = 1/E_norm -> lowest-energy sample weighted most.
        assert!(w[0] > w[1]);
    }

    #[test]
    fn sliding_window_evicts_oldest() {
        let mut d = Dataset::new(3);
        for i in 1..=5 {
            d.push(&fv(), i as f64 * 1e-3);
        }
        assert_eq!(d.len(), 3);
        let energies: Vec<f64> = d.samples().iter().map(|s| s.energy_j).collect();
        assert_eq!(energies, vec![3e-3, 4e-3, 5e-3]);
    }

    #[test]
    fn scale_energies_rescales_targets() {
        let mut d = Dataset::new(0);
        d.push(&fv(), 2e-3);
        d.push(&fv(), 4e-3);
        d.scale_energies(2.0);
        let energies: Vec<f64> = d.samples().iter().map(|s| s.energy_j).collect();
        assert_eq!(energies, vec![4e-3, 8e-3]);
    }

    #[test]
    fn unweighted_mode_is_flat() {
        let mut d = Dataset::new(0);
        d.push(&fv(), 1e-3);
        d.push(&fv(), 9e-3);
        let (_, _, w) = d.training_arrays(false);
        assert_eq!(w, vec![1.0, 1.0]);
    }
}
