//! Training objectives for the energy cost model.
//!
//! The paper's Eq. 1: `Loss(E_p, E_m) = (E_p - E_m)^2 / E_m` — a
//! squared error whose per-sample weight `1/E_m` concentrates accuracy
//! on *low-energy* kernels, which are exactly the ones the search must
//! rank correctly near convergence.

/// A twice-differentiable per-sample loss.
pub trait Loss: Sync {
    /// Loss value for prediction `p`, target `y`, sample weight `w`.
    fn value(&self, p: f64, y: f64, w: f64) -> f64;
    /// (gradient, hessian) of the loss w.r.t. `p`.
    fn grad_hess(&self, p: f64, y: f64, w: f64) -> (f64, f64);
}

/// Plain squared error: `w * (p - y)^2`.
pub struct SquaredError;

impl Loss for SquaredError {
    fn value(&self, p: f64, y: f64, w: f64) -> f64 {
        w * (p - y).powi(2)
    }

    fn grad_hess(&self, p: f64, y: f64, w: f64) -> (f64, f64) {
        (2.0 * w * (p - y), 2.0 * w)
    }
}

/// Eq. 1 of the paper: squared error weighted by `1/E_m`. Callers pass
/// the weight `w = 1/E_m` explicitly (via the dataset), which makes the
/// weighting visible and ablatable.
pub struct PaperWeightedSquaredError;

impl Loss for PaperWeightedSquaredError {
    fn value(&self, p: f64, y: f64, w: f64) -> f64 {
        // With w = 1/E_m this is exactly (E_p - E_m)^2 / E_m.
        w * (p - y).powi(2)
    }

    fn grad_hess(&self, p: f64, y: f64, w: f64) -> (f64, f64) {
        (2.0 * w * (p - y), 2.0 * w)
    }
}

/// The paper's Eq. 1 weight for a measured energy.
pub fn eq1_weight(measured_energy: f64) -> f64 {
    1.0 / measured_energy.max(1e-12)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn gradient_matches_finite_difference() {
        let losses: [&dyn Loss; 2] = [&SquaredError, &PaperWeightedSquaredError];
        for loss in losses {
            for &(p, y, w) in &[(0.5, 1.0, 1.0), (2.0, 0.3, 3.0), (-1.0, 1.0, 0.25)] {
                let eps = 1e-6;
                let num = (loss.value(p + eps, y, w) - loss.value(p - eps, y, w)) / (2.0 * eps);
                let (g, h) = loss.grad_hess(p, y, w);
                assert!((g - num).abs() < 1e-5, "grad {g} vs fd {num}");
                let heps = 1e-4;
                let numh = (loss.value(p + heps, y, w) - 2.0 * loss.value(p, y, w)
                    + loss.value(p - heps, y, w))
                    / (heps * heps);
                assert!((h - numh).abs() / h.abs() < 1e-2, "hess {h} vs fd {numh}");
            }
        }
    }

    #[test]
    fn eq1_weight_is_inverse_energy() {
        assert!((eq1_weight(2.0) - 0.5).abs() < 1e-12);
        assert!(eq1_weight(0.0).is_finite(), "guards zero energy");
    }

    #[test]
    fn eq1_value_matches_paper_formula() {
        let (ep, em) = (3.0, 2.0);
        let v = PaperWeightedSquaredError.value(ep, em, eq1_weight(em));
        assert!((v - (ep - em) * (ep - em) / em).abs() < 1e-12);
    }
}
