//! Quantile-sketch feature binning for histogram-based GBDT training
//! (the same strategy XGBoost's `hist` tree method uses).

/// Per-feature bin edges. A value `v` lands in the first bin whose upper
/// edge is `>= v`; values above the last edge land in the last bin.
#[derive(Debug, Clone, PartialEq)]
pub struct BinCuts {
    /// `edges[f]` holds the ascending upper edges for feature `f`
    /// (length <= n_bins - 1; the last bin is implicit).
    pub edges: Vec<Vec<f64>>,
}

impl BinCuts {
    /// Build quantile cuts from column-accessible data.
    ///
    /// `get(i, f)` returns feature `f` of sample `i`.
    pub fn from_data(
        n_samples: usize,
        n_features: usize,
        n_bins: usize,
        get: impl Fn(usize, usize) -> f64,
    ) -> BinCuts {
        assert!(n_bins >= 2);
        let mut edges = Vec::with_capacity(n_features);
        let mut col: Vec<f64> = Vec::with_capacity(n_samples);
        for f in 0..n_features {
            col.clear();
            col.extend((0..n_samples).map(|i| get(i, f)));
            col.sort_by(|a, b| a.partial_cmp(b).expect("finite features"));
            col.dedup();
            let mut e = Vec::new();
            if col.len() > 1 {
                // Up to n_bins-1 quantile edges over the distinct values.
                let want = (n_bins - 1).min(col.len() - 1);
                for q in 1..=want {
                    let pos = q * (col.len() - 1) / (want + 1).max(1);
                    let edge = (col[pos] + col[(pos + 1).min(col.len() - 1)]) / 2.0;
                    if e.last().map_or(true, |&last| edge > last) {
                        e.push(edge);
                    }
                }
            }
            edges.push(e);
        }
        BinCuts { edges }
    }

    /// Bin index of value `v` for feature `f` (0..=edges.len()).
    #[inline]
    pub fn bin(&self, f: usize, v: f64) -> u16 {
        let e = &self.edges[f];
        // Binary search: first edge >= v.
        match e.binary_search_by(|edge| edge.partial_cmp(&v).expect("finite")) {
            Ok(i) => i as u16,
            Err(i) => i as u16,
        }
    }

    /// Number of bins for feature `f`.
    pub fn n_bins(&self, f: usize) -> usize {
        self.edges[f].len() + 1
    }

    /// Representative split value for (feature, bin boundary): values in
    /// bins `<= b` go left iff `v <= threshold(f, b)`.
    pub fn threshold(&self, f: usize, b: usize) -> f64 {
        self.edges[f][b]
    }

    pub fn n_features(&self) -> usize {
        self.edges.len()
    }
}

/// Dense pre-binned matrix (row-major, one u16 bin per value).
#[derive(Debug, Clone)]
pub struct BinnedMatrix {
    pub bins: Vec<u16>,
    pub n_samples: usize,
    pub n_features: usize,
}

impl BinnedMatrix {
    pub fn new(cuts: &BinCuts, n_samples: usize, get: impl Fn(usize, usize) -> f64) -> Self {
        let n_features = cuts.n_features();
        let mut bins = vec![0u16; n_samples * n_features];
        for i in 0..n_samples {
            for f in 0..n_features {
                bins[i * n_features + f] = cuts.bin(f, get(i, f));
            }
        }
        BinnedMatrix { bins, n_samples, n_features }
    }

    #[inline]
    pub fn bin(&self, i: usize, f: usize) -> u16 {
        self.bins[i * self.n_features + f]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cuts_partition_values() {
        let data = [0.0, 1.0, 2.0, 3.0, 4.0, 5.0, 6.0, 7.0];
        let cuts = BinCuts::from_data(data.len(), 1, 4, |i, _| data[i]);
        assert!(cuts.edges[0].len() <= 3);
        // Bins are monotone in the value.
        let mut prev = 0u16;
        for &v in &data {
            let b = cuts.bin(0, v);
            assert!(b >= prev);
            prev = b;
        }
    }

    #[test]
    fn constant_feature_gets_single_bin() {
        let cuts = BinCuts::from_data(10, 1, 8, |_, _| 5.0);
        assert_eq!(cuts.n_bins(0), 1);
        assert_eq!(cuts.bin(0, 5.0), 0);
        assert_eq!(cuts.bin(0, 100.0), 0);
    }

    #[test]
    fn binned_matrix_roundtrip() {
        let data = vec![[1.0, 10.0], [2.0, 20.0], [3.0, 30.0], [4.0, 40.0]];
        let cuts = BinCuts::from_data(4, 2, 4, |i, f| data[i][f]);
        let m = BinnedMatrix::new(&cuts, 4, |i, f| data[i][f]);
        assert_eq!(m.n_samples, 4);
        // Larger values never land in smaller bins.
        for f in 0..2 {
            for i in 1..4 {
                assert!(m.bin(i, f) >= m.bin(i - 1, f));
            }
        }
    }

    #[test]
    fn threshold_separates_bins() {
        let data = [1.0, 2.0, 3.0, 4.0, 5.0, 6.0, 7.0, 8.0];
        let cuts = BinCuts::from_data(8, 1, 4, |i, _| data[i]);
        for b in 0..cuts.edges[0].len() {
            let t = cuts.threshold(0, b);
            for &v in &data {
                let bin = cuts.bin(0, v);
                if v <= t {
                    assert!(bin as usize <= b);
                } else {
                    assert!(bin as usize > b);
                }
            }
        }
    }
}
