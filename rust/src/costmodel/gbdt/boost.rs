//! Gradient boosting over regression trees (the XGBoost algorithm):
//! sequential second-order boosting with shrinkage and column
//! subsampling.

use super::histogram::{BinCuts, BinnedMatrix};
use super::tree::{Tree, TreeParams};
use crate::costmodel::loss::Loss;
use crate::util::parallel::par_map;
use crate::util::Rng;

/// Boosting hyperparameters.
#[derive(Debug, Clone, Copy)]
pub struct BoostParams {
    pub n_trees: usize,
    pub learning_rate: f64,
    pub max_depth: usize,
    pub lambda: f64,
    pub min_child_weight: f64,
    pub n_bins: usize,
    pub colsample: f64,
}

impl Default for BoostParams {
    fn default() -> Self {
        BoostParams {
            n_trees: 80,
            learning_rate: 0.15,
            max_depth: 6,
            lambda: 1.0,
            min_child_weight: 1e-4,
            n_bins: 32,
            colsample: 0.9,
        }
    }
}

/// A trained gradient-boosted tree ensemble.
#[derive(Debug, Clone)]
pub struct Gbdt {
    pub base_score: f64,
    pub learning_rate: f64,
    pub trees: Vec<Tree>,
    /// Flattened ensemble for the prediction hot path: all trees' nodes
    /// in one contiguous array (EXPERIMENTS.md §Perf: ~2x faster than
    /// walking per-tree `Node` enums).
    flat: Vec<FlatNode>,
    roots: Vec<u32>,
}

/// Branch-light node layout: `feature == u32::MAX` marks a leaf whose
/// weight is stored in `threshold`.
#[derive(Debug, Clone, Copy)]
struct FlatNode {
    feature: u32,
    threshold: f64,
    left: u32,
    right: u32,
}

fn flatten(trees: &[Tree]) -> (Vec<FlatNode>, Vec<u32>) {
    use crate::costmodel::gbdt::tree::Node;
    let mut flat = Vec::new();
    let mut roots = Vec::with_capacity(trees.len());
    for t in trees {
        let base = flat.len() as u32;
        roots.push(base);
        for n in &t.nodes {
            flat.push(match n {
                Node::Leaf { weight } => FlatNode {
                    feature: u32::MAX,
                    threshold: *weight,
                    left: 0,
                    right: 0,
                },
                Node::Split { feature, threshold, left, right, .. } => FlatNode {
                    feature: *feature as u32,
                    threshold: *threshold,
                    left: base + *left as u32,
                    right: base + *right as u32,
                },
            });
        }
    }
    (flat, roots)
}

impl Gbdt {
    /// Rebuild an ensemble from persisted parts (the tuning store's
    /// cost-model snapshots); the flattened prediction layout is
    /// reconstructed from the trees.
    pub fn from_parts(base_score: f64, learning_rate: f64, trees: Vec<Tree>) -> Gbdt {
        let (flat, roots) = flatten(&trees);
        Gbdt { base_score, learning_rate, trees, flat, roots }
    }

    /// Fit on rows `x` (each of equal length), targets `y`, per-sample
    /// weights `w`, with loss `loss`.
    pub fn fit(
        x: &[Vec<f64>],
        y: &[f64],
        w: &[f64],
        loss: &dyn Loss,
        p: &BoostParams,
        rng: &mut Rng,
    ) -> Gbdt {
        assert_eq!(x.len(), y.len());
        assert_eq!(x.len(), w.len());
        assert!(!x.is_empty(), "cannot fit on empty data");
        let n = x.len();
        let d = x[0].len();

        let cuts = BinCuts::from_data(n, d, p.n_bins, |i, f| x[i][f]);
        let m = BinnedMatrix::new(&cuts, n, |i, f| x[i][f]);

        // Base score: weighted mean of targets (argmin of weighted MSE).
        let wsum: f64 = w.iter().sum();
        let base_score = y.iter().zip(w).map(|(yi, wi)| yi * wi).sum::<f64>() / wsum.max(1e-30);

        let tree_params = TreeParams {
            max_depth: p.max_depth,
            lambda: p.lambda,
            min_child_weight: p.min_child_weight,
            min_gain: 1e-9,
        };

        let mut preds = vec![base_score; n];
        let mut g = vec![0.0; n];
        let mut h = vec![0.0; n];
        let idx: Vec<usize> = (0..n).collect();
        let all_features: Vec<usize> = (0..d).collect();
        let n_cols = ((d as f64 * p.colsample).ceil() as usize).clamp(1, d);

        let mut trees = Vec::with_capacity(p.n_trees);
        for _ in 0..p.n_trees {
            for i in 0..n {
                let (gi, hi) = loss.grad_hess(preds[i], y[i], w[i]);
                g[i] = gi;
                h[i] = hi;
            }
            let features: Vec<usize> = if n_cols == d {
                all_features.clone()
            } else {
                let mut f = all_features.clone();
                rng.shuffle(&mut f);
                f.truncate(n_cols);
                f
            };
            let tree = Tree::grow(&cuts, &m, &g, &h, &idx, &features, &tree_params);
            for i in 0..n {
                preds[i] += p.learning_rate * tree.predict_binned(&m, i);
            }
            trees.push(tree);
        }

        let (flat, roots) = flatten(&trees);
        Gbdt { base_score, learning_rate: p.learning_rate, trees, flat, roots }
    }

    /// Predict one sample (flattened-ensemble hot path).
    pub fn predict(&self, x: &[f64]) -> f64 {
        let mut acc = 0.0;
        for &root in &self.roots {
            let mut i = root as usize;
            loop {
                let n = unsafe { self.flat.get_unchecked(i) };
                if n.feature == u32::MAX {
                    acc += n.threshold;
                    break;
                }
                i = if x[n.feature as usize] <= n.threshold {
                    n.left as usize
                } else {
                    n.right as usize
                };
            }
        }
        self.base_score + self.learning_rate * acc
    }

    /// Reference (unflattened) prediction, kept for equivalence tests.
    pub fn predict_reference(&self, x: &[f64]) -> f64 {
        let mut p = self.base_score;
        for t in &self.trees {
            p += self.learning_rate * t.predict(x);
        }
        p
    }

    /// Predict a batch (thread-parallel; the search's hot path).
    pub fn predict_batch(&self, xs: &[Vec<f64>]) -> Vec<f64> {
        par_map(xs, |x| self.predict(x))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::costmodel::loss::{PaperWeightedSquaredError, SquaredError};
    
    

    fn synth(n: usize, f: impl Fn(f64, f64) -> f64) -> (Vec<Vec<f64>>, Vec<f64>) {
        let mut xs = Vec::new();
        let mut ys = Vec::new();
        for i in 0..n {
            let a = (i % 37) as f64 / 37.0;
            let b = (i % 11) as f64 / 11.0;
            xs.push(vec![a, b]);
            ys.push(f(a, b));
        }
        (xs, ys)
    }

    #[test]
    fn fits_additive_function() {
        let (xs, ys) = synth(600, |a, b| 3.0 * a + 2.0 * b * b + 1.0);
        let w = vec![1.0; xs.len()];
        let mut rng = Rng::seed_from_u64(0);
        let model = Gbdt::fit(&xs, &ys, &w, &SquaredError, &BoostParams::default(), &mut rng);
        let mut sse = 0.0;
        let mut sst = 0.0;
        let mean = ys.iter().sum::<f64>() / ys.len() as f64;
        for (x, y) in xs.iter().zip(&ys) {
            let p = model.predict(x);
            sse += (p - y).powi(2);
            sst += (y - mean).powi(2);
        }
        let r2 = 1.0 - sse / sst;
        assert!(r2 > 0.97, "train R^2 = {r2}");
    }

    #[test]
    fn generalizes_on_holdout() {
        let (xs, ys) = synth(1000, |a, b| (a * 6.0).sin() + b);
        let w = vec![1.0; 800];
        let mut rng = Rng::seed_from_u64(1);
        let model =
            Gbdt::fit(&xs[..800], &ys[..800], &w, &SquaredError, &BoostParams::default(), &mut rng);
        let mean = ys[800..].iter().sum::<f64>() / 200.0;
        let mut sse = 0.0;
        let mut sst = 0.0;
        for i in 800..1000 {
            sse += (model.predict(&xs[i]) - ys[i]).powi(2);
            sst += (ys[i] - mean).powi(2);
        }
        let r2 = 1.0 - sse / sst;
        assert!(r2 > 0.9, "holdout R^2 = {r2}");
    }

    #[test]
    fn paper_loss_prioritizes_low_energy_samples() {
        // Eq. 1 weights samples by 1/E_m: relative accuracy on the
        // *low*-target samples must beat an unweighted fit.
        let mut xs = Vec::new();
        let mut ys = Vec::new();
        for i in 0..400 {
            let a = (i % 20) as f64 / 20.0;
            let b = ((i / 20) % 20) as f64 / 20.0;
            xs.push(vec![a, b]);
            // Targets span two orders of magnitude.
            ys.push(0.1 + 10.0 * a + 0.5 * b);
        }
        let w_paper: Vec<f64> = ys.iter().map(|&e| 1.0 / e).collect();
        let w_flat = vec![1.0; ys.len()];
        let mut rng = Rng::seed_from_u64(2);
        let p = BoostParams { n_trees: 40, max_depth: 4, ..Default::default() };
        let weighted =
            Gbdt::fit(&xs, &ys, &w_paper, &PaperWeightedSquaredError, &p, &mut rng.clone());
        let flat = Gbdt::fit(&xs, &ys, &w_flat, &SquaredError, &p, &mut rng);

        let rel_err = |model: &Gbdt| {
            let mut e = 0.0;
            let mut n = 0;
            for (x, y) in xs.iter().zip(&ys) {
                if *y < 2.0 {
                    e += ((model.predict(x) - y) / y).abs();
                    n += 1;
                }
            }
            e / n as f64
        };
        assert!(
            rel_err(&weighted) <= rel_err(&flat) * 1.05,
            "weighted {} vs flat {}",
            rel_err(&weighted),
            rel_err(&flat)
        );
    }

    #[test]
    fn deterministic_given_seed() {
        let (xs, ys) = synth(200, |a, b| a + b);
        let w = vec![1.0; 200];
        let p = BoostParams { n_trees: 10, ..Default::default() };
        let m1 = Gbdt::fit(&xs, &ys, &w, &SquaredError, &p, &mut Rng::seed_from_u64(7));
        let m2 = Gbdt::fit(&xs, &ys, &w, &SquaredError, &p, &mut Rng::seed_from_u64(7));
        for x in xs.iter().take(20) {
            assert_eq!(m1.predict(x), m2.predict(x));
        }
    }

    #[test]
    fn flat_predict_matches_reference() {
        let (xs, ys) = synth(400, |a, b| a * 3.0 - b * b);
        let w = vec![1.0; 400];
        let m = Gbdt::fit(
            &xs,
            &ys,
            &w,
            &SquaredError,
            &BoostParams::default(),
            &mut Rng::seed_from_u64(3),
        );
        for x in xs.iter().take(100) {
            let fast = m.predict(x);
            let slow = m.predict_reference(x);
            assert!((fast - slow).abs() < 1e-12, "{fast} vs {slow}");
        }
    }

    #[test]
    fn batch_matches_single() {
        let (xs, ys) = synth(300, |a, b| a * b);
        let w = vec![1.0; 300];
        let p = BoostParams { n_trees: 15, ..Default::default() };
        let m = Gbdt::fit(&xs, &ys, &w, &SquaredError, &p, &mut Rng::seed_from_u64(9));
        let batch = m.predict_batch(&xs);
        for (i, x) in xs.iter().enumerate() {
            assert_eq!(batch[i], m.predict(x));
        }
    }
}
