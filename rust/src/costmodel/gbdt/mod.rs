//! From-scratch gradient-boosted decision trees (the XGBoost algorithm:
//! second-order boosting, histogram splits, shrinkage, column
//! subsampling) — the model family behind the paper's energy cost model
//! and Ansor's latency model.

pub mod boost;
pub mod histogram;
pub mod tree;

pub use boost::{BoostParams, Gbdt};
pub use histogram::{BinCuts, BinnedMatrix};
pub use tree::{Node, Tree, TreeParams};
