//! A single regression tree grown greedily over binned features with
//! second-order (gradient, hessian) statistics — the XGBoost tree
//! booster's core.

use super::histogram::{BinCuts, BinnedMatrix};

/// One node of a regression tree (flat array layout).
#[derive(Debug, Clone, PartialEq)]
pub enum Node {
    Split {
        feature: usize,
        /// Go left iff `value <= threshold`.
        threshold: f64,
        /// Bin-space threshold: left iff `bin <= bin_threshold`.
        bin_threshold: u16,
        left: usize,
        right: usize,
    },
    Leaf {
        weight: f64,
    },
}

/// A trained regression tree.
#[derive(Debug, Clone, PartialEq)]
pub struct Tree {
    pub nodes: Vec<Node>,
}

/// Growth hyperparameters.
#[derive(Debug, Clone, Copy)]
pub struct TreeParams {
    pub max_depth: usize,
    pub lambda: f64,
    pub min_child_weight: f64,
    pub min_gain: f64,
}

impl Tree {
    /// Predict from raw feature values.
    pub fn predict(&self, x: &[f64]) -> f64 {
        let mut i = 0;
        loop {
            match &self.nodes[i] {
                Node::Leaf { weight } => return *weight,
                Node::Split { feature, threshold, left, right, .. } => {
                    i = if x[*feature] <= *threshold { *left } else { *right };
                }
            }
        }
    }

    /// Predict from pre-binned values (training-time fast path).
    pub fn predict_binned(&self, m: &BinnedMatrix, row: usize) -> f64 {
        let mut i = 0;
        loop {
            match &self.nodes[i] {
                Node::Leaf { weight } => return *weight,
                Node::Split { feature, bin_threshold, left, right, .. } => {
                    i = if m.bin(row, *feature) <= *bin_threshold { *left } else { *right };
                }
            }
        }
    }

    /// Number of leaves.
    pub fn n_leaves(&self) -> usize {
        self.nodes.iter().filter(|n| matches!(n, Node::Leaf { .. })).count()
    }

    /// Grow a tree on samples `idx` with per-sample gradients `g` and
    /// hessians `h`. `features` restricts the candidate split features
    /// (column subsampling).
    pub fn grow(
        cuts: &BinCuts,
        m: &BinnedMatrix,
        g: &[f64],
        h: &[f64],
        idx: &[usize],
        features: &[usize],
        p: &TreeParams,
    ) -> Tree {
        let mut nodes = Vec::new();
        let mut tree = Tree { nodes: Vec::new() };
        grow_node(cuts, m, g, h, idx, features, p, 0, &mut nodes);
        tree.nodes = nodes;
        tree
    }
}

/// Recursively grow; returns the index of the created node.
#[allow(clippy::too_many_arguments)]
fn grow_node(
    cuts: &BinCuts,
    m: &BinnedMatrix,
    g: &[f64],
    h: &[f64],
    idx: &[usize],
    features: &[usize],
    p: &TreeParams,
    depth: usize,
    nodes: &mut Vec<Node>,
) -> usize {
    let g_sum: f64 = idx.iter().map(|&i| g[i]).sum();
    let h_sum: f64 = idx.iter().map(|&i| h[i]).sum();
    let leaf_weight = -g_sum / (h_sum + p.lambda);

    let make_leaf = |nodes: &mut Vec<Node>| {
        nodes.push(Node::Leaf { weight: leaf_weight });
        nodes.len() - 1
    };

    if depth >= p.max_depth || idx.len() < 2 {
        return make_leaf(nodes);
    }

    // Find the best (feature, bin) split by histogram aggregation.
    let parent_score = g_sum * g_sum / (h_sum + p.lambda);
    let mut best: Option<(usize, usize, f64)> = None; // (feature, bin, gain)
    let mut hist_g = Vec::new();
    let mut hist_h = Vec::new();
    for &f in features {
        let nb = cuts.n_bins(f);
        if nb < 2 {
            continue;
        }
        hist_g.clear();
        hist_g.resize(nb, 0.0);
        hist_h.clear();
        hist_h.resize(nb, 0.0);
        for &i in idx {
            let b = m.bin(i, f) as usize;
            hist_g[b] += g[i];
            hist_h[b] += h[i];
        }
        let mut gl = 0.0;
        let mut hl = 0.0;
        for b in 0..nb - 1 {
            gl += hist_g[b];
            hl += hist_h[b];
            let gr = g_sum - gl;
            let hr = h_sum - hl;
            if hl < p.min_child_weight || hr < p.min_child_weight {
                continue;
            }
            let gain =
                gl * gl / (hl + p.lambda) + gr * gr / (hr + p.lambda) - parent_score;
            if gain > p.min_gain && best.map_or(true, |(_, _, bg)| gain > bg) {
                best = Some((f, b, gain));
            }
        }
    }

    let Some((feature, bin, _gain)) = best else {
        return make_leaf(nodes);
    };

    let (left_idx, right_idx): (Vec<usize>, Vec<usize>) =
        idx.iter().partition(|&&i| m.bin(i, feature) as usize <= bin);
    if left_idx.is_empty() || right_idx.is_empty() {
        return make_leaf(nodes);
    }

    // Reserve this node's slot, then grow children.
    let slot = nodes.len();
    nodes.push(Node::Leaf { weight: 0.0 }); // placeholder
    let left = grow_node(cuts, m, g, h, &left_idx, features, p, depth + 1, nodes);
    let right = grow_node(cuts, m, g, h, &right_idx, features, p, depth + 1, nodes);
    nodes[slot] = Node::Split {
        feature,
        threshold: cuts.threshold(feature, bin),
        bin_threshold: bin as u16,
        left,
        right,
    };
    slot
}

#[cfg(test)]
mod tests {
    use super::*;

    fn params() -> TreeParams {
        TreeParams { max_depth: 4, lambda: 1.0, min_child_weight: 1e-6, min_gain: 1e-9 }
    }

    /// Squared-error grads for current prediction 0: g = -2y (w=1), h = 2.
    fn sq_grads(y: &[f64]) -> (Vec<f64>, Vec<f64>) {
        (y.iter().map(|&v| -2.0 * v).collect(), vec![2.0; y.len()])
    }

    #[test]
    fn fits_a_step_function() {
        // y = 10 if x > 0.5 else -10
        let xs: Vec<f64> = (0..100).map(|i| i as f64 / 100.0).collect();
        let ys: Vec<f64> = xs.iter().map(|&x| if x > 0.5 { 10.0 } else { -10.0 }).collect();
        let cuts = BinCuts::from_data(100, 1, 32, |i, _| xs[i]);
        let m = BinnedMatrix::new(&cuts, 100, |i, _| xs[i]);
        let (g, h) = sq_grads(&ys);
        let idx: Vec<usize> = (0..100).collect();
        let tree = Tree::grow(&cuts, &m, &g, &h, &idx, &[0], &params());
        assert!(tree.n_leaves() >= 2);
        assert!(tree.predict(&[0.1]) < -8.0, "{}", tree.predict(&[0.1]));
        assert!(tree.predict(&[0.9]) > 8.0, "{}", tree.predict(&[0.9]));
    }

    #[test]
    fn pure_leaf_uses_newton_weight() {
        // All targets equal: tree is a single leaf with weight
        // -G/(H+lambda) = 2n*y/(2n+lambda).
        let ys = vec![4.0; 10];
        let cuts = BinCuts::from_data(10, 1, 8, |_, _| 1.0);
        let m = BinnedMatrix::new(&cuts, 10, |_, _| 1.0);
        let (g, h) = sq_grads(&ys);
        let idx: Vec<usize> = (0..10).collect();
        let tree = Tree::grow(&cuts, &m, &g, &h, &idx, &[0], &params());
        assert_eq!(tree.n_leaves(), 1);
        let expect = 2.0 * 10.0 * 4.0 / (2.0 * 10.0 + 1.0);
        assert!((tree.predict(&[1.0]) - expect).abs() < 1e-9);
    }

    #[test]
    fn respects_max_depth() {
        let xs: Vec<f64> = (0..256).map(|i| i as f64).collect();
        let ys: Vec<f64> = xs.iter().map(|&x| (x * 0.1).sin()).collect();
        let cuts = BinCuts::from_data(256, 1, 64, |i, _| xs[i]);
        let m = BinnedMatrix::new(&cuts, 256, |i, _| xs[i]);
        let (g, h) = sq_grads(&ys);
        let idx: Vec<usize> = (0..256).collect();
        let p = TreeParams { max_depth: 3, ..params() };
        let tree = Tree::grow(&cuts, &m, &g, &h, &idx, &[0], &p);
        assert!(tree.n_leaves() <= 8);
    }

    #[test]
    fn binned_and_raw_prediction_agree() {
        let xs: Vec<f64> = (0..64).map(|i| (i * 7 % 64) as f64).collect();
        let ys: Vec<f64> = xs.iter().map(|&x| x * 2.0).collect();
        let cuts = BinCuts::from_data(64, 1, 16, |i, _| xs[i]);
        let m = BinnedMatrix::new(&cuts, 64, |i, _| xs[i]);
        let (g, h) = sq_grads(&ys);
        let idx: Vec<usize> = (0..64).collect();
        let tree = Tree::grow(&cuts, &m, &g, &h, &idx, &[0], &params());
        for i in 0..64 {
            let a = tree.predict(&[xs[i]]);
            let b = tree.predict_binned(&m, i);
            assert!((a - b).abs() < 1e-12);
        }
    }
}
