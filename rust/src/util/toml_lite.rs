//! Minimal TOML-subset parser for config files (offline build: no
//! `toml` crate).
//!
//! Supports the subset the configs use: `[section]` / `[a.b]` headers,
//! `key = value` with string / bool / integer / float values, `#`
//! comments, and blank lines. Keys are exposed flat as
//! `section.key` paths.

use std::collections::BTreeMap;

/// A parsed scalar value.
#[derive(Debug, Clone, PartialEq)]
pub enum TomlValue {
    Str(String),
    Bool(bool),
    Int(i64),
    Float(f64),
}

impl TomlValue {
    pub fn as_str(&self) -> Option<&str> {
        match self {
            TomlValue::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            TomlValue::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            TomlValue::Float(x) => Some(*x),
            TomlValue::Int(i) => Some(*i as f64),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        match self {
            TomlValue::Int(i) if *i >= 0 => Some(*i as usize),
            _ => None,
        }
    }

    pub fn as_u64(&self) -> Option<u64> {
        match self {
            TomlValue::Int(i) if *i >= 0 => Some(*i as u64),
            _ => None,
        }
    }
}

/// Flat `section.key -> value` document.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct TomlDoc {
    pub entries: BTreeMap<String, TomlValue>,
}

impl TomlDoc {
    pub fn parse(text: &str) -> Result<TomlDoc, String> {
        let mut entries = BTreeMap::new();
        let mut section = String::new();
        for (lineno, raw) in text.lines().enumerate() {
            let line = strip_comment(raw).trim();
            if line.is_empty() {
                continue;
            }
            if let Some(rest) = line.strip_prefix('[') {
                let name = rest
                    .strip_suffix(']')
                    .ok_or_else(|| format!("line {}: unterminated section", lineno + 1))?
                    .trim();
                if name.is_empty() {
                    return Err(format!("line {}: empty section name", lineno + 1));
                }
                section = name.to_string();
                continue;
            }
            let (key, value) = line
                .split_once('=')
                .ok_or_else(|| format!("line {}: expected key = value", lineno + 1))?;
            let key = key.trim();
            if key.is_empty() {
                return Err(format!("line {}: empty key", lineno + 1));
            }
            let full = if section.is_empty() {
                key.to_string()
            } else {
                format!("{section}.{key}")
            };
            entries.insert(full, parse_value(value.trim(), lineno + 1)?);
        }
        Ok(TomlDoc { entries })
    }

    pub fn get(&self, path: &str) -> Option<&TomlValue> {
        self.entries.get(path)
    }

    pub fn str_or<'a>(&'a self, path: &str, default: &'a str) -> &'a str {
        self.get(path).and_then(|v| v.as_str()).unwrap_or(default)
    }

    pub fn f64_or(&self, path: &str, default: f64) -> f64 {
        self.get(path).and_then(|v| v.as_f64()).unwrap_or(default)
    }

    pub fn usize_or(&self, path: &str, default: usize) -> usize {
        self.get(path).and_then(|v| v.as_usize()).unwrap_or(default)
    }

    pub fn u64_or(&self, path: &str, default: u64) -> u64 {
        self.get(path).and_then(|v| v.as_u64()).unwrap_or(default)
    }

    pub fn bool_or(&self, path: &str, default: bool) -> bool {
        self.get(path).and_then(|v| v.as_bool()).unwrap_or(default)
    }
}

fn strip_comment(line: &str) -> &str {
    // A '#' outside quotes starts a comment.
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn parse_value(text: &str, lineno: usize) -> Result<TomlValue, String> {
    if let Some(rest) = text.strip_prefix('"') {
        let inner = rest
            .strip_suffix('"')
            .ok_or_else(|| format!("line {lineno}: unterminated string"))?;
        return Ok(TomlValue::Str(inner.replace("\\\"", "\"").replace("\\\\", "\\")));
    }
    match text {
        "true" => return Ok(TomlValue::Bool(true)),
        "false" => return Ok(TomlValue::Bool(false)),
        _ => {}
    }
    if !text.contains('.') && !text.contains('e') && !text.contains('E') {
        if let Ok(i) = text.replace('_', "").parse::<i64>() {
            return Ok(TomlValue::Int(i));
        }
    }
    text.replace('_', "")
        .parse::<f64>()
        .map(TomlValue::Float)
        .map_err(|_| format!("line {lineno}: cannot parse value '{text}'"))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_sections_and_scalars() {
        let doc = TomlDoc::parse(
            r#"
# top comment
gpu = "a100"
seed = 42
rounds = 12     # trailing comment
mu_snr_db = 10.5

[nvml]
sampling_hz = 45.0
warmup_s = 3
noisy = true

[cost_model]
n_trees = 80
"#,
        )
        .unwrap();
        assert_eq!(doc.str_or("gpu", ""), "a100");
        assert_eq!(doc.u64_or("seed", 0), 42);
        assert_eq!(doc.usize_or("rounds", 0), 12);
        assert!((doc.f64_or("mu_snr_db", 0.0) - 10.5).abs() < 1e-12);
        assert!((doc.f64_or("nvml.sampling_hz", 0.0) - 45.0).abs() < 1e-12);
        assert_eq!(doc.f64_or("nvml.warmup_s", 0.0), 3.0);
        assert!(doc.bool_or("nvml.noisy", false));
        assert_eq!(doc.usize_or("cost_model.n_trees", 0), 80);
    }

    #[test]
    fn hash_inside_string_is_not_comment() {
        let doc = TomlDoc::parse(r##"name = "a#b""##).unwrap();
        assert_eq!(doc.str_or("name", ""), "a#b");
    }

    #[test]
    fn errors_are_reported_with_line_numbers() {
        assert!(TomlDoc::parse("[oops").unwrap_err().contains("line 1"));
        assert!(TomlDoc::parse("just a line").unwrap_err().contains("line 1"));
        assert!(TomlDoc::parse("x = @@").unwrap_err().contains("line 1"));
    }

    #[test]
    fn defaults_apply_for_missing_keys() {
        let doc = TomlDoc::parse("").unwrap();
        assert_eq!(doc.usize_or("absent", 7), 7);
        assert_eq!(doc.str_or("absent", "d"), "d");
    }
}
