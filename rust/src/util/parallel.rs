//! Scoped data-parallel map over std::thread (offline build: no rayon).
//!
//! Work is split into contiguous chunks, one per worker; results come
//! back in input order. Used by the latency-evaluation hot path and
//! GBDT batch prediction.

/// Number of worker threads to use (capped, respects available cores).
pub fn default_workers() -> usize {
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4).min(16)
}

/// Parallel map preserving order. Falls back to serial for small inputs.
pub fn par_map<T, U, F>(items: &[T], f: F) -> Vec<U>
where
    T: Sync,
    U: Send,
    F: Fn(&T) -> U + Sync,
{
    let n = items.len();
    let workers = default_workers();
    // Thread spawn/join costs ~10-20us per worker; only fan out when
    // each worker gets enough work to amortize it (tuned via the
    // `gbdt_predict` bench: 256-item batches are faster serial).
    if n < 1024 || workers <= 1 {
        return items.iter().map(&f).collect();
    }

    let chunk = n.div_ceil(workers);
    let mut out: Vec<Option<U>> = Vec::with_capacity(n);
    out.resize_with(n, || None);

    let out_chunks: Vec<&mut [Option<U>]> = out.chunks_mut(chunk).collect();
    std::thread::scope(|scope| {
        for (ci, out_chunk) in out_chunks.into_iter().enumerate() {
            let start = ci * chunk;
            let f = &f;
            let items = &items[start..(start + out_chunk.len()).min(n)];
            scope.spawn(move || {
                for (slot, item) in out_chunk.iter_mut().zip(items) {
                    *slot = Some(f(item));
                }
            });
        }
    });
    out.into_iter().map(|o| o.expect("all slots filled")).collect()
}

/// Parallel map with per-chunk index, for cases needing a distinct seed
/// per item: `f(index, item)`.
pub fn par_map_indexed<T, U, F>(items: &[T], f: F) -> Vec<U>
where
    T: Sync,
    U: Send,
    F: Fn(usize, &T) -> U + Sync,
{
    let indexed: Vec<(usize, &T)> = items.iter().enumerate().collect();
    par_map(&indexed, |(i, t)| f(*i, t))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn preserves_order() {
        let items: Vec<usize> = (0..1000).collect();
        let out = par_map(&items, |&x| x * 2);
        assert_eq!(out, (0..1000).map(|x| x * 2).collect::<Vec<_>>());
    }

    #[test]
    fn small_inputs_work() {
        let items = vec![1, 2, 3];
        assert_eq!(par_map(&items, |&x| x + 1), vec![2, 3, 4]);
        let empty: Vec<i32> = vec![];
        assert!(par_map(&empty, |&x| x).is_empty());
    }

    #[test]
    fn indexed_variant_sees_indices() {
        let items = vec![10usize; 300];
        let out = par_map_indexed(&items, |i, &x| i + x);
        for (i, v) in out.iter().enumerate() {
            assert_eq!(*v, i + 10);
        }
    }

    #[test]
    fn actually_uses_threads_for_large_inputs() {
        use std::collections::HashSet;
        use std::sync::Mutex;
        let ids = Mutex::new(HashSet::new());
        let items: Vec<usize> = (0..10_000).collect();
        par_map(&items, |&x| {
            ids.lock().unwrap().insert(std::thread::current().id());
            x
        });
        if default_workers() > 1 {
            assert!(ids.lock().unwrap().len() > 1, "expected multiple worker threads");
        }
    }
}
