//! Small statistics helpers shared by the search, the experiments, and
//! the tests: mean/variance, Pearson/Spearman correlation, R², and the
//! SNR metric of Algorithm 1.

/// Arithmetic mean (0 for empty input).
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Population variance.
pub fn variance(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let m = mean(xs);
    xs.iter().map(|x| (x - m).powi(2)).sum::<f64>() / xs.len() as f64
}

/// Pearson correlation coefficient.
pub fn pearson(xs: &[f64], ys: &[f64]) -> f64 {
    assert_eq!(xs.len(), ys.len());
    if xs.len() < 2 {
        return 0.0;
    }
    let mx = mean(xs);
    let my = mean(ys);
    let cov: f64 = xs.iter().zip(ys).map(|(x, y)| (x - mx) * (y - my)).sum();
    let vx: f64 = xs.iter().map(|x| (x - mx).powi(2)).sum();
    let vy: f64 = ys.iter().map(|y| (y - my).powi(2)).sum();
    let denom = (vx * vy).sqrt();
    if denom <= 0.0 {
        0.0
    } else {
        cov / denom
    }
}

/// Ranks with average tie handling.
fn ranks(xs: &[f64]) -> Vec<f64> {
    let mut idx: Vec<usize> = (0..xs.len()).collect();
    idx.sort_by(|&a, &b| xs[a].partial_cmp(&xs[b]).expect("finite"));
    let mut r = vec![0.0; xs.len()];
    let mut i = 0;
    while i < idx.len() {
        let mut j = i;
        while j + 1 < idx.len() && xs[idx[j + 1]] == xs[idx[i]] {
            j += 1;
        }
        let avg = (i + j) as f64 / 2.0 + 1.0;
        for &k in &idx[i..=j] {
            r[k] = avg;
        }
        i = j + 1;
    }
    r
}

/// Spearman rank correlation — the metric that matters for a cost model
/// used only to *rank* kernels.
pub fn spearman(xs: &[f64], ys: &[f64]) -> f64 {
    pearson(&ranks(xs), &ranks(ys))
}

/// Coefficient of determination of predictions vs targets.
pub fn r2(pred: &[f64], target: &[f64]) -> f64 {
    assert_eq!(pred.len(), target.len());
    let m = mean(target);
    let sse: f64 = pred.iter().zip(target).map(|(p, t)| (p - t).powi(2)).sum();
    let sst: f64 = target.iter().map(|t| (t - m).powi(2)).sum();
    if sst <= 0.0 {
        return if sse == 0.0 { 1.0 } else { 0.0 };
    }
    1.0 - sse / sst
}

/// Signal-to-noise ratio of predictions vs measurements, in dB
/// (Algorithm 1's `PredictionError` is this SNR; higher = better model):
/// `SNR = 10 log10( Var(measured) / MSE(pred - measured) )`.
pub fn snr_db(pred: &[f64], measured: &[f64]) -> f64 {
    assert_eq!(pred.len(), measured.len());
    if pred.is_empty() {
        return f64::NEG_INFINITY;
    }
    let mse: f64 =
        pred.iter().zip(measured).map(|(p, m)| (p - m).powi(2)).sum::<f64>() / pred.len() as f64;
    let sig = variance(measured);
    if mse <= 0.0 {
        return f64::INFINITY;
    }
    if sig <= 0.0 {
        // No signal variance: treat near-zero error as high SNR.
        let scale = mean(measured).abs().max(1e-30);
        return 10.0 * (scale * scale / mse).log10();
    }
    10.0 * (sig / mse).log10()
}

/// Percentile (0..=100) by nearest-rank on a copy.
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    assert!(!xs.is_empty());
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).expect("finite"));
    let rank = ((p / 100.0) * (v.len() - 1) as f64).round() as usize;
    v[rank.min(v.len() - 1)]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pearson_perfect_and_inverse() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        let ys = [2.0, 4.0, 6.0, 8.0];
        assert!((pearson(&xs, &ys) - 1.0).abs() < 1e-12);
        let inv = [8.0, 6.0, 4.0, 2.0];
        assert!((pearson(&xs, &inv) + 1.0).abs() < 1e-12);
    }

    #[test]
    fn spearman_monotone_nonlinear() {
        let xs = [1.0, 2.0, 3.0, 4.0, 5.0];
        let ys = [1.0, 8.0, 27.0, 64.0, 125.0]; // nonlinear but monotone
        assert!((spearman(&xs, &ys) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn spearman_handles_ties() {
        let xs = [1.0, 1.0, 2.0, 3.0];
        let ys = [1.0, 1.0, 2.0, 3.0];
        assert!((spearman(&xs, &ys) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn r2_behaviour() {
        let t = [1.0, 2.0, 3.0];
        assert!((r2(&t, &t) - 1.0).abs() < 1e-12);
        let m = mean(&t);
        assert!(r2(&[m, m, m], &t).abs() < 1e-12);
    }

    #[test]
    fn snr_scales_with_error() {
        let measured = [1.0, 2.0, 3.0, 4.0, 5.0];
        let good: Vec<f64> = measured.iter().map(|x| x + 0.01).collect();
        let bad: Vec<f64> = measured.iter().map(|x| x + 1.0).collect();
        assert!(snr_db(&good, &measured) > snr_db(&bad, &measured));
        assert!(snr_db(&good, &measured) > 30.0);
        assert!(snr_db(&bad, &measured) < 5.0);
    }

    #[test]
    fn percentile_nearest_rank() {
        let xs = [5.0, 1.0, 3.0, 2.0, 4.0];
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 50.0), 3.0);
        assert_eq!(percentile(&xs, 100.0), 5.0);
    }
}
