//! In-tree utility substrates (the build is fully offline, so the
//! framework carries its own RNG, JSON, TOML-subset parser, thread
//! pool, and statistics toolkit instead of pulling crates).

pub mod json;
pub mod parallel;
pub mod rng;
pub mod stats;
pub mod toml_lite;

pub use json::Json;
pub use rng::Rng;
pub use toml_lite::{TomlDoc, TomlValue};
