//! Deterministic PRNG: xoshiro256** seeded via SplitMix64.
//!
//! The whole framework must be reproducible from a single `--seed`, and
//! the build is offline (no `rand` crate), so we carry our own
//! generator. xoshiro256** passes BigCrush and is the generator behind
//! `rand`'s `SmallRng`; SplitMix64 is the canonical seeder.

/// Deterministic, seedable PRNG.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Rng {
    s: [u64; 4],
}

#[inline]
fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

impl Rng {
    /// Seed deterministically from a u64.
    pub fn seed_from_u64(seed: u64) -> Rng {
        let mut sm = seed;
        Rng {
            s: [splitmix64(&mut sm), splitmix64(&mut sm), splitmix64(&mut sm), splitmix64(&mut sm)],
        }
    }

    /// Derive an independent stream (for per-worker RNGs).
    pub fn fork(&mut self, stream: u64) -> Rng {
        Rng::seed_from_u64(self.next_u64() ^ stream.wrapping_mul(0x9E3779B97F4A7C15))
    }

    /// Next raw 64 bits (xoshiro256**).
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform f64 in [0, 1).
    #[inline]
    pub fn gen_f64(&mut self) -> f64 {
        // 53 high bits -> [0,1).
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform usize in [lo, hi) — hi exclusive, hi > lo.
    #[inline]
    pub fn gen_range(&mut self, lo: usize, hi: usize) -> usize {
        debug_assert!(hi > lo);
        let span = (hi - lo) as u64;
        // Lemire's method with rejection for unbiased sampling.
        loop {
            let x = self.next_u64();
            let m = (x as u128).wrapping_mul(span as u128);
            let l = m as u64;
            if l >= span {
                return lo + (m >> 64) as usize;
            }
            let t = span.wrapping_neg() % span;
            if l >= t {
                return lo + (m >> 64) as usize;
            }
        }
    }

    /// Bernoulli draw.
    #[inline]
    pub fn gen_bool(&mut self, p: f64) -> bool {
        self.gen_f64() < p
    }

    /// Uniformly chosen element reference.
    #[inline]
    pub fn choose<'a, T>(&mut self, v: &'a [T]) -> &'a T {
        &v[self.gen_range(0, v.len())]
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, v: &mut [T]) {
        for i in (1..v.len()).rev() {
            let j = self.gen_range(0, i + 1);
            v.swap(i, j);
        }
    }

    /// Standard normal draw (Box–Muller).
    pub fn normal(&mut self) -> f64 {
        let u1 = self.gen_f64().max(f64::EPSILON);
        let u2 = self.gen_f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::seed_from_u64(42);
        let mut b = Rng::seed_from_u64(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::seed_from_u64(1);
        let mut b = Rng::seed_from_u64(2);
        assert_ne!(
            (0..8).map(|_| a.next_u64()).collect::<Vec<_>>(),
            (0..8).map(|_| b.next_u64()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn gen_range_in_bounds_and_covers() {
        let mut r = Rng::seed_from_u64(3);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let x = r.gen_range(0, 10);
            assert!(x < 10);
            seen[x] = true;
        }
        assert!(seen.iter().all(|&s| s), "all values hit");
    }

    #[test]
    fn gen_f64_is_uniform_enough() {
        let mut r = Rng::seed_from_u64(4);
        let n = 20_000;
        let mean: f64 = (0..n).map(|_| r.gen_f64()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn normal_has_unit_variance() {
        let mut r = Rng::seed_from_u64(5);
        let n = 20_000;
        let draws: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = draws.iter().sum::<f64>() / n as f64;
        let var = draws.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.03, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn shuffle_permutes() {
        let mut r = Rng::seed_from_u64(6);
        let mut v: Vec<usize> = (0..20).collect();
        let orig = v.clone();
        r.shuffle(&mut v);
        assert_ne!(v, orig);
        let mut sorted = v.clone();
        sorted.sort();
        assert_eq!(sorted, orig);
    }

    #[test]
    fn fork_streams_are_independent() {
        let mut base = Rng::seed_from_u64(7);
        let mut f1 = base.fork(1);
        let mut f2 = base.fork(2);
        assert_ne!(f1.next_u64(), f2.next_u64());
    }

    #[test]
    fn gen_bool_probability() {
        let mut r = Rng::seed_from_u64(8);
        let hits = (0..10_000).filter(|_| r.gen_bool(0.3)).count();
        assert!((2800..3200).contains(&hits), "hits={hits}");
    }
}
