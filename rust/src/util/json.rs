//! Minimal JSON value + writer + parser (offline build: no serde_json).
//!
//! Used for experiment result files, the coordinator's JSONL event log,
//! and cost-model snapshots. Supports the full JSON value model; numbers
//! are f64 (adequate for telemetry).

use std::collections::BTreeMap;
use std::fmt::Write as _;

/// A JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn arr<I: IntoIterator<Item = Json>>(items: I) -> Json {
        Json::Arr(items.into_iter().collect())
    }

    pub fn num(x: f64) -> Json {
        Json::Num(x)
    }

    pub fn str(s: impl Into<String>) -> Json {
        Json::Str(s.into())
    }

    /// Access an object field.
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(x) => {
                if x.is_finite() {
                    if *x == x.trunc() && x.abs() < 1e15 {
                        let _ = write!(out, "{}", *x as i64);
                    } else {
                        let _ = write!(out, "{x}");
                    }
                } else {
                    out.push_str("null");
                }
            }
            Json::Str(s) => write_escaped(s, out),
            Json::Arr(v) => {
                out.push('[');
                for (i, item) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    item.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(k, out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }

    /// Parse a JSON document.
    pub fn parse(text: &str) -> Result<Json, String> {
        let mut p = Parser { b: text.as_bytes(), i: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.i != p.b.len() {
            return Err(format!("trailing data at byte {}", p.i));
        }
        Ok(v)
    }
}

/// Compact serialization (`value.to_string()` via the blanket
/// `ToString`).
impl std::fmt::Display for Json {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let mut s = String::new();
        self.write(&mut s);
        f.write_str(&s)
    }
}

fn write_escaped(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn expect(&mut self, c: u8) -> Result<(), String> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(format!("expected '{}' at byte {}", c as char, self.i))
        }
    }

    fn value(&mut self) -> Result<Json, String> {
        self.skip_ws();
        match self.peek() {
            Some(b'n') => self.lit("null", Json::Null),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b'[') => {
                self.i += 1;
                let mut v = Vec::new();
                self.skip_ws();
                if self.peek() == Some(b']') {
                    self.i += 1;
                    return Ok(Json::Arr(v));
                }
                loop {
                    v.push(self.value()?);
                    self.skip_ws();
                    match self.peek() {
                        Some(b',') => self.i += 1,
                        Some(b']') => {
                            self.i += 1;
                            return Ok(Json::Arr(v));
                        }
                        _ => return Err(format!("bad array at byte {}", self.i)),
                    }
                }
            }
            Some(b'{') => {
                self.i += 1;
                let mut m = BTreeMap::new();
                self.skip_ws();
                if self.peek() == Some(b'}') {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                loop {
                    self.skip_ws();
                    let k = self.string()?;
                    self.skip_ws();
                    self.expect(b':')?;
                    let v = self.value()?;
                    m.insert(k, v);
                    self.skip_ws();
                    match self.peek() {
                        Some(b',') => self.i += 1,
                        Some(b'}') => {
                            self.i += 1;
                            return Ok(Json::Obj(m));
                        }
                        _ => return Err(format!("bad object at byte {}", self.i)),
                    }
                }
            }
            _ => self.number(),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, String> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            Err(format!("bad literal at byte {}", self.i))
        }
    }

    fn string(&mut self) -> Result<String, String> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err("unterminated string".into()),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'n') => s.push('\n'),
                        Some(b'r') => s.push('\r'),
                        Some(b't') => s.push('\t'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'u') => {
                            let hex = self
                                .b
                                .get(self.i + 1..self.i + 5)
                                .ok_or("bad \\u escape")?;
                            let code = u32::from_str_radix(
                                std::str::from_utf8(hex).map_err(|_| "bad \\u")?,
                                16,
                            )
                            .map_err(|_| "bad \\u")?;
                            s.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                            self.i += 4;
                        }
                        _ => return Err("bad escape".into()),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // Consume one UTF-8 scalar.
                    let rest = std::str::from_utf8(&self.b[self.i..])
                        .map_err(|_| "invalid utf8".to_string())?;
                    let c = rest.chars().next().unwrap();
                    s.push(c);
                    self.i += c.len_utf8();
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.i;
        while self
            .peek()
            .is_some_and(|c| c.is_ascii_digit() || matches!(c, b'-' | b'+' | b'.' | b'e' | b'E'))
        {
            self.i += 1;
        }
        let text = std::str::from_utf8(&self.b[start..self.i]).map_err(|_| "bad number")?;
        text.parse::<f64>().map(Json::Num).map_err(|_| format!("bad number '{text}'"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip() {
        let v = Json::obj(vec![
            ("name", Json::str("MM1")),
            ("energy_mj", Json::num(6.5)),
            ("tags", Json::arr([Json::str("a100"), Json::Bool(true), Json::Null])),
            ("count", Json::num(42.0)),
        ]);
        let text = v.to_string();
        let back = Json::parse(&text).unwrap();
        assert_eq!(back, v);
        assert_eq!(back.get("energy_mj").unwrap().as_f64(), Some(6.5));
        assert_eq!(back.get("name").unwrap().as_str(), Some("MM1"));
    }

    #[test]
    fn integers_render_without_decimal() {
        assert_eq!(Json::num(42.0).to_string(), "42");
        assert_eq!(Json::num(2.5).to_string(), "2.5");
    }

    #[test]
    fn escapes() {
        let v = Json::str("a\"b\\c\nd");
        let text = v.to_string();
        assert_eq!(Json::parse(&text).unwrap(), v);
    }

    #[test]
    fn parses_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": null}], "c": -1.5e3}"#).unwrap();
        assert_eq!(v.get("c").unwrap().as_f64(), Some(-1500.0));
        assert_eq!(v.get("a").unwrap().as_arr().unwrap().len(), 3);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("nul").is_err());
        assert!(Json::parse("{} trailing").is_err());
    }
}
