//! The dynamic-k controller of Algorithm 1 (§6).
//!
//! `k ∈ [0, 1]` is the fraction of the `M` fastest kernels whose energy
//! is NVML-measured each round. The controller watches the cost model's
//! SNR prediction error: when the SNR is *below* the threshold `µ` the
//! model is struggling, so `k` grows by 0.2 (more measurements, bigger
//! update); when the SNR clears `µ`, `k` shrinks by 0.2 (the model is
//! trusted, measurement budget is saved). This is the mechanism behind
//! the ~2x search-speed gain of Fig. 5.

/// Dynamic measurement-fraction controller.
#[derive(Debug, Clone)]
pub struct KController {
    /// Current measurement fraction.
    pub k: f64,
    /// Step applied per round (paper: 0.2).
    pub step: f64,
    /// SNR threshold `µ` in dB.
    pub mu_db: f64,
    /// Lower bound on measured kernels per round (0 = paper-literal,
    /// allowing the model to starve once k hits 0).
    pub min_measure: usize,
    /// Trace of k values (diagnostics / Fig. 5 accounting).
    pub trace: Vec<f64>,
}

impl KController {
    pub fn new(k_init: f64, step: f64, mu_db: f64, min_measure: usize) -> KController {
        KController {
            k: k_init.clamp(0.0, 1.0),
            step,
            mu_db,
            min_measure,
            trace: vec![k_init.clamp(0.0, 1.0)],
        }
    }

    /// Number of kernels to measure this round out of the `m` fastest.
    pub fn n_measure(&self, m: usize) -> usize {
        let km = (self.k * m as f64).ceil() as usize;
        km.max(self.min_measure).min(m)
    }

    /// Algorithm 1's update: `snr_db < µ` → k += step (model is bad,
    /// measure more); otherwise k -= step.
    pub fn update(&mut self, snr_db: f64) {
        if snr_db < self.mu_db {
            self.k = (self.k + self.step).min(1.0);
        } else {
            self.k = (self.k - self.step).max(0.0);
        }
        self.trace.push(self.k);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn good_model_shrinks_k() {
        let mut c = KController::new(1.0, 0.2, 10.0, 1);
        for _ in 0..3 {
            c.update(25.0); // SNR well above threshold
        }
        assert!((c.k - 0.4).abs() < 1e-12, "k={}", c.k);
    }

    #[test]
    fn bad_model_grows_k() {
        let mut c = KController::new(0.2, 0.2, 10.0, 1);
        c.update(3.0);
        c.update(3.0);
        assert!((c.k - 0.6).abs() < 1e-12);
    }

    #[test]
    fn k_clamped_to_unit_interval() {
        let mut c = KController::new(1.0, 0.2, 10.0, 1);
        c.update(3.0);
        assert_eq!(c.k, 1.0);
        for _ in 0..10 {
            c.update(50.0);
        }
        assert_eq!(c.k, 0.0);
    }

    #[test]
    fn n_measure_respects_floor_and_cap() {
        let c = KController::new(0.5, 0.2, 10.0, 2);
        assert_eq!(c.n_measure(32), 16);
        let zero = KController::new(0.0, 0.2, 10.0, 2);
        assert_eq!(zero.n_measure(32), 2, "floor applies");
        let paper_literal = KController::new(0.0, 0.2, 10.0, 0);
        assert_eq!(paper_literal.n_measure(32), 0, "paper-literal allows zero");
        let full = KController::new(1.0, 0.2, 10.0, 0);
        assert_eq!(full.n_measure(32), 32);
    }

    #[test]
    fn ceil_rounding_matches_paper_example() {
        // §6.4: k = 0.5 with M kernels -> M/2 measurements.
        let c = KController::new(0.5, 0.2, 10.0, 0);
        assert_eq!(c.n_measure(32), 16);
        // Odd M rounds up.
        assert_eq!(c.n_measure(33), 17);
    }

    #[test]
    fn trace_records_history() {
        let mut c = KController::new(1.0, 0.2, 10.0, 1);
        c.update(50.0);
        c.update(1.0);
        assert_eq!(c.trace, vec![1.0, 0.8, 1.0]);
    }
}
