//! The paper's energy-aware search (§4.4 + §6.4, Algorithm 1).
//!
//! Each round after the initial one:
//!
//! 1. `GeneticReproduction` — new generation from parents;
//! 2. `LatencyEvaAndPick` — keep the `M` fastest (latency first: §4.3);
//! 3. `EnergyModelEvaAndPick` — cost model ranks the `M`, keep `k·M`;
//! 4. `NVMLMeasurement` — measure those `k·M` kernels;
//! 5. `ModelUpdate` — fold measurements into the cost model;
//! 6. SNR check → `k ± 0.2` (the dynamic updating strategy);
//! 7. parents = top 50% lowest (model-)energy of the `M`.
//!
//! With `use_model = false` this degenerates to the **NVML-only**
//! configuration (every one of the `M` kernels measured, no model) used
//! as the comparison arm in Fig. 5.

use super::dynamic_k::KController;
use super::{
    latency_eva_and_pick, select_final, EvaluatedKernel, RoundStats, SearchOutcome,
    MODEL_PREDICT_BASE_S, MODEL_PREDICT_PER_KERNEL_S, MODEL_TRAIN_BASE_S,
    MODEL_TRAIN_PER_SAMPLE_S,
};
use crate::config::{SearchConfig, SearchMode};
use crate::costmodel::EnergyCostModel;
use crate::features::{featurize, FeatureVector};
use crate::nvml::NvmlMeter;
use crate::schedule::space::ScheduleSpace;
use crate::schedule::{Candidate, Schedule};
use crate::store::WarmStart;
use crate::util::Rng;
use crate::workload::Workload;
use std::collections::HashSet;

/// Run the energy-aware search cold. `use_model = true` is the paper's
/// method; `false` is the NVML-only ablation.
pub fn run(workload: Workload, cfg: &SearchConfig, use_model: bool) -> SearchOutcome {
    run_warm(workload, cfg, use_model, None)
}

/// Run the energy-aware search, optionally warm-started from a tuning
/// store (see [`crate::store::transfer`]). With `warm = None` this is
/// byte-identical to the cold search. A warm start:
///
/// * injects re-legalized neighbor schedules into the initial
///   population (capped at half the population);
/// * pre-trains the cost model on transferred measured samples — or,
///   when the neighbor record carries a persisted model snapshot,
///   installs those trees directly and **skips the first fit** — so
///   round 0 runs model-guided like every later round: one
///   scale-calibration measurement plus `k·M` kernels instead of all
///   `M`;
/// * starts the dynamic-k controller at the neighbor's final `k`
///   (SNR-guarded: a bad transfer drives `k` back up).
pub fn run_warm(
    workload: Workload,
    cfg: &SearchConfig,
    use_model: bool,
    warm: Option<&WarmStart>,
) -> SearchOutcome {
    let spec = cfg.gpu.spec();
    let space = ScheduleSpace::new(workload, &spec);
    let mut rng = Rng::seed_from_u64(cfg.seed);
    let mut meter = NvmlMeter::new(spec.clone(), cfg.nvml.clone());
    meter.warm_up();

    let mut model = EnergyCostModel::new(cfg.cost_model.clone());
    let k_init = match warm.and_then(|w| w.k_hint) {
        Some(k) if use_model => k,
        _ => cfg.k_init,
    };
    let mut kctrl = KController::new(k_init, cfg.k_step, cfg.mu_snr_db, cfg.min_measure_per_round);

    let mut rounds: Vec<RoundStats> = Vec::new();
    let mut measured_pool: Vec<EvaluatedKernel> = Vec::new();
    #[allow(unused_assignments)]
    let mut best_energy = f64::INFINITY;
    let mut stale = 0usize;
    // Fastest (schedule, timed latency) seen across all rounds.
    let mut fastest_seen: Option<(Schedule, f64)> = None;

    // ---- initial round ---------------------------------------------------
    let mut pop = super::population::init_population(&space, cfg.population, &mut rng);
    if let Some(w) = warm {
        inject_seeds(&mut pop, &w.seed_schedules, cfg.population);
    }
    // Pre-train the model on transferred measured samples: round 0 can
    // then run model-guided instead of measuring all M. When the
    // neighbor record carries a persisted model snapshot, install its
    // trees instead of refitting — the first fit (and its simulated
    // training cost) is skipped; the samples are still banked so the
    // calibration refit below trains on them.
    if use_model {
        if let Some(w) = warm {
            let installed =
                w.model.as_ref().is_some_and(|snap| model.install(snap).is_ok());
            if !w.seed_samples.is_empty() {
                if installed {
                    model.add_samples(&w.seed_samples);
                } else {
                    model.update(&w.seed_samples, &mut rng);
                    meter.clock.charge_model_train(
                        MODEL_TRAIN_BASE_S + MODEL_TRAIN_PER_SAMPLE_S * model.n_samples() as f64,
                    );
                }
            }
        }
    }
    let warm_model = use_model && model.is_trained();

    let top = latency_eva_and_pick(workload, &pop, cfg.m_latency_keep, &mut meter, &mut rng);
    if let Some(&(s, l)) = top.first() {
        fastest_seen = Some((s, l));
    }
    let mut parents: Vec<Schedule>;
    if warm_model {
        // Warm round 0: rank the M fastest with the transferred model,
        // measure only k·M (this is where warm starts save NVML time).
        //
        // First, calibrate the transferred model's absolute scale with
        // ONE real measurement of the fastest kernel: cross-shape
        // samples carry an approximate (MAC-ratio) energy scale, and an
        // uncorrected scale error would show up as a huge SNR error and
        // trip the dynamic-k guard on the spot.
        let cal_cand = Candidate::new(workload, top[0].0);
        let cal_feats = featurize(&cal_cand, &spec);
        let cal_pred = model.predict_energy_j(&cal_feats);
        meter.clock.charge_model_predict(MODEL_PREDICT_BASE_S + MODEL_PREDICT_PER_KERNEL_S);
        let cal = meter.measure(&cal_cand, &mut rng);
        if cal_pred.is_finite() && cal_pred > 0.0 {
            let ratio = (cal.energy_j / cal_pred).clamp(0.2, 5.0);
            model.scale_energies(ratio);
        }
        model.update(&[(cal_feats, cal.energy_j)], &mut rng);
        meter.clock.charge_model_train(
            MODEL_TRAIN_BASE_S + MODEL_TRAIN_PER_SAMPLE_S * model.n_samples() as f64,
        );
        let cal_kernel = EvaluatedKernel {
            schedule: top[0].0,
            latency_s: cal.latency_s,
            energy_j: cal.energy_j,
            avg_power_w: cal.avg_power_w,
            energy_measured: true,
        };

        let r = model_guided_round(
            workload,
            &spec,
            cfg,
            &top,
            true,
            Some(&cal_kernel),
            &mut model,
            &mut kctrl,
            &mut meter,
            &mut rng,
        );
        parents = r.parents;
        best_energy = r.measured.iter().map(|e| e.energy_j).fold(f64::INFINITY, f64::min);
        let n_measured = r.measured.len();
        measured_pool.extend(r.measured);
        rounds.push(RoundStats {
            round: 0,
            best_latency_s: top[0].1,
            best_energy_j: best_energy,
            snr_db: r.snr,
            relerr: r.relerr,
            k: kctrl.k,
            n_measured,
            elapsed_s: meter.clock.total_s,
        });
    } else {
        // Cold round 0 (the paper's flow): measure all M.
        let feats: Vec<FeatureVector> = top
            .iter()
            .map(|(s, _)| featurize(&Candidate::new(workload, *s), &spec))
            .collect();
        let mut samples: Vec<(FeatureVector, f64)> = Vec::new();
        let mut measured: Vec<EvaluatedKernel> = Vec::new();
        for ((s, _), fv) in top.iter().zip(&feats) {
            let m = meter.measure(&Candidate::new(workload, *s), &mut rng);
            samples.push((fv.clone(), m.energy_j));
            measured.push(EvaluatedKernel {
                schedule: *s,
                latency_s: m.latency_s,
                energy_j: m.energy_j,
                avg_power_w: m.avg_power_w,
                energy_measured: true,
            });
        }
        if use_model {
            model.update(&samples, &mut rng);
            meter.clock.charge_model_train(
                MODEL_TRAIN_BASE_S + MODEL_TRAIN_PER_SAMPLE_S * model.n_samples() as f64,
            );
        }
        // Parents: top 50% lowest measured energy.
        let mut by_energy = measured.clone();
        by_energy.sort_by(|a, b| a.energy_j.partial_cmp(&b.energy_j).expect("finite"));
        parents = by_energy
            .iter()
            .take((cfg.m_latency_keep / 2).max(1))
            .map(|e| e.schedule)
            .collect();
        best_energy = by_energy.first().map(|e| e.energy_j).unwrap_or(f64::INFINITY);
        measured_pool.extend(measured);
        rounds.push(RoundStats {
            round: 0,
            best_latency_s: top[0].1,
            best_energy_j: best_energy,
            snr_db: None,
            relerr: None,
            k: kctrl.k,
            n_measured: top.len(),
            elapsed_s: meter.clock.total_s,
        });
    }

    // ---- Algorithm 1 rounds ---------------------------------------------
    for round in 1..cfg.rounds {
        // Reproduce a new kernel generation with parent kernels.
        let generation = super::genetic::reproduce(&space, &parents, cfg, &mut rng);

        // Get the latency of kernels and pick the fastest M ones.
        let kernel_m =
            latency_eva_and_pick(workload, &generation, cfg.m_latency_keep, &mut meter, &mut rng);

        if let Some(&(s, l)) = kernel_m.first() {
            if fastest_seen.map_or(true, |(_, fl)| l < fl) {
                fastest_seen = Some((s, l));
            }
        }

        let r = model_guided_round(
            workload,
            &spec,
            cfg,
            &kernel_m,
            use_model,
            None,
            &mut model,
            &mut kctrl,
            &mut meter,
            &mut rng,
        );
        parents = r.parents;

        // Track convergence on measured energy.
        let round_best = r.measured.iter().map(|e| e.energy_j).fold(f64::INFINITY, f64::min);
        if round_best < best_energy * 0.999 {
            best_energy = round_best;
            stale = 0;
        } else {
            stale += 1;
        }
        measured_pool.extend(r.measured);

        rounds.push(RoundStats {
            round,
            best_latency_s: kernel_m.first().map(|k| k.1).unwrap_or(f64::NAN),
            best_energy_j: best_energy,
            snr_db: r.snr,
            relerr: r.relerr,
            k: kctrl.k,
            n_measured: r.n_measured,
            elapsed_s: meter.clock.total_s,
        });

        if cfg.patience > 0 && stale >= cfg.patience {
            break;
        }
    }

    // Anchor the final pool on the fastest schedule seen anywhere in the
    // search (it may never have been energy-measured if the model ranked
    // it poorly): one extra measurement keeps the latency band honest.
    if let Some((s, _)) = fastest_seen {
        if !measured_pool.iter().any(|e| e.schedule == s) {
            let m = meter.measure(&Candidate::new(workload, s), &mut rng);
            measured_pool.push(EvaluatedKernel {
                schedule: s,
                latency_s: m.latency_s,
                energy_j: m.energy_j,
                avg_power_w: m.avg_power_w,
                energy_measured: true,
            });
        }
    }
    let best = select_final(&measured_pool);
    let n_latency_evals = meter.clock.n_latency_timings;
    let model_snapshot = if use_model { model.snapshot() } else { None };
    SearchOutcome {
        workload,
        mode: if use_model { SearchMode::EnergyAware } else { SearchMode::EnergyNvmlOnly },
        best,
        rounds,
        clock: meter.clock,
        measured_pool,
        k_trace: kctrl.trace,
        n_latency_evals,
        model: model_snapshot,
    }
}

/// Outcome of one model-guided round over the `M` fastest kernels.
struct ModelRound {
    /// Parent schedules for the next generation.
    parents: Vec<Schedule>,
    /// Kernels NVML-measured this round (calibration kernel first on
    /// the warm round), in measurement order.
    measured: Vec<EvaluatedKernel>,
    /// SNR of this round's prediction check, when computed.
    snr: Option<f64>,
    /// Mean relative energy prediction error of the same check set,
    /// computed whenever `snr` is.
    relerr: Option<f64>,
    /// Measured-count to report in [`RoundStats`].
    n_measured: usize,
}

/// The round protocol shared by the warm round 0 and every later round
/// (steps 3–7 of Algorithm 1): model-rank the `M` fastest, NVML-measure
/// the best `k·M`, fold the measurements into the model, check SNR and
/// adjust `k`, then pick the next round's parents.
///
/// `cal` is the warm round's already-measured calibration kernel
/// (always `kernel_m[0]`, the fastest): its measurement counts against
/// the `k·M` budget, its prediction stays OUT of the SNR arrays (the
/// model was just fit on that exact point — an in-sample prediction
/// would flatter the SNR precisely when the transfer is bad), and the
/// parent selection reuses the ranking predictions instead of
/// re-predicting with the just-calibrated model.
#[allow(clippy::too_many_arguments)]
fn model_guided_round(
    workload: Workload,
    spec: &crate::config::GpuSpec,
    cfg: &SearchConfig,
    kernel_m: &[(Schedule, f64)],
    use_model: bool,
    cal: Option<&EvaluatedKernel>,
    model: &mut EnergyCostModel,
    kctrl: &mut KController,
    meter: &mut NvmlMeter,
    rng: &mut Rng,
) -> ModelRound {
    let feats: Vec<FeatureVector> = kernel_m
        .iter()
        .map(|(s, _)| featurize(&Candidate::new(workload, *s), spec))
        .collect();
    // Static prior (ISSUE 9): closed-form energy estimates that stand
    // in for the model until its first fit — a trained model ignores
    // them, so the cold-path fold stays byte-identical.
    let scheds: Vec<Schedule> = kernel_m.iter().map(|(s, _)| *s).collect();
    let priors = crate::analysis::static_energy_priors(&workload, &scheds, spec);

    // Evaluate the M kernels with the cost model; pick the most
    // energy-efficient k*M and their predicted energy.
    let (order, predicted): (Vec<usize>, Vec<f64>) = if use_model {
        let pred = model.predict_energy_batch_with_prior(&feats, &priors);
        meter.clock.charge_model_predict(
            MODEL_PREDICT_BASE_S + MODEL_PREDICT_PER_KERNEL_S * feats.len() as f64,
        );
        let mut idx: Vec<usize> = (0..kernel_m.len()).collect();
        idx.sort_by(|&a, &b| pred[a].partial_cmp(&pred[b]).expect("finite"));
        (idx, pred)
    } else {
        ((0..kernel_m.len()).collect(), vec![f64::NAN; kernel_m.len()])
    };
    let n_measure = if use_model { kctrl.n_measure(kernel_m.len()) } else { kernel_m.len() };
    let chosen: Vec<usize> = if cal.is_some() {
        // The calibration kernel (index 0) already has its measurement:
        // spend the rest of the round's budget on distinct kernels.
        order.iter().filter(|&&i| i != 0).take(n_measure.saturating_sub(1)).copied().collect()
    } else {
        order.iter().take(n_measure).copied().collect()
    };

    // NVML-measure the chosen kernels.
    let mut measured_pred: Vec<f64> = Vec::with_capacity(chosen.len());
    let mut measured_vals: Vec<f64> = Vec::with_capacity(chosen.len());
    let mut samples: Vec<(FeatureVector, f64)> = Vec::new();
    let mut round_measured: Vec<EvaluatedKernel> = Vec::new();
    for &i in &chosen {
        let (s, _) = kernel_m[i];
        let m = meter.measure(&Candidate::new(workload, s), rng);
        measured_pred.push(predicted[i]);
        measured_vals.push(m.energy_j);
        samples.push((feats[i].clone(), m.energy_j));
        round_measured.push(EvaluatedKernel {
            schedule: s,
            latency_s: m.latency_s,
            energy_j: m.energy_j,
            avg_power_w: m.avg_power_w,
            energy_measured: true,
        });
    }

    // Update the cost model with the measured kernels; compute SNR and
    // adjust k.
    let mut snr = None;
    let mut relerr = None;
    if use_model {
        if !samples.is_empty() {
            model.update(&samples, rng);
            meter.clock.charge_model_train(
                MODEL_TRAIN_BASE_S + MODEL_TRAIN_PER_SAMPLE_S * model.n_samples() as f64,
            );
        }
        if measured_vals.len() >= 2 && measured_pred.iter().all(|p| p.is_finite()) {
            let s = EnergyCostModel::snr_error_db(&measured_pred, &measured_vals);
            kctrl.update(s);
            snr = Some(s);
            // Accuracy telemetry (ISSUE 7): the same pred/measured
            // pairs the SNR check uses, as a unitless relative error
            // operators can alert on without knowing the SNR scale.
            let (sum, n) = measured_pred
                .iter()
                .zip(&measured_vals)
                .filter(|(_, &v)| v > 0.0 && v.is_finite())
                .fold((0.0f64, 0usize), |(sum, n), (&p, &v)| (sum + (p - v).abs() / v, n + 1));
            if n > 0 {
                relerr = Some(sum / n as f64);
            }
        }
    }

    // Select top 50% lower-energy kernels for the next round; measured
    // values override predictions where available.
    let energies: Vec<f64> = match cal {
        Some(c) => {
            let mut e = predicted;
            e[0] = c.energy_j;
            for (&i, &v) in chosen.iter().zip(&measured_vals) {
                e[i] = v;
            }
            e
        }
        None if use_model => {
            let pred = model.predict_energy_batch_with_prior(&feats, &priors);
            meter.clock.charge_model_predict(
                MODEL_PREDICT_BASE_S + MODEL_PREDICT_PER_KERNEL_S * feats.len() as f64,
            );
            let mut e = pred;
            for (&i, &v) in chosen.iter().zip(&measured_vals) {
                e[i] = v;
            }
            e
        }
        None => measured_vals.clone(),
    };
    let mut idx: Vec<usize> = (0..energies.len()).collect();
    idx.sort_by(|&a, &b| energies[a].partial_cmp(&energies[b]).expect("finite"));
    let mut parents: Vec<Schedule> = idx
        .iter()
        .take((cfg.m_latency_keep / 2).max(1))
        .map(|&i| kernel_m[i.min(kernel_m.len() - 1)].0)
        .collect();
    // §4.4: parents must keep "good latency AND low energy" — pin the
    // two fastest kernels of the round into the parent set so the
    // latency frontier never regresses while energy evolves.
    for (s, _) in kernel_m.iter().take(2) {
        if !parents.contains(s) {
            parents.push(*s);
        }
    }

    let n_measured = if cal.is_some() { round_measured.len() + 1 } else { n_measure };
    let mut measured = Vec::with_capacity(round_measured.len() + 1);
    if let Some(c) = cal {
        measured.push(*c);
    }
    measured.extend(round_measured);
    ModelRound { parents, measured, snr, relerr, n_measured }
}

/// Merge transferred seed schedules into the head of the initial
/// population (dedup, capped at half the population so random
/// exploration keeps its share).
fn inject_seeds(pop: &mut Vec<Schedule>, seeds: &[Schedule], population: usize) {
    if seeds.is_empty() || pop.is_empty() {
        return;
    }
    let n_seed = seeds.len().min((population / 2).max(1));
    let mut seen: HashSet<Schedule> = HashSet::new();
    let mut merged: Vec<Schedule> = Vec::with_capacity(population);
    for s in seeds.iter().take(n_seed).chain(pop.iter()) {
        if merged.len() == population {
            break;
        }
        if seen.insert(*s) {
            merged.push(*s);
        }
    }
    // Tiny/saturated spaces: refill with (possibly duplicate) originals.
    let mut i = 0;
    while merged.len() < population {
        merged.push(pop[i % pop.len()]);
        i += 1;
    }
    *pop = merged;
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::GpuArch;
    use crate::workload::suites;

    fn quick_cfg(seed: u64) -> SearchConfig {
        SearchConfig {
            gpu: GpuArch::A100,
            mode: SearchMode::EnergyAware,
            population: 48,
            m_latency_keep: 12,
            rounds: 6,
            patience: 0,
            seed,
            ..Default::default()
        }
    }

    #[test]
    fn energy_improves_across_rounds() {
        let out = run(suites::MM1, &quick_cfg(3), true);
        let first = out.rounds.first().unwrap().best_energy_j;
        let last = out.rounds.last().unwrap().best_energy_j;
        assert!(last <= first, "{last} > {first}");
        assert!(out.best.energy_measured);
    }

    #[test]
    fn k_adapts_and_reduces_measurements() {
        let out = run(suites::MM1, &quick_cfg(4), true);
        assert!(!out.k_trace.is_empty());
        // Once the model locks on, k should drop below its initial 1.0
        // in at least one round.
        assert!(
            out.k_trace.iter().any(|&k| k < 1.0),
            "k never dropped: {:?}",
            out.k_trace
        );
        // And measured count per round must track k*M.
        let m = 12.0;
        for r in &out.rounds[1..] {
            assert!(r.n_measured as f64 <= m + 1e-9);
        }
    }

    #[test]
    fn nvml_only_measures_everything() {
        let cfg = quick_cfg(5);
        let ours = run(suites::MM1, &cfg, true);
        let nvml = run(suites::MM1, &cfg, false);
        assert!(
            nvml.n_energy_measurements() > ours.n_energy_measurements(),
            "nvml {} !> ours {}",
            nvml.n_energy_measurements(),
            ours.n_energy_measurements()
        );
        // Fig. 5: the cost-model search must be decisively faster.
        assert!(
            ours.clock.total_s < nvml.clock.total_s,
            "ours {} !< nvml {}",
            ours.clock.total_s,
            nvml.clock.total_s
        );
    }

    #[test]
    fn deterministic_given_seed() {
        let cfg = quick_cfg(6);
        let a = run(suites::CONV2, &cfg, true);
        let b = run(suites::CONV2, &cfg, true);
        assert_eq!(a.best.schedule, b.best.schedule);
        assert_eq!(a.k_trace, b.k_trace);
    }

    /// Determinism pin for the folded cold path: two runs must agree on
    /// the FULL outcome structure — every round stat, the whole
    /// measured pool, the k trace, and the complete measurement clock —
    /// not just the winning schedule. (`run` is a thin delegate to
    /// `run_warm(.., None)`, so this cannot compare against the
    /// pre-fold implementation; together with the behavioral tests
    /// above it pins what the fold is allowed to produce.)
    #[test]
    fn cold_path_fold_is_fully_deterministic() {
        for (w, use_model) in [(suites::MM1, true), (suites::MV3, true), (suites::MM1, false)] {
            let cfg = quick_cfg(14);
            let a = run_warm(w, &cfg, use_model, None);
            let b = run_warm(w, &cfg, use_model, None);
            assert_eq!(a.best, b.best);
            assert_eq!(a.rounds, b.rounds);
            assert_eq!(a.measured_pool, b.measured_pool);
            assert_eq!(a.k_trace, b.k_trace);
            assert_eq!(a.n_latency_evals, b.n_latency_evals);
            assert_eq!(a.clock, b.clock, "identical simulated cost accounting");
        }
    }

    #[test]
    fn persisted_model_snapshot_skips_the_first_fit() {
        let cfg = quick_cfg(9);
        let cold = run(suites::MM1, &cfg, true);
        let snap = cold.model.clone().expect("energy-aware search persists its model");

        let spec = cfg.gpu.spec();
        let samples: Vec<(FeatureVector, f64)> = cold
            .measured_pool
            .iter()
            .map(|e| (featurize(&Candidate::new(suites::MM1, e.schedule), &spec), e.energy_j))
            .collect();
        let warm = WarmStart {
            seed_schedules: cold.measured_pool.iter().map(|e| e.schedule).take(8).collect(),
            seed_samples: samples,
            k_hint: Some(0.4),
            n_neighbors: 1,
            model: Some(snap),
        };
        let out = run_warm(suites::MM1, &cfg, true, Some(&warm));
        // The transferred trees replace the first fit: round 0 is still
        // model-guided (k·M budget, not all M)...
        assert!(out.rounds[0].n_measured < cfg.m_latency_keep);
        // ...and no training time is charged before the calibration
        // refit, so total model-training time is strictly below a warm
        // start that must fit the transferred samples first.
        let warm_refit = WarmStart { model: None, ..warm };
        let refit_out = run_warm(suites::MM1, &cfg, true, Some(&warm_refit));
        assert!(
            out.clock.model_train_s < refit_out.clock.model_train_s,
            "snapshot {} !< refit {}",
            out.clock.model_train_s,
            refit_out.clock.model_train_s
        );
        assert!(out.best.energy_measured && out.best.energy_j.is_finite());
    }

    #[test]
    fn inject_seeds_caps_and_keeps_population_size() {
        let spec = GpuArch::A100.spec();
        let space = crate::schedule::space::ScheduleSpace::new(suites::MM1, &spec);
        let mut rng = crate::util::Rng::seed_from_u64(21);
        let mut pop = super::super::population::init_population(&space, 32, &mut rng);
        let seeds = space.sample_n(&mut rng, 40);
        inject_seeds(&mut pop, &seeds, 32);
        assert_eq!(pop.len(), 32);
        // At most half the population comes from seeds; the head is
        // seed-first.
        let seed_set: HashSet<Schedule> = seeds.iter().copied().collect();
        let n_from_seeds = pop.iter().filter(|s| seed_set.contains(s)).count();
        assert!(n_from_seeds >= 1);
        assert!(pop.iter().any(|s| !seed_set.contains(s)), "random share survives");
    }

    #[test]
    fn warm_start_measures_less_in_round0_and_overall() {
        let cfg = quick_cfg(8);
        let cold = run(suites::MM1, &cfg, true);
        // Fabricate a warm start from the cold run's own measured pool —
        // the best-case transfer (same workload), isolating the
        // mechanism from neighbor-similarity effects.
        let spec = cfg.gpu.spec();
        let samples: Vec<(FeatureVector, f64)> = cold
            .measured_pool
            .iter()
            .map(|e| (featurize(&Candidate::new(suites::MM1, e.schedule), &spec), e.energy_j))
            .collect();
        let seeds: Vec<Schedule> =
            cold.measured_pool.iter().map(|e| e.schedule).take(8).collect();
        let warm = WarmStart {
            seed_schedules: seeds,
            seed_samples: samples,
            k_hint: Some(0.4),
            n_neighbors: 1,
            model: None,
        };
        let warm_out = run_warm(suites::MM1, &cfg, true, Some(&warm));
        // Round 0 cold measures all M = 12; warm spends ceil(0.4*12) = 5
        // total (1 calibration + 4 model-chosen kernels).
        assert_eq!(cold.rounds[0].n_measured, 12);
        assert!(
            warm_out.rounds[0].n_measured <= 5,
            "warm round 0 measured {}",
            warm_out.rounds[0].n_measured
        );
        assert!(
            warm_out.n_energy_measurements() < cold.n_energy_measurements(),
            "warm {} !< cold {}",
            warm_out.n_energy_measurements(),
            cold.n_energy_measurements()
        );
        // And the warm search still ends with a measured, finite winner.
        assert!(warm_out.best.energy_measured);
        assert!(warm_out.best.energy_j.is_finite());
    }

    #[test]
    fn beats_or_matches_latency_only_on_energy() {
        // The headline claim (Table 2): same latency class, less energy.
        let cfg = quick_cfg(7);
        let ours = run(suites::MM1, &cfg, true);
        let mut lat_cfg = cfg.clone();
        lat_cfg.mode = SearchMode::LatencyOnly;
        let ansor = crate::search::latency_only::run(suites::MM1, &lat_cfg);
        assert!(
            ours.best.energy_j <= ansor.best.energy_j * 1.02,
            "ours {} mJ vs ansor {} mJ",
            ours.best.energy_j * 1e3,
            ansor.best.energy_j * 1e3
        );
        // Latency stays in the same class (within ~20% on this tiny run).
        assert!(ours.best.latency_s <= ansor.best.latency_s * 1.25);
    }
}
