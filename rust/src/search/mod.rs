//! The search framework (§4, §6): latency-only baseline (Ansor),
//! the paper's energy-aware search with the dynamic-k cost-model
//! strategy (Algorithm 1), and the NVML-only ablation of Fig. 5.

pub mod dynamic_k;
pub mod energy_aware;
pub mod genetic;
pub mod latency_only;
pub mod population;

pub use dynamic_k::KController;

use crate::config::{SearchConfig, SearchMode};
use crate::nvml::{MeasurementClock, NvmlMeter};
use crate::schedule::{Candidate, Schedule};
use crate::util::Rng;
use crate::workload::Workload;

/// Latency tolerance for final kernel selection: among measured
/// kernels, those within this fraction of the best latency compete on
/// energy (§4.3: energy must not trade away latency).
pub const FINAL_LATENCY_TOL: f64 = 0.08;

/// Simulated cost charged per cost-model batch prediction (§7.4: "the
/// cost model predicts kernel times in milliseconds").
pub const MODEL_PREDICT_BASE_S: f64 = 1e-3;
/// Additional per-kernel prediction cost.
pub const MODEL_PREDICT_PER_KERNEL_S: f64 = 2e-5;
/// Simulated cost per model (re)fit, plus per-sample term.
pub const MODEL_TRAIN_BASE_S: f64 = 0.08;
pub const MODEL_TRAIN_PER_SAMPLE_S: f64 = 2e-4;

/// A schedule with its evaluated metrics.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct EvaluatedKernel {
    pub schedule: Schedule,
    /// Latency of one run (s) — NVML-timed (noisy).
    pub latency_s: f64,
    /// Energy of one run (J).
    pub energy_j: f64,
    /// Average power (W).
    pub avg_power_w: f64,
    /// True if `energy_j` came from an NVML measurement (vs cost model).
    pub energy_measured: bool,
}

/// Per-round telemetry.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct RoundStats {
    pub round: usize,
    pub best_latency_s: f64,
    pub best_energy_j: f64,
    /// SNR prediction error of this round's model check (dB).
    pub snr_db: Option<f64>,
    /// Mean relative error |predicted − measured| / measured of the
    /// round's energy predictions over the measured check set —
    /// computed alongside `snr_db` from the same pairs, so both are
    /// `Some`/`None` together (model-guided rounds with ≥ 2 finite
    /// check pairs).
    pub relerr: Option<f64>,
    /// k value *after* this round's update.
    pub k: f64,
    pub n_measured: usize,
    /// Cumulative simulated search time (s).
    pub elapsed_s: f64,
}

/// Result of one search run.
#[derive(Debug, Clone)]
pub struct SearchOutcome {
    pub workload: Workload,
    pub mode: SearchMode,
    /// The selected kernel (metrics NVML-measured).
    pub best: EvaluatedKernel,
    pub rounds: Vec<RoundStats>,
    /// Simulated wall-clock accounting (the Fig. 5 currency).
    pub clock: MeasurementClock,
    /// Every NVML-measured kernel seen during the search (Fig. 2 data).
    pub measured_pool: Vec<EvaluatedKernel>,
    /// k trace across rounds (energy-aware mode only).
    pub k_trace: Vec<f64>,
    /// Total kernels whose latency was timed.
    pub n_latency_evals: usize,
    /// Final fitted cost model (energy modes only) — persisted by the
    /// tuning store so warm starts can skip the first fit.
    pub model: Option<crate::costmodel::CostModelSnapshot>,
}

impl SearchOutcome {
    /// Total NVML energy measurements performed.
    pub fn n_energy_measurements(&self) -> usize {
        self.clock.n_energy_measurements
    }

    /// True when this outcome is a tuning-store replay rather than an
    /// executed search: a real search always runs at least one round
    /// and charges the clock; a cache hit does neither.
    pub fn is_cache_replay(&self) -> bool {
        self.rounds.is_empty() && self.clock.total_s == 0.0
    }
}

/// Run a search in the mode chosen by `cfg.mode`.
///
/// When `cfg.store.dir` is set, the search goes through the persistent
/// tuning store: an exact cache hit returns the recorded kernel with a
/// zero clock, an unseen workload warm-starts from its nearest cached
/// neighbors, and the finished outcome is written back. With no store
/// configured this is the stateless paper flow.
pub fn run_search(workload: Workload, cfg: &SearchConfig) -> SearchOutcome {
    cfg.validate().expect("invalid search config");
    if let Some(dir) = cfg.store.dir.as_deref() {
        match crate::store::TuningStore::open(std::path::Path::new(dir)) {
            Ok(mut store) => return run_search_with_store(workload, cfg, &mut store),
            Err(e) => {
                // An unreadable/corrupt store must not brick the search:
                // run stateless (and skip write-back into the bad store).
                eprintln!("warning: tuning store disabled: {e:#}");
            }
        }
    }
    run_search_stateless(workload, cfg)
}

fn run_search_stateless(workload: Workload, cfg: &SearchConfig) -> SearchOutcome {
    dispatch(workload, cfg, None)
}

fn dispatch(
    workload: Workload,
    cfg: &SearchConfig,
    warm: Option<&crate::store::WarmStart>,
) -> SearchOutcome {
    match cfg.mode {
        SearchMode::LatencyOnly => latency_only::run(workload, cfg),
        SearchMode::EnergyAware => energy_aware::run_warm(workload, cfg, true, warm),
        SearchMode::EnergyNvmlOnly => energy_aware::run_warm(workload, cfg, false, warm),
    }
}

fn build_warm(
    workload: Workload,
    cfg: &SearchConfig,
    store: &crate::store::TuningStore,
) -> Option<crate::store::WarmStart> {
    if cfg.store.transfer && cfg.mode != SearchMode::LatencyOnly {
        crate::store::transfer::build(store, workload, cfg)
    } else {
        None
    }
}

/// Run a search through an already-open tuning store: exact-hit
/// short-circuit, warm-start transfer, write-back.
pub fn run_search_with_store(
    workload: Workload,
    cfg: &SearchConfig,
    store: &mut crate::store::TuningStore,
) -> SearchOutcome {
    if let Some(rec) = store.exact_hit(workload, cfg) {
        return rec.to_outcome();
    }
    let warm = build_warm(workload, cfg, store);
    let out = dispatch(workload, cfg, warm.as_ref());
    if cfg.store.write_back {
        if let Err(e) = store.append(crate::store::TuningRecord::from_outcome(&out, cfg)) {
            eprintln!("warning: tuning store write-back failed: {e:#}");
        }
    }
    out
}

/// Run a search against a **shared, read-only snapshot** of the tuning
/// store (ROADMAP "Store parse-once plumbing"): the worker pool parses
/// the store once per suite and every job consults the same snapshot
/// instead of re-reading the whole JSONL file. Write-back appends
/// straight to the store file (O_APPEND, concurrent-safe) without
/// touching the snapshot — hits reflect the store as of snapshot time.
pub fn run_search_with_snapshot(
    workload: Workload,
    cfg: &SearchConfig,
    snapshot: &crate::store::TuningStore,
) -> SearchOutcome {
    cfg.validate().expect("invalid search config");
    if let Some(rec) = snapshot.exact_hit(workload, cfg) {
        return rec.to_outcome();
    }
    let warm = build_warm(workload, cfg, snapshot);
    let out = dispatch(workload, cfg, warm.as_ref());
    if cfg.store.write_back {
        if let Some(dir) = cfg.store.dir.as_deref() {
            let rec = crate::store::TuningRecord::from_outcome(&out, cfg);
            if let Err(e) = crate::store::append_record(std::path::Path::new(dir), &rec) {
                eprintln!("warning: tuning store write-back failed: {e:#}");
            }
        }
    }
    out
}

/// Time the latency of every schedule in `gen` with noisy NVML timing,
/// charging the measurement clock per candidate.
///
/// Returns (schedule, timed latency) pairs sorted ascending by latency.
pub fn latency_eva_and_pick(
    workload: Workload,
    gen: &[Schedule],
    m: usize,
    meter: &mut NvmlMeter,
    rng: &mut Rng,
) -> Vec<(Schedule, f64)> {
    // time_latency derives the analytic truth internally at the current
    // die temperature and charges the clock; ranking uses the timed
    // (noisy) value, as the paper does.
    let mut timed: Vec<(Schedule, f64)> = gen
        .iter()
        .map(|s| {
            let c = Candidate::new(workload, *s);
            (*s, meter.time_latency(&c, rng))
        })
        .collect();
    timed.sort_by(|a, b| a.1.partial_cmp(&b.1).expect("finite latency"));
    timed.truncate(m);
    timed
}

/// Final selection rule shared by the energy modes: among measured
/// kernels, restrict to those within `FINAL_LATENCY_TOL` of the best
/// measured latency, then take the lowest energy.
pub fn select_final(pool: &[EvaluatedKernel]) -> EvaluatedKernel {
    assert!(!pool.is_empty());
    let best_lat =
        pool.iter().map(|e| e.latency_s).fold(f64::INFINITY, f64::min);
    let cutoff = best_lat * (1.0 + FINAL_LATENCY_TOL);
    pool.iter()
        .filter(|e| e.latency_s <= cutoff)
        .min_by(|a, b| a.energy_j.partial_cmp(&b.energy_j).expect("finite"))
        .copied()
        .expect("non-empty pool within tolerance")
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::GpuArch;
    use crate::schedule::space::ScheduleSpace;
    use crate::workload::suites;

    fn ek(lat: f64, e: f64) -> EvaluatedKernel {
        let spec = GpuArch::A100.spec();
        let space = ScheduleSpace::new(suites::MM1, &spec);
        EvaluatedKernel {
            schedule: space.fallback(),
            latency_s: lat,
            energy_j: e,
            avg_power_w: e / lat,
            energy_measured: true,
        }
    }

    #[test]
    fn select_final_prefers_energy_within_latency_band() {
        let pool = vec![
            ek(1.00, 10.0), // fastest, high energy
            ek(1.05, 7.0),  // within 8% tolerance, lower energy -> winner
            ek(1.50, 2.0),  // lowest energy but too slow
        ];
        let best = select_final(&pool);
        assert!((best.latency_s - 1.05).abs() < 1e-12);
        assert!((best.energy_j - 7.0).abs() < 1e-12);
    }

    #[test]
    fn select_final_falls_back_to_fastest() {
        let pool = vec![ek(1.0, 5.0), ek(2.0, 1.0)];
        let best = select_final(&pool);
        assert_eq!(best.energy_j, 5.0);
    }

    #[test]
    fn latency_eva_sorts_and_truncates() {
        let cfg = SearchConfig::default();
        let spec = GpuArch::A100.spec();
        let space = ScheduleSpace::new(suites::MM1, &spec);
        let mut rng = Rng::seed_from_u64(9);
        let gen = space.sample_n(&mut rng, 40);
        let mut meter = NvmlMeter::warmed(spec, cfg.nvml.clone());
        let picked = latency_eva_and_pick(suites::MM1, &gen, 10, &mut meter, &mut rng);
        assert_eq!(picked.len(), 10);
        for w in picked.windows(2) {
            assert!(w[0].1 <= w[1].1, "not sorted");
        }
        assert_eq!(meter.clock.n_latency_timings, 40);
    }
}
