//! The search framework (§4, §6): latency-only baseline (Ansor),
//! the paper's energy-aware search with the dynamic-k cost-model
//! strategy (Algorithm 1), and the NVML-only ablation of Fig. 5.

pub mod dynamic_k;
pub mod energy_aware;
pub mod genetic;
pub mod latency_only;
pub mod population;

pub use dynamic_k::KController;

use crate::config::{SearchConfig, SearchMode};
use crate::nvml::{MeasurementClock, NvmlMeter};
use crate::schedule::{Candidate, Schedule};
use crate::util::parallel::par_map;
use crate::util::Rng;
use crate::workload::Workload;

/// Latency tolerance for final kernel selection: among measured
/// kernels, those within this fraction of the best latency compete on
/// energy (§4.3: energy must not trade away latency).
pub const FINAL_LATENCY_TOL: f64 = 0.08;

/// Simulated cost charged per cost-model batch prediction (§7.4: "the
/// cost model predicts kernel times in milliseconds").
pub const MODEL_PREDICT_BASE_S: f64 = 1e-3;
/// Additional per-kernel prediction cost.
pub const MODEL_PREDICT_PER_KERNEL_S: f64 = 2e-5;
/// Simulated cost per model (re)fit, plus per-sample term.
pub const MODEL_TRAIN_BASE_S: f64 = 0.08;
pub const MODEL_TRAIN_PER_SAMPLE_S: f64 = 2e-4;

/// A schedule with its evaluated metrics.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct EvaluatedKernel {
    pub schedule: Schedule,
    /// Latency of one run (s) — NVML-timed (noisy).
    pub latency_s: f64,
    /// Energy of one run (J).
    pub energy_j: f64,
    /// Average power (W).
    pub avg_power_w: f64,
    /// True if `energy_j` came from an NVML measurement (vs cost model).
    pub energy_measured: bool,
}

/// Per-round telemetry.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct RoundStats {
    pub round: usize,
    pub best_latency_s: f64,
    pub best_energy_j: f64,
    /// SNR prediction error of this round's model check (dB).
    pub snr_db: Option<f64>,
    /// k value *after* this round's update.
    pub k: f64,
    pub n_measured: usize,
    /// Cumulative simulated search time (s).
    pub elapsed_s: f64,
}

/// Result of one search run.
#[derive(Debug, Clone)]
pub struct SearchOutcome {
    pub workload: Workload,
    pub mode: SearchMode,
    /// The selected kernel (metrics NVML-measured).
    pub best: EvaluatedKernel,
    pub rounds: Vec<RoundStats>,
    /// Simulated wall-clock accounting (the Fig. 5 currency).
    pub clock: MeasurementClock,
    /// Every NVML-measured kernel seen during the search (Fig. 2 data).
    pub measured_pool: Vec<EvaluatedKernel>,
    /// k trace across rounds (energy-aware mode only).
    pub k_trace: Vec<f64>,
    /// Total kernels whose latency was timed.
    pub n_latency_evals: usize,
}

impl SearchOutcome {
    /// Total NVML energy measurements performed.
    pub fn n_energy_measurements(&self) -> usize {
        self.clock.n_energy_measurements
    }
}

/// Run a search in the mode chosen by `cfg.mode`.
pub fn run_search(workload: Workload, cfg: &SearchConfig) -> SearchOutcome {
    cfg.validate().expect("invalid search config");
    match cfg.mode {
        SearchMode::LatencyOnly => latency_only::run(workload, cfg),
        SearchMode::EnergyAware => energy_aware::run(workload, cfg, true),
        SearchMode::EnergyNvmlOnly => energy_aware::run(workload, cfg, false),
    }
}

/// Time the latency of every schedule in `gen` (noisy NVML timing for
/// the charged clock + deterministic simulator ranking in parallel).
///
/// Returns (schedule, timed latency) pairs sorted ascending by latency.
pub fn latency_eva_and_pick(
    workload: Workload,
    gen: &[Schedule],
    m: usize,
    meter: &mut NvmlMeter,
    rng: &mut Rng,
) -> Vec<(Schedule, f64)> {
    // Deterministic part (the analytic model) evaluates in parallel;
    // the noise + clock charge is applied serially for determinism.
    let spec = meter.spec().clone();
    let g = workload.gemm_view();
    let truths: Vec<f64> =
        par_map(gen, |s| crate::sim::evaluate_latency(&g, s, &spec));
    let mut timed: Vec<(Schedule, f64)> = gen
        .iter()
        .zip(&truths)
        .map(|(s, &truth)| {
            let c = Candidate::new(workload, *s);
            // time_latency re-derives truth internally at the current
            // temperature; we charge the clock through it.
            let t = meter.time_latency(&c, rng);
            // Blend: meter returns noisy truth (temperature-adjusted);
            // `truth` keeps ranking deterministic-ish but we use the
            // timed value, as the paper does.
            let _ = truth;
            (*s, t)
        })
        .collect();
    timed.sort_by(|a, b| a.1.partial_cmp(&b.1).expect("finite latency"));
    timed.truncate(m);
    timed
}

/// Final selection rule shared by the energy modes: among measured
/// kernels, restrict to those within `FINAL_LATENCY_TOL` of the best
/// measured latency, then take the lowest energy.
pub fn select_final(pool: &[EvaluatedKernel]) -> EvaluatedKernel {
    assert!(!pool.is_empty());
    let best_lat =
        pool.iter().map(|e| e.latency_s).fold(f64::INFINITY, f64::min);
    let cutoff = best_lat * (1.0 + FINAL_LATENCY_TOL);
    pool.iter()
        .filter(|e| e.latency_s <= cutoff)
        .min_by(|a, b| a.energy_j.partial_cmp(&b.energy_j).expect("finite"))
        .copied()
        .expect("non-empty pool within tolerance")
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::GpuArch;
    use crate::schedule::space::ScheduleSpace;
    use crate::workload::suites;

    fn ek(lat: f64, e: f64) -> EvaluatedKernel {
        let spec = GpuArch::A100.spec();
        let space = ScheduleSpace::new(suites::MM1, &spec);
        EvaluatedKernel {
            schedule: space.fallback(),
            latency_s: lat,
            energy_j: e,
            avg_power_w: e / lat,
            energy_measured: true,
        }
    }

    #[test]
    fn select_final_prefers_energy_within_latency_band() {
        let pool = vec![
            ek(1.00, 10.0), // fastest, high energy
            ek(1.05, 7.0),  // within 8% tolerance, lower energy -> winner
            ek(1.50, 2.0),  // lowest energy but too slow
        ];
        let best = select_final(&pool);
        assert!((best.latency_s - 1.05).abs() < 1e-12);
        assert!((best.energy_j - 7.0).abs() < 1e-12);
    }

    #[test]
    fn select_final_falls_back_to_fastest() {
        let pool = vec![ek(1.0, 5.0), ek(2.0, 1.0)];
        let best = select_final(&pool);
        assert_eq!(best.energy_j, 5.0);
    }

    #[test]
    fn latency_eva_sorts_and_truncates() {
        let cfg = SearchConfig::default();
        let spec = GpuArch::A100.spec();
        let space = ScheduleSpace::new(suites::MM1, &spec);
        let mut rng = Rng::seed_from_u64(9);
        let gen = space.sample_n(&mut rng, 40);
        let mut meter = NvmlMeter::warmed(spec, cfg.nvml.clone());
        let picked = latency_eva_and_pick(suites::MM1, &gen, 10, &mut meter, &mut rng);
        assert_eq!(picked.len(), 10);
        for w in picked.windows(2) {
            assert!(w[0].1 <= w[1].1, "not sorted");
        }
        assert_eq!(meter.clock.n_latency_timings, 40);
    }
}
