//! `GeneticReproduction` (Algorithm 1, first step): produce a new
//! generation from parent kernels via crossover + mutation, topped up
//! with random immigrants for diversity.

use crate::config::SearchConfig;
use crate::schedule::mutation::{crossover, mutate, mutate_one};
use crate::schedule::space::ScheduleSpace;
use crate::schedule::Schedule;
use crate::util::Rng;
use std::collections::HashSet;

/// Reproduce a generation of `cfg.population` schedules from `parents`.
///
/// Children are produced by (crossover with prob `crossover_prob`, else
/// clone a parent) followed by per-knob mutation with prob
/// `mutation_prob`; `immigrant_frac` of the generation is fresh random
/// samples. Elites (the parents themselves) are always included so the
/// best-so-far never regresses.
pub fn reproduce(
    space: &ScheduleSpace,
    parents: &[Schedule],
    cfg: &SearchConfig,
    rng: &mut Rng,
) -> Vec<Schedule> {
    assert!(!parents.is_empty(), "reproduce needs parents");
    let n = cfg.population;
    let mut seen: HashSet<Schedule> = HashSet::new();
    let mut out: Vec<Schedule> = Vec::with_capacity(n);

    // Elitism: carry parents through unchanged.
    for p in parents.iter().take(n) {
        if seen.insert(*p) {
            out.push(*p);
        }
    }

    let n_immigrants = ((n as f64) * cfg.immigrant_frac).round() as usize;
    let mut attempts = 0usize;
    while out.len() < n && attempts < n * 60 {
        attempts += 1;
        let child = if out.len() + n_immigrants >= n {
            // Immigrant tail: fresh random exploration.
            space.sample(rng)
        } else {
            let a = rng.choose(parents);
            let base = if parents.len() >= 2 && rng.gen_bool(cfg.crossover_prob) {
                let mut b = rng.choose(parents);
                // Avoid self-crossover when possible.
                for _ in 0..4 {
                    if b != a {
                        break;
                    }
                    b = rng.choose(parents);
                }
                crossover(space, a, b, rng)
            } else {
                *a
            };
            let mutated = mutate(space, &base, cfg.mutation_prob, rng);
            if mutated == base {
                mutate_one(space, &base, rng)
            } else {
                mutated
            }
        };
        if seen.insert(child) {
            out.push(child);
        }
    }
    // Small/saturated spaces: fill with (possibly duplicate) samples.
    while out.len() < n {
        out.push(space.sample(rng));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::GpuArch;
    use crate::search::population::init_population;
    use crate::workload::suites;

    fn setup() -> (ScheduleSpace, SearchConfig, Rng) {
        let cfg = SearchConfig::default();
        let spec = GpuArch::A100.spec();
        (ScheduleSpace::new(suites::MM1, &spec), cfg, Rng::seed_from_u64(3))
    }

    #[test]
    fn generation_has_requested_size_and_legal() {
        let (space, cfg, mut rng) = setup();
        let parents = init_population(&space, 16, &mut rng);
        let gen = reproduce(&space, &parents, &cfg, &mut rng);
        assert_eq!(gen.len(), cfg.population);
        assert!(gen.iter().all(|s| space.is_legal(s)));
    }

    #[test]
    fn elites_survive() {
        let (space, cfg, mut rng) = setup();
        let parents = init_population(&space, 16, &mut rng);
        let gen = reproduce(&space, &parents, &cfg, &mut rng);
        for p in &parents {
            assert!(gen.contains(p), "parent lost: {p}");
        }
    }

    #[test]
    fn generation_is_mostly_novel() {
        let (space, cfg, mut rng) = setup();
        let parents = init_population(&space, 16, &mut rng);
        let gen = reproduce(&space, &parents, &cfg, &mut rng);
        let parent_set: std::collections::HashSet<_> = parents.iter().collect();
        let novel = gen.iter().filter(|s| !parent_set.contains(s)).count();
        assert!(novel >= cfg.population - parents.len() - 4, "novel={novel}");
    }

    #[test]
    fn single_parent_works() {
        let (space, cfg, mut rng) = setup();
        let parents = vec![space.fallback()];
        let gen = reproduce(&space, &parents, &cfg, &mut rng);
        assert_eq!(gen.len(), cfg.population);
    }

    #[test]
    fn deterministic_given_seed() {
        let (space, cfg, _) = setup();
        let parents = init_population(&space, 8, &mut Rng::seed_from_u64(11));
        let a = reproduce(&space, &parents, &cfg, &mut Rng::seed_from_u64(12));
        let b = reproduce(&space, &parents, &cfg, &mut Rng::seed_from_u64(12));
        assert_eq!(a, b);
    }
}
