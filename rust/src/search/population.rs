//! Population initialization: random sketch sampling with dedup.

use crate::schedule::space::ScheduleSpace;
use crate::schedule::Schedule;
use crate::util::Rng;
use std::collections::HashSet;

/// Sample an initial population of `n` *distinct* legal schedules
/// (falls back to allowing duplicates if the space is too small).
pub fn init_population(space: &ScheduleSpace, n: usize, rng: &mut Rng) -> Vec<Schedule> {
    let mut seen: HashSet<Schedule> = HashSet::new();
    let mut out = Vec::with_capacity(n);
    let mut attempts = 0usize;
    while out.len() < n && attempts < n * 50 {
        let s = space.sample(rng);
        attempts += 1;
        if seen.insert(s) {
            out.push(s);
        }
    }
    // Space exhausted (tiny workloads): pad with repeats.
    while out.len() < n {
        out.push(space.sample(rng));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::GpuArch;
    use crate::workload::suites;

    #[test]
    fn population_is_distinct_and_legal() {
        let spec = GpuArch::A100.spec();
        let space = ScheduleSpace::new(suites::MM2, &spec);
        let mut rng = Rng::seed_from_u64(1);
        let pop = init_population(&space, 128, &mut rng);
        assert_eq!(pop.len(), 128);
        let distinct: std::collections::HashSet<_> = pop.iter().collect();
        assert!(distinct.len() >= 120, "only {} distinct", distinct.len());
        assert!(pop.iter().all(|s| space.is_legal(s)));
    }

    #[test]
    fn tiny_spaces_still_fill() {
        // A tiny MV shape has a small legal space; population must
        // still reach the requested size (with repeats).
        let spec = GpuArch::A100.spec();
        let w = crate::workload::Workload::MatVec { batch: 1, n: 64, k: 64 };
        let space = ScheduleSpace::new(w, &spec);
        let mut rng = Rng::seed_from_u64(2);
        let pop = init_population(&space, 64, &mut rng);
        assert_eq!(pop.len(), 64);
    }

    #[test]
    fn deterministic_given_seed() {
        let spec = GpuArch::A100.spec();
        let space = ScheduleSpace::new(suites::MM1, &spec);
        let a = init_population(&space, 32, &mut Rng::seed_from_u64(5));
        let b = init_population(&space, 32, &mut Rng::seed_from_u64(5));
        assert_eq!(a, b);
    }
}
