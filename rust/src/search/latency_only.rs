//! The Ansor-style baseline: evolutionary search optimizing latency
//! only (§7.1 "we select the state-of-the-art open-source
//! auto-scheduler Ansor as the baseline").
//!
//! Structure matches the energy-aware search exactly — same population,
//! same genetic operators, same latency evaluation — with parent
//! selection purely by latency and no energy measurements during the
//! search. The winner's energy is NVML-measured once at the end (that
//! is the "Ansor" row of Tables 2–4).

use super::{latency_eva_and_pick, EvaluatedKernel, RoundStats, SearchOutcome};
use crate::config::{SearchConfig, SearchMode};
use crate::nvml::NvmlMeter;
use crate::schedule::space::ScheduleSpace;
use crate::schedule::Candidate;
use crate::util::Rng;
use crate::workload::Workload;

/// Run the latency-only baseline search.
pub fn run(workload: Workload, cfg: &SearchConfig) -> SearchOutcome {
    let spec = cfg.gpu.spec();
    let space = ScheduleSpace::new(workload, &spec);
    let mut rng = Rng::seed_from_u64(cfg.seed);
    let mut meter = NvmlMeter::new(spec.clone(), cfg.nvml.clone());
    meter.warm_up();

    let mut rounds: Vec<RoundStats> = Vec::new();
    let mut best: Option<(crate::schedule::Schedule, f64)> = None;
    let mut parents = super::population::init_population(&space, cfg.population, &mut rng);
    let mut stale = 0usize;

    for round in 0..cfg.rounds {
        let gen = if round == 0 {
            parents.clone()
        } else {
            super::genetic::reproduce(&space, &parents, cfg, &mut rng)
        };
        let top = latency_eva_and_pick(workload, &gen, cfg.m_latency_keep, &mut meter, &mut rng);

        let round_best = top[0];
        let improved = best.map_or(true, |(_, l)| round_best.1 < l * 0.999);
        if improved {
            best = Some(round_best);
            stale = 0;
        } else {
            stale += 1;
        }

        parents = top.iter().map(|(s, _)| *s).collect();
        rounds.push(RoundStats {
            round,
            best_latency_s: best.expect("set").1,
            best_energy_j: f64::NAN,
            snr_db: None,
            relerr: None,
            k: 0.0,
            n_measured: 0,
            elapsed_s: meter.clock.total_s,
        });

        if cfg.patience > 0 && stale >= cfg.patience {
            break;
        }
    }

    // Measure the winner's energy once (the Tables' "Ansor" energy).
    let (best_sched, _) = best.expect("at least one round ran");
    let m = meter.measure(&Candidate::new(workload, best_sched), &mut rng);
    let best_kernel = EvaluatedKernel {
        schedule: best_sched,
        latency_s: m.latency_s,
        energy_j: m.energy_j,
        avg_power_w: m.avg_power_w,
        energy_measured: true,
    };
    if let Some(last) = rounds.last_mut() {
        last.best_energy_j = m.energy_j;
    }

    let n_latency_evals = meter.clock.n_latency_timings;
    SearchOutcome {
        workload,
        mode: SearchMode::LatencyOnly,
        best: best_kernel,
        rounds,
        measured_pool: vec![best_kernel],
        clock: meter.clock,
        k_trace: Vec::new(),
        n_latency_evals,
        model: None,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::GpuArch;
    use crate::sim;
    use crate::workload::suites;

    fn quick_cfg() -> SearchConfig {
        SearchConfig {
            gpu: GpuArch::A100,
            mode: SearchMode::LatencyOnly,
            population: 48,
            m_latency_keep: 12,
            rounds: 6,
            patience: 0,
            seed: 1,
            ..Default::default()
        }
    }

    #[test]
    fn improves_over_random_population() {
        let cfg = quick_cfg();
        let out = run(suites::MM1, &cfg);
        // The final best must beat the first round's best noticeably or
        // at least match it (monotone best tracking).
        let first = out.rounds.first().unwrap().best_latency_s;
        let last = out.rounds.last().unwrap().best_latency_s;
        assert!(last <= first, "{last} > {first}");
        assert!(out.best.energy_measured);
        assert!(out.best.energy_j > 0.0);
    }

    #[test]
    fn finds_near_optimal_latency() {
        // Compare against exhaustive enumeration of a bounded slice of
        // the space: the GA should land within 25% of that reference.
        let cfg = quick_cfg();
        let out = run(suites::MM1, &cfg);
        let spec = cfg.gpu.spec();
        let space = crate::schedule::space::ScheduleSpace::new(suites::MM1, &spec);
        let g = suites::MM1.gemm_view();
        let best_enum = space
            .enumerate(4000)
            .iter()
            .map(|s| sim::evaluate_latency(&g, s, &spec))
            .fold(f64::INFINITY, f64::min);
        assert!(
            out.best.latency_s <= best_enum * 1.25,
            "GA {} vs enum {}",
            out.best.latency_s,
            best_enum
        );
    }

    #[test]
    fn deterministic_given_seed() {
        let cfg = quick_cfg();
        let a = run(suites::CONV2, &cfg);
        let b = run(suites::CONV2, &cfg);
        assert_eq!(a.best.schedule, b.best.schedule);
        assert_eq!(a.rounds.len(), b.rounds.len());
    }

    #[test]
    fn charges_latency_time_but_barely_any_energy_measurements() {
        let cfg = quick_cfg();
        let out = run(suites::MM1, &cfg);
        assert_eq!(out.n_energy_measurements(), 1, "only the final winner");
        assert!(out.n_latency_evals >= cfg.population);
        assert!(out.clock.latency_eval_s > 0.0);
    }
}
