//! Serving telemetry primitives (ISSUE 6).
//!
//! Pure data structures — no I/O, no unix gating — shared by the
//! daemon's hot-path instrumentation, the `metrics` wire op, the fleet
//! merge client, and the `bench serve` harness:
//!
//! - [`LogHistogram`]: fixed-size mergeable log2-bucket histogram.
//!   O(1) allocation-free record, bounded memory forever, quantiles
//!   accurate to one bucket width, and `merge` that exactly equals the
//!   histogram of the concatenated sample streams.
//! - [`Stage`] / [`StageTrace`]: the daemon hot-path stage taxonomy
//!   (parse, shard read, snapshot lookup, claim I/O, enqueue, reply
//!   write) and a stack-only per-request accumulator.
//! - [`EnergyLedger`] (ISSUE 8): mergeable per-(gpu, workload-family)
//!   counters of joules saved vs the latency-only baseline and
//!   measurement joules paid — the serving-time account behind the
//!   paper's energy-savings claim.
//! - [`TraceId`] / [`Span`] / [`Trace`] / [`TraceLog`] (ISSUE 7):
//!   span-based request tracing — a `Copy` trace id that crosses
//!   daemon boundaries through the notify channel, and a bounded
//!   in-daemon ring with tail-sampling (slowest-N + errored traces
//!   always retained).

mod histogram;
mod ledger;
mod stages;
mod trace;

pub use histogram::{bucket_lower, LogHistogram, MIN_LOG2, N_BUCKETS};
pub use ledger::{
    ledger_family_index, ledger_gpu_index, EnergyLedger, LEDGER_FAMILIES, LEDGER_GPUS,
    UNATTRIBUTED,
};
pub use stages::{Stage, StageTrace, N_STAGES};
pub use trace::{Span, Trace, TraceId, TraceLog, TRACE_KEEP_SLOWEST, TRACE_LOG_CAP};
