//! Span-based request tracing (ISSUE 7): follow ONE miss from the wire
//! frame through claim I/O, enqueue, every search round, the write-back
//! landing, and a peer's notify-refresh ingest — as a single causal
//! chain keyed by a [`TraceId`] that crosses daemon boundaries.
//!
//! Design constraints, in order:
//!
//! * **the exact-hit path stays allocation-free** — [`TraceId`] is a
//!   `Copy` `u64` minted from an atomic counter mixed with a clock
//!   nonce; minting, copying, and comparing ids never touch the heap.
//!   Only the MISS path (which already pays claim file I/O) opens a
//!   trace, and only there do span strings get allocated;
//! * **bounded memory forever** — completed traces live in a
//!   [`TraceLog`] ring with a hard capacity. Eviction is
//!   *tail-sampling*: the slowest-N completed traces and every errored
//!   trace are preferentially retained, because the slow and the broken
//!   are exactly the traces an operator pages through `query --trace`
//!   for. When protected traces alone exceed the cap, the oldest of
//!   them goes too — the bound always wins;
//! * **pure data** — no I/O and no platform gating here; the daemon
//!   owns the clock and the mutex, this module owns the shapes and the
//!   retention policy.
//!
//! A span records a `start_s` offset from the trace's start plus a
//! duration; spans appended after the fact (search rounds are
//! synthesized at write-back landing from [`RoundStats`] deltas)
//! simply extend the trace's `total_s`. A trace that travels to a peer
//! daemon via the notify channel shows up there as a single-span
//! *remote* trace under the SAME id — `query --trace` against each
//! fleet member reassembles the chain.
//!
//! [`RoundStats`]: crate::search::RoundStats

use crate::util::Json;
use std::sync::atomic::{AtomicU64, Ordering};

/// A fleet-unique trace id: `Copy`, 8 bytes, allocation-free to mint
/// and compare. Rendered as 16 lowercase hex chars on the wire and in
/// notify announcements; parsed back tolerantly (any 1–16 hex chars).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct TraceId(u64);

/// Process-wide mint counter; mixed with a clock nonce so two daemons
/// (or two restarts of one) never collide on low counter values.
static TRACE_SEQ: AtomicU64 = AtomicU64::new(0);

/// SplitMix64 finalizer: one multiply-xor round is enough to spread
/// (pid, seq, nanos) into all 64 bits.
fn mix64(mut x: u64) -> u64 {
    x = (x ^ (x >> 30)).wrapping_mul(0xbf58476d1ce4e5b9);
    x = (x ^ (x >> 27)).wrapping_mul(0x94d049bb133111eb);
    x ^ (x >> 31)
}

impl TraceId {
    /// Mint a fresh id. No heap, no syscalls beyond the vDSO clock
    /// read — safe on the exact-hit path (pinned by the counting-
    /// allocator test in `tests/telemetry_alloc.rs`).
    pub fn mint() -> TraceId {
        let seq = TRACE_SEQ.fetch_add(1, Ordering::Relaxed);
        let nanos = std::time::SystemTime::now()
            .duration_since(std::time::UNIX_EPOCH)
            .map(|d| d.as_nanos() as u64)
            .unwrap_or(0);
        let pid = std::process::id() as u64;
        let id = mix64(nanos ^ (pid << 40) ^ seq.wrapping_mul(0x9e3779b97f4a7c15));
        // 0 is reserved as "no trace" in a couple of packed contexts;
        // remap the 1-in-2^64 collision instead of branching callers.
        TraceId(if id == 0 { 1 } else { id })
    }

    /// The wire rendering: 16 lowercase hex chars. Allocates — cold
    /// paths only (miss bookkeeping, notify announcements, replies).
    pub fn to_hex(self) -> String {
        format!("{:016x}", self.0)
    }

    /// Parse a wire rendering. Tolerant: any 1–16 hex chars (clients
    /// may mint shorter ids). Allocation-free.
    pub fn from_hex(s: &str) -> Option<TraceId> {
        if s.is_empty() || s.len() > 16 {
            return None;
        }
        u64::from_str_radix(s, 16).ok().map(|v| TraceId(if v == 0 { 1 } else { v }))
    }
}

impl std::fmt::Display for TraceId {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{:016x}", self.0)
    }
}

/// One timed operation inside a trace. `start_s` is the offset from
/// the trace's start on the recording daemon's clock; spans recorded
/// on a peer (notify-refresh ingest) start their own remote trace, so
/// offsets never mix clocks.
#[derive(Debug, Clone, PartialEq)]
pub struct Span {
    /// Span name: `parse`, `shard_read`, `snapshot_lookup`, `claim_io`,
    /// `enqueue`, `reply_write`, `search_round`, `writeback`,
    /// `notify_refresh`.
    pub name: String,
    /// Offset from the trace start (seconds).
    pub start_s: f64,
    /// Duration (seconds).
    pub dur_s: f64,
    /// Search-round index (search_round spans only).
    pub round: Option<usize>,
    /// Model SNR prediction error for the round (dB), when computed.
    pub snr_db: Option<f64>,
    /// Dynamic-k value after the round's update.
    pub k: Option<f64>,
    /// NVML measurements paid by the round.
    pub n_measured: Option<usize>,
    /// Mean relative error |predicted − measured| / measured of the
    /// round's energy predictions, when computed.
    pub relerr: Option<f64>,
    /// Free-form annotation: write-back landing (`accepted` / `fenced`
    /// / `dropped`), shed reason, the refreshing peer's holder id.
    pub note: Option<String>,
}

impl Span {
    pub fn new(name: &str, start_s: f64, dur_s: f64) -> Span {
        Span {
            name: name.to_string(),
            start_s,
            dur_s,
            round: None,
            snr_db: None,
            k: None,
            n_measured: None,
            relerr: None,
            note: None,
        }
    }

    pub fn with_note(mut self, note: &str) -> Span {
        self.note = Some(note.to_string());
        self
    }

    /// Optional fields encode only when present, so span lines stay
    /// short and old readers parse new spans unchanged.
    pub fn to_json(&self) -> Json {
        let mut fields = vec![
            ("name", Json::str(self.name.clone())),
            ("start_s", Json::num(self.start_s)),
            ("dur_s", Json::num(self.dur_s)),
        ];
        if let Some(r) = self.round {
            fields.push(("round", Json::num(r as f64)));
        }
        if let Some(s) = self.snr_db {
            fields.push(("snr_db", Json::num(s)));
        }
        if let Some(k) = self.k {
            fields.push(("k", Json::num(k)));
        }
        if let Some(n) = self.n_measured {
            fields.push(("n_measured", Json::num(n as f64)));
        }
        if let Some(e) = self.relerr {
            fields.push(("relerr", Json::num(e)));
        }
        if let Some(note) = &self.note {
            fields.push(("note", Json::str(note.clone())));
        }
        Json::obj(fields)
    }

    pub fn from_json(v: &Json) -> Option<Span> {
        Some(Span {
            name: v.get("name")?.as_str()?.to_string(),
            start_s: v.get("start_s")?.as_f64()?,
            dur_s: v.get("dur_s")?.as_f64()?,
            round: v.get("round").and_then(|x| x.as_f64()).map(|x| x as usize),
            snr_db: v.get("snr_db").and_then(|x| x.as_f64()),
            k: v.get("k").and_then(|x| x.as_f64()),
            n_measured: v.get("n_measured").and_then(|x| x.as_f64()).map(|x| x as usize),
            relerr: v.get("relerr").and_then(|x| x.as_f64()),
            note: v.get("note").and_then(|x| x.as_str()).map(|s| s.to_string()),
        })
    }
}

/// One request's causal chain on one daemon. Under the same id a peer
/// daemon holds its own `remote: true` continuation.
#[derive(Debug, Clone, PartialEq)]
pub struct Trace {
    pub id: TraceId,
    /// Serve key of the miss that opened the trace.
    pub key: String,
    /// Wire request id of the originating frame ("" on remote traces).
    pub req: String,
    /// Unix timestamp of the trace start on the recording daemon.
    pub start_unix_s: f64,
    /// End offset of the furthest span (seconds since `start_unix_s`).
    pub total_s: f64,
    /// True once a terminal failure was recorded (search failed,
    /// write-back dropped) — errored traces are always tail-sampled in.
    pub error: bool,
    /// True once the chain closed (write-back landed / shed / failed).
    pub complete: bool,
    /// True for a foreign trace continued here via the notify channel.
    pub remote: bool,
    pub spans: Vec<Span>,
}

impl Trace {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("id", Json::str(self.id.to_hex())),
            ("key", Json::str(self.key.clone())),
            ("req", Json::str(self.req.clone())),
            ("start_unix_s", Json::num(self.start_unix_s)),
            ("total_s", Json::num(self.total_s)),
            ("error", Json::Bool(self.error)),
            ("complete", Json::Bool(self.complete)),
            ("remote", Json::Bool(self.remote)),
            ("spans", Json::arr(self.spans.iter().map(|s| s.to_json()))),
        ])
    }

    pub fn from_json(v: &Json) -> Option<Trace> {
        Some(Trace {
            id: TraceId::from_hex(v.get("id")?.as_str()?)?,
            key: v.get("key")?.as_str()?.to_string(),
            req: v.get("req").and_then(|x| x.as_str()).unwrap_or("").to_string(),
            start_unix_s: v.get("start_unix_s").and_then(|x| x.as_f64()).unwrap_or(0.0),
            total_s: v.get("total_s").and_then(|x| x.as_f64()).unwrap_or(0.0),
            error: v.get("error").and_then(|x| x.as_bool()).unwrap_or(false),
            complete: v.get("complete").and_then(|x| x.as_bool()).unwrap_or(false),
            remote: v.get("remote").and_then(|x| x.as_bool()).unwrap_or(false),
            spans: v
                .get("spans")
                .and_then(|a| a.as_arr())
                .map(|a| a.iter().filter_map(Span::from_json).collect())
                .unwrap_or_default(),
        })
    }
}

/// Default retained-trace capacity of a daemon's ring.
pub const TRACE_LOG_CAP: usize = 128;
/// Default slowest-N protection under tail-sampling.
pub const TRACE_KEEP_SLOWEST: usize = 8;

/// Bounded in-daemon trace ring with tail-sampling eviction.
///
/// Open traces (miss admitted, write-back not yet landed) and
/// completed traces share one store, bounded by `cap` together.
/// Eviction prefers victims in this order: completed traces that are
/// neither errored nor among the slowest-`keep_slowest`, then open
/// traces (oldest first — a trace held open past a full ring of churn
/// is presumed leaked), then errored/slow traces oldest-first. Memory
/// is therefore bounded by `cap` no matter the error rate or how
/// skewed the latency tail is.
#[derive(Debug)]
pub struct TraceLog {
    cap: usize,
    keep_slowest: usize,
    traces: Vec<Trace>,
}

impl TraceLog {
    pub fn new(cap: usize, keep_slowest: usize) -> TraceLog {
        TraceLog { cap: cap.max(1), keep_slowest, traces: Vec::new() }
    }

    pub fn len(&self) -> usize {
        self.traces.len()
    }

    pub fn is_empty(&self) -> bool {
        self.traces.is_empty()
    }

    /// Begin a trace (the miss path, at the point it reserves the
    /// search). Re-opening a live id is a no-op so a client retrying
    /// with the same trace id cannot wipe the original chain.
    pub fn open(&mut self, id: TraceId, key: &str, req: &str, start_unix_s: f64) {
        if self.traces.iter().any(|t| t.id == id) {
            return;
        }
        self.traces.push(Trace {
            id,
            key: key.to_string(),
            req: req.to_string(),
            start_unix_s,
            total_s: 0.0,
            error: false,
            complete: false,
            remote: false,
            spans: Vec::new(),
        });
        self.enforce_cap();
    }

    /// Append a span to a trace (open or completed — write-back spans
    /// land after the reply did). Returns false if the id is unknown
    /// (evicted or never opened here).
    pub fn span(&mut self, id: TraceId, span: Span) -> bool {
        match self.traces.iter_mut().find(|t| t.id == id) {
            Some(t) => {
                t.total_s = t.total_s.max(span.start_s + span.dur_s);
                t.spans.push(span);
                true
            }
            None => false,
        }
    }

    /// The trace's start as a unix timestamp, for computing span
    /// offsets from wall-clock "now".
    pub fn start_unix_s(&self, id: TraceId) -> Option<f64> {
        self.traces.iter().find(|t| t.id == id).map(|t| t.start_unix_s)
    }

    /// Close a trace; `error` marks it for unconditional retention
    /// under tail-sampling. Unknown ids are ignored.
    pub fn close(&mut self, id: TraceId, error: bool) {
        if let Some(t) = self.traces.iter_mut().find(|t| t.id == id) {
            t.complete = true;
            t.error = t.error || error;
        }
        self.enforce_cap();
    }

    /// Record a FOREIGN trace's continuation on this daemon (the peer
    /// side of a notify announcement): one completed single-span remote
    /// trace under the foreign id.
    pub fn record_remote(&mut self, id: TraceId, key: &str, start_unix_s: f64, span: Span) {
        if self.span(id, span.clone()) {
            return;
        }
        self.traces.push(Trace {
            id,
            key: key.to_string(),
            req: String::new(),
            start_unix_s,
            total_s: span.start_s + span.dur_s,
            error: false,
            complete: true,
            remote: true,
            spans: vec![span],
        });
        self.enforce_cap();
    }

    pub fn get(&self, id: TraceId) -> Option<&Trace> {
        self.traces.iter().find(|t| t.id == id)
    }

    /// Completed traces, slowest first, at most `n`. With `n == 0`,
    /// every completed trace (still bounded by the ring cap).
    pub fn slowest(&self, n: usize) -> Vec<&Trace> {
        let mut done: Vec<&Trace> = self.traces.iter().filter(|t| t.complete).collect();
        done.sort_by(|a, b| b.total_s.partial_cmp(&a.total_s).unwrap_or(std::cmp::Ordering::Equal));
        if n > 0 {
            done.truncate(n);
        }
        done
    }

    /// Ids of the slowest-`keep_slowest` completed traces (the
    /// tail-sampling protection set).
    fn protected_slowest(&self) -> Vec<TraceId> {
        self.slowest(self.keep_slowest).iter().map(|t| t.id).collect()
    }

    /// Tail-sampling eviction down to `cap`. See the type docs for the
    /// victim order.
    fn enforce_cap(&mut self) {
        while self.traces.len() > self.cap {
            let slow = self.protected_slowest();
            let unprotected = self
                .traces
                .iter()
                .position(|t| t.complete && !t.error && !slow.contains(&t.id));
            let victim = unprotected
                .or_else(|| self.traces.iter().position(|t| !t.complete))
                .unwrap_or(0);
            self.traces.remove(victim);
        }
    }
}

impl Default for TraceLog {
    fn default() -> Self {
        TraceLog::new(TRACE_LOG_CAP, TRACE_KEEP_SLOWEST)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn span(name: &str, start_s: f64, dur_s: f64) -> Span {
        Span::new(name, start_s, dur_s)
    }

    #[test]
    fn trace_ids_are_unique_and_roundtrip_hex() {
        let ids: Vec<TraceId> = (0..1000).map(|_| TraceId::mint()).collect();
        let mut dedup = ids.clone();
        dedup.sort();
        dedup.dedup();
        assert_eq!(dedup.len(), ids.len(), "minted ids collide");
        for id in ids.iter().take(16) {
            let hex = id.to_hex();
            assert_eq!(hex.len(), 16);
            assert_eq!(TraceId::from_hex(&hex), Some(*id));
        }
        assert_eq!(TraceId::from_hex(""), None);
        assert_eq!(TraceId::from_hex("zz"), None);
        assert_eq!(TraceId::from_hex("deadbeefdeadbeef00"), None, "17+ chars rejected");
        // Short client-minted ids parse.
        assert!(TraceId::from_hex("a3f").is_some());
    }

    #[test]
    fn spans_and_traces_roundtrip_json_with_optional_fields() {
        let mut s = span("search_round", 0.5, 1.25);
        s.round = Some(2);
        s.snr_db = Some(18.4);
        s.k = Some(0.5);
        s.n_measured = Some(4);
        s.relerr = Some(0.07);
        s.note = Some(r#"peer "a""#.to_string());
        let back = Span::from_json(&Json::parse(&s.to_json().to_string()).unwrap()).unwrap();
        assert_eq!(back, s);
        // A minimal span omits every optional field on the wire.
        let lean = span("claim_io", 0.0, 0.001);
        let line = lean.to_json().to_string();
        assert!(!line.contains("snr_db") && !line.contains("note"), "{line}");
        assert_eq!(Span::from_json(&Json::parse(&line).unwrap()).unwrap(), lean);

        let mut log = TraceLog::new(8, 2);
        let id = TraceId::mint();
        log.open(id, "mm1|a100|energy_aware|fp", "c7", 1234.5);
        log.span(id, s);
        log.close(id, false);
        let t = log.get(id).unwrap();
        let back = Trace::from_json(&Json::parse(&t.to_json().to_string()).unwrap()).unwrap();
        assert_eq!(&back, t);
    }

    #[test]
    fn spans_extend_total_and_close_marks_complete() {
        let mut log = TraceLog::new(8, 2);
        let id = TraceId::mint();
        log.open(id, "k", "c1", 0.0);
        assert!(log.span(id, span("claim_io", 0.001, 0.002)));
        assert!(log.span(id, span("writeback", 3.0, 0.5)));
        assert!(!log.span(TraceId::mint(), span("claim_io", 0.0, 0.1)), "unknown id");
        let t = log.get(id).unwrap();
        assert!(!t.complete);
        assert!((t.total_s - 3.5).abs() < 1e-12);
        log.close(id, false);
        assert!(log.get(id).unwrap().complete);
        // Spans may still land after close (write-back after reply).
        assert!(log.span(id, span("notify_refresh", 4.0, 0.1)));
        assert!((log.get(id).unwrap().total_s - 4.1).abs() < 1e-12);
    }

    #[test]
    fn tail_sampling_keeps_slowest_and_errored_under_churn() {
        let mut log = TraceLog::new(10, 3);
        // Two errored traces early on, then heavy churn of fast traces.
        let mut errored = Vec::new();
        for i in 0..2 {
            let id = TraceId::mint();
            log.open(id, &format!("err{i}"), "c", i as f64);
            log.span(id, span("claim_io", 0.0, 0.001));
            log.close(id, true);
            errored.push(id);
        }
        // Three slow traces (the slowest-N protection set).
        let mut slow = Vec::new();
        for i in 0..3 {
            let id = TraceId::mint();
            log.open(id, &format!("slow{i}"), "c", 10.0 + i as f64);
            log.span(id, span("writeback", 0.0, 100.0 + i as f64));
            log.close(id, false);
            slow.push(id);
        }
        // 200 fast completed traces churn through.
        for i in 0..200 {
            let id = TraceId::mint();
            log.open(id, &format!("fast{i}"), "c", 100.0 + i as f64);
            log.span(id, span("claim_io", 0.0, 1e-4));
            log.close(id, false);
            assert!(log.len() <= 10, "cap violated at churn {i}");
        }
        for id in &errored {
            assert!(log.get(*id).is_some(), "errored trace evicted");
        }
        for id in &slow {
            assert!(log.get(*id).is_some(), "slow trace evicted");
        }
        // slowest() orders by duration, slowest first.
        let top = log.slowest(3);
        assert_eq!(top.len(), 3);
        assert!(top[0].total_s >= top[1].total_s && top[1].total_s >= top[2].total_s);
        assert!(top[0].key.starts_with("slow"));
    }

    #[test]
    fn bounded_even_when_every_trace_is_protected() {
        // All errored: protection cannot override the hard cap.
        let mut log = TraceLog::new(5, 2);
        for i in 0..50 {
            let id = TraceId::mint();
            log.open(id, &format!("e{i}"), "c", i as f64);
            log.close(id, true);
            assert!(log.len() <= 5);
        }
        // All open (leaked): still bounded.
        let mut log = TraceLog::new(5, 2);
        for i in 0..50 {
            log.open(TraceId::mint(), &format!("o{i}"), "c", i as f64);
            assert!(log.len() <= 5);
        }
    }

    #[test]
    fn remote_traces_complete_immediately_under_the_foreign_id() {
        let mut log = TraceLog::default();
        let foreign = TraceId::mint();
        let s = span("notify_refresh", 0.0, 0.004).with_note("daemon-a");
        log.record_remote(foreign, "k", 50.0, s);
        let t = log.get(foreign).unwrap();
        assert!(t.remote && t.complete && t.req.is_empty());
        assert_eq!(t.spans.len(), 1);
        // A second ingest for the same id appends, not duplicates.
        log.record_remote(foreign, "k", 51.0, span("notify_refresh", 0.1, 0.002));
        assert_eq!(log.get(foreign).unwrap().spans.len(), 2);
    }

    #[test]
    fn reopening_a_live_id_is_a_noop() {
        let mut log = TraceLog::default();
        let id = TraceId::mint();
        log.open(id, "k", "c1", 1.0);
        log.span(id, span("claim_io", 0.0, 0.5));
        log.open(id, "other", "c2", 2.0);
        let t = log.get(id).unwrap();
        assert_eq!(t.key, "k");
        assert_eq!(t.spans.len(), 1);
    }
}
