//! Fleet-wide energy-accounting ledger (ISSUE 8).
//!
//! The paper's headline claim is *joules saved*; this ledger is the
//! serving-time bookkeeping that backs it. Two sides of the account:
//!
//! - **saved**: every exact hit credits `baseline_energy_j −
//!   energy_j` — what the latency-only schedule would have burned
//!   minus what the energy-aware schedule burns. Records written
//!   before the baseline existed are **never guessed at**: their hits
//!   land in the `unattributed` family with 0 J, visible as a count.
//! - **paid**: every landed search debits the NVML measurement joules
//!   it spent, so the net (saved − paid) is honest about tuning cost.
//!
//! Like [`LogHistogram`](super::LogHistogram), the ledger is a fixed
//! array of counters: recording is O(1) and allocation-free (the
//! exact-hit zero-allocation pin covers it), and `merge` is elementwise
//! addition — a fleet's merged ledger is *exactly* the ledger of the
//! union of its requests.

use crate::util::Json;

/// GPU axis — mirrors `GpuArch::ALL` order.
pub const LEDGER_GPUS: [&str; 4] = ["a100", "rtx4090", "p100", "v100"];

/// Workload-family axis. The last slot is the `unattributed` bucket:
/// hits on records with no persisted baseline (and anything a newer
/// peer sends that this build doesn't know) land there, never guessed.
pub const LEDGER_FAMILIES: [&str; 4] = ["mm", "mv", "conv", "unattributed"];

/// Family index of the `unattributed` bucket.
pub const UNATTRIBUTED: usize = LEDGER_FAMILIES.len() - 1;

const N_GPUS: usize = LEDGER_GPUS.len();
const N_FAMILIES: usize = LEDGER_FAMILIES.len();

/// Mergeable per-(gpu, workload-family) energy counters.
#[derive(Debug, Clone, PartialEq)]
pub struct EnergyLedger {
    saved_j: [[f64; N_FAMILIES]; N_GPUS],
    paid_j: [[f64; N_FAMILIES]; N_GPUS],
    n_hits: [[u64; N_FAMILIES]; N_GPUS],
    n_searches: [[u64; N_FAMILIES]; N_GPUS],
}

impl Default for EnergyLedger {
    fn default() -> Self {
        EnergyLedger {
            saved_j: [[0.0; N_FAMILIES]; N_GPUS],
            paid_j: [[0.0; N_FAMILIES]; N_GPUS],
            n_hits: [[0; N_FAMILIES]; N_GPUS],
            n_searches: [[0; N_FAMILIES]; N_GPUS],
        }
    }
}

/// Index of a GPU name on the ledger's GPU axis. Allocation-free
/// (short `&str` compares), `None` for names this build doesn't know.
pub fn ledger_gpu_index(name: &str) -> Option<usize> {
    LEDGER_GPUS.iter().position(|g| *g == name)
}

/// Index of a workload family on the family axis; unknown families
/// fold into `unattributed` rather than being dropped.
pub fn ledger_family_index(family: &str) -> usize {
    LEDGER_FAMILIES
        .iter()
        .position(|f| *f == family)
        .unwrap_or(UNATTRIBUTED)
}

impl EnergyLedger {
    pub fn new() -> Self {
        Self::default()
    }

    /// Credit one served hit. O(1), allocation-free. `joules` is 0 for
    /// unattributed hits (`family == UNATTRIBUTED`) — the hit count
    /// still moves, so baseline-less records are visible, not silent.
    pub fn record_saved(&mut self, gpu: usize, family: usize, joules: f64) {
        let joules = if joules.is_finite() && joules > 0.0 { joules } else { 0.0 };
        self.saved_j[gpu][family] += joules;
        self.n_hits[gpu][family] += 1;
    }

    /// Debit one landed search's measurement joules. O(1),
    /// allocation-free.
    pub fn record_paid(&mut self, gpu: usize, family: usize, joules: f64) {
        let joules = if joules.is_finite() && joules > 0.0 { joules } else { 0.0 };
        self.paid_j[gpu][family] += joules;
        self.n_searches[gpu][family] += 1;
    }

    /// Fold another ledger in — elementwise addition, so the merged
    /// ledger equals the ledger of the union of both request streams
    /// (associative + commutative, like the histograms).
    pub fn merge(&mut self, other: &EnergyLedger) {
        for g in 0..N_GPUS {
            for f in 0..N_FAMILIES {
                self.saved_j[g][f] += other.saved_j[g][f];
                self.paid_j[g][f] += other.paid_j[g][f];
                self.n_hits[g][f] += other.n_hits[g][f];
                self.n_searches[g][f] += other.n_searches[g][f];
            }
        }
    }

    pub fn is_empty(&self) -> bool {
        self.n_hits.iter().flatten().all(|&n| n == 0)
            && self.n_searches.iter().flatten().all(|&n| n == 0)
    }

    pub fn saved_j(&self, gpu: usize, family: usize) -> f64 {
        self.saved_j[gpu][family]
    }

    pub fn paid_j(&self, gpu: usize, family: usize) -> f64 {
        self.paid_j[gpu][family]
    }

    pub fn n_hits(&self, gpu: usize, family: usize) -> u64 {
        self.n_hits[gpu][family]
    }

    pub fn n_searches(&self, gpu: usize, family: usize) -> u64 {
        self.n_searches[gpu][family]
    }

    /// Total joules credited across every cell.
    pub fn total_saved_j(&self) -> f64 {
        self.saved_j.iter().flatten().sum()
    }

    /// Total measurement joules debited across every cell.
    pub fn total_paid_j(&self) -> f64 {
        self.paid_j.iter().flatten().sum()
    }

    /// Served hits whose record carried no baseline (credited 0 J).
    pub fn total_unattributed(&self) -> u64 {
        self.n_hits.iter().map(|row| row[UNATTRIBUTED]).sum()
    }

    /// Visit every non-empty cell as `(gpu, family)` indices — the
    /// iteration order (gpu-major, then family) is what the Prometheus
    /// exposition and the bench block rely on.
    pub fn cells(&self) -> impl Iterator<Item = (usize, usize)> + '_ {
        (0..N_GPUS).flat_map(move |g| (0..N_FAMILIES).map(move |f| (g, f))).filter(
            move |&(g, f)| {
                self.n_hits[g][f] > 0
                    || self.n_searches[g][f] > 0
                    || self.saved_j[g][f] != 0.0
                    || self.paid_j[g][f] != 0.0
            },
        )
    }

    /// Wire encoding: sparse map keyed `"<gpu>/<family>"`, only
    /// non-empty cells present — an idle daemon's ledger costs nothing
    /// on the wire, and an absent field parses back as empty.
    pub fn to_json(&self) -> Json {
        let cells: std::collections::BTreeMap<String, Json> = self
            .cells()
            .map(|(g, f)| {
                let key = format!("{}/{}", LEDGER_GPUS[g], LEDGER_FAMILIES[f]);
                let cell = Json::obj(vec![
                    ("saved_j", Json::num(self.saved_j[g][f])),
                    ("paid_j", Json::num(self.paid_j[g][f])),
                    ("n_hits", Json::num(self.n_hits[g][f] as f64)),
                    ("n_searches", Json::num(self.n_searches[g][f] as f64)),
                ]);
                (key, cell)
            })
            .collect();
        Json::Obj(cells)
    }

    /// Decode the wire form. Tolerant: unknown GPUs are dropped,
    /// unknown families fold into `unattributed`, absent fields are 0.
    pub fn from_json(v: &Json) -> EnergyLedger {
        let mut ledger = EnergyLedger::default();
        let Json::Obj(cells) = v else {
            return ledger;
        };
        for (key, cell) in cells {
            let Some((gpu_name, family_name)) = key.split_once('/') else {
                continue;
            };
            let Some(g) = ledger_gpu_index(gpu_name) else {
                continue;
            };
            let f = ledger_family_index(family_name);
            let num = |name: &str| cell.get(name).and_then(Json::as_f64).unwrap_or(0.0);
            ledger.saved_j[g][f] += num("saved_j");
            ledger.paid_j[g][f] += num("paid_j");
            ledger.n_hits[g][f] += num("n_hits") as u64;
            ledger.n_searches[g][f] += num("n_searches") as u64;
        }
        ledger
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn axes_match_the_arch_and_family_enums() {
        for (i, arch) in crate::config::GpuArch::ALL.iter().enumerate() {
            assert_eq!(LEDGER_GPUS[i], arch.name());
            assert_eq!(ledger_gpu_index(arch.name()), Some(i));
        }
        assert_eq!(ledger_gpu_index("tpu"), None);
        assert_eq!(ledger_family_index("mm"), 0);
        assert_eq!(ledger_family_index("conv"), 2);
        assert_eq!(ledger_family_index("unattributed"), UNATTRIBUTED);
        assert_eq!(ledger_family_index("something_new"), UNATTRIBUTED);
    }

    #[test]
    fn saved_and_paid_accumulate_per_cell() {
        let mut l = EnergyLedger::new();
        l.record_saved(0, 0, 1.5);
        l.record_saved(0, 0, 0.5);
        l.record_saved(1, 2, 3.0);
        l.record_paid(0, 0, 10.0);
        assert_eq!(l.saved_j(0, 0), 2.0);
        assert_eq!(l.n_hits(0, 0), 2);
        assert_eq!(l.saved_j(1, 2), 3.0);
        assert_eq!(l.paid_j(0, 0), 10.0);
        assert_eq!(l.n_searches(0, 0), 1);
        assert_eq!(l.total_saved_j(), 5.0);
        assert_eq!(l.total_paid_j(), 10.0);
        assert!(!l.is_empty());
    }

    #[test]
    fn unattributed_hits_count_but_credit_nothing() {
        let mut l = EnergyLedger::new();
        l.record_saved(2, UNATTRIBUTED, 0.0);
        // Negative/NaN credits clamp to 0 instead of corrupting sums.
        l.record_saved(2, UNATTRIBUTED, -4.0);
        l.record_saved(2, UNATTRIBUTED, f64::NAN);
        assert_eq!(l.total_saved_j(), 0.0);
        assert_eq!(l.total_unattributed(), 3);
    }

    #[test]
    fn merge_equals_ledger_of_the_union() {
        let (mut a, mut b, mut union) =
            (EnergyLedger::new(), EnergyLedger::new(), EnergyLedger::new());
        for (g, f, j) in [(0, 0, 1.0), (0, 1, 2.0), (3, 2, 0.25)] {
            a.record_saved(g, f, j);
            union.record_saved(g, f, j);
        }
        for (g, f, j) in [(0, 0, 4.0), (2, UNATTRIBUTED, 0.0)] {
            b.record_saved(g, f, j);
            union.record_saved(g, f, j);
        }
        a.record_paid(0, 0, 7.0);
        union.record_paid(0, 0, 7.0);
        b.record_paid(1, 1, 3.0);
        union.record_paid(1, 1, 3.0);
        let mut merged = a.clone();
        merged.merge(&b);
        assert_eq!(merged, union);
        let mut other_order = b;
        other_order.merge(&a);
        assert_eq!(other_order, union);
    }

    #[test]
    fn json_roundtrip_is_lossless_and_sparse() {
        let mut l = EnergyLedger::new();
        l.record_saved(0, 0, 1.25);
        l.record_paid(3, 1, 0.5);
        l.record_saved(1, UNATTRIBUTED, 0.0);
        let j = l.to_json();
        if let Json::Obj(cells) = &j {
            assert_eq!(cells.len(), 3, "only non-empty cells on the wire: {j}");
            assert!(cells.contains_key("a100/mm"));
            assert!(cells.contains_key("v100/mv"));
            assert!(cells.contains_key("rtx4090/unattributed"));
        } else {
            panic!("ledger encodes as an object: {j}");
        }
        assert_eq!(EnergyLedger::from_json(&j), l);
        // Empty ledger: empty object, roundtrips, absent parses empty.
        let empty = EnergyLedger::new();
        assert_eq!(EnergyLedger::from_json(&empty.to_json()), empty);
        assert_eq!(EnergyLedger::from_json(&Json::Null), empty);
    }
}
