//! Stage taxonomy for the daemon hot path, plus a stack-only trace
//! accumulator.
//!
//! A request's life inside the daemon decomposes into fixed stages;
//! each request carries a [`StageTrace`] — two fixed arrays on the
//! stack, no heap — and the durations fold into per-stage
//! [`LogHistogram`](super::LogHistogram)s under the state lock the
//! reply bookkeeping already takes. Telemetry therefore adds no
//! allocation and no extra syscall to the exact-hit path
//! (`Instant::now` is a vDSO `clock_gettime`, not a syscall).

/// Number of traced stages — sized for fixed arrays.
pub const N_STAGES: usize = 6;

/// One stage of the daemon hot path.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Stage {
    /// Frame parse: bytes → `Request`.
    Parse = 0,
    /// Shard read-lock + record lookup in the sharded store.
    ShardRead = 1,
    /// Neighbor/snapshot lookup for warm-guess replies on a miss.
    SnapshotLookup = 2,
    /// Claim I/O on the miss path: targeted shard refresh plus the
    /// fleet in-flight claim (lease file create).
    ClaimIo = 3,
    /// Handing the search job to the worker pool or backlog.
    Enqueue = 4,
    /// Serializing + writing the reply frame back to the socket.
    ReplyWrite = 5,
}

impl Stage {
    /// All stages, in hot-path order.
    pub const ALL: [Stage; N_STAGES] = [
        Stage::Parse,
        Stage::ShardRead,
        Stage::SnapshotLookup,
        Stage::ClaimIo,
        Stage::Enqueue,
        Stage::ReplyWrite,
    ];

    /// Stable wire/exposition name.
    pub fn name(self) -> &'static str {
        match self {
            Stage::Parse => "parse",
            Stage::ShardRead => "shard_read",
            Stage::SnapshotLookup => "snapshot_lookup",
            Stage::ClaimIo => "claim_io",
            Stage::Enqueue => "enqueue",
            Stage::ReplyWrite => "reply_write",
        }
    }

    /// Inverse of [`Stage::name`] (for decoding merged fleet views).
    pub fn parse_name(name: &str) -> Option<Stage> {
        Stage::ALL.into_iter().find(|s| s.name() == name)
    }
}

/// Per-request stage durations: fixed arrays, stack-allocated, cheap
/// to pass down the serve call chain by `&mut`.
#[derive(Debug, Clone, Copy, Default)]
pub struct StageTrace {
    secs: [f64; N_STAGES],
    set: [bool; N_STAGES],
}

impl StageTrace {
    pub fn new() -> Self {
        Self::default()
    }

    /// Add a duration to a stage. Accumulates — the miss path touches
    /// claim I/O twice (refresh, then the in-flight claim).
    pub fn add(&mut self, stage: Stage, secs: f64) {
        self.secs[stage as usize] += secs;
        self.set[stage as usize] = true;
    }

    /// The accumulated duration, if the stage ran for this request.
    pub fn get(&self, stage: Stage) -> Option<f64> {
        if self.set[stage as usize] {
            Some(self.secs[stage as usize])
        } else {
            None
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn names_roundtrip() {
        for s in Stage::ALL {
            assert_eq!(Stage::parse_name(s.name()), Some(s));
        }
        assert_eq!(Stage::parse_name("nope"), None);
    }

    #[test]
    fn trace_accumulates_per_stage() {
        let mut t = StageTrace::new();
        assert_eq!(t.get(Stage::ClaimIo), None);
        t.add(Stage::ClaimIo, 1e-4);
        t.add(Stage::ClaimIo, 2e-4);
        t.add(Stage::Parse, 5e-6);
        assert!((t.get(Stage::ClaimIo).unwrap() - 3e-4).abs() < 1e-12);
        assert!((t.get(Stage::Parse).unwrap() - 5e-6).abs() < 1e-12);
        assert_eq!(t.get(Stage::Enqueue), None);
    }
}
