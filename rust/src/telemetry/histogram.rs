//! Fixed-size mergeable log2-bucket histogram.
//!
//! The serving fleet needs percentiles that are cheap to record on the
//! exact-hit path, bounded in memory no matter how long a daemon runs,
//! and exactly mergeable across daemons. A log2-bucket histogram gives
//! all three: `record` is a handful of integer ops on the f64 bit
//! pattern (no `log2()` call, no allocation), the struct is a fixed
//! array of counters, and `merge` is elementwise addition — the merged
//! histogram is *identical* to the histogram of the concatenated sample
//! streams, which is what lets a fleet client sum N daemons' views into
//! one.
//!
//! Bucket `i` covers `[2^(MIN_LOG2+i), 2^(MIN_LOG2+i+1))` seconds, so a
//! quantile is accurate to one power-of-two bucket width (a factor of
//! `√2` either way from the geometric midpoint we report, before the
//! clamp to the observed `[min, max]` tightens it further).

use crate::util::Json;

/// Number of buckets. With `MIN_LOG2 = -30` the span is
/// `[2^-30 s, 2^34 s)` ≈ 1 ns … 500 years — every wall-clock or
/// simulated duration the serving path can produce, with slack.
pub const N_BUCKETS: usize = 64;

/// log2 of the lower bound of bucket 0, in seconds (≈ 0.93 ns).
/// Anything smaller (including zero) lands in bucket 0.
pub const MIN_LOG2: i32 = -30;

/// Fixed-size log2-bucket histogram of durations in seconds.
#[derive(Debug, Clone, PartialEq)]
pub struct LogHistogram {
    buckets: [u64; N_BUCKETS],
    count: u64,
    sum: f64,
    min: f64,
    max: f64,
    invalid: u64,
}

impl Default for LogHistogram {
    fn default() -> Self {
        LogHistogram {
            buckets: [0; N_BUCKETS],
            count: 0,
            sum: 0.0,
            min: f64::INFINITY,
            max: f64::NEG_INFINITY,
            invalid: 0,
        }
    }
}

/// Bucket index for a duration: `clamp(floor(log2(v)) - MIN_LOG2)`.
/// The exponent comes straight from the f64 bit pattern — no float
/// math, no branches beyond the clamps — so recording is O(1) and
/// allocation-free by construction.
fn bucket_of(v: f64) -> usize {
    if !(v.is_finite() && v > 0.0) {
        return 0;
    }
    // IEEE-754 biased exponent; subnormals give -1023 and clamp to 0.
    let e = ((v.to_bits() >> 52) & 0x7ff) as i32 - 1023;
    (e - MIN_LOG2).clamp(0, N_BUCKETS as i32 - 1) as usize
}

/// Lower bound of bucket `i` in seconds.
pub fn bucket_lower(i: usize) -> f64 {
    ((MIN_LOG2 + i as i32) as f64).exp2()
}

impl LogHistogram {
    pub fn new() -> Self {
        Self::default()
    }

    /// Record one duration (seconds). O(1), allocation-free.
    /// Non-finite or non-positive values still clamp into bucket 0 so
    /// `count` stays an honest sample count, but they are tallied in
    /// [`Self::invalid`] — a NaN-producing measurement bug surfaces as
    /// a counter instead of hiding in the smallest bucket.
    pub fn record(&mut self, v: f64) {
        let v = if v.is_finite() && v > 0.0 {
            v
        } else {
            self.invalid += 1;
            0.0
        };
        self.buckets[bucket_of(v)] += 1;
        self.count += 1;
        self.sum += v;
        if v < self.min {
            self.min = v;
        }
        if v > self.max {
            self.max = v;
        }
    }

    /// Fold another histogram in. The result equals the histogram of
    /// the two sample streams concatenated — merge is associative and
    /// commutative, so fleet aggregation order never matters.
    pub fn merge(&mut self, other: &LogHistogram) {
        for (b, o) in self.buckets.iter_mut().zip(other.buckets.iter()) {
            *b += o;
        }
        self.count += other.count;
        self.sum += other.sum;
        self.invalid += other.invalid;
        if other.min < self.min {
            self.min = other.min;
        }
        if other.max > self.max {
            self.max = other.max;
        }
    }

    pub fn count(&self) -> u64 {
        self.count
    }

    pub fn sum(&self) -> f64 {
        self.sum
    }

    pub fn is_empty(&self) -> bool {
        self.count == 0
    }

    /// How many recorded samples were non-finite or non-positive
    /// (clamped into bucket 0).
    pub fn invalid(&self) -> u64 {
        self.invalid
    }

    /// Smallest recorded value (0 when empty).
    pub fn min(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.min
        }
    }

    /// Largest recorded value (0 when empty).
    pub fn max(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.max
        }
    }

    pub fn mean(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum / self.count as f64
        }
    }

    /// Raw count of bucket `i` (for merge pinning and exposition).
    pub fn bucket(&self, i: usize) -> u64 {
        self.buckets[i]
    }

    /// Nearest-rank quantile, `p` in `0..=100`. Walks the cumulative
    /// counts and reports the geometric midpoint of the winning bucket,
    /// clamped to the observed `[min, max]` — so the error is at most
    /// one bucket width and exact at the extremes. Allocation-free.
    pub fn quantile(&self, p: f64) -> f64 {
        if self.count == 0 {
            return 0.0;
        }
        let rank = ((p / 100.0) * self.count as f64).ceil().max(1.0) as u64;
        let rank = rank.min(self.count);
        let mut seen = 0u64;
        for (i, &n) in self.buckets.iter().enumerate() {
            seen += n;
            if seen >= rank {
                let mid = ((MIN_LOG2 + i as i32) as f64 + 0.5).exp2();
                return mid.clamp(self.min, self.max);
            }
        }
        self.max
    }

    /// Samples recorded since `earlier` was snapshotted — the fast
    /// window behind the `health` op's burn-rate evaluation. Bucket and
    /// sample counts subtract exactly (saturating, so a restarted or
    /// unrelated snapshot degrades to `self` instead of underflowing);
    /// `min`/`max` are copied from `self` as a documented approximation
    /// since extremes cannot be un-merged. Quantiles of the delta are
    /// exact to the usual one-bucket width.
    pub fn delta(&self, earlier: &LogHistogram) -> LogHistogram {
        let mut buckets = [0u64; N_BUCKETS];
        for (i, b) in buckets.iter_mut().enumerate() {
            *b = self.buckets[i].saturating_sub(earlier.buckets[i]);
        }
        let count = self.count.saturating_sub(earlier.count);
        LogHistogram {
            buckets,
            count,
            sum: (self.sum - earlier.sum).max(0.0),
            min: if count > 0 { self.min } else { f64::INFINITY },
            max: if count > 0 { self.max } else { f64::NEG_INFINITY },
            invalid: self.invalid.saturating_sub(earlier.invalid),
        }
    }

    /// Wire encoding: counts keyed by bucket index, only non-zero
    /// buckets present (sparse — a fresh daemon's histogram is tiny on
    /// the wire).
    pub fn to_json(&self) -> Json {
        let sparse: std::collections::BTreeMap<String, Json> = self
            .buckets
            .iter()
            .enumerate()
            .filter(|(_, &n)| n > 0)
            .map(|(i, &n)| (i.to_string(), Json::num(n as f64)))
            .collect();
        let mut fields = vec![
            ("count", Json::num(self.count as f64)),
            ("sum", Json::num(self.sum)),
            ("min", Json::num(self.min())),
            ("max", Json::num(self.max())),
            ("buckets", Json::Obj(sparse)),
        ];
        // Sparse like the buckets: only present when something was
        // actually invalid, so healthy frames don't grow.
        if self.invalid > 0 {
            fields.push(("invalid", Json::num(self.invalid as f64)));
        }
        Json::obj(fields)
    }

    /// Decode the wire form. Tolerant: absent fields mean zero/empty,
    /// unknown bucket indices are ignored (a newer daemon with more
    /// buckets degrades gracefully against an older client).
    pub fn from_json(v: &Json) -> LogHistogram {
        let count = v.get("count").and_then(Json::as_f64).unwrap_or(0.0) as u64;
        let mut buckets = [0u64; N_BUCKETS];
        if let Some(Json::Obj(m)) = v.get("buckets") {
            for (k, n) in m {
                if let (Ok(i), Some(n)) = (k.parse::<usize>(), n.as_f64()) {
                    if i < N_BUCKETS {
                        buckets[i] = n as u64;
                    }
                }
            }
        }
        let (min, max) = if count > 0 {
            (
                v.get("min").and_then(Json::as_f64).unwrap_or(0.0),
                v.get("max").and_then(Json::as_f64).unwrap_or(0.0),
            )
        } else {
            (f64::INFINITY, f64::NEG_INFINITY)
        };
        LogHistogram {
            buckets,
            count,
            sum: v.get("sum").and_then(Json::as_f64).unwrap_or(0.0),
            min,
            max,
            invalid: v.get("invalid").and_then(Json::as_f64).unwrap_or(0.0) as u64,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn record_places_values_in_log2_buckets() {
        let mut h = LogHistogram::new();
        h.record(1.0); // 2^0 → bucket -MIN_LOG2 = 30
        h.record(1.5); // same bucket
        h.record(2.0); // bucket 31
        assert_eq!(h.bucket(30), 2);
        assert_eq!(h.bucket(31), 1);
        assert_eq!(h.count(), 3);
        assert!((h.sum() - 4.5).abs() < 1e-12);
    }

    #[test]
    fn degenerate_values_clamp_to_bucket_zero() {
        let mut h = LogHistogram::new();
        h.record(0.0);
        h.record(-1.0);
        h.record(f64::NAN);
        h.record(1e-300); // far below 2^MIN_LOG2
        assert_eq!(h.bucket(0), 4);
        assert_eq!(h.count(), 4);
        assert_eq!(h.min(), 0.0);
        // 0.0, -1.0 and NaN count as invalid; 1e-300 is a legitimate
        // (if tiny) duration and is not.
        assert_eq!(h.invalid(), 3);
        h.record(f64::INFINITY);
        assert_eq!(h.invalid(), 4);
    }

    #[test]
    fn invalid_counter_merges_and_stays_out_of_clean_histograms() {
        let mut a = LogHistogram::new();
        a.record(1e-3);
        assert_eq!(a.invalid(), 0);
        let mut b = LogHistogram::new();
        b.record(f64::NAN);
        b.record(-2.0);
        a.merge(&b);
        assert_eq!(a.invalid(), 2);
        assert_eq!(a.count(), 3);
    }

    #[test]
    fn huge_values_clamp_to_last_bucket() {
        let mut h = LogHistogram::new();
        h.record(1e300);
        assert_eq!(h.bucket(N_BUCKETS - 1), 1);
        assert_eq!(h.max(), 1e300);
    }

    #[test]
    fn quantile_is_exact_at_extremes_and_bounded_between() {
        let mut h = LogHistogram::new();
        for i in 1..=100 {
            h.record(i as f64 * 1e-3); // 1ms..100ms
        }
        assert_eq!(h.quantile(0.0), 1e-3);
        assert_eq!(h.quantile(100.0), 0.1);
        // p50 of 1..=100 ms is 50ms; one bucket = factor 2 either way.
        let p50 = h.quantile(50.0);
        assert!((0.025..=0.1).contains(&p50), "{p50}");
    }

    #[test]
    fn empty_histogram_is_inert() {
        let h = LogHistogram::new();
        assert_eq!(h.quantile(50.0), 0.0);
        assert_eq!(h.min(), 0.0);
        assert_eq!(h.max(), 0.0);
        assert_eq!(h.mean(), 0.0);
        assert!(h.is_empty());
    }

    #[test]
    fn merge_equals_histogram_of_concatenated_streams() {
        let (mut a, mut b, mut union) =
            (LogHistogram::new(), LogHistogram::new(), LogHistogram::new());
        for v in [1e-6, 3e-5, 7e-4, 2e-3] {
            a.record(v);
            union.record(v);
        }
        for v in [9e-7, 4e-4, 0.5] {
            b.record(v);
            union.record(v);
        }
        let mut merged = a.clone();
        merged.merge(&b);
        assert_eq!(merged, union);
        let mut other_order = b;
        other_order.merge(&a);
        assert_eq!(other_order, union);
    }

    #[test]
    fn delta_recovers_the_samples_since_a_snapshot() {
        let mut h = LogHistogram::new();
        for v in [1e-3, 2e-3] {
            h.record(v);
        }
        let snap = h.clone();
        for v in [4e-3, 8e-3, 8e-3] {
            h.record(v);
        }
        h.record(f64::NAN);
        let d = h.delta(&snap);
        assert_eq!(d.count(), 4);
        assert_eq!(d.invalid(), 1);
        assert!((d.sum() - 0.020).abs() < 1e-12);
        assert_eq!(d.bucket(bucket_of(8e-3)), 2);
        assert_eq!(d.bucket(bucket_of(1e-3)), 0, "pre-snapshot samples subtract out");
        // Nothing new since the snapshot → an empty, inert window.
        let empty = h.delta(&h.clone());
        assert!(empty.is_empty());
        assert_eq!(empty.quantile(99.0), 0.0);
        // A snapshot from a different (larger) stream saturates instead
        // of underflowing.
        let weird = snap.delta(&h);
        assert_eq!(weird.count(), 0);
    }

    #[test]
    fn json_roundtrip_is_lossless() {
        let mut h = LogHistogram::new();
        for v in [1e-6, 5e-5, 5e-5, 2e-3, 40.0] {
            h.record(v);
        }
        let back = LogHistogram::from_json(&h.to_json());
        assert_eq!(back, h);
        // Empty histogram roundtrips too.
        let empty = LogHistogram::new();
        assert_eq!(LogHistogram::from_json(&empty.to_json()), empty);
        // The invalid tally survives the wire; absent parses as 0 so
        // old frames (no `invalid` key) still decode.
        h.record(f64::NAN);
        let back = LogHistogram::from_json(&h.to_json());
        assert_eq!(back, h);
        assert_eq!(back.invalid(), 1);
    }
}
