//! Advisory lease files: crash-tolerant, epoch-fenced exclusive
//! ownership over pieces of a shared store.
//!
//! A fleet of serving daemons mounts one sharded store. Appends are
//! already safe (O_APPEND whole-line writes interleave), but shard
//! **rewrites** (eviction, rebalance, torn-tail repair) and in-flight
//! search claims need an owner. A lease is one small JSON file:
//!
//! ```text
//! {"holder":"daemon-412-0","epoch":7,"deadline_ms":1738229400123,"payload":"..."}
//! ```
//!
//! * **acquire** — succeeds when the file is absent, expired, or
//!   already ours; every successful acquire bumps the **epoch**, so a
//!   holder that lost its lease can be told apart from the current one.
//! * **heartbeat** — [`Lease::renew`] extends the deadline while work
//!   is in progress; a crashed holder stops renewing and its lease
//!   expires after the TTL, letting any other daemon reclaim it.
//! * **fencing** — [`Lease::is_current`] re-reads the file and checks
//!   `(holder, epoch)`; a stale holder's guarded write (e.g. a search
//!   write-back after its claim was reclaimed) is rejected instead of
//!   clobbering the new owner's work.
//!
//! The lock is *advisory* and file-based: acquisition is
//! write-then-verify (atomic rename, then a short settle pause and a
//! read-back), which resolves races by last-writer-wins — at most one
//! contender sees itself on disk after the settle window. That is the
//! right trade for this store: leases guard multi-millisecond
//! maintenance and multi-second searches, not nanosecond-scale state.

use crate::util::Json;
use anyhow::Context as _;
use std::path::{Path, PathBuf};

/// Settle pause between writing a candidate lease and the read-back
/// verdict: long enough for a racing writer's rename to land.
const SETTLE_MS: u64 = 2;

/// Milliseconds since the Unix epoch (the lease clock).
pub fn now_ms() -> u64 {
    std::time::SystemTime::now()
        .duration_since(std::time::UNIX_EPOCH)
        .map(|d| d.as_millis() as u64)
        .unwrap_or(0)
}

/// Snapshot of a lease file's contents.
#[derive(Debug, Clone, PartialEq)]
pub struct LeaseInfo {
    pub holder: String,
    pub epoch: u64,
    pub deadline_ms: u64,
    /// Free-form payload (the in-flight tables store the serve key
    /// here, so hash-named claim files stay self-describing).
    pub payload: Option<String>,
}

impl LeaseInfo {
    pub fn is_live(&self, now: u64) -> bool {
        self.deadline_ms > now
    }
}

/// Read a lease file. `None` when the file is absent — or unreadable
/// as a lease, which the next acquire simply overwrites (a torn lease
/// file must never wedge the store).
pub fn read_lease(path: &Path) -> anyhow::Result<Option<LeaseInfo>> {
    let text = match std::fs::read_to_string(path) {
        Ok(t) => t,
        Err(e) if e.kind() == std::io::ErrorKind::NotFound => return Ok(None),
        Err(e) => return Err(e).with_context(|| format!("read lease {path:?}")),
    };
    Ok(parse_lease(&text))
}

fn parse_lease(text: &str) -> Option<LeaseInfo> {
    let v = Json::parse(text).ok()?;
    Some(LeaseInfo {
        holder: v.get("holder")?.as_str()?.to_string(),
        epoch: v.get("epoch")?.as_f64()? as u64,
        deadline_ms: v.get("deadline_ms")?.as_f64()? as u64,
        payload: v.get("payload").and_then(|p| p.as_str()).map(|s| s.to_string()),
    })
}

/// A lease this process believes it holds. Guarded operations must
/// check [`Lease::is_current`] (or go through an API that does) —
/// holding the struct alone proves nothing once the TTL has passed.
/// Cloning copies the identity, not the ownership: clones renew and
/// verify against the same `(holder, epoch)`.
#[derive(Debug, Clone)]
pub struct Lease {
    path: PathBuf,
    holder: String,
    epoch: u64,
    ttl_ms: u64,
    payload: Option<String>,
}

impl Lease {
    /// Try to acquire the lease at `path` for `holder`. Returns
    /// `Ok(None)` when another holder's live lease is in the way (or a
    /// racing acquirer won the write).
    pub fn acquire(
        path: &Path,
        holder: &str,
        ttl_ms: u64,
        payload: Option<&str>,
    ) -> anyhow::Result<Option<Lease>> {
        let now = now_ms();
        let cur = read_lease(path)?;
        if let Some(cur) = &cur {
            if cur.is_live(now) && cur.holder != holder {
                return Ok(None);
            }
        }
        let lease = Lease {
            path: path.to_path_buf(),
            holder: holder.to_string(),
            epoch: cur.map(|c| c.epoch).unwrap_or(0) + 1,
            ttl_ms,
            payload: payload.map(|s| s.to_string()),
        };
        lease.write(now + ttl_ms)?;
        // Let a racing writer's rename land before the verdict: after
        // the settle pause, last-writer-wins and every loser sees the
        // winner on disk.
        std::thread::sleep(std::time::Duration::from_millis(SETTLE_MS));
        if lease.is_current()? {
            Ok(Some(lease))
        } else {
            Ok(None)
        }
    }

    /// Heartbeat: extend the deadline by one TTL if the lease is still
    /// ours. Returns `false` when it was lost (expired and reclaimed).
    /// Same write-then-settle-then-verify shape as acquire, so a renew
    /// racing a reclaim converges on one on-disk owner before either
    /// side trusts its verdict (heartbeating at ~TTL/3 keeps renewals
    /// far from the deadline, making that race a crash-recovery edge).
    pub fn renew(&self) -> anyhow::Result<bool> {
        if !self.is_current()? {
            return Ok(false);
        }
        self.write(now_ms() + self.ttl_ms)?;
        std::thread::sleep(std::time::Duration::from_millis(SETTLE_MS));
        self.is_current()
    }

    /// Fencing check: does the file still name this `(holder, epoch)`,
    /// unexpired?
    pub fn is_current(&self) -> anyhow::Result<bool> {
        Ok(match read_lease(&self.path)? {
            Some(info) => {
                info.holder == self.holder && info.epoch == self.epoch && info.is_live(now_ms())
            }
            None => false,
        })
    }

    /// Release the lease: expire it in place (epoch preserved, so the
    /// next acquire still fences us out). Releasing a lease we already
    /// lost is a no-op.
    pub fn release(&self) -> anyhow::Result<()> {
        if self.is_current()? {
            self.write(0)?;
        }
        Ok(())
    }

    pub fn epoch(&self) -> u64 {
        self.epoch
    }

    pub fn holder(&self) -> &str {
        &self.holder
    }

    pub fn payload(&self) -> Option<&str> {
        self.payload.as_deref()
    }

    /// Write the lease file atomically (per-holder tmp + rename).
    fn write(&self, deadline_ms: u64) -> anyhow::Result<()> {
        let mut fields = vec![
            ("holder", Json::str(self.holder.clone())),
            ("epoch", Json::num(self.epoch as f64)),
            ("deadline_ms", Json::num(deadline_ms as f64)),
        ];
        if let Some(p) = &self.payload {
            fields.push(("payload", Json::str(p.clone())));
        }
        let tmp = self.path.with_extension(format!("{:08x}.tmp", holder_tag(&self.holder)));
        let text = Json::obj(fields).to_string();
        std::fs::write(&tmp, &text).with_context(|| format!("write lease tmp {tmp:?}"))?;
        std::fs::rename(&tmp, &self.path)
            .with_context(|| format!("replace lease {:?}", self.path))?;
        Ok(())
    }
}

/// Short stable tag of a holder id (tmp-file disambiguation between
/// racing acquirers).
fn holder_tag(holder: &str) -> u32 {
    let mut h: u32 = 0x811c9dc5;
    for b in holder.as_bytes() {
        h ^= *b as u32;
        h = h.wrapping_mul(0x01000193);
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp_lease(tag: &str) -> PathBuf {
        let dir = std::env::temp_dir()
            .join(format!("ecokernel_lease_test_{tag}_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        dir.join("lease.json")
    }

    #[test]
    fn acquire_is_exclusive_until_released() {
        let path = tmp_lease("exclusive");
        let a = Lease::acquire(&path, "a", 60_000, None).unwrap().expect("a acquires");
        assert!(a.is_current().unwrap());
        // A live foreign lease blocks b.
        assert!(Lease::acquire(&path, "b", 60_000, None).unwrap().is_none());
        // Release frees it; the epoch advances across owners.
        a.release().unwrap();
        let b = Lease::acquire(&path, "b", 60_000, None).unwrap().expect("b acquires");
        assert!(b.epoch() > a.epoch());
        assert!(!a.is_current().unwrap(), "released lease is fenced out");
        let _ = std::fs::remove_dir_all(path.parent().unwrap());
    }

    #[test]
    fn expired_lease_is_reclaimed_and_old_holder_fenced() {
        let path = tmp_lease("expiry");
        let a = Lease::acquire(&path, "a", 50, None).unwrap().expect("a acquires");
        // Simulated crash: a stops renewing; after the TTL, b reclaims.
        std::thread::sleep(std::time::Duration::from_millis(120));
        assert!(!a.is_current().unwrap(), "expired lease is no longer current");
        let b = Lease::acquire(&path, "b", 60_000, None).unwrap().expect("b reclaims");
        assert!(b.is_current().unwrap());
        assert!(b.epoch() > a.epoch(), "reclaim bumps the epoch");
        // The crashed holder's guarded writes must now be rejected.
        assert!(!a.is_current().unwrap());
        let _ = std::fs::remove_dir_all(path.parent().unwrap());
    }

    #[test]
    fn renew_extends_and_fails_after_takeover() {
        let path = tmp_lease("renew");
        let a = Lease::acquire(&path, "a", 60, None).unwrap().expect("a acquires");
        for _ in 0..4 {
            std::thread::sleep(std::time::Duration::from_millis(25));
            assert!(a.renew().unwrap(), "heartbeat keeps the lease alive past one TTL");
        }
        // Stop heartbeating, let it expire, let b take over.
        std::thread::sleep(std::time::Duration::from_millis(130));
        let b = Lease::acquire(&path, "b", 60_000, None).unwrap().expect("b reclaims");
        assert!(!a.renew().unwrap(), "renew after takeover reports the loss");
        assert!(b.is_current().unwrap(), "a failed renew does not disturb the new owner");
        let _ = std::fs::remove_dir_all(path.parent().unwrap());
    }

    #[test]
    fn payload_travels_with_the_lease() {
        let path = tmp_lease("payload");
        let a = Lease::acquire(&path, "a", 60_000, Some("mm1|a100|energy_aware|fp"))
            .unwrap()
            .expect("acquires");
        assert_eq!(a.payload(), Some("mm1|a100|energy_aware|fp"));
        let info = read_lease(&path).unwrap().expect("lease on disk");
        assert_eq!(info.payload.as_deref(), Some("mm1|a100|energy_aware|fp"));
        assert_eq!(info.holder, "a");
        let _ = std::fs::remove_dir_all(path.parent().unwrap());
    }

    #[test]
    fn corrupt_lease_file_reads_as_absent_and_is_overwritten() {
        let path = tmp_lease("corrupt");
        std::fs::write(&path, "{torn").unwrap();
        assert_eq!(read_lease(&path).unwrap(), None);
        let a = Lease::acquire(&path, "a", 60_000, None).unwrap().expect("acquires over torn file");
        assert!(a.is_current().unwrap());
        let _ = std::fs::remove_dir_all(path.parent().unwrap());
    }

    #[test]
    fn same_holder_reacquires_its_own_live_lease() {
        let path = tmp_lease("reacquire");
        let a1 = Lease::acquire(&path, "a", 60_000, None).unwrap().expect("first");
        let a2 = Lease::acquire(&path, "a", 60_000, None).unwrap().expect("same holder again");
        assert!(a2.epoch() > a1.epoch(), "reacquire still bumps the epoch");
        assert!(!a1.is_current().unwrap(), "the older guard is fenced");
        assert!(a2.is_current().unwrap());
        let _ = std::fs::remove_dir_all(path.parent().unwrap());
    }
}
