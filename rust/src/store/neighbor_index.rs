//! An incremental nearest-neighbor index over the store's workload
//! shapes — the structure that takes the miss path's warm-guess lookup
//! from O(store) to O(candidate cells).
//!
//! [`super::neighbors_among`] is the reference semantics: the latest
//! record per foreign workload id on the requested GPU (with a
//! non-empty measured pool), ranked by [`super::similarity`]'s
//! log-shape distance. Brute-forcing that scans every record on every
//! miss; a serving daemon under zipf traffic pays it constantly. This
//! index keeps the same answer reachable through two levels of
//! narrowing, maintained incrementally on append, fleet refresh,
//! eviction rewrite, rebalance, and legacy import:
//!
//! * **regime buckets** — per (GPU, im2col?, matrix-vector?) group,
//!   mirroring the fixed structural penalties of
//!   [`super::similarity::gemm_distance`]: a bucket whose regime
//!   mismatch penalty alone exceeds the current worst kept candidate
//!   is never opened;
//! * **log-dim cells** — within a bucket, workload ids grouped by their
//!   [`GemmView`] quantized to [`CELL_LN`]-wide cells in ln-space (one
//!   doubling per axis per cell). Each occupied cell carries a provable
//!   lower bound on the distance of anything inside it, so a query
//!   visits cells in bound order and stops as soon as no remaining cell
//!   can improve the running top-`max_n`.
//!
//! Queries are therefore **exactly** equal to the brute force (the
//! sharded-store parity test pins this), while visiting only the
//! occupied cells near the target — not every record.
//!
//! "Latest per workload id" follows the store's shard-major record
//! order: the index keeps one slot per (shard → latest measured record
//! in that shard) and serves the highest shard's slot, which is the
//! record a shard-major scan would have kept last. Shard-local
//! maintenance (a refresh or eviction rewrite of one shard) therefore
//! touches only that shard's slots.

use super::record::TuningRecord;
use super::similarity::{gemm_distance, IM2COL_PENALTY, MV_REGIME_PENALTY};
use crate::workload::{GemmView, Workload};
use std::collections::{BTreeMap, HashMap, HashSet};
use std::sync::Arc;

/// Cell width in ln-space: one doubling per axis per cell.
pub const CELL_LN: f64 = std::f64::consts::LN_2;

/// Slack subtracted from every cell's distance lower bound so that
/// floating-point drift between the bound arithmetic and
/// [`gemm_distance`] can never prune a cell holding a true candidate.
const BOUND_SLACK: f64 = 1e-9;

/// Identity of one indexed entry: neighbor selection is per
/// (GPU, workload id).
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
struct EntryKey {
    gpu: String,
    workload_id: String,
}

/// A quantized log-shape cell (floor of each ln-dimension / CELL_LN).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
struct Cell {
    b: i64,
    m: i64,
    n: i64,
    k: i64,
}

fn ln_coords(view: &GemmView) -> [f64; 4] {
    let ln = |x: usize| (x.max(1) as f64).ln();
    [ln(view.batch), ln(view.m), ln(view.n), ln(view.k)]
}

impl Cell {
    fn of(view: &GemmView) -> Cell {
        let [b, m, n, k] = ln_coords(view).map(|x| (x / CELL_LN).floor() as i64);
        Cell { b, m, n, k }
    }

    /// Lower bound on the log-space distance from the target's
    /// ln-coordinates `t` to any shape quantizing into this cell
    /// (distance from `t` to the cell's axis-aligned box).
    fn min_distance(&self, t: &[f64; 4]) -> f64 {
        let mut sum = 0.0;
        for (c, ti) in [self.b, self.m, self.n, self.k].iter().zip(t) {
            let lo = *c as f64 * CELL_LN;
            let hi = lo + CELL_LN;
            let d = if *ti < lo {
                lo - *ti
            } else if *ti > hi {
                *ti - hi
            } else {
                0.0
            };
            sum += d * d;
        }
        sum.sqrt()
    }
}

/// A regime bucket: workloads whose structural penalties against any
/// target are identical.
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
struct BucketKey {
    gpu: String,
    im2col: bool,
    mv: bool,
}

fn bucket_of(gpu: &str, view: &GemmView) -> BucketKey {
    BucketKey { gpu: gpu.to_string(), im2col: view.im2col, mv: view.m == 1 }
}

/// Per-shard slots of one entry: shard index → that shard's latest
/// record with a measured pool. The entry served is the highest
/// shard's slot (shard-major "latest").
type ShardSlots = BTreeMap<usize, Arc<TuningRecord>>;

/// Workload ids present per occupied cell of one bucket.
type CellIds = HashMap<Cell, HashSet<String>>;

/// The incremental neighbor index. Cloning is O(distinct workload
/// ids), not O(records) — snapshots handed to background searches
/// freeze a copy cheaply.
#[derive(Debug, Clone, Default)]
pub struct NeighborIndex {
    entries: HashMap<EntryKey, ShardSlots>,
    buckets: HashMap<BucketKey, CellIds>,
    /// Entry keys holding a slot from each shard (rebuild bookkeeping).
    by_shard: Vec<HashSet<EntryKey>>,
}

impl NeighborIndex {
    /// Index one appended record. Records without a measured pool are
    /// invisible to neighbor selection and are skipped — exactly as the
    /// brute force skips them (they never shadow an earlier measured
    /// record either).
    pub fn insert(&mut self, shard: usize, rec: &Arc<TuningRecord>) {
        if rec.measured.is_empty() {
            return;
        }
        if self.by_shard.len() <= shard {
            self.by_shard.resize_with(shard + 1, HashSet::new);
        }
        let view = rec.workload.gemm_view();
        self.buckets
            .entry(bucket_of(&rec.gpu, &view))
            .or_default()
            .entry(Cell::of(&view))
            .or_default()
            .insert(rec.workload_id.clone());
        let key = EntryKey { gpu: rec.gpu.clone(), workload_id: rec.workload_id.clone() };
        self.by_shard[shard].insert(key.clone());
        self.entries.entry(key).or_default().insert(shard, rec.clone());
    }

    /// Drop every slot contributed by `shard` (the shard is about to be
    /// reloaded or rewritten).
    pub fn remove_shard(&mut self, shard: usize) {
        if shard >= self.by_shard.len() {
            return;
        }
        for key in std::mem::take(&mut self.by_shard[shard]) {
            let Some(slots) = self.entries.get_mut(&key) else { continue };
            let removed = slots.remove(&shard);
            if !slots.is_empty() {
                continue;
            }
            self.entries.remove(&key);
            // Last slot gone: the workload id leaves its cell too.
            let Some(rec) = removed else { continue };
            let view = rec.workload.gemm_view();
            let bucket = bucket_of(&rec.gpu, &view);
            if let Some(cells) = self.buckets.get_mut(&bucket) {
                let cell = Cell::of(&view);
                if let Some(ids) = cells.get_mut(&cell) {
                    ids.remove(&key.workload_id);
                    if ids.is_empty() {
                        cells.remove(&cell);
                    }
                }
                if cells.is_empty() {
                    self.buckets.remove(&bucket);
                }
            }
        }
    }

    /// Re-index one shard from its current records (eviction rewrite,
    /// generation-bump reload, rebalance).
    pub fn rebuild_shard(&mut self, shard: usize, records: &[Arc<TuningRecord>]) {
        self.remove_shard(shard);
        for rec in records {
            self.insert(shard, rec);
        }
    }

    /// Distinct (GPU, workload id) entries currently indexed.
    pub fn n_entries(&self) -> usize {
        self.entries.len()
    }

    /// Nearest cached neighbors of `workload` on `gpu` — identical to
    /// [`super::neighbors_among`] over the indexed records in
    /// shard-major order, but visiting only candidate cells.
    pub fn neighbors(
        &self,
        workload: Workload,
        gpu: &str,
        max_n: usize,
    ) -> Vec<(Arc<TuningRecord>, f64)> {
        if max_n == 0 {
            return Vec::new();
        }
        let id = workload.id();
        let target = workload.gemm_view();
        let t = ln_coords(&target);

        // Every occupied cell of this GPU's four regime buckets, with a
        // provable lower bound on the distance of anything inside.
        let mut cells: Vec<(f64, &HashSet<String>)> = Vec::new();
        for im2col in [false, true] {
            for mv in [false, true] {
                let bucket = BucketKey { gpu: gpu.to_string(), im2col, mv };
                let Some(cell_ids) = self.buckets.get(&bucket) else { continue };
                let mut penalty = 0.0;
                if im2col != target.im2col {
                    penalty += IM2COL_PENALTY;
                }
                if mv != (target.m == 1) {
                    penalty += MV_REGIME_PENALTY;
                }
                for (cell, ids) in cell_ids {
                    cells.push((penalty + cell.min_distance(&t) - BOUND_SLACK, ids));
                }
            }
        }
        cells.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap_or(std::cmp::Ordering::Equal));

        // Scan cells in bound order. Once max_n candidates are held, a
        // cell whose bound exceeds the worst kept distance — and hence
        // every later cell — can contain no candidate that would make
        // the cut (the bound slack keeps exact ties scannable).
        let mut out: Vec<(Arc<TuningRecord>, f64)> = Vec::new();
        for (bound, ids) in cells {
            if out.len() >= max_n {
                let worst = out.last().map(|(_, d)| *d).unwrap_or(f64::INFINITY);
                if bound > worst {
                    break;
                }
            }
            for wid in ids {
                if *wid == id {
                    continue;
                }
                let key = EntryKey { gpu: gpu.to_string(), workload_id: wid.clone() };
                let Some(slots) = self.entries.get(&key) else { continue };
                let Some((_, rec)) = slots.iter().next_back() else { continue };
                let d = gemm_distance(&target, &rec.workload.gemm_view());
                out.push((rec.clone(), d));
            }
            out.sort_by(|a, b| {
                a.1.partial_cmp(&b.1)
                    .unwrap_or(std::cmp::Ordering::Equal)
                    .then_with(|| a.0.workload_id.cmp(&b.0.workload_id))
            });
            out.truncate(max_n);
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::GpuArch;
    use crate::store::neighbors_among;
    use crate::util::Rng;
    use crate::workload::suites;

    /// A cheap handmade record (no search): enough structure for
    /// neighbor selection.
    fn rec(w: Workload, gpu: GpuArch, seed: u64, measured: bool) -> Arc<TuningRecord> {
        let mut r = TuningRecord::synthetic(w, gpu, seed);
        if !measured {
            r.measured.clear();
        }
        Arc::new(r)
    }

    /// Identity capturing WHICH record was selected for a workload id.
    fn picks<'a, I>(results: I) -> Vec<(String, u64, f64)>
    where
        I: IntoIterator<Item = (&'a TuningRecord, f64)>,
    {
        results.into_iter().map(|(r, d)| (r.workload_id.clone(), r.seed, d)).collect()
    }

    fn assert_parity(
        index: &NeighborIndex,
        shards: &[Vec<Arc<TuningRecord>>],
        targets: &[Workload],
        tag: &str,
    ) {
        for &target in targets {
            for gpu in ["a100", "v100"] {
                for max_n in [1, 3, 8] {
                    let indexed = index.neighbors(target, gpu, max_n);
                    let fast = picks(indexed.iter().map(|(r, d)| (r.as_ref(), *d)));
                    let brute = picks(neighbors_among(
                        shards.iter().flatten().map(|r| r.as_ref()),
                        target,
                        gpu,
                        max_n,
                    ));
                    assert_eq!(fast, brute, "{tag}: target={target} gpu={gpu} max_n={max_n}");
                }
            }
        }
    }

    #[test]
    fn randomized_parity_with_brute_force() {
        let mut rng = Rng::seed_from_u64(41);
        let n_shards = 5;
        let mut shards: Vec<Vec<Arc<TuningRecord>>> = vec![Vec::new(); n_shards];
        let mut index = NeighborIndex::default();

        fn dim(rng: &mut Rng, hi: usize) -> usize {
            1usize << rng.gen_range(0, hi)
        }
        let mut workloads: Vec<Workload> = vec![suites::CONV1, suites::CONV2, suites::CONV3];
        for _ in 0..24 {
            let mv = rng.gen_f64() < 0.3;
            workloads.push(if mv {
                Workload::MatVec {
                    batch: dim(&mut rng, 6),
                    n: dim(&mut rng, 12),
                    k: dim(&mut rng, 12),
                }
            } else {
                Workload::MatMul {
                    batch: dim(&mut rng, 4),
                    m: dim(&mut rng, 12),
                    n: dim(&mut rng, 12),
                    k: dim(&mut rng, 12),
                }
            });
        }
        for (i, &w) in workloads.iter().enumerate() {
            let gpu = if i % 3 == 0 { GpuArch::V100 } else { GpuArch::A100 };
            // Every 5th record has no measured pool: invisible to
            // neighbor selection, and it must not shadow anything.
            let r = rec(w, gpu, i as u64, i % 5 != 0);
            let shard = (i * 7 + 3) % n_shards;
            shards[shard].push(r.clone());
            index.insert(shard, &r);
        }
        let targets =
            [suites::MM1, suites::MV3, suites::CONV2, workloads[3], workloads[10], workloads[20]];
        assert_parity(&index, &shards, &targets, "after inserts");

        // Duplicate workload ids across shards: the highest shard's
        // latest measured record must win, exactly as a shard-major
        // scan would pick it.
        let dup = rec(workloads[4], GpuArch::A100, 900, true);
        shards[1].push(dup.clone());
        index.insert(1, &dup);
        let dup2 = rec(workloads[4], GpuArch::A100, 901, true);
        shards[4].push(dup2.clone());
        index.insert(4, &dup2);
        assert_parity(&index, &shards, &targets, "after cross-shard duplicates");

        // Shard rewrite (eviction): drop half of shard 4's records and
        // rebuild its slots.
        let mut keep = Vec::new();
        for (i, r) in shards[4].iter().enumerate() {
            if i % 2 == 0 {
                keep.push(r.clone());
            }
        }
        shards[4] = keep;
        index.rebuild_shard(4, &shards[4]);
        assert_parity(&index, &shards, &targets, "after shard rewrite");

        // Shard reload to empty (foreign truncation).
        shards[2].clear();
        index.rebuild_shard(2, &shards[2]);
        assert_parity(&index, &shards, &targets, "after shard truncation");
    }

    #[test]
    fn unmeasured_records_are_invisible_but_do_not_shadow() {
        let mut index = NeighborIndex::default();
        let measured = rec(suites::MM1, GpuArch::A100, 1, true);
        let bare = rec(suites::MM1, GpuArch::A100, 2, false);
        index.insert(0, &measured);
        index.insert(0, &bare); // later, but unmeasured: ignored
        let n = index.neighbors(suites::MM2, "a100", 4);
        assert_eq!(n.len(), 1);
        assert_eq!(n[0].0.seed, 1, "the measured record still serves");
        assert_eq!(index.n_entries(), 1);
    }

    #[test]
    fn query_excludes_self_and_respects_gpu() {
        let mut index = NeighborIndex::default();
        index.insert(0, &rec(suites::MM1, GpuArch::A100, 1, true));
        index.insert(0, &rec(suites::MM2, GpuArch::V100, 2, true));
        assert!(index.neighbors(suites::MM1, "a100", 4).is_empty(), "self excluded");
        assert!(index.neighbors(suites::MM1, "h100", 4).is_empty(), "unknown gpu empty");
        let n = index.neighbors(suites::MM1, "v100", 4);
        assert_eq!(n.len(), 1);
        assert_eq!(n[0].0.workload_id, suites::MM2.id());
    }

    #[test]
    fn cell_bound_never_exceeds_true_distance() {
        let mut rng = Rng::seed_from_u64(17);
        for _ in 0..500 {
            let mut dim = || 1 + (rng.gen_f64() * 4000.0) as usize;
            let a = GemmView { batch: dim(), m: dim(), n: dim(), k: dim(), im2col: false };
            let b = GemmView { batch: dim(), m: dim(), n: dim(), k: dim(), im2col: false };
            let bound = Cell::of(&b).min_distance(&ln_coords(&a)) - BOUND_SLACK;
            let true_d = gemm_distance(&a, &b);
            assert!(
                bound <= true_d,
                "cell bound {bound} exceeds true distance {true_d} for {a:?} vs {b:?}"
            );
        }
    }
}
