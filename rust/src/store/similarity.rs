//! Workload similarity for warm-start transfer: a log-space metric
//! over [`GemmView`] shapes.
//!
//! Every workload family lowers to an implicit (batch, M, N, K) GEMM,
//! so shape similarity is distance in log-dimension space — a GEMM
//! twice as large in every dimension is "one doubling away", not "a
//! billion MACs away". Structural mismatches that change which
//! schedules are even legal (im2col indexing, the M == 1 matrix-vector
//! regime) add fixed penalties on top.

use crate::workload::GemmView;

/// Penalty when one side is an implicit-im2col GEMM and the other not.
pub const IM2COL_PENALTY: f64 = 1.0;

/// Penalty when one side is MV-shaped (M == 1) and the other is not —
/// their schedule spaces barely overlap.
pub const MV_REGIME_PENALTY: f64 = 2.0;

/// Log-space distance between two GEMM views. 0 = identical shape;
/// ~0.7 per doubled dimension; structural mismatches add their
/// penalties.
pub fn gemm_distance(a: &GemmView, b: &GemmView) -> f64 {
    let ln = |x: usize| (x.max(1) as f64).ln();
    let db = ln(a.batch) - ln(b.batch);
    let dm = ln(a.m) - ln(b.m);
    let dn = ln(a.n) - ln(b.n);
    let dk = ln(a.k) - ln(b.k);
    let mut dist = (db * db + dm * dm + dn * dn + dk * dk).sqrt();
    if a.im2col != b.im2col {
        dist += IM2COL_PENALTY;
    }
    if (a.m == 1) != (b.m == 1) {
        dist += MV_REGIME_PENALTY;
    }
    dist
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::suites;

    fn d(a: crate::workload::Workload, b: crate::workload::Workload) -> f64 {
        gemm_distance(&a.gemm_view(), &b.gemm_view())
    }

    #[test]
    fn identical_shapes_are_zero() {
        assert_eq!(d(suites::MM1, suites::MM1), 0.0);
    }

    #[test]
    fn metric_is_symmetric() {
        let ab = d(suites::MM1, suites::MM4);
        let ba = d(suites::MM4, suites::MM1);
        assert!((ab - ba).abs() < 1e-12);
    }

    #[test]
    fn within_family_is_closer_than_across() {
        // MM1 -> MM2 (one doubling per dim) must beat MM1 -> MV3.
        assert!(d(suites::MM1, suites::MM2) < d(suites::MM1, suites::MV3));
        // MV shapes cluster together.
        assert!(d(suites::MV3, suites::MV4) < d(suites::MV3, suites::MM1));
        // CONV 1x1 shapes differ only in batch.
        assert!(d(suites::CONV2, suites::CONV3) < d(suites::CONV2, suites::CONV1));
    }

    #[test]
    fn mv_regime_mismatch_is_penalized() {
        let mm = suites::MM1.gemm_view();
        let mv = suites::MV3.gemm_view();
        assert!(gemm_distance(&mm, &mv) >= MV_REGIME_PENALTY);
    }

    #[test]
    fn doubling_every_dim_is_about_ln2_per_dim() {
        // MM1 (1,512,512,512) vs MM2 (1,1024,1024,1024): 3 doubled dims.
        let got = d(suites::MM1, suites::MM2);
        let want = (3.0f64).sqrt() * (2.0f64).ln();
        assert!((got - want).abs() < 1e-9, "{got} vs {want}");
    }
}
