//! The persistent tuning store: an on-disk, append-only cache of
//! finished searches plus warm-start transfer for unseen workloads.
//!
//! Production deployments see the same workloads over and over; paying
//! the full search cost (hours of NVML measurement in the paper's
//! setting) per repeat is the dominant amortized cost. This subsystem
//! makes search results durable and reusable:
//!
//! * [`TuningStore`] — a JSONL file (`tuning_store.jsonl`) of
//!   schema-versioned [`TuningRecord`]s keyed by
//!   `(workload id, GPU arch, search mode)` + a config fingerprint.
//!   Append-only writes are crash-safe and safe under concurrent
//!   workers; [`TuningStore::prune`] compacts superseded records.
//! * **exact hit** — a repeat search returns the cached kernel with a
//!   zero measurement clock (0 NVML measurements, 0 simulated seconds).
//! * **warm-start transfer** ([`transfer`]) — an unseen workload seeds
//!   its genetic population, GBDT dataset, and dynamic-k controller
//!   from its nearest cached neighbors (log-shape similarity,
//!   [`similarity`]), cutting on-device measurements from round 0.
//! * **neighbor index** ([`neighbor_index`]) — an incremental
//!   log-shape index maintained by the sharded store, so the serving
//!   miss path's nearest-neighbor lookup (and transfer inside a
//!   snapshot-driven search) visits candidate buckets, never the whole
//!   store.
//!
//! Enabled via [`crate::config::StoreConfig`] (`--store DIR` on the
//! CLI); the stateless path is untouched when no store is configured.

pub mod lease;
pub mod neighbor_index;
pub mod record;
pub mod sharded;
pub mod similarity;
pub mod transfer;

pub use lease::{Lease, LeaseInfo};
pub use neighbor_index::NeighborIndex;
pub use record::{config_fingerprint, StoredKernel, TuningRecord, SCHEMA_VERSION};
pub use sharded::{serve_key, AppendOutcome, EvictedKey, EvictionReport, ShardedStore};
pub use similarity::gemm_distance;
pub use transfer::WarmStart;

use crate::config::SearchConfig;
use crate::util::Json;
use crate::workload::Workload;
use anyhow::{anyhow, Context as _};
use std::collections::{BTreeMap, HashSet};
use std::path::{Path, PathBuf};
use std::sync::Arc;

/// File name of the store inside its directory.
pub const STORE_FILE: &str = "tuning_store.jsonl";

/// Append one JSON value as one line (O_APPEND, creating the file) —
/// the single append path shared by the flat store, the sharded store,
/// and the LRU sidecar. Payload and newline go down in ONE write so
/// concurrent appenders interleave whole lines and a crash can tear at
/// most the final line. Returns the bytes written (line + newline).
pub(crate) fn append_jsonl(path: &Path, value: &Json) -> anyhow::Result<usize> {
    use std::io::Write as _;
    let mut f = std::fs::OpenOptions::new()
        .create(true)
        .append(true)
        .open(path)
        .with_context(|| format!("append to {path:?}"))?;
    let mut line = value.to_string();
    line.push('\n');
    f.write_all(line.as_bytes()).with_context(|| format!("append to {path:?}"))?;
    Ok(line.len())
}

/// Append one record to a store directory **without parsing the store**
/// (one JSONL line, O_APPEND): the write-back path for workers that
/// consult a shared parsed snapshot instead of reopening the file.
pub fn append_record(dir: &Path, rec: &TuningRecord) -> anyhow::Result<()> {
    std::fs::create_dir_all(dir).with_context(|| format!("create tuning store dir {dir:?}"))?;
    append_jsonl(&dir.join(STORE_FILE), &rec.to_json())?;
    Ok(())
}

/// Nearest-neighbor selection shared by [`TuningStore`] and
/// [`ShardedStore`]: the latest record per foreign workload id on
/// `gpu` (with a non-empty measured pool), sorted by shape distance
/// with a deterministic tie-break on workload id, truncated to `max_n`.
/// "Latest" follows the iteration order of `records`. This is the
/// reference the [`NeighborIndex`] is parity-tested against.
pub fn neighbors_among<'a, I>(
    records: I,
    workload: Workload,
    gpu: &str,
    max_n: usize,
) -> Vec<(&'a TuningRecord, f64)>
where
    I: IntoIterator<Item = &'a TuningRecord>,
{
    let records: Vec<&TuningRecord> = records.into_iter().collect();
    neighbor_indices(&records, workload, gpu, max_n)
        .into_iter()
        .map(|(i, d)| (records[i], d))
        .collect()
}

/// The selection core behind [`neighbors_among`] and the index-less
/// [`TuningStore::neighbors`] path, on positions so either caller can
/// map back to its own ownership (refs vs `Arc` clones) without
/// duplicating the filter/sort/truncate rules.
fn neighbor_indices(
    records: &[&TuningRecord],
    workload: Workload,
    gpu: &str,
    max_n: usize,
) -> Vec<(usize, f64)> {
    let id = workload.id();
    let target = workload.gemm_view();
    let mut latest: BTreeMap<&str, usize> = BTreeMap::new();
    for (i, r) in records.iter().enumerate() {
        if r.gpu == gpu && r.workload_id != id && !r.measured.is_empty() {
            latest.insert(r.workload_id.as_str(), i);
        }
    }
    let mut out: Vec<(usize, f64)> = latest
        .into_values()
        .map(|i| (i, gemm_distance(&target, &records[i].workload.gemm_view())))
        .collect();
    out.sort_by(|a, b| {
        a.1.partial_cmp(&b.1)
            .unwrap_or(std::cmp::Ordering::Equal)
            .then_with(|| records[a.0].workload_id.cmp(&records[b.0].workload_id))
    });
    out.truncate(max_n);
    out
}

/// An open tuning store: the on-disk JSONL file plus its parsed
/// records. Records are held as `Arc<TuningRecord>` so snapshots and
/// the sharded store share one allocation per record (ROADMAP
/// "Snapshot incrementality": cloning a snapshot is pointer clones).
#[derive(Debug, Clone)]
pub struct TuningStore {
    dir: PathBuf,
    path: PathBuf,
    records: Vec<Arc<TuningRecord>>,
    /// Shape index frozen by the sharded store when it snapshots
    /// itself, so warm-start transfer inside a background search pays
    /// the same candidate-bucket lookup as the daemon's miss path.
    /// `None` for flat CLI stores, which brute-force scan.
    index: Option<Arc<NeighborIndex>>,
}

/// Aggregate store statistics (the `ecokernel cache stats` view).
#[derive(Debug, Clone, Default, PartialEq)]
pub struct StoreStats {
    pub n_records: usize,
    /// Distinct workload ids.
    pub n_workloads: usize,
    /// Distinct (workload, gpu, mode, fingerprint) keys.
    pub n_keys: usize,
    /// NVML energy measurements the recorded searches paid for.
    pub total_energy_measurements: usize,
    /// Simulated seconds the recorded searches paid for — what an exact
    /// hit saves.
    pub total_sim_time_s: f64,
}

impl TuningStore {
    /// Open (creating the directory if needed) and load every record.
    /// A corrupt line or an incompatible schema version is an error —
    /// the store never silently drops data.
    pub fn open(dir: &Path) -> anyhow::Result<TuningStore> {
        std::fs::create_dir_all(dir)
            .with_context(|| format!("create tuning store dir {dir:?}"))?;
        let path = dir.join(STORE_FILE);
        let mut records = Vec::new();
        if path.exists() {
            let text = std::fs::read_to_string(&path)
                .with_context(|| format!("read tuning store {path:?}"))?;
            for (lineno, line) in text.lines().enumerate() {
                if line.trim().is_empty() {
                    continue;
                }
                let v = Json::parse(line)
                    .map_err(|e| anyhow!("{path:?} line {}: {e}", lineno + 1))?;
                let rec = TuningRecord::from_json(&v)
                    .map_err(|e| anyhow!("{path:?} line {}: {e}", lineno + 1))?;
                records.push(Arc::new(rec));
            }
        }
        Ok(TuningStore { dir: dir.to_path_buf(), path, records, index: None })
    }

    pub fn dir(&self) -> &Path {
        &self.dir
    }

    pub fn records(&self) -> &[Arc<TuningRecord>] {
        &self.records
    }

    pub fn len(&self) -> usize {
        self.records.len()
    }

    pub fn is_empty(&self) -> bool {
        self.records.is_empty()
    }

    /// Append one record (one JSONL line, O_APPEND — concurrent workers
    /// interleave whole lines, never partial ones at these sizes).
    pub fn append(&mut self, rec: TuningRecord) -> anyhow::Result<()> {
        append_jsonl(&self.path, &rec.to_json())?;
        self.records.push(Arc::new(rec));
        // A frozen index no longer describes the records: drop back to
        // the brute-force scan rather than serve stale neighbors.
        self.index = None;
        Ok(())
    }

    /// The latest record exactly matching `(workload, gpu, mode)` and
    /// the config fingerprint. Cold-search records replay an identical
    /// deterministic search; transfer-enabled records replay the
    /// *recorded* outcome (which also depended on store contents at
    /// write time) — see [`record::config_fingerprint`].
    pub fn exact_hit(&self, workload: Workload, cfg: &SearchConfig) -> Option<&TuningRecord> {
        let id = workload.id();
        let fp = config_fingerprint(cfg);
        self.records
            .iter()
            .rev()
            .find(|r| {
                r.workload_id == id
                    && r.gpu == cfg.gpu.name()
                    && r.mode == cfg.mode.name()
                    && r.fingerprint == fp
            })
            .map(|r| r.as_ref())
    }

    /// Nearest cached neighbors of `workload` on `gpu`: the latest
    /// record per foreign workload id, sorted by shape distance
    /// (deterministic tie-break on workload id), truncated to `max_n`.
    /// Served from the attached [`NeighborIndex`] when one was frozen
    /// in (sharded-store snapshots), by brute-force scan otherwise —
    /// the two agree exactly (the index parity test pins it).
    pub fn neighbors(
        &self,
        workload: Workload,
        gpu: &str,
        max_n: usize,
    ) -> Vec<(Arc<TuningRecord>, f64)> {
        if let Some(index) = &self.index {
            return index.neighbors(workload, gpu, max_n);
        }
        let refs: Vec<&TuningRecord> = self.records.iter().map(|r| r.as_ref()).collect();
        neighbor_indices(&refs, workload, gpu, max_n)
            .into_iter()
            .map(|(i, d)| (self.records[i].clone(), d))
            .collect()
    }

    /// Build an in-memory snapshot over externally-loaded records (the
    /// sharded store hands these to workers as pointer clones). The
    /// snapshot reads like any other store; appending to it writes
    /// `dir/tuning_store.jsonl`.
    pub fn from_records(dir: &Path, records: Vec<Arc<TuningRecord>>) -> TuningStore {
        TuningStore { dir: dir.to_path_buf(), path: dir.join(STORE_FILE), records, index: None }
    }

    /// Attach a frozen neighbor index describing `records` (see
    /// [`ShardedStore::snapshot`]).
    pub fn with_index(mut self, index: Arc<NeighborIndex>) -> TuningStore {
        self.index = Some(index);
        self
    }

    /// Compact the store: keep only the **latest** record per
    /// `(workload id, gpu, mode, fingerprint)` key, drop everything
    /// superseded, and rewrite the file atomically (tmp + rename).
    /// Returns the number of records removed.
    pub fn prune(&mut self) -> anyhow::Result<usize> {
        let mut seen: HashSet<(&str, &str, &str, &str)> = HashSet::new();
        let mut keep_rev: Vec<usize> = Vec::new();
        for (i, r) in self.records.iter().enumerate().rev() {
            let key =
                (r.workload_id.as_str(), r.gpu.as_str(), r.mode.as_str(), r.fingerprint.as_str());
            if seen.insert(key) {
                keep_rev.push(i);
            }
        }
        keep_rev.reverse();
        let removed = self.records.len() - keep_rev.len();
        if removed == 0 {
            return Ok(0);
        }
        let kept: Vec<Arc<TuningRecord>> =
            keep_rev.into_iter().map(|i| self.records[i].clone()).collect();
        let mut text = String::new();
        for r in &kept {
            text.push_str(&r.to_json().to_string());
            text.push('\n');
        }
        let tmp = self.path.with_extension("jsonl.tmp");
        std::fs::write(&tmp, &text)
            .with_context(|| format!("write pruned store {tmp:?}"))?;
        std::fs::rename(&tmp, &self.path)
            .with_context(|| format!("replace store {:?}", self.path))?;
        self.records = kept;
        self.index = None;
        Ok(removed)
    }

    pub fn stats(&self) -> StoreStats {
        stats_among(self.records.iter().map(|r| r.as_ref()))
    }
}

/// Aggregate [`StoreStats`] over any record collection (shared by
/// [`TuningStore`] and [`ShardedStore`]).
pub fn stats_among<'a, I>(records: I) -> StoreStats
where
    I: IntoIterator<Item = &'a TuningRecord>,
{
    let mut workloads: HashSet<&str> = HashSet::new();
    let mut keys: HashSet<(&str, &str, &str, &str)> = HashSet::new();
    let mut stats = StoreStats::default();
    for r in records {
        stats.n_records += 1;
        workloads.insert(r.workload_id.as_str());
        keys.insert((
            r.workload_id.as_str(),
            r.gpu.as_str(),
            r.mode.as_str(),
            r.fingerprint.as_str(),
        ));
        stats.total_energy_measurements += r.n_energy_measurements;
        stats.total_sim_time_s += r.sim_time_s;
    }
    stats.n_workloads = workloads.len();
    stats.n_keys = keys.len();
    stats
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::suites;

    fn tmp_dir(tag: &str) -> PathBuf {
        let dir = std::env::temp_dir()
            .join(format!("ecokernel_store_test_{tag}_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        dir
    }

    fn quick_cfg(seed: u64) -> SearchConfig {
        SearchConfig {
            population: 24,
            m_latency_keep: 6,
            rounds: 3,
            patience: 0,
            seed,
            ..Default::default()
        }
    }

    fn record_for(w: Workload, seed: u64) -> (TuningRecord, SearchConfig) {
        let cfg = quick_cfg(seed);
        let out = crate::search::run_search(w, &cfg);
        (TuningRecord::from_outcome(&out, &cfg), cfg)
    }

    #[test]
    fn roundtrip_write_reopen_identical() {
        let dir = tmp_dir("roundtrip");
        let (rec1, _) = record_for(suites::MM1, 1);
        let (rec2, _) = record_for(suites::MV3, 2);
        {
            let mut store = TuningStore::open(&dir).unwrap();
            store.append(rec1.clone()).unwrap();
            store.append(rec2.clone()).unwrap();
        }
        let store = TuningStore::open(&dir).unwrap();
        let loaded: Vec<TuningRecord> =
            store.records().iter().map(|r| r.as_ref().clone()).collect();
        assert_eq!(loaded, vec![rec1, rec2]);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn incompatible_schema_version_fails_open() {
        let dir = tmp_dir("schema");
        let (rec, _) = record_for(suites::MM1, 3);
        {
            let mut store = TuningStore::open(&dir).unwrap();
            store.append(rec.clone()).unwrap();
        }
        // Rewrite the line with a bumped version field.
        let path = dir.join(STORE_FILE);
        let text = std::fs::read_to_string(&path).unwrap();
        let bumped = text.replace(
            &format!("\"v\":{SCHEMA_VERSION}"),
            &format!("\"v\":{}", SCHEMA_VERSION + 1),
        );
        assert_ne!(text, bumped, "version field must appear in the line");
        std::fs::write(&path, bumped).unwrap();
        let err = TuningStore::open(&dir).unwrap_err();
        assert!(format!("{err:#}").contains("schema version"), "{err:#}");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn corrupt_line_fails_open() {
        let dir = tmp_dir("corrupt");
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join(STORE_FILE), "{not json\n").unwrap();
        assert!(TuningStore::open(&dir).is_err());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn exact_hit_matches_key_and_fingerprint() {
        let dir = tmp_dir("hit");
        let (rec, cfg) = record_for(suites::MM1, 4);
        let mut store = TuningStore::open(&dir).unwrap();
        store.append(rec).unwrap();
        assert!(store.exact_hit(suites::MM1, &cfg).is_some());
        assert!(store.exact_hit(suites::MM2, &cfg).is_none(), "different workload");
        let mut other_seed = cfg.clone();
        other_seed.seed = 999;
        assert!(store.exact_hit(suites::MM1, &other_seed).is_none(), "different fingerprint");
        let mut other_mode = cfg.clone();
        other_mode.mode = crate::config::SearchMode::LatencyOnly;
        assert!(store.exact_hit(suites::MM1, &other_mode).is_none(), "different mode");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn prune_keeps_latest_per_key_and_rewrites_file() {
        let dir = tmp_dir("prune");
        let (rec_a1, cfg) = record_for(suites::MM1, 5);
        let (rec_b, _) = record_for(suites::MV3, 6);
        let mut store = TuningStore::open(&dir).unwrap();
        // Same key appended three times: two must be pruned.
        store.append(rec_a1.clone()).unwrap();
        store.append(rec_a1.clone()).unwrap();
        store.append(rec_b.clone()).unwrap();
        store.append(rec_a1.clone()).unwrap();
        let removed = store.prune().unwrap();
        assert_eq!(removed, 2);
        assert_eq!(store.len(), 2);
        // Latest-per-key survives in original relative order, and the
        // exact hit still resolves after reopen.
        let reopened = TuningStore::open(&dir).unwrap();
        assert_eq!(reopened.records(), store.records());
        assert!(reopened.exact_hit(suites::MM1, &cfg).is_some());
        // Pruning an already-compact store is a no-op.
        let mut store = reopened;
        assert_eq!(store.prune().unwrap(), 0);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn neighbors_sorted_by_distance_and_exclude_self() {
        let dir = tmp_dir("neighbors");
        let mut store = TuningStore::open(&dir).unwrap();
        for (w, seed) in [(suites::MM1, 7), (suites::MM3, 8), (suites::MV3, 9)] {
            let (rec, _) = record_for(w, seed);
            store.append(rec).unwrap();
        }
        let n = store.neighbors(suites::MM2, "a100", 8);
        assert_eq!(n.len(), 3);
        for w in n.windows(2) {
            assert!(w[0].1 <= w[1].1, "not sorted by distance");
        }
        // MM neighbors beat the MV record for an MM target.
        assert!(n[0].0.workload_id.starts_with("mm_"));
        // Self is excluded.
        let self_n = store.neighbors(suites::MM1, "a100", 8);
        assert!(self_n.iter().all(|(r, _)| r.workload_id != suites::MM1.id()));
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn stats_aggregate() {
        let dir = tmp_dir("stats");
        let mut store = TuningStore::open(&dir).unwrap();
        let (rec1, _) = record_for(suites::MM1, 10);
        let (rec2, _) = record_for(suites::MM1, 11);
        store.append(rec1).unwrap();
        store.append(rec2).unwrap();
        let s = store.stats();
        assert_eq!(s.n_records, 2);
        assert_eq!(s.n_workloads, 1);
        assert_eq!(s.n_keys, 2, "different seeds are different keys");
        assert!(s.total_energy_measurements > 0);
        assert!(s.total_sim_time_s > 0.0);
        let _ = std::fs::remove_dir_all(&dir);
    }
}
