//! Warm-start transfer: seed a new search from cached neighbors.
//!
//! For an unseen workload, the nearest cached neighbors (by
//! [`crate::store::similarity::gemm_distance`]) contribute three things:
//!
//! 1. **population seeds** — their best schedules, re-legalized against
//!    the new workload's [`ScheduleSpace`], injected into the initial
//!    genetic population;
//! 2. **cost-model seeds** — their NVML-measured (schedule, energy)
//!    samples, re-featurized, so the GBDT starts trained instead of
//!    blind and the dynamic-k controller can trust it immediately;
//! 3. **a k hint** — the neighbor's final dynamic-k value, so round 0
//!    measures `k·M` kernels instead of all `M`.
//!
//! The SNR guard of Algorithm 1 keeps the transfer honest: if the
//! transferred model turns out wrong for the new shape, prediction SNR
//! drops below `µ` and `k` climbs back toward full measurement.
//!
//! Neighbor selection goes through [`TuningStore::neighbors`]: on a
//! sharded-store snapshot that is the frozen
//! [`crate::store::NeighborIndex`] (candidate buckets, not a full
//! scan) — the same index the serving daemon's miss path queries.

use super::TuningStore;
use crate::analysis;
use crate::config::{GpuSpec, SearchConfig};
use crate::costmodel::CostModelSnapshot;
use crate::features::{featurize, FeatureVector};
use crate::schedule::space::ScheduleSpace;
use crate::schedule::tiling::snap;
use crate::schedule::{Candidate, Schedule};
use crate::workload::Workload;
use std::collections::HashSet;

/// Neighbors farther than this (log-space + penalties) are ignored:
/// within-family shape changes stay well below it, cross-family
/// transfers (whose schedule spaces barely overlap) sit far above.
pub const MAX_TRANSFER_DISTANCE: f64 = 3.0;

/// Best/measured schedules taken per neighbor as population seeds.
const SEEDS_PER_NEIGHBOR: usize = 16;

/// Measured samples taken per neighbor as cost-model training data.
const SAMPLES_PER_NEIGHBOR: usize = 64;

/// Bounds for the transferred k hint: never start fully trusting a
/// transferred model (floor), and always grant some round-0
/// measurement discount (ceiling) — the SNR guard raises `k` again if
/// the transfer proves wrong.
const K_HINT_FLOOR: f64 = 0.2;
const K_HINT_CEIL: f64 = 0.8;

/// Everything a warm-started search begins with.
#[derive(Debug, Clone)]
pub struct WarmStart {
    /// Re-legalized neighbor schedules, nearest neighbor first.
    pub seed_schedules: Vec<Schedule>,
    /// (features, measured energy) pairs from neighbor searches.
    pub seed_samples: Vec<(FeatureVector, f64)>,
    /// Initial dynamic-k suggestion (nearest neighbor's final k).
    pub k_hint: Option<f64>,
    /// How many neighbor records contributed.
    pub n_neighbors: usize,
    /// The nearest neighbor's persisted cost model (energy scale
    /// pre-adjusted by the MAC ratio): installing it lets the warm
    /// search skip the first fit entirely.
    pub model: Option<CostModelSnapshot>,
}

/// Build a warm start for `workload` from the store, or `None` when no
/// neighbor is close enough to help.
pub fn build(store: &TuningStore, workload: Workload, cfg: &SearchConfig) -> Option<WarmStart> {
    let spec = cfg.gpu.spec();
    let space = ScheduleSpace::new(workload, &spec);

    let neighbors: Vec<_> = store
        .neighbors(workload, cfg.gpu.name(), cfg.store.max_neighbors)
        .into_iter()
        .filter(|(_, dist)| *dist <= MAX_TRANSFER_DISTANCE)
        .collect();
    if neighbors.is_empty() {
        return None;
    }

    let target_macs = workload.gemm_view().macs() as f64;
    let mut seed_schedules: Vec<Schedule> = Vec::new();
    let mut seen: HashSet<Schedule> = HashSet::new();
    let mut seed_samples: Vec<(FeatureVector, f64)> = Vec::new();
    for (rec, _) in &neighbors {
        // Population seeds: the neighbor's best + its lowest-energy
        // measured schedules, made legal for the new shape.
        let candidates =
            std::iter::once(&rec.best).chain(rec.measured.iter()).take(1 + SEEDS_PER_NEIGHBOR);
        for sk in candidates {
            if let Some(s) = relegalize(&sk.schedule, &space) {
                if seen.insert(s) {
                    seed_schedules.push(s);
                }
            }
        }
        // Model seeds: approximate training points for the TARGET —
        // each measured neighbor schedule is re-legalized into the
        // target space, featurized against the target workload, and its
        // measured energy rescaled per schedule by the static-energy
        // ratio (ISSUE 9): `static(target, s') / static(neighbor, s)`
        // captures the shape-dependent traffic/occupancy shift the old
        // MAC ratio ignored. The MAC ratio stays as the fallback when
        // the static estimate degenerates. Keeping predictions in the
        // target's energy range is what lets round 0's SNR check pass
        // and the dynamic-k controller trust the transferred model.
        let neighbor_macs = rec.workload.gemm_view().macs() as f64;
        let mac_scale = target_macs / neighbor_macs.max(1.0);
        for sk in rec.measured.iter().take(SAMPLES_PER_NEIGHBOR) {
            if let Some(s) = relegalize(&sk.schedule, &space) {
                let c = Candidate::new(workload, s);
                let scale =
                    static_scale(&rec.workload, &sk.schedule, &workload, &s, &spec)
                        .unwrap_or(mac_scale);
                seed_samples.push((featurize(&c, &spec), sk.energy_j * scale));
            }
        }
    }
    // Cap population seeding at half the population: transfer guides
    // the search, it must not collapse its diversity.
    seed_schedules.truncate((cfg.population / 2).max(1));

    if seed_schedules.is_empty() && seed_samples.is_empty() {
        return None;
    }
    let k_hint = neighbors[0].0.final_k.map(|k| k.clamp(K_HINT_FLOOR, K_HINT_CEIL));
    // The nearest neighbor's persisted model transfers directly; its
    // energy scale is rescaled like the samples — static-energy ratio
    // on the neighbor's best schedule, MAC ratio as fallback — so
    // round 0's calibration sees a sane starting point.
    let model = neighbors[0].0.model.as_ref().map(|snap| {
        let nearest = &neighbors[0].0;
        let neighbor_macs = nearest.workload.gemm_view().macs() as f64;
        let mut snap = snap.clone();
        let best = &nearest.best.schedule;
        snap.scale_j *= relegalize(best, &space)
            .and_then(|s| static_scale(&nearest.workload, best, &workload, &s, &spec))
            .unwrap_or(target_macs / neighbor_macs.max(1.0));
        snap
    });
    Some(WarmStart { seed_schedules, seed_samples, k_hint, n_neighbors: neighbors.len(), model })
}

/// Energy-transfer ratio from static analysis: how much more (or less)
/// energy the TARGET shape should cost than the anchor, for one
/// transferred schedule. `None` when either closed-form estimate
/// degenerates (non-finite or non-positive) — callers fall back to the
/// MAC ratio.
fn static_scale(
    anchor: &Workload,
    anchor_sched: &Schedule,
    target: &Workload,
    target_sched: &Schedule,
    spec: &GpuSpec,
) -> Option<f64> {
    let from = analysis::analyze(anchor, anchor_sched, spec).static_energy_j;
    let to = analysis::analyze(target, target_sched, spec).static_energy_j;
    let ratio = to / from;
    (from > 0.0 && ratio.is_finite() && ratio > 0.0).then_some(ratio)
}

/// Map a schedule from another workload's space into `space`: snap each
/// knob to the nearest domain value, restore invariants, and repair the
/// usual legality offenders. Returns `None` when no close legal
/// schedule exists (the seed is dropped rather than distorted).
pub fn relegalize(s: &Schedule, space: &ScheduleSpace) -> Option<Schedule> {
    let d = &space.domains;
    let g = &space.gemm;
    let mut out = Schedule {
        threads_m: snap(&d.threads_m, s.threads_m),
        threads_n: snap(&d.threads_n, s.threads_n),
        reg_m: snap(&d.reg_m, s.reg_m),
        reg_n: snap(&d.reg_n, s.reg_n),
        tile_k: snap(&d.tile_k, s.tile_k),
        unroll_k: snap(&d.unroll_k, s.unroll_k),
        vector_width: snap(&d.vector_width, s.vector_width),
        split_k: snap(&d.split_k, s.split_k),
        use_shared: if d.use_shared.contains(&s.use_shared) {
            s.use_shared
        } else {
            d.use_shared[0]
        },
    };
    // Invariant: unroll divides tile_k (domains always contain 1).
    while out.tile_k % out.unroll_k != 0 {
        out.unroll_k /= 2;
    }
    if space.is_legal(&out) {
        return Some(out);
    }
    // Repair 1: vector loads must divide the contiguous N extent.
    if g.n % out.vector_width != 0 {
        out.vector_width = d
            .vector_width
            .iter()
            .copied()
            .filter(|&v| v <= s.vector_width && g.n % v == 0)
            .max()
            .unwrap_or(1);
    }
    if space.is_legal(&out) {
        return Some(out);
    }
    // Repair 2: split-k must leave a full stage of work per block.
    out.split_k = 1;
    if space.is_legal(&out) {
        return Some(out);
    }
    None
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::GpuArch;
    use crate::util::Rng;
    use crate::workload::suites;

    #[test]
    fn relegalize_maps_across_mm_shapes() {
        let spec = GpuArch::A100.spec();
        let from = ScheduleSpace::new(suites::MM2, &spec);
        let to = ScheduleSpace::new(suites::MM1, &spec);
        let mut rng = Rng::seed_from_u64(11);
        let mut mapped = 0;
        for s in from.sample_n(&mut rng, 50) {
            if let Some(t) = relegalize(&s, &to) {
                assert!(to.is_legal(&t), "relegalized schedule illegal: {t}");
                mapped += 1;
            }
        }
        assert!(mapped >= 45, "only {mapped}/50 MM2 schedules mapped onto MM1");
    }

    #[test]
    fn relegalize_respects_mv_regime() {
        // MM schedules forced into an MV space must pin the M axis.
        let spec = GpuArch::A100.spec();
        let from = ScheduleSpace::new(suites::MM1, &spec);
        let to = ScheduleSpace::new(suites::MV3, &spec);
        let mut rng = Rng::seed_from_u64(12);
        for s in from.sample_n(&mut rng, 30) {
            if let Some(t) = relegalize(&s, &to) {
                assert_eq!(t.threads_m, 1);
                assert_eq!(t.reg_m, 1);
                assert!(to.is_legal(&t));
            }
        }
    }

    /// ISSUE 9 acceptance: on the warm/cold experiment family pairs,
    /// rescaling a neighbor's measured energies by the static-energy
    /// ratio tracks the target's true (simulated) energies at least as
    /// well as the old MAC-only ratio — this is what cuts round-0
    /// relerr for warm-start transfer.
    #[test]
    fn static_ratio_beats_mac_ratio_on_warmcold_pairs() {
        let spec = GpuArch::A100.spec();
        let pairs = [
            (suites::MM3, suites::MM1),
            (suites::MV4, suites::MV3),
            (suites::CONV3, suites::CONV2),
        ];
        let mut err_static = 0.0;
        let mut err_mac = 0.0;
        let mut n = 0usize;
        for (anchor, target) in pairs {
            let from = ScheduleSpace::new(anchor, &spec);
            let to = ScheduleSpace::new(target, &spec);
            let mac_scale = target.gemm_view().macs() as f64
                / anchor.gemm_view().macs().max(1) as f64;
            let mut rng = Rng::seed_from_u64(7);
            for s in from.sample_n(&mut rng, 40) {
                let Some(t) = relegalize(&s, &to) else { continue };
                let e_anchor =
                    crate::sim::evaluate_candidate(&Candidate::new(anchor, s), &spec).energy_j;
                let truth =
                    crate::sim::evaluate_candidate(&Candidate::new(target, t), &spec).energy_j;
                let st = static_scale(&anchor, &s, &target, &t, &spec).unwrap_or(mac_scale);
                err_static += ((e_anchor * st - truth) / truth).abs();
                err_mac += ((e_anchor * mac_scale - truth) / truth).abs();
                n += 1;
            }
        }
        assert!(n >= 60, "too few transferable samples across the pairs: {n}");
        assert!(
            err_static <= err_mac,
            "static-ratio transfer must not be worse than the MAC ratio: \
             mean relerr {:.4} vs {:.4} over {n} samples",
            err_static / n as f64,
            err_mac / n as f64
        );
    }

    #[test]
    fn identity_relegalization_is_exact() {
        let spec = GpuArch::A100.spec();
        let space = ScheduleSpace::new(suites::MM1, &spec);
        let mut rng = Rng::seed_from_u64(13);
        for s in space.sample_n(&mut rng, 30) {
            assert_eq!(relegalize(&s, &space), Some(s), "legal schedule must map to itself");
        }
    }
}
