//! The on-disk schema of the tuning store: one [`TuningRecord`] per
//! finished search, serialized as one JSONL line via [`crate::util::Json`].
//!
//! Records are schema-versioned: every line carries `"v"`, and loading
//! rejects records written by an incompatible schema instead of
//! guessing. The record stores *schedules + measured metrics*, not
//! feature vectors — features are re-derived at load time, so the
//! feature map can evolve without invalidating the store.

use crate::config::{SearchConfig, SearchMode};
use crate::costmodel::CostModelSnapshot;
use crate::nvml::MeasurementClock;
use crate::schedule::Schedule;
use crate::search::{EvaluatedKernel, SearchOutcome};
use crate::util::Json;
use crate::workload::Workload;

/// Version of the record schema; bump on incompatible change.
pub const SCHEMA_VERSION: u64 = 1;

/// Cap on measured-pool entries stored per record (lowest-energy kept).
pub const MAX_STORED_MEASURED: usize = 256;

/// One NVML-measured kernel as stored on disk.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct StoredKernel {
    pub schedule: Schedule,
    pub latency_s: f64,
    pub energy_j: f64,
    pub avg_power_w: f64,
}

impl StoredKernel {
    pub fn from_evaluated(e: &EvaluatedKernel) -> StoredKernel {
        StoredKernel {
            schedule: e.schedule,
            latency_s: e.latency_s,
            energy_j: e.energy_j,
            avg_power_w: e.avg_power_w,
        }
    }

    /// A zero-measurement kernel from static analysis — the serve
    /// daemon's search-free tier. The closed-form estimates stand in
    /// for NVML metrics until the background search's write-back lands;
    /// such kernels are served, never persisted to the store.
    pub fn from_static(
        schedule: Schedule,
        profile: &crate::analysis::StaticProfile,
    ) -> StoredKernel {
        StoredKernel {
            schedule,
            latency_s: profile.static_latency_s,
            energy_j: profile.static_energy_j,
            avg_power_w: profile.static_avg_power_w,
        }
    }

    pub fn to_evaluated(&self) -> EvaluatedKernel {
        EvaluatedKernel {
            schedule: self.schedule,
            latency_s: self.latency_s,
            energy_j: self.energy_j,
            avg_power_w: self.avg_power_w,
            energy_measured: true,
        }
    }

    fn to_json(&self) -> Json {
        Json::obj(vec![
            ("schedule", schedule_to_json(&self.schedule)),
            ("latency_s", Json::num(self.latency_s)),
            ("energy_j", Json::num(self.energy_j)),
            ("avg_power_w", Json::num(self.avg_power_w)),
        ])
    }

    fn from_json(v: &Json) -> Result<StoredKernel, String> {
        Ok(StoredKernel {
            schedule: schedule_from_json(v.get("schedule").ok_or("kernel missing 'schedule'")?)?,
            latency_s: get_f64(v, "latency_s")?,
            energy_j: get_f64(v, "energy_j")?,
            avg_power_w: get_f64(v, "avg_power_w")?,
        })
    }
}

/// One finished search, keyed by (workload id, GPU arch, search mode)
/// plus a config fingerprint for exact-hit semantics.
#[derive(Debug, Clone, PartialEq)]
pub struct TuningRecord {
    /// Compact workload identifier (`Workload::id()`).
    pub workload_id: String,
    /// The full workload (reconstructible: transfer needs its shape).
    pub workload: Workload,
    /// GPU architecture name (`GpuArch::name()`).
    pub gpu: String,
    /// Search mode name (`SearchMode::name()`).
    pub mode: String,
    /// RNG seed of the recorded run.
    pub seed: u64,
    /// Fingerprint of the search-relevant config knobs; exact cache
    /// hits require an identical fingerprint.
    pub fingerprint: String,
    /// The selected kernel (NVML-measured metrics).
    pub best: StoredKernel,
    /// Measured pool, sorted by energy ascending, capped at
    /// [`MAX_STORED_MEASURED`] — the cost-model seed for transfer.
    pub measured: Vec<StoredKernel>,
    /// Cost accounting of the recorded search.
    pub n_energy_measurements: usize,
    pub n_latency_evals: usize,
    pub sim_time_s: f64,
    pub rounds: usize,
    /// Final dynamic-k value (None for latency-only searches).
    pub final_k: Option<f64>,
    /// The search's fitted cost model, when one was trained. The field
    /// carries its own version (`model_v`): records written before the
    /// field existed — and records whose snapshot version this build
    /// does not understand — still load, just without a model.
    pub model: Option<CostModelSnapshot>,
    /// Energy (J) of the latency-only baseline: what a latency-first
    /// selection over the same measured pool would have picked. The
    /// energy ledger credits `baseline_energy_j − best.energy_j` per
    /// served hit. `None` on records written before the field existed
    /// — such hits are counted as *unattributed*, never guessed.
    pub baseline_energy_j: Option<f64>,
}

impl TuningRecord {
    /// Build a record from a finished search.
    pub fn from_outcome(out: &SearchOutcome, cfg: &SearchConfig) -> TuningRecord {
        let mut measured: Vec<StoredKernel> =
            out.measured_pool.iter().map(StoredKernel::from_evaluated).collect();
        measured.sort_by(|a, b| a.energy_j.partial_cmp(&b.energy_j).expect("finite energy"));
        measured.truncate(MAX_STORED_MEASURED);
        TuningRecord {
            workload_id: out.workload.id(),
            workload: out.workload,
            gpu: cfg.gpu.name().to_string(),
            mode: out.mode.name().to_string(),
            seed: cfg.seed,
            fingerprint: config_fingerprint(cfg),
            best: StoredKernel::from_evaluated(&out.best),
            measured,
            n_energy_measurements: out.n_energy_measurements(),
            n_latency_evals: out.n_latency_evals,
            sim_time_s: out.clock.total_s,
            rounds: out.rounds.len(),
            final_k: out.k_trace.last().copied(),
            model: out.model.clone(),
            // The latency-minimal measured kernel is what latency-only
            // tuning would deploy; `select_final` restricts the energy
            // pick to its latency tolerance band, so the credit
            // (baseline − best) is never negative.
            baseline_energy_j: out
                .measured_pool
                .iter()
                .filter(|e| e.energy_measured)
                .min_by(|a, b| {
                    a.latency_s.partial_cmp(&b.latency_s).expect("finite latency")
                })
                .map(|e| e.energy_j),
        }
    }

    /// Minimal synthetic record: the workload's legal fallback schedule
    /// with one measured sample and fixed metrics — enough structure
    /// for routing, persistence roundtrips, and neighbor selection
    /// without running a search. Test/bench support (all fields are
    /// public; callers overwrite what they need, e.g. the fingerprint
    /// to match a real config); hidden from docs — not a product
    /// constructor.
    #[doc(hidden)]
    pub fn synthetic(
        workload: Workload,
        gpu: crate::config::GpuArch,
        seed: u64,
    ) -> TuningRecord {
        let spec = gpu.spec();
        let k = StoredKernel {
            schedule: crate::schedule::space::ScheduleSpace::new(workload, &spec).fallback(),
            latency_s: 1e-3,
            energy_j: 0.5,
            avg_power_w: 100.0,
        };
        TuningRecord {
            workload_id: workload.id(),
            workload,
            gpu: gpu.name().to_string(),
            mode: "energy_aware".to_string(),
            seed,
            fingerprint: format!("fp{seed}"),
            best: k,
            measured: vec![k],
            n_energy_measurements: 1,
            n_latency_evals: 1,
            sim_time_s: 0.1,
            rounds: 1,
            final_k: None,
            model: None,
            baseline_energy_j: None,
        }
    }

    /// Reconstruct a zero-cost [`SearchOutcome`] from this record — the
    /// exact-hit short-circuit: the cached kernel with a fresh (all
    /// zeros) measurement clock.
    pub fn to_outcome(&self) -> SearchOutcome {
        SearchOutcome {
            workload: self.workload,
            mode: SearchMode::parse(&self.mode).unwrap_or(SearchMode::EnergyAware),
            best: self.best.to_evaluated(),
            rounds: Vec::new(),
            clock: MeasurementClock::new(),
            measured_pool: self.measured.iter().map(|k| k.to_evaluated()).collect(),
            k_trace: Vec::new(),
            n_latency_evals: 0,
            model: self.model.clone(),
        }
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("v", Json::num(SCHEMA_VERSION as f64)),
            ("workload_id", Json::str(self.workload_id.clone())),
            ("workload", workload_to_json(&self.workload)),
            ("gpu", Json::str(self.gpu.clone())),
            ("mode", Json::str(self.mode.clone())),
            ("seed", Json::num(self.seed as f64)),
            ("fingerprint", Json::str(self.fingerprint.clone())),
            ("best", self.best.to_json()),
            ("measured", Json::arr(self.measured.iter().map(|k| k.to_json()))),
            ("n_energy_measurements", Json::num(self.n_energy_measurements as f64)),
            ("n_latency_evals", Json::num(self.n_latency_evals as f64)),
            ("sim_time_s", Json::num(self.sim_time_s)),
            ("rounds", Json::num(self.rounds as f64)),
            (
                "final_k",
                match self.final_k {
                    Some(k) => Json::num(k),
                    None => Json::Null,
                },
            ),
            (
                "cost_model",
                match &self.model {
                    Some(snap) => snap.to_json(),
                    None => Json::Null,
                },
            ),
            (
                "baseline_energy_j",
                match self.baseline_energy_j {
                    Some(e) => Json::num(e),
                    None => Json::Null,
                },
            ),
        ])
    }

    pub fn from_json(v: &Json) -> Result<TuningRecord, String> {
        let version = get_usize(v, "v")? as u64;
        if version != SCHEMA_VERSION {
            return Err(format!(
                "unsupported tuning-store schema version {version} (this build reads v{SCHEMA_VERSION})"
            ));
        }
        let measured = v
            .get("measured")
            .and_then(|m| m.as_arr())
            .ok_or("record missing 'measured'")?
            .iter()
            .map(StoredKernel::from_json)
            .collect::<Result<Vec<_>, String>>()?;
        Ok(TuningRecord {
            workload_id: get_str(v, "workload_id")?,
            workload: workload_from_json(v.get("workload").ok_or("record missing 'workload'")?)?,
            gpu: get_str(v, "gpu")?,
            mode: get_str(v, "mode")?,
            seed: get_usize(v, "seed")? as u64,
            fingerprint: get_str(v, "fingerprint")?,
            best: StoredKernel::from_json(v.get("best").ok_or("record missing 'best'")?)?,
            measured,
            n_energy_measurements: get_usize(v, "n_energy_measurements")?,
            n_latency_evals: get_usize(v, "n_latency_evals")?,
            sim_time_s: get_f64(v, "sim_time_s")?,
            rounds: get_usize(v, "rounds")?,
            final_k: match v.get("final_k") {
                None | Some(Json::Null) => None,
                Some(x) => Some(x.as_f64().ok_or("bad 'final_k'")?),
            },
            // Tolerant by design: a missing field (pre-snapshot
            // records), an unknown model_v, or a malformed snapshot all
            // load as "no model" — the kernel data stays servable.
            model: match v.get("cost_model") {
                None | Some(Json::Null) => None,
                Some(m) => CostModelSnapshot::from_json(m).ok(),
            },
            // Tolerant like `final_k`/`cost_model`: pre-ledger records
            // load without a baseline and serve as unattributed hits.
            baseline_energy_j: match v.get("baseline_energy_j") {
                None | Some(Json::Null) => None,
                Some(x) => Some(x.as_f64().ok_or("bad 'baseline_energy_j'")?),
            },
        })
    }
}

/// Fingerprint of every config knob that shapes the search trajectory —
/// including the NVML measurement and cost-model hyperparameters (which
/// change what gets measured and recorded) and the transfer knobs (a
/// warm-started run must never serve a `--no-transfer` request, or vice
/// versa). For cold runs, equal fingerprints imply an identical
/// deterministic search; for transfer-enabled runs the outcome also
/// depends on the store contents at write time, so a hit replays the
/// *recorded* result — cache semantics, refreshable via `cache prune`
/// or a new seed.
pub fn config_fingerprint(cfg: &SearchConfig) -> String {
    format!(
        "{}|{}|s{}|p{}|m{}|r{}|ki{}|mu{}|ks{}|mm{}|pat{}|mut{}|x{}|im{}|\
         nv:{}:{}:{}:{}:{}:{}|cm:{}:{}:{}:{}:{}:{}:{}:{}:{}|tr:{}:{}",
        cfg.gpu.name(),
        cfg.mode.name(),
        cfg.seed,
        cfg.population,
        cfg.m_latency_keep,
        cfg.rounds,
        cfg.k_init,
        cfg.mu_snr_db,
        cfg.k_step,
        cfg.min_measure_per_round,
        cfg.patience,
        cfg.mutation_prob,
        cfg.crossover_prob,
        cfg.immigrant_frac,
        cfg.nvml.sampling_hz,
        cfg.nvml.min_samples,
        cfg.nvml.max_reps,
        cfg.nvml.warmup_s,
        cfg.nvml.power_noise_rel,
        cfg.nvml.latency_noise_rel,
        cfg.cost_model.n_trees,
        cfg.cost_model.max_depth,
        cfg.cost_model.learning_rate,
        cfg.cost_model.lambda,
        cfg.cost_model.min_child_weight,
        cfg.cost_model.n_bins,
        cfg.cost_model.colsample,
        cfg.cost_model.weighted_loss,
        cfg.cost_model.max_train_samples,
        cfg.store.transfer,
        cfg.store.max_neighbors,
    )
}

/// Compact JSON encoding of a schedule (shared with the serve
/// protocol's kernel replies).
pub fn schedule_to_json(s: &Schedule) -> Json {
    Json::obj(vec![
        ("tm", Json::num(s.threads_m as f64)),
        ("tn", Json::num(s.threads_n as f64)),
        ("rm", Json::num(s.reg_m as f64)),
        ("rn", Json::num(s.reg_n as f64)),
        ("tk", Json::num(s.tile_k as f64)),
        ("uk", Json::num(s.unroll_k as f64)),
        ("vw", Json::num(s.vector_width as f64)),
        ("sk", Json::num(s.split_k as f64)),
        ("sh", Json::Bool(s.use_shared)),
    ])
}

pub fn schedule_from_json(v: &Json) -> Result<Schedule, String> {
    Ok(Schedule {
        threads_m: get_usize(v, "tm")?,
        threads_n: get_usize(v, "tn")?,
        reg_m: get_usize(v, "rm")?,
        reg_n: get_usize(v, "rn")?,
        tile_k: get_usize(v, "tk")?,
        unroll_k: get_usize(v, "uk")?,
        vector_width: get_usize(v, "vw")?,
        split_k: get_usize(v, "sk")?,
        use_shared: v.get("sh").and_then(|b| b.as_bool()).ok_or("schedule missing 'sh'")?,
    })
}

/// JSON encoding of a workload (shared with the serve protocol's
/// `get_kernel` requests).
pub fn workload_to_json(w: &Workload) -> Json {
    match *w {
        Workload::MatMul { batch, m, n, k } => Json::obj(vec![
            ("kind", Json::str("mm")),
            ("batch", Json::num(batch as f64)),
            ("m", Json::num(m as f64)),
            ("n", Json::num(n as f64)),
            ("k", Json::num(k as f64)),
        ]),
        Workload::MatVec { batch, n, k } => Json::obj(vec![
            ("kind", Json::str("mv")),
            ("batch", Json::num(batch as f64)),
            ("n", Json::num(n as f64)),
            ("k", Json::num(k as f64)),
        ]),
        Workload::Conv2d { batch, h, w, cin, cout, ksize, stride, pad } => Json::obj(vec![
            ("kind", Json::str("conv")),
            ("batch", Json::num(batch as f64)),
            ("h", Json::num(h as f64)),
            ("w", Json::num(w as f64)),
            ("cin", Json::num(cin as f64)),
            ("cout", Json::num(cout as f64)),
            ("ksize", Json::num(ksize as f64)),
            ("stride", Json::num(stride as f64)),
            ("pad", Json::num(pad as f64)),
        ]),
    }
}

pub fn workload_from_json(v: &Json) -> Result<Workload, String> {
    match get_str(v, "kind")?.as_str() {
        "mm" => Ok(Workload::MatMul {
            batch: get_usize(v, "batch")?,
            m: get_usize(v, "m")?,
            n: get_usize(v, "n")?,
            k: get_usize(v, "k")?,
        }),
        "mv" => Ok(Workload::MatVec {
            batch: get_usize(v, "batch")?,
            n: get_usize(v, "n")?,
            k: get_usize(v, "k")?,
        }),
        "conv" => Ok(Workload::Conv2d {
            batch: get_usize(v, "batch")?,
            h: get_usize(v, "h")?,
            w: get_usize(v, "w")?,
            cin: get_usize(v, "cin")?,
            cout: get_usize(v, "cout")?,
            ksize: get_usize(v, "ksize")?,
            stride: get_usize(v, "stride")?,
            pad: get_usize(v, "pad")?,
        }),
        other => Err(format!("unknown workload kind '{other}'")),
    }
}

fn get_f64(v: &Json, key: &str) -> Result<f64, String> {
    v.get(key).and_then(|x| x.as_f64()).ok_or_else(|| format!("missing/bad field '{key}'"))
}

fn get_usize(v: &Json, key: &str) -> Result<usize, String> {
    let x = get_f64(v, key)?;
    if x < 0.0 || x.fract() != 0.0 {
        return Err(format!("field '{key}' is not a non-negative integer: {x}"));
    }
    Ok(x as usize)
}

fn get_str(v: &Json, key: &str) -> Result<String, String> {
    Ok(v.get(key)
        .and_then(|x| x.as_str())
        .ok_or_else(|| format!("missing/bad field '{key}'"))?
        .to_string())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::GpuArch;
    use crate::workload::suites;

    fn sample_record() -> TuningRecord {
        let cfg = SearchConfig {
            population: 24,
            m_latency_keep: 6,
            rounds: 3,
            patience: 0,
            seed: 5,
            ..Default::default()
        };
        let out = crate::search::run_search(suites::MM1, &cfg);
        TuningRecord::from_outcome(&out, &cfg)
    }

    #[test]
    fn record_json_roundtrip_is_identical() {
        let rec = sample_record();
        let line = rec.to_json().to_string();
        let back = TuningRecord::from_json(&Json::parse(&line).unwrap()).unwrap();
        assert_eq!(back, rec);
    }

    #[test]
    fn wrong_schema_version_is_rejected() {
        let rec = sample_record();
        let mut v = rec.to_json();
        if let Json::Obj(m) = &mut v {
            m.insert("v".to_string(), Json::num((SCHEMA_VERSION + 1) as f64));
        }
        let err = TuningRecord::from_json(&v).unwrap_err();
        assert!(err.contains("schema version"), "{err}");
    }

    #[test]
    fn workload_json_covers_all_families() {
        for w in [suites::MM3, suites::MV1, suites::CONV1] {
            let v = workload_to_json(&w);
            assert_eq!(workload_from_json(&v).unwrap(), w);
        }
    }

    #[test]
    fn fingerprint_separates_configs() {
        let a = SearchConfig::default();
        let mut b = SearchConfig::default();
        b.seed = 99;
        let mut c = SearchConfig::default();
        c.gpu = GpuArch::V100;
        assert_ne!(config_fingerprint(&a), config_fingerprint(&b));
        assert_ne!(config_fingerprint(&a), config_fingerprint(&c));
        // Measurement + cost-model knobs shape outcomes too: no stale
        // hit after a TOML edit to either section.
        let mut d = SearchConfig::default();
        d.cost_model.n_trees = 7;
        assert_ne!(config_fingerprint(&a), config_fingerprint(&d));
        let mut e = SearchConfig::default();
        e.nvml.power_noise_rel = 0.5;
        assert_ne!(config_fingerprint(&a), config_fingerprint(&e));
        // A warm-started record must not serve a --no-transfer request.
        let mut g = SearchConfig::default();
        g.store.transfer = false;
        assert_ne!(config_fingerprint(&a), config_fingerprint(&g));
        // The store *location* is not part of the key (the same record
        // set copied to another dir stays valid).
        let mut h = SearchConfig::default();
        h.store.dir = Some("/tmp/elsewhere".into());
        assert_eq!(config_fingerprint(&a), config_fingerprint(&h));
        assert_eq!(config_fingerprint(&a), config_fingerprint(&SearchConfig::default()));
    }

    #[test]
    fn model_field_is_versioned_and_optional() {
        let rec = sample_record();
        assert!(rec.model.is_some(), "energy-aware searches persist their model");

        // A pre-snapshot record (no 'cost_model' field) still parses.
        let mut v = rec.to_json();
        if let Json::Obj(m) = &mut v {
            m.remove("cost_model");
        }
        let old = TuningRecord::from_json(&v).unwrap();
        assert_eq!(old.model, None);
        assert_eq!(old.best, rec.best, "kernel data intact without a model");

        // A record whose snapshot version is from the future also
        // parses — just without a model.
        let mut v = rec.to_json();
        if let Json::Obj(m) = &mut v {
            if let Some(Json::Obj(snap)) = m.get_mut("cost_model") {
                snap.insert(
                    "model_v".to_string(),
                    Json::num((crate::costmodel::MODEL_SNAPSHOT_VERSION + 1) as f64),
                );
            }
        }
        let future = TuningRecord::from_json(&v).unwrap();
        assert_eq!(future.model, None);
        assert_eq!(future.best, rec.best);
    }

    #[test]
    fn baseline_energy_is_persisted_and_optional() {
        let rec = sample_record();
        let baseline = rec.baseline_energy_j.expect("measured searches persist a baseline");
        assert!(
            baseline >= rec.best.energy_j,
            "latency-only baseline ({baseline} J) cannot beat the energy-aware pick ({} J)",
            rec.best.energy_j
        );
        // It is the energy of the latency-minimal measured kernel.
        let fastest = rec
            .measured
            .iter()
            .min_by(|a, b| a.latency_s.partial_cmp(&b.latency_s).unwrap())
            .unwrap();
        assert_eq!(baseline, fastest.energy_j);

        // Pre-ledger records (no field) still parse — as unattributed.
        let mut v = rec.to_json();
        if let Json::Obj(m) = &mut v {
            m.remove("baseline_energy_j");
        }
        let old = TuningRecord::from_json(&v).unwrap();
        assert_eq!(old.baseline_energy_j, None);
        assert_eq!(old.best, rec.best, "kernel data intact without a baseline");
    }

    #[test]
    fn to_outcome_is_zero_cost_and_preserves_best() {
        let rec = sample_record();
        let out = rec.to_outcome();
        assert_eq!(out.n_energy_measurements(), 0);
        assert_eq!(out.clock.total_s, 0.0);
        assert_eq!(out.best.schedule, rec.best.schedule);
        assert_eq!(out.measured_pool.len(), rec.measured.len());
        assert!(out.best.energy_measured);
    }

    #[test]
    fn from_static_mirrors_profile_estimates() {
        let spec = GpuArch::A100.spec();
        let (s, prof) = crate::analysis::best_static(suites::MM1, &spec);
        let k = StoredKernel::from_static(s, &prof);
        assert_eq!(k.schedule, s);
        assert_eq!(k.latency_s, prof.static_latency_s);
        assert_eq!(k.energy_j, prof.static_energy_j);
        assert_eq!(k.avg_power_w, prof.static_avg_power_w);
    }

    #[test]
    fn measured_pool_is_sorted_and_capped() {
        let rec = sample_record();
        assert!(rec.measured.len() <= MAX_STORED_MEASURED);
        for w in rec.measured.windows(2) {
            assert!(w[0].energy_j <= w[1].energy_j);
        }
    }
}
