//! The fleet-scale store layout behind the serving daemon: the tuning
//! store sharded across N append-only JSONL files, with eviction.
//!
//! A single `tuning_store.jsonl` is fine for one experimenter; a daemon
//! serving fleet traffic accumulates orders of magnitude more keys and
//! must bound both file sizes and total footprint. This layer adds:
//!
//! * **sharding** — records are routed to `shards/shard_XXX.jsonl` by a
//!   hash of their serve key (workload id, GPU, mode, fingerprint), so
//!   appends and compactions touch one small file, never the world.
//!   Reopening with a different shard count **rebalances** the layout
//!   in place.
//! * **eviction** — beyond `cache prune`'s compaction: a per-GPU record
//!   quota and a global record cap, both evicting the least-recently
//!   **served** keys first (an LRU over serve traffic, persisted in a
//!   `served.jsonl` sidecar), so hot keys stay cached while dead
//!   workloads age out.
//! * **legacy import** — a PR-1 single-file store found in the same
//!   directory is folded into the shards on first open, then archived
//!   (`tuning_store.jsonl.imported`) so evicted records cannot
//!   resurrect from it.
//!
//! Configured via the `[serve]` section ([`crate::config::ServeConfig`]).

use super::{neighbors_among, StoreStats, TuningRecord, TuningStore, STORE_FILE};
use crate::config::SearchConfig;
use crate::workload::Workload;
use anyhow::{anyhow, Context as _};
use std::collections::{BTreeMap, HashMap};
use std::path::{Path, PathBuf};

/// Subdirectory of the store dir holding the shard files.
pub const SHARDS_DIR: &str = "shards";
/// Shard-layout metadata file (shard count + layout version).
pub const META_FILE: &str = "meta.json";
/// Append-only sidecar of (key, tick) last-served events.
pub const SERVED_FILE: &str = "served.jsonl";
/// Version of the on-disk shard layout; bump on incompatible change.
pub const LAYOUT_VERSION: u64 = 1;

/// The serve key: the exact-hit identity of a record, also the unit of
/// shard routing and eviction.
pub fn serve_key(workload_id: &str, gpu: &str, mode: &str, fingerprint: &str) -> String {
    format!("{workload_id}|{gpu}|{mode}|{fingerprint}")
}

fn record_key(r: &TuningRecord) -> String {
    serve_key(&r.workload_id, &r.gpu, &r.mode, &r.fingerprint)
}

/// FNV-1a — stable across runs and platforms (shard routing must not
/// depend on `DefaultHasher`'s unspecified, per-process seed).
fn fnv1a(key: &str) -> u64 {
    let mut h: u64 = 0xcbf29ce484222325;
    for b in key.as_bytes() {
        h ^= *b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

/// A sharded tuning store rooted at a store directory.
#[derive(Debug)]
pub struct ShardedStore {
    dir: PathBuf,
    shards_dir: PathBuf,
    n_shards: usize,
    shards: Vec<Vec<TuningRecord>>,
    /// Serve key -> last-served logical tick (0 = never served).
    served: HashMap<String, u64>,
    tick: u64,
    /// Lines appended to `served.jsonl` since the last compaction.
    served_appends: usize,
}

impl ShardedStore {
    /// Open (creating if needed) a sharded store with `n_shards`
    /// shards. An existing layout with a different shard count is
    /// rebalanced; a PR-1 single-file store in `dir` is imported when
    /// the shards are empty.
    pub fn open(dir: &Path, n_shards: usize) -> anyhow::Result<ShardedStore> {
        anyhow::ensure!(n_shards >= 1, "shard count must be >= 1");
        let shards_dir = dir.join(SHARDS_DIR);
        std::fs::create_dir_all(&shards_dir)
            .with_context(|| format!("create shards dir {shards_dir:?}"))?;

        // Read the on-disk layout (if any) and load every record.
        let meta_path = shards_dir.join(META_FILE);
        let disk_shards =
            if meta_path.exists() { read_meta(&meta_path)? } else { n_shards };

        let (loaded, torn) = load_shard_files(&shards_dir, disk_shards)?;
        let mut store = ShardedStore {
            dir: dir.to_path_buf(),
            shards_dir,
            n_shards,
            shards: vec![Vec::new(); n_shards],
            served: HashMap::new(),
            tick: 0,
            served_appends: 0,
        };
        for rec in loaded {
            let shard = store.shard_of(&record_key(&rec));
            store.shards[shard].push(rec);
        }

        // Import a legacy single-file store once, while the shards are
        // still empty; the file is then renamed so records a later
        // eviction removes cannot resurrect from it on reopen.
        let rebalanced = disk_shards != n_shards;
        let mut rewrote_all = false;
        if store.shards.iter().all(|s| s.is_empty()) && dir.join(STORE_FILE).exists() {
            let legacy = TuningStore::open(dir)?;
            for rec in legacy.records() {
                let shard = store.shard_of(&record_key(rec));
                store.shards[shard].push(rec.clone());
            }
            store.rewrite_all_shards()?;
            rewrote_all = true;
            let imported = dir.join(format!("{STORE_FILE}.imported"));
            std::fs::rename(dir.join(STORE_FILE), &imported)
                .with_context(|| format!("archive imported legacy store to {imported:?}"))?;
        } else if rebalanced {
            // Shard count changed: rewrite every shard file under the
            // new routing and drop surplus old files.
            store.rewrite_all_shards()?;
            rewrote_all = true;
            for i in n_shards..disk_shards {
                let _ = std::fs::remove_file(store.shards_dir.join(shard_file(i)));
            }
        }
        // Repair any torn shard tail now, before a future append would
        // concatenate onto the partial line (a full rewrite above
        // already repaired everything).
        if !rewrote_all {
            for i in torn {
                if i < n_shards {
                    store.rewrite_shard(i)?;
                }
            }
        }
        if !meta_path.exists() || rebalanced {
            store.write_meta()?;
        }

        store.replay_served(true)?;
        Ok(store)
    }

    /// Open an existing sharded store with whatever shard count its
    /// meta file records, **without writing anything** — no rebalance,
    /// no legacy import, no sidecar compaction. Safe to run against a
    /// live daemon's store (`ecokernel cache` on a serve dir).
    pub fn open_existing(dir: &Path) -> anyhow::Result<ShardedStore> {
        let shards_dir = dir.join(SHARDS_DIR);
        let meta_path = shards_dir.join(META_FILE);
        anyhow::ensure!(meta_path.exists(), "no sharded store at {dir:?}");
        let n_shards = read_meta(&meta_path)?;
        let (loaded, _torn) = load_shard_files(&shards_dir, n_shards)?;
        let mut store = ShardedStore {
            dir: dir.to_path_buf(),
            shards_dir,
            n_shards,
            shards: vec![Vec::new(); n_shards],
            served: HashMap::new(),
            tick: 0,
            served_appends: 0,
        };
        for rec in loaded {
            let shard = store.shard_of(&record_key(&rec));
            store.shards[shard].push(rec);
        }
        store.replay_served(false)?;
        Ok(store)
    }

    pub fn dir(&self) -> &Path {
        &self.dir
    }

    pub fn n_shards(&self) -> usize {
        self.n_shards
    }

    /// Total records across all shards.
    pub fn len(&self) -> usize {
        self.shards.iter().map(|s| s.len()).sum()
    }

    pub fn is_empty(&self) -> bool {
        self.shards.iter().all(|s| s.is_empty())
    }

    /// All records, shard-major (shard 0 first, append order within).
    pub fn iter(&self) -> impl Iterator<Item = &TuningRecord> {
        self.shards.iter().flatten()
    }

    /// Shard index a serve key routes to.
    pub fn shard_of(&self, key: &str) -> usize {
        (fnv1a(key) % self.n_shards as u64) as usize
    }

    /// Records currently in the shard a key routes to (the scan length
    /// a lookup pays — the serving daemon's simulated reply-time term).
    pub fn shard_len_for(&self, key: &str) -> usize {
        self.shards[self.shard_of(key)].len()
    }

    /// The latest record exactly matching `(workload, gpu, mode)` and
    /// the config fingerprint — only the key's shard is scanned.
    pub fn get(&self, workload: Workload, cfg: &SearchConfig) -> Option<&TuningRecord> {
        let id = workload.id();
        let fp = super::config_fingerprint(cfg);
        let key = serve_key(&id, cfg.gpu.name(), cfg.mode.name(), &fp);
        self.shards[self.shard_of(&key)].iter().rev().find(|r| {
            r.workload_id == id
                && r.gpu == cfg.gpu.name()
                && r.mode == cfg.mode.name()
                && r.fingerprint == fp
        })
    }

    /// Nearest cached neighbors (see [`neighbors_among`]); scans every
    /// shard in index order.
    pub fn neighbors(
        &self,
        workload: Workload,
        gpu: &str,
        max_n: usize,
    ) -> Vec<(&TuningRecord, f64)> {
        neighbors_among(self.iter(), workload, gpu, max_n)
    }

    /// Append a record to its shard (memory + one O_APPEND line) and
    /// mark its key hot (a fresh record must not be the next eviction
    /// victim).
    pub fn append(&mut self, rec: TuningRecord) -> anyhow::Result<()> {
        let key = record_key(&rec);
        let shard = self.shard_of(&key);
        super::append_jsonl(&self.shards_dir.join(shard_file(shard)), &rec.to_json())?;
        self.shards[shard].push(rec);
        self.touch(&key)?;
        Ok(())
    }

    /// Record that `key` was just served (bumps its LRU tick).
    pub fn mark_served(&mut self, key: &str) -> anyhow::Result<()> {
        self.touch(key)
    }

    /// Last-served tick of a key (0 = never).
    pub fn last_served(&self, key: &str) -> u64 {
        self.served.get(key).copied().unwrap_or(0)
    }

    /// Enforce the eviction policy: keep at most `per_gpu_quota`
    /// records per GPU and `max_records` records overall (0 disables
    /// either bound), evicting least-recently-served keys whole.
    /// Returns the number of records removed.
    pub fn enforce_limits(
        &mut self,
        per_gpu_quota: usize,
        max_records: usize,
    ) -> anyhow::Result<usize> {
        // Aggregate per serve key: gpu, record count, last-served tick.
        let mut keys: BTreeMap<String, (String, usize, u64)> = BTreeMap::new();
        for r in self.iter() {
            let key = record_key(r);
            let tick = self.last_served(&key);
            let e = keys.entry(key).or_insert_with(|| (r.gpu.clone(), 0, tick));
            e.1 += 1;
        }
        let mut per_gpu: HashMap<&str, usize> = HashMap::new();
        let mut total = 0usize;
        for (gpu, n, _) in keys.values() {
            *per_gpu.entry(gpu.as_str()).or_default() += *n;
            total += *n;
        }

        // Oldest-served first; deterministic tie-break on the key.
        let mut order: Vec<(&String, &(String, usize, u64))> = keys.iter().collect();
        order.sort_by(|a, b| a.1 .2.cmp(&b.1 .2).then_with(|| a.0.cmp(b.0)));

        let mut victims: Vec<&String> = Vec::new();
        let mut evicted = 0usize;
        for (key, (gpu, n, _)) in &order {
            let gpu_over = per_gpu_quota > 0
                && per_gpu.values().any(|&count| count > per_gpu_quota);
            let total_over = max_records > 0 && total > max_records;
            if !gpu_over && !total_over {
                break;
            }
            let this_gpu_over =
                per_gpu_quota > 0 && per_gpu.get(gpu.as_str()).copied().unwrap_or(0) > per_gpu_quota;
            if this_gpu_over || total_over {
                victims.push(*key);
                evicted += *n;
                total -= *n;
                if let Some(count) = per_gpu.get_mut(gpu.as_str()) {
                    *count -= *n;
                }
            }
        }
        if victims.is_empty() {
            return Ok(0);
        }

        let victim_set: std::collections::HashSet<&str> =
            victims.iter().map(|k| k.as_str()).collect();
        let dirty: Vec<usize> = victims.iter().map(|k| self.shard_of(k)).collect();
        for shard in &dirty {
            self.shards[*shard].retain(|r| !victim_set.contains(record_key(r).as_str()));
        }
        for shard in dirty {
            self.rewrite_shard(shard)?;
        }
        self.served.retain(|k, _| !victim_set.contains(k.as_str()));
        self.rewrite_served()?;
        Ok(evicted)
    }

    /// Flatten into a plain [`TuningStore`] snapshot (what background
    /// search workers consult for exact hits and warm-start transfer).
    pub fn snapshot(&self) -> TuningStore {
        TuningStore::from_records(&self.dir, self.iter().cloned().collect())
    }

    pub fn stats(&self) -> StoreStats {
        super::stats_among(self.iter())
    }

    fn touch(&mut self, key: &str) -> anyhow::Result<()> {
        self.tick += 1;
        self.served.insert(key.to_string(), self.tick);
        super::append_jsonl(
            &self.shards_dir.join(SERVED_FILE),
            &crate::util::Json::obj(vec![
                ("key", crate::util::Json::str(key)),
                ("tick", crate::util::Json::num(self.tick as f64)),
            ]),
        )?;
        // Compact online so a long-running daemon's sidecar stays
        // bounded at ~2 lines per live key (+ slack for small stores).
        self.served_appends += 1;
        if self.served_appends > 2 * self.served.len() + 64 {
            self.rewrite_served()?;
        }
        Ok(())
    }

    fn replay_served(&mut self, compact: bool) -> anyhow::Result<()> {
        let path = self.shards_dir.join(SERVED_FILE);
        if !path.exists() {
            return Ok(());
        }
        let text = std::fs::read_to_string(&path).with_context(|| format!("read {path:?}"))?;
        let all: Vec<&str> = text.lines().collect();
        let last = all.iter().rposition(|l| !l.trim().is_empty());
        let mut lines = 0usize;
        let mut torn = false;
        for (lineno, line) in all.iter().enumerate() {
            if line.trim().is_empty() {
                continue;
            }
            let parsed = crate::util::Json::parse(line).and_then(|v| {
                let key = v
                    .get("key")
                    .and_then(|k| k.as_str())
                    .ok_or_else(|| "missing 'key'".to_string())?
                    .to_string();
                let tick = v
                    .get("tick")
                    .and_then(|t| t.as_f64())
                    .ok_or_else(|| "missing 'tick'".to_string())? as u64;
                Ok((key, tick))
            });
            match parsed {
                Ok((key, tick)) => {
                    self.served.insert(key, tick);
                    self.tick = self.tick.max(tick);
                    lines += 1;
                }
                // A torn trailing touch only loses one LRU bump.
                Err(e) if Some(lineno) == last => {
                    eprintln!(
                        "warning: {path:?} line {}: dropping torn trailing line ({e})",
                        lineno + 1
                    );
                    torn = true;
                }
                Err(e) => return Err(anyhow!("{path:?} line {}: {e}", lineno + 1)),
            }
        }
        // Compact a sidecar that has grown past ~2 lines per live key,
        // or whose tail is torn (a future append would concatenate onto
        // the partial line). Never in read-only opens.
        if compact && (torn || lines > 2 * self.served.len().max(1)) {
            self.rewrite_served()?;
        }
        Ok(())
    }

    fn write_meta(&self) -> anyhow::Result<()> {
        let path = self.shards_dir.join(META_FILE);
        let v = crate::util::Json::obj(vec![
            ("v", crate::util::Json::num(LAYOUT_VERSION as f64)),
            ("n_shards", crate::util::Json::num(self.n_shards as f64)),
        ]);
        write_atomic(&path, &v.to_string())
    }

    fn rewrite_shard(&self, shard: usize) -> anyhow::Result<()> {
        let path = self.shards_dir.join(shard_file(shard));
        let mut text = String::new();
        for r in &self.shards[shard] {
            text.push_str(&r.to_json().to_string());
            text.push('\n');
        }
        write_atomic(&path, &text)
    }

    fn rewrite_all_shards(&self) -> anyhow::Result<()> {
        for i in 0..self.n_shards {
            self.rewrite_shard(i)?;
        }
        Ok(())
    }

    fn rewrite_served(&mut self) -> anyhow::Result<()> {
        let path = self.shards_dir.join(SERVED_FILE);
        let mut entries: Vec<(&String, &u64)> = self.served.iter().collect();
        entries.sort_by_key(|(_, tick)| **tick);
        let mut text = String::new();
        for (key, tick) in entries {
            text.push_str(
                &crate::util::Json::obj(vec![
                    ("key", crate::util::Json::str(key.clone())),
                    ("tick", crate::util::Json::num(*tick as f64)),
                ])
                .to_string(),
            );
            text.push('\n');
        }
        self.served_appends = 0;
        write_atomic(&path, &text)
    }
}

fn shard_file(i: usize) -> String {
    format!("shard_{i:03}.jsonl")
}

/// Parse `meta.json`: validate the layout version, return the shard
/// count (shared by [`ShardedStore::open`] and
/// [`ShardedStore::open_existing`]).
fn read_meta(meta_path: &Path) -> anyhow::Result<usize> {
    let text =
        std::fs::read_to_string(meta_path).with_context(|| format!("read {meta_path:?}"))?;
    let v = crate::util::Json::parse(&text).map_err(|e| anyhow!("{meta_path:?}: {e}"))?;
    let layout = v.get("v").and_then(|x| x.as_f64()).unwrap_or(0.0) as u64;
    anyhow::ensure!(
        layout == LAYOUT_VERSION,
        "unsupported shard layout version {layout} (this build reads v{LAYOUT_VERSION})"
    );
    Ok(v.get("n_shards")
        .and_then(|x| x.as_f64())
        .filter(|&n| n >= 1.0)
        .ok_or_else(|| anyhow!("{meta_path:?}: missing 'n_shards'"))? as usize)
}

/// Load every record from `shard_000..shard_{n-1}` under `shards_dir`;
/// also returns the indices of shard files whose tail was torn.
///
/// A malformed FINAL line is dropped with a warning rather than failing
/// the open: a daemon killed mid-append can tear at most the last line
/// (see [`super::append_jsonl`]), and a torn tail must not leave the
/// store unbootable. Corruption anywhere else is still a hard error.
fn load_shard_files(
    shards_dir: &Path,
    n_shards: usize,
) -> anyhow::Result<(Vec<TuningRecord>, Vec<usize>)> {
    let mut loaded: Vec<TuningRecord> = Vec::new();
    let mut torn: Vec<usize> = Vec::new();
    for i in 0..n_shards {
        let path = shards_dir.join(shard_file(i));
        if !path.exists() {
            continue;
        }
        let text =
            std::fs::read_to_string(&path).with_context(|| format!("read shard {path:?}"))?;
        let lines: Vec<&str> = text.lines().collect();
        let last = lines.iter().rposition(|l| !l.trim().is_empty());
        for (lineno, line) in lines.iter().enumerate() {
            if line.trim().is_empty() {
                continue;
            }
            match crate::util::Json::parse(line).and_then(|v| TuningRecord::from_json(&v)) {
                Ok(rec) => loaded.push(rec),
                Err(e) if Some(lineno) == last => {
                    eprintln!(
                        "warning: {path:?} line {}: dropping torn trailing line ({e})",
                        lineno + 1
                    );
                    torn.push(i);
                }
                Err(e) => return Err(anyhow!("{path:?} line {}: {e}", lineno + 1)),
            }
        }
    }
    Ok((loaded, torn))
}

fn write_atomic(path: &Path, text: &str) -> anyhow::Result<()> {
    let tmp = path.with_extension("tmp");
    std::fs::write(&tmp, text).with_context(|| format!("write {tmp:?}"))?;
    std::fs::rename(&tmp, path).with_context(|| format!("replace {path:?}"))?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::GpuArch;
    use crate::workload::suites;

    fn tmp_dir(tag: &str) -> PathBuf {
        let dir = std::env::temp_dir()
            .join(format!("ecokernel_sharded_test_{tag}_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        dir
    }

    fn quick_cfg(seed: u64, gpu: GpuArch) -> SearchConfig {
        SearchConfig {
            gpu,
            population: 24,
            m_latency_keep: 6,
            rounds: 3,
            patience: 0,
            seed,
            ..Default::default()
        }
    }

    fn record_for(w: Workload, seed: u64, gpu: GpuArch) -> (TuningRecord, SearchConfig) {
        let cfg = quick_cfg(seed, gpu);
        let out = crate::search::run_search(w, &cfg);
        (TuningRecord::from_outcome(&out, &cfg), cfg)
    }

    #[test]
    fn append_get_and_reopen_roundtrip() {
        let dir = tmp_dir("roundtrip");
        let (rec1, cfg1) = record_for(suites::MM1, 1, GpuArch::A100);
        let (rec2, cfg2) = record_for(suites::MV3, 2, GpuArch::A100);
        {
            let mut store = ShardedStore::open(&dir, 4).unwrap();
            store.append(rec1.clone()).unwrap();
            store.append(rec2.clone()).unwrap();
            assert_eq!(store.len(), 2);
        }
        let store = ShardedStore::open(&dir, 4).unwrap();
        assert_eq!(store.len(), 2);
        assert_eq!(store.get(suites::MM1, &cfg1), Some(&rec1));
        assert_eq!(store.get(suites::MV3, &cfg2), Some(&rec2));
        assert_eq!(store.get(suites::MM2, &cfg1), None);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn reopen_with_different_shard_count_rebalances() {
        let dir = tmp_dir("rebalance");
        let mut recs = Vec::new();
        {
            let mut store = ShardedStore::open(&dir, 2).unwrap();
            for (w, seed) in [(suites::MM1, 3), (suites::MM3, 4), (suites::MV3, 5)] {
                let (rec, cfg) = record_for(w, seed, GpuArch::A100);
                store.append(rec.clone()).unwrap();
                recs.push((w, rec, cfg));
            }
        }
        let store = ShardedStore::open(&dir, 5).unwrap();
        assert_eq!(store.n_shards(), 5);
        assert_eq!(store.len(), 3);
        for (w, rec, cfg) in &recs {
            assert_eq!(store.get(*w, cfg), Some(rec), "{} survives rebalance", rec.workload_id);
        }
        // The new layout is durable: meta records 5 shards and a fresh
        // open at the same count does not rewrite anything.
        drop(store);
        let store = ShardedStore::open(&dir, 5).unwrap();
        assert_eq!(store.len(), 3);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn legacy_single_file_store_is_imported() {
        let dir = tmp_dir("legacy");
        let (rec, cfg) = record_for(suites::MM1, 6, GpuArch::A100);
        {
            let mut legacy = TuningStore::open(&dir).unwrap();
            legacy.append(rec.clone()).unwrap();
        }
        let store = ShardedStore::open(&dir, 3).unwrap();
        assert_eq!(store.get(suites::MM1, &cfg), Some(&rec));
        // The legacy file is archived so evicted records can never
        // resurrect from it, and a second open cannot re-import.
        assert!(!dir.join(crate::store::STORE_FILE).exists());
        assert!(dir.join(format!("{}.imported", crate::store::STORE_FILE)).exists());
        drop(store);
        let store = ShardedStore::open(&dir, 3).unwrap();
        assert_eq!(store.len(), 1);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn per_gpu_quota_evicts_least_recently_served() {
        let dir = tmp_dir("quota");
        let mut store = ShardedStore::open(&dir, 4).unwrap();
        let (rec_a, cfg_a) = record_for(suites::MM1, 7, GpuArch::A100);
        let (rec_b, cfg_b) = record_for(suites::MV3, 8, GpuArch::A100);
        let (rec_c, cfg_c) = record_for(suites::CONV2, 9, GpuArch::A100);
        store.append(rec_a.clone()).unwrap();
        store.append(rec_b.clone()).unwrap();
        // Serve A so B becomes the least-recently-served key.
        store.mark_served(&record_key(&rec_a)).unwrap();
        store.append(rec_c.clone()).unwrap();

        let evicted = store.enforce_limits(2, 0).unwrap();
        assert_eq!(evicted, 1);
        assert_eq!(store.len(), 2);
        assert!(store.get(suites::MV3, &cfg_b).is_none(), "LRU victim evicted");
        assert!(store.get(suites::MM1, &cfg_a).is_some(), "recently served key retained");
        assert!(store.get(suites::CONV2, &cfg_c).is_some(), "fresh key retained");

        // Eviction is durable and under quota no further eviction runs.
        drop(store);
        let mut store = ShardedStore::open(&dir, 4).unwrap();
        assert_eq!(store.len(), 2);
        assert_eq!(store.enforce_limits(2, 0).unwrap(), 0);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn quota_is_per_gpu_and_global_cap_is_global() {
        let dir = tmp_dir("pergpu");
        let mut store = ShardedStore::open(&dir, 2).unwrap();
        let (rec_a100, cfg_a100) = record_for(suites::MM1, 10, GpuArch::A100);
        let (rec_v100, cfg_v100) = record_for(suites::MM1, 11, GpuArch::V100);
        store.append(rec_a100).unwrap();
        store.append(rec_v100).unwrap();
        // One record per GPU: a per-GPU quota of 1 evicts nothing.
        assert_eq!(store.enforce_limits(1, 0).unwrap(), 0);
        assert!(store.get(suites::MM1, &cfg_a100).is_some());
        assert!(store.get(suites::MM1, &cfg_v100).is_some());
        // A global cap of 1 evicts the older key even across GPUs.
        assert_eq!(store.enforce_limits(0, 1).unwrap(), 1);
        assert_eq!(store.len(), 1);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn torn_trailing_line_is_dropped_and_repaired_on_open() {
        let dir = tmp_dir("torn");
        let (rec, cfg) = record_for(suites::MM1, 12, GpuArch::A100);
        let shard_path;
        {
            let mut store = ShardedStore::open(&dir, 1).unwrap();
            store.append(rec.clone()).unwrap();
            shard_path = dir.join(SHARDS_DIR).join(shard_file(0));
        }
        // Simulate a crash mid-append: an unterminated partial line.
        let mut text = std::fs::read_to_string(&shard_path).unwrap();
        text.push_str(r#"{"v":1,"workload_id":"mm_torn"#);
        std::fs::write(&shard_path, &text).unwrap();

        let mut store = ShardedStore::open(&dir, 1).unwrap();
        assert_eq!(store.len(), 1, "torn tail dropped, intact record kept");
        assert_eq!(store.get(suites::MM1, &cfg), Some(&rec));
        // The open repaired the file: appending again and reopening
        // must not produce a corrupt middle line.
        let (rec2, cfg2) = record_for(suites::MV3, 13, GpuArch::A100);
        store.append(rec2.clone()).unwrap();
        drop(store);
        let store = ShardedStore::open(&dir, 1).unwrap();
        assert_eq!(store.len(), 2);
        assert_eq!(store.get(suites::MV3, &cfg2), Some(&rec2));

        // Corruption in the MIDDLE of a shard is still a hard error.
        let mut lines: Vec<String> =
            std::fs::read_to_string(&shard_path).unwrap().lines().map(String::from).collect();
        lines[0] = "{broken".into();
        std::fs::write(&shard_path, format!("{}\n", lines.join("\n"))).unwrap();
        assert!(ShardedStore::open(&dir, 1).is_err());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn shard_routing_is_stable_and_in_range() {
        let dir = tmp_dir("routing");
        let store = ShardedStore::open(&dir, 8).unwrap();
        let key = serve_key("mm_b1_m512_n512_k512", "a100", "energy_aware", "fp");
        let shard = store.shard_of(&key);
        assert!(shard < 8);
        for _ in 0..10 {
            assert_eq!(store.shard_of(&key), shard, "routing must be deterministic");
        }
        let _ = std::fs::remove_dir_all(&dir);
    }
}
