//! The fleet-scale store layout behind the serving daemon: the tuning
//! store sharded across N append-only JSONL files, with eviction,
//! shared-ownership leases, incremental refresh, and an incremental
//! neighbor index.
//!
//! A single `tuning_store.jsonl` is fine for one experimenter; a daemon
//! serving fleet traffic accumulates orders of magnitude more keys and
//! must bound both file sizes and total footprint. This layer adds:
//!
//! * **sharding** — records are routed to `shards/shard_XXX.jsonl` by a
//!   hash of their serve key (workload id, GPU, mode, fingerprint), so
//!   appends and compactions touch one small file, never the world.
//!   Reopening with a different shard count **rebalances** the layout
//!   in place.
//! * **eviction** — beyond `cache prune`'s compaction: a per-GPU record
//!   quota and a global record cap, both evicting the least-recently
//!   **served** keys first (an LRU over serve traffic, persisted in a
//!   `served.jsonl` sidecar), so hot keys stay cached while dead
//!   workloads age out. [`ShardedStore::enforce_limits`] reports every
//!   victim (key, shard, reason) for the serve audit stream.
//! * **fleet mode** ([`ShardedStore::open_fleet`]) — N daemons mount
//!   one store concurrently. Appends and shard rewrites take per-shard
//!   advisory leases (`leases/shard_XXX.json`, see
//!   [`crate::store::lease`]); a crashed holder's lease expires and is
//!   reclaimed, and rewrites bump a per-shard generation counter
//!   (`leases/gen_XXX`) so the other daemons' **incremental refresh**
//!   ([`ShardedStore::refresh`]) knows when to re-read a whole shard
//!   instead of just its appended tail.
//! * **legacy import** — a PR-1 single-file store found in the same
//!   directory is folded into the shards on first open, then archived
//!   (`tuning_store.jsonl.imported`) so evicted records cannot
//!   resurrect from it.
//!
//! # In-process locking
//!
//! The store is internally synchronized and every operation takes
//! `&self` — a daemon shares one `ShardedStore` across all of its
//! connection handlers with **no outer lock**:
//!
//! * each shard's records sit behind their own `RwLock`, so an exact
//!   hit against shard A never waits behind another connection's miss
//!   refreshing shard B, and an append or eviction rewrite takes only
//!   its shard's lock;
//! * the served-LRU sidecar state has its own small mutex;
//! * the [`NeighborIndex`] has its own `RwLock`, maintained in lockstep
//!   with shard changes (append, refresh, reload, eviction rewrite,
//!   rebalance, import) and read without touching any shard.
//!
//! Lock order is `shard → index` (and the served mutex is never held
//! while taking either), so the store cannot deadlock against itself.
//!
//! Records are held as `Arc<TuningRecord>`: a worker snapshot
//! ([`ShardedStore::snapshot`]) is a vector of pointer clones, not an
//! O(N) deep copy, so rebuilding it after every write-back no longer
//! stalls hit replies on a large store.
//!
//! Configured via the `[serve]` and `[fleet]` sections
//! ([`crate::config::ServeConfig`], [`crate::config::FleetConfig`]).

use super::lease::Lease;
use super::neighbor_index::NeighborIndex;
use super::{StoreStats, TuningRecord, TuningStore, STORE_FILE};
use crate::config::SearchConfig;
use crate::util::Json;
use crate::workload::Workload;
use anyhow::{anyhow, Context as _};
use std::collections::{BTreeMap, HashMap, HashSet};
use std::path::{Path, PathBuf};
use std::sync::{Arc, Mutex, RwLock, RwLockWriteGuard};

/// Subdirectory of the store dir holding the shard files.
pub const SHARDS_DIR: &str = "shards";
/// Subdirectory of the store dir holding lease + generation files.
pub const LEASES_DIR: &str = "leases";
/// Shard-layout metadata file (shard count + layout version).
pub const META_FILE: &str = "meta.json";
/// Append-only sidecar of (key, tick) last-served events.
pub const SERVED_FILE: &str = "served.jsonl";
/// Version of the on-disk shard layout; bump on incompatible change.
pub const LAYOUT_VERSION: u64 = 1;
/// Lease name guarding `served.jsonl` compaction.
pub const SERVED_LEASE_NAME: &str = "served";

/// The serve key: the exact-hit identity of a record, also the unit of
/// shard routing and eviction.
pub fn serve_key(workload_id: &str, gpu: &str, mode: &str, fingerprint: &str) -> String {
    format!("{workload_id}|{gpu}|{mode}|{fingerprint}")
}

fn record_key(r: &TuningRecord) -> String {
    serve_key(&r.workload_id, &r.gpu, &r.mode, &r.fingerprint)
}

/// FNV-1a — stable across runs and platforms (shard routing must not
/// depend on `DefaultHasher`'s unspecified, per-process seed).
pub(crate) fn fnv1a(key: &str) -> u64 {
    let mut h: u64 = 0xcbf29ce484222325;
    for b in key.as_bytes() {
        h ^= *b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

/// Identity of one fleet member: lease holder id + lease TTL.
#[derive(Debug, Clone)]
pub struct FleetIdentity {
    pub holder: String,
    pub lease_ttl_ms: u64,
}

/// One evicted serve key, for the audit stream.
#[derive(Debug, Clone, PartialEq)]
pub struct EvictedKey {
    pub key: String,
    pub gpu: String,
    pub shard: usize,
    pub n_records: usize,
    /// `"per_gpu_quota"` or `"max_records"`.
    pub reason: &'static str,
}

/// Outcome of one [`ShardedStore::enforce_limits`] pass.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct EvictionReport {
    /// Records actually removed.
    pub n_evicted: usize,
    /// Victim keys, in eviction order.
    pub victims: Vec<EvictedKey>,
    /// Shards whose eviction was skipped because another daemon held
    /// their lease (retried on the next pass).
    pub n_skipped_shards: usize,
}

/// Outcome of a non-blocking fleet append attempt.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum AppendOutcome {
    /// Record written (memory + disk).
    Appended,
    /// The shard's lease is held by another live member right now —
    /// retry later, without holding any caller-side locks.
    LeaseBusy,
    /// The guarding claim is stale (the key was reclaimed by another
    /// daemon): the record must NOT be written.
    FencedOut,
}

/// Result of a lease attempt for a guarded store operation.
enum Guard {
    /// Single-owner store: no lease needed.
    Unneeded,
    Held(Lease),
    /// Another live daemon holds it.
    Busy,
}

impl Guard {
    fn available(&self) -> bool {
        !matches!(self, Guard::Busy)
    }

    fn release(self) {
        if let Guard::Held(lease) = self {
            let _ = lease.release();
        }
    }
}

/// One shard file parsed: records, bytes consumed (through the last
/// intact line), and whether a torn tail was dropped.
struct ShardLoad {
    records: Vec<Arc<TuningRecord>>,
    consumed: u64,
    torn: bool,
}

/// One shard's in-memory state, behind its own lock.
#[derive(Debug)]
struct ShardState {
    records: Vec<Arc<TuningRecord>>,
    /// Bytes of the shard file already ingested into memory.
    offset: u64,
    /// Last observed rewrite generation (fleet mode).
    gen: u64,
}

/// The served-LRU sidecar state, behind its own small mutex.
#[derive(Debug, Default)]
struct ServedState {
    /// Serve key -> last-served logical tick (0 = never served).
    served: HashMap<String, u64>,
    tick: u64,
    /// Lines appended to `served.jsonl` since the last compaction.
    appends: usize,
}

/// An exclusive in-process hold on one shard's lock — test
/// instrumentation (see [`ShardedStore::hold_shard`]).
pub struct ShardHold<'a> {
    _guard: RwLockWriteGuard<'a, ShardState>,
}

/// A sharded tuning store rooted at a store directory. Internally
/// synchronized (see the module docs); all operations take `&self`.
#[derive(Debug)]
pub struct ShardedStore {
    dir: PathBuf,
    shards_dir: PathBuf,
    leases_dir: PathBuf,
    n_shards: usize,
    shards: Vec<RwLock<ShardState>>,
    /// Incremental log-shape neighbor index over the shard records.
    index: RwLock<NeighborIndex>,
    served: Mutex<ServedState>,
    /// `Some` when this store is one member of a multi-daemon fleet.
    fleet: Option<FleetIdentity>,
}

impl ShardedStore {
    /// Open (creating if needed) a sharded store with `n_shards`
    /// shards, as the sole owner. An existing layout with a different
    /// shard count is rebalanced; a PR-1 single-file store in `dir` is
    /// imported when the shards are empty.
    pub fn open(dir: &Path, n_shards: usize) -> anyhow::Result<ShardedStore> {
        Self::open_inner(dir, n_shards, None)
    }

    /// Open as one member of a daemon fleet sharing this directory:
    /// appends and rewrites are fenced by per-shard leases held as
    /// `holder`, and [`ShardedStore::refresh`] ingests what the other
    /// members wrote.
    pub fn open_fleet(
        dir: &Path,
        n_shards: usize,
        holder: &str,
        lease_ttl_ms: u64,
    ) -> anyhow::Result<ShardedStore> {
        let fleet = FleetIdentity { holder: holder.to_string(), lease_ttl_ms };
        Self::open_inner(dir, n_shards, Some(fleet))
    }

    fn open_inner(
        dir: &Path,
        n_shards: usize,
        fleet: Option<FleetIdentity>,
    ) -> anyhow::Result<ShardedStore> {
        anyhow::ensure!(n_shards >= 1, "shard count must be >= 1");
        let shards_dir = dir.join(SHARDS_DIR);
        std::fs::create_dir_all(&shards_dir)
            .with_context(|| format!("create shards dir {shards_dir:?}"))?;
        let leases_dir = dir.join(LEASES_DIR);
        if fleet.is_some() {
            std::fs::create_dir_all(&leases_dir)
                .with_context(|| format!("create leases dir {leases_dir:?}"))?;
        }

        // Read the on-disk layout (if any) and load every shard file.
        let meta_path = shards_dir.join(META_FILE);
        let disk_shards = if meta_path.exists() { read_meta(&meta_path)? } else { n_shards };

        let gens: Vec<u64> = if fleet.is_some() {
            (0..n_shards).map(|i| read_gen_at(&leases_dir, i)).collect()
        } else {
            vec![0; n_shards]
        };
        let store = ShardedStore {
            dir: dir.to_path_buf(),
            shards_dir,
            leases_dir,
            n_shards,
            shards: gens
                .iter()
                .map(|&gen| RwLock::new(ShardState { records: Vec::new(), offset: 0, gen }))
                .collect(),
            index: RwLock::new(NeighborIndex::default()),
            served: Mutex::new(ServedState::default()),
            fleet,
        };

        let mut torn: Vec<usize> = Vec::new();
        let mut disk_loads: Vec<ShardLoad> = Vec::new();
        for i in 0..disk_shards {
            let load = load_shard_file(&store.shards_dir.join(shard_file(i)))?;
            if load.torn {
                torn.push(i);
            }
            disk_loads.push(load);
        }

        let rebalanced = disk_shards != n_shards;
        let import_legacy =
            disk_loads.iter().all(|l| l.records.is_empty()) && dir.join(STORE_FILE).exists();

        if rebalanced || import_legacy {
            // Layout-changing open: exclusive over every shard involved.
            let lock_n = disk_shards.max(n_shards);
            let mut guards: Vec<Guard> = Vec::new();
            let mut all = true;
            for i in 0..lock_n {
                match store.acquire_guard(&shard_lease_name(i), 3)? {
                    Guard::Busy => {
                        all = false;
                        break;
                    }
                    g => guards.push(g),
                }
            }
            if !all {
                for g in guards {
                    g.release();
                }
                anyhow::bail!(
                    "cannot {} {dir:?}: another daemon holds shard leases (stop the fleet first)",
                    if rebalanced { "rebalance" } else { "import a legacy store into" },
                );
            }
            // Route every record under the new layout, then rewrite.
            let mut routed: Vec<Vec<Arc<TuningRecord>>> = vec![Vec::new(); n_shards];
            for load in &disk_loads {
                for rec in &load.records {
                    routed[store.shard_of(&record_key(rec.as_ref()))].push(rec.clone());
                }
            }
            if import_legacy {
                let legacy = TuningStore::open(dir)?;
                for rec in legacy.records() {
                    routed[store.shard_of(&record_key(rec.as_ref()))].push(rec.clone());
                }
            }
            let res = (|| -> anyhow::Result<()> {
                for (i, records) in routed.into_iter().enumerate() {
                    let mut state = store.shards[i].write().expect("shard lock");
                    state.records = records;
                    store.rewrite_shard_locked(i, &mut state)?;
                }
                for i in n_shards..disk_shards {
                    let _ = std::fs::remove_file(store.shards_dir.join(shard_file(i)));
                }
                if import_legacy {
                    // Archive the imported file so records a later
                    // eviction removes cannot resurrect from it.
                    let imported = dir.join(format!("{STORE_FILE}.imported"));
                    std::fs::rename(dir.join(STORE_FILE), &imported)
                        .with_context(|| format!("archive imported legacy store to {imported:?}"))?;
                }
                store.write_meta()
            })();
            for g in guards {
                g.release();
            }
            res?;
        } else {
            // Same-layout open: adopt the records in place, then repair
            // any torn shard tail before a future append would
            // concatenate onto the partial line.
            for (i, load) in disk_loads.into_iter().enumerate() {
                let mut state = store.shards[i].write().expect("shard lock");
                state.records = load.records;
                state.offset = load.consumed;
            }
            for i in torn {
                let guard = store.acquire_guard(&shard_lease_name(i), 4)?;
                if !guard.available() {
                    anyhow::bail!(
                        "shard {i} of {dir:?} has a torn tail but a live daemon holds its \
                         lease; retry the open once it finishes"
                    );
                }
                let res = {
                    let mut state = store.shards[i].write().expect("shard lock");
                    store.rewrite_shard_locked(i, &mut state)
                };
                guard.release();
                res?;
            }
            if !meta_path.exists() {
                store.write_meta()?;
            }
        }

        store.rebuild_index();
        store.replay_served(true)?;
        Ok(store)
    }

    /// Open an existing sharded store with whatever shard count its
    /// meta file records, **without writing anything** — no rebalance,
    /// no legacy import, no sidecar compaction. Safe to run against a
    /// live daemon's store (`ecokernel cache` on a serve dir).
    pub fn open_existing(dir: &Path) -> anyhow::Result<ShardedStore> {
        let shards_dir = dir.join(SHARDS_DIR);
        let meta_path = shards_dir.join(META_FILE);
        anyhow::ensure!(meta_path.exists(), "no sharded store at {dir:?}");
        let n_shards = read_meta(&meta_path)?;
        let store = ShardedStore {
            dir: dir.to_path_buf(),
            shards_dir,
            leases_dir: dir.join(LEASES_DIR),
            n_shards,
            shards: (0..n_shards)
                .map(|_| RwLock::new(ShardState { records: Vec::new(), offset: 0, gen: 0 }))
                .collect(),
            index: RwLock::new(NeighborIndex::default()),
            served: Mutex::new(ServedState::default()),
            fleet: None,
        };
        for (i, shard) in store.shards.iter().enumerate() {
            let load = load_shard_file(&store.shards_dir.join(shard_file(i)))?;
            let mut state = shard.write().expect("shard lock");
            state.records = load.records;
            state.offset = load.consumed;
        }
        store.rebuild_index();
        store.replay_served(false)?;
        Ok(store)
    }

    pub fn dir(&self) -> &Path {
        &self.dir
    }

    pub fn n_shards(&self) -> usize {
        self.n_shards
    }

    /// Total records across all shards.
    pub fn len(&self) -> usize {
        self.shards.iter().map(|s| s.read().expect("shard lock").records.len()).sum()
    }

    pub fn is_empty(&self) -> bool {
        self.shards.iter().all(|s| s.read().expect("shard lock").records.is_empty())
    }

    /// All records, shard-major (shard 0 first, append order within),
    /// as pointer clones. Shards are locked one at a time, so the view
    /// may straddle a concurrent append — fine for stats, snapshots,
    /// and the CLI; exact-hit reads use [`ShardedStore::get`].
    pub fn records(&self) -> Vec<Arc<TuningRecord>> {
        let mut out = Vec::new();
        for shard in &self.shards {
            out.extend(shard.read().expect("shard lock").records.iter().cloned());
        }
        out
    }

    /// Records per shard (the `query --stats` size histogram).
    pub fn shard_sizes(&self) -> Vec<usize> {
        self.shards.iter().map(|s| s.read().expect("shard lock").records.len()).collect()
    }

    /// Shard index a serve key routes to.
    pub fn shard_of(&self, key: &str) -> usize {
        (fnv1a(key) % self.n_shards as u64) as usize
    }

    /// Records currently in the shard a key routes to (the scan length
    /// a lookup pays — the serving daemon's simulated reply-time term).
    pub fn shard_len_for(&self, key: &str) -> usize {
        self.shards[self.shard_of(key)].read().expect("shard lock").records.len()
    }

    /// Take one shard's in-process write lock and hold it until the
    /// returned guard drops. Test instrumentation: concurrency tests
    /// pin that a stalled operation on one shard (e.g. a refresh mid
    /// disk read) never blocks requests against the others.
    pub fn hold_shard(&self, shard: usize) -> ShardHold<'_> {
        ShardHold { _guard: self.shards[shard].write().expect("shard lock") }
    }

    /// The latest record exactly matching `(workload, gpu, mode)` and
    /// the config fingerprint — only the key's shard is locked and
    /// scanned.
    pub fn get(&self, workload: Workload, cfg: &SearchConfig) -> Option<Arc<TuningRecord>> {
        let id = workload.id();
        let fp = super::config_fingerprint(cfg);
        let key = serve_key(&id, cfg.gpu.name(), cfg.mode.name(), &fp);
        let state = self.shards[self.shard_of(&key)].read().expect("shard lock");
        state
            .records
            .iter()
            .rev()
            .find(|r| {
                r.workload_id == id
                    && r.gpu == cfg.gpu.name()
                    && r.mode == cfg.mode.name()
                    && r.fingerprint == fp
            })
            .cloned()
    }

    /// Nearest cached neighbors, served from the incremental
    /// [`NeighborIndex`] — candidate buckets only, never a full-store
    /// scan, and no shard lock is touched. Exactly equal to
    /// [`super::neighbors_among`] over [`ShardedStore::records`] (the
    /// parity test pins it).
    pub fn neighbors(
        &self,
        workload: Workload,
        gpu: &str,
        max_n: usize,
    ) -> Vec<(Arc<TuningRecord>, f64)> {
        self.index.read().expect("index lock").neighbors(workload, gpu, max_n)
    }

    /// Append a record to its shard (memory + one O_APPEND line) and
    /// mark its key hot (a fresh record must not be the next eviction
    /// victim). In fleet mode the append holds the shard's lease so it
    /// cannot be lost under a concurrent eviction rewrite.
    pub fn append(&self, rec: TuningRecord) -> anyhow::Result<()> {
        // Blocking variant for callers that hold no locks of their own:
        // wait out transient lease contention (~0.5s) before giving up
        // — the record is a finished multi-second search, and losing it
        // re-pays the whole search on the next miss. The daemon's
        // writer thread uses [`Self::try_append`] and parks the record
        // for a later retry instead of sleeping here.
        for attempt in 0..16 {
            if attempt > 0 {
                std::thread::sleep(std::time::Duration::from_millis(30));
            }
            if self.try_append(rec.clone())? == AppendOutcome::Appended {
                return Ok(());
            }
        }
        anyhow::bail!("shard lease stayed busy; append of {} not attempted", record_key(&rec));
    }

    /// Non-blocking append: one short lease attempt, then
    /// [`AppendOutcome::LeaseBusy`] instead of sleeping.
    pub fn try_append(&self, rec: TuningRecord) -> anyhow::Result<AppendOutcome> {
        let key = record_key(&rec);
        let shard = self.shard_of(&key);
        let guard = self.acquire_guard(&shard_lease_name(shard), 2)?;
        if !guard.available() {
            return Ok(AppendOutcome::LeaseBusy);
        }
        let res = {
            let mut state = self.shards[shard].write().expect("shard lock");
            self.append_locked(shard, &mut state, rec)
        };
        guard.release();
        res?;
        self.touch(&key)?;
        Ok(AppendOutcome::Appended)
    }

    /// Epoch-fenced non-blocking append: the write-back path of a fleet
    /// daemon whose in-flight claim on this key may have been reclaimed
    /// (its lease expired mid-search).
    pub fn try_append_claimed(
        &self,
        rec: TuningRecord,
        claim: &Lease,
    ) -> anyhow::Result<AppendOutcome> {
        if !claim.is_current()? {
            return Ok(AppendOutcome::FencedOut);
        }
        self.try_append(rec)
    }

    /// Epoch-fenced blocking append. Returns `Ok(false)` — record
    /// **not** written — when `claim` is stale.
    pub fn append_claimed(&self, rec: TuningRecord, claim: &Lease) -> anyhow::Result<bool> {
        if !claim.is_current()? {
            return Ok(false);
        }
        self.append(rec)?;
        Ok(true)
    }

    fn append_locked(
        &self,
        shard: usize,
        state: &mut ShardState,
        rec: TuningRecord,
    ) -> anyhow::Result<()> {
        let written =
            super::append_jsonl(&self.shards_dir.join(shard_file(shard)), &rec.to_json())?;
        if self.fleet.is_some() {
            // Consume the file tail (our line plus any the fleet
            // interleaved) so memory tracks the file exactly; the
            // refresh indexes every ingested record.
            self.refresh_shard_locked(shard, state)?;
        } else {
            let rec = Arc::new(rec);
            self.index.write().expect("index lock").insert(shard, &rec);
            state.records.push(rec);
            state.offset += written as u64;
        }
        Ok(())
    }

    /// Ingest everything the other fleet members wrote since the last
    /// look: appended tails are read incrementally, rewritten shards
    /// (generation bump or truncation) are reloaded whole. Returns the
    /// number of records touched (0 = nothing changed). No-op for a
    /// single-owner store. Shards are locked one at a time.
    pub fn refresh(&self) -> anyhow::Result<usize> {
        if self.fleet.is_none() {
            return Ok(0);
        }
        let mut changed = 0;
        for (i, shard) in self.shards.iter().enumerate() {
            let mut state = shard.write().expect("shard lock");
            changed += self.refresh_shard_locked(i, &mut state)?;
        }
        Ok(changed)
    }

    /// [`ShardedStore::refresh`] for one shard by index — the notify
    /// loop's targeted entry point (a peer announced a write-back
    /// landing in `shard`, so only that shard needs re-reading). Only
    /// that shard's lock is taken.
    pub fn refresh_shard(&self, shard: usize) -> anyhow::Result<usize> {
        if self.fleet.is_none() {
            return Ok(0);
        }
        anyhow::ensure!(
            shard < self.n_shards,
            "shard {shard} out of range (store has {} shards)",
            self.n_shards
        );
        let mut state = self.shards[shard].write().expect("shard lock");
        self.refresh_shard_locked(shard, &mut state)
    }

    /// [`ShardedStore::refresh`] for the single shard `key` routes to —
    /// the miss path's cheap "did another daemon already fill this?".
    /// Only that shard's lock is taken.
    pub fn refresh_key(&self, key: &str) -> anyhow::Result<usize> {
        if self.fleet.is_none() {
            return Ok(0);
        }
        let shard = self.shard_of(key);
        let mut state = self.shards[shard].write().expect("shard lock");
        self.refresh_shard_locked(shard, &mut state)
    }

    fn refresh_shard_locked(&self, shard: usize, state: &mut ShardState) -> anyhow::Result<usize> {
        if self.fleet.is_none() {
            return Ok(0);
        }
        use std::io::{Read as _, Seek as _};
        let path = self.shards_dir.join(shard_file(shard));
        let disk_gen = self.read_gen(shard);
        let len = std::fs::metadata(&path).map(|m| m.len()).unwrap_or(0);
        if disk_gen != state.gen || len < state.offset {
            return self.reload_shard_locked(shard, state, disk_gen);
        }
        if len == state.offset {
            return Ok(0);
        }
        let mut f = std::fs::File::open(&path).with_context(|| format!("open shard {path:?}"))?;
        f.seek(std::io::SeekFrom::Start(state.offset))
            .with_context(|| format!("seek shard {path:?}"))?;
        let mut buf = String::new();
        f.read_to_string(&mut buf).with_context(|| format!("read shard tail {path:?}"))?;
        // Only complete lines: a concurrent append's not-yet-flushed
        // tail stays unconsumed until the next refresh.
        let Some(end) = buf.rfind('\n') else { return Ok(0) };
        let complete = &buf[..=end];
        let mut parsed: Vec<Arc<TuningRecord>> = Vec::new();
        for line in complete.lines() {
            if line.trim().is_empty() {
                continue;
            }
            match Json::parse(line).and_then(|v| TuningRecord::from_json(&v)) {
                Ok(rec) => parsed.push(Arc::new(rec)),
                // Mid-tail garbage means we raced a rewrite around its
                // generation bump: the whole file is self-consistent,
                // so reload it.
                Err(_) => return self.reload_shard_locked(shard, state, disk_gen),
            }
        }
        let added = parsed.len();
        {
            let mut index = self.index.write().expect("index lock");
            for rec in &parsed {
                index.insert(shard, rec);
            }
        }
        state.records.extend(parsed);
        state.offset += complete.len() as u64;
        Ok(added)
    }

    fn reload_shard_locked(
        &self,
        shard: usize,
        state: &mut ShardState,
        disk_gen: u64,
    ) -> anyhow::Result<usize> {
        let load = load_shard_file(&self.shards_dir.join(shard_file(shard)))?;
        let n = load.records.len().max(state.records.len());
        state.records = load.records;
        state.offset = load.consumed;
        state.gen = disk_gen;
        self.index.write().expect("index lock").rebuild_shard(shard, &state.records);
        Ok(n)
    }

    /// Record that `key` was just served (bumps its LRU tick).
    pub fn mark_served(&self, key: &str) -> anyhow::Result<()> {
        self.touch(key)
    }

    /// Last-served tick of a key (0 = never).
    pub fn last_served(&self, key: &str) -> u64 {
        self.served.lock().expect("served lock").served.get(key).copied().unwrap_or(0)
    }

    /// Enforce the eviction policy: keep at most `per_gpu_quota`
    /// records per GPU and `max_records` records overall (0 disables
    /// either bound), evicting least-recently-served keys whole. In
    /// fleet mode every shard rewrite happens under that shard's lease;
    /// shards whose lease another daemon holds are skipped and retried
    /// on the next pass. Shards are locked one at a time, so requests
    /// against other shards keep flowing while one is rewritten.
    /// Returns what was evicted, for the audit stream.
    pub fn enforce_limits(
        &self,
        per_gpu_quota: usize,
        max_records: usize,
    ) -> anyhow::Result<EvictionReport> {
        if self.fleet.is_some() {
            // Count the whole fleet's records — and the whole fleet's
            // serve traffic: LRU ranking over only our own ticks would
            // evict the keys the *other* daemons serve hottest.
            self.refresh()?;
            let mut st = self.served.lock().expect("served lock");
            self.merge_served_from_disk_locked(&mut st)?;
        }
        // Aggregate per serve key: gpu, record count, last-served tick
        // (a snapshot of the LRU map — the served mutex is not held
        // across the shard scans).
        let served: HashMap<String, u64> = self.served.lock().expect("served lock").served.clone();
        let mut keys: BTreeMap<String, (String, usize, u64)> = BTreeMap::new();
        for shard in &self.shards {
            let state = shard.read().expect("shard lock");
            for r in &state.records {
                let key = record_key(r.as_ref());
                let tick = served.get(&key).copied().unwrap_or(0);
                let e = keys.entry(key).or_insert_with(|| (r.gpu.clone(), 0, tick));
                e.1 += 1;
            }
        }
        let mut per_gpu: HashMap<&str, usize> = HashMap::new();
        let mut total = 0usize;
        for (gpu, n, _) in keys.values() {
            *per_gpu.entry(gpu.as_str()).or_default() += *n;
            total += *n;
        }

        // Oldest-served first; deterministic tie-break on the key.
        let mut order: Vec<(&String, &(String, usize, u64))> = keys.iter().collect();
        order.sort_by(|a, b| a.1 .2.cmp(&b.1 .2).then_with(|| a.0.cmp(b.0)));

        let mut victims: Vec<EvictedKey> = Vec::new();
        for (key, (gpu, n, _)) in &order {
            let gpu_over =
                per_gpu_quota > 0 && per_gpu.values().any(|&count| count > per_gpu_quota);
            let total_over = max_records > 0 && total > max_records;
            if !gpu_over && !total_over {
                break;
            }
            let this_gpu_over = per_gpu_quota > 0
                && per_gpu.get(gpu.as_str()).copied().unwrap_or(0) > per_gpu_quota;
            if this_gpu_over || total_over {
                victims.push(EvictedKey {
                    key: (*key).clone(),
                    gpu: gpu.clone(),
                    shard: self.shard_of(key),
                    n_records: *n,
                    reason: if this_gpu_over { "per_gpu_quota" } else { "max_records" },
                });
                total -= *n;
                if let Some(count) = per_gpu.get_mut(gpu.as_str()) {
                    *count -= *n;
                }
            }
        }
        if victims.is_empty() {
            return Ok(EvictionReport::default());
        }

        let mut by_shard: BTreeMap<usize, Vec<EvictedKey>> = BTreeMap::new();
        for v in victims {
            by_shard.entry(v.shard).or_default().push(v);
        }
        let mut report = EvictionReport::default();
        for (shard, shard_victims) in by_shard {
            let guard = self.acquire_guard(&shard_lease_name(shard), 1)?;
            if !guard.available() {
                report.n_skipped_shards += 1;
                continue;
            }
            let res = (|| -> anyhow::Result<usize> {
                let mut state = self.shards[shard].write().expect("shard lock");
                if self.fleet.is_some() {
                    // See appends that landed after the count above;
                    // retained keys must survive the rewrite.
                    self.refresh_shard_locked(shard, &mut state)?;
                }
                let victim_set: HashSet<&str> =
                    shard_victims.iter().map(|v| v.key.as_str()).collect();
                let before = state.records.len();
                state.records.retain(|r| !victim_set.contains(record_key(r.as_ref()).as_str()));
                let removed = before - state.records.len();
                self.rewrite_shard_locked(shard, &mut state)?;
                self.index.write().expect("index lock").rebuild_shard(shard, &state.records);
                Ok(removed)
            })();
            guard.release();
            let removed = res?;
            report.n_evicted += removed;
            report.victims.extend(shard_victims);
        }
        if !report.victims.is_empty() {
            let mut st = self.served.lock().expect("served lock");
            for v in &report.victims {
                st.served.remove(&v.key);
            }
            // No re-merge here: the fleet's history was folded in at
            // the top of this pass, and re-reading the sidecar now
            // would resurrect the victims' entries we just dropped.
            self.compact_served_locked(&mut st, false)?;
        }
        Ok(report)
    }

    /// Flatten into a plain [`TuningStore`] snapshot (what background
    /// search workers consult for exact hits and warm-start transfer).
    /// Records are shared by `Arc` and the neighbor index is frozen in
    /// as an O(workload-ids) clone, so this never deep-copies records
    /// and transfer inside the search pays the indexed lookup too.
    pub fn snapshot(&self) -> TuningStore {
        let records = self.records();
        let index = Arc::new(self.index.read().expect("index lock").clone());
        TuningStore::from_records(&self.dir, records).with_index(index)
    }

    pub fn stats(&self) -> StoreStats {
        let records = self.records();
        super::stats_among(records.iter().map(|r| r.as_ref()))
    }

    /// Rebuild the whole neighbor index from the current shard records
    /// (open-time: rebalance, import, plain load).
    fn rebuild_index(&self) {
        let mut index = NeighborIndex::default();
        for (i, shard) in self.shards.iter().enumerate() {
            let state = shard.read().expect("shard lock");
            for rec in &state.records {
                index.insert(i, rec);
            }
        }
        *self.index.write().expect("index lock") = index;
    }

    fn touch(&self, key: &str) -> anyhow::Result<()> {
        // Wall-clock-ms ticks: fleet members append to one sidecar, so
        // recency must be comparable across daemons — a per-daemon
        // logical counter would make a quiet daemon's fresh serves look
        // ancient to a busy one's eviction pass. The max() keeps ticks
        // strictly increasing within this store against clock skew and
        // multiple touches in one millisecond.
        let (tick, want_compact) = {
            let mut st = self.served.lock().expect("served lock");
            st.tick = super::lease::now_ms().max(st.tick + 1);
            let tick = st.tick;
            st.served.insert(key.to_string(), tick);
            st.appends += 1;
            (tick, st.appends > 2 * st.served.len() + 64)
        };
        // The sidecar append runs OUTSIDE the served mutex: O_APPEND
        // whole-line writes interleave safely, and the hit path must
        // not serialize every request on one disk write. An append that
        // lands between a concurrent compactor's merge and its rename
        // loses one LRU bump from the file (not from memory) — benign,
        // and the same window the fleet's cross-process compaction
        // already tolerates.
        super::append_jsonl(
            &self.shards_dir.join(SERVED_FILE),
            &Json::obj(vec![("key", Json::str(key)), ("tick", Json::num(tick as f64))]),
        )?;
        // Compact online so a long-running daemon's sidecar stays
        // bounded at ~2 lines per live key (+ slack for small stores).
        if want_compact {
            let mut st = self.served.lock().expect("served lock");
            // Re-check: another thread may have compacted meanwhile.
            if st.appends > 2 * st.served.len() + 64 {
                self.compact_served_locked(&mut st, true)?;
            }
        }
        Ok(())
    }

    /// Acquire a named lease, or report it unneeded (single-owner) /
    /// busy (held by a live fleet member).
    fn acquire_guard(&self, name: &str, tries: usize) -> anyhow::Result<Guard> {
        let Some(fleet) = &self.fleet else {
            return Ok(Guard::Unneeded);
        };
        let path = self.leases_dir.join(format!("{name}.json"));
        for attempt in 0..tries.max(1) {
            if attempt > 0 {
                std::thread::sleep(std::time::Duration::from_millis(10));
            }
            if let Some(lease) = Lease::acquire(&path, &fleet.holder, fleet.lease_ttl_ms, None)? {
                return Ok(Guard::Held(lease));
            }
        }
        Ok(Guard::Busy)
    }

    fn read_gen(&self, shard: usize) -> u64 {
        read_gen_at(&self.leases_dir, shard)
    }

    fn replay_served(&self, compact: bool) -> anyhow::Result<()> {
        let path = self.shards_dir.join(SERVED_FILE);
        if !path.exists() {
            return Ok(());
        }
        let text = std::fs::read_to_string(&path).with_context(|| format!("read {path:?}"))?;
        let all: Vec<&str> = text.lines().collect();
        let last = all.iter().rposition(|l| !l.trim().is_empty());
        let mut st = self.served.lock().expect("served lock");
        let mut lines = 0usize;
        let mut torn = false;
        for (lineno, line) in all.iter().enumerate() {
            if line.trim().is_empty() {
                continue;
            }
            let parsed = Json::parse(line).and_then(|v| {
                let key = v
                    .get("key")
                    .and_then(|k| k.as_str())
                    .ok_or_else(|| "missing 'key'".to_string())?
                    .to_string();
                let tick = v
                    .get("tick")
                    .and_then(|t| t.as_f64())
                    .ok_or_else(|| "missing 'tick'".to_string())? as u64;
                Ok((key, tick))
            });
            match parsed {
                Ok((key, tick)) => {
                    // Max per key, not last-line-wins: fleet members'
                    // appends interleave and a lagging member's clock
                    // may write an older tick after a newer one — the
                    // same rule the disk merge uses, so a reopen and a
                    // running daemon agree.
                    let entry = st.served.entry(key).or_insert(0);
                    *entry = (*entry).max(tick);
                    st.tick = st.tick.max(tick);
                    lines += 1;
                }
                // A torn trailing touch only loses one LRU bump.
                Err(e) if Some(lineno) == last => {
                    eprintln!(
                        "warning: {path:?} line {}: dropping torn trailing line ({e})",
                        lineno + 1
                    );
                    torn = true;
                }
                Err(e) => return Err(anyhow!("{path:?} line {}: {e}", lineno + 1)),
            }
        }
        // Compact a sidecar that has grown past ~2 lines per live key,
        // or whose tail is torn (a future append would concatenate onto
        // the partial line). Never in read-only opens.
        if compact && (torn || lines > 2 * st.served.len().max(1)) {
            self.compact_served_locked(&mut st, true)?;
        }
        Ok(())
    }

    fn write_meta(&self) -> anyhow::Result<()> {
        let path = self.shards_dir.join(META_FILE);
        let v = Json::obj(vec![
            ("v", Json::num(LAYOUT_VERSION as f64)),
            ("n_shards", Json::num(self.n_shards as f64)),
        ]);
        write_atomic(&path, &v.to_string())
    }

    /// Rewrite one shard file from memory (the caller holds the shard's
    /// in-process lock, and its lease in fleet mode). The per-shard
    /// generation is bumped AFTER the atomic rename — a member
    /// refreshing inside the window sees either old gen + shrunken file
    /// (caught by the `len < offset` check: in-place rewrites only ever
    /// shrink) or the gen bump (one redundant reload) — never a stale
    /// byte offset applied to content it did not load.
    fn rewrite_shard_locked(&self, shard: usize, state: &mut ShardState) -> anyhow::Result<()> {
        let path = self.shards_dir.join(shard_file(shard));
        let mut text = String::new();
        for r in &state.records {
            text.push_str(&r.to_json().to_string());
            text.push('\n');
        }
        write_atomic(&path, &text)?;
        state.offset = text.len() as u64;
        if self.fleet.is_some() {
            let g = state.gen.max(self.read_gen(shard)) + 1;
            write_atomic(&self.leases_dir.join(gen_file(shard)), &format!("{g}\n"))?;
            state.gen = g;
        }
        Ok(())
    }

    /// Compact `served.jsonl`, lease-guarded in fleet mode (skipped —
    /// and retried later — while another member compacts). The caller
    /// holds the served mutex, which serializes in-process compactors.
    fn compact_served_locked(&self, st: &mut ServedState, merge: bool) -> anyhow::Result<()> {
        if self.fleet.is_none() {
            return self.rewrite_served_locked(st, merge);
        }
        let guard = self.acquire_guard(SERVED_LEASE_NAME, 1)?;
        if !guard.available() {
            return Ok(());
        }
        let res = self.rewrite_served_locked(st, merge);
        guard.release();
        res
    }

    /// Fold the on-disk `served.jsonl` into the in-memory LRU map: max
    /// tick per key. Fleet members append their touches to the same
    /// sidecar, so eviction ranking and compaction must see everyone's
    /// serve history, not just ours. Malformed lines (including a torn
    /// tail) are skipped — a lost bump is benign.
    fn merge_served_from_disk_locked(&self, st: &mut ServedState) -> anyhow::Result<()> {
        let path = self.shards_dir.join(SERVED_FILE);
        let text = match std::fs::read_to_string(&path) {
            Ok(t) => t,
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => return Ok(()),
            Err(e) => return Err(e).with_context(|| format!("read {path:?}")),
        };
        for line in text.lines() {
            if line.trim().is_empty() {
                continue;
            }
            let Ok(v) = Json::parse(line) else { continue };
            let key = v.get("key").and_then(|k| k.as_str());
            let tick = v.get("tick").and_then(|t| t.as_f64());
            if let (Some(key), Some(tick)) = (key, tick) {
                let tick = tick as u64;
                let entry = st.served.entry(key.to_string()).or_insert(0);
                *entry = (*entry).max(tick);
                st.tick = st.tick.max(tick);
            }
        }
        Ok(())
    }

    fn rewrite_served_locked(&self, st: &mut ServedState, merge: bool) -> anyhow::Result<()> {
        // Compaction must not discard the other members' LRU history:
        // fold the on-disk state in first (touches they append between
        // this merge and the rename lose one bump — benign).
        if merge && self.fleet.is_some() {
            self.merge_served_from_disk_locked(st)?;
        }
        let path = self.shards_dir.join(SERVED_FILE);
        let mut entries: Vec<(&String, &u64)> = st.served.iter().collect();
        entries.sort_by_key(|(_, tick)| **tick);
        let mut text = String::new();
        for (key, tick) in entries {
            text.push_str(
                &Json::obj(vec![
                    ("key", Json::str(key.clone())),
                    ("tick", Json::num(*tick as f64)),
                ])
                .to_string(),
            );
            text.push('\n');
        }
        st.appends = 0;
        write_atomic(&path, &text)
    }
}

fn shard_file(i: usize) -> String {
    format!("shard_{i:03}.jsonl")
}

/// Name of the lease guarding shard `i`'s rewrites and appends.
pub fn shard_lease_name(i: usize) -> String {
    format!("shard_{i:03}")
}

fn gen_file(i: usize) -> String {
    format!("gen_{i:03}")
}

/// Last rewrite generation recorded for a shard (0 = never rewritten).
fn read_gen_at(leases_dir: &Path, shard: usize) -> u64 {
    std::fs::read_to_string(leases_dir.join(gen_file(shard)))
        .ok()
        .and_then(|t| t.trim().parse::<u64>().ok())
        .unwrap_or(0)
}

/// Parse `meta.json`: validate the layout version, return the shard
/// count (shared by [`ShardedStore::open`] and
/// [`ShardedStore::open_existing`]).
fn read_meta(meta_path: &Path) -> anyhow::Result<usize> {
    let text =
        std::fs::read_to_string(meta_path).with_context(|| format!("read {meta_path:?}"))?;
    let v = Json::parse(&text).map_err(|e| anyhow!("{meta_path:?}: {e}"))?;
    let layout = v.get("v").and_then(|x| x.as_f64()).unwrap_or(0.0) as u64;
    anyhow::ensure!(
        layout == LAYOUT_VERSION,
        "unsupported shard layout version {layout} (this build reads v{LAYOUT_VERSION})"
    );
    Ok(v.get("n_shards")
        .and_then(|x| x.as_f64())
        .filter(|&n| n >= 1.0)
        .ok_or_else(|| anyhow!("{meta_path:?}: missing 'n_shards'"))? as usize)
}

/// Load one shard file: records, bytes consumed, torn-tail flag.
///
/// A malformed FINAL line is dropped with a warning rather than failing
/// the open: a daemon killed mid-append can tear at most the last line
/// (see [`super::append_jsonl`]), and a torn tail must not leave the
/// store unbootable. Corruption anywhere else is still a hard error.
fn load_shard_file(path: &Path) -> anyhow::Result<ShardLoad> {
    let mut out = ShardLoad { records: Vec::new(), consumed: 0, torn: false };
    if !path.exists() {
        return Ok(out);
    }
    let text = std::fs::read_to_string(path).with_context(|| format!("read shard {path:?}"))?;
    let lines: Vec<&str> = text.lines().collect();
    let last = lines.iter().rposition(|l| !l.trim().is_empty());
    let mut pos = 0u64;
    for (lineno, line) in lines.iter().enumerate() {
        // `lines()` strips the newline; account for it when present.
        let raw_len = line.len() as u64
            + if text.len() as u64 > pos + line.len() as u64 { 1 } else { 0 };
        if line.trim().is_empty() {
            pos += raw_len;
            out.consumed = pos;
            continue;
        }
        match Json::parse(line).and_then(|v| TuningRecord::from_json(&v)) {
            Ok(rec) => {
                out.records.push(Arc::new(rec));
                pos += raw_len;
                out.consumed = pos;
            }
            Err(e) if Some(lineno) == last => {
                eprintln!(
                    "warning: {path:?} line {}: dropping torn trailing line ({e})",
                    lineno + 1
                );
                out.torn = true;
                break;
            }
            Err(e) => return Err(anyhow!("{path:?} line {}: {e}", lineno + 1)),
        }
    }
    Ok(out)
}

fn write_atomic(path: &Path, text: &str) -> anyhow::Result<()> {
    let tmp = path.with_extension("tmp");
    std::fs::write(&tmp, text).with_context(|| format!("write {tmp:?}"))?;
    std::fs::rename(&tmp, path).with_context(|| format!("replace {path:?}"))?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::GpuArch;
    use crate::store::neighbors_among;
    use crate::util::Rng;
    use crate::workload::suites;

    fn tmp_dir(tag: &str) -> PathBuf {
        let dir = std::env::temp_dir()
            .join(format!("ecokernel_sharded_test_{tag}_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        dir
    }

    fn quick_cfg(seed: u64, gpu: GpuArch) -> SearchConfig {
        SearchConfig {
            gpu,
            population: 24,
            m_latency_keep: 6,
            rounds: 3,
            patience: 0,
            seed,
            ..Default::default()
        }
    }

    fn record_for(w: Workload, seed: u64, gpu: GpuArch) -> (TuningRecord, SearchConfig) {
        let cfg = quick_cfg(seed, gpu);
        let out = crate::search::run_search(w, &cfg);
        (TuningRecord::from_outcome(&out, &cfg), cfg)
    }

    /// A cheap handmade record (no search): enough structure for
    /// routing, persistence roundtrips, and neighbor selection.
    fn quick_record(w: Workload, gpu: GpuArch, seed: u64) -> TuningRecord {
        TuningRecord::synthetic(w, gpu, seed)
    }

    #[test]
    fn append_get_and_reopen_roundtrip() {
        let dir = tmp_dir("roundtrip");
        let (rec1, cfg1) = record_for(suites::MM1, 1, GpuArch::A100);
        let (rec2, cfg2) = record_for(suites::MV3, 2, GpuArch::A100);
        {
            let store = ShardedStore::open(&dir, 4).unwrap();
            store.append(rec1.clone()).unwrap();
            store.append(rec2.clone()).unwrap();
            assert_eq!(store.len(), 2);
        }
        let store = ShardedStore::open(&dir, 4).unwrap();
        assert_eq!(store.len(), 2);
        assert_eq!(store.get(suites::MM1, &cfg1).as_deref(), Some(&rec1));
        assert_eq!(store.get(suites::MV3, &cfg2).as_deref(), Some(&rec2));
        assert_eq!(store.get(suites::MM2, &cfg1), None);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn reopen_with_different_shard_count_rebalances() {
        let dir = tmp_dir("rebalance");
        let mut recs = Vec::new();
        {
            let store = ShardedStore::open(&dir, 2).unwrap();
            for (w, seed) in [(suites::MM1, 3), (suites::MM3, 4), (suites::MV3, 5)] {
                let (rec, cfg) = record_for(w, seed, GpuArch::A100);
                store.append(rec.clone()).unwrap();
                recs.push((w, rec, cfg));
            }
        }
        let store = ShardedStore::open(&dir, 5).unwrap();
        assert_eq!(store.n_shards(), 5);
        assert_eq!(store.len(), 3);
        for (w, rec, cfg) in &recs {
            assert_eq!(
                store.get(*w, cfg).as_deref(),
                Some(rec),
                "{} survives rebalance",
                rec.workload_id
            );
        }
        // The new layout is durable: meta records 5 shards and a fresh
        // open at the same count does not rewrite anything.
        drop(store);
        let store = ShardedStore::open(&dir, 5).unwrap();
        assert_eq!(store.len(), 3);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn legacy_single_file_store_is_imported() {
        let dir = tmp_dir("legacy");
        let (rec, cfg) = record_for(suites::MM1, 6, GpuArch::A100);
        {
            let mut legacy = TuningStore::open(&dir).unwrap();
            legacy.append(rec.clone()).unwrap();
        }
        let store = ShardedStore::open(&dir, 3).unwrap();
        assert_eq!(store.get(suites::MM1, &cfg).as_deref(), Some(&rec));
        // The legacy file is archived so evicted records can never
        // resurrect from it, and a second open cannot re-import.
        assert!(!dir.join(crate::store::STORE_FILE).exists());
        assert!(dir.join(format!("{}.imported", crate::store::STORE_FILE)).exists());
        drop(store);
        let store = ShardedStore::open(&dir, 3).unwrap();
        assert_eq!(store.len(), 1);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn per_gpu_quota_evicts_least_recently_served() {
        let dir = tmp_dir("quota");
        let store = ShardedStore::open(&dir, 4).unwrap();
        let (rec_a, cfg_a) = record_for(suites::MM1, 7, GpuArch::A100);
        let (rec_b, cfg_b) = record_for(suites::MV3, 8, GpuArch::A100);
        let (rec_c, cfg_c) = record_for(suites::CONV2, 9, GpuArch::A100);
        store.append(rec_a.clone()).unwrap();
        store.append(rec_b.clone()).unwrap();
        // Serve A so B becomes the least-recently-served key.
        store.mark_served(&record_key(&rec_a)).unwrap();
        store.append(rec_c.clone()).unwrap();

        let report = store.enforce_limits(2, 0).unwrap();
        assert_eq!(report.n_evicted, 1);
        assert_eq!(report.victims.len(), 1);
        assert_eq!(report.victims[0].key, record_key(&rec_b), "victim identity reported");
        assert_eq!(report.victims[0].reason, "per_gpu_quota");
        assert_eq!(report.victims[0].shard, store.shard_of(&record_key(&rec_b)));
        assert_eq!(store.len(), 2);
        assert!(store.get(suites::MV3, &cfg_b).is_none(), "LRU victim evicted");
        assert!(store.get(suites::MM1, &cfg_a).is_some(), "recently served key retained");
        assert!(store.get(suites::CONV2, &cfg_c).is_some(), "fresh key retained");

        // Eviction is durable and under quota no further eviction runs.
        drop(store);
        let store = ShardedStore::open(&dir, 4).unwrap();
        assert_eq!(store.len(), 2);
        assert_eq!(store.enforce_limits(2, 0).unwrap(), EvictionReport::default());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn quota_is_per_gpu_and_global_cap_is_global() {
        let dir = tmp_dir("pergpu");
        let store = ShardedStore::open(&dir, 2).unwrap();
        let (rec_a100, cfg_a100) = record_for(suites::MM1, 10, GpuArch::A100);
        let (rec_v100, cfg_v100) = record_for(suites::MM1, 11, GpuArch::V100);
        store.append(rec_a100).unwrap();
        store.append(rec_v100).unwrap();
        // One record per GPU: a per-GPU quota of 1 evicts nothing.
        assert_eq!(store.enforce_limits(1, 0).unwrap().n_evicted, 0);
        assert!(store.get(suites::MM1, &cfg_a100).is_some());
        assert!(store.get(suites::MM1, &cfg_v100).is_some());
        // A global cap of 1 evicts the older key even across GPUs.
        let report = store.enforce_limits(0, 1).unwrap();
        assert_eq!(report.n_evicted, 1);
        assert_eq!(report.victims[0].reason, "max_records");
        assert_eq!(store.len(), 1);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn torn_trailing_line_is_dropped_and_repaired_on_open() {
        let dir = tmp_dir("torn");
        let (rec, cfg) = record_for(suites::MM1, 12, GpuArch::A100);
        let shard_path;
        {
            let store = ShardedStore::open(&dir, 1).unwrap();
            store.append(rec.clone()).unwrap();
            shard_path = dir.join(SHARDS_DIR).join(shard_file(0));
        }
        // Simulate a crash mid-append: an unterminated partial line.
        let mut text = std::fs::read_to_string(&shard_path).unwrap();
        text.push_str(r#"{"v":1,"workload_id":"mm_torn"#);
        std::fs::write(&shard_path, &text).unwrap();

        let store = ShardedStore::open(&dir, 1).unwrap();
        assert_eq!(store.len(), 1, "torn tail dropped, intact record kept");
        assert_eq!(store.get(suites::MM1, &cfg).as_deref(), Some(&rec));
        // The open repaired the file: appending again and reopening
        // must not produce a corrupt middle line.
        let (rec2, cfg2) = record_for(suites::MV3, 13, GpuArch::A100);
        store.append(rec2.clone()).unwrap();
        drop(store);
        let store = ShardedStore::open(&dir, 1).unwrap();
        assert_eq!(store.len(), 2);
        assert_eq!(store.get(suites::MV3, &cfg2).as_deref(), Some(&rec2));

        // Corruption in the MIDDLE of a shard is still a hard error.
        let mut lines: Vec<String> =
            std::fs::read_to_string(&shard_path).unwrap().lines().map(String::from).collect();
        lines[0] = "{broken".into();
        std::fs::write(&shard_path, format!("{}\n", lines.join("\n"))).unwrap();
        assert!(ShardedStore::open(&dir, 1).is_err());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn shard_routing_is_stable_and_in_range() {
        let dir = tmp_dir("routing");
        let store = ShardedStore::open(&dir, 8).unwrap();
        let key = serve_key("mm_b1_m512_n512_k512", "a100", "energy_aware", "fp");
        let shard = store.shard_of(&key);
        assert!(shard < 8);
        for _ in 0..10 {
            assert_eq!(store.shard_of(&key), shard, "routing must be deterministic");
        }
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn snapshots_share_record_allocations() {
        let dir = tmp_dir("arcsnap");
        let store = ShardedStore::open(&dir, 2).unwrap();
        let (rec, _) = record_for(suites::MM1, 14, GpuArch::A100);
        store.append(rec).unwrap();
        let s1 = store.snapshot();
        let s2 = store.snapshot();
        assert_eq!(s1.len(), 1);
        // The snapshot is pointer clones of the store's records, not a
        // deep copy: two snapshots share the same allocation.
        assert!(
            Arc::ptr_eq(&s1.records()[0], &s2.records()[0]),
            "snapshot must share the store's Arc allocations"
        );
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn fleet_refresh_ingests_foreign_appends_and_rewrites() {
        let dir = tmp_dir("refresh");
        let s1 = ShardedStore::open_fleet(&dir, 2, "h1", 60_000).unwrap();
        let s2 = ShardedStore::open_fleet(&dir, 2, "h2", 60_000).unwrap();

        // s1's append becomes visible to s2 through refresh only.
        let (rec_a, cfg_a) = record_for(suites::MM1, 15, GpuArch::A100);
        s1.append(rec_a.clone()).unwrap();
        assert!(s2.get(suites::MM1, &cfg_a).is_none(), "not yet refreshed");
        assert!(s2.refresh().unwrap() > 0);
        assert_eq!(s2.get(suites::MM1, &cfg_a).as_deref(), Some(&rec_a));

        // A foreign eviction rewrite (generation bump) is picked up too.
        let (rec_b, cfg_b) = record_for(suites::MV3, 16, GpuArch::A100);
        s2.append(rec_b.clone()).unwrap();
        s2.mark_served(&record_key(&rec_b)).unwrap();
        let report = s2.enforce_limits(0, 1).unwrap();
        assert_eq!(report.n_evicted, 1, "older key evicted under the global cap");
        s1.refresh().unwrap();
        assert!(s1.get(suites::MM1, &cfg_a).is_none(), "s1 sees the fleet eviction");
        assert_eq!(s1.get(suites::MV3, &cfg_b).as_deref(), Some(&rec_b), "s1 sees the append");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn fleet_eviction_skips_shards_whose_lease_is_held() {
        let dir = tmp_dir("leaseheld");
        let store = ShardedStore::open_fleet(&dir, 1, "evictor", 60_000).unwrap();
        let (rec_a, _) = record_for(suites::MM1, 17, GpuArch::A100);
        let (rec_b, cfg_b) = record_for(suites::MV3, 18, GpuArch::A100);
        store.append(rec_a.clone()).unwrap();
        store.append(rec_b.clone()).unwrap();
        store.mark_served(&record_key(&rec_b)).unwrap();

        // A live foreign holder owns the only shard's lease.
        let lease_path = dir.join(LEASES_DIR).join(format!("{}.json", shard_lease_name(0)));
        let foreign = Lease::acquire(&lease_path, "other-daemon", 60_000, None)
            .unwrap()
            .expect("foreign daemon takes the shard lease");
        let report = store.enforce_limits(0, 1).unwrap();
        assert_eq!(report.n_evicted, 0, "lease held: nothing evicted");
        assert_eq!(report.n_skipped_shards, 1);
        assert_eq!(store.len(), 2);

        // Once released, the next pass evicts normally.
        foreign.release().unwrap();
        let report = store.enforce_limits(0, 1).unwrap();
        assert_eq!(report.n_evicted, 1);
        assert_eq!(store.len(), 1);
        assert_eq!(store.get(suites::MV3, &cfg_b).as_deref(), Some(&rec_b), "served key kept");
        let _ = std::fs::remove_dir_all(&dir);
    }

    /// The incremental neighbor index returns byte-identical results to
    /// the brute-force scan through every maintenance path: appends,
    /// eviction rewrites, fleet refresh of foreign appends, and a
    /// rebalancing reopen.
    #[test]
    fn neighbor_index_matches_brute_force_through_store_ops() {
        let dir = tmp_dir("nnparity");

        fn check(store: &ShardedStore, targets: &[Workload], tag: &str) {
            let all = store.records();
            for &target in targets {
                for gpu in ["a100", "v100"] {
                    for max_n in [1, 3, 8] {
                        let fast: Vec<(String, u64, f64)> = store
                            .neighbors(target, gpu, max_n)
                            .into_iter()
                            .map(|(r, d)| (r.workload_id.clone(), r.seed, d))
                            .collect();
                        let brute: Vec<(String, u64, f64)> =
                            neighbors_among(all.iter().map(|r| r.as_ref()), target, gpu, max_n)
                                .into_iter()
                                .map(|(r, d)| (r.workload_id.clone(), r.seed, d))
                                .collect();
                        assert_eq!(fast, brute, "{tag}: target={target} gpu={gpu} n={max_n}");
                    }
                }
            }
        }

        // A randomized population: mixed families, two GPUs, duplicate
        // workload ids under different fingerprints, some records
        // without a measured pool (invisible to neighbor selection).
        let mut rng = Rng::seed_from_u64(99);
        let mut pool: Vec<Workload> = vec![suites::CONV1, suites::CONV2];
        fn dim(rng: &mut Rng, hi: usize) -> usize {
            1usize << rng.gen_range(0, hi)
        }
        for _ in 0..16 {
            let mv = rng.gen_f64() < 0.3;
            pool.push(if mv {
                Workload::MatVec {
                    batch: dim(&mut rng, 5),
                    n: dim(&mut rng, 11),
                    k: dim(&mut rng, 11),
                }
            } else {
                Workload::MatMul {
                    batch: 1,
                    m: dim(&mut rng, 11),
                    n: dim(&mut rng, 11),
                    k: dim(&mut rng, 11),
                }
            });
        }
        let targets = [suites::MM1, suites::MV3, suites::CONV2, pool[3], pool[9]];

        let store = ShardedStore::open(&dir, 4).unwrap();
        for (i, &w) in pool.iter().enumerate() {
            let gpu = if i % 3 == 0 { GpuArch::V100 } else { GpuArch::A100 };
            let mut rec = quick_record(w, gpu, i as u64);
            if i % 5 == 0 {
                rec.measured.clear();
            }
            store.append(rec).unwrap();
        }
        // Duplicate ids under fresh fingerprints: "latest wins".
        store.append(quick_record(pool[4], GpuArch::A100, 900)).unwrap();
        store.append(quick_record(pool[4], GpuArch::A100, 901)).unwrap();
        check(&store, &targets, "after appends");

        // Eviction rewrites shards; the index follows.
        let first_key = record_key(store.records()[0].as_ref());
        store.mark_served(&first_key).unwrap();
        store.enforce_limits(0, 9).unwrap();
        check(&store, &targets, "after eviction");
        drop(store);

        // A foreign fleet append arrives through refresh.
        let s1 = ShardedStore::open_fleet(&dir, 4, "h1", 60_000).unwrap();
        let s2 = ShardedStore::open_fleet(&dir, 4, "h2", 60_000).unwrap();
        s1.append(quick_record(suites::MM4, GpuArch::A100, 777)).unwrap();
        s2.refresh().unwrap();
        check(&s2, &targets, "after fleet refresh");
        drop(s1);
        drop(s2);

        // A rebalancing reopen rebuilds the index over the new layout.
        let store = ShardedStore::open(&dir, 7).unwrap();
        check(&store, &targets, "after rebalance");
        let _ = std::fs::remove_dir_all(&dir);
    }

    /// Per-shard locks: operations against one shard proceed while
    /// another shard's lock is held (a stalled refresh, simulated with
    /// [`ShardedStore::hold_shard`]).
    #[test]
    fn other_shards_stay_servable_while_one_shard_is_held() {
        let dir = tmp_dir("shardhold");
        let store = ShardedStore::open(&dir, 2).unwrap();
        // Find two handmade records routing to different shards (seeds
        // change the fingerprint, so candidates are unbounded).
        let mut by_shard: [Option<(Workload, SearchConfig)>; 2] = [None, None];
        'fill: for seed in 0..8u64 {
            for (i, (_, w)) in suites::table2_suite().iter().enumerate() {
                let cfg = quick_cfg(30 + seed * 31 + i as u64, GpuArch::A100);
                let fp = crate::store::config_fingerprint(&cfg);
                let key = serve_key(&w.id(), cfg.gpu.name(), cfg.mode.name(), &fp);
                let shard = store.shard_of(&key);
                if by_shard[shard].is_none() {
                    let mut rec = quick_record(*w, GpuArch::A100, cfg.seed);
                    rec.fingerprint = fp;
                    store.append(rec).unwrap();
                    by_shard[shard] = Some((*w, cfg));
                }
                if by_shard.iter().all(|s| s.is_some()) {
                    break 'fill;
                }
            }
        }
        let (w_a, cfg_a) = by_shard[0].clone().expect("a key routing to shard 0");
        let (w_b, cfg_b) = by_shard[1].clone().expect("a key routing to shard 1");

        let store = Arc::new(store);
        let hold = store.hold_shard(1);

        // Shard 0 stays fully servable (lookup + LRU touch)...
        let (tx, rx) = std::sync::mpsc::channel();
        let s = store.clone();
        std::thread::spawn(move || {
            let hit = s.get(w_a, &cfg_a).is_some();
            let key = serve_key(
                &w_a.id(),
                cfg_a.gpu.name(),
                cfg_a.mode.name(),
                &crate::store::config_fingerprint(&cfg_a),
            );
            s.mark_served(&key).unwrap();
            tx.send(hit).unwrap();
        });
        let served = rx.recv_timeout(std::time::Duration::from_secs(20));
        assert_eq!(served, Ok(true), "shard 0 must serve while shard 1 is held");

        // ...while a lookup against the held shard waits for the hold.
        let (tx, rx) = std::sync::mpsc::channel();
        let s = store.clone();
        std::thread::spawn(move || {
            tx.send(s.get(w_b, &cfg_b).is_some()).unwrap();
        });
        assert!(
            rx.recv_timeout(std::time::Duration::from_millis(300)).is_err(),
            "a shard-1 lookup must block behind the held lock"
        );
        drop(hold);
        assert_eq!(rx.recv_timeout(std::time::Duration::from_secs(20)), Ok(true));
        let _ = std::fs::remove_dir_all(&dir);
    }
}
