//! Static schedule analysis: zero-measurement energy priors.
//!
//! Walks a legalized [`Schedule`] + [`Workload`] and derives a
//! deterministic [`StaticProfile`] — modeled memory traffic per level,
//! arithmetic intensity, occupancy geometry, tile-reuse factor, a
//! predicted-stall fraction, and a **closed-form** static energy /
//! latency estimate per [`GpuSpec`]. No simulator run, no NVML
//! measurement: everything here is the arithmetic a compiler can do
//! from the schedule alone (FlipFlop-style static analysis), which is
//! why the serving daemon can answer never-seen keys from it at wire
//! speed and the cost model can use it as a prior before it has a
//! single sample (DSO-style static+dynamic fusion).
//!
//! The pass deliberately reuses only the *static* substrates of the
//! simulator — [`MemoryTraffic::compute`] (blocked-GEMM byte counting)
//! and [`occupancy`] (resource-limit arithmetic) — and never the
//! latency/power models themselves (`sim::latency::latency`,
//! `sim::power::energy`): no ILP pipeline model, no TDP throttling, no
//! DVFS, no thermal state. The estimate is a roofline, not a
//! simulation, and it is **structurally monotone** in modeled DRAM
//! traffic (pinned by a property test below).
//!
//! Three consumers:
//!
//! 1. [`crate::features`] folds four profile-derived features into the
//!    GBDT input vector (geometry/bandwidth only — never the energy
//!    coefficients, so the "features do not leak energy" invariant
//!    holds);
//! 2. [`crate::costmodel::EnergyCostModel::predict_energy_batch_with_prior`]
//!    falls back to `static_energy_j` when it has zero samples, and
//!    [`crate::store::transfer`] rescales neighbor samples by the
//!    static-energy ratio instead of the cruder MAC ratio;
//! 3. the serve daemon's **static tier** answers a never-seen key with
//!    the best-of-N statically-ranked legal schedule ([`rank_static`])
//!    while the real search runs in the background; the `analyze` CLI
//!    subcommand dumps the same profile as JSON for inspection and CI
//!    golden pins.

use crate::config::GpuSpec;
use crate::schedule::space::ScheduleSpace;
use crate::schedule::Schedule;
use crate::sim::latency::int_ops;
use crate::sim::{occupancy, MemoryTraffic, Occupancy};
use crate::util::Json;
use crate::workload::Workload;

/// Enumeration cap for [`rank_static`]: bounds the static ranking to a
/// deterministic prefix of the schedule space so the serving daemon's
/// miss path stays at wire speed (the full space can be ~10^4).
pub const STATIC_RANK_CAP: usize = 512;

/// Deterministic zero-measurement profile of one (workload, schedule)
/// pair on one GPU spec. All fields are finite for legal schedules.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct StaticProfile {
    /// Floating-point operations (2 per MAC, GEMM view).
    pub flops: f64,
    /// Modeled integer/address ops (loop + addressing overhead).
    pub int_ops: f64,
    /// Modeled DRAM bytes (compulsory + L2-spill re-reads + split-k).
    pub dram_bytes: f64,
    /// Modeled L2 bytes (all global traffic passes L2).
    pub l2_bytes: f64,
    /// Modeled shared-memory bytes (staging stores + fragment loads).
    pub shared_bytes: f64,
    /// Modeled register-file bytes (operand reads + accumulator RMW).
    pub reg_bytes: f64,
    /// FLOPs per DRAM byte — the roofline x-axis.
    pub arithmetic_intensity: f64,
    /// FLOPs per global element loaded: how much arithmetic each
    /// global load feeds (bigger tiles => more reuse, the §8 lever).
    pub tile_reuse_factor: f64,
    /// Achieved occupancy (resident threads / max threads per SM).
    pub occupancy: f64,
    /// Fraction of SMs with at least one block at launch.
    pub active_sm_frac: f64,
    /// Scheduling waves of the launch grid.
    pub waves: f64,
    /// Wave-schedule efficiency (1.0 = all slots busy all waves).
    pub tail_efficiency: f64,
    /// Predicted fraction of time stalled on memory:
    /// `mem / (compute + mem)` on the roofline terms, in `[0, 1]`.
    pub predicted_stall_frac: f64,
    /// Closed-form latency estimate: roofline max of compute time and
    /// the slowest memory level, plus launch latency. No ILP model, no
    /// throttling.
    pub static_latency_s: f64,
    /// Closed-form energy estimate: per-byte transfer energy per level
    /// + per-op compute energy + transaction issue energy + launch
    /// energy + background (constant + utilization-scaled static)
    /// power over the static latency. Strictly increasing in
    /// `dram_bytes`.
    pub static_energy_j: f64,
    /// `static_energy_j / static_latency_s`.
    pub static_avg_power_w: f64,
}

impl StaticProfile {
    /// JSON encoding (sorted keys — byte-stable for golden pins).
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("flops", Json::num(self.flops)),
            ("int_ops", Json::num(self.int_ops)),
            ("dram_bytes", Json::num(self.dram_bytes)),
            ("l2_bytes", Json::num(self.l2_bytes)),
            ("shared_bytes", Json::num(self.shared_bytes)),
            ("reg_bytes", Json::num(self.reg_bytes)),
            ("arithmetic_intensity", Json::num(self.arithmetic_intensity)),
            ("tile_reuse_factor", Json::num(self.tile_reuse_factor)),
            ("occupancy", Json::num(self.occupancy)),
            ("active_sm_frac", Json::num(self.active_sm_frac)),
            ("waves", Json::num(self.waves)),
            ("tail_efficiency", Json::num(self.tail_efficiency)),
            ("predicted_stall_frac", Json::num(self.predicted_stall_frac)),
            ("static_latency_s", Json::num(self.static_latency_s)),
            ("static_energy_j", Json::num(self.static_energy_j)),
            ("static_avg_power_w", Json::num(self.static_avg_power_w)),
        ])
    }
}

/// Analyze one (workload, schedule) pair on `spec`.
pub fn analyze(workload: &Workload, sched: &Schedule, spec: &GpuSpec) -> StaticProfile {
    let g = workload.gemm_view();
    let traffic = MemoryTraffic::compute(sched, &g, spec);
    let occ = occupancy(sched, sched.grid(&g), spec);
    let flops = 2.0 * g.macs() as f64;
    let iops = int_ops(sched, &g);
    profile_from_parts(flops, iops, &traffic, &occ, spec)
}

/// Assemble the profile from its statically-derived parts. Split out so
/// the monotonicity property test can vary one traffic term in
/// isolation.
fn profile_from_parts(
    flops: f64,
    iops: f64,
    t: &MemoryTraffic,
    occ: &Occupancy,
    spec: &GpuSpec,
) -> StaticProfile {
    // --- roofline latency -------------------------------------------
    // Compute time at the achieved-parallelism-derated peak; memory
    // time is the slowest level at its full bandwidth. Overlap is
    // modeled as a hard max (perfect overlap) — deliberately simpler
    // than sim::latency's partial-overlap ILP model.
    let compute_s = flops / (spec.peak_gflops() * 1e9 * occ.sm_efficiency.max(1e-3));
    let dram_s = t.dram_bytes / (spec.dram_bw_gbs * 1e9);
    let l2_s = t.l2_bytes / (spec.l2_bw_gbs * 1e9);
    let shared_bw = spec.shared_bw_per_sm_gbs * 1e9 * occ.active_sms.max(1) as f64;
    let shared_s = t.shared_bytes / shared_bw;
    let mem_s = dram_s.max(l2_s).max(shared_s);
    let static_latency_s = compute_s.max(mem_s) + spec.launch_latency_us * 1e-6;
    let predicted_stall_frac =
        if compute_s + mem_s > 0.0 { (mem_s / (compute_s + mem_s)).clamp(0.0, 1.0) } else { 0.0 };

    // --- closed-form energy -----------------------------------------
    let transfer_j = (t.dram_bytes * spec.energy_per_dram_byte_pj
        + t.l2_bytes * spec.energy_per_l2_byte_pj
        + t.shared_bytes * spec.energy_per_shared_byte_pj
        + t.reg_bytes * spec.energy_per_reg_byte_pj)
        * 1e-12;
    let compute_j = (flops * spec.energy_per_flop_pj + iops * spec.energy_per_intop_pj) * 1e-12;
    let issue_txn = t.glb_ld_txn + t.glb_st_txn + t.shared_ld_txn + t.shared_st_txn;
    let issue_j = issue_txn * spec.energy_per_mem_issue_pj * 1e-12;
    // Background draw: board constant power plus chip static power
    // scaled between its idle floor and full value by occupancy — idle
    // SMs still leak, busy SMs leak fully. No thermal slope, no DVFS.
    let util = spec.static_floor_frac + (1.0 - spec.static_floor_frac) * occ.occupancy;
    let background_w = spec.constant_power_w + spec.static_power_full_w * util;
    let static_energy_j = transfer_j
        + compute_j
        + issue_j
        + spec.launch_energy_uj * 1e-6
        + background_w * static_latency_s;

    StaticProfile {
        flops,
        int_ops: iops,
        dram_bytes: t.dram_bytes,
        l2_bytes: t.l2_bytes,
        shared_bytes: t.shared_bytes,
        reg_bytes: t.reg_bytes,
        arithmetic_intensity: flops / t.dram_bytes.max(1.0),
        tile_reuse_factor: flops / t.glb_ld_elems.max(1.0),
        occupancy: occ.occupancy,
        active_sm_frac: occ.active_sms as f64 / spec.num_sms as f64,
        waves: occ.waves as f64,
        tail_efficiency: occ.tail_efficiency,
        predicted_stall_frac,
        static_latency_s,
        static_energy_j,
        static_avg_power_w: static_energy_j / static_latency_s.max(1e-12),
    }
}

/// Statically rank up to [`STATIC_RANK_CAP`] legal schedules for
/// `workload` by ascending `static_energy_j` and return the best
/// `top`. Deterministic: the enumeration order is a fixed grid walk
/// and the sort is stable, so ties keep enumeration order. Never
/// empty — falls back to the space's always-legal fallback schedule.
pub fn rank_static(
    workload: Workload,
    spec: &GpuSpec,
    top: usize,
) -> Vec<(Schedule, StaticProfile)> {
    let space = ScheduleSpace::new(workload, spec);
    let mut ranked: Vec<(Schedule, StaticProfile)> = space
        .enumerate(STATIC_RANK_CAP)
        .into_iter()
        .map(|s| (s, analyze(&workload, &s, spec)))
        .collect();
    if ranked.is_empty() {
        let s = space.fallback();
        ranked.push((s, analyze(&workload, &s, spec)));
    }
    ranked.sort_by(|a, b| {
        a.1.static_energy_j
            .partial_cmp(&b.1.static_energy_j)
            .unwrap_or(std::cmp::Ordering::Equal)
    });
    ranked.truncate(top.max(1));
    ranked
}

/// The single statically-best schedule for `workload` — what the serve
/// daemon's search-free tier replies with on a never-seen key.
pub fn best_static(workload: Workload, spec: &GpuSpec) -> (Schedule, StaticProfile) {
    rank_static(workload, spec, 1).swap_remove(0)
}

/// Static energy estimates for a batch of schedules — the zero-sample
/// prior handed to
/// [`crate::costmodel::EnergyCostModel::predict_energy_batch_with_prior`].
pub fn static_energy_priors(workload: &Workload, scheds: &[Schedule], spec: &GpuSpec) -> Vec<f64> {
    scheds.iter().map(|s| analyze(workload, s, spec).static_energy_j).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::GpuArch;
    use crate::util::Rng;
    use crate::workload::suites;

    #[test]
    fn profile_is_bytewise_deterministic() {
        for arch in GpuArch::ALL {
            let spec = arch.spec();
            for (_, w) in suites::all_named() {
                let s = ScheduleSpace::new(w, &spec).fallback();
                let a = analyze(&w, &s, &spec).to_json().to_string();
                let b = analyze(&w, &s, &spec).to_json().to_string();
                assert_eq!(a, b, "{arch:?}/{w}");
            }
        }
    }

    #[test]
    fn profile_finite_and_positive_for_all_suites() {
        let mut rng = Rng::seed_from_u64(23);
        for arch in [GpuArch::A100, GpuArch::Rtx4090, GpuArch::P100, GpuArch::V100] {
            let spec = arch.spec();
            for (_, w) in suites::all_named() {
                let space = ScheduleSpace::new(w, &spec);
                for s in space.sample_n(&mut rng, 8) {
                    let p = analyze(&w, &s, &spec);
                    assert!(p.static_energy_j > 0.0, "{w}: {p:?}");
                    assert!(p.static_latency_s > 0.0, "{w}: {p:?}");
                    assert!(p.static_avg_power_w > 0.0, "{w}: {p:?}");
                    assert!((0.0..=1.0).contains(&p.predicted_stall_frac), "{w}: {p:?}");
                    let v = p.to_json();
                    if let Json::Obj(m) = &v {
                        for (k, x) in m {
                            let f = x.as_f64().unwrap();
                            assert!(f.is_finite(), "{w}: field {k} not finite");
                        }
                    } else {
                        panic!("profile JSON must be an object");
                    }
                }
            }
        }
    }

    /// Property test (ISSUE 9): the static energy estimate is monotone
    /// — in fact strictly increasing — in modeled global-memory
    /// traffic, holding every other input fixed. Checked across all
    /// GPU specs, all workload families, and a spread of sampled
    /// schedules.
    #[test]
    fn static_energy_is_monotone_in_dram_traffic() {
        let mut rng = Rng::seed_from_u64(41);
        for arch in GpuArch::ALL {
            let spec = arch.spec();
            for w in [suites::MM1, suites::MV3, suites::CONV2] {
                let g = w.gemm_view();
                let space = ScheduleSpace::new(w, &spec);
                for s in space.sample_n(&mut rng, 6) {
                    let base = MemoryTraffic::compute(&s, &g, &spec);
                    let occ = occupancy(&s, s.grid(&g), &spec);
                    let flops = 2.0 * g.macs() as f64;
                    let iops = int_ops(&s, &g);
                    let mut last = f64::NEG_INFINITY;
                    for mult in [1.0, 1.5, 2.0, 4.0, 8.0, 16.0] {
                        let mut t = base;
                        t.dram_bytes = base.dram_bytes * mult;
                        let p = profile_from_parts(flops, iops, &t, &occ, &spec);
                        assert!(
                            p.static_energy_j > last,
                            "{arch:?}/{w}: energy not monotone in dram_bytes \
                             (x{mult}: {} <= {last})",
                            p.static_energy_j
                        );
                        last = p.static_energy_j;
                    }
                }
            }
        }
    }

    #[test]
    fn rank_static_is_deterministic_sorted_and_nonempty() {
        let spec = GpuArch::A100.spec();
        for (_, w) in suites::all_named() {
            let a = rank_static(w, &spec, 8);
            let b = rank_static(w, &spec, 8);
            assert_eq!(a, b, "{w}: ranking must be deterministic");
            assert!(!a.is_empty());
            for pair in a.windows(2) {
                assert!(pair[0].1.static_energy_j <= pair[1].1.static_energy_j, "{w}");
            }
            let space = ScheduleSpace::new(w, &spec);
            for (s, _) in &a {
                assert!(space.is_legal(s), "{w}: ranked schedule must be legal: {s}");
            }
        }
    }

    #[test]
    fn best_static_no_worse_than_fallback() {
        let spec = GpuArch::A100.spec();
        for (_, w) in suites::all_named() {
            let fallback = ScheduleSpace::new(w, &spec).fallback();
            let fb = analyze(&w, &fallback, &spec);
            let (_, best) = best_static(w, &spec);
            assert!(
                best.static_energy_j <= fb.static_energy_j,
                "{w}: best-of-N ({}) worse than fallback ({})",
                best.static_energy_j,
                fb.static_energy_j
            );
        }
    }

    #[test]
    fn priors_align_with_individual_analysis() {
        let spec = GpuArch::V100.spec();
        let w = suites::MM2;
        let space = ScheduleSpace::new(w, &spec);
        let scheds = space.enumerate(16);
        let priors = static_energy_priors(&w, &scheds, &spec);
        assert_eq!(priors.len(), scheds.len());
        for (s, p) in scheds.iter().zip(&priors) {
            assert_eq!(*p, analyze(&w, s, &spec).static_energy_j);
        }
    }
}
