//! Architecture spec sheets for the simulated GPUs.
//!
//! Each [`GpuSpec`] captures the *structural* parameters the paper's
//! energy analysis depends on (§2.1, §2.3, §8): SM array geometry, memory
//! hierarchy bandwidths, and the energy/power decomposition into
//! constant, static, and dynamic components. Absolute numbers are drawn
//! from public spec sheets and the AccelWattch-style energy-per-access
//! literature; they are calibration constants for the simulator, not
//! claims about real silicon.


/// Identifier for a built-in GPU architecture.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum GpuArch {
    /// NVIDIA A100 (Ampere, SXM4 80GB) — the paper's primary platform.
    A100,
    /// NVIDIA RTX 4090 (Ada Lovelace) — the paper's secondary platform.
    Rtx4090,
    /// NVIDIA P100 (Pascal) — used for the paper's Figure 2.
    P100,
    /// NVIDIA V100 (Volta) — extra platform for ablations.
    V100,
}

impl GpuArch {
    /// All built-in architectures.
    pub const ALL: [GpuArch; 4] = [GpuArch::A100, GpuArch::Rtx4090, GpuArch::P100, GpuArch::V100];

    /// Short lowercase name used by the CLI and artifact registry.
    pub fn name(self) -> &'static str {
        match self {
            GpuArch::A100 => "a100",
            GpuArch::Rtx4090 => "rtx4090",
            GpuArch::P100 => "p100",
            GpuArch::V100 => "v100",
        }
    }

    /// Parse a CLI name. Accepts the forms `a100`, `rtx4090`, `4090`, `p100`, `v100`.
    pub fn parse(s: &str) -> Option<GpuArch> {
        match s.to_ascii_lowercase().as_str() {
            "a100" => Some(GpuArch::A100),
            "rtx4090" | "4090" | "rtx_4090" => Some(GpuArch::Rtx4090),
            "p100" => Some(GpuArch::P100),
            "v100" => Some(GpuArch::V100),
            _ => None,
        }
    }

    /// The full spec sheet for this architecture.
    pub fn spec(self) -> GpuSpec {
        match self {
            GpuArch::A100 => GpuSpec::a100(),
            GpuArch::Rtx4090 => GpuSpec::rtx4090(),
            GpuArch::P100 => GpuSpec::p100(),
            GpuArch::V100 => GpuSpec::v100(),
        }
    }
}

impl std::fmt::Display for GpuArch {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

/// Structural + energy parameters of one GPU architecture.
///
/// Units: clocks in GHz, bandwidths in GB/s, energies in picojoules per
/// event, powers in watts, memories in bytes.
#[derive(Debug, Clone)]
pub struct GpuSpec {
    pub arch_name: &'static str,
    // --- SM array -----------------------------------------------------
    /// Number of streaming multiprocessors.
    pub num_sms: usize,
    /// FP32 CUDA cores (SPs) per SM.
    pub cores_per_sm: usize,
    /// Sustained SM clock under load (GHz).
    pub sm_clock_ghz: f64,
    /// Max resident threads per SM.
    pub max_threads_per_sm: usize,
    /// Max resident thread blocks per SM.
    pub max_blocks_per_sm: usize,
    /// Max threads per block (hardware limit).
    pub max_threads_per_block: usize,
    /// Register file size per SM (32-bit registers).
    pub regs_per_sm: usize,
    /// Max registers per thread.
    pub max_regs_per_thread: usize,
    // --- memory hierarchy ----------------------------------------------
    /// Shared memory (scratchpad) per SM, bytes.
    pub shared_mem_per_sm: usize,
    /// Max shared memory per block, bytes.
    pub max_shared_per_block: usize,
    /// DRAM (HBM/GDDR) bandwidth, GB/s.
    pub dram_bw_gbs: f64,
    /// L2 cache size, bytes.
    pub l2_size: usize,
    /// L2 bandwidth, GB/s (aggregate).
    pub l2_bw_gbs: f64,
    /// Aggregate shared-memory bandwidth per SM, GB/s.
    pub shared_bw_per_sm_gbs: f64,
    // --- power / energy decomposition (§2.3) ----------------------------
    /// Constant power: fans, peripheral circuits, VRM overhead (W).
    pub constant_power_w: f64,
    /// Static (leakage) power with *all* SMs gated on, chip idle at load
    /// clocks (W). Scales with the fraction of SMs kept active.
    pub static_power_full_w: f64,
    /// Fraction of static power that is unavoidable chip-wide leakage
    /// (uncore, memory controllers) even when most SMs idle.
    pub static_floor_frac: f64,
    /// Dynamic energy per FP32 FLOP (pJ). MAC counted as 2 FLOPs.
    pub energy_per_flop_pj: f64,
    /// Dynamic energy per 32-bit integer ALU op (pJ).
    pub energy_per_intop_pj: f64,
    /// Dynamic energy per byte moved from DRAM (pJ/B).
    pub energy_per_dram_byte_pj: f64,
    /// Dynamic energy per byte moved through L2 (pJ/B).
    pub energy_per_l2_byte_pj: f64,
    /// Dynamic energy per byte moved through shared memory (pJ/B).
    pub energy_per_shared_byte_pj: f64,
    /// Dynamic energy per byte moved through the register file (pJ/B).
    pub energy_per_reg_byte_pj: f64,
    /// Instruction issue/decode energy per *memory instruction* (pJ).
    /// Vectorized loads amortize this — one of the §5.4 vectorization
    /// features' physical effects on energy.
    pub energy_per_mem_issue_pj: f64,
    /// Per-kernel-launch fixed energy overhead (uJ).
    pub launch_energy_uj: f64,
    /// Kernel launch latency overhead (us).
    pub launch_latency_us: f64,
    /// Board power limit (W) — power capping ceiling.
    pub tdp_w: f64,
    // --- thermal model ---------------------------------------------------
    /// Power multiplier slope per degree C above the calibration point
    /// (leakage grows with temperature; §5.1 motivation for warm-up).
    pub thermal_power_slope_per_c: f64,
    /// Calibration (steady, warmed-up) temperature, C.
    pub steady_temp_c: f64,
    /// Idle temperature, C.
    pub idle_temp_c: f64,
}

impl GpuSpec {
    /// NVIDIA A100 SXM4 80GB (Ampere, GA100). 108 SMs x 64 FP32 cores.
    pub fn a100() -> GpuSpec {
        GpuSpec {
            arch_name: "a100",
            num_sms: 108,
            cores_per_sm: 64,
            sm_clock_ghz: 1.41,
            max_threads_per_sm: 2048,
            max_blocks_per_sm: 32,
            max_threads_per_block: 1024,
            regs_per_sm: 65536,
            max_regs_per_thread: 255,
            shared_mem_per_sm: 164 * 1024,
            max_shared_per_block: 160 * 1024,
            dram_bw_gbs: 2039.0,
            l2_size: 40 * 1024 * 1024,
            l2_bw_gbs: 5120.0,
            shared_bw_per_sm_gbs: 128.0,
            constant_power_w: 58.0,
            static_power_full_w: 92.0,
            static_floor_frac: 0.42,
            energy_per_flop_pj: 0.75,
            energy_per_intop_pj: 0.45,
            energy_per_dram_byte_pj: 22.0,
            energy_per_l2_byte_pj: 4.5,
            energy_per_shared_byte_pj: 1.1,
            energy_per_reg_byte_pj: 0.25,
            energy_per_mem_issue_pj: 28.0,
            launch_energy_uj: 18.0,
            launch_latency_us: 3.0,
            tdp_w: 400.0,
            thermal_power_slope_per_c: 0.0035,
            steady_temp_c: 62.0,
            idle_temp_c: 33.0,
        }
    }

    /// NVIDIA RTX 4090 (Ada, AD102). 128 SMs x 128 FP32 cores; GDDR6X.
    ///
    /// Ada's high clocks + narrower DRAM make memory-bound kernels (MV)
    /// especially schedule-sensitive in energy — matching the paper's
    /// observation of a 53% MV reduction on this card.
    pub fn rtx4090() -> GpuSpec {
        GpuSpec {
            arch_name: "rtx4090",
            num_sms: 128,
            cores_per_sm: 128,
            sm_clock_ghz: 2.52,
            max_threads_per_sm: 1536,
            max_blocks_per_sm: 24,
            max_threads_per_block: 1024,
            regs_per_sm: 65536,
            max_regs_per_thread: 255,
            shared_mem_per_sm: 100 * 1024,
            max_shared_per_block: 99 * 1024,
            dram_bw_gbs: 1008.0,
            l2_size: 72 * 1024 * 1024,
            l2_bw_gbs: 5200.0,
            shared_bw_per_sm_gbs: 160.0,
            constant_power_w: 45.0,
            static_power_full_w: 110.0,
            static_floor_frac: 0.35,
            energy_per_flop_pj: 0.52,
            energy_per_intop_pj: 0.33,
            energy_per_dram_byte_pj: 30.0,
            energy_per_l2_byte_pj: 3.8,
            energy_per_shared_byte_pj: 0.9,
            energy_per_reg_byte_pj: 0.2,
            energy_per_mem_issue_pj: 20.0,
            launch_energy_uj: 12.0,
            launch_latency_us: 2.5,
            tdp_w: 450.0,
            thermal_power_slope_per_c: 0.004,
            steady_temp_c: 66.0,
            idle_temp_c: 35.0,
        }
    }

    /// NVIDIA P100 (Pascal, GP100). 56 SMs x 64 FP32 cores; HBM2.
    pub fn p100() -> GpuSpec {
        GpuSpec {
            arch_name: "p100",
            num_sms: 56,
            cores_per_sm: 64,
            sm_clock_ghz: 1.30,
            max_threads_per_sm: 2048,
            max_blocks_per_sm: 32,
            max_threads_per_block: 1024,
            regs_per_sm: 65536,
            max_regs_per_thread: 255,
            shared_mem_per_sm: 64 * 1024,
            max_shared_per_block: 48 * 1024,
            dram_bw_gbs: 732.0,
            l2_size: 4 * 1024 * 1024,
            l2_bw_gbs: 1600.0,
            shared_bw_per_sm_gbs: 64.0,
            constant_power_w: 50.0,
            static_power_full_w: 75.0,
            static_floor_frac: 0.45,
            energy_per_flop_pj: 1.6,
            energy_per_intop_pj: 0.9,
            energy_per_dram_byte_pj: 31.0,
            energy_per_l2_byte_pj: 6.5,
            energy_per_shared_byte_pj: 1.6,
            energy_per_reg_byte_pj: 0.35,
            energy_per_mem_issue_pj: 40.0,
            launch_energy_uj: 22.0,
            launch_latency_us: 4.0,
            tdp_w: 300.0,
            thermal_power_slope_per_c: 0.004,
            steady_temp_c: 60.0,
            idle_temp_c: 32.0,
        }
    }

    /// NVIDIA V100 (Volta, GV100). 80 SMs x 64 FP32 cores; HBM2.
    pub fn v100() -> GpuSpec {
        GpuSpec {
            arch_name: "v100",
            num_sms: 80,
            cores_per_sm: 64,
            sm_clock_ghz: 1.38,
            max_threads_per_sm: 2048,
            max_blocks_per_sm: 32,
            max_threads_per_block: 1024,
            regs_per_sm: 65536,
            max_regs_per_thread: 255,
            shared_mem_per_sm: 96 * 1024,
            max_shared_per_block: 96 * 1024,
            dram_bw_gbs: 900.0,
            l2_size: 6 * 1024 * 1024,
            l2_bw_gbs: 2100.0,
            shared_bw_per_sm_gbs: 96.0,
            constant_power_w: 52.0,
            static_power_full_w: 82.0,
            static_floor_frac: 0.44,
            energy_per_flop_pj: 1.1,
            energy_per_intop_pj: 0.6,
            energy_per_dram_byte_pj: 26.0,
            energy_per_l2_byte_pj: 5.5,
            energy_per_shared_byte_pj: 1.3,
            energy_per_reg_byte_pj: 0.3,
            energy_per_mem_issue_pj: 34.0,
            launch_energy_uj: 20.0,
            launch_latency_us: 3.5,
            tdp_w: 300.0,
            thermal_power_slope_per_c: 0.0038,
            steady_temp_c: 61.0,
            idle_temp_c: 33.0,
        }
    }

    /// Peak FP32 throughput in GFLOP/s (2 FLOPs per core per cycle: FMA).
    pub fn peak_gflops(&self) -> f64 {
        self.num_sms as f64 * self.cores_per_sm as f64 * self.sm_clock_ghz * 2.0
    }

    /// Peak FP32 throughput of a single SM, GFLOP/s.
    pub fn peak_gflops_per_sm(&self) -> f64 {
        self.cores_per_sm as f64 * self.sm_clock_ghz * 2.0
    }

    /// Roofline arithmetic-intensity break-even point (FLOP per DRAM byte).
    pub fn roofline_knee(&self) -> f64 {
        self.peak_gflops() / self.dram_bw_gbs
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn arch_roundtrip_names() {
        for arch in GpuArch::ALL {
            assert_eq!(GpuArch::parse(arch.name()), Some(arch));
        }
        assert_eq!(GpuArch::parse("4090"), Some(GpuArch::Rtx4090));
        assert_eq!(GpuArch::parse("nope"), None);
    }

    #[test]
    fn a100_peak_matches_spec_sheet() {
        // A100 FP32 peak is ~19.5 TFLOP/s.
        let s = GpuSpec::a100();
        let peak = s.peak_gflops();
        assert!((19_000.0..20_500.0).contains(&peak), "peak={peak}");
        assert_eq!(s.num_sms, 108);
    }

    #[test]
    fn rtx4090_peak_matches_spec_sheet() {
        // 4090 FP32 peak is ~82.6 TFLOP/s.
        let peak = GpuSpec::rtx4090().peak_gflops();
        assert!((78_000.0..86_000.0).contains(&peak), "peak={peak}");
    }

    #[test]
    fn all_specs_are_sane() {
        for arch in GpuArch::ALL {
            let s = arch.spec();
            assert!(s.num_sms > 0);
            assert!(s.sm_clock_ghz > 0.5 && s.sm_clock_ghz < 4.0);
            assert!(s.constant_power_w > 0.0);
            assert!(s.static_power_full_w > 0.0);
            assert!((0.0..1.0).contains(&s.static_floor_frac));
            // DRAM access must cost more energy than L2, than shared, than regs.
            assert!(s.energy_per_dram_byte_pj > s.energy_per_l2_byte_pj);
            assert!(s.energy_per_l2_byte_pj > s.energy_per_shared_byte_pj);
            assert!(s.energy_per_shared_byte_pj > s.energy_per_reg_byte_pj);
            assert!(s.tdp_w > s.constant_power_w + s.static_power_full_w);
            assert!(s.steady_temp_c > s.idle_temp_c);
        }
    }

    #[test]
    fn roofline_knee_is_reasonable() {
        // A100: ~19500/2039 ≈ 9.6 FLOP/B.
        let knee = GpuSpec::a100().roofline_knee();
        assert!((8.0..12.0).contains(&knee), "knee={knee}");
    }
}
