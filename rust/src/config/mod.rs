//! Configuration system: typed configs for search, measurement, and
//! experiments, loadable from TOML files with CLI overrides.
//!
//! Every experiment in the paper is reproducible from a config + seed;
//! [`SearchConfig::validate`] rejects inconsistent settings up front so
//! a bad flag fails fast instead of mid-search.

pub mod gpu_specs;

pub use gpu_specs::{GpuArch, GpuSpec};


/// Which objective drives parent selection in the evolutionary search.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SearchMode {
    /// Ansor-style baseline: latency only (§7 baseline).
    LatencyOnly,
    /// The paper's method: latency-first, then energy (Algorithm 1),
    /// with the dynamic-k cost-model updating strategy.
    EnergyAware,
    /// Ablation: energy-aware but every candidate is NVML-measured
    /// (no cost model) — the "NVML-only" configuration of Figure 5.
    EnergyNvmlOnly,
}

impl SearchMode {
    pub fn name(self) -> &'static str {
        match self {
            SearchMode::LatencyOnly => "latency_only",
            SearchMode::EnergyAware => "energy_aware",
            SearchMode::EnergyNvmlOnly => "energy_nvml_only",
        }
    }

    pub fn parse(s: &str) -> Option<SearchMode> {
        match s.to_ascii_lowercase().as_str() {
            "latency" | "latency_only" | "ansor" => Some(SearchMode::LatencyOnly),
            "energy" | "energy_aware" | "ours" => Some(SearchMode::EnergyAware),
            "nvml" | "energy_nvml_only" | "nvml_only" => Some(SearchMode::EnergyNvmlOnly),
            _ => None,
        }
    }
}

/// Full configuration of one search run (Algorithm 1 hyperparameters
/// plus population/budget knobs).
#[derive(Debug, Clone)]
pub struct SearchConfig {
    /// Target GPU architecture.
    pub gpu: GpuArch,
    /// Search objective mode.
    pub mode: SearchMode,
    /// RNG seed — all runs are deterministic given the seed.
    pub seed: u64,
    /// Population size per genetic generation.
    pub population: usize,
    /// `M` in Algorithm 1: number of lowest-latency kernels kept per round.
    pub m_latency_keep: usize,
    /// Initial `k` (fraction of `M` that is NVML-measured). Paper: 1.0.
    pub k_init: f64,
    /// `µ` in Algorithm 1: SNR threshold (dB) below which more
    /// measurements are scheduled.
    pub mu_snr_db: f64,
    /// Step applied to `k` each round. Paper: 0.2.
    pub k_step: f64,
    /// Floor for `k·M` so the model never fully starves of fresh
    /// measurements (Algorithm 1 allows k = 0; a floor of 1 keeps the
    /// SNR signal alive; set 0 for the paper-literal behaviour).
    pub min_measure_per_round: usize,
    /// Number of genetic rounds (including the initial random round).
    pub rounds: usize,
    /// Convergence: stop early after this many rounds without
    /// best-objective improvement (0 disables early stop).
    pub patience: usize,
    /// Mutation probability per tiling knob during reproduction.
    pub mutation_prob: f64,
    /// Crossover probability during reproduction.
    pub crossover_prob: f64,
    /// Fraction of each generation filled with fresh random immigrants.
    pub immigrant_frac: f64,
    /// NVML measurement settings.
    pub nvml: NvmlConfig,
    /// Cost model hyperparameters.
    pub cost_model: CostModelConfig,
    /// Persistent tuning store + warm-start transfer settings.
    pub store: StoreConfig,
    /// Kernel-serving daemon settings (`ecokernel serve`).
    pub serve: ServeConfig,
    /// Fleet-serving settings (multi-daemon shared store).
    pub fleet: FleetConfig,
    /// Serving SLO targets + drift-watchdog settings (`health` op).
    pub slo: SloConfig,
}

impl Default for SearchConfig {
    fn default() -> Self {
        SearchConfig {
            gpu: GpuArch::A100,
            mode: SearchMode::EnergyAware,
            seed: 0,
            population: 128,
            m_latency_keep: 32,
            k_init: 1.0,
            mu_snr_db: 0.0,
            k_step: 0.2,
            min_measure_per_round: 1,
            rounds: 12,
            patience: 5,
            mutation_prob: 0.35,
            crossover_prob: 0.5,
            immigrant_frac: 0.1,
            nvml: NvmlConfig::default(),
            cost_model: CostModelConfig::default(),
            store: StoreConfig::default(),
            serve: ServeConfig::default(),
            fleet: FleetConfig::default(),
            slo: SloConfig::default(),
        }
    }
}

impl SearchConfig {
    /// Validate internal consistency; returns a human-readable error.
    pub fn validate(&self) -> Result<(), String> {
        if self.population == 0 {
            return Err("population must be > 0".into());
        }
        if self.m_latency_keep == 0 || self.m_latency_keep > self.population {
            return Err(format!(
                "m_latency_keep ({}) must be in 1..=population ({})",
                self.m_latency_keep, self.population
            ));
        }
        if !(0.0..=1.0).contains(&self.k_init) {
            return Err(format!("k_init ({}) must be in [0, 1]", self.k_init));
        }
        if !(0.0..=1.0).contains(&self.k_step) {
            return Err(format!("k_step ({}) must be in [0, 1]", self.k_step));
        }
        if self.rounds < 2 {
            return Err("rounds must be >= 2 (initial + at least one genetic round)".into());
        }
        for (name, p) in [
            ("mutation_prob", self.mutation_prob),
            ("crossover_prob", self.crossover_prob),
            ("immigrant_frac", self.immigrant_frac),
        ] {
            if !(0.0..=1.0).contains(&p) {
                return Err(format!("{name} ({p}) must be in [0, 1]"));
            }
        }
        self.nvml.validate()?;
        self.cost_model.validate()?;
        self.store.validate()?;
        self.serve.validate()?;
        self.fleet.validate()?;
        self.slo.validate()?;
        Ok(())
    }

    /// Load from a TOML file. Missing keys keep their defaults; unknown
    /// keys are rejected so typos fail fast.
    pub fn from_toml_file(path: &std::path::Path) -> anyhow::Result<Self> {
        let text = std::fs::read_to_string(path)?;
        let cfg = Self::from_toml_str(&text).map_err(|e| anyhow::anyhow!(e))?;
        Ok(cfg)
    }

    /// Parse from TOML text (subset parser; see [`crate::util::toml_lite`]).
    pub fn from_toml_str(text: &str) -> Result<Self, String> {
        let doc = crate::util::TomlDoc::parse(text)?;
        let known = [
            "gpu",
            "mode",
            "seed",
            "population",
            "m_latency_keep",
            "k_init",
            "mu_snr_db",
            "k_step",
            "min_measure_per_round",
            "rounds",
            "patience",
            "mutation_prob",
            "crossover_prob",
            "immigrant_frac",
            "nvml.sampling_hz",
            "nvml.min_samples",
            "nvml.max_reps",
            "nvml.warmup_s",
            "nvml.power_noise_rel",
            "nvml.latency_noise_rel",
            "cost_model.n_trees",
            "cost_model.max_depth",
            "cost_model.learning_rate",
            "cost_model.lambda",
            "cost_model.min_child_weight",
            "cost_model.n_bins",
            "cost_model.colsample",
            "cost_model.weighted_loss",
            "cost_model.max_train_samples",
            "store.dir",
            "store.transfer",
            "store.max_neighbors",
            "store.write_back",
            "serve.n_shards",
            "serve.per_gpu_quota",
            "serve.max_records",
            "serve.n_workers",
            "serve.queue_cap",
            "fleet.coordinate",
            "fleet.lease_ttl_ms",
            "fleet.backlog_cap",
            "fleet.heat_half_life",
            "fleet.heat_keys_cap",
            "fleet.notify",
            "fleet.notify_interval_ms",
            "fleet.poll_interval_ms",
            "slo.p99_reply_wall_s",
            "slo.hit_rate_floor",
            "slo.relerr_ceiling",
            "slo.backlog_ceiling",
            "slo.min_window",
            "slo.drift_interval_ms",
            "slo.drift_budget",
        ];
        for key in doc.entries.keys() {
            if !known.contains(&key.as_str()) {
                return Err(format!("unknown config key '{key}'"));
            }
        }
        let d = SearchConfig::default();
        let cfg = SearchConfig {
            gpu: {
                let name = doc.str_or("gpu", d.gpu.name());
                GpuArch::parse(name).ok_or_else(|| format!("unknown gpu '{name}'"))?
            },
            mode: {
                let name = doc.str_or("mode", d.mode.name());
                SearchMode::parse(name).ok_or_else(|| format!("unknown mode '{name}'"))?
            },
            seed: doc.u64_or("seed", d.seed),
            population: doc.usize_or("population", d.population),
            m_latency_keep: doc.usize_or("m_latency_keep", d.m_latency_keep),
            k_init: doc.f64_or("k_init", d.k_init),
            mu_snr_db: doc.f64_or("mu_snr_db", d.mu_snr_db),
            k_step: doc.f64_or("k_step", d.k_step),
            min_measure_per_round: doc.usize_or("min_measure_per_round", d.min_measure_per_round),
            rounds: doc.usize_or("rounds", d.rounds),
            patience: doc.usize_or("patience", d.patience),
            mutation_prob: doc.f64_or("mutation_prob", d.mutation_prob),
            crossover_prob: doc.f64_or("crossover_prob", d.crossover_prob),
            immigrant_frac: doc.f64_or("immigrant_frac", d.immigrant_frac),
            nvml: NvmlConfig {
                sampling_hz: doc.f64_or("nvml.sampling_hz", d.nvml.sampling_hz),
                min_samples: doc.usize_or("nvml.min_samples", d.nvml.min_samples),
                max_reps: doc.usize_or("nvml.max_reps", d.nvml.max_reps),
                warmup_s: doc.f64_or("nvml.warmup_s", d.nvml.warmup_s),
                power_noise_rel: doc.f64_or("nvml.power_noise_rel", d.nvml.power_noise_rel),
                latency_noise_rel: doc.f64_or("nvml.latency_noise_rel", d.nvml.latency_noise_rel),
            },
            cost_model: CostModelConfig {
                n_trees: doc.usize_or("cost_model.n_trees", d.cost_model.n_trees),
                max_depth: doc.usize_or("cost_model.max_depth", d.cost_model.max_depth),
                learning_rate: doc.f64_or("cost_model.learning_rate", d.cost_model.learning_rate),
                lambda: doc.f64_or("cost_model.lambda", d.cost_model.lambda),
                min_child_weight: doc
                    .f64_or("cost_model.min_child_weight", d.cost_model.min_child_weight),
                n_bins: doc.usize_or("cost_model.n_bins", d.cost_model.n_bins),
                colsample: doc.f64_or("cost_model.colsample", d.cost_model.colsample),
                weighted_loss: doc.bool_or("cost_model.weighted_loss", d.cost_model.weighted_loss),
                max_train_samples: doc
                    .usize_or("cost_model.max_train_samples", d.cost_model.max_train_samples),
            },
            store: StoreConfig {
                dir: doc
                    .get("store.dir")
                    .and_then(|v| v.as_str())
                    .map(|s| s.to_string())
                    .or(d.store.dir),
                transfer: doc.bool_or("store.transfer", d.store.transfer),
                max_neighbors: doc.usize_or("store.max_neighbors", d.store.max_neighbors),
                write_back: doc.bool_or("store.write_back", d.store.write_back),
            },
            serve: ServeConfig {
                n_shards: doc.usize_or("serve.n_shards", d.serve.n_shards),
                per_gpu_quota: doc.usize_or("serve.per_gpu_quota", d.serve.per_gpu_quota),
                max_records: doc.usize_or("serve.max_records", d.serve.max_records),
                n_workers: doc.usize_or("serve.n_workers", d.serve.n_workers),
                queue_cap: doc.usize_or("serve.queue_cap", d.serve.queue_cap),
            },
            fleet: FleetConfig {
                coordinate: doc.bool_or("fleet.coordinate", d.fleet.coordinate),
                lease_ttl_ms: doc.u64_or("fleet.lease_ttl_ms", d.fleet.lease_ttl_ms),
                backlog_cap: doc.usize_or("fleet.backlog_cap", d.fleet.backlog_cap),
                heat_half_life: doc.f64_or("fleet.heat_half_life", d.fleet.heat_half_life),
                heat_keys_cap: doc.usize_or("fleet.heat_keys_cap", d.fleet.heat_keys_cap),
                notify: doc.bool_or("fleet.notify", d.fleet.notify),
                notify_interval_ms: doc
                    .u64_or("fleet.notify_interval_ms", d.fleet.notify_interval_ms),
                poll_interval_ms: doc.u64_or("fleet.poll_interval_ms", d.fleet.poll_interval_ms),
            },
            slo: SloConfig {
                p99_reply_wall_s: doc.f64_or("slo.p99_reply_wall_s", d.slo.p99_reply_wall_s),
                hit_rate_floor: doc.f64_or("slo.hit_rate_floor", d.slo.hit_rate_floor),
                relerr_ceiling: doc.f64_or("slo.relerr_ceiling", d.slo.relerr_ceiling),
                backlog_ceiling: doc.usize_or("slo.backlog_ceiling", d.slo.backlog_ceiling),
                min_window: doc.u64_or("slo.min_window", d.slo.min_window),
                drift_interval_ms: doc.u64_or("slo.drift_interval_ms", d.slo.drift_interval_ms),
                drift_budget: doc.usize_or("slo.drift_budget", d.slo.drift_budget),
            },
        };
        cfg.validate()?;
        Ok(cfg)
    }

    /// Serialize to TOML (round-trips through [`Self::from_toml_str`]).
    pub fn to_toml(&self) -> String {
        let mut out = format!(
            "gpu = \"{}\"\nmode = \"{}\"\nseed = {}\npopulation = {}\n\
             m_latency_keep = {}\nk_init = {}\nmu_snr_db = {}\nk_step = {}\n\
             min_measure_per_round = {}\nrounds = {}\npatience = {}\n\
             mutation_prob = {}\ncrossover_prob = {}\nimmigrant_frac = {}\n\n\
             [nvml]\nsampling_hz = {}\nmin_samples = {}\nmax_reps = {}\n\
             warmup_s = {}\npower_noise_rel = {}\nlatency_noise_rel = {}\n\n\
             [cost_model]\nn_trees = {}\nmax_depth = {}\nlearning_rate = {}\n\
             lambda = {}\nmin_child_weight = {}\nn_bins = {}\ncolsample = {}\n\
             weighted_loss = {}\nmax_train_samples = {}\n",
            self.gpu.name(),
            self.mode.name(),
            self.seed,
            self.population,
            self.m_latency_keep,
            fmt_f(self.k_init),
            fmt_f(self.mu_snr_db),
            fmt_f(self.k_step),
            self.min_measure_per_round,
            self.rounds,
            self.patience,
            fmt_f(self.mutation_prob),
            fmt_f(self.crossover_prob),
            fmt_f(self.immigrant_frac),
            fmt_f(self.nvml.sampling_hz),
            self.nvml.min_samples,
            self.nvml.max_reps,
            fmt_f(self.nvml.warmup_s),
            fmt_f(self.nvml.power_noise_rel),
            fmt_f(self.nvml.latency_noise_rel),
            self.cost_model.n_trees,
            self.cost_model.max_depth,
            fmt_f(self.cost_model.learning_rate),
            fmt_f(self.cost_model.lambda),
            fmt_f(self.cost_model.min_child_weight),
            self.cost_model.n_bins,
            fmt_f(self.cost_model.colsample),
            self.cost_model.weighted_loss,
            self.cost_model.max_train_samples,
        );
        out.push_str("\n[store]\n");
        if let Some(dir) = &self.store.dir {
            let escaped = dir.replace('\\', "\\\\").replace('"', "\\\"");
            out.push_str(&format!("dir = \"{escaped}\"\n"));
        }
        out.push_str(&format!(
            "transfer = {}\nmax_neighbors = {}\nwrite_back = {}\n",
            self.store.transfer, self.store.max_neighbors, self.store.write_back
        ));
        out.push_str(&format!(
            "\n[serve]\nn_shards = {}\nper_gpu_quota = {}\nmax_records = {}\n\
             n_workers = {}\nqueue_cap = {}\n",
            self.serve.n_shards,
            self.serve.per_gpu_quota,
            self.serve.max_records,
            self.serve.n_workers,
            self.serve.queue_cap
        ));
        out.push_str(&format!(
            "\n[fleet]\ncoordinate = {}\nlease_ttl_ms = {}\nbacklog_cap = {}\n\
             heat_half_life = {}\nheat_keys_cap = {}\nnotify = {}\n\
             notify_interval_ms = {}\npoll_interval_ms = {}\n",
            self.fleet.coordinate,
            self.fleet.lease_ttl_ms,
            self.fleet.backlog_cap,
            fmt_f(self.fleet.heat_half_life),
            self.fleet.heat_keys_cap,
            self.fleet.notify,
            self.fleet.notify_interval_ms,
            self.fleet.poll_interval_ms
        ));
        out.push_str(&format!(
            "\n[slo]\np99_reply_wall_s = {}\nhit_rate_floor = {}\n\
             relerr_ceiling = {}\nbacklog_ceiling = {}\nmin_window = {}\n\
             drift_interval_ms = {}\ndrift_budget = {}\n",
            fmt_f(self.slo.p99_reply_wall_s),
            fmt_f(self.slo.hit_rate_floor),
            fmt_f(self.slo.relerr_ceiling),
            self.slo.backlog_ceiling,
            self.slo.min_window,
            self.slo.drift_interval_ms,
            self.slo.drift_budget
        ));
        out
    }
}

/// Format a float so the TOML-lite parser reads it back as a float.
fn fmt_f(x: f64) -> String {
    if x == x.trunc() && x.abs() < 1e15 {
        format!("{x:.1}")
    } else {
        format!("{x}")
    }
}

/// Simulated-NVML measurement settings (§4.4, §5.1).
#[derive(Debug, Clone)]
pub struct NvmlConfig {
    /// Power sampling rate, Hz. NVML supports 30–50 Hz (§5.1).
    pub sampling_hz: f64,
    /// Minimum number of power samples needed for one measurement; the
    /// kernel is re-executed until this many samples are collected.
    pub min_samples: usize,
    /// Upper bound on kernel repetitions per measurement.
    pub max_reps: usize,
    /// Warm-up (pre-heating) time in seconds before a measurement batch
    /// when the GPU is cold (§4.4).
    pub warmup_s: f64,
    /// Relative std-dev of per-sample power noise.
    pub power_noise_rel: f64,
    /// Relative std-dev of latency timing noise.
    pub latency_noise_rel: f64,
}

impl Default for NvmlConfig {
    fn default() -> Self {
        NvmlConfig {
            sampling_hz: 45.0,
            min_samples: 50,
            max_reps: 20_000,
            warmup_s: 3.0,
            power_noise_rel: 0.015,
            latency_noise_rel: 0.01,
        }
    }
}

impl NvmlConfig {
    pub fn validate(&self) -> Result<(), String> {
        if !(1.0..=1000.0).contains(&self.sampling_hz) {
            return Err(format!("sampling_hz ({}) out of range", self.sampling_hz));
        }
        if self.min_samples == 0 {
            return Err("min_samples must be > 0".into());
        }
        if self.warmup_s < 0.0 {
            return Err("warmup_s must be >= 0".into());
        }
        Ok(())
    }
}

/// Hyperparameters for the GBDT energy cost model (§5.4).
#[derive(Debug, Clone)]
pub struct CostModelConfig {
    /// Number of boosting rounds (trees).
    pub n_trees: usize,
    /// Maximum tree depth.
    pub max_depth: usize,
    /// Learning rate (shrinkage).
    pub learning_rate: f64,
    /// L2 regularization on leaf weights (xgboost lambda).
    pub lambda: f64,
    /// Minimum hessian sum per leaf (xgboost min_child_weight).
    pub min_child_weight: f64,
    /// Number of histogram bins per feature.
    pub n_bins: usize,
    /// Feature subsampling rate per tree.
    pub colsample: f64,
    /// Use the paper's Eq. 1 weighted loss (weight = 1 / E_m).
    pub weighted_loss: bool,
    /// Cap on retained training samples (sliding window over rounds;
    /// 0 = unlimited).
    pub max_train_samples: usize,
}

impl Default for CostModelConfig {
    fn default() -> Self {
        CostModelConfig {
            n_trees: 80,
            max_depth: 6,
            learning_rate: 0.15,
            lambda: 1.0,
            min_child_weight: 1e-4,
            n_bins: 32,
            colsample: 0.9,
            weighted_loss: true,
            max_train_samples: 0,
        }
    }
}

impl CostModelConfig {
    pub fn validate(&self) -> Result<(), String> {
        if self.n_trees == 0 {
            return Err("n_trees must be > 0".into());
        }
        if self.max_depth == 0 || self.max_depth > 16 {
            return Err("max_depth must be in 1..=16".into());
        }
        if !(0.0..=1.0).contains(&self.learning_rate) || self.learning_rate == 0.0 {
            return Err("learning_rate must be in (0, 1]".into());
        }
        if self.n_bins < 2 {
            return Err("n_bins must be >= 2".into());
        }
        if !(0.0..=1.0).contains(&self.colsample) || self.colsample == 0.0 {
            return Err("colsample must be in (0, 1]".into());
        }
        Ok(())
    }
}

/// Persistent tuning-store + warm-start transfer settings (see
/// [`crate::store`]). With `dir = None` the search is fully stateless
/// (the seed behaviour); with a directory set, finished searches are
/// recorded and repeat/neighboring workloads are served from the cache.
#[derive(Debug, Clone, PartialEq)]
pub struct StoreConfig {
    /// Store directory (holds `tuning_store.jsonl`). `None` disables
    /// the store entirely.
    pub dir: Option<String>,
    /// Warm-start new searches from cached neighbor workloads.
    pub transfer: bool,
    /// Maximum number of neighbor records consulted per transfer.
    pub max_neighbors: usize,
    /// Record finished searches back into the store.
    pub write_back: bool,
}

impl Default for StoreConfig {
    fn default() -> Self {
        StoreConfig { dir: None, transfer: true, max_neighbors: 3, write_back: true }
    }
}

impl StoreConfig {
    pub fn validate(&self) -> Result<(), String> {
        if self.transfer && self.max_neighbors == 0 {
            return Err("store.max_neighbors must be >= 1 when store.transfer is on".into());
        }
        Ok(())
    }
}

/// Kernel-serving daemon settings (`[serve]`, see [`crate::serve`]).
/// None of these knobs shape a search trajectory, so they stay out of
/// the store's config fingerprint: records written under one serve
/// topology remain exact hits under another.
#[derive(Debug, Clone, PartialEq)]
pub struct ServeConfig {
    /// Number of store shards (`shards/shard_XXX.jsonl` files).
    pub n_shards: usize,
    /// Maximum records kept per GPU arch; 0 = unlimited. Overflow
    /// evicts least-recently-served keys on that GPU.
    pub per_gpu_quota: usize,
    /// Global record cap across all GPUs; 0 = unlimited.
    pub max_records: usize,
    /// Background search workers owned by the daemon.
    pub n_workers: usize,
    /// Bounded search-queue capacity; a full queue load-sheds new
    /// background searches (misses still answer immediately).
    pub queue_cap: usize,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig {
            n_shards: 8,
            per_gpu_quota: 0,
            max_records: 0,
            n_workers: 2,
            queue_cap: 16,
        }
    }
}

impl ServeConfig {
    pub fn validate(&self) -> Result<(), String> {
        if self.n_shards == 0 {
            return Err("serve.n_shards must be >= 1".into());
        }
        if self.n_workers == 0 {
            return Err("serve.n_workers must be >= 1".into());
        }
        if self.queue_cap == 0 {
            return Err("serve.queue_cap must be >= 1".into());
        }
        Ok(())
    }
}

/// Fleet-serving settings (`[fleet]`, see [`crate::fleet`]): how N
/// daemons sharing one store coordinate. Like `[serve]`, none of these
/// knobs shape a search trajectory, so they stay out of the store's
/// config fingerprint.
#[derive(Debug, Clone, PartialEq)]
pub struct FleetConfig {
    /// Coordinate with other daemons mounting this store: fleet-mode
    /// storage (per-shard leases, incremental refresh) and in-store
    /// in-flight claims. Turn off for a known-single-daemon deployment
    /// to keep the purely in-memory + O_APPEND request path (no lease
    /// files, no claim I/O on misses).
    pub coordinate: bool,
    /// TTL (ms) of shard leases and in-flight search claims. The
    /// daemon heartbeats its claims at ~TTL/3; a crashed daemon's
    /// leases expire after one TTL and are reclaimed by the fleet.
    pub lease_ttl_ms: u64,
    /// Admission backlog in front of the search queue: how many keys
    /// wait, heat-ordered, when the queue is saturated. Overflow sheds
    /// the coldest key.
    pub backlog_cap: usize,
    /// Half-life of the per-key request-rate sketch, in requests: a
    /// key untouched for this many requests loses half its heat.
    pub heat_half_life: f64,
    /// Max keys tracked by the heat sketch (prunes to the hottest
    /// half when exceeded).
    pub heat_keys_cap: usize,
    /// Announce landed write-backs on the store's notify channel and
    /// act on peers' announcements ([`crate::fleet::notify`]): the
    /// refresh loop refreshes only the touched shard per announcement
    /// instead of relying on the interval poll. Off = interval polling
    /// alone (pre-notify behavior).
    pub notify: bool,
    /// Cadence (ms) at which the refresh loop checks the notify
    /// channel for new announcements (one file-metadata stat when the
    /// channel is idle).
    pub notify_interval_ms: u64,
    /// Interval (ms) of the full-store poll fallback: the safety net
    /// that keeps a daemon fresh when announcements are lost (crashed
    /// announcer, compaction race) or notify is off.
    pub poll_interval_ms: u64,
}

impl Default for FleetConfig {
    fn default() -> Self {
        FleetConfig {
            coordinate: true,
            lease_ttl_ms: 10_000,
            backlog_cap: 32,
            heat_half_life: 256.0,
            heat_keys_cap: 4096,
            notify: true,
            notify_interval_ms: 50,
            poll_interval_ms: 5_000,
        }
    }
}

impl FleetConfig {
    pub fn validate(&self) -> Result<(), String> {
        if self.lease_ttl_ms < 50 {
            return Err("fleet.lease_ttl_ms must be >= 50".into());
        }
        if self.backlog_cap == 0 {
            return Err("fleet.backlog_cap must be >= 1".into());
        }
        if self.heat_half_life <= 0.0 {
            return Err("fleet.heat_half_life must be > 0".into());
        }
        if self.heat_keys_cap < 16 {
            return Err("fleet.heat_keys_cap must be >= 16".into());
        }
        if self.notify_interval_ms < 10 {
            return Err("fleet.notify_interval_ms must be >= 10".into());
        }
        if self.poll_interval_ms < 100 {
            return Err("fleet.poll_interval_ms must be >= 100".into());
        }
        if self.poll_interval_ms < self.notify_interval_ms {
            return Err("fleet.poll_interval_ms must be >= fleet.notify_interval_ms".into());
        }
        Ok(())
    }
}

/// Serving SLO targets + cost-model drift-watchdog settings (`[slo]`,
/// evaluated by the daemon's `health` wire op; see [`crate::serve`]).
/// A threshold of `0`/`0.0` disables its target (it always reports
/// `ok`). Like `[serve]` and `[fleet]`, none of these knobs shape a
/// search trajectory, so they stay out of the store's config
/// fingerprint.
#[derive(Debug, Clone, PartialEq)]
pub struct SloConfig {
    /// Ceiling on the p99 wall-clock reply time, seconds (0 disables).
    pub p99_reply_wall_s: f64,
    /// Floor on the hit rate, 0..=1 (0 disables).
    pub hit_rate_floor: f64,
    /// Ceiling on the steady-regime mean energy relative error of the
    /// cost model (0 disables). Doubles as the drift watchdog's
    /// re-search trigger.
    pub relerr_ceiling: f64,
    /// Ceiling on the admission-backlog depth (0 disables). Warns at
    /// half the ceiling.
    pub backlog_ceiling: usize,
    /// Minimum samples a window needs before its target can breach —
    /// keeps cold daemons from paging on noise.
    pub min_window: u64,
    /// Cadence (ms) of the drift watchdog, which also snapshots the
    /// fast (burn-rate) window the `health` op evaluates.
    pub drift_interval_ms: u64,
    /// Max drift re-searches admitted per watchdog interval, so a
    /// drifting model cannot starve real misses (0 disables the
    /// watchdog's re-search side; drift is still reported).
    pub drift_budget: usize,
}

impl Default for SloConfig {
    fn default() -> Self {
        SloConfig {
            p99_reply_wall_s: 0.25,
            hit_rate_floor: 0.0,
            relerr_ceiling: 0.35,
            backlog_ceiling: 16,
            min_window: 16,
            drift_interval_ms: 1_000,
            drift_budget: 2,
        }
    }
}

impl SloConfig {
    pub fn validate(&self) -> Result<(), String> {
        if !(self.p99_reply_wall_s >= 0.0) {
            return Err("slo.p99_reply_wall_s must be >= 0".into());
        }
        if !(0.0..=1.0).contains(&self.hit_rate_floor) {
            return Err("slo.hit_rate_floor must be in [0, 1]".into());
        }
        if !(self.relerr_ceiling >= 0.0) {
            return Err("slo.relerr_ceiling must be >= 0".into());
        }
        if self.min_window == 0 {
            return Err("slo.min_window must be >= 1".into());
        }
        if self.drift_interval_ms < 50 {
            return Err("slo.drift_interval_ms must be >= 50".into());
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_config_validates() {
        SearchConfig::default().validate().unwrap();
    }

    #[test]
    fn bad_configs_rejected() {
        let mut c = SearchConfig::default();
        c.population = 0;
        assert!(c.validate().is_err());

        let mut c = SearchConfig::default();
        c.m_latency_keep = c.population + 1;
        assert!(c.validate().is_err());

        let mut c = SearchConfig::default();
        c.k_init = 1.5;
        assert!(c.validate().is_err());

        let mut c = SearchConfig::default();
        c.rounds = 1;
        assert!(c.validate().is_err());

        let mut c = SearchConfig::default();
        c.cost_model.n_trees = 0;
        assert!(c.validate().is_err());

        let mut c = SearchConfig::default();
        c.nvml.min_samples = 0;
        assert!(c.validate().is_err());
    }

    #[test]
    fn toml_roundtrip() {
        let c = SearchConfig::default();
        let text = c.to_toml();
        let back = SearchConfig::from_toml_str(&text).unwrap();
        assert_eq!(back.population, c.population);
        assert_eq!(back.gpu, c.gpu);
        assert_eq!(back.mode, c.mode);
        assert!((back.mu_snr_db - c.mu_snr_db).abs() < 1e-12);
        assert_eq!(back.cost_model.n_trees, c.cost_model.n_trees);
        assert_eq!(back.nvml.min_samples, c.nvml.min_samples);
    }

    #[test]
    fn unknown_keys_rejected() {
        assert!(SearchConfig::from_toml_str("typo_key = 3").is_err());
        assert!(SearchConfig::from_toml_str("gpu = \"not_a_gpu\"").is_err());
    }

    #[test]
    fn partial_toml_keeps_defaults() {
        let c = SearchConfig::from_toml_str("population = 64\n[nvml]\nwarmup_s = 1.0\n").unwrap();
        assert_eq!(c.population, 64);
        assert!((c.nvml.warmup_s - 1.0).abs() < 1e-12);
        assert_eq!(c.rounds, SearchConfig::default().rounds);
    }

    #[test]
    fn store_config_roundtrips_and_validates() {
        let mut c = SearchConfig::default();
        c.store.dir = Some("/tmp/ecokernel-store".into());
        c.store.transfer = false;
        c.store.max_neighbors = 5;
        let back = SearchConfig::from_toml_str(&c.to_toml()).unwrap();
        assert_eq!(back.store, c.store);

        let parsed = SearchConfig::from_toml_str(
            "[store]\ndir = \"/tmp/s\"\ntransfer = true\nmax_neighbors = 2\n",
        )
        .unwrap();
        assert_eq!(parsed.store.dir.as_deref(), Some("/tmp/s"));
        assert_eq!(parsed.store.max_neighbors, 2);
        assert!(parsed.store.write_back, "default preserved");

        let mut bad = SearchConfig::default();
        bad.store.transfer = true;
        bad.store.max_neighbors = 0;
        assert!(bad.validate().is_err());
    }

    #[test]
    fn serve_config_roundtrips_and_validates() {
        let mut c = SearchConfig::default();
        c.serve.n_shards = 16;
        c.serve.per_gpu_quota = 1000;
        c.serve.max_records = 5000;
        c.serve.n_workers = 4;
        let back = SearchConfig::from_toml_str(&c.to_toml()).unwrap();
        assert_eq!(back.serve, c.serve);

        let parsed = SearchConfig::from_toml_str(
            "[serve]\nn_shards = 4\nper_gpu_quota = 100\nqueue_cap = 8\n",
        )
        .unwrap();
        assert_eq!(parsed.serve.n_shards, 4);
        assert_eq!(parsed.serve.per_gpu_quota, 100);
        assert_eq!(parsed.serve.queue_cap, 8);
        assert_eq!(parsed.serve.n_workers, ServeConfig::default().n_workers, "default kept");

        for bad_toml in
            ["[serve]\nn_shards = 0\n", "[serve]\nn_workers = 0\n", "[serve]\nqueue_cap = 0\n"]
        {
            assert!(SearchConfig::from_toml_str(bad_toml).is_err(), "{bad_toml}");
        }
        assert!(SearchConfig::from_toml_str("[serve]\ntypo = 1\n").is_err());
    }

    #[test]
    fn fleet_config_roundtrips_and_validates() {
        let mut c = SearchConfig::default();
        c.fleet.lease_ttl_ms = 2_500;
        c.fleet.backlog_cap = 8;
        c.fleet.heat_half_life = 64.0;
        c.fleet.heat_keys_cap = 512;
        c.fleet.notify = false;
        c.fleet.notify_interval_ms = 75;
        c.fleet.poll_interval_ms = 1_234;
        let back = SearchConfig::from_toml_str(&c.to_toml()).unwrap();
        assert_eq!(back.fleet, c.fleet);

        let parsed = SearchConfig::from_toml_str(
            "[fleet]\ncoordinate = false\nlease_ttl_ms = 500\nbacklog_cap = 4\n",
        )
        .unwrap();
        assert!(!parsed.fleet.coordinate);
        assert_eq!(parsed.fleet.lease_ttl_ms, 500);
        assert_eq!(parsed.fleet.backlog_cap, 4);
        assert!(
            (parsed.fleet.heat_half_life - FleetConfig::default().heat_half_life).abs() < 1e-12,
            "default kept"
        );
        assert!(parsed.fleet.notify, "notify defaults on");
        assert_eq!(parsed.fleet.poll_interval_ms, FleetConfig::default().poll_interval_ms);

        for bad_toml in [
            "[fleet]\nlease_ttl_ms = 10\n",
            "[fleet]\nbacklog_cap = 0\n",
            "[fleet]\nheat_half_life = 0.0\n",
            "[fleet]\nheat_keys_cap = 2\n",
            "[fleet]\nnotify_interval_ms = 5\n",
            "[fleet]\npoll_interval_ms = 50\n",
            "[fleet]\nnotify_interval_ms = 400\npoll_interval_ms = 300\n",
        ] {
            assert!(SearchConfig::from_toml_str(bad_toml).is_err(), "{bad_toml}");
        }
        assert!(SearchConfig::from_toml_str("[fleet]\ntypo = 1\n").is_err());
    }

    #[test]
    fn slo_config_roundtrips_and_validates() {
        let mut c = SearchConfig::default();
        c.slo.p99_reply_wall_s = 0.5;
        c.slo.hit_rate_floor = 0.9;
        c.slo.relerr_ceiling = 0.2;
        c.slo.backlog_ceiling = 8;
        c.slo.min_window = 32;
        c.slo.drift_interval_ms = 250;
        c.slo.drift_budget = 4;
        let back = SearchConfig::from_toml_str(&c.to_toml()).unwrap();
        assert_eq!(back.slo, c.slo);

        let parsed = SearchConfig::from_toml_str(
            "[slo]\nhit_rate_floor = 0.75\nbacklog_ceiling = 0\n",
        )
        .unwrap();
        assert!((parsed.slo.hit_rate_floor - 0.75).abs() < 1e-12);
        assert_eq!(parsed.slo.backlog_ceiling, 0, "0 = disabled is valid");
        assert!(
            (parsed.slo.p99_reply_wall_s - SloConfig::default().p99_reply_wall_s).abs() < 1e-12,
            "default kept"
        );
        assert_eq!(parsed.slo.drift_budget, SloConfig::default().drift_budget);

        for bad_toml in [
            "[slo]\np99_reply_wall_s = -1.0\n",
            "[slo]\nhit_rate_floor = 1.5\n",
            "[slo]\nrelerr_ceiling = -0.1\n",
            "[slo]\nmin_window = 0\n",
            "[slo]\ndrift_interval_ms = 10\n",
        ] {
            assert!(SearchConfig::from_toml_str(bad_toml).is_err(), "{bad_toml}");
        }
        assert!(SearchConfig::from_toml_str("[slo]\ntypo = 1\n").is_err());
    }

    #[test]
    fn mode_parse() {
        assert_eq!(SearchMode::parse("ansor"), Some(SearchMode::LatencyOnly));
        assert_eq!(SearchMode::parse("ours"), Some(SearchMode::EnergyAware));
        assert_eq!(SearchMode::parse("nvml"), Some(SearchMode::EnergyNvmlOnly));
        assert_eq!(SearchMode::parse("x"), None);
    }
}
