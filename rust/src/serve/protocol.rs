//! The wire protocol of the kernel-serving daemon: line-delimited JSON
//! frames over a Unix-domain socket.
//!
//! Every frame — request or response — is one JSON object on one line,
//! carrying a protocol version `"v"`. Requests carry `"op"` and a
//! client-chosen `"id"` echoed back in the response; responses carry
//! `"ok"` (`true` for results, `false` for [`error_code`] frames).
//!
//! Request ops:
//!
//! * `get_kernel` — workload (suite name like `"MM1"` or a workload
//!   object), optional `gpu` and `mode` overrides;
//! * `batch` — N `get_kernel` requests in ONE frame (`"requests"`
//!   array), answered by one `batch` reply whose `"replies"` array is
//!   positionally matched — request *i* gets reply *i*. A malformed
//!   entry yields an error frame at its position; siblings are still
//!   served. This is the pipelined path: a client packs its queue
//!   into one write syscall instead of one frame per write;
//! * `stats` — serving metrics + store counters;
//! * `metrics` — the full telemetry view: every counter plus the
//!   reply-time and per-stage wall-clock histograms, as mergeable
//!   log2-bucket encodings (its payload carries its own
//!   [`METRICS_VERSION`] so the histogram encoding can evolve without
//!   a protocol bump). Clients merge N daemons' frames into one fleet
//!   view; `query --metrics --prom` renders Prometheus text;
//! * `trace` — completed request traces from the daemon's
//!   tail-sampled ring, slowest first (optional `"slowest"` cap); the
//!   payload carries its own [`TRACE_VERSION`]. A `get_kernel` frame
//!   may carry an optional `"trace"` id (hex) the miss path threads
//!   through its spans; absent, the daemon mints one; a `trace` value
//!   that is not 1–16 hex chars is refused with `bad_request` naming
//!   the field rather than silently dropped;
//! * `health` — per-target SLO verdicts (`ok|warn|critical`) against
//!   the `[slo]` config section, evaluated in-daemon over fast
//!   (burn-rate) and slow (lifetime) windows, plus the drift
//!   watchdog's state; the payload carries its own
//!   [`HEALTH_VERSION`]. Fleet clients fold N daemons' frames with
//!   [`HealthReply::merge_worst`] — the fleet is as healthy as its
//!   least healthy member;
//! * `hello` — wire negotiation: the client proposes `"wire":
//!   "binary"` and, when the daemon acks it, both directions switch
//!   to the length-prefixed tagged binary framing ([`wire`], wire
//!   v2) with out-of-order replies. A connection that never sends
//!   `hello` speaks line-JSON forever, byte-identical to the
//!   pre-negotiation daemon;
//! * `shutdown` — graceful daemon stop (acked before the socket
//!   closes).
//!
//! Single `get_kernel` frames are untouched by batching — a v-current
//! daemon answers them byte-identically to the pre-batch wire format
//! (pinned by test), so old clients keep working unchanged.
//!
//! See README.md ("Serving daemon") for the full frame reference.

use crate::config::{GpuArch, SearchMode};
use crate::schedule::Schedule;
use crate::store::record::{
    schedule_from_json, schedule_to_json, workload_from_json, workload_to_json,
};
use crate::telemetry::{
    bucket_lower, EnergyLedger, LogHistogram, TraceId, LEDGER_FAMILIES, LEDGER_GPUS, N_BUCKETS,
};
use crate::util::Json;
use crate::workload::{suites, Workload};
use std::collections::BTreeMap;

/// Version of the wire protocol; a frame with any other `"v"` is
/// rejected with [`error_code::VERSION_MISMATCH`].
pub const PROTOCOL_VERSION: u64 = 1;

/// Version of the `metrics` reply PAYLOAD (the histogram encoding),
/// carried as `"metrics_v"` inside the frame — orthogonal to
/// [`PROTOCOL_VERSION`] so richer telemetry never forces a protocol
/// bump. A client rejects payloads newer than it understands.
pub const METRICS_VERSION: u64 = 1;

/// Version of the `trace` reply PAYLOAD (the span encoding), carried
/// as `"trace_v"` inside the frame — same contract as
/// [`METRICS_VERSION`]: absent reads as v1, newer than the client is
/// refused.
pub const TRACE_VERSION: u64 = 1;

/// Version of the `health` reply PAYLOAD (the SLO-verdict encoding),
/// carried as `"health_v"` inside the frame — same contract as
/// [`METRICS_VERSION`]: absent reads as v1, newer than the client is
/// refused.
pub const HEALTH_VERSION: u64 = 1;

/// Hard cap on `batch` frame size: a runaway client must not make the
/// daemon buffer an unbounded reply frame.
pub const MAX_BATCH_ITEMS: usize = 1024;

/// Wire-format revision negotiated by the `hello` op. Wire v1 is the
/// line-JSON framing every connection starts in (and stays in forever
/// unless it negotiates up — the compat guarantee); wire v2 is the
/// length-prefixed binary framing with client-assigned reply tags
/// (see [`wire`]).
pub const WIRE_VERSION: u64 = 2;

/// The `wire` field values a `hello` frame can carry / an ack echoes.
pub mod wire_name {
    /// Line-delimited JSON (wire v1, the default and compat wire).
    pub const LINE: &str = "line";
    /// Length-prefixed binary frames with reply tags (wire v2).
    pub const BINARY: &str = "binary";
}

/// Stable error codes carried by error frames.
pub mod error_code {
    /// Unparseable frame, unknown op, or malformed fields.
    pub const BAD_REQUEST: &str = "bad_request";
    /// The frame's `"v"` is not this daemon's [`super::PROTOCOL_VERSION`].
    pub const VERSION_MISMATCH: &str = "version_mismatch";
    /// The `workload` field names no known suite member and parses as
    /// no workload object.
    pub const UNKNOWN_WORKLOAD: &str = "unknown_workload";
    /// Daemon-side failure while handling an otherwise valid request.
    pub const INTERNAL: &str = "internal";
}

/// A request frame, parsed.
#[derive(Debug, Clone, PartialEq)]
pub enum Request {
    GetKernel {
        id: String,
        workload: Workload,
        gpu: Option<GpuArch>,
        mode: Option<SearchMode>,
        /// Client-chosen trace id (hex), threaded through the miss
        /// path's spans end-to-end; absent → the daemon mints one.
        /// Only encoded when present, so single-hit frames stay
        /// byte-identical to the pre-trace wire format.
        trace: Option<String>,
    },
    /// N `get_kernel` requests in one frame. Entries parse
    /// independently: a malformed one carries its [`Reject`] (answered
    /// as an error frame at that position) without failing siblings.
    Batch {
        id: String,
        items: Vec<Result<BatchItem, Reject>>,
    },
    Stats { id: String },
    Metrics { id: String },
    /// Wire negotiation: the client proposes a framing (`"binary"` /
    /// `"line"`); the daemon acks with the framing it will actually
    /// speak from the next frame on. Always sent line-JSON (it is the
    /// first frame on a fresh connection), so an old daemon answers
    /// `bad_request` ("unknown op 'hello'") and the client cleanly
    /// stays on line-JSON.
    Hello { id: String, wire: String },
    /// Completed traces from the daemon's [`TraceLog`] ring, slowest
    /// first, at most `slowest` of them (0 = every retained trace).
    ///
    /// [`TraceLog`]: crate::telemetry::TraceLog
    Traces { id: String, slowest: usize },
    /// SLO verdicts + drift-watchdog state against the `[slo]` section.
    Health { id: String },
    Shutdown { id: String },
}

/// One `get_kernel` entry inside a `batch` frame: the same fields as a
/// single request, with an optional per-entry `id` (defaulted to
/// `<batch id>.<index>` — replies are matched by position, the ids are
/// for the client's bookkeeping).
#[derive(Debug, Clone, PartialEq)]
pub struct BatchItem {
    pub id: String,
    pub workload: Workload,
    pub gpu: Option<GpuArch>,
    pub mode: Option<SearchMode>,
}

/// A request the daemon refuses, with the code + message for the error
/// frame (and the request id when one could be read).
#[derive(Debug, Clone, PartialEq)]
pub struct Reject {
    pub id: Option<String>,
    pub code: &'static str,
    pub message: String,
}

impl Reject {
    fn new(id: Option<String>, code: &'static str, message: impl Into<String>) -> Reject {
        Reject { id, code, message: message.into() }
    }

    /// The error frame for this rejection (one encoding shared with
    /// [`Response::Error`]).
    pub fn to_json(&self) -> Json {
        Response::Error {
            id: self.id.clone(),
            code: self.code.to_string(),
            message: self.message.clone(),
        }
        .to_json()
    }
}

impl Request {
    /// Encode as one frame line (no trailing newline).
    pub fn to_json(&self) -> Json {
        let mut fields = vec![("v", Json::num(PROTOCOL_VERSION as f64))];
        match self {
            Request::GetKernel { id, workload, gpu, mode, trace } => {
                fields.push(("op", Json::str("get_kernel")));
                fields.push(("id", Json::str(id.clone())));
                fields.push(("workload", workload_to_json(workload)));
                if let Some(g) = gpu {
                    fields.push(("gpu", Json::str(g.name())));
                }
                if let Some(m) = mode {
                    fields.push(("mode", Json::str(m.name())));
                }
                if let Some(t) = trace {
                    fields.push(("trace", Json::str(t.clone())));
                }
            }
            Request::Batch { id, items } => {
                fields.push(("op", Json::str("batch")));
                fields.push(("id", Json::str(id.clone())));
                // Only well-formed entries encode: `Err` items exist
                // solely on the parse side (a client never builds one).
                let entries = items.iter().filter_map(|item| item.as_ref().ok()).map(|item| {
                    let mut f = vec![
                        ("id", Json::str(item.id.clone())),
                        ("workload", workload_to_json(&item.workload)),
                    ];
                    if let Some(g) = item.gpu {
                        f.push(("gpu", Json::str(g.name())));
                    }
                    if let Some(m) = item.mode {
                        f.push(("mode", Json::str(m.name())));
                    }
                    Json::obj(f)
                });
                fields.push(("requests", Json::arr(entries)));
            }
            Request::Stats { id } => {
                fields.push(("op", Json::str("stats")));
                fields.push(("id", Json::str(id.clone())));
            }
            Request::Metrics { id } => {
                fields.push(("op", Json::str("metrics")));
                fields.push(("id", Json::str(id.clone())));
            }
            Request::Hello { id, wire } => {
                fields.push(("op", Json::str("hello")));
                fields.push(("id", Json::str(id.clone())));
                fields.push(("wire", Json::str(wire.clone())));
            }
            Request::Traces { id, slowest } => {
                fields.push(("op", Json::str("trace")));
                fields.push(("id", Json::str(id.clone())));
                if *slowest > 0 {
                    fields.push(("slowest", Json::num(*slowest as f64)));
                }
            }
            Request::Health { id } => {
                fields.push(("op", Json::str("health")));
                fields.push(("id", Json::str(id.clone())));
            }
            Request::Shutdown { id } => {
                fields.push(("op", Json::str("shutdown")));
                fields.push(("id", Json::str(id.clone())));
            }
        }
        Json::obj(fields)
    }

    /// Parse one request line; a `Reject` maps 1:1 to an error frame.
    pub fn parse_line(line: &str) -> Result<Request, Reject> {
        let v = Json::parse(line)
            .map_err(|e| Reject::new(None, error_code::BAD_REQUEST, format!("bad frame: {e}")))?;
        let id = v.get("id").and_then(|x| x.as_str()).map(|s| s.to_string());
        let version = v.get("v").and_then(|x| x.as_f64()).map(|x| x as u64);
        match version {
            Some(ver) if ver == PROTOCOL_VERSION => {}
            Some(ver) => {
                return Err(Reject::new(
                    id,
                    error_code::VERSION_MISMATCH,
                    format!("frame is v{ver}, this daemon speaks v{PROTOCOL_VERSION}"),
                ))
            }
            None => return Err(Reject::new(id, error_code::BAD_REQUEST, "frame missing 'v'")),
        }
        let id = id
            .ok_or_else(|| Reject::new(None, error_code::BAD_REQUEST, "frame missing 'id'"))?;
        let op = v
            .get("op")
            .and_then(|x| x.as_str())
            .ok_or_else(|| {
                Reject::new(Some(id.clone()), error_code::BAD_REQUEST, "frame missing 'op'")
            })?;
        match op {
            "stats" => Ok(Request::Stats { id }),
            "metrics" => Ok(Request::Metrics { id }),
            "trace" => {
                let slowest =
                    v.get("slowest").and_then(|x| x.as_f64()).unwrap_or(0.0).max(0.0) as usize;
                Ok(Request::Traces { id, slowest })
            }
            "health" => Ok(Request::Health { id }),
            "hello" => {
                // An absent/unknown `wire` is NOT an error: the ack
                // simply names the framing the daemon will speak
                // (line), so future wire names degrade gracefully.
                let wire = v
                    .get("wire")
                    .and_then(|x| x.as_str())
                    .unwrap_or(wire_name::LINE)
                    .to_string();
                Ok(Request::Hello { id, wire })
            }
            "shutdown" => Ok(Request::Shutdown { id }),
            "get_kernel" => {
                let (workload, gpu, mode) = parse_get_kernel_fields(&v, &id)?;
                // A present-but-unparseable trace id is the client's
                // bug: refuse it loudly (naming the field) instead of
                // silently minting a fresh id and orphaning the
                // client's correlation.
                let trace = match v.get("trace") {
                    None => None,
                    Some(t) => match t.as_str().filter(|s| TraceId::from_hex(s).is_some()) {
                        Some(s) => Some(s.to_string()),
                        None => {
                            return Err(Reject::new(
                                Some(id),
                                error_code::BAD_REQUEST,
                                "bad 'trace': want 1-16 hex chars",
                            ))
                        }
                    },
                };
                Ok(Request::GetKernel { id, workload, gpu, mode, trace })
            }
            "batch" => {
                let entries = v.get("requests").and_then(|r| r.as_arr()).ok_or_else(|| {
                    Reject::new(
                        Some(id.clone()),
                        error_code::BAD_REQUEST,
                        "batch missing 'requests' array",
                    )
                })?;
                if entries.is_empty() {
                    return Err(Reject::new(
                        Some(id),
                        error_code::BAD_REQUEST,
                        "batch 'requests' must not be empty",
                    ));
                }
                if entries.len() > MAX_BATCH_ITEMS {
                    return Err(Reject::new(
                        Some(id),
                        error_code::BAD_REQUEST,
                        format!(
                            "batch of {} exceeds the {MAX_BATCH_ITEMS}-request cap",
                            entries.len()
                        ),
                    ));
                }
                let items = entries
                    .iter()
                    .enumerate()
                    .map(|(i, entry)| parse_batch_item(entry, &id, i))
                    .collect();
                Ok(Request::Batch { id, items })
            }
            other => Err(Reject::new(
                Some(id),
                error_code::BAD_REQUEST,
                format!("unknown op '{other}'"),
            )),
        }
    }
}

/// The `workload`/`gpu`/`mode` fields of a `get_kernel`-shaped object
/// (a single request frame or one `batch` entry).
fn parse_get_kernel_fields(
    v: &Json,
    id: &str,
) -> Result<(Workload, Option<GpuArch>, Option<SearchMode>), Reject> {
    let wv = v.get("workload").ok_or_else(|| {
        Reject::new(
            Some(id.to_string()),
            error_code::BAD_REQUEST,
            "get_kernel missing 'workload'",
        )
    })?;
    let workload = parse_workload(wv)
        .map_err(|msg| Reject::new(Some(id.to_string()), error_code::UNKNOWN_WORKLOAD, msg))?;
    let gpu = match v.get("gpu").and_then(|x| x.as_str()) {
        None => None,
        Some(name) => Some(GpuArch::parse(name).ok_or_else(|| {
            Reject::new(
                Some(id.to_string()),
                error_code::BAD_REQUEST,
                format!("unknown gpu '{name}'"),
            )
        })?),
    };
    let mode = match v.get("mode").and_then(|x| x.as_str()) {
        None => None,
        Some(name) => Some(SearchMode::parse(name).ok_or_else(|| {
            Reject::new(
                Some(id.to_string()),
                error_code::BAD_REQUEST,
                format!("unknown mode '{name}'"),
            )
        })?),
    };
    Ok((workload, gpu, mode))
}

/// One `batch` entry. A malformed entry rejects only its own position
/// (carrying its effective id for the error frame), never the batch.
fn parse_batch_item(v: &Json, batch_id: &str, index: usize) -> Result<BatchItem, Reject> {
    let id = v
        .get("id")
        .and_then(|x| x.as_str())
        .map(|s| s.to_string())
        .unwrap_or_else(|| format!("{batch_id}.{index}"));
    if let Some(op) = v.get("op").and_then(|x| x.as_str()) {
        if op != "get_kernel" {
            return Err(Reject::new(
                Some(id),
                error_code::BAD_REQUEST,
                format!("batch entries must be get_kernel requests, not '{op}'"),
            ));
        }
    }
    let (workload, gpu, mode) = parse_get_kernel_fields(v, &id)?;
    Ok(BatchItem { id, workload, gpu, mode })
}

/// A workload field: a suite name string (`"MM1"`) or a workload object.
fn parse_workload(v: &Json) -> Result<Workload, String> {
    match v {
        Json::Str(name) => suites::by_name(name)
            .ok_or_else(|| format!("unknown workload '{name}' (MM1..MM4, MV1..MV4, CONV1..CONV3)")),
        obj => workload_from_json(obj),
    }
}

/// Where a `get_kernel` reply came from.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ServeSource {
    /// Exact store hit: the recorded, NVML-measured kernel.
    Store,
    /// Miss: the nearest neighbor's best schedule re-legalized for this
    /// shape; metrics are MAC-rescaled estimates.
    WarmGuess,
    /// Miss with no usable neighbor: the schedule space's fallback.
    Fallback,
}

impl ServeSource {
    pub fn name(self) -> &'static str {
        match self {
            ServeSource::Store => "store",
            ServeSource::WarmGuess => "warm_guess",
            ServeSource::Fallback => "fallback",
        }
    }

    pub fn parse(s: &str) -> Option<ServeSource> {
        match s {
            "store" => Some(ServeSource::Store),
            "warm_guess" => Some(ServeSource::WarmGuess),
            "fallback" => Some(ServeSource::Fallback),
            _ => None,
        }
    }
}

/// Serving tier of a `get_kernel` reply (ISSUE 9): how much evidence
/// stands behind the returned schedule. Orthogonal to `source` (which
/// names the mechanism); the tier names the guarantee.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ServeTier {
    /// Exact store hit: NVML-measured metrics for this very key.
    Exact,
    /// Warm transfer: a neighbor's measured kernel re-legalized for
    /// this shape, metrics rescaled estimates.
    Warm,
    /// Search-free static tier: no usable neighbor — the best-of-N
    /// statically-ranked legal schedule with closed-form
    /// [`crate::analysis::StaticProfile`] estimates and **zero**
    /// measurements. The background search still runs; the next
    /// request upgrades to `exact` once its write-back lands.
    Static,
}

impl ServeTier {
    pub fn name(self) -> &'static str {
        match self {
            ServeTier::Exact => "exact",
            ServeTier::Warm => "warm",
            ServeTier::Static => "static",
        }
    }

    pub fn parse(s: &str) -> Option<ServeTier> {
        match s {
            "exact" => Some(ServeTier::Exact),
            "warm" => Some(ServeTier::Warm),
            "static" => Some(ServeTier::Static),
            _ => None,
        }
    }

    /// The tier a pre-tier frame implies: sources mapped 1:1 (older
    /// daemons' fallback replies carried no static profile, but they
    /// made the same zero-measurement promise).
    pub fn from_source(source: ServeSource) -> ServeTier {
        match source {
            ServeSource::Store => ServeTier::Exact,
            ServeSource::WarmGuess => ServeTier::Warm,
            ServeSource::Fallback => ServeTier::Static,
        }
    }
}

/// The `get_kernel` response frame.
#[derive(Debug, Clone, PartialEq)]
pub struct KernelReply {
    pub id: String,
    /// True for an exact store hit.
    pub hit: bool,
    pub source: ServeSource,
    /// Serving tier: `exact` / `warm` / `static` (absent in pre-tier
    /// frames — derived from `source` on parse).
    pub tier: ServeTier,
    pub schedule: Schedule,
    /// Measured metrics on a hit; MAC-rescaled estimates (or 0.0 =
    /// unknown, for fallback schedules) on a miss.
    pub latency_s: f64,
    pub energy_j: f64,
    pub avg_power_w: f64,
    /// True when this reply enqueued a background search.
    pub enqueued: bool,
    /// Keys enqueued-or-searching when the reply was sent.
    pub queue_depth: usize,
    /// Simulated reply latency charged to this request.
    pub reply_time_s: f64,
}

impl KernelReply {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("v", Json::num(PROTOCOL_VERSION as f64)),
            ("id", Json::str(self.id.clone())),
            ("ok", Json::Bool(true)),
            ("op", Json::str("get_kernel")),
            ("result", Json::str(if self.hit { "hit" } else { "miss" })),
            ("source", Json::str(self.source.name())),
            ("tier", Json::str(self.tier.name())),
            ("schedule", schedule_to_json(&self.schedule)),
            ("variant_id", Json::str(self.schedule.variant_id())),
            ("latency_s", Json::num(self.latency_s)),
            ("energy_j", Json::num(self.energy_j)),
            ("avg_power_w", Json::num(self.avg_power_w)),
            ("enqueued", Json::Bool(self.enqueued)),
            ("queue_depth", Json::num(self.queue_depth as f64)),
            ("reply_time_s", Json::num(self.reply_time_s)),
        ])
    }

    pub fn from_json(v: &Json) -> Result<KernelReply, String> {
        let result = get_str(v, "result")?;
        let hit = match result.as_str() {
            "hit" => true,
            "miss" => false,
            other => return Err(format!("bad 'result' value '{other}'")),
        };
        let source = ServeSource::parse(&get_str(v, "source")?).ok_or("bad 'source' value")?;
        // Pre-tier frames carry no 'tier': derive it from the source.
        let tier = match v.get("tier").and_then(|t| t.as_str()) {
            Some(t) => ServeTier::parse(t).ok_or("bad 'tier' value")?,
            None => ServeTier::from_source(source),
        };
        Ok(KernelReply {
            id: get_str(v, "id")?,
            hit,
            source,
            tier,
            schedule: schedule_from_json(v.get("schedule").ok_or("reply missing 'schedule'")?)?,
            latency_s: get_f64(v, "latency_s")?,
            energy_j: get_f64(v, "energy_j")?,
            avg_power_w: get_f64(v, "avg_power_w")?,
            enqueued: v.get("enqueued").and_then(|b| b.as_bool()).ok_or("missing 'enqueued'")?,
            queue_depth: get_f64(v, "queue_depth")? as usize,
            reply_time_s: get_f64(v, "reply_time_s")?,
        })
    }
}

/// The `stats` response frame: serving metrics + store counters.
#[derive(Debug, Clone, PartialEq)]
pub struct StatsReply {
    pub id: String,
    pub n_requests: usize,
    pub n_hits: usize,
    pub n_misses: usize,
    pub n_enqueued: usize,
    pub n_searches_done: usize,
    pub n_evicted_records: usize,
    /// Jobs in the worker pool (queued or running). Before protocol
    /// frames gained `pending_keys`, this field conflated the pool
    /// depth with backlogged and in-flight keys.
    pub queue_depth: usize,
    pub n_records: usize,
    pub n_shards: usize,
    pub hit_rate: f64,
    pub p50_reply_s: f64,
    pub p99_reply_s: f64,
    /// NVML measurements the daemon's background searches have paid.
    pub measurements_paid: usize,
    /// Misses shed by admission control (queue + backlog saturated).
    pub n_shed: usize,
    /// Misses coalesced into another fleet member's in-flight search.
    pub n_fleet_coalesced: usize,
    /// Misses answered by the search-free static tier — best-of-N
    /// statically-ranked schedules, zero measurements (absent in
    /// pre-tier frames = 0).
    pub n_static_tier: usize,
    /// Keys currently heat-queued behind a saturated search queue.
    pub backlog_len: usize,
    /// Serve keys with a search queued, backlogged, running, or
    /// awaiting write-back on this daemon (the drain signal; absent in
    /// pre-split frames = 0).
    pub pending_keys: usize,
    /// Finished searches fenced out by a reclaimed fleet claim (absent
    /// in older frames = 0).
    pub n_writebacks_fenced: usize,
    /// Finished searches whose write-back was dropped for good (absent
    /// in older frames = 0).
    pub n_writebacks_dropped: usize,
    /// `batch` frames served — one socket write each (absent in
    /// pre-batch frames = 0).
    pub n_batch_frames: usize,
    /// `get_kernel` requests that arrived inside `batch` frames
    /// (absent in pre-batch frames = 0).
    pub n_batch_requests: usize,
    /// Foreign write-back announcements acted on by the notify refresh
    /// loop — the push path (absent in older frames = 0).
    pub n_notify_refresh: usize,
    /// Interval-poll fallback passes that ingested changes the notify
    /// channel missed (absent in older frames = 0).
    pub n_poll_refresh: usize,
    /// Seconds since the daemon bound its socket (absent in older
    /// frames = 0).
    pub uptime_s: f64,
    /// Build identity of the serving daemon: crate version, plus the
    /// git hash when one was baked in at compile time (absent in older
    /// frames = empty).
    pub build_info: String,
    /// Records per shard (the store-size histogram).
    pub shard_records: Vec<usize>,
    /// Key counts per heat bucket (log2 buckets, coldest first — see
    /// [`crate::fleet::HeatSketch::histogram`]).
    pub heat_histogram: Vec<usize>,
}

impl StatsReply {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("v", Json::num(PROTOCOL_VERSION as f64)),
            ("id", Json::str(self.id.clone())),
            ("ok", Json::Bool(true)),
            ("op", Json::str("stats")),
            (
                "stats",
                Json::obj(vec![
                    ("n_requests", Json::num(self.n_requests as f64)),
                    ("n_hits", Json::num(self.n_hits as f64)),
                    ("n_misses", Json::num(self.n_misses as f64)),
                    ("n_enqueued", Json::num(self.n_enqueued as f64)),
                    ("n_searches_done", Json::num(self.n_searches_done as f64)),
                    ("n_evicted_records", Json::num(self.n_evicted_records as f64)),
                    ("queue_depth", Json::num(self.queue_depth as f64)),
                    ("n_records", Json::num(self.n_records as f64)),
                    ("n_shards", Json::num(self.n_shards as f64)),
                    ("hit_rate", Json::num(self.hit_rate)),
                    ("p50_reply_s", Json::num(self.p50_reply_s)),
                    ("p99_reply_s", Json::num(self.p99_reply_s)),
                    ("measurements_paid", Json::num(self.measurements_paid as f64)),
                    ("n_shed", Json::num(self.n_shed as f64)),
                    ("n_fleet_coalesced", Json::num(self.n_fleet_coalesced as f64)),
                    ("n_static_tier", Json::num(self.n_static_tier as f64)),
                    ("backlog_len", Json::num(self.backlog_len as f64)),
                    ("pending_keys", Json::num(self.pending_keys as f64)),
                    ("n_writebacks_fenced", Json::num(self.n_writebacks_fenced as f64)),
                    ("n_writebacks_dropped", Json::num(self.n_writebacks_dropped as f64)),
                    ("n_batch_frames", Json::num(self.n_batch_frames as f64)),
                    ("n_batch_requests", Json::num(self.n_batch_requests as f64)),
                    ("n_notify_refresh", Json::num(self.n_notify_refresh as f64)),
                    ("n_poll_refresh", Json::num(self.n_poll_refresh as f64)),
                    ("uptime_s", Json::num(self.uptime_s)),
                    ("build_info", Json::str(self.build_info.clone())),
                    (
                        "shard_records",
                        Json::arr(self.shard_records.iter().map(|&n| Json::num(n as f64))),
                    ),
                    (
                        "heat_histogram",
                        Json::arr(self.heat_histogram.iter().map(|&n| Json::num(n as f64))),
                    ),
                ]),
            ),
        ])
    }

    pub fn from_json(v: &Json) -> Result<StatsReply, String> {
        let id = get_str(v, "id")?;
        let s = v.get("stats").ok_or("reply missing 'stats'")?;
        Ok(StatsReply {
            id,
            n_requests: get_f64(s, "n_requests")? as usize,
            n_hits: get_f64(s, "n_hits")? as usize,
            n_misses: get_f64(s, "n_misses")? as usize,
            n_enqueued: get_f64(s, "n_enqueued")? as usize,
            n_searches_done: get_f64(s, "n_searches_done")? as usize,
            n_evicted_records: get_f64(s, "n_evicted_records")? as usize,
            queue_depth: get_f64(s, "queue_depth")? as usize,
            n_records: get_f64(s, "n_records")? as usize,
            n_shards: get_f64(s, "n_shards")? as usize,
            hit_rate: get_f64(s, "hit_rate")?,
            p50_reply_s: get_f64(s, "p50_reply_s")?,
            p99_reply_s: get_f64(s, "p99_reply_s")?,
            measurements_paid: get_f64(s, "measurements_paid")? as usize,
            // Fleet-era fields: tolerated as absent so frames from a
            // pre-fleet daemon still parse.
            n_shed: opt_usize(s, "n_shed"),
            n_fleet_coalesced: opt_usize(s, "n_fleet_coalesced"),
            n_static_tier: opt_usize(s, "n_static_tier"),
            backlog_len: opt_usize(s, "backlog_len"),
            pending_keys: opt_usize(s, "pending_keys"),
            n_writebacks_fenced: opt_usize(s, "n_writebacks_fenced"),
            n_writebacks_dropped: opt_usize(s, "n_writebacks_dropped"),
            n_batch_frames: opt_usize(s, "n_batch_frames"),
            n_batch_requests: opt_usize(s, "n_batch_requests"),
            n_notify_refresh: opt_usize(s, "n_notify_refresh"),
            n_poll_refresh: opt_usize(s, "n_poll_refresh"),
            uptime_s: s.get("uptime_s").and_then(Json::as_f64).unwrap_or(0.0),
            build_info: s
                .get("build_info")
                .and_then(|x| x.as_str())
                .unwrap_or_default()
                .to_string(),
            shard_records: opt_usize_arr(s, "shard_records"),
            heat_histogram: opt_usize_arr(s, "heat_histogram"),
        })
    }
}

/// The `metrics` response frame: the full telemetry view of one daemon
/// — every serving counter plus reply-time and per-stage wall-clock
/// histograms — built to be MERGED: [`MetricsReply::merge`] folds N
/// daemons' frames into one fleet view that is exactly the view a
/// single daemon would report had it served every request itself
/// (counters sum; log2-bucket histograms merge losslessly).
#[derive(Debug, Clone, PartialEq)]
pub struct MetricsReply {
    pub id: String,
    /// Serving counters by their `stats`-field names (`n_requests`,
    /// `n_hits`, `n_batch_frames`, ...).
    pub counters: BTreeMap<String, u64>,
    /// Simulated-clock reply times (the Fig. 5 currency).
    pub reply_sim_s: LogHistogram,
    /// Wall-clock reply times: frame receipt → reply frame built.
    pub reply_wall_s: LogHistogram,
    /// Wall-clock per-stage histograms keyed by stage name (`parse`,
    /// `shard_read`, `snapshot_lookup`, `claim_io`, `enqueue`,
    /// `reply_write`).
    pub stages: BTreeMap<String, LogHistogram>,
    /// Cost-model accuracy histograms keyed `family/regime`
    /// (`model_snr_db/round0`, `model_energy_relerr/steady`,
    /// `model_dynamic_k/steady`, ...) — the ISSUE 7 drift telemetry.
    /// Absent in pre-trace frames (reads as empty), so no
    /// `metrics_v` bump.
    pub model: BTreeMap<String, LogHistogram>,
    /// The energy-savings ledger (ISSUE 8): joules saved vs the
    /// latency-only baseline and measurement joules paid, per
    /// (gpu, workload-family). Sparse on the wire and absent in older
    /// frames (reads as empty), so no `metrics_v` bump — same
    /// precedent as `model`.
    pub energy: EnergyLedger,
}

impl MetricsReply {
    pub fn to_json(&self) -> Json {
        let counters: BTreeMap<String, Json> =
            self.counters.iter().map(|(k, &v)| (k.clone(), Json::num(v as f64))).collect();
        let stages: BTreeMap<String, Json> =
            self.stages.iter().map(|(k, h)| (k.clone(), h.to_json())).collect();
        let model: BTreeMap<String, Json> =
            self.model.iter().map(|(k, h)| (k.clone(), h.to_json())).collect();
        Json::obj(vec![
            ("v", Json::num(PROTOCOL_VERSION as f64)),
            ("id", Json::str(self.id.clone())),
            ("ok", Json::Bool(true)),
            ("op", Json::str("metrics")),
            ("metrics_v", Json::num(METRICS_VERSION as f64)),
            ("counters", Json::Obj(counters)),
            ("reply_sim_s", self.reply_sim_s.to_json()),
            ("reply_wall_s", self.reply_wall_s.to_json()),
            ("stages", Json::Obj(stages)),
            ("model", Json::Obj(model)),
            ("energy", self.energy.to_json()),
        ])
    }

    pub fn from_json(v: &Json) -> Result<MetricsReply, String> {
        // Absent `metrics_v` reads as v1 (the first shipped payload);
        // anything newer than this client is refused rather than
        // silently mis-decoded.
        let payload_v = v.get("metrics_v").and_then(|x| x.as_f64()).unwrap_or(1.0) as u64;
        if payload_v > METRICS_VERSION {
            return Err(format!(
                "metrics payload is v{payload_v}, this client understands v{METRICS_VERSION}"
            ));
        }
        let mut counters = BTreeMap::new();
        if let Some(Json::Obj(m)) = v.get("counters") {
            for (k, n) in m {
                if let Some(n) = n.as_f64() {
                    counters.insert(k.clone(), n as u64);
                }
            }
        }
        let mut stages = BTreeMap::new();
        if let Some(Json::Obj(m)) = v.get("stages") {
            for (k, h) in m {
                stages.insert(k.clone(), LogHistogram::from_json(h));
            }
        }
        // Absent in pre-trace frames: an empty model map merges as a
        // no-op, so old daemons mix into a fleet view cleanly.
        let mut model = BTreeMap::new();
        if let Some(Json::Obj(m)) = v.get("model") {
            for (k, h) in m {
                model.insert(k.clone(), LogHistogram::from_json(h));
            }
        }
        let hist = |key: &str| v.get(key).map(LogHistogram::from_json).unwrap_or_default();
        Ok(MetricsReply {
            id: get_str(v, "id")?,
            counters,
            reply_sim_s: hist("reply_sim_s"),
            reply_wall_s: hist("reply_wall_s"),
            stages,
            model,
            energy: v.get("energy").map(EnergyLedger::from_json).unwrap_or_default(),
        })
    }

    /// A counter by its `stats`-field name; 0 when absent.
    pub fn counter(&self, name: &str) -> u64 {
        self.counters.get(name).copied().unwrap_or(0)
    }

    /// Fold another daemon's metrics in (fleet aggregation): counters
    /// sum, histograms merge bucket-wise. Associative and commutative,
    /// so a fleet client can fold daemons in any order.
    pub fn merge(&mut self, other: &MetricsReply) {
        for (k, &v) in &other.counters {
            *self.counters.entry(k.clone()).or_insert(0) += v;
        }
        self.reply_sim_s.merge(&other.reply_sim_s);
        self.reply_wall_s.merge(&other.reply_wall_s);
        for (name, h) in &other.stages {
            match self.stages.get_mut(name) {
                Some(mine) => mine.merge(h),
                None => {
                    self.stages.insert(name.clone(), h.clone());
                }
            }
        }
        for (name, h) in &other.model {
            match self.model.get_mut(name) {
                Some(mine) => mine.merge(h),
                None => {
                    self.model.insert(name.clone(), h.clone());
                }
            }
        }
        self.energy.merge(&other.energy);
    }

    /// Requests amortized per `batch` frame — how many `get_kernel`s
    /// the batched path carried per socket write. 0.0 before any batch
    /// frame was served.
    pub fn frames_per_syscall(&self) -> f64 {
        let frames = self.counter("n_batch_frames");
        if frames == 0 {
            return 0.0;
        }
        self.counter("n_batch_requests") as f64 / frames as f64
    }

    /// Prometheus text exposition (v0.0.4): counters as `_total`
    /// counters, histograms as cumulative-`le` histograms with the
    /// log2 bucket upper bounds, stages as one histogram family with a
    /// `stage` label, model-accuracy families with a `regime` label
    /// (`ecokernel_model_snr_db`, `ecokernel_model_energy_relerr`,
    /// `ecokernel_model_dynamic_k`), and the energy ledger as two
    /// `gpu`/`family`-labelled counter families
    /// (`ecokernel_energy_{saved,paid}_joules_total`) — nothing is
    /// emitted for an empty ledger.
    pub fn to_prometheus(&self) -> String {
        use std::fmt::Write as _;
        let mut out = String::new();
        for (name, value) in &self.counters {
            let base = name.strip_prefix("n_").unwrap_or(name);
            let _ = writeln!(out, "# TYPE ecokernel_{base}_total counter");
            let _ = writeln!(out, "ecokernel_{base}_total {value}");
        }
        if !self.energy.is_empty() {
            let _ = writeln!(out, "# TYPE ecokernel_energy_saved_joules_total counter");
            for (g, f) in self.energy.cells() {
                let _ = writeln!(
                    out,
                    "ecokernel_energy_saved_joules_total{{gpu=\"{}\",family=\"{}\"}} {}",
                    LEDGER_GPUS[g],
                    LEDGER_FAMILIES[f],
                    self.energy.saved_j(g, f),
                );
            }
            let _ = writeln!(out, "# TYPE ecokernel_energy_paid_joules_total counter");
            for (g, f) in self.energy.cells() {
                let _ = writeln!(
                    out,
                    "ecokernel_energy_paid_joules_total{{gpu=\"{}\",family=\"{}\"}} {}",
                    LEDGER_GPUS[g],
                    LEDGER_FAMILIES[f],
                    self.energy.paid_j(g, f),
                );
            }
        }
        prom_histogram(&mut out, "ecokernel_reply_sim_seconds", None, &self.reply_sim_s);
        prom_histogram(&mut out, "ecokernel_reply_wall_seconds", None, &self.reply_wall_s);
        let _ = writeln!(out, "# TYPE ecokernel_stage_seconds histogram");
        for (stage, h) in &self.stages {
            prom_histogram(&mut out, "ecokernel_stage_seconds", Some(("stage", stage)), h);
        }
        // Model keys are `family/regime`; each family becomes one
        // histogram family labelled by regime. Keys sort family-major
        // (BTreeMap), so the `# TYPE` line precedes its label values.
        let mut last_family = "";
        for (key, h) in &self.model {
            let (family, regime) = key.split_once('/').unwrap_or((key.as_str(), "all"));
            if family != last_family {
                let _ = writeln!(out, "# TYPE ecokernel_{family} histogram");
                last_family = family;
            }
            let name = format!("ecokernel_{family}");
            prom_histogram(&mut out, &name, Some(("regime", regime)), h);
        }
        out
    }
}

/// Escape a Prometheus label VALUE (text exposition v0.0.4):
/// backslash, double-quote, and newline.
fn prom_escape(v: &str) -> String {
    let mut out = String::with_capacity(v.len());
    for c in v.chars() {
        match c {
            '\\' => out.push_str("\\\\"),
            '"' => out.push_str("\\\""),
            '\n' => out.push_str("\\n"),
            c => out.push(c),
        }
    }
    out
}

/// One Prometheus histogram family: cumulative `le` buckets (empty
/// leading buckets elided, counts stay cumulative), then `_sum` and
/// `_count`. With a `(key, value)` label the `# TYPE` line is the
/// caller's (one per family, not per label value); the label value is
/// escaped per the exposition format.
fn prom_histogram(out: &mut String, name: &str, label: Option<(&str, &str)>, h: &LogHistogram) {
    use std::fmt::Write as _;
    let label = label.map(|(k, v)| (k, prom_escape(v)));
    let tag = |le: &str| match &label {
        Some((k, v)) => format!("{{{k}=\"{v}\",le=\"{le}\"}}"),
        None => format!("{{le=\"{le}\"}}"),
    };
    let suffix = match &label {
        Some((k, v)) => format!("{{{k}=\"{v}\"}}"),
        None => String::new(),
    };
    if label.is_none() {
        let _ = writeln!(out, "# TYPE {name} histogram");
    }
    let total = h.count();
    let mut cumulative = 0u64;
    for i in 0..N_BUCKETS {
        cumulative += h.bucket(i);
        // Elide the all-zero head and the saturated tail; what prints
        // keeps `le` and the cumulative counts monotone.
        if cumulative == 0 {
            continue;
        }
        let le = format!("{:e}", bucket_lower(i + 1));
        let _ = writeln!(out, "{name}_bucket{} {cumulative}", tag(&le));
        if cumulative == total {
            break;
        }
    }
    let _ = writeln!(out, "{name}_bucket{} {}", tag("+Inf"), h.count());
    let _ = writeln!(out, "{name}_sum{suffix} {}", h.sum());
    let _ = writeln!(out, "{name}_count{suffix} {}", h.count());
}

/// The `trace` response frame: completed traces from the daemon's
/// tail-sampled ring, slowest first. Carries its own payload version
/// (`"trace_v"`, like `metrics_v`) so the span encoding can evolve
/// without a protocol bump.
#[derive(Debug, Clone, PartialEq)]
pub struct TraceReply {
    pub id: String,
    pub traces: Vec<crate::telemetry::Trace>,
}

impl TraceReply {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("v", Json::num(PROTOCOL_VERSION as f64)),
            ("id", Json::str(self.id.clone())),
            ("ok", Json::Bool(true)),
            ("op", Json::str("trace")),
            ("trace_v", Json::num(TRACE_VERSION as f64)),
            ("traces", Json::arr(self.traces.iter().map(|t| t.to_json()))),
        ])
    }

    pub fn from_json(v: &Json) -> Result<TraceReply, String> {
        let payload_v = v.get("trace_v").and_then(|x| x.as_f64()).unwrap_or(1.0) as u64;
        if payload_v > TRACE_VERSION {
            return Err(format!(
                "trace payload is v{payload_v}, this client understands v{TRACE_VERSION}"
            ));
        }
        let traces = v
            .get("traces")
            .and_then(|a| a.as_arr())
            .map(|a| a.iter().filter_map(crate::telemetry::Trace::from_json).collect())
            .unwrap_or_default();
        Ok(TraceReply { id: get_str(v, "id")?, traces })
    }
}

/// One SLO verdict: `ok`, `warn`, or `critical`, ordered by severity.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum HealthStatus {
    Ok,
    Warn,
    Critical,
}

impl HealthStatus {
    pub fn name(self) -> &'static str {
        match self {
            HealthStatus::Ok => "ok",
            HealthStatus::Warn => "warn",
            HealthStatus::Critical => "critical",
        }
    }

    pub fn parse(s: &str) -> Option<HealthStatus> {
        match s {
            "ok" => Some(HealthStatus::Ok),
            "warn" => Some(HealthStatus::Warn),
            "critical" => Some(HealthStatus::Critical),
            _ => None,
        }
    }

    /// Severity rank: `ok` < `warn` < `critical`.
    pub fn rank(self) -> u8 {
        match self {
            HealthStatus::Ok => 0,
            HealthStatus::Warn => 1,
            HealthStatus::Critical => 2,
        }
    }

    /// The more severe of the two.
    pub fn worst(self, other: HealthStatus) -> HealthStatus {
        if other.rank() > self.rank() {
            other
        } else {
            self
        }
    }
}

/// One `[slo]` target's verdict inside a `health` reply.
#[derive(Debug, Clone, PartialEq)]
pub struct HealthTarget {
    /// Stable target name (`p99_reply_wall_s`, `hit_rate`,
    /// `relerr_steady`, `backlog`; fleet clients may synthesize
    /// `fleet_reachability`).
    pub name: String,
    pub status: HealthStatus,
    /// Human-readable cause — names the breached window(s) or says why
    /// the target is inert (`disabled`, `warming up`).
    pub reason: String,
    /// Slow-window (lifetime) observation the verdict compared.
    pub value: f64,
    /// Fast-window (burn-rate) observation since the last watchdog
    /// tick; equals `value` until the first tick.
    pub fast_value: f64,
    /// The `[slo]` threshold in force.
    pub threshold: f64,
}

impl HealthTarget {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("name", Json::str(self.name.clone())),
            ("status", Json::str(self.status.name())),
            ("reason", Json::str(self.reason.clone())),
            ("value", Json::num(self.value)),
            ("fast_value", Json::num(self.fast_value)),
            ("threshold", Json::num(self.threshold)),
        ])
    }

    pub fn from_json(v: &Json) -> Option<HealthTarget> {
        Some(HealthTarget {
            name: v.get("name")?.as_str()?.to_string(),
            status: HealthStatus::parse(v.get("status")?.as_str()?)?,
            reason: v.get("reason").and_then(|x| x.as_str()).unwrap_or_default().to_string(),
            value: v.get("value").and_then(Json::as_f64).unwrap_or(0.0),
            fast_value: v.get("fast_value").and_then(Json::as_f64).unwrap_or(0.0),
            threshold: v.get("threshold").and_then(Json::as_f64).unwrap_or(0.0),
        })
    }
}

/// The drift watchdog's state inside a `health` reply.
#[derive(Debug, Clone, PartialEq, Default)]
pub struct DriftHealth {
    /// Re-searches the watchdog has admitted over the daemon lifetime.
    pub n_drift_researches: u64,
    /// Lifetime steady-regime mean energy relative error.
    pub relerr_steady_mean: f64,
    /// Fast-window steady-regime mean relerr (since the last tick).
    pub relerr_fast_mean: f64,
    /// `slo.drift_budget` in force (max re-searches per interval).
    pub budget: usize,
    /// True while the steady relerr sits past the `[slo]` ceiling.
    pub drifting: bool,
}

impl DriftHealth {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("n_drift_researches", Json::num(self.n_drift_researches as f64)),
            ("relerr_steady_mean", Json::num(self.relerr_steady_mean)),
            ("relerr_fast_mean", Json::num(self.relerr_fast_mean)),
            ("budget", Json::num(self.budget as f64)),
            ("drifting", Json::Bool(self.drifting)),
        ])
    }

    pub fn from_json(v: &Json) -> DriftHealth {
        DriftHealth {
            n_drift_researches: v.get("n_drift_researches").and_then(Json::as_f64).unwrap_or(0.0)
                as u64,
            relerr_steady_mean: v.get("relerr_steady_mean").and_then(Json::as_f64).unwrap_or(0.0),
            relerr_fast_mean: v.get("relerr_fast_mean").and_then(Json::as_f64).unwrap_or(0.0),
            budget: v.get("budget").and_then(Json::as_f64).unwrap_or(0.0) as usize,
            drifting: v.get("drifting").and_then(|b| b.as_bool()).unwrap_or(false),
        }
    }
}

/// The `health` response frame: the overall verdict, one
/// [`HealthTarget`] per `[slo]` target, and the drift watchdog's
/// state. Carries its own payload version (`"health_v"`, like
/// `metrics_v`) so the verdict encoding can evolve without a protocol
/// bump.
#[derive(Debug, Clone, PartialEq)]
pub struct HealthReply {
    pub id: String,
    /// Worst status across `targets`.
    pub status: HealthStatus,
    pub targets: Vec<HealthTarget>,
    pub drift: DriftHealth,
}

impl HealthReply {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("v", Json::num(PROTOCOL_VERSION as f64)),
            ("id", Json::str(self.id.clone())),
            ("ok", Json::Bool(true)),
            ("op", Json::str("health")),
            ("health_v", Json::num(HEALTH_VERSION as f64)),
            ("status", Json::str(self.status.name())),
            ("targets", Json::arr(self.targets.iter().map(|t| t.to_json()))),
            ("drift", self.drift.to_json()),
        ])
    }

    pub fn from_json(v: &Json) -> Result<HealthReply, String> {
        let payload_v = v.get("health_v").and_then(Json::as_f64).unwrap_or(1.0) as u64;
        if payload_v > HEALTH_VERSION {
            return Err(format!(
                "health payload is v{payload_v}, this client understands v{HEALTH_VERSION}"
            ));
        }
        let status =
            HealthStatus::parse(&get_str(v, "status")?).ok_or("bad 'status' value")?;
        let targets = v
            .get("targets")
            .and_then(|a| a.as_arr())
            .map(|a| a.iter().filter_map(HealthTarget::from_json).collect())
            .unwrap_or_default();
        let drift = v.get("drift").map(DriftHealth::from_json).unwrap_or_default();
        Ok(HealthReply { id: get_str(v, "id")?, status, targets, drift })
    }

    /// Fold another daemon's health in: the fleet is exactly as
    /// healthy as its least healthy member. Targets merge by name —
    /// the worse status wins, and on a tie the larger fast-window
    /// value (the daemon burning hotter) carries the reason. Targets
    /// only one side reports survive, so partial fleets keep their
    /// verdicts. Drift counters sum; means take the worst; `drifting`
    /// is sticky.
    pub fn merge_worst(&mut self, other: &HealthReply) {
        self.status = self.status.worst(other.status);
        for t in &other.targets {
            match self.targets.iter_mut().find(|mine| mine.name == t.name) {
                None => self.targets.push(t.clone()),
                Some(mine) => {
                    let replace = t.status.rank() > mine.status.rank()
                        || (t.status == mine.status && t.fast_value > mine.fast_value);
                    if replace {
                        *mine = t.clone();
                    }
                }
            }
        }
        self.drift.n_drift_researches += other.drift.n_drift_researches;
        self.drift.relerr_steady_mean =
            self.drift.relerr_steady_mean.max(other.drift.relerr_steady_mean);
        self.drift.relerr_fast_mean =
            self.drift.relerr_fast_mean.max(other.drift.relerr_fast_mean);
        self.drift.budget = self.drift.budget.max(other.drift.budget);
        self.drift.drifting |= other.drift.drifting;
    }
}

fn opt_usize(v: &Json, key: &str) -> usize {
    v.get(key).and_then(|x| x.as_f64()).unwrap_or(0.0) as usize
}

fn opt_usize_arr(v: &Json, key: &str) -> Vec<usize> {
    v.get(key)
        .and_then(|a| a.as_arr())
        .map(|a| a.iter().filter_map(|x| x.as_f64()).map(|f| f as usize).collect())
        .unwrap_or_default()
}

/// Any response frame, as parsed by the client.
#[derive(Debug, Clone, PartialEq)]
pub enum Response {
    Kernel(KernelReply),
    /// Positionally-matched replies to a `batch` frame: entry *i*
    /// answers request *i*, and is a `Kernel` or `Error` frame.
    Batch { id: String, replies: Vec<Response> },
    Stats(StatsReply),
    Metrics(MetricsReply),
    Trace(TraceReply),
    Health(HealthReply),
    /// Ack of a `hello` negotiation: `wire` names the framing the
    /// daemon speaks from the next frame on (it may decline binary by
    /// acking `"line"`); `wire_v` is 2 for binary, 1 for line.
    HelloAck { id: String, wire: String },
    ShutdownAck { id: String },
    Error { id: Option<String>, code: String, message: String },
}

impl Response {
    pub fn to_json(&self) -> Json {
        match self {
            Response::Kernel(r) => r.to_json(),
            Response::Batch { id, replies } => Json::obj(vec![
                ("v", Json::num(PROTOCOL_VERSION as f64)),
                ("id", Json::str(id.clone())),
                ("ok", Json::Bool(true)),
                ("op", Json::str("batch")),
                ("replies", Json::arr(replies.iter().map(|r| r.to_json()))),
            ]),
            Response::Stats(r) => r.to_json(),
            Response::Metrics(r) => r.to_json(),
            Response::Trace(r) => r.to_json(),
            Response::Health(r) => r.to_json(),
            Response::HelloAck { id, wire } => {
                let wire_v = if wire == wire_name::BINARY { WIRE_VERSION } else { 1 };
                Json::obj(vec![
                    ("v", Json::num(PROTOCOL_VERSION as f64)),
                    ("id", Json::str(id.clone())),
                    ("ok", Json::Bool(true)),
                    ("op", Json::str("hello")),
                    ("wire", Json::str(wire.clone())),
                    ("wire_v", Json::num(wire_v as f64)),
                ])
            }
            Response::ShutdownAck { id } => Json::obj(vec![
                ("v", Json::num(PROTOCOL_VERSION as f64)),
                ("id", Json::str(id.clone())),
                ("ok", Json::Bool(true)),
                ("op", Json::str("shutdown")),
            ]),
            Response::Error { id, code, message } => Json::obj(vec![
                ("v", Json::num(PROTOCOL_VERSION as f64)),
                (
                    "id",
                    match id {
                        Some(id) => Json::str(id.clone()),
                        None => Json::Null,
                    },
                ),
                ("ok", Json::Bool(false)),
                (
                    "error",
                    Json::obj(vec![
                        ("code", Json::str(code.clone())),
                        ("message", Json::str(message.clone())),
                    ]),
                ),
            ]),
        }
    }

    pub fn parse_line(line: &str) -> Result<Response, String> {
        Response::from_json(&Json::parse(line)?)
    }

    /// Parse one response frame object — [`Response::parse_line`]
    /// minus the text parse; `batch` replies nest full frames, so this
    /// recurses one level into the `"replies"` array.
    pub fn from_json(v: &Json) -> Result<Response, String> {
        let version = v.get("v").and_then(|x| x.as_f64()).ok_or("frame missing 'v'")? as u64;
        if version != PROTOCOL_VERSION {
            return Err(format!(
                "frame is v{version}, this client speaks v{PROTOCOL_VERSION}"
            ));
        }
        let ok = v.get("ok").and_then(|b| b.as_bool()).ok_or("frame missing 'ok'")?;
        if !ok {
            let e = v.get("error").ok_or("error frame missing 'error'")?;
            return Ok(Response::Error {
                id: v.get("id").and_then(|x| x.as_str()).map(|s| s.to_string()),
                code: get_str(e, "code")?,
                message: get_str(e, "message")?,
            });
        }
        match get_str(v, "op")?.as_str() {
            "get_kernel" => Ok(Response::Kernel(KernelReply::from_json(v)?)),
            "batch" => {
                let arr =
                    v.get("replies").and_then(|r| r.as_arr()).ok_or("batch missing 'replies'")?;
                let mut replies = Vec::with_capacity(arr.len());
                for entry in arr {
                    let reply = Response::from_json(entry)?;
                    if matches!(reply, Response::Batch { .. }) {
                        return Err("batch replies cannot nest".to_string());
                    }
                    replies.push(reply);
                }
                Ok(Response::Batch { id: get_str(v, "id")?, replies })
            }
            "stats" => Ok(Response::Stats(StatsReply::from_json(v)?)),
            "metrics" => Ok(Response::Metrics(MetricsReply::from_json(v)?)),
            "trace" => Ok(Response::Trace(TraceReply::from_json(v)?)),
            "health" => Ok(Response::Health(HealthReply::from_json(v)?)),
            "hello" => {
                Ok(Response::HelloAck { id: get_str(v, "id")?, wire: get_str(v, "wire")? })
            }
            "shutdown" => Ok(Response::ShutdownAck { id: get_str(v, "id")? }),
            other => Err(format!("unknown response op '{other}'")),
        }
    }
}

/// The wire-v2 binary framing: length-prefixed frames with
/// client-assigned reply tags, negotiated per connection by `hello`.
///
/// Frame layout (all integers little-endian):
///
/// ```text
/// [len: u32][tag: u64][kind: u8][payload: len-9 bytes]
/// ```
///
/// `len` counts every byte after the length field (tag + kind +
/// payload), so a reader needs 4 bytes to size the frame and `4+len`
/// to have it whole. `tag` is chosen by the client and echoed verbatim
/// on the reply — replies may arrive **out of order**, the tag is the
/// only correlation. Kinds:
///
/// * `0` — the payload is one line-JSON frame object (any op). Every
///   logical op rides on the binary wire this way; errors always come
///   back as kind-0 JSON error frames so new failure modes never need
///   new binary encodings.
/// * `1` — a binary `get_kernel` request (the hot op, parse-free):
///   workload family + dims as `u32`s, then length-prefixed optional
///   `gpu`/`mode` names. The request id is implied: `t{tag}`.
/// * `2` — a binary `get_kernel` reply (fixed layout, parse-free).
pub mod wire {
    use super::*;

    /// Payload is one line-JSON frame object (request or response).
    pub const KIND_JSON: u8 = 0;
    /// Payload is a binary `get_kernel` request.
    pub const KIND_GET_KERNEL: u8 = 1;
    /// Payload is a binary `get_kernel` reply.
    pub const KIND_KERNEL_REPLY: u8 = 2;

    /// Bytes of tag + kind — the minimum (and fixed) overhead `len`
    /// counts beyond the payload.
    pub const FRAME_OVERHEAD: usize = 8 + 1;

    /// Upper bound on `len`: a full `metrics` reply is ~100 KiB and a
    /// max batch a few MiB; anything beyond this is a desynced or
    /// hostile peer and the connection is dropped.
    pub const MAX_FRAME_LEN: u32 = 16 << 20;

    /// One decoded binary frame.
    #[derive(Debug, Clone, PartialEq, Eq)]
    pub struct Frame {
        pub tag: u64,
        pub kind: u8,
        pub payload: Vec<u8>,
    }

    impl Frame {
        pub fn json(tag: u64, obj: &Json) -> Frame {
            Frame { tag, kind: KIND_JSON, payload: obj.to_string().into_bytes() }
        }

        /// Append the encoded frame to `out` (a connection write
        /// buffer — no intermediate allocation).
        pub fn encode_into(&self, out: &mut Vec<u8>) {
            let len = (FRAME_OVERHEAD + self.payload.len()) as u32;
            out.extend_from_slice(&len.to_le_bytes());
            out.extend_from_slice(&self.tag.to_le_bytes());
            out.push(self.kind);
            out.extend_from_slice(&self.payload);
        }

        pub fn encode(&self) -> Vec<u8> {
            let mut out = Vec::with_capacity(4 + FRAME_OVERHEAD + self.payload.len());
            self.encode_into(&mut out);
            out
        }

        /// Decode one frame from the front of `buf`: `Ok(Some((frame,
        /// consumed)))` when a whole frame is buffered, `Ok(None)` when
        /// more bytes are needed, `Err` on a malformed length (the
        /// caller must drop the connection — framing is lost).
        pub fn decode(buf: &[u8]) -> Result<Option<(Frame, usize)>, String> {
            if buf.len() < 4 {
                return Ok(None);
            }
            let len = u32::from_le_bytes([buf[0], buf[1], buf[2], buf[3]]);
            if (len as usize) < FRAME_OVERHEAD {
                return Err(format!("binary frame length {len} shorter than its header"));
            }
            if len > MAX_FRAME_LEN {
                return Err(format!("binary frame length {len} exceeds {MAX_FRAME_LEN}"));
            }
            let total = 4 + len as usize;
            if buf.len() < total {
                return Ok(None);
            }
            let mut tag_bytes = [0u8; 8];
            tag_bytes.copy_from_slice(&buf[4..12]);
            Ok(Some((
                Frame {
                    tag: u64::from_le_bytes(tag_bytes),
                    kind: buf[12],
                    payload: buf[13..total].to_vec(),
                },
                total,
            )))
        }
    }

    /// The request id implied by a tagged binary frame (kinds 1/2
    /// carry no id bytes; JSON frames riding kind 0 keep their own).
    pub fn tag_id(tag: u64) -> String {
        format!("t{tag}")
    }

    fn push_u32(out: &mut Vec<u8>, x: usize) {
        out.extend_from_slice(&(x as u32).to_le_bytes());
    }

    fn push_name(out: &mut Vec<u8>, name: Option<&str>) {
        let bytes = name.unwrap_or("").as_bytes();
        out.push(bytes.len() as u8);
        out.extend_from_slice(bytes);
    }

    struct Cursor<'a> {
        buf: &'a [u8],
        at: usize,
    }

    impl<'a> Cursor<'a> {
        fn take(&mut self, n: usize) -> Result<&'a [u8], String> {
            let end = self.at.checked_add(n).filter(|&e| e <= self.buf.len());
            match end {
                Some(end) => {
                    let s = &self.buf[self.at..end];
                    self.at = end;
                    Ok(s)
                }
                None => Err("binary payload truncated".to_string()),
            }
        }

        fn u8(&mut self) -> Result<u8, String> {
            Ok(self.take(1)?[0])
        }

        fn u32(&mut self) -> Result<usize, String> {
            let s = self.take(4)?;
            Ok(u32::from_le_bytes([s[0], s[1], s[2], s[3]]) as usize)
        }

        fn f64(&mut self) -> Result<f64, String> {
            let s = self.take(8)?;
            let mut b = [0u8; 8];
            b.copy_from_slice(s);
            Ok(f64::from_le_bytes(b))
        }

        fn name(&mut self) -> Result<Option<String>, String> {
            let n = self.u8()? as usize;
            if n == 0 {
                return Ok(None);
            }
            let s = self.take(n)?;
            String::from_utf8(s.to_vec()).map(Some).map_err(|_| "bad name bytes".to_string())
        }
    }

    /// Encode a kind-1 `get_kernel` request payload.
    pub fn encode_get_kernel(
        workload: &Workload,
        gpu: Option<GpuArch>,
        mode: Option<SearchMode>,
    ) -> Vec<u8> {
        let mut out = Vec::with_capacity(48);
        match *workload {
            Workload::MatMul { batch, m, n, k } => {
                out.push(1);
                for d in [batch, m, n, k] {
                    push_u32(&mut out, d);
                }
            }
            Workload::MatVec { batch, n, k } => {
                out.push(2);
                for d in [batch, n, k] {
                    push_u32(&mut out, d);
                }
            }
            Workload::Conv2d { batch, h, w, cin, cout, ksize, stride, pad } => {
                out.push(3);
                for d in [batch, h, w, cin, cout, ksize, stride, pad] {
                    push_u32(&mut out, d);
                }
            }
        }
        push_name(&mut out, gpu.map(|g| g.name()));
        push_name(&mut out, mode.map(|m| m.name()));
        out
    }

    /// Decode a kind-1 `get_kernel` request payload.
    #[allow(clippy::type_complexity)]
    pub fn decode_get_kernel(
        payload: &[u8],
    ) -> Result<(Workload, Option<GpuArch>, Option<SearchMode>), String> {
        let mut c = Cursor { buf: payload, at: 0 };
        let workload = match c.u8()? {
            1 => Workload::MatMul { batch: c.u32()?, m: c.u32()?, n: c.u32()?, k: c.u32()? },
            2 => Workload::MatVec { batch: c.u32()?, n: c.u32()?, k: c.u32()? },
            3 => Workload::Conv2d {
                batch: c.u32()?,
                h: c.u32()?,
                w: c.u32()?,
                cin: c.u32()?,
                cout: c.u32()?,
                ksize: c.u32()?,
                stride: c.u32()?,
                pad: c.u32()?,
            },
            other => return Err(format!("unknown workload family byte {other}")),
        };
        let gpu = match c.name()? {
            None => None,
            Some(name) => {
                Some(GpuArch::parse(&name).ok_or_else(|| format!("unknown gpu '{name}'"))?)
            }
        };
        let mode = match c.name()? {
            None => None,
            Some(name) => {
                Some(SearchMode::parse(&name).ok_or_else(|| format!("unknown mode '{name}'"))?)
            }
        };
        Ok((workload, gpu, mode))
    }

    fn source_byte(s: ServeSource) -> u8 {
        match s {
            ServeSource::Store => 0,
            ServeSource::WarmGuess => 1,
            ServeSource::Fallback => 2,
        }
    }

    fn tier_byte(t: ServeTier) -> u8 {
        match t {
            ServeTier::Exact => 0,
            ServeTier::Warm => 1,
            ServeTier::Static => 2,
        }
    }

    /// Encode a kind-2 `get_kernel` reply payload (the id is implied
    /// by the frame tag).
    pub fn encode_kernel_reply(r: &KernelReply) -> Vec<u8> {
        let mut out = Vec::with_capacity(96);
        let flags = u8::from(r.hit) | (u8::from(r.enqueued) << 1);
        out.push(flags);
        out.push(source_byte(r.source));
        out.push(tier_byte(r.tier));
        let s = &r.schedule;
        for d in [
            s.threads_m,
            s.threads_n,
            s.reg_m,
            s.reg_n,
            s.tile_k,
            s.unroll_k,
            s.vector_width,
            s.split_k,
        ] {
            push_u32(&mut out, d);
        }
        out.push(u8::from(s.use_shared));
        for x in [r.latency_s, r.energy_j, r.avg_power_w] {
            out.extend_from_slice(&x.to_le_bytes());
        }
        push_u32(&mut out, r.queue_depth);
        out.extend_from_slice(&r.reply_time_s.to_le_bytes());
        out
    }

    /// Decode a kind-2 `get_kernel` reply payload.
    pub fn decode_kernel_reply(tag: u64, payload: &[u8]) -> Result<KernelReply, String> {
        let mut c = Cursor { buf: payload, at: 0 };
        let flags = c.u8()?;
        let source = match c.u8()? {
            0 => ServeSource::Store,
            1 => ServeSource::WarmGuess,
            2 => ServeSource::Fallback,
            other => return Err(format!("unknown source byte {other}")),
        };
        let tier = match c.u8()? {
            0 => ServeTier::Exact,
            1 => ServeTier::Warm,
            2 => ServeTier::Static,
            other => return Err(format!("unknown tier byte {other}")),
        };
        let schedule = Schedule {
            threads_m: c.u32()?,
            threads_n: c.u32()?,
            reg_m: c.u32()?,
            reg_n: c.u32()?,
            tile_k: c.u32()?,
            unroll_k: c.u32()?,
            vector_width: c.u32()?,
            split_k: c.u32()?,
            use_shared: c.u8()? != 0,
        };
        Ok(KernelReply {
            id: tag_id(tag),
            hit: flags & 1 != 0,
            source,
            tier,
            schedule,
            latency_s: c.f64()?,
            energy_j: c.f64()?,
            avg_power_w: c.f64()?,
            enqueued: flags & 2 != 0,
            queue_depth: c.u32()?,
            reply_time_s: c.f64()?,
        })
    }
}

fn get_f64(v: &Json, key: &str) -> Result<f64, String> {
    v.get(key).and_then(|x| x.as_f64()).ok_or_else(|| format!("missing/bad field '{key}'"))
}

fn get_str(v: &Json, key: &str) -> Result<String, String> {
    Ok(v.get(key)
        .and_then(|x| x.as_str())
        .ok_or_else(|| format!("missing/bad field '{key}'"))?
        .to_string())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{GpuArch, GpuSpec, SearchMode};
    use crate::schedule::space::ScheduleSpace;

    fn sample_schedule() -> Schedule {
        let spec: GpuSpec = GpuArch::A100.spec();
        ScheduleSpace::new(suites::MM1, &spec).fallback()
    }

    #[test]
    fn request_roundtrip_all_ops() {
        let reqs = [
            Request::GetKernel {
                id: "c1".into(),
                workload: suites::MM1,
                gpu: Some(GpuArch::A100),
                mode: Some(SearchMode::EnergyAware),
                trace: Some("deadbeefcafef00d".into()),
            },
            Request::GetKernel {
                id: "c2".into(),
                workload: suites::CONV2,
                gpu: None,
                mode: None,
                trace: None,
            },
            Request::Stats { id: "c3".into() },
            Request::Metrics { id: "c5".into() },
            Request::Traces { id: "c6".into(), slowest: 5 },
            Request::Traces { id: "c7".into(), slowest: 0 },
            Request::Health { id: "c8".into() },
            Request::Shutdown { id: "c4".into() },
        ];
        for req in reqs {
            let line = req.to_json().to_string();
            assert_eq!(Request::parse_line(&line), Ok(req), "{line}");
        }
    }

    #[test]
    fn workload_accepts_suite_name_or_object() {
        let by_name = r#"{"v":1,"op":"get_kernel","id":"x","workload":"mv3"}"#;
        match Request::parse_line(by_name).unwrap() {
            Request::GetKernel { workload, .. } => assert_eq!(workload, suites::MV3),
            other => panic!("{other:?}"),
        }
        let by_obj = format!(
            r#"{{"v":1,"op":"get_kernel","id":"x","workload":{}}}"#,
            workload_to_json(&suites::CONV1).to_string()
        );
        match Request::parse_line(&by_obj).unwrap() {
            Request::GetKernel { workload, .. } => assert_eq!(workload, suites::CONV1),
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn malformed_frames_are_bad_requests() {
        for line in [
            "{not json",
            r#"{"op":"get_kernel","id":"x","workload":"MM1"}"#, // no version
            r#"{"v":1,"op":"get_kernel","workload":"MM1"}"#,    // no id
            r#"{"v":1,"op":"frobnicate","id":"x"}"#,            // unknown op
            r#"{"v":1,"op":"get_kernel","id":"x","workload":"MM1","gpu":"tpu"}"#,
        ] {
            let rej = Request::parse_line(line).unwrap_err();
            assert_eq!(rej.code, error_code::BAD_REQUEST, "{line}");
            let frame = rej.to_json();
            assert_eq!(frame.get("ok").and_then(|b| b.as_bool()), Some(false));
        }
    }

    #[test]
    fn version_mismatch_is_its_own_code_and_echoes_id() {
        let rej = Request::parse_line(r#"{"v":99,"op":"stats","id":"c9"}"#).unwrap_err();
        assert_eq!(rej.code, error_code::VERSION_MISMATCH);
        assert_eq!(rej.id.as_deref(), Some("c9"));
        let frame = rej.to_json();
        assert_eq!(frame.get("id").and_then(|x| x.as_str()), Some("c9"));
    }

    /// A `trace` value that is not valid hex is the client's bug: the
    /// daemon must answer `bad_request` naming the field, never
    /// silently re-mint the id (which would orphan the client's
    /// correlation). Valid short hex like `"a3f9"` stays accepted.
    #[test]
    fn bad_trace_hex_is_rejected_naming_the_field() {
        for line in [
            r#"{"v":1,"op":"get_kernel","id":"x","workload":"MM1","trace":"nothex!"}"#,
            r#"{"v":1,"op":"get_kernel","id":"x","workload":"MM1","trace":""}"#,
            r#"{"v":1,"op":"get_kernel","id":"x","workload":"MM1","trace":"0123456789abcdef0"}"#,
            r#"{"v":1,"op":"get_kernel","id":"x","workload":"MM1","trace":7}"#,
        ] {
            let rej = Request::parse_line(line).unwrap_err();
            assert_eq!(rej.code, error_code::BAD_REQUEST, "{line}");
            assert_eq!(rej.id.as_deref(), Some("x"), "{line}");
            assert!(rej.message.contains("trace"), "{line}: {}", rej.message);
        }
        let ok = r#"{"v":1,"op":"get_kernel","id":"x","workload":"MM1","trace":"a3f9"}"#;
        assert!(Request::parse_line(ok).is_ok());
    }

    #[test]
    fn unknown_workload_code() {
        let rej =
            Request::parse_line(r#"{"v":1,"op":"get_kernel","id":"x","workload":"MM99"}"#)
                .unwrap_err();
        assert_eq!(rej.code, error_code::UNKNOWN_WORKLOAD);
    }

    #[test]
    fn kernel_reply_roundtrip() {
        for (hit, source, tier) in [
            (true, ServeSource::Store, ServeTier::Exact),
            (false, ServeSource::WarmGuess, ServeTier::Warm),
            (false, ServeSource::Fallback, ServeTier::Static),
        ] {
            let reply = KernelReply {
                id: "c1".into(),
                hit,
                source,
                tier,
                schedule: sample_schedule(),
                latency_s: 1.5e-3,
                energy_j: 2.5e-3,
                avg_power_w: 123.0,
                enqueued: false,
                queue_depth: 2,
                reply_time_s: 6.4e-5,
            };
            let line = reply.to_json().to_string();
            match Response::parse_line(&line).unwrap() {
                Response::Kernel(back) => assert_eq!(back, reply),
                other => panic!("{other:?}"),
            }
        }
    }

    #[test]
    fn pre_tier_kernel_reply_derives_tier_from_source() {
        // A frame from a pre-tier daemon carries no 'tier' field: the
        // parse derives it from the source 1:1.
        for (source, want) in [
            (ServeSource::Store, ServeTier::Exact),
            (ServeSource::WarmGuess, ServeTier::Warm),
            (ServeSource::Fallback, ServeTier::Static),
        ] {
            let reply = KernelReply {
                id: "c1".into(),
                hit: source == ServeSource::Store,
                source,
                tier: want,
                schedule: sample_schedule(),
                latency_s: 0.0,
                energy_j: 0.0,
                avg_power_w: 0.0,
                enqueued: false,
                queue_depth: 0,
                reply_time_s: 0.0,
            };
            let mut v = reply.to_json();
            if let Json::Obj(m) = &mut v {
                m.remove("tier");
            }
            let back = KernelReply::from_json(&v).unwrap();
            assert_eq!(back.tier, want, "{source:?}");
            assert_eq!(back, reply);
        }
    }

    #[test]
    fn stats_reply_roundtrip() {
        let reply = StatsReply {
            id: "c2".into(),
            n_requests: 10,
            n_hits: 7,
            n_misses: 3,
            n_enqueued: 3,
            n_searches_done: 2,
            n_evicted_records: 1,
            queue_depth: 1,
            n_records: 9,
            n_shards: 8,
            hit_rate: 0.7,
            p50_reply_s: 5e-5,
            p99_reply_s: 2.1e-3,
            measurements_paid: 140,
            n_shed: 4,
            n_fleet_coalesced: 2,
            n_static_tier: 1,
            backlog_len: 3,
            pending_keys: 5,
            n_writebacks_fenced: 1,
            n_writebacks_dropped: 2,
            n_batch_frames: 3,
            n_batch_requests: 17,
            n_notify_refresh: 6,
            n_poll_refresh: 1,
            uptime_s: 12.5,
            build_info: "ecokernel 0.1.0 (abc1234)".into(),
            shard_records: vec![2, 0, 4, 3],
            heat_histogram: vec![1, 0, 2, 0, 0, 0, 0, 1],
        };
        let line = reply.to_json().to_string();
        match Response::parse_line(&line).unwrap() {
            Response::Stats(back) => assert_eq!(back, reply),
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn stats_reply_tolerates_missing_fleet_fields() {
        // A frame from a pre-fleet daemon: no shed/backlog/shard data.
        let line = r#"{"v":1,"id":"c3","ok":true,"op":"stats","stats":{
            "n_requests":1,"n_hits":1,"n_misses":0,"n_enqueued":0,"n_searches_done":0,
            "n_evicted_records":0,"queue_depth":0,"n_records":1,"n_shards":2,
            "hit_rate":1.0,"p50_reply_s":1e-5,"p99_reply_s":1e-5,"measurements_paid":0}}"#
            .replace('\n', "");
        match Response::parse_line(&line).unwrap() {
            Response::Stats(back) => {
                assert_eq!(back.n_requests, 1);
                assert_eq!(back.n_shed, 0);
                assert_eq!(back.n_static_tier, 0);
                assert_eq!(back.backlog_len, 0);
                assert_eq!(back.pending_keys, 0);
                assert_eq!(back.n_writebacks_fenced, 0);
                assert_eq!(back.n_writebacks_dropped, 0);
                assert_eq!(back.n_batch_frames, 0);
                assert_eq!(back.n_batch_requests, 0);
                assert_eq!(back.n_notify_refresh, 0);
                assert_eq!(back.n_poll_refresh, 0);
                assert_eq!(back.uptime_s, 0.0);
                assert_eq!(back.build_info, "");
                assert!(back.shard_records.is_empty());
                assert!(back.heat_histogram.is_empty());
            }
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn batch_request_roundtrip() {
        let req = Request::Batch {
            id: "b1".into(),
            items: vec![
                Ok(BatchItem {
                    id: "b1.0".into(),
                    workload: suites::MM1,
                    gpu: Some(GpuArch::A100),
                    mode: Some(SearchMode::EnergyAware),
                }),
                Ok(BatchItem { id: "b1.1".into(), workload: suites::MV3, gpu: None, mode: None }),
            ],
        };
        let line = req.to_json().to_string();
        assert_eq!(Request::parse_line(&line), Ok(req), "{line}");
    }

    #[test]
    fn batch_entries_default_positional_ids_and_reject_positionally() {
        // Entry 0 is fine, entry 1 is an unknown workload, entry 2 is
        // an unknown gpu: the good entry parses and each bad one
        // carries its own positional reject — the batch never fails
        // whole.
        let line = r#"{"v":1,"op":"batch","id":"b7","requests":[
            {"workload":"mm1"},
            {"workload":"MM99"},
            {"id":"mine","workload":"MM2","gpu":"tpu"}]}"#
            .replace('\n', "");
        match Request::parse_line(&line).unwrap() {
            Request::Batch { id, items } => {
                assert_eq!(id, "b7");
                assert_eq!(items.len(), 3);
                let ok = items[0].as_ref().unwrap();
                assert_eq!(ok.id, "b7.0", "missing entry ids default positionally");
                assert_eq!(ok.workload, suites::MM1);
                let rej = items[1].as_ref().unwrap_err();
                assert_eq!(rej.code, error_code::UNKNOWN_WORKLOAD);
                assert_eq!(rej.id.as_deref(), Some("b7.1"));
                let rej = items[2].as_ref().unwrap_err();
                assert_eq!(rej.code, error_code::BAD_REQUEST);
                assert_eq!(rej.id.as_deref(), Some("mine"), "explicit entry id echoed");
            }
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn batch_frame_level_errors() {
        for (line, needle) in [
            (r#"{"v":1,"op":"batch","id":"b1"}"#.to_string(), "requests"),
            (r#"{"v":1,"op":"batch","id":"b1","requests":[]}"#.to_string(), "empty"),
            (
                format!(
                    r#"{{"v":1,"op":"batch","id":"b1","requests":[{}]}}"#,
                    vec![r#"{"workload":"MM1"}"#; MAX_BATCH_ITEMS + 1].join(",")
                ),
                "cap",
            ),
        ] {
            let rej = Request::parse_line(&line).unwrap_err();
            assert_eq!(rej.code, error_code::BAD_REQUEST, "{needle}");
            assert!(rej.message.contains(needle), "{}: {}", needle, rej.message);
        }
        // Non-get_kernel ops cannot hide inside a batch.
        let parsed = Request::parse_line(
            r#"{"v":1,"op":"batch","id":"b1","requests":[{"op":"shutdown"}]}"#,
        )
        .unwrap();
        match parsed {
            Request::Batch { items, .. } => {
                assert_eq!(items[0].as_ref().unwrap_err().code, error_code::BAD_REQUEST);
            }
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn batch_reply_roundtrip() {
        let reply = Response::Batch {
            id: "b2".into(),
            replies: vec![
                Response::Kernel(KernelReply {
                    id: "b2.0".into(),
                    hit: true,
                    source: ServeSource::Store,
                    tier: ServeTier::Exact,
                    schedule: sample_schedule(),
                    latency_s: 1e-3,
                    energy_j: 2e-3,
                    avg_power_w: 120.0,
                    enqueued: false,
                    queue_depth: 0,
                    reply_time_s: 5e-5,
                }),
                Response::Error {
                    id: Some("b2.1".into()),
                    code: error_code::UNKNOWN_WORKLOAD.into(),
                    message: "nope".into(),
                },
            ],
        };
        let line = reply.to_json().to_string();
        assert_eq!(Response::parse_line(&line), Ok(reply), "{line}");
        // Nested batches are rejected rather than parsed.
        let nested = r#"{"v":1,"id":"o","ok":true,"op":"batch","replies":[
            {"v":1,"id":"i","ok":true,"op":"batch","replies":[]}]}"#
            .replace('\n', "");
        assert!(Response::parse_line(&nested).unwrap_err().contains("nest"));
    }

    /// The single-frame wire format is frozen: batching added NEW
    /// frames, it must not disturb the bytes of a plain `get_kernel`
    /// reply. Frames serialize with a deterministic (sorted) key
    /// order, so pinning the exact top-level key SET pins the bytes
    /// for given values — a field added, renamed, or dropped breaks
    /// this test before it breaks an old client.
    #[test]
    fn single_kernel_reply_wire_fields_are_pinned() {
        let reply = KernelReply {
            id: "pin".into(),
            hit: true,
            source: ServeSource::Store,
            tier: ServeTier::Exact,
            schedule: sample_schedule(),
            latency_s: 1e-3,
            energy_j: 2e-3,
            avg_power_w: 120.0,
            enqueued: false,
            queue_depth: 0,
            reply_time_s: 5e-5,
        };
        let line = reply.to_json().to_string();
        // Exactly the PR-4 field set plus the ISSUE-9 'tier' field,
        // nothing else added or dropped.
        let parsed = Json::parse(&line).unwrap();
        let keys: Vec<&str> = match &parsed {
            Json::Obj(m) => m.keys().map(|k| k.as_str()).collect(),
            other => panic!("{other:?}"),
        };
        assert_eq!(
            keys,
            vec![
                "avg_power_w", "energy_j", "enqueued", "id", "latency_s", "ok", "op",
                "queue_depth", "reply_time_s", "result", "schedule", "source", "tier", "v",
                "variant_id",
            ],
            "{line}"
        );
        // Serialization is canonical: encode → parse → encode is the
        // identity, and repeated encodes are byte-identical.
        assert_eq!(parsed.to_string(), line);
        assert_eq!(reply.to_json().to_string(), line);
    }

    /// The `stats` payload schema is byte-pinned the same way the
    /// kernel reply is: deterministic sorted-key serialization means
    /// pinning the exact key set (top level and inside `"stats"`) pins
    /// the bytes for given values. New telemetry lives in the
    /// `metrics` op — a field slipping into `stats` breaks this test
    /// before it breaks an old client.
    #[test]
    fn stats_reply_wire_fields_are_pinned() {
        let reply = full_stats_reply();
        let line = reply.to_json().to_string();
        let parsed = Json::parse(&line).unwrap();
        let top: Vec<&str> = match &parsed {
            Json::Obj(m) => m.keys().map(|k| k.as_str()).collect(),
            other => panic!("{other:?}"),
        };
        assert_eq!(top, vec!["id", "ok", "op", "stats", "v"], "{line}");
        let inner: Vec<&str> = match parsed.get("stats") {
            Some(Json::Obj(m)) => m.keys().map(|k| k.as_str()).collect(),
            other => panic!("{other:?}"),
        };
        assert_eq!(
            inner,
            vec![
                "backlog_len",
                "build_info",
                "heat_histogram",
                "hit_rate",
                "measurements_paid",
                "n_batch_frames",
                "n_batch_requests",
                "n_enqueued",
                "n_evicted_records",
                "n_fleet_coalesced",
                "n_hits",
                "n_misses",
                "n_notify_refresh",
                "n_poll_refresh",
                "n_records",
                "n_requests",
                "n_searches_done",
                "n_shards",
                "n_shed",
                "n_static_tier",
                "n_writebacks_dropped",
                "n_writebacks_fenced",
                "p50_reply_s",
                "p99_reply_s",
                "pending_keys",
                "queue_depth",
                "shard_records",
                "uptime_s",
            ],
            "{line}"
        );
        // Canonical serialization: encode → parse → encode is identity.
        assert_eq!(parsed.to_string(), line);
        assert_eq!(reply.to_json().to_string(), line);
    }

    fn full_stats_reply() -> StatsReply {
        StatsReply {
            id: "pin".into(),
            n_requests: 10,
            n_hits: 7,
            n_misses: 3,
            n_enqueued: 3,
            n_searches_done: 2,
            n_evicted_records: 1,
            queue_depth: 1,
            n_records: 9,
            n_shards: 8,
            hit_rate: 0.7,
            p50_reply_s: 5e-5,
            p99_reply_s: 2.1e-3,
            measurements_paid: 140,
            n_shed: 4,
            n_fleet_coalesced: 2,
            n_static_tier: 1,
            backlog_len: 3,
            pending_keys: 5,
            n_writebacks_fenced: 1,
            n_writebacks_dropped: 2,
            n_batch_frames: 3,
            n_batch_requests: 17,
            n_notify_refresh: 6,
            n_poll_refresh: 1,
            uptime_s: 42.0,
            build_info: "ecokernel 0.1.0".into(),
            shard_records: vec![2, 0, 4, 3],
            heat_histogram: vec![1, 0, 2],
        }
    }

    /// Absent-field = 0 across ALL frame generations: gen-1 (pre-fleet,
    /// covered above), gen-2 (fleet counters but no batch/notify
    /// fields), and gen-3 (current, covered by the roundtrip). Each
    /// older frame must parse with its era's fields intact and every
    /// later field zero/empty.
    #[test]
    fn stats_reply_back_compat_across_frame_generations() {
        // Gen-2: a PR-3/PR-4-era daemon — shed/backlog/fence/shard
        // data, but nothing from the batching or notify eras.
        let line = r#"{"v":1,"id":"g2","ok":true,"op":"stats","stats":{
            "n_requests":8,"n_hits":5,"n_misses":3,"n_enqueued":3,"n_searches_done":2,
            "n_evicted_records":0,"queue_depth":1,"n_records":5,"n_shards":4,
            "hit_rate":0.625,"p50_reply_s":6e-5,"p99_reply_s":2.2e-3,"measurements_paid":90,
            "n_shed":1,"n_fleet_coalesced":1,"backlog_len":0,"pending_keys":2,
            "n_writebacks_fenced":1,"n_writebacks_dropped":0,
            "shard_records":[2,1,1,1],"heat_histogram":[3,1]}}"#
            .replace('\n', "");
        match Response::parse_line(&line).unwrap() {
            Response::Stats(back) => {
                assert_eq!(back.n_requests, 8);
                assert_eq!(back.n_shed, 1, "gen-2 fields parse");
                assert_eq!(back.n_writebacks_fenced, 1);
                assert_eq!(back.shard_records, vec![2, 1, 1, 1]);
                assert_eq!(back.n_batch_frames, 0, "gen-3 fields default to 0");
                assert_eq!(back.n_batch_requests, 0);
                assert_eq!(back.n_notify_refresh, 0);
                assert_eq!(back.n_poll_refresh, 0);
                assert_eq!(back.n_static_tier, 0, "ISSUE-9 field defaults to 0");
                assert_eq!(back.uptime_s, 0.0, "gen-4 fields default too");
                assert_eq!(back.build_info, "");
            }
            other => panic!("{other:?}"),
        }
    }

    fn sample_metrics_reply(id: &str, seed: &[f64]) -> MetricsReply {
        let mut reply_sim_s = LogHistogram::new();
        let mut reply_wall_s = LogHistogram::new();
        let mut parse = LogHistogram::new();
        let mut snr = LogHistogram::new();
        let mut k = LogHistogram::new();
        let mut energy = EnergyLedger::new();
        for &v in seed {
            reply_sim_s.record(v);
            reply_wall_s.record(v * 0.5);
            parse.record(v * 0.1);
            snr.record(v * 1e5);
            k.record(0.5);
            energy.record_saved(0, 0, v * 100.0);
            energy.record_paid(0, 1, v * 200.0);
        }
        MetricsReply {
            id: id.into(),
            counters: [
                ("n_requests".to_string(), seed.len() as u64),
                ("n_hits".to_string(), seed.len() as u64 / 2),
                ("n_batch_frames".to_string(), 2),
                ("n_batch_requests".to_string(), 16),
            ]
            .into_iter()
            .collect(),
            reply_sim_s,
            reply_wall_s,
            stages: [("parse".to_string(), parse)].into_iter().collect(),
            model: [
                ("model_snr_db/steady".to_string(), snr),
                ("model_dynamic_k/steady".to_string(), k),
            ]
            .into_iter()
            .collect(),
            energy,
        }
    }

    #[test]
    fn metrics_reply_roundtrip() {
        let reply = sample_metrics_reply("m1", &[5e-5, 7e-5, 2.1e-3, 9e-4]);
        let line = reply.to_json().to_string();
        match Response::parse_line(&line).unwrap() {
            Response::Metrics(back) => assert_eq!(back, reply),
            other => panic!("{other:?}"),
        }
        // The payload carries its own version...
        let v = Json::parse(&line).unwrap();
        assert_eq!(v.get("metrics_v").and_then(Json::as_f64), Some(1.0));
        // ...and a payload newer than the client is refused.
        let newer = line.replace(r#""metrics_v":1"#, r#""metrics_v":2"#);
        assert!(Response::parse_line(&newer).unwrap_err().contains("metrics payload"));
    }

    /// The fleet property the merge client relies on: merging two
    /// daemons' metrics equals the metrics of one daemon that served
    /// both sample streams.
    #[test]
    fn metrics_merge_equals_union_and_commutes() {
        let a_samples = [5e-5, 6e-5, 2.1e-3];
        let b_samples = [7e-5, 9e-4];
        let union: Vec<f64> = a_samples.iter().chain(&b_samples).copied().collect();
        let a = sample_metrics_reply("a", &a_samples);
        let b = sample_metrics_reply("b", &b_samples);
        let expect = sample_metrics_reply("a", &union);

        let mut ab = a.clone();
        ab.merge(&b);
        assert_eq!(ab.reply_sim_s, expect.reply_sim_s);
        assert_eq!(ab.reply_wall_s, expect.reply_wall_s);
        assert_eq!(ab.stages, expect.stages);
        assert_eq!(ab.model, expect.model, "model families merge per key");
        assert_eq!(ab.energy, expect.energy, "ledger merge equals the union ledger");
        assert_eq!(ab.counter("n_requests"), 5);
        assert_eq!(ab.counter("n_batch_frames"), 4);
        assert_eq!(ab.frames_per_syscall(), 8.0);

        let mut ba = b;
        ba.merge(&a);
        assert_eq!(ba.reply_sim_s, ab.reply_sim_s, "merge commutes");
        assert_eq!(ba.counters, ab.counters);
    }

    #[test]
    fn metrics_prometheus_exposition() {
        let reply = sample_metrics_reply("m2", &[5e-5, 7e-5, 2.1e-3]);
        let prom = reply.to_prometheus();
        assert!(prom.contains("# TYPE ecokernel_requests_total counter"), "{prom}");
        assert!(prom.contains("ecokernel_requests_total 3"), "{prom}");
        assert!(prom.contains("# TYPE ecokernel_reply_wall_seconds histogram"), "{prom}");
        assert!(prom.contains("ecokernel_reply_sim_seconds_bucket{le=\"+Inf\"} 3"), "{prom}");
        assert!(prom.contains("ecokernel_reply_sim_seconds_count 3"), "{prom}");
        assert!(prom.contains("ecokernel_stage_seconds_bucket{stage=\"parse\",le="), "{prom}");
        assert!(prom.contains("ecokernel_stage_seconds_count{stage=\"parse\"} 3"), "{prom}");
        // Cumulative bucket counts are monotone non-decreasing.
        let mut last = 0u64;
        for line in prom.lines().filter(|l| l.starts_with("ecokernel_reply_sim_seconds_bucket")) {
            let n: u64 = line.rsplit(' ').next().unwrap().parse().unwrap();
            assert!(n >= last, "{line}");
            last = n;
        }
        assert_eq!(last, 3);
    }

    #[test]
    fn error_frames_parse_as_errors() {
        let rej = Reject::new(Some("c7".into()), error_code::INTERNAL, "boom");
        match Response::parse_line(&rej.to_json().to_string()).unwrap() {
            Response::Error { id, code, message } => {
                assert_eq!(id.as_deref(), Some("c7"));
                assert_eq!(code, error_code::INTERNAL);
                assert_eq!(message, "boom");
            }
            other => panic!("{other:?}"),
        }
    }

    /// GOLDEN exposition: one counter, one single-sample histogram
    /// (0.5 s lands in the 2^-1..2^0 bucket, so exactly one finite
    /// `le` line survives head+tail elision), empty histograms, and a
    /// model regime whose label value needs every escape the text
    /// format defines. Pinned line-for-line: any drift in escaping,
    /// elision, or family ordering breaks here before it breaks a
    /// scraper.
    #[test]
    fn prometheus_exposition_is_golden() {
        let mut h = LogHistogram::new();
        h.record(0.5);
        let reply = MetricsReply {
            id: "g".into(),
            counters: [("n_requests".to_string(), 7)].into_iter().collect(),
            reply_sim_s: h.clone(),
            reply_wall_s: LogHistogram::new(),
            stages: BTreeMap::new(),
            model: [("model_snr_db/we\"ird\\regime\n".to_string(), h)].into_iter().collect(),
            energy: EnergyLedger::new(),
        };
        let expect = concat!(
            "# TYPE ecokernel_requests_total counter\n",
            "ecokernel_requests_total 7\n",
            "# TYPE ecokernel_reply_sim_seconds histogram\n",
            "ecokernel_reply_sim_seconds_bucket{le=\"1e0\"} 1\n",
            "ecokernel_reply_sim_seconds_bucket{le=\"+Inf\"} 1\n",
            "ecokernel_reply_sim_seconds_sum 0.5\n",
            "ecokernel_reply_sim_seconds_count 1\n",
            "# TYPE ecokernel_reply_wall_seconds histogram\n",
            "ecokernel_reply_wall_seconds_bucket{le=\"+Inf\"} 0\n",
            "ecokernel_reply_wall_seconds_sum 0\n",
            "ecokernel_reply_wall_seconds_count 0\n",
            "# TYPE ecokernel_stage_seconds histogram\n",
            "# TYPE ecokernel_model_snr_db histogram\n",
            "ecokernel_model_snr_db_bucket{regime=\"we\\\"ird\\\\regime\\n\",le=\"1e0\"} 1\n",
            "ecokernel_model_snr_db_bucket{regime=\"we\\\"ird\\\\regime\\n\",le=\"+Inf\"} 1\n",
            "ecokernel_model_snr_db_sum{regime=\"we\\\"ird\\\\regime\\n\"} 0.5\n",
            "ecokernel_model_snr_db_count{regime=\"we\\\"ird\\\\regime\\n\"} 1\n",
        );
        assert_eq!(reply.to_prometheus(), expect);
    }

    /// Model families share one `# TYPE` line across regimes, and the
    /// fleet-merged view exposes per-regime model histograms — the
    /// ISSUE 7 acceptance shape.
    #[test]
    fn prometheus_model_families_are_labelled_per_regime() {
        let mut a = sample_metrics_reply("a", &[5e-5, 2.1e-3]);
        let mut round0 = LogHistogram::new();
        round0.record(9.0);
        a.model.insert("model_snr_db/round0".to_string(), round0);
        let b = sample_metrics_reply("b", &[7e-5]);
        a.merge(&b);
        let prom = a.to_prometheus();
        assert_eq!(prom.matches("# TYPE ecokernel_model_snr_db histogram").count(), 1, "{prom}");
        assert!(prom.contains("ecokernel_model_snr_db_bucket{regime=\"round0\",le="), "{prom}");
        assert!(prom.contains("ecokernel_model_snr_db_count{regime=\"steady\"} 3"), "{prom}");
        assert!(prom.contains("ecokernel_model_dynamic_k_count{regime=\"steady\"} 3"), "{prom}");
        assert!(prom.contains("# TYPE ecokernel_model_dynamic_k histogram"), "{prom}");
    }

    /// The energy ledger exposes as two-label counter families, one
    /// `# TYPE` line per family, gpu-major cell order — and an empty
    /// ledger emits NOTHING (pinned by the golden test above, whose
    /// ledger is empty).
    #[test]
    fn prometheus_energy_ledger_lines_are_exact() {
        let mut energy = EnergyLedger::new();
        energy.record_saved(0, 0, 2.5);
        energy.record_paid(0, 0, 1.0);
        energy.record_saved(3, 1, 0.25);
        let reply = MetricsReply {
            id: "e".into(),
            counters: BTreeMap::new(),
            reply_sim_s: LogHistogram::new(),
            reply_wall_s: LogHistogram::new(),
            stages: BTreeMap::new(),
            model: BTreeMap::new(),
            energy,
        };
        let prom = reply.to_prometheus();
        let expect_head = concat!(
            "# TYPE ecokernel_energy_saved_joules_total counter\n",
            "ecokernel_energy_saved_joules_total{gpu=\"a100\",family=\"mm\"} 2.5\n",
            "ecokernel_energy_saved_joules_total{gpu=\"v100\",family=\"mv\"} 0.25\n",
            "# TYPE ecokernel_energy_paid_joules_total counter\n",
            "ecokernel_energy_paid_joules_total{gpu=\"a100\",family=\"mm\"} 1\n",
            "ecokernel_energy_paid_joules_total{gpu=\"v100\",family=\"mv\"} 0\n",
        );
        assert!(prom.starts_with(expect_head), "{prom}");
    }

    #[test]
    fn metrics_reply_tolerates_an_absent_energy_field() {
        // A pre-ledger daemon's frame: no `energy` key at all.
        let line = r#"{"v":1,"id":"m9","ok":true,"op":"metrics","metrics_v":1,
            "counters":{"n_requests":1}}"#
            .replace('\n', "");
        match Response::parse_line(&line).unwrap() {
            Response::Metrics(back) => assert!(back.energy.is_empty()),
            other => panic!("{other:?}"),
        }
    }

    fn sample_health_reply(id: &str, status: HealthStatus) -> HealthReply {
        HealthReply {
            id: id.into(),
            status,
            targets: vec![
                HealthTarget {
                    name: "p99_reply_wall_s".into(),
                    status,
                    reason: if status == HealthStatus::Ok {
                        "within target".into()
                    } else {
                        "fast and slow windows past 0.25s".into()
                    },
                    value: 0.12,
                    fast_value: 0.30,
                    threshold: 0.25,
                },
                HealthTarget {
                    name: "backlog".into(),
                    status: HealthStatus::Ok,
                    reason: "depth 0 of 16".into(),
                    value: 0.0,
                    fast_value: 0.0,
                    threshold: 16.0,
                },
            ],
            drift: DriftHealth {
                n_drift_researches: 2,
                relerr_steady_mean: 0.4,
                relerr_fast_mean: 0.6,
                budget: 2,
                drifting: true,
            },
        }
    }

    #[test]
    fn health_reply_roundtrip_and_version_gate() {
        let reply = sample_health_reply("h1", HealthStatus::Warn);
        let line = reply.to_json().to_string();
        match Response::parse_line(&line).unwrap() {
            Response::Health(back) => assert_eq!(back, reply),
            other => panic!("{other:?}"),
        }
        let v = Json::parse(&line).unwrap();
        assert_eq!(v.get("health_v").and_then(Json::as_f64), Some(1.0));
        let newer = line.replace(r#""health_v":1"#, r#""health_v":2"#);
        assert!(Response::parse_line(&newer).unwrap_err().contains("health payload"));
        // An empty-target reply is well-formed; absent drift defaults.
        let bare = r#"{"v":1,"id":"h2","ok":true,"op":"health","status":"ok"}"#;
        match Response::parse_line(bare).unwrap() {
            Response::Health(back) => {
                assert_eq!(back.status, HealthStatus::Ok);
                assert!(back.targets.is_empty());
                assert_eq!(back.drift, DriftHealth::default());
            }
            other => panic!("{other:?}"),
        }
    }

    /// The fleet merge is worst-of per target: a critical member makes
    /// the fleet critical and its reason survives; targets only one
    /// side reports are kept (partial merges over dead daemons).
    #[test]
    fn health_merge_takes_the_worst_per_target() {
        let a = sample_health_reply("a", HealthStatus::Ok);
        let mut b = sample_health_reply("b", HealthStatus::Critical);
        b.targets[0].reason = "fast and slow windows past 0.25s".into();
        b.targets.push(HealthTarget {
            name: "hit_rate".into(),
            status: HealthStatus::Warn,
            reason: "fast window under floor".into(),
            value: 0.9,
            fast_value: 0.4,
            threshold: 0.5,
        });
        let mut ab = a.clone();
        ab.merge_worst(&b);
        assert_eq!(ab.status, HealthStatus::Critical);
        let p99 = ab.targets.iter().find(|t| t.name == "p99_reply_wall_s").unwrap();
        assert_eq!(p99.status, HealthStatus::Critical);
        assert!(p99.reason.contains("past"));
        assert!(ab.targets.iter().any(|t| t.name == "hit_rate"), "one-sided targets survive");
        assert_eq!(ab.drift.n_drift_researches, 4, "drift counters sum");
        assert!(ab.drift.drifting);
        // Merge is symmetric on the verdicts.
        b.merge_worst(&a);
        assert_eq!(b.status, HealthStatus::Critical);
        assert_eq!(
            b.targets.iter().find(|t| t.name == "backlog").unwrap().status,
            HealthStatus::Ok
        );
    }

    #[test]
    fn trace_reply_roundtrip_and_version_gate() {
        use crate::telemetry::{Span, Trace, TraceId};
        let mut span = Span::new("search_round", 0.2, 1.5);
        span.round = Some(1);
        span.snr_db = Some(14.0);
        span.k = Some(0.5);
        span.n_measured = Some(8);
        span.relerr = Some(0.2);
        let trace = Trace {
            id: TraceId::from_hex("deadbeefcafef00d").unwrap(),
            key: "mm1|a100|energy_aware|fp".into(),
            req: "c9".into(),
            start_unix_s: 1700000000.25,
            total_s: 1.7,
            error: false,
            complete: true,
            remote: false,
            spans: vec![Span::new("claim_io", 0.0, 0.01), span],
        };
        let reply = TraceReply { id: "t1".into(), traces: vec![trace] };
        let line = reply.to_json().to_string();
        match Response::parse_line(&line).unwrap() {
            Response::Trace(back) => assert_eq!(back, reply),
            other => panic!("{other:?}"),
        }
        let v = Json::parse(&line).unwrap();
        assert_eq!(v.get("trace_v").and_then(Json::as_f64), Some(1.0));
        let newer = line.replace(r#""trace_v":1"#, r#""trace_v":2"#);
        assert!(Response::parse_line(&newer).unwrap_err().contains("trace payload"));
        // An empty ring answers an empty-but-well-formed reply.
        let empty = TraceReply { id: "t2".into(), traces: vec![] };
        let line = empty.to_json().to_string();
        assert_eq!(Response::parse_line(&line), Ok(Response::Trace(empty)));
    }

    /// A trace-less `get_kernel` frame is byte-identical to the
    /// pre-trace wire format (the `trace` field encodes only when
    /// present), so old daemons and clients interoperate unchanged.
    #[test]
    fn traceless_get_kernel_frames_are_unchanged() {
        let req = Request::GetKernel {
            id: "c1".into(),
            workload: suites::MM1,
            gpu: None,
            mode: None,
            trace: None,
        };
        let line = req.to_json().to_string();
        assert!(!line.contains("trace"), "{line}");
        // And a foreign field named `trace` on the wire parses into
        // the id slot without disturbing the rest.
        let with = r#"{"v":1,"op":"get_kernel","id":"x","workload":"MM1","trace":"a3f9"}"#;
        match Request::parse_line(with).unwrap() {
            Request::GetKernel { trace, .. } => assert_eq!(trace.as_deref(), Some("a3f9")),
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn hello_frames_roundtrip_and_default_to_line() {
        let req = Request::Hello { id: "h1".into(), wire: wire_name::BINARY.into() };
        let line = req.to_json().to_string();
        assert_eq!(Request::parse_line(&line), Ok(req));
        // Absent `wire` degrades to line, never errors.
        match Request::parse_line(r#"{"v":1,"op":"hello","id":"h2"}"#).unwrap() {
            Request::Hello { wire, .. } => assert_eq!(wire, wire_name::LINE),
            other => panic!("{other:?}"),
        }
        let ack = Response::HelloAck { id: "h1".into(), wire: wire_name::BINARY.into() };
        let line = ack.to_json().to_string();
        assert!(line.contains(r#""wire_v":2"#), "{line}");
        assert_eq!(Response::parse_line(&line), Ok(ack));
        let ack = Response::HelloAck { id: "h1".into(), wire: wire_name::LINE.into() };
        assert!(ack.to_json().to_string().contains(r#""wire_v":1"#));
    }

    #[test]
    fn binary_frames_roundtrip_and_split_reads_wait() {
        let frame = wire::Frame { tag: 7, kind: wire::KIND_JSON, payload: b"{}".to_vec() };
        let bytes = frame.encode();
        let (back, used) = wire::Frame::decode(&bytes).unwrap().unwrap();
        assert_eq!(used, bytes.len());
        assert_eq!(back, frame);
        // Every strict prefix is "need more bytes", never an error.
        for cut in 0..bytes.len() {
            assert_eq!(wire::Frame::decode(&bytes[..cut]).unwrap(), None, "cut={cut}");
        }
        // Two frames back-to-back decode one at a time.
        let mut two = bytes.clone();
        wire::Frame { tag: 8, kind: wire::KIND_JSON, payload: vec![] }.encode_into(&mut two);
        let (first, used) = wire::Frame::decode(&two).unwrap().unwrap();
        assert_eq!(first.tag, 7);
        let (second, _) = wire::Frame::decode(&two[used..]).unwrap().unwrap();
        assert_eq!(second.tag, 8);
        // A desynced length field is an error, not a stall.
        assert!(wire::Frame::decode(&[0, 0, 0, 0]).is_err());
        assert!(wire::Frame::decode(&u32::MAX.to_le_bytes()).is_err());
    }

    #[test]
    fn binary_get_kernel_payloads_roundtrip() {
        for w in [
            suites::MM1,
            suites::MV3,
            Workload::Conv2d {
                batch: 8,
                h: 56,
                w: 56,
                cin: 64,
                cout: 128,
                ksize: 3,
                stride: 2,
                pad: 1,
            },
        ] {
            let payload = wire::encode_get_kernel(&w, None, None);
            let (back, gpu, mode) = wire::decode_get_kernel(&payload).unwrap();
            assert_eq!(back, w);
            assert_eq!(gpu, None);
            assert_eq!(mode, None);
        }
        let payload = wire::encode_get_kernel(
            &suites::MM1,
            Some(GpuArch::A100),
            Some(SearchMode::EnergyAware),
        );
        let (_, gpu, mode) = wire::decode_get_kernel(&payload).unwrap();
        assert_eq!(gpu, Some(GpuArch::A100));
        assert_eq!(mode, Some(SearchMode::EnergyAware));
        // Truncated payloads refuse instead of panicking.
        assert!(wire::decode_get_kernel(&payload[..3]).is_err());
        assert!(wire::decode_get_kernel(&[9]).is_err());
    }

    #[test]
    fn binary_kernel_reply_payloads_roundtrip() {
        let reply = KernelReply {
            id: wire::tag_id(42),
            hit: true,
            source: ServeSource::Store,
            tier: ServeTier::Exact,
            schedule: sample_schedule(),
            latency_s: 1.5e-3,
            energy_j: 0.25,
            avg_power_w: 166.6,
            enqueued: false,
            queue_depth: 3,
            reply_time_s: 2.0e-4,
        };
        let payload = wire::encode_kernel_reply(&reply);
        assert_eq!(wire::decode_kernel_reply(42, &payload).unwrap(), reply);
        let miss = KernelReply {
            id: wire::tag_id(9),
            hit: false,
            source: ServeSource::WarmGuess,
            tier: ServeTier::Warm,
            enqueued: true,
            ..reply
        };
        let payload = wire::encode_kernel_reply(&miss);
        assert_eq!(wire::decode_kernel_reply(9, &payload).unwrap(), miss);
        assert!(wire::decode_kernel_reply(9, &payload[..10]).is_err());
    }
}
