//! The kernel-serving layer: a long-running daemon that answers
//! `get_kernel(workload, gpu, mode)` over a Unix-domain socket.
//!
//! This is where the paper's tuning cost amortizes at deployment time:
//! a fleet serving repeat traffic should pay for a search **once** and
//! serve every later request from the store at zero measurement cost.
//! The pieces:
//!
//! * [`protocol`] — versioned, line-delimited JSON frames
//!   (request/response/error, stable error codes);
//! * [`daemon`] — the socket server: exact hits reply instantly from
//!   the sharded store; misses reply with a warm-start guess and
//!   enqueue a real search on a daemon-owned
//!   [`crate::coordinator::WorkerPool`], whose outcome is written back
//!   so the next request hits;
//! * [`client`] — a small blocking client (`ecokernel query`, the
//!   serving-fleet example);
//! * [`metrics`] — hit rate, p50/p99 reply time on the simulated
//!   clock, queue depth, measurement-cost ledger.
//!
//! Storage is [`crate::store::ShardedStore`]: the tuning store split
//! across N append-only shard files with last-served LRU eviction and
//! per-GPU record quotas (the `[serve]` config section).

pub mod client;
pub mod daemon;
pub mod metrics;
pub mod protocol;

pub use client::ServeClient;
pub use daemon::{Daemon, DaemonConfig, DaemonHandle};
pub use metrics::ServeMetrics;
pub use protocol::{
    error_code, KernelReply, Request, Response, ServeSource, StatsReply, PROTOCOL_VERSION,
};
