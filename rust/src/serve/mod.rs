//! The kernel-serving layer: long-running daemons that answer
//! `get_kernel(workload, gpu, mode)` over `unix:` or `tcp:` sockets.
//!
//! This is where the paper's tuning cost amortizes at deployment time:
//! a fleet serving repeat traffic should pay for a search **once
//! fleet-wide** and serve every later request from the shared store at
//! zero measurement cost. The pieces:
//!
//! * [`protocol`] — versioned, line-delimited JSON frames
//!   (request/response/error, stable error codes), identical on both
//!   wires; a `batch` frame carries N `get_kernel` requests per
//!   socket write with positionally-matched replies. A `hello` frame
//!   can negotiate the length-prefixed **binary wire v2**
//!   ([`protocol::wire`]): tagged frames, out-of-order replies, a
//!   fixed-layout `get_kernel`/kernel-reply encoding that skips JSON
//!   entirely on the hot path. Line-JSON stays the compat wire
//!   forever — a connection that never says `hello` is served
//!   byte-identically to every prior release;
//! * [`daemon`] — the socket server: an evented `poll(2)` reactor
//!   accept loop sized to cores (no thread-per-connection), a fast
//!   lane that answers hits and admin ops inline, and a slow lane for
//!   misses and batches so one miss never head-of-line-blocks a
//!   sibling hit on a multiplexed binary connection. Exact hits reply
//!   instantly from
//!   the sharded store; misses reply with a warm-start guess — or,
//!   with no neighbor in range, the search-free **static tier**
//!   ([`crate::analysis`]) — and enqueue a real search on a daemon-owned
//!   [`crate::coordinator::WorkerPool`], whose outcome is written back
//!   so the next request hits. N daemons can mount one store: misses
//!   coalesce fleet-wide through in-store claims, shard maintenance is
//!   lease-fenced, and a saturated search queue admits hot keys and
//!   sheds cold ones ([`crate::fleet`]);
//! * [`client`] — a small blocking client (`ecokernel query`, the
//!   fleet examples);
//! * [`metrics`] — hit rate, p50/p99 reply time on the simulated AND
//!   wall clocks, per-stage hot-path histograms
//!   ([`crate::telemetry`]), queue depth, shed/coalesce counters,
//!   measurement-cost ledger, and per-regime cost-model accuracy
//!   histograms; served whole by the `metrics` wire op and mergeable
//!   fleet-wide ([`client::merged_metrics`]). The `trace` wire op
//!   returns the daemon's tail-sampled distributed traces
//!   ([`crate::telemetry::TraceLog`]) — one miss followed from wire
//!   parse through search rounds, write-back, and the peers'
//!   notify-refresh ingest. The `health` wire op (ISSUE 8) evaluates
//!   the `[slo]` targets in-daemon over fast/slow windows and reports
//!   `ok|warn|critical` per target plus the cost-model drift
//!   watchdog's state; [`client::merged_health`] folds a fleet's
//!   verdicts worst-of per target. The energy-savings ledger
//!   ([`crate::telemetry::EnergyLedger`]) rides the `metrics` op;
//! * [`bench`] — the `ecokernel bench serve` harness: zipf replay
//!   against live daemons (single + two-daemon TCP fleet), producing
//!   the `BENCH_serving.json` baseline.
//!
//! Storage is [`crate::store::ShardedStore`]: the tuning store split
//! across N append-only shard files with last-served LRU eviction and
//! per-GPU record quotas (the `[serve]` config section); fleet
//! coordination knobs live in `[fleet]`.

pub mod bench;
pub mod client;
pub mod daemon;
pub mod metrics;
pub mod protocol;
#[cfg(unix)]
mod reactor;

pub use crate::fleet::{AddrList, ServeAddr};
pub use bench::{run_bench_serve, BenchServeOpts};
pub use client::{
    merged_health, merged_metrics, BatchError, BatchRequest, FleetHealth, FleetMetrics, Op, Reply,
    ServeClient,
};
pub use daemon::{Daemon, DaemonConfig, DaemonHandle};
pub use metrics::{ServeMetrics, MODEL_REGIMES};
pub use protocol::{
    error_code, wire, wire_name, BatchItem, DriftHealth, HealthReply, HealthStatus, HealthTarget,
    KernelReply, MetricsReply, Reject, Request, Response, ServeSource, ServeTier, StatsReply,
    TraceReply, HEALTH_VERSION, MAX_BATCH_ITEMS, METRICS_VERSION, PROTOCOL_VERSION, TRACE_VERSION,
    WIRE_VERSION,
};
