//! Serving metrics: hit rate, reply-time percentiles (simulated
//! clock), queue depth, and the measurement-cost ledger.
//!
//! Reply times are charged on the same simulated clock as the search
//! framework (the Fig. 5 currency): a store lookup costs a base term
//! plus a per-record scan of the key's shard, and a miss additionally
//! pays the nearest-neighbor scan that produces the warm guess. This
//! keeps hits and misses distinguishable in p50/p99 without the noise
//! of host wall-clock.

use crate::util::stats;

/// Simulated base cost of one store lookup.
pub const REPLY_LOOKUP_BASE_S: f64 = 50e-6;
/// Simulated per-record scan cost within the key's shard (the term
/// sharding shrinks: N shards cut it N-fold).
pub const REPLY_PER_RECORD_S: f64 = 200e-9;
/// Simulated cost of the neighbor scan + re-legalization on a miss.
pub const REPLY_MISS_NEIGHBOR_S: f64 = 2e-3;

/// Reply-time samples kept for the percentile window: a long-running
/// daemon must not grow memory per request, so p50/p99 are computed
/// over a sliding window of the most recent replies.
pub const REPLY_WINDOW: usize = 4096;

/// Aggregate serving counters for one daemon lifetime.
#[derive(Debug, Clone, Default)]
pub struct ServeMetrics {
    pub n_requests: usize,
    pub n_hits: usize,
    pub n_misses: usize,
    /// Background searches enqueued (≤ misses: duplicates coalesce).
    pub n_enqueued: usize,
    pub n_searches_done: usize,
    pub n_evicted_records: usize,
    /// Misses shed by admission control (queue + backlog saturated and
    /// the key was colder than everything waiting).
    pub n_shed: usize,
    /// Misses coalesced into another fleet member's in-flight search.
    pub n_fleet_coalesced: usize,
    /// Finished searches whose write-back was rejected by the epoch
    /// fence (another daemon reclaimed the key mid-search). NOT counted
    /// in `n_searches_done` — this daemon's result went unused.
    pub n_writebacks_fenced: usize,
    /// Finished searches whose write-back was dropped for good (shard
    /// lease never freed across every park retry, or an I/O error).
    /// NOT counted in `n_searches_done`.
    pub n_writebacks_dropped: usize,
    /// NVML measurements paid by completed background searches whose
    /// write-back landed.
    pub measurements_paid: usize,
    /// `batch` frames served — each one is a single socket write
    /// carrying N `get_kernel` requests, so frames-per-syscall is
    /// `n_batch_requests / n_batch_frames`.
    pub n_batch_frames: usize,
    /// `get_kernel` requests that arrived inside `batch` frames
    /// (each also counted in `n_requests`/`n_hits`/`n_misses`).
    pub n_batch_requests: usize,
    /// Foreign write-back announcements the notify refresh loop acted
    /// on — each one refreshed only the touched shard (the push path).
    pub n_notify_refresh: usize,
    /// Interval-poll fallback passes that actually ingested changes
    /// the notify channel had missed (0 on a healthy push path).
    pub n_poll_refresh: usize,
    /// Ring buffer of the last [`REPLY_WINDOW`] reply times.
    reply_times_s: Vec<f64>,
    reply_next: usize,
}

impl ServeMetrics {
    /// Record one served request.
    pub fn record_reply(&mut self, hit: bool, reply_time_s: f64) {
        self.n_requests += 1;
        if hit {
            self.n_hits += 1;
        } else {
            self.n_misses += 1;
        }
        if self.reply_times_s.len() < REPLY_WINDOW {
            self.reply_times_s.push(reply_time_s);
        } else {
            self.reply_times_s[self.reply_next] = reply_time_s;
            self.reply_next = (self.reply_next + 1) % REPLY_WINDOW;
        }
    }

    pub fn hit_rate(&self) -> f64 {
        if self.n_requests == 0 {
            return 0.0;
        }
        self.n_hits as f64 / self.n_requests as f64
    }

    pub fn p50_reply_s(&self) -> f64 {
        if self.reply_times_s.is_empty() {
            return 0.0;
        }
        stats::percentile(&self.reply_times_s, 50.0)
    }

    pub fn p99_reply_s(&self) -> f64 {
        if self.reply_times_s.is_empty() {
            return 0.0;
        }
        stats::percentile(&self.reply_times_s, 99.0)
    }

    pub fn summary(&self) -> String {
        format!(
            "requests={} hits={} misses={} hit_rate={:.2} enqueued={} searched={} \
             shed={} fleet_coalesced={} evicted={} wb_fenced={} wb_dropped={} \
             batches={}/{} notify_refresh={} poll_refresh={} \
             p50={:.2}ms p99={:.2}ms measurements_paid={}",
            self.n_requests,
            self.n_hits,
            self.n_misses,
            self.hit_rate(),
            self.n_enqueued,
            self.n_searches_done,
            self.n_shed,
            self.n_fleet_coalesced,
            self.n_evicted_records,
            self.n_writebacks_fenced,
            self.n_writebacks_dropped,
            self.n_batch_requests,
            self.n_batch_frames,
            self.n_notify_refresh,
            self.n_poll_refresh,
            self.p50_reply_s() * 1e3,
            self.p99_reply_s() * 1e3,
            self.measurements_paid,
        )
    }
}

/// Simulated reply time of one request against a shard holding
/// `shard_len` records. The miss term models the warm-guess neighbor
/// lookup — since the incremental [`crate::store::NeighborIndex`] it
/// is a bounded candidate-bucket probe, not an O(store) scan, so the
/// flat constant stays honest as the store grows.
pub fn reply_time_s(hit: bool, shard_len: usize) -> f64 {
    let lookup = REPLY_LOOKUP_BASE_S + shard_len as f64 * REPLY_PER_RECORD_S;
    if hit {
        lookup
    } else {
        lookup + REPLY_MISS_NEIGHBOR_S
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn hit_rate_and_percentiles() {
        let mut m = ServeMetrics::default();
        assert_eq!(m.hit_rate(), 0.0);
        assert_eq!(m.p50_reply_s(), 0.0);
        for _ in 0..9 {
            m.record_reply(true, reply_time_s(true, 100));
        }
        m.record_reply(false, reply_time_s(false, 100));
        assert_eq!(m.n_requests, 10);
        assert!((m.hit_rate() - 0.9).abs() < 1e-12);
        // The single slow miss shows up at p99 but not p50.
        assert!(m.p99_reply_s() > m.p50_reply_s());
        assert!(m.p99_reply_s() >= REPLY_MISS_NEIGHBOR_S);
        assert!(m.p50_reply_s() < REPLY_MISS_NEIGHBOR_S);
        assert!(m.summary().contains("hit_rate=0.90"));
    }

    #[test]
    fn reply_window_stays_bounded_under_load() {
        let mut m = ServeMetrics::default();
        for i in 0..(REPLY_WINDOW + 100) {
            m.record_reply(true, (i + 1) as f64 * 1e-6);
        }
        assert_eq!(m.n_requests, REPLY_WINDOW + 100);
        assert_eq!(m.reply_times_s.len(), REPLY_WINDOW, "ring buffer capped");
        // Old samples aged out: the minimum surviving sample is from
        // after the first 100 replies.
        assert!(m.reply_times_s.iter().all(|&t| t > 100.0 * 1e-6));
        assert!(m.p50_reply_s() > 0.0 && m.p99_reply_s() >= m.p50_reply_s());
    }

    #[test]
    fn misses_cost_more_and_sharding_cuts_scan_cost() {
        assert!(reply_time_s(false, 10) > reply_time_s(true, 10));
        assert!(reply_time_s(true, 10_000) > reply_time_s(true, 10_000 / 8));
    }
}
