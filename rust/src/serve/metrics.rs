//! Serving metrics: hit rate, reply-time histograms on both clocks,
//! per-stage hot-path histograms, and the measurement-cost ledger.
//!
//! Reply times are charged on two clocks at once. The **simulated**
//! clock is the search framework's currency (Fig. 5): a store lookup
//! costs a base term plus a per-record scan of the key's shard, and a
//! miss additionally pays the nearest-neighbor scan that produces the
//! warm guess — hits and misses stay distinguishable without host
//! noise. The **wall clock** is what a client actually waits, recorded
//! since ISSUE 6 so `BENCH_serving.json` and the `metrics` op report
//! real latencies.
//!
//! All distributions live in fixed-size [`LogHistogram`]s: O(1)
//! allocation-free record (folded under the state-lock acquisition the
//! reply bookkeeping already pays), bounded memory for the lifetime of
//! the daemon, and exact fleet-wide merging.

use crate::telemetry::{EnergyLedger, LogHistogram, Stage, StageTrace, N_STAGES};

/// Cost-model accuracy regimes (ISSUE 7): `round0` isolates the warm-
/// start transfer round (where a poisoned seed model shows up first),
/// `steady` aggregates every later round. Index = `regime_of(round)`.
pub const MODEL_REGIMES: [&str; 2] = ["round0", "steady"];

/// Regime bucket index for a search-round number.
pub fn regime_of(round: usize) -> usize {
    usize::from(round != 0)
}

/// Simulated base cost of one store lookup.
pub const REPLY_LOOKUP_BASE_S: f64 = 50e-6;
/// Simulated per-record scan cost within the key's shard (the term
/// sharding shrinks: N shards cut it N-fold).
pub const REPLY_PER_RECORD_S: f64 = 200e-9;
/// Simulated cost of the neighbor scan + re-legalization on a miss.
pub const REPLY_MISS_NEIGHBOR_S: f64 = 2e-3;

/// Aggregate serving counters for one daemon lifetime.
#[derive(Debug, Clone, Default)]
pub struct ServeMetrics {
    pub n_requests: usize,
    pub n_hits: usize,
    pub n_misses: usize,
    /// Background searches enqueued (≤ misses: duplicates coalesce).
    pub n_enqueued: usize,
    pub n_searches_done: usize,
    pub n_evicted_records: usize,
    /// Misses shed by admission control (queue + backlog saturated and
    /// the key was colder than everything waiting).
    pub n_shed: usize,
    /// Misses coalesced into another fleet member's in-flight search.
    pub n_fleet_coalesced: usize,
    /// Misses answered from the search-free static tier (ISSUE 9): no
    /// neighbor in range, so the reply carried the best statically-
    /// ranked schedule with closed-form estimates — zero measurements.
    pub n_static_tier: usize,
    /// Finished searches whose write-back was rejected by the epoch
    /// fence (another daemon reclaimed the key mid-search). NOT counted
    /// in `n_searches_done` — this daemon's result went unused.
    pub n_writebacks_fenced: usize,
    /// Finished searches whose write-back was dropped for good (shard
    /// lease never freed across every park retry, or an I/O error).
    /// NOT counted in `n_searches_done`.
    pub n_writebacks_dropped: usize,
    /// NVML measurements paid by completed background searches whose
    /// write-back landed.
    pub measurements_paid: usize,
    /// `batch` frames served — each one is a single socket write
    /// carrying N `get_kernel` requests, so frames-per-syscall is
    /// `n_batch_requests / n_batch_frames`.
    pub n_batch_frames: usize,
    /// `get_kernel` requests that arrived inside `batch` frames
    /// (each also counted in `n_requests`/`n_hits`/`n_misses`).
    pub n_batch_requests: usize,
    /// Foreign write-back announcements the notify refresh loop acted
    /// on — each one refreshed only the touched shard (the push path).
    pub n_notify_refresh: usize,
    /// Interval-poll fallback passes that actually ingested changes
    /// the notify channel had missed (0 on a healthy push path).
    pub n_poll_refresh: usize,
    /// Re-searches the drift watchdog admitted after the steady-regime
    /// relerr crossed the `[slo]` ceiling (ISSUE 8). Bounded per
    /// interval by `slo.drift_budget`.
    pub n_drift_researches: usize,
    /// `hello` negotiations handled (whatever the outcome — the ack's
    /// `wire` field says what was granted).
    pub n_hello: usize,
    /// Frames received on the wire-v2 binary framing (kinds 0–2;
    /// line-JSON frames are `n_requests`-adjacent but uncounted here).
    pub n_binary_frames: usize,
    /// Replies written out of arrival order on a binary connection —
    /// each one is a hit (or other fast reply) that did NOT wait
    /// behind an earlier slow sibling. The multiplexing win, counted.
    pub n_ooo_replies: usize,
    /// Energy-savings ledger (ISSUE 8): joules saved vs the latency-only
    /// baseline per served hit, measurement joules paid per landed
    /// search, both per (gpu, workload-family). Fixed arrays — recording
    /// rides the same state-lock acquisition as the reply histograms.
    pub ledger: EnergyLedger,
    /// Simulated-clock reply times (the Fig. 5 currency).
    reply_sim: LogHistogram,
    /// Wall-clock reply times: frame receipt → reply frame built.
    reply_wall: LogHistogram,
    /// Wall-clock per-stage histograms, indexed by `Stage as usize`.
    stages: [LogHistogram; N_STAGES],
    /// Cost-model SNR prediction error per round (dB), per regime.
    /// Recorded off the hot path (write-back landing, writer thread).
    /// Non-positive dB values clamp into bucket 0 — a histogram count
    /// piling up there IS the drift signal.
    model_snr_db: [LogHistogram; MODEL_REGIMES.len()],
    /// Predicted-vs-measured relative energy error per round, per
    /// regime (unitless; 0.1 = 10% off).
    model_energy_relerr: [LogHistogram; MODEL_REGIMES.len()],
    /// Dynamic-k trajectory per regime: the fraction of each round's
    /// candidates paid for with NVML measurements.
    model_dynamic_k: [LogHistogram; MODEL_REGIMES.len()],
}

impl ServeMetrics {
    /// Record one served request: both clocks plus every stage the
    /// request's trace touched. One call, already under the state
    /// lock — no allocation, no syscalls.
    pub fn record_reply(&mut self, hit: bool, sim_s: f64, wall_s: f64, trace: &StageTrace) {
        self.n_requests += 1;
        if hit {
            self.n_hits += 1;
        } else {
            self.n_misses += 1;
        }
        self.reply_sim.record(sim_s);
        self.reply_wall.record(wall_s);
        for stage in Stage::ALL {
            if let Some(secs) = trace.get(stage) {
                self.stages[stage as usize].record(secs);
            }
        }
    }

    /// Record a single stage outside a reply trace (frame-level parse
    /// for batches; reply write, which is only measurable after the
    /// reply has left the state lock).
    pub fn record_stage(&mut self, stage: Stage, secs: f64) {
        self.stages[stage as usize].record(secs);
    }

    pub fn hit_rate(&self) -> f64 {
        if self.n_requests == 0 {
            return 0.0;
        }
        self.n_hits as f64 / self.n_requests as f64
    }

    pub fn p50_reply_s(&self) -> f64 {
        self.reply_sim.quantile(50.0)
    }

    pub fn p99_reply_s(&self) -> f64 {
        self.reply_sim.quantile(99.0)
    }

    pub fn reply_sim(&self) -> &LogHistogram {
        &self.reply_sim
    }

    pub fn reply_wall(&self) -> &LogHistogram {
        &self.reply_wall
    }

    pub fn stage(&self, stage: Stage) -> &LogHistogram {
        &self.stages[stage as usize]
    }

    /// Record one search round's cost-model accuracy telemetry
    /// (ISSUE 7). Called at write-back landing — the writer thread,
    /// never the request hot path. `snr_db`/`relerr` are recorded when
    /// the round computed them; `k` whenever the round ran the dynamic
    /// controller (k > 0 — latency-only rounds report 0 and carry no
    /// model).
    pub fn record_model_round(&mut self, round: &crate::search::RoundStats) {
        let regime = regime_of(round.round);
        if let Some(snr) = round.snr_db {
            self.model_snr_db[regime].record(snr);
        }
        if let Some(e) = round.relerr {
            self.model_energy_relerr[regime].record(e);
        }
        if round.k > 0.0 {
            self.model_dynamic_k[regime].record(round.k);
        }
    }

    pub fn model_snr_db(&self, regime: usize) -> &LogHistogram {
        &self.model_snr_db[regime]
    }

    pub fn model_energy_relerr(&self, regime: usize) -> &LogHistogram {
        &self.model_energy_relerr[regime]
    }

    pub fn model_dynamic_k(&self, regime: usize) -> &LogHistogram {
        &self.model_dynamic_k[regime]
    }

    /// Every non-empty model-accuracy histogram as
    /// `("family/regime", histogram)` pairs — the `metrics` op's
    /// `model` map keys (family is the Prometheus base name minus the
    /// `ecokernel_` prefix). Cold path only; allocates the Vec.
    pub fn model_pairs(&self) -> Vec<(String, &LogHistogram)> {
        let mut out = Vec::new();
        for (regime, name) in MODEL_REGIMES.iter().enumerate() {
            for (family, hist) in [
                ("model_snr_db", &self.model_snr_db[regime]),
                ("model_energy_relerr", &self.model_energy_relerr[regime]),
                ("model_dynamic_k", &self.model_dynamic_k[regime]),
            ] {
                if !hist.is_empty() {
                    out.push((format!("{family}/{name}"), hist));
                }
            }
        }
        out
    }

    /// Samples across every histogram that arrived non-finite or
    /// non-positive and were clamped into bucket 0 (ISSUE 8) — a
    /// NaN-producing measurement bug surfaces here as a counter instead
    /// of silently skewing the smallest bucket. Cold path (the
    /// `metrics` op); the per-histogram tallies it sums are O(1) reads.
    pub fn n_invalid_samples(&self) -> u64 {
        let mut n = self.reply_sim.invalid() + self.reply_wall.invalid();
        for h in &self.stages {
            n += h.invalid();
        }
        for regime in 0..MODEL_REGIMES.len() {
            n += self.model_snr_db[regime].invalid()
                + self.model_energy_relerr[regime].invalid()
                + self.model_dynamic_k[regime].invalid();
        }
        n
    }

    /// Counter name/value pairs, names matching the `stats` wire
    /// fields — the `metrics` op serves these as its counter map.
    pub fn counter_pairs(&self) -> [(&'static str, u64); 21] {
        [
            ("n_requests", self.n_requests as u64),
            ("n_hits", self.n_hits as u64),
            ("n_misses", self.n_misses as u64),
            ("n_enqueued", self.n_enqueued as u64),
            ("n_searches_done", self.n_searches_done as u64),
            ("n_evicted_records", self.n_evicted_records as u64),
            ("n_shed", self.n_shed as u64),
            ("n_fleet_coalesced", self.n_fleet_coalesced as u64),
            ("n_static_tier", self.n_static_tier as u64),
            ("n_writebacks_fenced", self.n_writebacks_fenced as u64),
            ("n_writebacks_dropped", self.n_writebacks_dropped as u64),
            ("measurements_paid", self.measurements_paid as u64),
            ("n_batch_frames", self.n_batch_frames as u64),
            ("n_batch_requests", self.n_batch_requests as u64),
            ("n_notify_refresh", self.n_notify_refresh as u64),
            ("n_poll_refresh", self.n_poll_refresh as u64),
            ("n_drift_researches", self.n_drift_researches as u64),
            ("n_hello", self.n_hello as u64),
            ("n_binary_frames", self.n_binary_frames as u64),
            ("n_ooo_replies", self.n_ooo_replies as u64),
            ("n_invalid_samples", self.n_invalid_samples()),
        ]
    }

    pub fn summary(&self) -> String {
        format!(
            "requests={} hits={} misses={} hit_rate={:.2} enqueued={} searched={} \
             shed={} fleet_coalesced={} static_tier={} evicted={} wb_fenced={} wb_dropped={} \
             batches={}/{} notify_refresh={} poll_refresh={} \
             p50={:.2}ms p99={:.2}ms wall_p50={:.3}ms wall_p99={:.3}ms measurements_paid={}",
            self.n_requests,
            self.n_hits,
            self.n_misses,
            self.hit_rate(),
            self.n_enqueued,
            self.n_searches_done,
            self.n_shed,
            self.n_fleet_coalesced,
            self.n_static_tier,
            self.n_evicted_records,
            self.n_writebacks_fenced,
            self.n_writebacks_dropped,
            self.n_batch_requests,
            self.n_batch_frames,
            self.n_notify_refresh,
            self.n_poll_refresh,
            self.p50_reply_s() * 1e3,
            self.p99_reply_s() * 1e3,
            self.reply_wall.quantile(50.0) * 1e3,
            self.reply_wall.quantile(99.0) * 1e3,
            self.measurements_paid,
        )
    }
}

/// Simulated reply time of one request against a shard holding
/// `shard_len` records. The miss term models the warm-guess neighbor
/// lookup — since the incremental [`crate::store::NeighborIndex`] it
/// is a bounded candidate-bucket probe, not an O(store) scan, so the
/// flat constant stays honest as the store grows.
pub fn reply_time_s(hit: bool, shard_len: usize) -> f64 {
    let lookup = REPLY_LOOKUP_BASE_S + shard_len as f64 * REPLY_PER_RECORD_S;
    if hit {
        lookup
    } else {
        lookup + REPLY_MISS_NEIGHBOR_S
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn hit_trace() -> StageTrace {
        let mut t = StageTrace::new();
        t.add(Stage::Parse, 4e-6);
        t.add(Stage::ShardRead, 9e-6);
        t
    }

    #[test]
    fn hit_rate_and_percentiles() {
        let mut m = ServeMetrics::default();
        assert_eq!(m.hit_rate(), 0.0);
        assert_eq!(m.p50_reply_s(), 0.0);
        for _ in 0..9 {
            m.record_reply(true, reply_time_s(true, 100), 30e-6, &hit_trace());
        }
        let mut miss = hit_trace();
        miss.add(Stage::SnapshotLookup, 80e-6);
        miss.add(Stage::ClaimIo, 120e-6);
        miss.add(Stage::Enqueue, 15e-6);
        m.record_reply(false, reply_time_s(false, 100), 400e-6, &miss);
        assert_eq!(m.n_requests, 10);
        assert!((m.hit_rate() - 0.9).abs() < 1e-12);
        // The single slow miss shows up at p99 but not p50.
        assert!(m.p99_reply_s() > m.p50_reply_s());
        assert!(m.p99_reply_s() >= REPLY_MISS_NEIGHBOR_S);
        assert!(m.p50_reply_s() < REPLY_MISS_NEIGHBOR_S);
        assert!(m.summary().contains("hit_rate=0.90"));
        // Stage histograms saw exactly what the traces carried.
        assert_eq!(m.stage(Stage::Parse).count(), 10);
        assert_eq!(m.stage(Stage::ShardRead).count(), 10);
        assert_eq!(m.stage(Stage::SnapshotLookup).count(), 1);
        assert_eq!(m.stage(Stage::ClaimIo).count(), 1);
        assert_eq!(m.stage(Stage::ReplyWrite).count(), 0);
        assert_eq!(m.reply_wall().count(), 10);
    }

    #[test]
    fn memory_stays_fixed_under_load() {
        let mut m = ServeMetrics::default();
        for i in 0..50_000usize {
            m.record_reply(true, (i + 1) as f64 * 1e-6, 20e-6, &hit_trace());
        }
        assert_eq!(m.n_requests, 50_000);
        // Histograms and the ledger are fixed arrays: no per-request
        // growth anywhere (14 × ~552 B histograms + 512 B ledger).
        assert!(std::mem::size_of::<ServeMetrics>() < 12288);
        assert!(m.p50_reply_s() > 0.0 && m.p99_reply_s() >= m.p50_reply_s());
    }

    #[test]
    fn record_stage_feeds_the_out_of_trace_stages() {
        let mut m = ServeMetrics::default();
        m.record_stage(Stage::ReplyWrite, 6e-6);
        m.record_stage(Stage::ReplyWrite, 8e-6);
        assert_eq!(m.stage(Stage::ReplyWrite).count(), 2);
        assert_eq!(m.n_requests, 0, "stage-only records are not requests");
    }

    #[test]
    fn misses_cost_more_and_sharding_cuts_scan_cost() {
        assert!(reply_time_s(false, 10) > reply_time_s(true, 10));
        assert!(reply_time_s(true, 10_000) > reply_time_s(true, 10_000 / 8));
    }

    #[test]
    fn invalid_samples_roll_up_across_every_histogram() {
        let mut m = ServeMetrics::default();
        assert_eq!(m.n_invalid_samples(), 0);
        m.record_reply(true, f64::NAN, 30e-6, &hit_trace());
        m.record_stage(Stage::ReplyWrite, -1.0);
        assert_eq!(m.n_invalid_samples(), 2);
        assert!(m.counter_pairs().iter().any(|&(k, v)| k == "n_invalid_samples" && v == 2));
        assert!(m.counter_pairs().iter().any(|&(k, v)| k == "n_drift_researches" && v == 0));
    }

    #[test]
    fn ledger_rides_the_metrics_struct() {
        let mut m = ServeMetrics::default();
        assert!(m.ledger.is_empty());
        m.ledger.record_saved(0, 0, 2.5);
        m.ledger.record_paid(0, 0, 1.0);
        assert_eq!(m.ledger.total_saved_j(), 2.5);
        assert_eq!(m.ledger.total_paid_j(), 1.0);
    }

    #[test]
    fn model_rounds_land_in_the_right_regime_bucket() {
        use crate::search::RoundStats;
        let mut m = ServeMetrics::default();
        assert!(m.model_pairs().is_empty(), "no rounds, no model families");
        // Cold round 0: no SNR check yet, but k is live.
        m.record_model_round(&RoundStats {
            round: 0,
            best_latency_s: 1e-3,
            best_energy_j: 0.5,
            snr_db: None,
            relerr: None,
            k: 0.5,
            n_measured: 16,
            elapsed_s: 1.0,
        });
        // Steady round with a model check.
        m.record_model_round(&RoundStats {
            round: 3,
            best_latency_s: 0.9e-3,
            best_energy_j: 0.4,
            snr_db: Some(17.2),
            relerr: Some(0.12),
            k: 0.25,
            n_measured: 8,
            elapsed_s: 2.0,
        });
        // Latency-only round: k == 0 records nothing.
        m.record_model_round(&RoundStats {
            round: 1,
            best_latency_s: 1e-3,
            best_energy_j: f64::NAN,
            snr_db: None,
            relerr: None,
            k: 0.0,
            n_measured: 0,
            elapsed_s: 0.1,
        });
        assert_eq!(m.model_dynamic_k(regime_of(0)).count(), 1);
        assert_eq!(m.model_dynamic_k(regime_of(3)).count(), 1);
        assert_eq!(m.model_snr_db(0).count(), 0);
        assert_eq!(m.model_snr_db(1).count(), 1);
        assert!((m.model_snr_db(1).mean() - 17.2).abs() < 1e-12);
        assert_eq!(m.model_energy_relerr(1).count(), 1);
        let keys: Vec<String> = m.model_pairs().into_iter().map(|(k, _)| k).collect();
        assert_eq!(
            keys,
            [
                "model_dynamic_k/round0",
                "model_snr_db/steady",
                "model_energy_relerr/steady",
                "model_dynamic_k/steady"
            ]
        );
    }
}
