//! `ecokernel bench serve` — the serving benchmark harness behind
//! `BENCH_serving.json`.
//!
//! Spawns a real daemon (and, unless disabled, a two-daemon TCP fleet
//! sharing one store), warms a small working set, replays a
//! zipf-skewed request stream mixing single `get_kernel` frames with
//! pipelined `batch` frames, and reports what the **`metrics` wire
//! op** measured: wall-clock reply p50/p99, per-stage histograms, hit
//! rate, and frames-per-syscall. Client-side wall time gives req/s.
//!
//! The single-daemon phase replays per wire mode (`--wire`): once on
//! the forever-compat line-JSON framing and once on the
//! hello-negotiated binary framing, against the same hot daemon. The
//! per-mode `wire` block in the baseline (req/s, client-side p50/p99,
//! `negotiated`) is how CI pins that the binary wire actually pays —
//! requests per second at or above line-JSON, with the parse stage
//! histogram visibly shrinking.
//!
//! Everything that can be deterministic is ([`crate::util::Rng`],
//! fixed working set, fixed frame mix); the wall-clock numbers are of
//! course machine-dependent — the JSON carries a `note` saying so.

use super::client::{merged_metrics, BatchRequest, Op, ServeClient};
use super::daemon::{Daemon, DaemonConfig, DaemonHandle};
use super::protocol::{wire_name, MetricsReply};
use crate::config::{GpuArch, SearchConfig, SearchMode};
use crate::fleet::ServeAddr;
use crate::telemetry::{LogHistogram, LEDGER_FAMILIES, LEDGER_GPUS};
use crate::util::{Json, Rng};
use crate::workload::{suites, Workload};
use anyhow::Context as _;
use std::path::PathBuf;
use std::time::{Duration, Instant};

/// Knobs for one `bench serve` run.
#[derive(Debug, Clone)]
pub struct BenchServeOpts {
    /// Requests in the measured single-daemon phase.
    pub requests: usize,
    /// Zipf skew exponent of the replayed key popularity.
    pub zipf_s: f64,
    /// Requests packed per `batch` frame (≈¼ of traffic is batched).
    pub batch: usize,
    /// Also run the two-daemon TCP fleet phase.
    pub fleet: bool,
    /// Which wire(s) the single-daemon phase replays over:
    /// `"line"`, `"binary"` (hello-negotiated), or `"both"` — both
    /// runs back-to-back against the same hot daemon so the per-mode
    /// `wire` block in the baseline is an apples-to-apples comparison.
    pub wire: String,
    /// CI smoke mode: small request counts, small working set.
    pub quick: bool,
    /// Where the JSON baseline is written.
    pub out: PathBuf,
}

impl Default for BenchServeOpts {
    fn default() -> Self {
        BenchServeOpts {
            requests: 2000,
            zipf_s: 1.1,
            batch: 8,
            fleet: true,
            wire: "both".to_string(),
            quick: false,
            out: PathBuf::from("BENCH_serving.json"),
        }
    }
}

/// Zipf(s) over ranks 0..n via an inverse-CDF table.
struct Zipf {
    cdf: Vec<f64>,
}

impl Zipf {
    fn new(n: usize, s: f64) -> Zipf {
        let weights: Vec<f64> = (0..n).map(|i| 1.0 / ((i + 1) as f64).powf(s)).collect();
        let total: f64 = weights.iter().sum();
        let mut acc = 0.0;
        let cdf = weights
            .iter()
            .map(|w| {
                acc += w / total;
                acc
            })
            .collect();
        Zipf { cdf }
    }

    fn sample(&self, rng: &mut Rng) -> usize {
        let u = rng.gen_f64();
        self.cdf.iter().position(|&c| u < c).unwrap_or(self.cdf.len() - 1)
    }
}

/// Search knobs for the warm-up misses: the bench measures *serving*,
/// so background searches just need to land fast.
fn bench_search(seed: u64) -> SearchConfig {
    let mut search = SearchConfig {
        gpu: GpuArch::A100,
        mode: SearchMode::EnergyAware,
        population: 16,
        m_latency_keep: 4,
        rounds: 2,
        patience: 0,
        seed,
        ..Default::default()
    };
    search.serve.n_workers = 1;
    search.serve.n_shards = 4;
    search
}

fn fresh_dir(tag: &str) -> anyhow::Result<PathBuf> {
    let dir = std::env::temp_dir().join(format!("ecokernel_bench_{tag}_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).with_context(|| format!("create {dir:?}"))?;
    Ok(dir)
}

/// Warm `set` into the store through the daemon itself (miss → search
/// → write-back → hit), so the measured phase replays a hot cache.
fn warm(client: &mut ServeClient, set: &[Workload]) -> anyhow::Result<()> {
    for &w in set {
        client
            .get_kernel_wait(w, None, None, Duration::from_secs(180))
            .with_context(|| format!("warm {w}"))?;
    }
    Ok(())
}

/// Replay `requests` zipf-sampled requests on one connection, ~¼ of
/// them packed into `batch`-sized frames. The op mix is identical on
/// both wires (negotiation is the caller's job), so per-wire numbers
/// compare like for like. Returns the elapsed seconds plus a
/// client-side histogram of per-frame reply wall time.
fn replay(
    client: &mut ServeClient,
    set: &[Workload],
    zipf: &Zipf,
    rng: &mut Rng,
    requests: usize,
    batch: usize,
) -> anyhow::Result<(f64, LogHistogram)> {
    let mut lat = LogHistogram::new();
    let t0 = Instant::now();
    let mut issued = 0usize;
    while issued < requests {
        if issued % (4 * batch) < batch && requests - issued >= batch {
            let reqs: Vec<BatchRequest> =
                (0..batch).map(|_| (set[zipf.sample(rng)], None, None)).collect();
            let t = Instant::now();
            for entry in client.call(Op::Batch(reqs))?.into_batch(batch)? {
                entry.map_err(|e| anyhow::anyhow!("batch entry rejected: {e}"))?;
            }
            lat.record(t.elapsed().as_secs_f64());
            issued += batch;
        } else {
            let workload = set[zipf.sample(rng)];
            let t = Instant::now();
            client.call(Op::GetKernel { workload, gpu: None, mode: None, trace: None })?
                .into_kernel()?;
            lat.record(t.elapsed().as_secs_f64());
            issued += 1;
        }
    }
    Ok((t0.elapsed().as_secs_f64(), lat))
}

fn stage_json(h: &LogHistogram) -> Json {
    Json::obj(vec![
        ("count", Json::num(h.count() as f64)),
        ("p50_ms", Json::num(h.quantile(50.0) * 1e3)),
        ("p99_ms", Json::num(h.quantile(99.0) * 1e3)),
        ("mean_ms", Json::num(h.mean() * 1e3)),
    ])
}

/// The energy-accounting ledger (ISSUE 8) as a baseline block:
/// totals plus every non-empty `gpu/family` cell, so a regression in
/// savings attribution (e.g. hits landing unattributed) is visible in
/// the diff of `BENCH_serving.json`.
fn ledger_json(m: &MetricsReply) -> Json {
    let l = &m.energy;
    let cells: std::collections::BTreeMap<String, Json> = l
        .cells()
        .map(|(g, f)| {
            let key = format!("{}/{}", LEDGER_GPUS[g], LEDGER_FAMILIES[f]);
            let cell = Json::obj(vec![
                ("saved_j", Json::num(l.saved_j(g, f))),
                ("paid_j", Json::num(l.paid_j(g, f))),
                ("n_hits", Json::num(l.n_hits(g, f) as f64)),
                ("n_searches", Json::num(l.n_searches(g, f) as f64)),
            ]);
            (key, cell)
        })
        .collect();
    Json::obj(vec![
        ("total_saved_j", Json::num(l.total_saved_j())),
        ("total_paid_j", Json::num(l.total_paid_j())),
        ("unattributed_hits", Json::num(l.total_unattributed() as f64)),
        ("cells", Json::Obj(cells)),
    ])
}

fn phase_json(m: &MetricsReply, requests: usize, elapsed_s: f64) -> Vec<(String, Json)> {
    let hits = m.counter("n_hits") as f64;
    let total = m.counter("n_requests") as f64;
    let misses = m.counter("n_misses") as f64;
    // Per-tier reply counts (ISSUE 9): every exact hit is the `exact`
    // tier; a miss is `static` when it was answered search-free from
    // the static ranking, `warm` otherwise. Pre-tier daemons report no
    // `n_static_tier` counter (merged as 0): all misses count as warm.
    let n_static = m.counter("n_static_tier") as f64;
    let tiers = Json::obj(vec![
        ("exact", Json::num(hits)),
        ("warm", Json::num((misses - n_static).max(0.0))),
        ("static", Json::num(n_static)),
    ]);
    vec![
        ("req_per_s".to_string(), Json::num(requests as f64 / elapsed_s.max(1e-9))),
        ("p50_ms".to_string(), Json::num(m.reply_wall_s.quantile(50.0) * 1e3)),
        ("p99_ms".to_string(), Json::num(m.reply_wall_s.quantile(99.0) * 1e3)),
        ("hit_rate".to_string(), Json::num(if total > 0.0 { hits / total } else { 0.0 })),
        ("tiers".to_string(), tiers),
        ("frames_per_syscall".to_string(), Json::num(m.frames_per_syscall())),
        ("energy_ledger".to_string(), ledger_json(m)),
        (
            "stages".to_string(),
            Json::Obj(
                m.stages
                    .iter()
                    .filter(|(_, h)| !h.is_empty())
                    .map(|(name, h)| (name.clone(), stage_json(h)))
                    .collect(),
            ),
        ),
    ]
}

fn shutdown(addr: &ServeAddr, handle: DaemonHandle) -> anyhow::Result<()> {
    ServeClient::connect(addr)?.shutdown()?;
    handle.join()
}

/// Run the benchmark and write `opts.out`. Returns the written JSON.
pub fn run_bench_serve(opts: &BenchServeOpts) -> anyhow::Result<Json> {
    let requests = if opts.quick { opts.requests.min(320) } else { opts.requests };
    anyhow::ensure!(requests >= 4 * opts.batch, "need at least {} requests", 4 * opts.batch);
    let set: &[Workload] = if opts.quick {
        &[suites::MM1, suites::MV3, suites::CONV2]
    } else {
        &[suites::MM1, suites::MM3, suites::MV3, suites::MV4, suites::CONV2]
    };
    let zipf = Zipf::new(set.len(), opts.zipf_s);
    let mut rng = Rng::seed_from_u64(0x6e_c0);

    // ---- Phase 1: single daemon on a Unix socket, replayed per
    // wire mode (line-JSON first, then hello-negotiated binary), so
    // the `wire` block compares the framings on the same hot store. --
    let wire_modes: &[&str] = match opts.wire.as_str() {
        "line" => &[wire_name::LINE],
        "binary" => &[wire_name::BINARY],
        _ => &[wire_name::LINE, wire_name::BINARY],
    };
    let dir = fresh_dir("single")?;
    let addr = ServeAddr::Unix(dir.join("bench.sock"));
    let handle = Daemon::spawn(
        DaemonConfig { addr: addr.clone(), store_dir: dir.clone(), search: bench_search(11) },
        None,
    )?;
    let (single_metrics, single_traces, wire_blocks, total_issued, total_elapsed) = {
        let mut warm_client = ServeClient::connect(&addr)?;
        warm(&mut warm_client, set)?;
        let mut blocks: Vec<(String, Json)> = Vec::new();
        let mut total_elapsed = 0.0f64;
        for &mode in wire_modes {
            eprintln!("bench serve: phase 1 — {mode} wire replay ({requests} requests)");
            let mut client = ServeClient::connect(&addr)?;
            let negotiated = mode == wire_name::BINARY && client.negotiate_binary()?;
            anyhow::ensure!(
                mode != wire_name::BINARY || negotiated,
                "daemon declined binary wire negotiation"
            );
            let (elapsed, lat) = replay(&mut client, set, &zipf, &mut rng, requests, opts.batch)?;
            total_elapsed += elapsed;
            blocks.push((
                mode.to_string(),
                Json::obj(vec![
                    ("requests", Json::num(requests as f64)),
                    ("req_per_s", Json::num(requests as f64 / elapsed.max(1e-9))),
                    ("p50_ms", Json::num(lat.quantile(50.0) * 1e3)),
                    ("p99_ms", Json::num(lat.quantile(99.0) * 1e3)),
                    ("negotiated", Json::Bool(negotiated)),
                ]),
            ));
        }
        let mut client = ServeClient::connect(&addr)?;
        let m = client.call(Op::Metrics)?.into_metrics()?;
        // The warm-up misses are this phase's only traces — every one
        // complete by now (get_kernel_wait polled until its write-back
        // landed). The top-5 with per-span breakdowns go in the
        // baseline so a regression shows WHERE the time moved.
        let traces = client.call(Op::Traces { slowest: 5 })?.into_traces()?;
        (m, traces, blocks, requests * wire_modes.len(), total_elapsed)
    };
    shutdown(&addr, handle)?;
    let _ = std::fs::remove_dir_all(&dir);

    let mut doc: Vec<(String, Json)> = phase_json(&single_metrics, total_issued, total_elapsed);
    doc.push(("wire".to_string(), Json::Obj(wire_blocks.into_iter().collect())));
    doc.push((
        "slowest_traces".to_string(),
        Json::arr(single_traces.traces.iter().map(|t| t.to_json())),
    ));
    doc.push(("requests".to_string(), Json::num(total_issued as f64)));
    doc.push(("zipf_s".to_string(), Json::num(opts.zipf_s)));
    doc.push((
        "note".to_string(),
        Json::str(
            "measured by `ecokernel bench serve` against live daemons; wall-clock \
             figures are machine-dependent (CI regenerates this file)",
        ),
    ));

    // ---- Phase 2: two TCP daemons, one store. ---------------------
    if opts.fleet {
        eprintln!("bench serve: phase 2 — two-daemon TCP fleet");
        let fdir = fresh_dir("fleet")?;
        let store = fdir.join("store");
        let ha = Daemon::spawn(
            DaemonConfig {
                addr: ServeAddr::Tcp("127.0.0.1:0".into()),
                store_dir: store.clone(),
                search: bench_search(12),
            },
            None,
        )?;
        let hb = Daemon::spawn(
            DaemonConfig {
                addr: ServeAddr::Tcp("127.0.0.1:0".into()),
                store_dir: store,
                search: bench_search(13),
            },
            None,
        )?;
        let (aa, ab) = (ha.addr.clone(), hb.addr.clone());
        let fleet_requests = (requests / 2).max(2 * opts.batch);
        let mut ca = ServeClient::connect(&aa)?;
        let mut cb = ServeClient::connect(&ab)?;
        // Warm through daemon A; daemon B ingests via notify refresh
        // (its warm loop below then hits without re-searching).
        warm(&mut ca, set)?;
        warm(&mut cb, set)?;
        let (ea, _) = replay(&mut ca, set, &zipf, &mut rng, fleet_requests, opts.batch)?;
        let (eb, _) = replay(&mut cb, set, &zipf, &mut rng, fleet_requests, opts.batch)?;
        let fm = merged_metrics(&[aa.clone(), ab.clone()])?;
        anyhow::ensure!(fm.errors.is_empty(), "bench fleet daemon unreachable: {:?}", fm.errors);
        let mut fleet = phase_json(&fm.merged, 2 * fleet_requests, ea + eb);
        fleet.push(("daemons".to_string(), Json::num(2.0)));
        doc.push(("fleet".to_string(), Json::Obj(fleet.into_iter().collect())));
        shutdown(&aa, ha)?;
        shutdown(&ab, hb)?;
        let _ = std::fs::remove_dir_all(&fdir);
    }

    let json = Json::Obj(doc.into_iter().collect());
    std::fs::write(&opts.out, format!("{json}\n"))
        .with_context(|| format!("write {:?}", opts.out))?;
    Ok(json)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zipf_cdf_is_monotone_and_skewed() {
        let z = Zipf::new(5, 1.1);
        assert!(z.cdf.windows(2).all(|w| w[0] < w[1]));
        assert!((z.cdf.last().unwrap() - 1.0).abs() < 1e-12);
        // Rank 0 dominates: sampled far more often than rank 4.
        let mut rng = Rng::seed_from_u64(7);
        let mut counts = [0usize; 5];
        for _ in 0..10_000 {
            counts[z.sample(&mut rng)] += 1;
        }
        assert!(counts[0] > 3 * counts[4], "{counts:?}");
        assert!(counts.iter().all(|&c| c > 0), "{counts:?}");
    }

    #[test]
    fn default_opts_satisfy_the_quick_floor() {
        let opts = BenchServeOpts::default();
        assert!(opts.requests.min(320) >= 4 * opts.batch);
    }
}
