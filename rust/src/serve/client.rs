//! A small blocking client for the kernel-serving daemon (used by
//! `ecokernel query` and the fleet examples). Transport-agnostic: the
//! same frames flow over `unix:` and `tcp:` addresses.

use super::protocol::{KernelReply, Request, Response, StatsReply};
use crate::config::{GpuArch, SearchMode};
use crate::fleet::{ServeAddr, Stream};
use crate::workload::Workload;
use anyhow::{anyhow, Context as _};
use std::io::{BufRead as _, BufReader, Write as _};
use std::time::{Duration, Instant};

/// One connection to a serving daemon. Requests are sequential
/// (send a frame, read the reply line).
pub struct ServeClient {
    stream: Stream,
    reader: BufReader<Stream>,
    next_id: u64,
}

impl ServeClient {
    pub fn connect(addr: &ServeAddr) -> anyhow::Result<ServeClient> {
        let stream = Stream::connect(addr)?;
        let reader = BufReader::new(stream.try_clone().context("clone daemon stream")?);
        Ok(ServeClient { stream, reader, next_id: 0 })
    }

    fn fresh_id(&mut self) -> String {
        self.next_id += 1;
        format!("c{}", self.next_id)
    }

    /// Send one raw line and read one raw reply line (tests use this to
    /// probe malformed / version-mismatched frames).
    pub fn roundtrip_raw(&mut self, line: &str) -> anyhow::Result<String> {
        writeln!(self.stream, "{line}").context("send frame")?;
        self.stream.flush().context("flush frame")?;
        let mut reply = String::new();
        let n = self.reader.read_line(&mut reply).context("read reply")?;
        anyhow::ensure!(n > 0, "daemon closed the connection");
        Ok(reply.trim_end().to_string())
    }

    fn roundtrip(&mut self, req: &Request) -> anyhow::Result<Response> {
        let line = self.roundtrip_raw(&req.to_json().to_string())?;
        Response::parse_line(&line).map_err(|e| anyhow!("bad response frame: {e} ({line})"))
    }

    /// One `get_kernel` request.
    pub fn get_kernel(
        &mut self,
        workload: Workload,
        gpu: Option<GpuArch>,
        mode: Option<SearchMode>,
    ) -> anyhow::Result<KernelReply> {
        let id = self.fresh_id();
        match self.roundtrip(&Request::GetKernel { id, workload, gpu, mode })? {
            Response::Kernel(r) => Ok(r),
            Response::Error { code, message, .. } => {
                Err(anyhow!("daemon error [{code}]: {message}"))
            }
            other => Err(anyhow!("unexpected response {other:?}")),
        }
    }

    /// Poll `get_kernel` until the store serves an exact hit (the
    /// background search for a first-seen workload has landed), or the
    /// timeout expires. Returns the hit reply.
    pub fn get_kernel_wait(
        &mut self,
        workload: Workload,
        gpu: Option<GpuArch>,
        mode: Option<SearchMode>,
        timeout: Duration,
    ) -> anyhow::Result<KernelReply> {
        let start = Instant::now();
        loop {
            let reply = self.get_kernel(workload, gpu, mode)?;
            if reply.hit {
                return Ok(reply);
            }
            if start.elapsed() > timeout {
                return Err(anyhow!(
                    "no hit for {workload} within {:.0}s (queue depth {})",
                    timeout.as_secs_f64(),
                    reply.queue_depth
                ));
            }
            std::thread::sleep(Duration::from_millis(100));
        }
    }

    pub fn stats(&mut self) -> anyhow::Result<StatsReply> {
        let id = self.fresh_id();
        match self.roundtrip(&Request::Stats { id })? {
            Response::Stats(r) => Ok(r),
            Response::Error { code, message, .. } => {
                Err(anyhow!("daemon error [{code}]: {message}"))
            }
            other => Err(anyhow!("unexpected response {other:?}")),
        }
    }

    /// Poll `stats` until every admitted search has been written back
    /// — no key queued, backlogged, running, or awaiting write-back
    /// (`pending_keys == 0`) — or the timeout expires. `pending_keys`
    /// subsumes the worker-queue depth on a current daemon (a key
    /// leaves the pending set only after its record landed), but the
    /// pool depth is checked too: a pre-split daemon's frames lack
    /// `pending_keys` (parsed as 0) while their `queue_depth` carries
    /// the old pending-key meaning, so this stays a real drain signal
    /// against both generations.
    pub fn wait_for_drain(&mut self, timeout: Duration) -> anyhow::Result<StatsReply> {
        let start = Instant::now();
        loop {
            let s = self.stats()?;
            if s.pending_keys == 0 && s.queue_depth == 0 {
                return Ok(s);
            }
            if start.elapsed() > timeout {
                return Err(anyhow!(
                    "searches not drained within {:.0}s ({} keys pending, pool depth {})",
                    timeout.as_secs_f64(),
                    s.pending_keys,
                    s.queue_depth
                ));
            }
            std::thread::sleep(Duration::from_millis(100));
        }
    }

    /// Graceful daemon stop (acked before the daemon drains and exits).
    pub fn shutdown(&mut self) -> anyhow::Result<()> {
        let id = self.fresh_id();
        match self.roundtrip(&Request::Shutdown { id })? {
            Response::ShutdownAck { .. } => Ok(()),
            Response::Error { code, message, .. } => {
                Err(anyhow!("daemon error [{code}]: {message}"))
            }
            other => Err(anyhow!("unexpected response {other:?}")),
        }
    }
}
