//! A small blocking client for the kernel-serving daemon (used by
//! `ecokernel query` and the fleet examples). Transport-agnostic: the
//! same frames flow over `unix:` and `tcp:` addresses.
//!
//! # The op API
//!
//! Every wire operation is one [`Op`] variant; [`ServeClient::call`]
//! sends it and returns the typed [`Reply`]. [`ServeClient::call_many`]
//! pipelines a whole slice of ops — on the line-JSON wire that is N
//! frames in one write syscall answered strictly in order; on the
//! negotiated binary wire it is N **tagged** frames whose replies may
//! arrive out of order (a hit overtakes a slow miss) and are matched
//! back to their ops by tag, so the returned vector is always
//! positionally correct.
//!
//! # Wire negotiation
//!
//! A client starts on line-JSON (the forever-compat wire). Calling
//! [`ServeClient::negotiate_binary`] (or connecting via
//! [`ServeClient::connect_negotiated`]) sends a `hello` frame asking
//! for the binary wire; a current daemon acks and both sides switch
//! framing, an old daemon rejects the unknown op and the client
//! simply stays on line-JSON — downgrade is silent and loss-free.
//! The codec behind the connection is an internal detail: every `Op`
//! works identically on both wires.
//!
//! The old per-op method zoo (`get_kernel`, `get_kernel_batch`,
//! `queue_get_kernel`/`flush_batch`, `stats`, `metrics`, `traces`,
//! `health`) survives one release as thin deprecated wrappers over
//! [`ServeClient::call`].

use super::protocol::{
    wire, wire_name, BatchItem, HealthReply, HealthStatus, HealthTarget, KernelReply,
    MetricsReply, Reject, Request, Response, StatsReply, TraceReply, MAX_BATCH_ITEMS,
};
use crate::config::{GpuArch, SearchMode};
use crate::fleet::{ServeAddr, Stream};
use crate::workload::Workload;
use anyhow::{anyhow, Context as _};
use std::collections::HashMap;
use std::io::{BufRead as _, BufReader, Read as _, Write as _};
use std::time::{Duration, Instant};

/// One queued `get_kernel` for the batch path.
pub type BatchRequest = (Workload, Option<GpuArch>, Option<SearchMode>);

/// A positional failure inside a batch reply: the daemon rejected that
/// entry (its siblings were still served).
#[derive(Debug, Clone, PartialEq)]
pub struct BatchError {
    pub code: String,
    pub message: String,
}

impl std::fmt::Display for BatchError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "[{}] {}", self.code, self.message)
    }
}

/// One wire operation. [`ServeClient::call`] sends it; the matching
/// [`Reply`] variant comes back (`Reply::Error` on daemon rejection).
#[derive(Debug, Clone)]
pub enum Op {
    /// One kernel request. `trace` is an optional caller-chosen trace
    /// id (hex): a reserving miss adopts it as the distributed trace's
    /// id so the caller can correlate its own log with `query --trace`
    /// output fleet-wide; `None` lets the daemon mint one.
    GetKernel {
        workload: Workload,
        gpu: Option<GpuArch>,
        mode: Option<SearchMode>,
        trace: Option<String>,
    },
    /// N kernel requests in ONE `batch` frame (one write syscall),
    /// answered by one positionally-matched reply. Capped at
    /// [`MAX_BATCH_ITEMS`]; enforced client-side before any bytes hit
    /// the wire.
    Batch(Vec<BatchRequest>),
    /// Scalar serving counters.
    Stats,
    /// Full telemetry snapshot: counters plus reply-time and
    /// per-stage histograms.
    Metrics,
    /// Retained request traces, slowest first (`slowest == 0` asks
    /// for every completed trace the ring holds).
    Traces { slowest: usize },
    /// In-daemon SLO verdicts + drift-watchdog state.
    Health,
    /// Graceful daemon stop (acked before the daemon drains).
    Shutdown,
}

/// What an [`Op`] returns. This IS the wire response enum: a typed
/// reply for every op, plus `Reply::Error` carrying the daemon's
/// stable error code. The `into_*` accessors convert to the payload
/// type, turning a daemon error into a descriptive `anyhow` error.
pub type Reply = Response;

impl Reply {
    fn daemon_err(self) -> anyhow::Error {
        match self {
            Response::Error { code, message, .. } => anyhow!("daemon error [{code}]: {message}"),
            other => anyhow!("unexpected response {other:?}"),
        }
    }

    /// The kernel reply, or a descriptive error.
    pub fn into_kernel(self) -> anyhow::Result<KernelReply> {
        match self {
            Response::Kernel(r) => Ok(r),
            other => Err(other.daemon_err()),
        }
    }

    /// The positionally-matched batch results. `expected` is the
    /// request count — a daemon answering with a different arity is
    /// an error, never a silent truncation.
    pub fn into_batch(
        self,
        expected: usize,
    ) -> anyhow::Result<Vec<Result<KernelReply, BatchError>>> {
        match self {
            Response::Batch { replies, .. } => {
                anyhow::ensure!(
                    replies.len() == expected,
                    "batch of {expected} requests got {} replies",
                    replies.len()
                );
                replies
                    .into_iter()
                    .map(|reply| match reply {
                        Response::Kernel(k) => Ok(Ok(k)),
                        Response::Error { code, message, .. } => {
                            Ok(Err(BatchError { code, message }))
                        }
                        other => Err(anyhow!("unexpected batch entry {other:?}")),
                    })
                    .collect()
            }
            other => Err(other.daemon_err()),
        }
    }

    pub fn into_stats(self) -> anyhow::Result<StatsReply> {
        match self {
            Response::Stats(r) => Ok(r),
            other => Err(other.daemon_err()),
        }
    }

    pub fn into_metrics(self) -> anyhow::Result<MetricsReply> {
        match self {
            Response::Metrics(r) => Ok(r),
            other => Err(other.daemon_err()),
        }
    }

    pub fn into_traces(self) -> anyhow::Result<TraceReply> {
        match self {
            Response::Trace(r) => Ok(r),
            other => Err(other.daemon_err()),
        }
    }

    pub fn into_health(self) -> anyhow::Result<HealthReply> {
        match self {
            Response::Health(r) => Ok(r),
            other => Err(other.daemon_err()),
        }
    }

    pub fn into_shutdown_ack(self) -> anyhow::Result<()> {
        match self {
            Response::ShutdownAck { .. } => Ok(()),
            other => Err(other.daemon_err()),
        }
    }
}

/// The framing a connection speaks. Chosen at `hello` negotiation;
/// internal — every [`Op`] works identically over either.
enum WireCodec {
    /// Line-delimited JSON (wire v1, the forever-compat default).
    Line,
    /// Length-prefixed tagged frames (wire v2). `rbuf` holds inbound
    /// bytes straddling frame boundaries.
    Binary { rbuf: Vec<u8> },
}

/// One connection to a serving daemon.
pub struct ServeClient {
    stream: Stream,
    reader: BufReader<Stream>,
    codec: WireCodec,
    next_id: u64,
    queued: Vec<BatchRequest>,
}

impl ServeClient {
    /// Connect on the line-JSON wire (works against every daemon
    /// generation). Use [`ServeClient::negotiate_binary`] or
    /// [`ServeClient::connect_negotiated`] to upgrade.
    pub fn connect(addr: &ServeAddr) -> anyhow::Result<ServeClient> {
        let stream = Stream::connect(addr)?;
        let reader = BufReader::new(stream.try_clone().context("clone daemon stream")?);
        Ok(ServeClient { stream, reader, codec: WireCodec::Line, next_id: 0, queued: Vec::new() })
    }

    /// Connect and try to negotiate the binary wire, silently staying
    /// on line-JSON against a daemon that does not speak it. Check
    /// [`ServeClient::wire`] for the outcome.
    pub fn connect_negotiated(addr: &ServeAddr) -> anyhow::Result<ServeClient> {
        let mut client = ServeClient::connect(addr)?;
        client.negotiate_binary()?;
        Ok(client)
    }

    /// The wire this connection currently speaks
    /// ([`wire_name::LINE`] or [`wire_name::BINARY`]).
    pub fn wire(&self) -> &'static str {
        match self.codec {
            WireCodec::Line => wire_name::LINE,
            WireCodec::Binary { .. } => wire_name::BINARY,
        }
    }

    /// Ask the daemon to switch this connection to the binary wire.
    /// Returns whether binary was granted. An old daemon rejects the
    /// unknown `hello` op — that is a clean `Ok(false)` downgrade, not
    /// an error; the connection keeps working on line-JSON. Safe to
    /// call repeatedly (idempotent once granted). Must not race other
    /// in-flight requests — the framing switches right after the ack.
    pub fn negotiate_binary(&mut self) -> anyhow::Result<bool> {
        if matches!(self.codec, WireCodec::Binary { .. }) {
            return Ok(true);
        }
        let id = self.fresh_id();
        let req = Request::Hello { id, wire: wire_name::BINARY.to_string() };
        let line = self.roundtrip_raw(&req.to_json().to_string())?;
        match Response::parse_line(&line) {
            Ok(Response::HelloAck { wire, .. }) if wire == wire_name::BINARY => {
                self.codec = WireCodec::Binary { rbuf: Vec::new() };
                Ok(true)
            }
            // Daemon granted something other than binary: stay on line.
            Ok(Response::HelloAck { .. }) => Ok(false),
            // Old daemon: `hello` is an unknown op. Downgrade cleanly.
            Ok(Response::Error { .. }) => Ok(false),
            Ok(other) => Err(anyhow!("unexpected hello response {other:?}")),
            Err(e) => Err(anyhow!("bad hello response frame: {e} ({line})")),
        }
    }

    /// Send one op, return its reply.
    pub fn call(&mut self, op: Op) -> anyhow::Result<Reply> {
        self.call_many(vec![op])?
            .pop()
            .ok_or_else(|| anyhow!("no reply for op"))
    }

    /// Pipeline N ops: all requests are written up front (one buffer,
    /// one write syscall), then all replies are collected. The
    /// returned vector matches `ops` positionally on BOTH wires —
    /// on the binary wire replies may physically arrive out of order
    /// (that is the point) and are reordered by tag here.
    pub fn call_many(&mut self, ops: Vec<Op>) -> anyhow::Result<Vec<Reply>> {
        if ops.is_empty() {
            return Ok(Vec::new());
        }
        let mut buf = Vec::new();
        let mut tags = Vec::with_capacity(ops.len());
        for op in ops {
            let tag = self.next_id + 1;
            let req = self.build_request(op)?;
            match &self.codec {
                WireCodec::Line => {
                    buf.extend_from_slice(req.to_json().to_string().as_bytes());
                    buf.push(b'\n');
                }
                WireCodec::Binary { .. } => encode_binary_request(&req, tag, &mut buf),
            }
            tags.push(tag);
        }
        self.stream.write_all(&buf).context("send frames")?;
        self.stream.flush().context("flush frames")?;
        match self.codec {
            WireCodec::Line => {
                // Line wire: replies are strictly in-order.
                let mut replies = Vec::with_capacity(tags.len());
                for _ in 0..tags.len() {
                    replies.push(self.read_line_reply()?);
                }
                Ok(replies)
            }
            WireCodec::Binary { .. } => {
                // Binary wire: replies arrive in completion order,
                // tagged; reorder to request order.
                let mut by_tag: HashMap<u64, Reply> = HashMap::with_capacity(tags.len());
                while by_tag.len() < tags.len() {
                    let frame = self.read_binary_frame()?;
                    let tag = frame.tag;
                    anyhow::ensure!(
                        tags.contains(&tag),
                        "daemon replied with unknown tag {tag}"
                    );
                    by_tag.insert(tag, decode_binary_reply(frame)?);
                }
                tags.iter()
                    .map(|t| by_tag.remove(t).ok_or_else(|| anyhow!("no reply for tag {t}")))
                    .collect()
            }
        }
    }

    fn fresh_id(&mut self) -> String {
        self.next_id += 1;
        match self.codec {
            WireCodec::Line => format!("c{}", self.next_id),
            // Binary frames address replies by numeric tag; the JSON
            // id inside kind-0 frames is its canonical rendering.
            WireCodec::Binary { .. } => wire::tag_id(self.next_id),
        }
    }

    /// Turn one op into a wire request (allocating its id — and, on
    /// the binary wire, its tag `next_id`).
    fn build_request(&mut self, op: Op) -> anyhow::Result<Request> {
        Ok(match op {
            Op::GetKernel { workload, gpu, mode, trace } => {
                Request::GetKernel { id: self.fresh_id(), workload, gpu, mode, trace }
            }
            Op::Batch(requests) => {
                anyhow::ensure!(!requests.is_empty(), "empty batch");
                anyhow::ensure!(
                    requests.len() <= MAX_BATCH_ITEMS,
                    "batch of {} exceeds the {MAX_BATCH_ITEMS}-request cap (split it into chunks)",
                    requests.len()
                );
                let batch_id = self.fresh_id();
                let items: Vec<Result<BatchItem, Reject>> = requests
                    .iter()
                    .enumerate()
                    .map(|(i, &(workload, gpu, mode))| {
                        Ok(BatchItem { id: format!("{batch_id}.{i}"), workload, gpu, mode })
                    })
                    .collect();
                Request::Batch { id: batch_id, items }
            }
            Op::Stats => Request::Stats { id: self.fresh_id() },
            Op::Metrics => Request::Metrics { id: self.fresh_id() },
            Op::Traces { slowest } => Request::Traces { id: self.fresh_id(), slowest },
            Op::Health => Request::Health { id: self.fresh_id() },
            Op::Shutdown => Request::Shutdown { id: self.fresh_id() },
        })
    }

    fn read_line_reply(&mut self) -> anyhow::Result<Reply> {
        let mut reply = String::new();
        let n = self.reader.read_line(&mut reply).context("read reply")?;
        anyhow::ensure!(n > 0, "daemon closed the connection");
        let line = reply.trim_end();
        Response::parse_line(line).map_err(|e| anyhow!("bad response frame: {e} ({line})"))
    }

    /// Read one whole binary frame (reads straddle frame boundaries;
    /// leftover bytes stay in the codec's buffer for the next frame).
    fn read_binary_frame(&mut self) -> anyhow::Result<wire::Frame> {
        let Self { reader, codec, .. } = self;
        let WireCodec::Binary { rbuf } = codec else {
            return Err(anyhow!("connection is not on the binary wire"));
        };
        loop {
            match wire::Frame::decode(rbuf).map_err(|e| anyhow!("bad binary frame: {e}"))? {
                Some((frame, used)) => {
                    rbuf.drain(..used);
                    return Ok(frame);
                }
                None => {
                    let mut chunk = [0u8; 8192];
                    let n = reader.read(&mut chunk).context("read binary frame")?;
                    anyhow::ensure!(n > 0, "daemon closed the connection");
                    rbuf.extend_from_slice(&chunk[..n]);
                }
            }
        }
    }

    /// Send one raw line and read one raw reply line (tests use this to
    /// probe malformed / version-mismatched frames). Line wire only.
    pub fn roundtrip_raw(&mut self, line: &str) -> anyhow::Result<String> {
        let mut bytes = Vec::with_capacity(line.len() + 1);
        bytes.extend_from_slice(line.as_bytes());
        bytes.push(b'\n');
        self.stream.write_all(&bytes).context("send frame")?;
        self.stream.flush().context("flush frame")?;
        let mut reply = String::new();
        let n = self.reader.read_line(&mut reply).context("read reply")?;
        anyhow::ensure!(n > 0, "daemon closed the connection");
        Ok(reply.trim_end().to_string())
    }

    // -- conveniences over `call` ------------------------------------

    /// One `get_kernel` carrying a caller-chosen trace id (hex); see
    /// [`Op::GetKernel`]. `None` lets the daemon mint one.
    pub fn get_kernel_traced(
        &mut self,
        workload: Workload,
        gpu: Option<GpuArch>,
        mode: Option<SearchMode>,
        trace: Option<&str>,
    ) -> anyhow::Result<KernelReply> {
        let trace = trace.map(|t| t.to_string());
        self.call(Op::GetKernel { workload, gpu, mode, trace })?.into_kernel()
    }

    /// Poll `get_kernel` until the store serves an exact hit (the
    /// background search for a first-seen workload has landed), or the
    /// timeout expires. Returns the hit reply.
    pub fn get_kernel_wait(
        &mut self,
        workload: Workload,
        gpu: Option<GpuArch>,
        mode: Option<SearchMode>,
        timeout: Duration,
    ) -> anyhow::Result<KernelReply> {
        let start = Instant::now();
        loop {
            let reply = self
                .call(Op::GetKernel { workload, gpu, mode, trace: None })?
                .into_kernel()?;
            if reply.hit {
                return Ok(reply);
            }
            if start.elapsed() > timeout {
                return Err(anyhow!(
                    "no hit for {workload} within {:.0}s (queue depth {})",
                    timeout.as_secs_f64(),
                    reply.queue_depth
                ));
            }
            std::thread::sleep(Duration::from_millis(100));
        }
    }

    /// Poll `stats` until every admitted search has been written back
    /// — no key queued, backlogged, running, or awaiting write-back
    /// (`pending_keys == 0`) — or the timeout expires. `pending_keys`
    /// subsumes the worker-queue depth on a current daemon (a key
    /// leaves the pending set only after its record landed), but the
    /// pool depth is checked too: a pre-split daemon's frames lack
    /// `pending_keys` (parsed as 0) while their `queue_depth` carries
    /// the old pending-key meaning, so this stays a real drain signal
    /// against both generations.
    pub fn wait_for_drain(&mut self, timeout: Duration) -> anyhow::Result<StatsReply> {
        let start = Instant::now();
        loop {
            let s = self.call(Op::Stats)?.into_stats()?;
            if s.pending_keys == 0 && s.queue_depth == 0 {
                return Ok(s);
            }
            if start.elapsed() > timeout {
                return Err(anyhow!(
                    "searches not drained within {:.0}s ({} keys pending, pool depth {})",
                    timeout.as_secs_f64(),
                    s.pending_keys,
                    s.queue_depth
                ));
            }
            std::thread::sleep(Duration::from_millis(100));
        }
    }

    /// Graceful daemon stop (acked before the daemon drains and exits).
    pub fn shutdown(&mut self) -> anyhow::Result<()> {
        self.call(Op::Shutdown)?.into_shutdown_ack()
    }

    // -- the deprecated method zoo (one release of grace) ------------

    /// One `get_kernel` request.
    #[deprecated(note = "use `call(Op::GetKernel { .. })?.into_kernel()`")]
    pub fn get_kernel(
        &mut self,
        workload: Workload,
        gpu: Option<GpuArch>,
        mode: Option<SearchMode>,
    ) -> anyhow::Result<KernelReply> {
        self.call(Op::GetKernel { workload, gpu, mode, trace: None })?.into_kernel()
    }

    /// Queue one `get_kernel` for the next `flush_batch`.
    #[deprecated(note = "collect `BatchRequest`s and use `call(Op::Batch(..))`")]
    pub fn queue_get_kernel(
        &mut self,
        workload: Workload,
        gpu: Option<GpuArch>,
        mode: Option<SearchMode>,
    ) {
        self.queued.push((workload, gpu, mode));
    }

    /// Requests queued for the next flush.
    #[deprecated(note = "collect `BatchRequest`s and use `call(Op::Batch(..))`")]
    pub fn queued_len(&self) -> usize {
        self.queued.len()
    }

    /// Flush every queued request as ONE `batch` frame. On a failed
    /// flush the queue is restored, so nothing queued is silently
    /// lost.
    #[deprecated(note = "collect `BatchRequest`s and use `call(Op::Batch(..))`")]
    pub fn flush_batch(&mut self) -> anyhow::Result<Vec<Result<KernelReply, BatchError>>> {
        if self.queued.is_empty() {
            return Ok(Vec::new());
        }
        let requests = std::mem::take(&mut self.queued);
        let n = requests.len();
        match self.call(Op::Batch(requests.clone())).and_then(|r| r.into_batch(n)) {
            Ok(replies) => Ok(replies),
            Err(e) => {
                self.queued = requests;
                Err(e)
            }
        }
    }

    /// N `get_kernel` requests in one frame over one socket write.
    #[deprecated(note = "use `call(Op::Batch(requests.to_vec()))?.into_batch(n)`")]
    pub fn get_kernel_batch(
        &mut self,
        requests: &[BatchRequest],
    ) -> anyhow::Result<Vec<Result<KernelReply, BatchError>>> {
        let n = requests.len();
        self.call(Op::Batch(requests.to_vec()))?.into_batch(n)
    }

    /// Scalar serving counters.
    #[deprecated(note = "use `call(Op::Stats)?.into_stats()`")]
    pub fn stats(&mut self) -> anyhow::Result<StatsReply> {
        self.call(Op::Stats)?.into_stats()
    }

    /// Full telemetry snapshot: counters plus the reply-time and
    /// per-stage histograms (the `stats` op carries only scalars).
    #[deprecated(note = "use `call(Op::Metrics)?.into_metrics()`")]
    pub fn metrics(&mut self) -> anyhow::Result<MetricsReply> {
        self.call(Op::Metrics)?.into_metrics()
    }

    /// The daemon's retained request traces, slowest first.
    #[deprecated(note = "use `call(Op::Traces { slowest })?.into_traces()`")]
    pub fn traces(&mut self, slowest: usize) -> anyhow::Result<TraceReply> {
        self.call(Op::Traces { slowest })?.into_traces()
    }

    /// The daemon's SLO verdicts + drift-watchdog state.
    #[deprecated(note = "use `call(Op::Health)?.into_health()`")]
    pub fn health(&mut self) -> anyhow::Result<HealthReply> {
        self.call(Op::Health)?.into_health()
    }
}

/// Frame one request for the binary wire: a trace-less `get_kernel`
/// rides the fixed-layout kind-1 encoding (no JSON on the hot path);
/// everything else — including a traced `get_kernel`, whose trace id
/// the compact layout deliberately does not carry — rides a kind-0
/// JSON frame. Same bytes either way as far as the daemon's reply
/// contract is concerned.
fn encode_binary_request(req: &Request, tag: u64, buf: &mut Vec<u8>) {
    if let Request::GetKernel { workload, gpu, mode, trace: None, .. } = req {
        wire::Frame {
            tag,
            kind: wire::KIND_GET_KERNEL,
            payload: wire::encode_get_kernel(workload, *gpu, *mode),
        }
        .encode_into(buf);
    } else {
        wire::Frame::json(tag, &req.to_json()).encode_into(buf);
    }
}

fn decode_binary_reply(frame: wire::Frame) -> anyhow::Result<Reply> {
    match frame.kind {
        wire::KIND_KERNEL_REPLY => wire::decode_kernel_reply(frame.tag, &frame.payload)
            .map(Response::Kernel)
            .map_err(|e| anyhow!("bad kernel reply frame: {e}")),
        wire::KIND_JSON => {
            let text =
                std::str::from_utf8(&frame.payload).context("reply frame payload utf-8")?;
            Response::parse_line(text).map_err(|e| anyhow!("bad response frame: {e} ({text})"))
        }
        other => Err(anyhow!("unknown reply frame kind {other}")),
    }
}

/// A fleet-wide metrics merge plus the daemons that could not answer.
/// Partial by design: one dead daemon must not blind the operator to
/// the rest of the fleet (the old all-or-nothing merge aborted on the
/// first unreachable address).
#[derive(Debug)]
pub struct FleetMetrics {
    /// Exact merge over every daemon that answered.
    pub merged: MetricsReply,
    /// `(address, error)` per daemon that did NOT answer.
    pub errors: Vec<(String, String)>,
}

/// Fleet-wide telemetry: query every daemon's `metrics` op and merge.
/// Histogram merging is exact — the result equals the histogram a
/// single daemon would have recorded over the union of all samples —
/// so fleet-wide quantiles carry the same one-bucket error bound as a
/// single daemon's. Unreachable daemons are reported alongside the
/// merge, not turned into a whole-fleet failure; only an empty address
/// list or a fleet with NO reachable daemon is an `Err`.
pub fn merged_metrics(addrs: &[ServeAddr]) -> anyhow::Result<FleetMetrics> {
    anyhow::ensure!(!addrs.is_empty(), "no daemon addresses to query");
    let mut merged: Option<MetricsReply> = None;
    let mut errors: Vec<(String, String)> = Vec::new();
    for addr in addrs {
        let answer = ServeClient::connect(addr)
            .and_then(|mut c| c.call(Op::Metrics))
            .and_then(Reply::into_metrics);
        match answer {
            Ok(m) => match &mut merged {
                Some(acc) => acc.merge(&m),
                None => merged = Some(m),
            },
            Err(e) => errors.push((addr.to_string(), format!("{e:#}"))),
        }
    }
    match merged {
        Some(merged) => Ok(FleetMetrics { merged, errors }),
        None => {
            let detail: Vec<String> =
                errors.iter().map(|(a, e)| format!("{a}: {e}")).collect();
            Err(anyhow!("no daemon reachable ({})", detail.join("; ")))
        }
    }
}

/// A fleet-wide health merge plus the daemons that could not answer —
/// same partial-merge contract as [`FleetMetrics`].
#[derive(Debug)]
pub struct FleetHealth {
    /// Worst-of-per-target merge over every daemon that answered,
    /// including a synthesized `fleet_reachability` target that goes
    /// critical naming each dead address.
    pub merged: HealthReply,
    /// `(address, error)` per daemon that did NOT answer.
    pub errors: Vec<(String, String)>,
}

/// Fleet-wide health: query every daemon's `health` op and fold the
/// verdicts worst-of per target ([`HealthReply::merge_worst`]) — the
/// fleet is exactly as healthy as its least healthy member. A daemon
/// that cannot answer does not abort the merge; instead the
/// synthesized `fleet_reachability` target goes `critical` and its
/// reason names every dead address, so a half-dead fleet pages loudly
/// while the surviving members' verdicts stay visible. Only an empty
/// address list or a fleet with NO reachable daemon is an `Err`.
pub fn merged_health(addrs: &[ServeAddr]) -> anyhow::Result<FleetHealth> {
    anyhow::ensure!(!addrs.is_empty(), "no daemon addresses to query");
    let mut merged: Option<HealthReply> = None;
    let mut errors: Vec<(String, String)> = Vec::new();
    for addr in addrs {
        let answer = ServeClient::connect(addr)
            .and_then(|mut c| c.call(Op::Health))
            .and_then(Reply::into_health);
        match answer {
            Ok(h) => match &mut merged {
                Some(acc) => acc.merge_worst(&h),
                None => merged = Some(h),
            },
            Err(e) => errors.push((addr.to_string(), format!("{e:#}"))),
        }
    }
    let Some(mut merged) = merged else {
        let detail: Vec<String> = errors.iter().map(|(a, e)| format!("{a}: {e}")).collect();
        return Err(anyhow!("no daemon reachable ({})", detail.join("; ")));
    };
    let reachability = if errors.is_empty() {
        HealthTarget {
            name: "fleet_reachability".into(),
            status: HealthStatus::Ok,
            reason: format!("all {} daemon(s) answered", addrs.len()),
            value: addrs.len() as f64,
            fast_value: addrs.len() as f64,
            threshold: addrs.len() as f64,
        }
    } else {
        let dead: Vec<&str> = errors.iter().map(|(a, _)| a.as_str()).collect();
        HealthTarget {
            name: "fleet_reachability".into(),
            status: HealthStatus::Critical,
            reason: format!("unreachable: {}", dead.join(", ")),
            value: (addrs.len() - errors.len()) as f64,
            fast_value: (addrs.len() - errors.len()) as f64,
            threshold: addrs.len() as f64,
        }
    };
    merged.status = merged.status.worst(reachability.status);
    merged.targets.push(reachability);
    Ok(FleetHealth { merged, errors })
}
