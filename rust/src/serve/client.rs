//! A small blocking client for the kernel-serving daemon (used by
//! `ecokernel query` and the fleet examples). Transport-agnostic: the
//! same frames flow over `unix:` and `tcp:` addresses.
//!
//! Two request shapes:
//!
//! * one frame per call ([`ServeClient::get_kernel`] etc.) — one write
//!   syscall per request;
//! * the pipelined batch path ([`ServeClient::queue_get_kernel`] +
//!   [`ServeClient::flush_batch`], or [`ServeClient::get_kernel_batch`]
//!   directly) — N queued requests packed into ONE `batch` frame and
//!   ONE write syscall, answered by one positionally-matched
//!   `batch` reply.

use super::protocol::{
    BatchItem, HealthReply, HealthStatus, HealthTarget, KernelReply, MetricsReply, Reject,
    Request, Response, StatsReply, TraceReply, MAX_BATCH_ITEMS,
};
use crate::config::{GpuArch, SearchMode};
use crate::fleet::{ServeAddr, Stream};
use crate::workload::Workload;
use anyhow::{anyhow, Context as _};
use std::io::{BufRead as _, BufReader, Write as _};
use std::time::{Duration, Instant};

/// One queued `get_kernel` for the batch path.
pub type BatchRequest = (Workload, Option<GpuArch>, Option<SearchMode>);

/// A positional failure inside a batch reply: the daemon rejected that
/// entry (its siblings were still served).
#[derive(Debug, Clone, PartialEq)]
pub struct BatchError {
    pub code: String,
    pub message: String,
}

impl std::fmt::Display for BatchError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "[{}] {}", self.code, self.message)
    }
}

/// One connection to a serving daemon. Requests are sequential
/// (send a frame, read the reply line).
pub struct ServeClient {
    stream: Stream,
    reader: BufReader<Stream>,
    next_id: u64,
    queued: Vec<BatchRequest>,
}

impl ServeClient {
    pub fn connect(addr: &ServeAddr) -> anyhow::Result<ServeClient> {
        let stream = Stream::connect(addr)?;
        let reader = BufReader::new(stream.try_clone().context("clone daemon stream")?);
        Ok(ServeClient { stream, reader, next_id: 0, queued: Vec::new() })
    }

    fn fresh_id(&mut self) -> String {
        self.next_id += 1;
        format!("c{}", self.next_id)
    }

    /// Send one frame line in ONE write syscall: the newline is packed
    /// into the same buffer, never a second write (the whole point of
    /// the batch path is frames-per-syscall, so the transport must not
    /// quietly fragment).
    fn send_line(&mut self, line: &str) -> anyhow::Result<()> {
        let mut bytes = Vec::with_capacity(line.len() + 1);
        bytes.extend_from_slice(line.as_bytes());
        bytes.push(b'\n');
        self.stream.write_all(&bytes).context("send frame")?;
        self.stream.flush().context("flush frame")
    }

    /// Send one raw line and read one raw reply line (tests use this to
    /// probe malformed / version-mismatched frames).
    pub fn roundtrip_raw(&mut self, line: &str) -> anyhow::Result<String> {
        self.send_line(line)?;
        let mut reply = String::new();
        let n = self.reader.read_line(&mut reply).context("read reply")?;
        anyhow::ensure!(n > 0, "daemon closed the connection");
        Ok(reply.trim_end().to_string())
    }

    fn roundtrip(&mut self, req: &Request) -> anyhow::Result<Response> {
        let line = self.roundtrip_raw(&req.to_json().to_string())?;
        Response::parse_line(&line).map_err(|e| anyhow!("bad response frame: {e} ({line})"))
    }

    /// One `get_kernel` request.
    pub fn get_kernel(
        &mut self,
        workload: Workload,
        gpu: Option<GpuArch>,
        mode: Option<SearchMode>,
    ) -> anyhow::Result<KernelReply> {
        self.get_kernel_traced(workload, gpu, mode, None)
    }

    /// One `get_kernel` carrying a caller-chosen trace id (hex). A
    /// reserving miss adopts it as the distributed trace's id, so a
    /// client can correlate its own request log with `query --trace`
    /// output fleet-wide; `None` lets the daemon mint one.
    pub fn get_kernel_traced(
        &mut self,
        workload: Workload,
        gpu: Option<GpuArch>,
        mode: Option<SearchMode>,
        trace: Option<&str>,
    ) -> anyhow::Result<KernelReply> {
        let id = self.fresh_id();
        let trace = trace.map(|t| t.to_string());
        match self.roundtrip(&Request::GetKernel { id, workload, gpu, mode, trace })? {
            Response::Kernel(r) => Ok(r),
            Response::Error { code, message, .. } => {
                Err(anyhow!("daemon error [{code}]: {message}"))
            }
            other => Err(anyhow!("unexpected response {other:?}")),
        }
    }

    /// Queue one `get_kernel` for the next [`ServeClient::flush_batch`].
    /// Nothing is written yet.
    pub fn queue_get_kernel(
        &mut self,
        workload: Workload,
        gpu: Option<GpuArch>,
        mode: Option<SearchMode>,
    ) {
        self.queued.push((workload, gpu, mode));
    }

    /// Requests queued for the next flush.
    pub fn queued_len(&self) -> usize {
        self.queued.len()
    }

    /// Pack every queued request into ONE `batch` frame — one write
    /// syscall — and return the positionally-matched replies (entry
    /// *i* answers the *i*-th queued request). An empty queue is a
    /// no-op; on a failed flush the queue is restored, so nothing a
    /// caller queued is silently lost.
    pub fn flush_batch(&mut self) -> anyhow::Result<Vec<Result<KernelReply, BatchError>>> {
        if self.queued.is_empty() {
            return Ok(Vec::new());
        }
        let requests = std::mem::take(&mut self.queued);
        match self.get_kernel_batch(&requests) {
            Ok(replies) => Ok(replies),
            Err(e) => {
                self.queued = requests;
                Err(e)
            }
        }
    }

    /// N `get_kernel` requests in one frame over one socket write.
    /// Batches are capped at [`MAX_BATCH_ITEMS`] — enforced here too,
    /// so an oversized batch fails before any bytes hit the wire.
    pub fn get_kernel_batch(
        &mut self,
        requests: &[BatchRequest],
    ) -> anyhow::Result<Vec<Result<KernelReply, BatchError>>> {
        anyhow::ensure!(!requests.is_empty(), "empty batch");
        anyhow::ensure!(
            requests.len() <= MAX_BATCH_ITEMS,
            "batch of {} exceeds the {MAX_BATCH_ITEMS}-request cap (split it into chunks)",
            requests.len()
        );
        let batch_id = self.fresh_id();
        let items: Vec<Result<BatchItem, Reject>> = requests
            .iter()
            .enumerate()
            .map(|(i, &(workload, gpu, mode))| {
                Ok(BatchItem { id: format!("{batch_id}.{i}"), workload, gpu, mode })
            })
            .collect();
        match self.roundtrip(&Request::Batch { id: batch_id.clone(), items })? {
            Response::Batch { id, replies } => {
                anyhow::ensure!(
                    id == batch_id,
                    "batch reply id '{id}' does not echo request id '{batch_id}'"
                );
                anyhow::ensure!(
                    replies.len() == requests.len(),
                    "batch of {} requests got {} replies",
                    requests.len(),
                    replies.len()
                );
                replies
                    .into_iter()
                    .map(|reply| match reply {
                        Response::Kernel(k) => Ok(Ok(k)),
                        Response::Error { code, message, .. } => {
                            Ok(Err(BatchError { code, message }))
                        }
                        other => Err(anyhow!("unexpected batch entry {other:?}")),
                    })
                    .collect()
            }
            Response::Error { code, message, .. } => {
                Err(anyhow!("daemon error [{code}]: {message}"))
            }
            other => Err(anyhow!("unexpected response {other:?}")),
        }
    }

    /// Poll `get_kernel` until the store serves an exact hit (the
    /// background search for a first-seen workload has landed), or the
    /// timeout expires. Returns the hit reply.
    pub fn get_kernel_wait(
        &mut self,
        workload: Workload,
        gpu: Option<GpuArch>,
        mode: Option<SearchMode>,
        timeout: Duration,
    ) -> anyhow::Result<KernelReply> {
        let start = Instant::now();
        loop {
            let reply = self.get_kernel(workload, gpu, mode)?;
            if reply.hit {
                return Ok(reply);
            }
            if start.elapsed() > timeout {
                return Err(anyhow!(
                    "no hit for {workload} within {:.0}s (queue depth {})",
                    timeout.as_secs_f64(),
                    reply.queue_depth
                ));
            }
            std::thread::sleep(Duration::from_millis(100));
        }
    }

    pub fn stats(&mut self) -> anyhow::Result<StatsReply> {
        let id = self.fresh_id();
        match self.roundtrip(&Request::Stats { id })? {
            Response::Stats(r) => Ok(r),
            Response::Error { code, message, .. } => {
                Err(anyhow!("daemon error [{code}]: {message}"))
            }
            other => Err(anyhow!("unexpected response {other:?}")),
        }
    }

    /// Poll `stats` until every admitted search has been written back
    /// — no key queued, backlogged, running, or awaiting write-back
    /// (`pending_keys == 0`) — or the timeout expires. `pending_keys`
    /// subsumes the worker-queue depth on a current daemon (a key
    /// leaves the pending set only after its record landed), but the
    /// pool depth is checked too: a pre-split daemon's frames lack
    /// `pending_keys` (parsed as 0) while their `queue_depth` carries
    /// the old pending-key meaning, so this stays a real drain signal
    /// against both generations.
    pub fn wait_for_drain(&mut self, timeout: Duration) -> anyhow::Result<StatsReply> {
        let start = Instant::now();
        loop {
            let s = self.stats()?;
            if s.pending_keys == 0 && s.queue_depth == 0 {
                return Ok(s);
            }
            if start.elapsed() > timeout {
                return Err(anyhow!(
                    "searches not drained within {:.0}s ({} keys pending, pool depth {})",
                    timeout.as_secs_f64(),
                    s.pending_keys,
                    s.queue_depth
                ));
            }
            std::thread::sleep(Duration::from_millis(100));
        }
    }

    /// Full telemetry snapshot: counters plus the reply-time and
    /// per-stage histograms (the `stats` op carries only scalars).
    pub fn metrics(&mut self) -> anyhow::Result<MetricsReply> {
        let id = self.fresh_id();
        match self.roundtrip(&Request::Metrics { id })? {
            Response::Metrics(r) => Ok(r),
            Response::Error { code, message, .. } => {
                Err(anyhow!("daemon error [{code}]: {message}"))
            }
            other => Err(anyhow!("unexpected response {other:?}")),
        }
    }

    /// The daemon's retained request traces, slowest first
    /// (`slowest == 0` asks for every completed trace the ring holds).
    pub fn traces(&mut self, slowest: usize) -> anyhow::Result<TraceReply> {
        let id = self.fresh_id();
        match self.roundtrip(&Request::Traces { id, slowest })? {
            Response::Trace(r) => Ok(r),
            Response::Error { code, message, .. } => {
                Err(anyhow!("daemon error [{code}]: {message}"))
            }
            other => Err(anyhow!("unexpected response {other:?}")),
        }
    }

    /// The daemon's SLO verdicts + drift-watchdog state (the `health`
    /// wire op).
    pub fn health(&mut self) -> anyhow::Result<HealthReply> {
        let id = self.fresh_id();
        match self.roundtrip(&Request::Health { id })? {
            Response::Health(r) => Ok(r),
            Response::Error { code, message, .. } => {
                Err(anyhow!("daemon error [{code}]: {message}"))
            }
            other => Err(anyhow!("unexpected response {other:?}")),
        }
    }

    /// Graceful daemon stop (acked before the daemon drains and exits).
    pub fn shutdown(&mut self) -> anyhow::Result<()> {
        let id = self.fresh_id();
        match self.roundtrip(&Request::Shutdown { id })? {
            Response::ShutdownAck { .. } => Ok(()),
            Response::Error { code, message, .. } => {
                Err(anyhow!("daemon error [{code}]: {message}"))
            }
            other => Err(anyhow!("unexpected response {other:?}")),
        }
    }
}

/// A fleet-wide metrics merge plus the daemons that could not answer.
/// Partial by design: one dead daemon must not blind the operator to
/// the rest of the fleet (the old all-or-nothing merge aborted on the
/// first unreachable address).
#[derive(Debug)]
pub struct FleetMetrics {
    /// Exact merge over every daemon that answered.
    pub merged: MetricsReply,
    /// `(address, error)` per daemon that did NOT answer.
    pub errors: Vec<(String, String)>,
}

/// Fleet-wide telemetry: query every daemon's `metrics` op and merge.
/// Histogram merging is exact — the result equals the histogram a
/// single daemon would have recorded over the union of all samples —
/// so fleet-wide quantiles carry the same one-bucket error bound as a
/// single daemon's. Unreachable daemons are reported alongside the
/// merge, not turned into a whole-fleet failure; only an empty address
/// list or a fleet with NO reachable daemon is an `Err`.
pub fn merged_metrics(addrs: &[ServeAddr]) -> anyhow::Result<FleetMetrics> {
    anyhow::ensure!(!addrs.is_empty(), "no daemon addresses to query");
    let mut merged: Option<MetricsReply> = None;
    let mut errors: Vec<(String, String)> = Vec::new();
    for addr in addrs {
        match ServeClient::connect(addr).and_then(|mut c| c.metrics()) {
            Ok(m) => match &mut merged {
                Some(acc) => acc.merge(&m),
                None => merged = Some(m),
            },
            Err(e) => errors.push((addr.to_string(), format!("{e:#}"))),
        }
    }
    match merged {
        Some(merged) => Ok(FleetMetrics { merged, errors }),
        None => {
            let detail: Vec<String> =
                errors.iter().map(|(a, e)| format!("{a}: {e}")).collect();
            Err(anyhow!("no daemon reachable ({})", detail.join("; ")))
        }
    }
}

/// A fleet-wide health merge plus the daemons that could not answer —
/// same partial-merge contract as [`FleetMetrics`].
#[derive(Debug)]
pub struct FleetHealth {
    /// Worst-of-per-target merge over every daemon that answered,
    /// including a synthesized `fleet_reachability` target that goes
    /// critical naming each dead address.
    pub merged: HealthReply,
    /// `(address, error)` per daemon that did NOT answer.
    pub errors: Vec<(String, String)>,
}

/// Fleet-wide health: query every daemon's `health` op and fold the
/// verdicts worst-of per target ([`HealthReply::merge_worst`]) — the
/// fleet is exactly as healthy as its least healthy member. A daemon
/// that cannot answer does not abort the merge; instead the
/// synthesized `fleet_reachability` target goes `critical` and its
/// reason names every dead address, so a half-dead fleet pages loudly
/// while the surviving members' verdicts stay visible. Only an empty
/// address list or a fleet with NO reachable daemon is an `Err`.
pub fn merged_health(addrs: &[ServeAddr]) -> anyhow::Result<FleetHealth> {
    anyhow::ensure!(!addrs.is_empty(), "no daemon addresses to query");
    let mut merged: Option<HealthReply> = None;
    let mut errors: Vec<(String, String)> = Vec::new();
    for addr in addrs {
        match ServeClient::connect(addr).and_then(|mut c| c.health()) {
            Ok(h) => match &mut merged {
                Some(acc) => acc.merge_worst(&h),
                None => merged = Some(h),
            },
            Err(e) => errors.push((addr.to_string(), format!("{e:#}"))),
        }
    }
    let Some(mut merged) = merged else {
        let detail: Vec<String> = errors.iter().map(|(a, e)| format!("{a}: {e}")).collect();
        return Err(anyhow!("no daemon reachable ({})", detail.join("; ")));
    };
    let reachability = if errors.is_empty() {
        HealthTarget {
            name: "fleet_reachability".into(),
            status: HealthStatus::Ok,
            reason: format!("all {} daemon(s) answered", addrs.len()),
            value: addrs.len() as f64,
            fast_value: addrs.len() as f64,
            threshold: addrs.len() as f64,
        }
    } else {
        let dead: Vec<&str> = errors.iter().map(|(a, _)| a.as_str()).collect();
        HealthTarget {
            name: "fleet_reachability".into(),
            status: HealthStatus::Critical,
            reason: format!("unreachable: {}", dead.join(", ")),
            value: (addrs.len() - errors.len()) as f64,
            fast_value: (addrs.len() - errors.len()) as f64,
            threshold: addrs.len() as f64,
        }
    };
    merged.status = merged.status.worst(reachability.status);
    merged.targets.push(reachability);
    Ok(FleetHealth { merged, errors })
}
