//! The evented data plane: nonblocking accept + `poll(2)` reactors
//! sized to cores, replacing thread-per-connection (ISSUE 10).
//!
//! # Shape
//!
//! N reactor threads (`available_parallelism`, clamped 1..=8) each own
//! a set of connections and a `poll(2)` loop over them, with
//! per-connection read/write buffers. Reactor 0 also owns the
//! listener (nonblocking): accepted connections are handed round-robin
//! to the other reactors over a channel, each paired with a
//! `socketpair` wake pipe so a sleeping reactor notices the handoff
//! (and slow-lane completions) immediately instead of at the next
//! poll tick.
//!
//! # Fast lane / slow lane
//!
//! Frame dispatch reuses the daemon's seam
//! ([`dispatch_fast`]/[`run_slow`]): parse rejects, admin ops, and
//! `get_kernel` requests whose per-shard memory probe hits are
//! answered INLINE on the reactor thread — microseconds, no blocking
//! I/O beyond the shard read. Memory misses (targeted refresh, fleet
//! claim, search enqueue — file I/O) and whole `batch` frames go to a
//! small slow-lane executor pool; the finished reply lands in the
//! connection's outbox and the owning reactor is woken to write it.
//! The worker pool and write-back path are untouched — the slow lane
//! sits in front of them exactly where the per-connection thread used
//! to.
//!
//! # Ordering: the two wires differ on purpose
//!
//! * **line-JSON** (wire v1): replies are strictly in-order — frame
//!   extraction stalls while a slow reply is outstanding, so the
//!   connection behaves byte-identically to the blocking
//!   thread-per-connection daemon (pinned by e2e).
//! * **binary** (wire v2, negotiated via `hello`): frames carry
//!   client-assigned tags and extraction NEVER stalls — a hit behind
//!   a slow miss is answered the moment its shard read completes, out
//!   of order, tagged. This is the head-of-line-blocking fix the
//!   `n_ooo_replies` counter measures.
//!
//! # Lock discipline
//!
//! Reactor threads never bind a `state` guard at all — all state
//! access happens inside the daemon's serve functions or one-liner
//! counter helpers, and NO socket write ever happens with a state
//! guard live (`scripts/check_invariants.py` scans this file too).

use super::daemon::{
    dispatch_fast, note_reply_write, run_slow, serve_get_kernel, Ctx, FrameAction, SlowJob,
    SlowReplyBody,
};
use super::protocol::{error_code, wire, wire_name, Response};
use crate::fleet::{Listener, Stream};
use crate::telemetry::TraceId;
use std::collections::HashMap;
use std::io::{ErrorKind, Read as _, Write as _};
use std::os::unix::io::AsRawFd as _;
use std::os::unix::net::UnixStream;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::time::Instant;

// ---------------------------------------------------------------------------
// poll(2), hand-rolled on std (no libc crate in this tree): the one
// syscall the reactor needs, declared directly.

#[repr(C)]
struct PollFd {
    fd: i32,
    events: i16,
    revents: i16,
}

const POLLIN: i16 = 0x001;
const POLLOUT: i16 = 0x004;
const POLLERR: i16 = 0x008;
const POLLHUP: i16 = 0x010;
const POLLNVAL: i16 = 0x020;

#[cfg(target_os = "linux")]
type Nfds = std::os::raw::c_ulong;
#[cfg(not(target_os = "linux"))]
type Nfds = std::os::raw::c_uint;

extern "C" {
    fn poll(fds: *mut PollFd, nfds: Nfds, timeout: i32) -> i32;
}

fn poll_fds(fds: &mut [PollFd], timeout_ms: i32) -> i32 {
    // SAFETY: `fds` is an exclusive slice of repr(C) pollfd structs,
    // valid for the duration of the call; the kernel only writes the
    // `revents` fields.
    unsafe { poll(fds.as_mut_ptr(), fds.len() as Nfds, timeout_ms) }
}

/// Poll tick: the backstop latency for noticing `shutting` without a
/// wake byte. Every hot transition (new conn, slow reply, shutdown)
/// also writes a wake byte, so this is never on the request path.
const POLL_TICK_MS: i32 = 250;
/// Per-`read` chunk; also the partial-read heuristic boundary.
const READ_CHUNK: usize = 16 * 1024;
/// Soft cap on either per-connection buffer: past it the reactor stops
/// reading (backpressure) rather than buffering a hostile peer to OOM.
const MAX_BUFFER: usize = 32 << 20;

/// Entry point: serve until shutdown, then return so [`Daemon::run`]
/// can drain the worker pool and writer exactly as before.
///
/// [`Daemon::run`]: super::daemon::Daemon::run
pub(super) fn serve(listener: Listener, ctx: Arc<Ctx>) {
    if let Err(e) = listener.set_nonblocking(true) {
        eprintln!("serve: nonblocking listener unavailable ({e}); accepts may stall briefly");
    }
    let n_reactors =
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(2).clamp(1, 8);
    let n_slow = (n_reactors * 2).clamp(2, 16);

    let (slow_tx, slow_rx) = channel::<SlowTask>();
    let slow_rx = Arc::new(Mutex::new(slow_rx));
    let slow_threads: Vec<_> = (0..n_slow)
        .map(|_| {
            let ctx = Arc::clone(&ctx);
            let rx = Arc::clone(&slow_rx);
            std::thread::spawn(move || slow_loop(&ctx, &rx))
        })
        .collect();

    let mut mailboxes = Vec::with_capacity(n_reactors);
    let mut inboxes = Vec::with_capacity(n_reactors);
    for _ in 0..n_reactors {
        let (conn_tx, conn_rx) = channel::<Stream>();
        // A daemon that cannot open a socketpair at startup cannot
        // serve sockets either; failing loudly here is correct.
        let (wake_rx, wake_tx) = UnixStream::pair().expect("reactor wake pipe");
        let _ = wake_rx.set_nonblocking(true);
        let _ = wake_tx.set_nonblocking(true);
        mailboxes.push(Mailbox { conn_tx, wake: Arc::new(wake_tx) });
        inboxes.push((conn_rx, wake_rx));
    }
    let mailboxes = Arc::new(mailboxes);

    let mut reactors: Vec<Reactor> = inboxes
        .into_iter()
        .enumerate()
        .map(|(idx, (conn_rx, wake_rx))| Reactor {
            idx,
            ctx: Arc::clone(&ctx),
            conns: HashMap::new(),
            next_token: 0,
            next_rr: 0,
            conn_rx,
            wake_rx,
            wake_tx: Arc::clone(&mailboxes[idx].wake),
            mailboxes: Arc::clone(&mailboxes),
            slow_tx: slow_tx.clone(),
            listener: None,
        })
        .collect();
    drop(slow_tx);

    let mut first = reactors.remove(0);
    first.listener = Some(listener);
    let handles: Vec<_> =
        reactors.into_iter().map(|r| std::thread::spawn(move || r.run())).collect();
    first.run();
    for h in handles {
        let _ = h.join();
    }
    // Every reactor's slow_tx clone is dropped now: the channel closes
    // and the executor threads drain out.
    for h in slow_threads {
        let _ = h.join();
    }
}

/// How a slow-lane reply must be framed when it comes back.
enum ReplyEncoding {
    /// Line-JSON + `\n`; delivery also unblocks frame extraction
    /// (line mode is strictly in-order).
    Line,
    /// Kind-0 JSON frame echoing the request's tag.
    BinaryJson { tag: u64 },
    /// Kind-2 fixed-layout kernel reply (errors fall back to kind-0).
    BinaryKernel { tag: u64 },
}

struct SlowTask {
    job: SlowJob,
    shared: Arc<ConnShared>,
    encoding: ReplyEncoding,
}

/// Slow-lane executor body: finish jobs, drop replies into the owning
/// connection's outbox, wake its reactor. Exits when every reactor
/// (every sender) is gone.
fn slow_loop(ctx: &Arc<Ctx>, rx: &Mutex<Receiver<SlowTask>>) {
    loop {
        let task = {
            let rx = rx.lock().expect("slow-lane queue lock");
            rx.recv()
        };
        let Ok(task) = task else { break };
        let (body, opened) = run_slow(ctx, task.job);
        task.shared.push(encode_slow_reply(body, opened, &task.encoding));
    }
}

/// Frame one finished slow-lane reply for its wire.
fn encode_slow_reply(
    body: SlowReplyBody,
    opened: Option<TraceId>,
    encoding: &ReplyEncoding,
) -> OutMsg {
    let (bytes, tag, unblock_line) = match encoding {
        ReplyEncoding::Line => {
            let mut bytes = body.into_json().to_string().into_bytes();
            bytes.push(b'\n');
            (bytes, None, true)
        }
        ReplyEncoding::BinaryJson { tag } => {
            (wire::Frame::json(*tag, &body.into_json()).encode(), Some(*tag), false)
        }
        ReplyEncoding::BinaryKernel { tag } => {
            let frame = match body {
                SlowReplyBody::Kernel(reply) => wire::Frame {
                    tag: *tag,
                    kind: wire::KIND_KERNEL_REPLY,
                    payload: wire::encode_kernel_reply(&reply),
                },
                other => wire::Frame::json(*tag, &other.into_json()),
            };
            (frame.encode(), Some(*tag), false)
        }
    };
    OutMsg { bytes, traced: true, opened, tag, shutdown: false, unblock_line }
}

/// The cross-thread half of one connection: where the slow lane parks
/// finished replies, and how it wakes the owning reactor.
struct ConnShared {
    outbox: Mutex<Vec<OutMsg>>,
    /// Slow jobs submitted and not yet parked in the outbox.
    inflight: AtomicUsize,
    /// Write end of the owning reactor's wake pipe.
    wake: Arc<UnixStream>,
}

impl ConnShared {
    fn push(&self, msg: OutMsg) {
        self.outbox.lock().expect("outbox lock").push(msg);
        self.inflight.fetch_sub(1, Ordering::SeqCst);
        let _ = (&*self.wake).write(&[1u8]);
    }
}

/// One reply's bytes plus its post-write bookkeeping.
struct OutMsg {
    bytes: Vec<u8>,
    /// Kernel-serving replies record the reply-write stage.
    traced: bool,
    /// Trace opened by this frame — it gets the reply-write span.
    opened: Option<TraceId>,
    /// Binary reply tag, for arrival-order (OOO) bookkeeping.
    tag: Option<u64>,
    /// This reply acked a `shutdown` request.
    shutdown: bool,
    /// Line mode: resume frame extraction (the slow reply the
    /// connection was waiting on, in-order contract satisfied).
    unblock_line: bool,
}

impl OutMsg {
    fn plain(bytes: Vec<u8>) -> OutMsg {
        OutMsg {
            bytes,
            traced: false,
            opened: None,
            tag: None,
            shutdown: false,
            unblock_line: false,
        }
    }
}

#[derive(Clone, Copy, PartialEq, Eq)]
enum WireMode {
    Line,
    Binary,
}

struct Conn {
    stream: Stream,
    /// Unconsumed inbound bytes (partial frames span reads).
    rbuf: Vec<u8>,
    /// Outbound bytes not yet accepted by the socket; `wstart` is the
    /// write cursor (drained lazily to avoid per-write memmoves).
    wbuf: Vec<u8>,
    wstart: usize,
    mode: WireMode,
    /// Line mode only: a slow reply is outstanding — extraction is
    /// stalled to keep replies strictly in-order.
    line_blocked: bool,
    /// Close once the write buffer drains (post-shutdown-ack).
    closing: bool,
    /// Binary mode: tags in arrival order, not yet answered. A reply
    /// leaving from position > 0 is an out-of-order reply (a fast
    /// reply that overtook a slow sibling).
    pending_order: Vec<u64>,
    shared: Arc<ConnShared>,
}

impl Conn {
    fn new(stream: Stream, wake: Arc<UnixStream>) -> Conn {
        Conn {
            stream,
            rbuf: Vec::new(),
            wbuf: Vec::new(),
            wstart: 0,
            mode: WireMode::Line,
            line_blocked: false,
            closing: false,
            pending_order: Vec::new(),
            shared: Arc::new(ConnShared {
                outbox: Mutex::new(Vec::new()),
                inflight: AtomicUsize::new(0),
                wake,
            }),
        }
    }

    fn has_pending_write(&self) -> bool {
        self.wstart < self.wbuf.len()
    }

    fn pending_slow(&self) -> usize {
        self.shared.inflight.load(Ordering::SeqCst)
    }

    /// Push buffered output as far as the socket will take it without
    /// blocking. Returns false when the connection is dead.
    fn try_flush(&mut self) -> bool {
        while self.wstart < self.wbuf.len() {
            match self.stream.write(&self.wbuf[self.wstart..]) {
                Ok(0) => return false,
                Ok(n) => self.wstart += n,
                Err(e) if e.kind() == ErrorKind::WouldBlock => break,
                Err(e) if e.kind() == ErrorKind::Interrupted => continue,
                Err(_) => return false,
            }
        }
        if self.wstart == self.wbuf.len() {
            self.wbuf.clear();
            self.wstart = 0;
        } else if self.wstart > 64 * 1024 {
            self.wbuf.drain(..self.wstart);
            self.wstart = 0;
        }
        true
    }
}

/// Conn handoff + wakeup for one reactor.
struct Mailbox {
    conn_tx: Sender<Stream>,
    wake: Arc<UnixStream>,
}

impl Mailbox {
    fn wake(&self) {
        // A full pipe already has wakeups pending; dropping the byte
        // is fine.
        let _ = (&*self.wake).write(&[1u8]);
    }
}

struct Reactor {
    idx: usize,
    ctx: Arc<Ctx>,
    conns: HashMap<u64, Conn>,
    next_token: u64,
    /// Round-robin cursor for conn placement (reactor 0 only).
    next_rr: usize,
    conn_rx: Receiver<Stream>,
    wake_rx: UnixStream,
    wake_tx: Arc<UnixStream>,
    mailboxes: Arc<Vec<Mailbox>>,
    slow_tx: Sender<SlowTask>,
    /// Reactor 0 owns the listener.
    listener: Option<Listener>,
}

impl Reactor {
    fn run(mut self) {
        loop {
            self.maintain();
            let shutting = self.ctx.is_shutting();
            if shutting && self.drained() {
                break;
            }
            let mut fds: Vec<PollFd> = Vec::with_capacity(2 + self.conns.len());
            let mut tokens: Vec<u64> = Vec::with_capacity(self.conns.len());
            fds.push(PollFd { fd: self.wake_rx.as_raw_fd(), events: POLLIN, revents: 0 });
            let mut base = 1;
            let mut poll_listener = false;
            if !shutting {
                if let Some(listener) = &self.listener {
                    fds.push(PollFd { fd: listener.as_raw_fd(), events: POLLIN, revents: 0 });
                    poll_listener = true;
                    base = 2;
                }
            }
            for (&tok, conn) in &self.conns {
                let mut events = 0i16;
                if !conn.closing && conn.rbuf.len() < MAX_BUFFER {
                    events |= POLLIN;
                }
                if conn.has_pending_write() {
                    events |= POLLOUT;
                }
                // events == 0 still surfaces HUP/ERR.
                fds.push(PollFd { fd: conn.stream.as_raw_fd(), events, revents: 0 });
                tokens.push(tok);
            }
            let n = poll_fds(&mut fds, POLL_TICK_MS);
            if n < 0 {
                // EINTR or transient failure: back off one breath and
                // re-poll (the tick bounds the damage either way).
                std::thread::sleep(std::time::Duration::from_millis(5));
                continue;
            }
            if fds[0].revents != 0 {
                self.drain_wake();
            }
            if poll_listener && fds[1].revents != 0 {
                self.accept_ready();
            }
            self.adopt_new_conns();
            for (i, tok) in tokens.iter().enumerate() {
                let revents = fds[base + i].revents;
                if revents != 0 {
                    self.service(*tok, revents);
                }
            }
        }
    }

    /// Drop-box maintenance: deliver slow-lane replies parked in each
    /// connection's outbox, resume unblocked line connections, retire
    /// finished ones.
    fn maintain(&mut self) {
        let toks: Vec<u64> = self.conns.keys().copied().collect();
        for tok in toks {
            let Some(mut conn) = self.conns.remove(&tok) else { continue };
            let msgs = {
                let mut outbox = conn.shared.outbox.lock().expect("outbox lock");
                std::mem::take(&mut *outbox)
            };
            let had_msgs = !msgs.is_empty();
            let mut keep = true;
            for msg in msgs {
                if !self.deliver(&mut conn, msg) {
                    keep = false;
                    break;
                }
            }
            // A line conn freed by its slow reply may have whole
            // frames already buffered: extract them now, not at the
            // next socket read.
            if keep && had_msgs && !conn.rbuf.is_empty() {
                keep = self.extract_frames(&mut conn);
            }
            if keep
                && conn.closing
                && !conn.has_pending_write()
                && conn.pending_slow() == 0
            {
                keep = false;
            }
            if keep {
                self.conns.insert(tok, conn);
            }
        }
    }

    /// True when nothing remains to write or wait for (shutdown exit
    /// gate: in-flight slow replies still get written first).
    fn drained(&self) -> bool {
        self.conns.values().all(|c| {
            c.pending_slow() == 0
                && !c.has_pending_write()
                && c.shared.outbox.lock().expect("outbox lock").is_empty()
        })
    }

    fn drain_wake(&mut self) {
        let mut scratch = [0u8; 256];
        while let Ok(n) = (&self.wake_rx).read(&mut scratch) {
            if n < scratch.len() {
                break;
            }
        }
    }

    /// Accept every waiting connection and place it round-robin
    /// across the reactors (reactor 0 only).
    fn accept_ready(&mut self) {
        loop {
            let accepted = match &self.listener {
                Some(listener) => listener.accept(),
                None => return,
            };
            match accepted {
                Ok(stream) => {
                    let target = self.next_rr % self.mailboxes.len();
                    self.next_rr = self.next_rr.wrapping_add(1);
                    if target == self.idx {
                        self.register(stream);
                    } else if self.mailboxes[target].conn_tx.send(stream).is_ok() {
                        self.mailboxes[target].wake();
                    }
                }
                Err(e) if e.kind() == ErrorKind::WouldBlock => break,
                Err(e) if e.kind() == ErrorKind::Interrupted => continue,
                Err(e) => {
                    if self.ctx.is_shutting() {
                        break;
                    }
                    eprintln!("serve: accept failed: {e}");
                    break;
                }
            }
        }
    }

    fn adopt_new_conns(&mut self) {
        while let Ok(stream) = self.conn_rx.try_recv() {
            self.register(stream);
        }
    }

    fn register(&mut self, stream: Stream) {
        if stream.set_nonblocking(true).is_err() {
            return; // dead on arrival
        }
        let tok = self.next_token;
        self.next_token += 1;
        self.conns.insert(tok, Conn::new(stream, Arc::clone(&self.wake_tx)));
    }

    fn service(&mut self, tok: u64, revents: i16) {
        let Some(mut conn) = self.conns.remove(&tok) else { return };
        let mut keep = revents & (POLLERR | POLLNVAL) == 0;
        if keep && revents & POLLOUT != 0 {
            keep = conn.try_flush();
        }
        if keep && revents & (POLLIN | POLLHUP) != 0 && !conn.closing {
            keep = self.service_read(&mut conn);
        }
        if keep {
            self.conns.insert(tok, conn);
        }
    }

    fn service_read(&mut self, conn: &mut Conn) -> bool {
        let mut chunk = [0u8; READ_CHUNK];
        loop {
            match conn.stream.read(&mut chunk) {
                // EOF: the client is gone, replies have nowhere to go.
                Ok(0) => return false,
                Ok(n) => {
                    conn.rbuf.extend_from_slice(&chunk[..n]);
                    if conn.rbuf.len() >= MAX_BUFFER || n < chunk.len() {
                        break;
                    }
                }
                Err(e) if e.kind() == ErrorKind::WouldBlock => break,
                Err(e) if e.kind() == ErrorKind::Interrupted => continue,
                Err(_) => return false,
            }
        }
        self.extract_frames(conn)
    }

    /// Pull every complete frame out of the read buffer and process
    /// it. Line mode stalls at a slow frame (strict in-order replies);
    /// binary mode never stalls — that is the multiplexing.
    fn extract_frames(&mut self, conn: &mut Conn) -> bool {
        let mut consumed = 0usize;
        let keep = loop {
            match conn.mode {
                WireMode::Line => {
                    if conn.line_blocked {
                        break true;
                    }
                    let rest = &conn.rbuf[consumed..];
                    let Some(nl) = rest.iter().position(|&b| b == b'\n') else { break true };
                    let line = match std::str::from_utf8(&rest[..nl]) {
                        Ok(s) => s.trim_end_matches('\r').to_string(),
                        Err(_) => break false, // not our protocol
                    };
                    consumed += nl + 1;
                    if line.trim().is_empty() {
                        continue;
                    }
                    if !self.process_line(conn, &line) {
                        break false;
                    }
                }
                WireMode::Binary => match wire::Frame::decode(&conn.rbuf[consumed..]) {
                    Ok(None) => break true,
                    Ok(Some((frame, used))) => {
                        consumed += used;
                        self.ctx.note_binary_frames(1);
                        if !self.process_binary(conn, frame) {
                            break false;
                        }
                    }
                    Err(e) => {
                        eprintln!("serve: dropping desynced binary connection: {e}");
                        break false;
                    }
                },
            }
        };
        if consumed > 0 {
            conn.rbuf.drain(..consumed);
        }
        keep
    }

    /// One line-JSON frame. Returns false to drop the connection.
    fn process_line(&mut self, conn: &mut Conn, line: &str) -> bool {
        match dispatch_fast(&self.ctx, line) {
            FrameAction::Reply(frame, shutdown, traced, opened) => {
                let mut bytes = frame.to_string().into_bytes();
                bytes.push(b'\n');
                self.deliver(
                    conn,
                    OutMsg { bytes, traced, opened, tag: None, shutdown, unblock_line: false },
                )
            }
            FrameAction::Hello { id, wire } => {
                let grant = if wire == wire_name::BINARY {
                    wire_name::BINARY
                } else {
                    wire_name::LINE
                };
                let ack = Response::HelloAck { id, wire: grant.to_string() }.to_json();
                let mut bytes = ack.to_string().into_bytes();
                bytes.push(b'\n');
                let keep = self.deliver(conn, OutMsg::plain(bytes));
                // The ack is framed line-JSON (queued above); every
                // frame after it — both directions — is binary.
                if grant == wire_name::BINARY {
                    conn.mode = WireMode::Binary;
                }
                keep
            }
            FrameAction::Slow(job) => {
                conn.line_blocked = true;
                self.submit_slow(conn, job, ReplyEncoding::Line);
                true
            }
        }
    }

    /// One binary frame. Returns false to drop the connection.
    fn process_binary(&mut self, conn: &mut Conn, frame: wire::Frame) -> bool {
        let t0 = Instant::now();
        let tag = frame.tag;
        conn.pending_order.push(tag);
        match frame.kind {
            wire::KIND_GET_KERNEL => match wire::decode_get_kernel(&frame.payload) {
                Ok((workload, gpu, mode)) => {
                    let parse_s = t0.elapsed().as_secs_f64();
                    let id = wire::tag_id(tag);
                    match serve_get_kernel(&self.ctx, id, workload, gpu, mode, t0, parse_s, None)
                    {
                        Ok((reply, opened)) => {
                            let out = wire::Frame {
                                tag,
                                kind: wire::KIND_KERNEL_REPLY,
                                payload: wire::encode_kernel_reply(&reply),
                            };
                            self.deliver(
                                conn,
                                OutMsg {
                                    bytes: out.encode(),
                                    traced: true,
                                    opened,
                                    tag: Some(tag),
                                    shutdown: false,
                                    unblock_line: false,
                                },
                            )
                        }
                        Err(job) => {
                            self.submit_slow(
                                conn,
                                SlowJob::Miss(job),
                                ReplyEncoding::BinaryKernel { tag },
                            );
                            true
                        }
                    }
                }
                Err(msg) => self.deliver_binary_error(conn, tag, msg),
            },
            wire::KIND_JSON => {
                let line = match std::str::from_utf8(&frame.payload) {
                    Ok(s) => s,
                    Err(_) => {
                        return self.deliver_binary_error(
                            conn,
                            tag,
                            "frame payload is not UTF-8 JSON".to_string(),
                        )
                    }
                };
                match dispatch_fast(&self.ctx, line) {
                    FrameAction::Reply(obj, shutdown, traced, opened) => {
                        let bytes = wire::Frame::json(tag, &obj).encode();
                        self.deliver(
                            conn,
                            OutMsg {
                                bytes,
                                traced,
                                opened,
                                tag: Some(tag),
                                shutdown,
                                unblock_line: false,
                            },
                        )
                    }
                    FrameAction::Hello { id, .. } => {
                        // Already binary; re-ack binary, stay put.
                        let ack =
                            Response::HelloAck { id, wire: wire_name::BINARY.to_string() }
                                .to_json();
                        let bytes = wire::Frame::json(tag, &ack).encode();
                        self.deliver(
                            conn,
                            OutMsg {
                                bytes,
                                traced: false,
                                opened: None,
                                tag: Some(tag),
                                shutdown: false,
                                unblock_line: false,
                            },
                        )
                    }
                    FrameAction::Slow(job) => {
                        self.submit_slow(conn, job, ReplyEncoding::BinaryJson { tag });
                        true
                    }
                }
            }
            other => {
                self.deliver_binary_error(conn, tag, format!("unknown frame kind {other}"))
            }
        }
    }

    fn deliver_binary_error(&mut self, conn: &mut Conn, tag: u64, message: String) -> bool {
        let err = Response::Error {
            id: Some(wire::tag_id(tag)),
            code: error_code::BAD_REQUEST.to_string(),
            message,
        }
        .to_json();
        let bytes = wire::Frame::json(tag, &err).encode();
        self.deliver(
            conn,
            OutMsg {
                bytes,
                traced: false,
                opened: None,
                tag: Some(tag),
                shutdown: false,
                unblock_line: false,
            },
        )
    }

    /// Queue one reply's bytes and push them as far toward the socket
    /// as it will take without blocking. All post-write bookkeeping
    /// (reply-write stage, OOO accounting, shutdown, line unblock)
    /// happens here — with NO state guard held anywhere near the
    /// write. Returns false when the connection died mid-write.
    fn deliver(&mut self, conn: &mut Conn, msg: OutMsg) -> bool {
        if let Some(tag) = msg.tag {
            if let Some(pos) = conn.pending_order.iter().position(|&t| t == tag) {
                if pos > 0 {
                    self.ctx.note_ooo_reply();
                }
                conn.pending_order.remove(pos);
            }
        }
        if msg.unblock_line {
            conn.line_blocked = false;
        }
        let t = Instant::now();
        conn.wbuf.extend_from_slice(&msg.bytes);
        let alive = conn.try_flush();
        if msg.traced {
            note_reply_write(&self.ctx, msg.opened, t.elapsed().as_secs_f64());
        }
        if msg.shutdown {
            self.ctx.begin_shutdown();
            self.wake_all();
            conn.closing = true;
        }
        alive
    }

    fn submit_slow(&mut self, conn: &mut Conn, job: SlowJob, encoding: ReplyEncoding) {
        conn.shared.inflight.fetch_add(1, Ordering::SeqCst);
        let task = SlowTask { job, shared: Arc::clone(&conn.shared), encoding };
        if self.slow_tx.send(task).is_err() {
            // Slow lane gone (shutdown drain): count the job back so
            // the conn isn't waited on forever.
            conn.shared.inflight.fetch_sub(1, Ordering::SeqCst);
            conn.line_blocked = false;
        }
    }

    fn wake_all(&self) {
        for mb in self.mailboxes.iter() {
            mb.wake();
        }
    }
}
