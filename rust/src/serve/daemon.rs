//! The kernel-serving daemon: a long-running process answering
//! `get_kernel` requests over a Unix or TCP socket.
//!
//! Request flow:
//!
//! * **exact store hit** — reply immediately with the cached,
//!   NVML-measured kernel (zero measurements, zero search time);
//! * **miss** — reply immediately with the best warm guess (nearest
//!   neighbor's schedule re-legalized for the requested shape, or the
//!   space's fallback), and enqueue a real search on a daemon-owned
//!   [`WorkerPool`]. The finished search is written back into the
//!   sharded store, so the next request for that key is a hit.
//!
//! Fleet behavior (N daemons, one store — see [`crate::fleet`]):
//!
//! * the store opens in **fleet mode**: every miss first refreshes the
//!   key's shard, so a search another daemon already wrote back is
//!   served as a hit without ever searching here;
//! * duplicate misses coalesce at two levels — the in-memory `pending`
//!   set within one daemon, and an in-store [`InflightTable`] claim
//!   across daemons, so a key is searched **once fleet-wide**. Claims
//!   are heartbeat-renewed for the duration of the search; a crashed
//!   owner's claim expires and the key is reclaimed. Write-backs are
//!   epoch-fenced: a daemon that lost its claim mid-search has its
//!   late record rejected;
//! * when the search queue saturates, admission control
//!   ([`crate::fleet::admission`]) backlogs hot keys (pumped into
//!   freed slots in heat order) and sheds cold ones, instead of the
//!   old FIFO drop.

use super::metrics::{reply_time_s, ServeMetrics};
use super::protocol::{KernelReply, Request, Response, ServeSource, StatsReply, PROTOCOL_VERSION};
use crate::config::SearchConfig;
use crate::coordinator::{EventLog, PoolEvent, SearchJob, WorkerPool};
use crate::fleet::{Backlog, HeatSketch, InflightTable, Listener, Offer, ServeAddr, Stream};
use crate::schedule::space::ScheduleSpace;
use crate::store::lease::Lease;
use crate::store::transfer::{relegalize, MAX_TRANSFER_DISTANCE};
use crate::store::{
    config_fingerprint, serve_key, AppendOutcome, EvictionReport, ShardedStore, TuningRecord,
    TuningStore,
};
use crate::util::Json;
use crate::workload::Workload;
use std::collections::{HashMap, HashSet};
use std::io::{BufRead as _, BufReader, Write as _};
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::Receiver;
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;

/// Daemon configuration: where to listen (`unix:`/`tcp:`), where the
/// store lives, and the search template requests run under
/// (per-request `gpu`/`mode` overrides apply on top; the `[serve]` and
/// `[fleet]` sections set shard count, eviction quotas, pool size, and
/// fleet-coordination knobs).
#[derive(Debug, Clone)]
pub struct DaemonConfig {
    pub addr: ServeAddr,
    pub store_dir: PathBuf,
    pub search: SearchConfig,
}

/// A queued-but-not-yet-submitted background search.
type BacklogJob = (SearchJob, Arc<TuningStore>);

/// Mutable daemon state behind one lock.
struct Shared {
    store: ShardedStore,
    /// Parsed snapshot handed to background searches; rebuilt (pointer
    /// clones — records are `Arc`-shared) after every store change.
    snapshot: Arc<TuningStore>,
    /// Serve keys with a search queued, backlogged, or running here.
    pending: HashSet<String>,
    /// Fleet in-flight claims this daemon holds, by serve key.
    claims: HashMap<String, Lease>,
    /// Admission backlog behind a saturated search queue.
    backlog: Backlog<BacklogJob>,
    /// Decayed per-key request-rate sketch driving admission.
    heat: HeatSketch,
    metrics: ServeMetrics,
}

/// Everything a connection handler needs, shared across threads.
struct Ctx {
    shared: Mutex<Shared>,
    /// `None` once shutdown has begun.
    pool: Mutex<Option<WorkerPool>>,
    /// Set by a `shutdown` request: stop accepting connections.
    shutting: AtomicBool,
    /// Set after the drain completes: stops the claim heartbeat.
    stopped: AtomicBool,
    search: SearchConfig,
    addr: ServeAddr,
    inflight: InflightTable,
    log: Option<EventLog>,
}

/// A bound, running daemon (listener open, workers + writer started).
/// Call [`Daemon::run`] to serve until shutdown.
pub struct Daemon {
    listener: Listener,
    ctx: Arc<Ctx>,
    writer: JoinHandle<()>,
    heartbeat: JoinHandle<()>,
}

/// Handle to a daemon running on a background thread (in-process tests
/// and the fleet examples).
pub struct DaemonHandle {
    /// The resolved listen address (`tcp:...:0` becomes the real port).
    pub addr: ServeAddr,
    thread: JoinHandle<anyhow::Result<()>>,
}

impl DaemonHandle {
    /// Wait for the daemon to exit (after a `shutdown` request).
    pub fn join(self) -> anyhow::Result<()> {
        self.thread.join().map_err(|_| anyhow::anyhow!("daemon thread panicked"))?
    }
}

/// Distinguishes daemons within one process (tests spawn several), on
/// top of the pid that distinguishes processes on one host.
static DAEMON_SEQ: AtomicU64 = AtomicU64::new(0);

/// A globally-unique lease-holder id. The pid alone is NOT unique
/// across hosts or containers sharing one store volume (every
/// container's daemon can be pid 1), and two daemons with equal holder
/// strings would silently pass each other's lease checks — so a
/// startup-time nanosecond nonce disambiguates.
fn fresh_holder_id() -> String {
    let nonce = std::time::SystemTime::now()
        .duration_since(std::time::UNIX_EPOCH)
        .map(|d| d.as_nanos() as u64)
        .unwrap_or(0);
    format!(
        "daemon-{}-{}-{nonce:016x}",
        std::process::id(),
        DAEMON_SEQ.fetch_add(1, Ordering::Relaxed)
    )
}

impl Daemon {
    /// Open the store (fleet mode), start the worker pool + write-back
    /// + heartbeat threads, and bind the listen address. Clients can
    /// connect as soon as this returns.
    pub fn bind(cfg: DaemonConfig, log: Option<EventLog>) -> anyhow::Result<Daemon> {
        cfg.search.validate().map_err(anyhow::Error::msg)?;
        let holder = fresh_holder_id();
        let fleet = &cfg.search.fleet;
        // `fleet.coordinate = false` keeps a known-single-daemon
        // deployment on the in-memory + O_APPEND fast path: no lease
        // files, no per-miss claim I/O, no per-request refresh stat.
        let store = if fleet.coordinate {
            ShardedStore::open_fleet(
                &cfg.store_dir,
                cfg.search.serve.n_shards,
                &holder,
                fleet.lease_ttl_ms,
            )?
        } else {
            ShardedStore::open(&cfg.store_dir, cfg.search.serve.n_shards)?
        };
        let snapshot = Arc::new(store.snapshot());
        let inflight = InflightTable::open(&cfg.store_dir, &holder, fleet.lease_ttl_ms)?;

        let (tx, rx) = std::sync::mpsc::channel::<PoolEvent>();
        let pool =
            WorkerPool::with_sink(cfg.search.serve.n_workers, cfg.search.serve.queue_cap, tx);

        let (listener, addr) = Listener::bind(&cfg.addr)?;

        let ctx = Arc::new(Ctx {
            shared: Mutex::new(Shared {
                store,
                snapshot,
                pending: HashSet::new(),
                claims: HashMap::new(),
                backlog: Backlog::new(fleet.backlog_cap),
                heat: HeatSketch::new(fleet.heat_half_life, fleet.heat_keys_cap),
                metrics: ServeMetrics::default(),
            }),
            pool: Mutex::new(Some(pool)),
            shutting: AtomicBool::new(false),
            stopped: AtomicBool::new(false),
            search: cfg.search,
            addr,
            inflight,
            log,
        });
        let writer = {
            let ctx = ctx.clone();
            std::thread::spawn(move || writer_loop(&ctx, rx))
        };
        let heartbeat = {
            let ctx = ctx.clone();
            std::thread::spawn(move || heartbeat_loop(&ctx))
        };
        Ok(Daemon { listener, ctx, writer, heartbeat })
    }

    /// Bind and serve on a background thread.
    pub fn spawn(cfg: DaemonConfig, log: Option<EventLog>) -> anyhow::Result<DaemonHandle> {
        let daemon = Daemon::bind(cfg, log)?;
        let addr = daemon.ctx.addr.clone();
        let thread = std::thread::spawn(move || daemon.run());
        Ok(DaemonHandle { addr, thread })
    }

    /// The resolved listen address.
    pub fn addr(&self) -> &ServeAddr {
        &self.ctx.addr
    }

    /// Serve connections until a `shutdown` request arrives, then drain
    /// the worker pool, flush write-backs, release fleet claims, and
    /// remove a Unix socket file.
    pub fn run(self) -> anyhow::Result<()> {
        loop {
            match self.listener.accept() {
                Ok(stream) => {
                    if self.ctx.shutting.load(Ordering::SeqCst) {
                        break;
                    }
                    let ctx = self.ctx.clone();
                    std::thread::spawn(move || handle_connection(&ctx, stream));
                }
                Err(e) => {
                    if self.ctx.shutting.load(Ordering::SeqCst) {
                        break;
                    }
                    eprintln!("serve: accept failed: {e}");
                }
            }
        }
        // Drain: close the job queue, run queued searches to completion
        // (their write-backs land through the writer thread), then stop.
        // The heartbeat keeps renewing claims until the drain finishes,
        // so in-flight write-backs are not fenced out mid-shutdown.
        let pool = self.ctx.pool.lock().expect("pool lock").take();
        if let Some(pool) = pool {
            pool.finish();
        }
        let _ = self.writer.join();
        // Backlogged searches never ran: hand their keys back to the
        // fleet so another daemon's next miss claims them.
        {
            let mut shared = self.ctx.shared.lock().expect("shared lock");
            let Shared { backlog, claims, pending, .. } = &mut *shared;
            for (key, _job) in backlog.drain() {
                pending.remove(&key);
                if let Some(lease) = claims.remove(&key) {
                    let _ = lease.release();
                }
            }
        }
        self.ctx.stopped.store(true, Ordering::SeqCst);
        let _ = self.heartbeat.join();
        #[cfg(unix)]
        if let ServeAddr::Unix(path) = &self.ctx.addr {
            let _ = std::fs::remove_file(path);
        }
        Ok(())
    }
}

/// Claim heartbeat: renew this daemon's in-flight claims at ~TTL/3 so
/// they outlive multi-second searches. Runs until the drain completes
/// (not merely until `shutdown` arrives — queued searches still need
/// their claims). A claim that fails to renew stays in the map: the
/// write-back fence rejects its record, which is the correct outcome.
fn heartbeat_loop(ctx: &Ctx) {
    let interval =
        std::time::Duration::from_millis((ctx.search.fleet.lease_ttl_ms / 3).clamp(25, 2000));
    while !ctx.stopped.load(Ordering::SeqCst) {
        std::thread::sleep(interval);
        // Renew outside the shared lock — each renew is several file
        // ops and must not stall hit replies. A clone carries the same
        // (holder, epoch) identity, which is all renewal needs.
        let leases: Vec<Lease> = {
            let shared = ctx.shared.lock().expect("shared lock");
            shared.claims.values().cloned().collect()
        };
        for lease in &leases {
            let _ = lease.renew();
        }
    }
}

/// Write-back thread: append every finished search to the sharded
/// store (epoch-fenced by its fleet claim), emit the eviction audit,
/// refresh the worker snapshot, and pump the admission backlog into
/// the freed queue slot. A failed (panicked) search releases its
/// reservations so the next request for that key can retry instead of
/// coalescing into a dead search forever.
fn writer_loop(ctx: &Ctx, rx: Receiver<PoolEvent>) {
    for event in rx {
        let result = match event {
            PoolEvent::Done(result) => result,
            PoolEvent::Failed { name, cfg, workload, error, .. } => {
                let key = serve_key(
                    &workload.id(),
                    cfg.gpu.name(),
                    cfg.mode.name(),
                    &config_fingerprint(&cfg),
                );
                eprintln!("serve: background search '{name}' failed: {error}");
                {
                    let mut shared = ctx.shared.lock().expect("shared lock");
                    shared.pending.remove(&key);
                    if let Some(lease) = shared.claims.remove(&key) {
                        let _ = lease.release();
                    }
                }
                if let Some(log) = &ctx.log {
                    log.emit(
                        "job_search_failed",
                        vec![("key", Json::str(key)), ("error", Json::str(error))],
                    );
                }
                pump_backlog(ctx);
                continue;
            }
        };
        let rec = TuningRecord::from_outcome(&result.outcome, &result.cfg);
        let key = serve_key(&rec.workload_id, &rec.gpu, &rec.mode, &rec.fingerprint);
        let n_measurements = result.outcome.n_energy_measurements();
        let sim_time_s = result.outcome.clock.total_s;
        // Land the write-back without sleeping inside the shared lock:
        // lease contention (another member mid-eviction on this shard)
        // is waited out BETWEEN lock acquisitions, so hit replies keep
        // flowing while we retry.
        let mut accepted = false;
        let mut fenced = false;
        for attempt in 0..8 {
            if attempt > 0 {
                std::thread::sleep(std::time::Duration::from_millis(100));
            }
            let outcome = {
                let mut shared = ctx.shared.lock().expect("shared lock");
                let Shared { store, claims, .. } = &mut *shared;
                match claims.get(&key) {
                    Some(lease) => store.try_append_claimed(rec.clone(), lease),
                    None => store.try_append(rec.clone()),
                }
            };
            match outcome {
                Ok(AppendOutcome::Appended) => {
                    accepted = true;
                    break;
                }
                Ok(AppendOutcome::FencedOut) => {
                    fenced = true;
                    break;
                }
                Ok(AppendOutcome::LeaseBusy) => {}
                Err(e) => {
                    eprintln!("serve: write-back failed for {key}: {e:#}");
                    break;
                }
            }
        }
        if fenced {
            eprintln!(
                "serve: write-back for {key} rejected (stale fleet claim — another daemon \
                 reclaimed the key)"
            );
        } else if !accepted {
            eprintln!("serve: write-back for {key} dropped (shard lease stayed busy)");
        }
        let mut evict = EvictionReport::default();
        let claim = {
            let mut shared = ctx.shared.lock().expect("shared lock");
            if accepted {
                match shared.store.enforce_limits(
                    ctx.search.serve.per_gpu_quota,
                    ctx.search.serve.max_records,
                ) {
                    Ok(report) => evict = report,
                    Err(e) => eprintln!("serve: eviction failed: {e:#}"),
                }
            }
            shared.metrics.n_searches_done += 1;
            shared.metrics.measurements_paid += n_measurements;
            shared.metrics.n_evicted_records += evict.n_evicted;
            shared.pending.remove(&key);
            shared.snapshot = Arc::new(shared.store.snapshot());
            shared.claims.remove(&key)
        };
        // Released only now — after the record is durably appended — so
        // another daemon's claim can never race ahead of the data.
        if let Some(lease) = claim {
            let _ = lease.release();
        }
        if let Some(log) = &ctx.log {
            log.emit(
                "job_search_done",
                vec![
                    ("key", Json::str(key)),
                    ("n_energy_measurements", Json::num(n_measurements as f64)),
                    ("sim_time_s", Json::num(sim_time_s)),
                    ("evicted_records", Json::num(evict.n_evicted as f64)),
                    ("accepted", Json::Bool(accepted)),
                ],
            );
            for victim in &evict.victims {
                log.emit(
                    "job_evicted",
                    vec![
                        ("key", Json::str(victim.key.clone())),
                        ("reason", Json::str(victim.reason)),
                        ("shard", Json::num(victim.shard as f64)),
                        ("records", Json::num(victim.n_records as f64)),
                    ],
                );
            }
        }
        pump_backlog(ctx);
    }
}

/// Move backlogged searches into the worker queue, hottest first,
/// until the queue refuses or the backlog empties.
fn pump_backlog(ctx: &Ctx) {
    loop {
        let popped = {
            let mut shared = ctx.shared.lock().expect("shared lock");
            let Shared { backlog, heat, .. } = &mut *shared;
            backlog.pop_hottest(heat)
        };
        let Some((key, (job, snapshot))) = popped else { return };
        let submitted = {
            let mut pool = ctx.pool.lock().expect("pool lock");
            match pool.as_mut() {
                Some(p) => p.try_submit_with_snapshot(job.clone(), Some(snapshot.clone())),
                None => false, // shutting down: run() releases the claims
            }
        };
        if submitted {
            if let Some(log) = &ctx.log {
                log.emit(
                    "job_enqueued",
                    vec![("key", Json::str(key)), ("via", Json::str("backlog"))],
                );
            }
        } else {
            let mut shared = ctx.shared.lock().expect("shared lock");
            shared.backlog.restore(key, (job, snapshot));
            return;
        }
    }
}

/// One connection: serve frames until the client disconnects (or asks
/// for shutdown).
fn handle_connection(ctx: &Ctx, stream: Stream) {
    let reader = match stream.try_clone() {
        Ok(s) => BufReader::new(s),
        Err(e) => {
            eprintln!("serve: connection clone failed: {e}");
            return;
        }
    };
    let mut out = stream;
    for line in reader.lines() {
        let line = match line {
            Ok(l) => l,
            Err(_) => break, // client gone
        };
        if line.trim().is_empty() {
            continue;
        }
        let (frame, shutdown) = handle_frame(ctx, &line);
        if writeln!(out, "{frame}").is_err() {
            break;
        }
        let _ = out.flush();
        if shutdown {
            ctx.shutting.store(true, Ordering::SeqCst);
            // Wake the accept loop with a throwaway connection.
            let _ = Stream::connect(&ctx.addr);
            break;
        }
    }
}

/// Dispatch one request frame; returns (response frame, shutdown?).
fn handle_frame(ctx: &Ctx, line: &str) -> (Json, bool) {
    match Request::parse_line(line) {
        Err(rej) => (rej.to_json(), false),
        Ok(Request::Shutdown { id }) => (Response::ShutdownAck { id }.to_json(), true),
        Ok(Request::Stats { id }) => (stats_reply(ctx, id).to_json(), false),
        Ok(Request::GetKernel { id, workload, gpu, mode }) => {
            (serve_get_kernel(ctx, id, workload, gpu, mode).to_json(), false)
        }
    }
}

fn stats_reply(ctx: &Ctx, id: String) -> StatsReply {
    // Counts reflect what this daemon has ingested: the miss path's
    // per-key refresh pulls foreign write-backs in as they are
    // requested. No full-store refresh here — stats is polled in tight
    // loops (wait_for_drain) and must not stall hit replies behind an
    // all-shard disk scan under the shared lock.
    let shared = ctx.shared.lock().expect("shared lock");
    StatsReply {
        id,
        n_requests: shared.metrics.n_requests,
        n_hits: shared.metrics.n_hits,
        n_misses: shared.metrics.n_misses,
        n_enqueued: shared.metrics.n_enqueued,
        n_searches_done: shared.metrics.n_searches_done,
        n_evicted_records: shared.metrics.n_evicted_records,
        queue_depth: shared.pending.len(),
        n_records: shared.store.len(),
        n_shards: shared.store.n_shards(),
        hit_rate: shared.metrics.hit_rate(),
        p50_reply_s: shared.metrics.p50_reply_s(),
        p99_reply_s: shared.metrics.p99_reply_s(),
        measurements_paid: shared.metrics.measurements_paid,
        n_shed: shared.metrics.n_shed,
        n_fleet_coalesced: shared.metrics.n_fleet_coalesced,
        backlog_len: shared.backlog.len(),
        shard_records: shared.store.shard_sizes(),
        heat_histogram: shared.heat.histogram().to_vec(),
    }
}

fn serve_get_kernel(
    ctx: &Ctx,
    id: String,
    workload: Workload,
    gpu: Option<crate::config::GpuArch>,
    mode: Option<crate::config::SearchMode>,
) -> KernelReply {
    // The effective search config of this request: template + overrides.
    // Workers never write back themselves — the daemon owns the store.
    let mut cfg = ctx.search.clone();
    if let Some(g) = gpu {
        cfg.gpu = g;
    }
    if let Some(m) = mode {
        cfg.mode = m;
    }
    cfg.store.dir = None;
    cfg.store.write_back = false;
    let key = serve_key(&workload.id(), cfg.gpu.name(), cfg.mode.name(), &config_fingerprint(&cfg));

    let mut shared = ctx.shared.lock().expect("shared lock");
    shared.heat.touch(&key);
    // Fleet refresh: a search another daemon wrote back since we last
    // looked at this shard turns this request into a plain hit.
    match shared.store.refresh_key(&key) {
        Ok(0) => {}
        Ok(_) => shared.snapshot = Arc::new(shared.store.snapshot()),
        Err(e) => eprintln!("serve: shard refresh failed for {key}: {e:#}"),
    }
    let shard_len = shared.store.shard_len_for(&key);

    // Exact hit: reply with the recorded kernel, zero cost.
    let hit = shared
        .store
        .get(workload, &cfg)
        .map(|r| (r.best.schedule, r.best.latency_s, r.best.energy_j, r.best.avg_power_w));
    if let Some((schedule, latency_s, energy_j, avg_power_w)) = hit {
        if let Err(e) = shared.store.mark_served(&key) {
            eprintln!("serve: LRU touch failed for {key}: {e:#}");
        }
        let t = reply_time_s(true, shard_len);
        shared.metrics.record_reply(true, t);
        let queue_depth = shared.pending.len();
        drop(shared);
        emit_served(ctx, &key, "hit", ServeSource::Store, t);
        return KernelReply {
            id,
            hit: true,
            source: ServeSource::Store,
            schedule,
            latency_s,
            energy_j,
            avg_power_w,
            enqueued: false,
            queue_depth,
            reply_time_s: t,
        };
    }

    // Miss: best warm guess now, real search in the background.
    let spec = cfg.gpu.spec();
    let space = ScheduleSpace::new(workload, &spec);
    let guess = {
        let neighbors = shared.store.neighbors(workload, cfg.gpu.name(), 1);
        neighbors
            .first()
            .filter(|(_, dist)| *dist <= MAX_TRANSFER_DISTANCE)
            .and_then(|(rec, _)| {
                relegalize(&rec.best.schedule, &space).map(|s| {
                    let scale = workload.gemm_view().macs() as f64
                        / (rec.workload.gemm_view().macs() as f64).max(1.0);
                    (s, rec.best.latency_s * scale, rec.best.energy_j * scale, rec.best.avg_power_w)
                })
            })
    };
    let (schedule, source, latency_s, energy_j, avg_power_w) = match guess {
        Some((s, lat, en, pw)) => (s, ServeSource::WarmGuess, lat, en, pw),
        // 0.0 = unknown: no neighbor close enough to estimate from.
        None => (space.fallback(), ServeSource::Fallback, 0.0, 0.0, 0.0),
    };

    // Who searches this key? Local duplicates coalesce on `pending`;
    // fleet duplicates coalesce on the in-store claim. The claim is
    // several file ops plus a settle pause, so it runs OUTSIDE the
    // shared lock — a burst of cold misses must not stall concurrent
    // hit replies.
    let mut reserve = false;
    if !shared.pending.contains(&key) {
        if ctx.search.fleet.coordinate {
            drop(shared);
            let attempt = ctx.inflight.claim(&key);
            shared = ctx.shared.lock().expect("shared lock");
            match attempt {
                Ok(Some(lease)) => {
                    // Concurrent requests for this key may both have
                    // claimed while unlocked (same holder — each
                    // reacquire bumps the epoch). Only the NEWEST
                    // epoch matches the claim file, so that is the
                    // lease the write-back fence must check — and
                    // map-insert order follows lock reacquisition
                    // order, not claim order, so compare explicitly.
                    let raced = shared.pending.contains(&key);
                    let newest = match shared.claims.get(&key) {
                        Some(held) => lease.epoch() > held.epoch(),
                        None => true,
                    };
                    if newest {
                        shared.claims.insert(key.clone(), lease);
                    }
                    reserve = !raced;
                }
                Ok(None) => {
                    if !shared.pending.contains(&key) {
                        // Another daemon is already searching this key:
                        // serve the warm guess, its write-back lands.
                        shared.metrics.n_fleet_coalesced += 1;
                    }
                }
                Err(e) => {
                    if !shared.pending.contains(&key) {
                        eprintln!(
                            "serve: in-flight claim failed for {key}: {e:#} (running unfenced)"
                        );
                        reserve = true;
                    }
                }
            }
        } else {
            // Uncoordinated (single-owner) mode: nothing to claim.
            reserve = true;
        }
    }
    if reserve {
        shared.pending.insert(key.clone());
        shared.metrics.n_enqueued += 1;
    }
    let snapshot = shared.snapshot.clone();
    let queue_depth = shared.pending.len();
    let t = reply_time_s(false, shard_len);
    shared.metrics.record_reply(false, t);
    drop(shared);

    // The reply reports what actually happened: `enqueued` means the
    // search was admitted (worker queue or heat-ordered backlog). A
    // saturated daemon sheds the coldest key instead — a shed key's
    // claim is released so any daemon's next request for it retries.
    let mut enqueued = false;
    let mut shed_event: Option<(String, &'static str)> = None;
    let mut via = "queue";
    if reserve {
        let job = SearchJob { name: key.clone(), workload, cfg };
        let direct = {
            let mut pool = ctx.pool.lock().expect("pool lock");
            match pool.as_mut() {
                Some(p) => p.try_submit_with_snapshot(job.clone(), Some(snapshot.clone())),
                None => false, // shutting down
            }
        };
        if direct {
            enqueued = true;
        } else {
            let mut shared = ctx.shared.lock().expect("shared lock");
            let Shared { backlog, heat, pending, claims, metrics, .. } = &mut *shared;
            match backlog.offer(key.clone(), (job, snapshot), heat) {
                Offer::Queued => {
                    enqueued = true;
                    via = "backlog";
                }
                Offer::Displaced { key: shed_key, .. } => {
                    enqueued = true;
                    via = "backlog";
                    pending.remove(&shed_key);
                    metrics.n_enqueued -= 1;
                    metrics.n_shed += 1;
                    if let Some(lease) = claims.remove(&shed_key) {
                        let _ = lease.release();
                    }
                    shed_event = Some((shed_key, "displaced_by_hotter_key"));
                }
                Offer::Rejected { key: cold_key, .. } => {
                    pending.remove(&cold_key);
                    metrics.n_enqueued -= 1;
                    metrics.n_shed += 1;
                    if let Some(lease) = claims.remove(&cold_key) {
                        let _ = lease.release();
                    }
                    shed_event = Some((cold_key, "colder_than_backlog"));
                }
            }
        }
    }
    if let Some(log) = &ctx.log {
        if enqueued {
            log.emit(
                "job_enqueued",
                vec![
                    ("key", Json::str(key.clone())),
                    ("queue_depth", Json::num(queue_depth as f64)),
                    ("via", Json::str(via)),
                ],
            );
        }
        if let Some((shed_key, reason)) = shed_event {
            log.emit(
                "job_shed",
                vec![("key", Json::str(shed_key)), ("reason", Json::str(reason))],
            );
        }
    }
    emit_served(ctx, &key, "miss", source, t);
    KernelReply {
        id,
        hit: false,
        source,
        schedule,
        latency_s,
        energy_j,
        avg_power_w,
        enqueued,
        queue_depth,
        reply_time_s: t,
    }
}

fn emit_served(ctx: &Ctx, key: &str, result: &str, source: ServeSource, reply_time: f64) {
    if let Some(log) = &ctx.log {
        log.emit(
            "job_served",
            vec![
                ("key", Json::str(key)),
                ("result", Json::str(result)),
                ("source", Json::str(source.name())),
                ("reply_time_s", Json::num(reply_time)),
                ("protocol_v", Json::num(PROTOCOL_VERSION as f64)),
            ],
        );
    }
}
