//! The kernel-serving daemon: a long-running process answering
//! `get_kernel` requests over a Unix-domain socket.
//!
//! Request flow:
//!
//! * **exact store hit** — reply immediately with the cached,
//!   NVML-measured kernel (zero measurements, zero search time);
//! * **miss** — reply immediately with the best warm guess (nearest
//!   neighbor's schedule re-legalized for the requested shape, or the
//!   space's fallback), and enqueue a real search on the daemon-owned
//!   [`WorkerPool`]. The finished search is written back into the
//!   sharded store, so the next request for that key is a hit.
//!   Duplicate in-flight keys coalesce into one search.
//!
//! Background searches consult a shared parsed snapshot of the store
//! (parse-once plumbing) and warm-start from cached neighbors exactly
//! like `search --store`; eviction quotas run after every write-back.

use super::metrics::{reply_time_s, ServeMetrics};
use super::protocol::{KernelReply, Request, Response, ServeSource, StatsReply, PROTOCOL_VERSION};
use crate::config::SearchConfig;
use crate::coordinator::{EventLog, PoolEvent, SearchJob, WorkerPool};
use crate::schedule::space::ScheduleSpace;
use crate::store::transfer::{relegalize, MAX_TRANSFER_DISTANCE};
use crate::store::{config_fingerprint, serve_key, ShardedStore, TuningRecord, TuningStore};
use crate::util::Json;
use crate::workload::Workload;
use anyhow::Context as _;
use std::collections::HashSet;
use std::io::{BufRead as _, BufReader, Write as _};
use std::os::unix::net::{UnixListener, UnixStream};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::Receiver;
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;

/// Daemon configuration: where to listen, where the store lives, and
/// the search template requests run under (per-request `gpu`/`mode`
/// overrides apply on top; the `[serve]` section sets shard count,
/// eviction quotas, and the worker pool size).
#[derive(Debug, Clone)]
pub struct DaemonConfig {
    pub socket_path: PathBuf,
    pub store_dir: PathBuf,
    pub search: SearchConfig,
}

/// Mutable daemon state behind one lock.
struct Shared {
    store: ShardedStore,
    /// Parsed snapshot handed to background searches; rebuilt after
    /// every write-back.
    snapshot: Arc<TuningStore>,
    /// Serve keys with a search enqueued or running.
    pending: HashSet<String>,
    metrics: ServeMetrics,
}

/// Everything a connection handler needs, shared across threads.
struct Ctx {
    shared: Mutex<Shared>,
    /// `None` once shutdown has begun.
    pool: Mutex<Option<WorkerPool>>,
    shutting: AtomicBool,
    search: SearchConfig,
    socket_path: PathBuf,
    log: Option<EventLog>,
}

/// A bound, running daemon (listener open, workers + writer started).
/// Call [`Daemon::run`] to serve until shutdown.
pub struct Daemon {
    listener: UnixListener,
    ctx: Arc<Ctx>,
    writer: JoinHandle<()>,
}

/// Handle to a daemon running on a background thread (in-process tests
/// and the serving-fleet example).
pub struct DaemonHandle {
    pub socket_path: PathBuf,
    thread: JoinHandle<anyhow::Result<()>>,
}

impl DaemonHandle {
    /// Wait for the daemon to exit (after a `shutdown` request).
    pub fn join(self) -> anyhow::Result<()> {
        self.thread.join().map_err(|_| anyhow::anyhow!("daemon thread panicked"))?
    }
}

impl Daemon {
    /// Open the store, start the worker pool + write-back thread, and
    /// bind the socket (removing a stale socket file first). Clients
    /// can connect as soon as this returns.
    pub fn bind(cfg: DaemonConfig, log: Option<EventLog>) -> anyhow::Result<Daemon> {
        cfg.search.validate().map_err(anyhow::Error::msg)?;
        let store = ShardedStore::open(&cfg.store_dir, cfg.search.serve.n_shards)?;
        let snapshot = Arc::new(store.snapshot());

        let (tx, rx) = std::sync::mpsc::channel::<PoolEvent>();
        let pool =
            WorkerPool::with_sink(cfg.search.serve.n_workers, cfg.search.serve.queue_cap, tx);

        if cfg.socket_path.exists() {
            // A connectable socket means a live daemon: refuse to steal
            // its endpoint (two daemons would corrupt one store). Only
            // a dead (stale) socket file is removed.
            if UnixStream::connect(&cfg.socket_path).is_ok() {
                anyhow::bail!(
                    "a daemon is already serving on {:?} (shut it down first)",
                    cfg.socket_path
                );
            }
            std::fs::remove_file(&cfg.socket_path)
                .with_context(|| format!("remove stale socket {:?}", cfg.socket_path))?;
        }
        let listener = UnixListener::bind(&cfg.socket_path)
            .with_context(|| format!("bind {:?}", cfg.socket_path))?;

        let ctx = Arc::new(Ctx {
            shared: Mutex::new(Shared {
                store,
                snapshot,
                pending: HashSet::new(),
                metrics: ServeMetrics::default(),
            }),
            pool: Mutex::new(Some(pool)),
            shutting: AtomicBool::new(false),
            search: cfg.search,
            socket_path: cfg.socket_path,
            log,
        });
        let writer = {
            let ctx = ctx.clone();
            std::thread::spawn(move || writer_loop(&ctx, rx))
        };
        Ok(Daemon { listener, ctx, writer })
    }

    /// Bind and serve on a background thread.
    pub fn spawn(cfg: DaemonConfig, log: Option<EventLog>) -> anyhow::Result<DaemonHandle> {
        let daemon = Daemon::bind(cfg, log)?;
        let socket_path = daemon.ctx.socket_path.clone();
        let thread = std::thread::spawn(move || daemon.run());
        Ok(DaemonHandle { socket_path, thread })
    }

    pub fn socket_path(&self) -> &Path {
        &self.ctx.socket_path
    }

    /// Serve connections until a `shutdown` request arrives, then drain
    /// the worker pool, flush write-backs, and remove the socket file.
    pub fn run(self) -> anyhow::Result<()> {
        for stream in self.listener.incoming() {
            if self.ctx.shutting.load(Ordering::SeqCst) {
                break;
            }
            match stream {
                Ok(stream) => {
                    let ctx = self.ctx.clone();
                    std::thread::spawn(move || handle_connection(&ctx, stream));
                }
                Err(e) => eprintln!("serve: accept failed: {e}"),
            }
        }
        // Drain: close the job queue, run queued searches to completion
        // (their write-backs land through the writer thread), then stop.
        let pool = self.ctx.pool.lock().expect("pool lock").take();
        if let Some(pool) = pool {
            pool.finish();
        }
        let _ = self.writer.join();
        let _ = std::fs::remove_file(&self.ctx.socket_path);
        Ok(())
    }
}

/// Write-back thread: append every finished search to the sharded
/// store, enforce eviction quotas, refresh the worker snapshot. A
/// failed (panicked) search releases its in-flight reservation so the
/// next request for that key can retry instead of coalescing into a
/// dead search forever.
fn writer_loop(ctx: &Ctx, rx: Receiver<PoolEvent>) {
    for event in rx {
        let result = match event {
            PoolEvent::Done(result) => result,
            PoolEvent::Failed { name, cfg, workload, error, .. } => {
                let key = serve_key(
                    &workload.id(),
                    cfg.gpu.name(),
                    cfg.mode.name(),
                    &config_fingerprint(&cfg),
                );
                eprintln!("serve: background search '{name}' failed: {error}");
                ctx.shared.lock().expect("shared lock").pending.remove(&key);
                if let Some(log) = &ctx.log {
                    log.emit(
                        "job_search_failed",
                        vec![("key", Json::str(key)), ("error", Json::str(error))],
                    );
                }
                continue;
            }
        };
        let rec = TuningRecord::from_outcome(&result.outcome, &result.cfg);
        let key = serve_key(&rec.workload_id, &rec.gpu, &rec.mode, &rec.fingerprint);
        let n_measurements = result.outcome.n_energy_measurements();
        let sim_time_s = result.outcome.clock.total_s;
        let mut evicted = 0;
        {
            let mut shared = ctx.shared.lock().expect("shared lock");
            if let Err(e) = shared.store.append(rec) {
                eprintln!("serve: write-back failed for {key}: {e:#}");
            }
            match shared
                .store
                .enforce_limits(ctx.search.serve.per_gpu_quota, ctx.search.serve.max_records)
            {
                Ok(n) => evicted = n,
                Err(e) => eprintln!("serve: eviction failed: {e:#}"),
            }
            shared.metrics.n_searches_done += 1;
            shared.metrics.measurements_paid += n_measurements;
            shared.metrics.n_evicted_records += evicted;
            shared.pending.remove(&key);
            shared.snapshot = Arc::new(shared.store.snapshot());
        }
        if let Some(log) = &ctx.log {
            log.emit(
                "job_search_done",
                vec![
                    ("key", Json::str(key)),
                    ("n_energy_measurements", Json::num(n_measurements as f64)),
                    ("sim_time_s", Json::num(sim_time_s)),
                    ("evicted_records", Json::num(evicted as f64)),
                ],
            );
        }
    }
}

/// One connection: serve frames until the client disconnects (or asks
/// for shutdown).
fn handle_connection(ctx: &Ctx, stream: UnixStream) {
    let reader = match stream.try_clone() {
        Ok(s) => BufReader::new(s),
        Err(e) => {
            eprintln!("serve: connection clone failed: {e}");
            return;
        }
    };
    let mut out = stream;
    for line in reader.lines() {
        let line = match line {
            Ok(l) => l,
            Err(_) => break, // client gone
        };
        if line.trim().is_empty() {
            continue;
        }
        let (frame, shutdown) = handle_frame(ctx, &line);
        if writeln!(out, "{frame}").is_err() {
            break;
        }
        let _ = out.flush();
        if shutdown {
            ctx.shutting.store(true, Ordering::SeqCst);
            // Wake the accept loop with a throwaway connection.
            let _ = UnixStream::connect(&ctx.socket_path);
            break;
        }
    }
}

/// Dispatch one request frame; returns (response frame, shutdown?).
fn handle_frame(ctx: &Ctx, line: &str) -> (Json, bool) {
    match Request::parse_line(line) {
        Err(rej) => (rej.to_json(), false),
        Ok(Request::Shutdown { id }) => {
            (Response::ShutdownAck { id }.to_json(), true)
        }
        Ok(Request::Stats { id }) => (stats_reply(ctx, id).to_json(), false),
        Ok(Request::GetKernel { id, workload, gpu, mode }) => {
            (serve_get_kernel(ctx, id, workload, gpu, mode).to_json(), false)
        }
    }
}

fn stats_reply(ctx: &Ctx, id: String) -> StatsReply {
    let shared = ctx.shared.lock().expect("shared lock");
    StatsReply {
        id,
        n_requests: shared.metrics.n_requests,
        n_hits: shared.metrics.n_hits,
        n_misses: shared.metrics.n_misses,
        n_enqueued: shared.metrics.n_enqueued,
        n_searches_done: shared.metrics.n_searches_done,
        n_evicted_records: shared.metrics.n_evicted_records,
        queue_depth: shared.pending.len(),
        n_records: shared.store.len(),
        n_shards: shared.store.n_shards(),
        hit_rate: shared.metrics.hit_rate(),
        p50_reply_s: shared.metrics.p50_reply_s(),
        p99_reply_s: shared.metrics.p99_reply_s(),
        measurements_paid: shared.metrics.measurements_paid,
    }
}

fn serve_get_kernel(
    ctx: &Ctx,
    id: String,
    workload: Workload,
    gpu: Option<crate::config::GpuArch>,
    mode: Option<crate::config::SearchMode>,
) -> KernelReply {
    // The effective search config of this request: template + overrides.
    // Workers never write back themselves — the daemon owns the store.
    let mut cfg = ctx.search.clone();
    if let Some(g) = gpu {
        cfg.gpu = g;
    }
    if let Some(m) = mode {
        cfg.mode = m;
    }
    cfg.store.dir = None;
    cfg.store.write_back = false;
    let key = serve_key(&workload.id(), cfg.gpu.name(), cfg.mode.name(), &config_fingerprint(&cfg));

    let mut shared = ctx.shared.lock().expect("shared lock");
    let shard_len = shared.store.shard_len_for(&key);

    // Exact hit: reply with the recorded kernel, zero cost.
    let hit = shared
        .store
        .get(workload, &cfg)
        .map(|r| (r.best.schedule, r.best.latency_s, r.best.energy_j, r.best.avg_power_w));
    if let Some((schedule, latency_s, energy_j, avg_power_w)) = hit {
        if let Err(e) = shared.store.mark_served(&key) {
            eprintln!("serve: LRU touch failed for {key}: {e:#}");
        }
        let t = reply_time_s(true, shard_len);
        shared.metrics.record_reply(true, t);
        let queue_depth = shared.pending.len();
        drop(shared);
        emit_served(ctx, &key, "hit", ServeSource::Store, t);
        return KernelReply {
            id,
            hit: true,
            source: ServeSource::Store,
            schedule,
            latency_s,
            energy_j,
            avg_power_w,
            enqueued: false,
            queue_depth,
            reply_time_s: t,
        };
    }

    // Miss: best warm guess now, real search in the background.
    let spec = cfg.gpu.spec();
    let space = ScheduleSpace::new(workload, &spec);
    let guess = {
        let neighbors = shared.store.neighbors(workload, cfg.gpu.name(), 1);
        neighbors
            .first()
            .filter(|(_, dist)| *dist <= MAX_TRANSFER_DISTANCE)
            .and_then(|(rec, _)| {
                relegalize(&rec.best.schedule, &space).map(|s| {
                    let scale = workload.gemm_view().macs() as f64
                        / (rec.workload.gemm_view().macs() as f64).max(1.0);
                    (s, rec.best.latency_s * scale, rec.best.energy_j * scale, rec.best.avg_power_w)
                })
            })
    };
    let (schedule, source, latency_s, energy_j, avg_power_w) = match guess {
        Some((s, lat, en, pw)) => (s, ServeSource::WarmGuess, lat, en, pw),
        // 0.0 = unknown: no neighbor close enough to estimate from.
        None => (space.fallback(), ServeSource::Fallback, 0.0, 0.0, 0.0),
    };
    let reserve = !shared.pending.contains(&key);
    if reserve {
        shared.pending.insert(key.clone());
        shared.metrics.n_enqueued += 1;
    }
    let snapshot = shared.snapshot.clone();
    let queue_depth = shared.pending.len();
    let t = reply_time_s(false, shard_len);
    shared.metrics.record_reply(false, t);
    drop(shared);

    // The reply reports what actually happened: a reservation that
    // cannot be submitted — search queue full (load-shedding: the miss
    // reply must never wait on a multi-minute search slot) or daemon
    // shutting down — is rolled back and reported as not enqueued. A
    // shed key is retried by the next request for it.
    let mut enqueued = false;
    if reserve {
        let job = SearchJob { name: key.clone(), workload, cfg };
        enqueued = {
            let mut pool = ctx.pool.lock().expect("pool lock");
            match pool.as_mut() {
                Some(p) => p.try_submit_with_snapshot(job, Some(snapshot)),
                None => false, // shutting down
            }
        };
        if enqueued {
            if let Some(log) = &ctx.log {
                log.emit(
                    "job_enqueued",
                    vec![
                        ("key", Json::str(key.clone())),
                        ("queue_depth", Json::num(queue_depth as f64)),
                    ],
                );
            }
        } else {
            let mut shared = ctx.shared.lock().expect("shared lock");
            shared.pending.remove(&key);
            shared.metrics.n_enqueued -= 1;
        }
    }
    emit_served(ctx, &key, "miss", source, t);
    KernelReply {
        id,
        hit: false,
        source,
        schedule,
        latency_s,
        energy_j,
        avg_power_w,
        enqueued,
        queue_depth,
        reply_time_s: t,
    }
}

fn emit_served(ctx: &Ctx, key: &str, result: &str, source: ServeSource, reply_time: f64) {
    if let Some(log) = &ctx.log {
        log.emit(
            "job_served",
            vec![
                ("key", Json::str(key)),
                ("result", Json::str(result)),
                ("source", Json::str(source.name())),
                ("reply_time_s", Json::num(reply_time)),
                ("protocol_v", Json::num(PROTOCOL_VERSION as f64)),
            ],
        );
    }
}
